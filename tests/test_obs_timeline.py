"""Chrome-trace timelines (DESIGN.md §16): the exported catapult JSON is
structurally valid, the l=2 staged schedule track shows >= l reduction
windows genuinely overlapping vector/halo/hop work (the ISSUE 7
acceptance figure), and replay timelines are byte-deterministic.

The 8-device staged export runs in a subprocess (device count must be
set before jax imports), following tests/test_distributed.py."""

import json
import os
import subprocess
import sys

import numpy as np

from repro.linalg import operators as ops_mod
from repro.obs import Timeline, replay_timeline
from repro.obs.timeline import PID_SCHEDULE, hlo_schedule_track
from repro.parallel import get_backend
from repro.serve import SolverService, VirtualClock
from repro.serve.replay import TrafficClass, poisson_trace, replay
from repro.utils.trace import ChainEvent, OverlapReport

ENV = dict(os.environ, PYTHONPATH="src")
ENV.pop("XLA_FLAGS", None)


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=os.getcwd(), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


# ---------------------------------------------------------------- timeline --

def test_timeline_chrome_trace_structure(tmp_path):
    tl = Timeline()
    with tl.span("phase-a"):
        pass
    tl.instant("evt", ts_s=0.5)
    tl.counter("c", ts_s=0.5, values={"v": 1})
    doc = tl.to_chrome_trace()
    assert "kernel_mode" in doc["metadata"]
    assert "time_bases" in doc["metadata"]
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert {"X", "i", "C", "M"} <= phs
    p = tl.save(str(tmp_path / "t.json"))
    with open(p) as f:
        json.load(f)                        # loads in chrome://tracing


def test_hlo_schedule_track_renders_chains():
    """Synthetic report -> one reduction span per chain (position units),
    halo/hop instants, vector-phase spans between window starts."""
    events = [
        ChainEvent("start", 0, 0, "all-reduce", "s0"),
        ChainEvent("halo", 0, 1, "collective-permute", "h0"),
        ChainEvent("start", 1, 2, "all-reduce", "s1"),
        ChainEvent("wait", 0, 3, "fusion", "w0"),
        ChainEvent("wait", 1, 4, "fusion", "w1"),
    ]
    rep = OverlapReport(
        l=2, window=2, events=events, chains=[(0, 0, 3), (1, 2, 4)],
        max_in_flight=2, n_collectives=2, collective_bytes=0,
        starts_per_window={0: 1, 1: 1}, n_halo_permutes=1,
        halos_in_flight=1, reduce_hops_per_window={},
        staged_starts_per_window={}, n_reduce_hops=0, hops_in_flight=0)
    tl = hlo_schedule_track(rep)
    spans = [e for e in tl.events if e.get("ph") == "X"
             and e.get("cat") == "reduction"]
    assert len(spans) == 2
    assert spans[0]["ts"] == 0 and spans[0]["dur"] == 3
    halos = [e for e in tl.events if e.get("cat") == "halo"]
    assert len(halos) == 1 and halos[0]["ts"] == 1
    # the halo instant lands INSIDE reduction chain 0's span: overlap
    assert spans[0]["ts"] < halos[0]["ts"] < spans[0]["ts"] + spans[0]["dur"]
    assert tl.meta["hlo_schedule"]["units"].startswith("instruction")


def _overlapped(span, events):
    t0, t1 = span["ts"], span["ts"] + span["dur"]
    return [e for e in events if t0 <= e["ts"] <= t1]


def test_staged_l2_timeline_shows_overlapped_reduction_windows(tmp_path):
    """ISSUE 7 acceptance: the exported Chrome trace of an l=2 staged
    solve on the 8-device mesh contains >= l reduction-window spans each
    overlapping vector-phase/halo/hop events, and the file is valid
    catapult JSON."""
    path = tmp_path / "staged_l2.json"
    out = _run(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.parallel import get_backend
from repro.linalg import Stencil2D5
from repro.core.chebyshev import shifts_for_operator
from repro.obs import solve_timeline
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(3).standard_normal(op.n))
be = get_backend("shard_map", n_shards=8, reduction="staged")
tl, res = solve_timeline(be, op, b, l=2, sigmas=shifts_for_operator(op, 2),
                         tol=1e-10, maxit=400, telemetry_cap=128)
assert res.telemetry is not None and bool(res.converged)
tl.save({str(path)!r})
print("TIMELINE-SAVED")
""")
    assert "TIMELINE-SAVED" in out
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    sched = [e for e in evs if e.get("pid") == PID_SCHEDULE]
    red = [e for e in sched if e.get("cat") == "reduction"]
    assert len(red) >= 2, [e["name"] for e in sched]
    work = [e for e in sched
            if e.get("cat") in ("vector", "halo", "hop") and "ts" in e]
    n_overlapped = sum(bool(_overlapped(s, work)) for s in red)
    assert n_overlapped >= 2, (len(red), n_overlapped)
    # honesty metadata rode along
    assert doc["metadata"]["kernel_mode"] in ("interpret", "compiled")
    assert doc["metadata"]["hlo_schedule"]["l"] == 2
    assert doc["metadata"]["solver"]["backend"] == "shard_map"
    # measured host phases and the telemetry track are merged in
    assert any(e.get("ph") == "X" and e.get("pid") == 1 for e in evs)
    assert any(e.get("ph") == "C" and e.get("pid") == 3 for e in evs)


# ------------------------------------------------------------------ replay --

def _replay_once():
    op = ops_mod.Stencil2D5(8, 8)
    svc = SolverService(get_backend("local"), s=2, method="plcg", l=2,
                        chunk_iters=40, maxit=300, clock=VirtualClock())
    svc.register_operator("lap", op)
    classes = [TrafficClass(op_key="lap", n=op.n, tol=1e-8,
                            deadline_s=0.5)]
    trace = poisson_trace(classes, rate_per_s=50.0, n_requests=10, seed=4)
    rep = replay(svc, trace, iter_time_s=1e-4, tick_overhead_s=1e-4)
    return svc, rep


def test_replay_timeline_deterministic(tmp_path):
    """Two same-seed replays on fresh services export byte-identical
    timeline JSON (virtual clock: pure arithmetic)."""
    paths = []
    for k in range(2):
        svc, rep = _replay_once()
        tl = replay_timeline(svc, rep)
        p = str(tmp_path / f"replay{k}.json")
        tl.save(p)
        paths.append(p)
    b0, b1 = (open(p, "rb").read() for p in paths)
    assert b0 == b1
    doc = json.loads(b0)
    spans = [e for e in doc["traceEvents"]
             if e.get("ph") == "X" and e.get("cat") == "request"]
    assert len(spans) == doc["metadata"]["replay"]["retired"] > 0
    assert "virtual-clock" in doc["metadata"]["replay"]["units"]
    assert doc["metadata"]["replay"]["goodput_per_s"] == rep.goodput_per_s


def test_replay_timeline_renders_sheds():
    """Deadline-starved traffic: shed instants appear on the shed row."""
    op = ops_mod.Stencil2D5(8, 8)
    svc = SolverService(get_backend("local"), s=2, method="plcg", l=2,
                        chunk_iters=40, maxit=300, clock=VirtualClock())
    svc.register_operator("lap", op)
    rng = np.random.default_rng(0)
    for _ in range(6):
        svc.submit("lap", rng.standard_normal(op.n), deadline_s=1e-9)
    svc.drain()
    tl = replay_timeline(svc)
    sheds = [e for e in tl.events if e.get("cat") == "shed"]
    assert len(sheds) == len(svc.scheduler.shed_log)
    if sheds:
        assert svc.shed == len(sheds)
