"""Solver-family correctness: JAX solvers vs NumPy oracle vs direct solve.

The convergence-parity claims mirror the paper's §4.2 setup: p(l)-CG
converges like classic CG (same iteration counts modulo breakdown
restarts) on the 2D Laplacian and the diagonal toy problem.

The direct-solve tests are parametrized over the reduction backends
(DESIGN.md §3): ``local`` and a 1-device ``shard_map`` must be arithmetic
drop-ins, asserted via identical residual histories."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classic_cg, ghysels_pcg, pipelined_cg, reference
from repro.core.chebyshev import chebyshev_shifts, shifts_for_operator
from repro.core.types import SolverOps
from repro.linalg import operators as ops_mod
from repro.linalg.preconditioners import BlockJacobi, JacobiPrec
from repro.parallel import get_backend

RNG = np.random.default_rng(42)

# Both in-process-testable reduction backends (multiprocess needs >1
# controller); shard_map runs on a 1-device mesh here, the 8-device case
# lives in tests/test_distributed.py (subprocess).
BACKENDS = ["local", "shard_map"]


def _backend(name):
    return get_backend(name) if name == "local" \
        else get_backend(name, n_shards=1)


@pytest.fixture(scope="module")
def lap2d():
    op = ops_mod.Stencil2D5(24, 24)
    b = jnp.asarray(RNG.standard_normal(op.n))
    x_direct = np.linalg.solve(op.to_dense(), np.asarray(b))
    return op, b, x_direct


@pytest.mark.parametrize("backend", BACKENDS)
def test_classic_cg_matches_direct(lap2d, backend):
    op, b, x_direct = lap2d
    res = _backend(backend).solve(op, b, method="cg", tol=1e-10, maxit=2000)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_direct, atol=1e-7)


@pytest.mark.parametrize("backend", BACKENDS)
def test_ghysels_pcg_matches_direct(lap2d, backend):
    op, b, x_direct = lap2d
    res = _backend(backend).solve(op, b, method="pcg", tol=1e-10, maxit=2000)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), x_direct, atol=1e-7)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("method", ["cg", "pcg", "plcg"])
def test_backend_residual_history_parity(lap2d, backend, method):
    """Every backend reproduces the plain-SolverOps residual history
    exactly (same arithmetic, different substrate) — the ISSUE 1
    bitwise-comparability criterion, in-process."""
    op, b, _ = lap2d
    kw = dict(tol=1e-8, maxit=2000)
    if method == "plcg":
        kw.update(l=2, sigmas=shifts_for_operator(op, 2))
    ref_solver = {"cg": classic_cg.solve, "pcg": ghysels_pcg.solve,
                  "plcg": pipelined_cg.solve}[method]
    res_ref = ref_solver(SolverOps.local(op), b, **kw)
    res_be = _backend(backend).solve(op, b, method=method, **kw)
    h_ref = np.asarray(res_ref.res_history)
    h_be = np.asarray(res_be.res_history)
    assert int(res_ref.iters) == int(res_be.iters)
    np.testing.assert_allclose(h_be, h_ref, rtol=1e-12, atol=0)


@pytest.mark.parametrize("l", [1, 2, 3, 4])
def test_plcg_matches_oracle_elementwise(lap2d, l):
    """JAX p(l)-CG reproduces the NumPy Alg.-1 oracle to ~1e-12."""
    op, b, x_direct = lap2d
    sig = shifts_for_operator(op, l)
    res = pipelined_cg.solve(SolverOps.local(op), b, l=l, tol=1e-10,
                             maxit=2000, sigmas=sig)
    ref = reference.pl_cg_reference(
        lambda v: np.asarray(op.apply(jnp.asarray(v))), np.asarray(b),
        l=l, tol=1e-10, maxit=2000, sigmas=np.asarray(sig))
    assert int(res.iters) == ref.iters
    assert int(res.restarts) == ref.restarts
    np.testing.assert_allclose(np.asarray(res.x), ref.x, atol=1e-10)
    np.testing.assert_allclose(np.asarray(res.x), x_direct, atol=1e-7)


@pytest.mark.parametrize("l", [1, 2, 3])
def test_plcg_iteration_parity_with_cg(lap2d, l):
    """p(l)-CG needs (about) the same #iterations as CG (paper §4.2)."""
    op, b, _ = lap2d
    r_cg = classic_cg.solve(SolverOps.local(op), b, tol=1e-8, maxit=2000)
    r_pl = pipelined_cg.solve(SolverOps.local(op), b, l=l, tol=1e-8,
                              maxit=2000, sigmas=shifts_for_operator(op, l))
    assert abs(int(r_pl.iters) - int(r_cg.iters)) <= 2 + int(r_pl.restarts) * (l + 2)


def test_preconditioned_plcg_blockjacobi(lap2d):
    op, b, x_direct = lap2d
    bj = BlockJacobi.from_operator(op, block_size=24)
    sops = SolverOps.local(op, bj)
    res = pipelined_cg.solve(sops, b, l=2, tol=1e-9, maxit=2000,
                             sigmas=shifts_for_operator(op, 2))
    np.testing.assert_allclose(np.asarray(res.x), x_direct, atol=1e-5)


def test_diagonal_toy_with_jacobi_prec():
    d = ops_mod.laplacian_2d_spectrum(16, 16)
    op = ops_mod.DiagonalOp(d)
    b = jnp.asarray(RNG.standard_normal(op.n))
    sops = SolverOps.local(op, JacobiPrec.from_operator(op))
    res = pipelined_cg.solve(sops, b, l=2, tol=1e-10, maxit=100,
                             sigmas=shifts_for_operator(op, 2))
    # M^{-1}A = I: converges (possibly via lucky breakdown) to the answer
    np.testing.assert_allclose(
        np.asarray(res.x), np.asarray(b) / np.asarray(d), atol=1e-10)
    assert bool(res.converged)


def test_breakdown_restart_recovers():
    """Deep pipelines on ill-conditioned spectra hit square-root breakdowns
    (paper §2.2: Z^T Z goes numerically singular; 'restarting may delay
    convergence compared to standard CG').  Asserted here exactly as
    claimed: (a) on a cond=1e6 system the unshifted p(3) pipeline breaks
    down, restarts fire, and the solver terminates gracefully (finite
    iterate, no blow-up of the update count); (b) on a cond=1e3 system
    Chebyshev-shifted p(3)-CG converges THROUGH repeated restarts."""
    b48 = jnp.asarray(np.random.default_rng(42).standard_normal(48))

    op_hard = ops_mod.random_spd(jax.random.PRNGKey(1), 48, cond=1e6)
    res = pipelined_cg.solve(SolverOps.local(op_hard), b48, l=3, tol=1e-9,
                             maxit=3000, sigmas=None, max_restarts=20)
    assert int(res.restarts) >= 1          # breakdowns actually happened
    assert np.isfinite(np.asarray(res.x)).all()
    assert int(res.iters) <= 3000

    op = ops_mod.random_spd(jax.random.PRNGKey(1), 48, cond=1e3)
    x_direct = np.linalg.solve(op.to_dense(), np.asarray(b48))
    res2 = pipelined_cg.solve(SolverOps.local(op), b48, l=3, tol=1e-9,
                              maxit=2000, sigmas=shifts_for_operator(op, 3),
                              max_restarts=20)
    rel = np.linalg.norm(np.asarray(res2.x) - x_direct) \
        / np.linalg.norm(x_direct)
    assert int(res2.restarts) >= 1         # converged THROUGH restarts
    assert bool(res2.converged) and rel < 1e-6


def test_chebyshev_shifts_values():
    sig = np.asarray(chebyshev_shifts(0.0, 2.0, 4))
    expect = 1.0 + np.cos((2 * np.arange(4) + 1) * np.pi / 8)
    np.testing.assert_allclose(sig, expect, rtol=1e-12)


def test_residual_norm_is_true_norm(lap2d):
    """|zeta_j| equals the true residual norm (unpreconditioned case)."""
    op, b, _ = lap2d
    res = pipelined_cg.solve(SolverOps.local(op), b, l=2, tol=1e-8,
                             maxit=2000, sigmas=shifts_for_operator(op, 2))
    hist = np.asarray(res.res_history)
    hist = hist[hist >= 0]
    true_res = np.linalg.norm(np.asarray(b) - np.asarray(op.apply(res.x)))
    # recursive residual at convergence ~ true residual (no drift)
    assert abs(hist[-1] - true_res) / (true_res + 1e-30) < 5.0
