"""Table-1 cost-model assertions + HLO collective-parser unit tests."""

import numpy as np

from benchmarks import table1
from repro.utils.hlo import count_collectives, parse_shape_bytes
from repro.utils.roofline import HW_V5E, roofline_terms


def test_table1_counts():
    rows = table1.run(verbose=False)
    assert all(ok for _, _, _, ok in rows)


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
    assert parse_shape_bytes("bf16[2,3]") == 12
    assert parse_shape_bytes("(f32[10], s32[5])") == 60
    assert parse_shape_bytes("pred[7]") == 7
    assert parse_shape_bytes("f64[]") == 8


def test_count_collectives():
    hlo = """
ENTRY main {
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(bf16[1024]{0} %y), dimensions={0}
  %rs = f32[512]{0} reduce-scatter(f32[4096]{0} %z), dimensions={0}
  %cp = f32[64]{0} collective-permute-start(f32[64]{0} %w)
  %done = f32[64]{0} collective-permute-done(f32[64]{0} %cp)
}
"""
    c = count_collectives(hlo)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["bytes"] == 4096
    assert c["all-gather"]["bytes"] == 4096       # output shape
    assert c["reduce-scatter"]["bytes"] == 16384  # input shape
    assert c["collective-permute"]["count"] == 1  # -done not double counted


def test_roofline_terms_math():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    hlo = "%ar = f32[12500000]{0} all-reduce(f32[12500000]{0} %x)\n"
    t = roofline_terms(cost, hlo, chips=256, hw=HW_V5E)
    assert abs(t.t_compute - 1.0) < 1e-6      # per-device seconds
    assert abs(t.t_memory - 1.0) < 1e-6
    # 50 MB all-reduce, ring factor 2*(255/256), 50 GB/s
    expect = 2 * (255 / 256) * 50e6 / 50e9
    assert abs(t.t_collective - expect) < 1e-9
    assert t.dominant in ("compute", "memory")
    assert abs(t.useful_fraction(197e12 * 256) - 1.0) < 1e-6


def test_schedule_sim_limits():
    """Steady-state checks of the event simulator against Table 1:
    p(l)-CG iteration time -> max(body, glred/l) for large glred."""
    from benchmarks.schedule_sim import iteration_time
    k = {"spmv": 10e-6, "axpy1": 0.0, "glred": 600e-6}
    t1 = iteration_time("plcg", 1, k, n_iters=500)
    t3 = iteration_time("plcg", 3, k, n_iters=500)
    assert abs(t1 - 600e-6) / 600e-6 < 0.05       # glred-bound
    assert abs(t3 - 200e-6) / 200e-6 < 0.05       # glred/3
    # classic CG: spmv + 2 glred
    tcg = iteration_time("cg", 0, k, n_iters=500)
    assert abs(tcg - (10e-6 + 1200e-6)) / 1210e-6 < 0.05
