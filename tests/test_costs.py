"""Table-1 cost-model assertions + HLO collective-parser unit tests."""

import numpy as np

from benchmarks import table1
from repro.utils.hlo import count_collectives, parse_shape_bytes
from repro.utils.roofline import HW_V5E, roofline_terms


def test_table1_counts():
    rows = table1.run(verbose=False)
    assert all(ok for _, _, _, ok in rows)


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
    assert parse_shape_bytes("bf16[2,3]") == 12
    assert parse_shape_bytes("(f32[10], s32[5])") == 60
    assert parse_shape_bytes("pred[7]") == 7
    assert parse_shape_bytes("f64[]") == 8


def test_count_collectives():
    hlo = """
ENTRY main {
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(bf16[1024]{0} %y), dimensions={0}
  %rs = f32[512]{0} reduce-scatter(f32[4096]{0} %z), dimensions={0}
  %cp = f32[64]{0} collective-permute-start(f32[64]{0} %w)
  %done = f32[64]{0} collective-permute-done(f32[64]{0} %cp)
}
"""
    c = count_collectives(hlo)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["bytes"] == 4096
    assert c["all-gather"]["bytes"] == 4096       # output shape
    assert c["reduce-scatter"]["bytes"] == 16384  # input shape
    assert c["collective-permute"]["count"] == 1  # -done not double counted


def test_roofline_terms_math():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    hlo = "%ar = f32[12500000]{0} all-reduce(f32[12500000]{0} %x)\n"
    t = roofline_terms(cost, hlo, chips=256, hw=HW_V5E)
    assert abs(t.t_compute - 1.0) < 1e-6      # per-device seconds
    assert abs(t.t_memory - 1.0) < 1e-6
    # 50 MB all-reduce, ring factor 2*(255/256), 50 GB/s
    expect = 2 * (255 / 256) * 50e6 / 50e9
    assert abs(t.t_collective - expect) < 1e-9
    assert t.dominant in ("compute", "memory")
    assert abs(t.useful_fraction(197e12 * 256) - 1.0) < 1e-6


def test_autotune_reduction_payload_term():
    """The cost model carries the reduction PAYLOAD, not just latency
    (ISSUE 2 satellite): glred bytes scale with (2l+1)*s, the slab's
    local work scales with s, and only the per-reduction alpha latency
    amortizes — so per-column cost falls toward the bandwidth floor and
    the depth choice stays correct as the batcher widens the slab."""
    from benchmarks.timing_model import CORI, stencil_kernel_times
    from repro.launch.autotune import (autotune_depth, model_iteration_time,
                                       reduction_payload_bytes)

    assert reduction_payload_bytes("cg", 0, s=1) == 8
    assert reduction_payload_bytes("pcg", 0, s=4) == 2 * 4 * 8
    assert reduction_payload_bytes("plcg", 3, s=8) == 7 * 8 * 8

    # Payload reaches the glred kernel time exactly as bytes/link_bw —
    # the term the latency-only model dropped.
    k0 = stencil_kernel_times(CORI, 1_000_000, 512, glred_payload=0)
    kp = stencil_kernel_times(
        CORI, 1_000_000, 512,
        glred_payload=reduction_payload_bytes("plcg", 3, s=2048))
    dp = kp["glred"] - k0["glred"]
    assert abs(dp - 7 * 2048 * 8 / CORI.link_bw) < 1e-12
    assert dp > k0["glred"]            # payload dominates latency here

    args = (CORI, 1_000_000, 512, "plcg")
    # Slab-consistent scaling: per-slab time grows with s, while the
    # per-COLUMN time on the serialized path strictly falls — the alpha
    # latency amortizes over the slab (the serving win of DESIGN.md §11).
    per_col = [model_iteration_time(*args, l=2, unroll=1, s=s,
                                    jitter=0.0) / s
               for s in (1, 8, 64, 1024)]
    assert all(a > b for a, b in zip(per_col, per_col[1:]))
    t_slab = [model_iteration_time(*args, l=2, unroll=3, s=s, jitter=0.0)
              for s in (1, 8, 64)]
    assert t_slab[0] < t_slab[1] < t_slab[2]

    # Depth direction: narrow slabs lean on deep pipelines to hide the
    # reduction latency; wide slabs amortize it and want shallower ones.
    ls = (1, 2, 3, 5, 8)
    best_narrow = autotune_depth(n=1_000_000, p=512, s=1, ls=ls,
                                 jitter=0.0).best
    best_wide = autotune_depth(n=1_000_000, p=512, s=4096, ls=ls,
                               jitter=0.0).best
    assert best_narrow.method == "plcg" and best_narrow.l >= 2
    assert best_wide.l < best_narrow.l, (best_narrow, best_wide)


def test_autotune_neighbor_bytes_term():
    """ISSUE 3 satellite: the cost model carries the point-to-point halo
    traffic of the unstructured SpMV.  Neighbour bytes ride the SPMV
    term (they serialize with local work), so they raise the floor at
    EVERY depth and leave the latency-hiding ranking intact — unlike the
    glred payload they cannot be hidden by a deeper pipeline."""
    from repro.launch.autotune import model_iteration_time

    def t(l, nb):
        from benchmarks.timing_model import CORI
        return model_iteration_time(CORI, 4_000_000, 512, "plcg", l=l,
                                    unroll=l + 1, jitter=0.0,
                                    neighbor_bytes=nb)

    for l in (1, 2, 3):
        assert t(l, 8_000_000) > t(l, 8_000)
    # the halo penalty is depth-independent: deltas match across l
    d2 = t(2, 8_000_000) - t(2, 8_000)
    d3 = t(3, 8_000_000) - t(3, 8_000)
    assert abs(d2 - d3) / d2 < 1e-9
    # neighbor_bytes=None keeps the structured surface-term default
    from benchmarks.timing_model import CORI
    base = model_iteration_time(CORI, 4_000_000, 512, "plcg", l=2,
                                unroll=3, jitter=0.0)
    assert base > 0


def test_iteration_bytes_calibration():
    """ISSUE 4: the cost model's local stream budget recalibrates
    against a measured bytes/iteration (cost_analysis-fed).  Fewer
    bytes -> faster modeled iteration at every depth; the halo and
    reduction terms are untouched (the overlap ranking logic survives
    calibration)."""
    from benchmarks.timing_model import CORI
    from repro.launch.autotune import (autotune_depth, fused_iteration_bytes,
                                       model_iteration_time)

    n, p = 4_000_000, 512
    n_loc = n / p
    unfused_b = 150 * 8 * n_loc          # ~measured multi-pass traffic
    fused_b = float(fused_iteration_bytes(int(n_loc), 2))
    assert fused_b < unfused_b / 2

    def t(l, ib):
        return model_iteration_time(CORI, n, p, "plcg", l=l, unroll=l + 1,
                                    jitter=0.0, iteration_bytes=ib)

    for l in (1, 2, 3):
        assert t(l, fused_iteration_bytes(int(n_loc), l)) < t(l, unfused_b)
    # uncalibrated == calibrated at the model's own stream budget shape:
    # passing None simply keeps the analytic terms
    assert t(2, None) > 0
    # autotune_depth accepts the per-depth callable form
    res = autotune_depth(n, p, hw=CORI, ls=(1, 2), jitter=0.0,
                         iteration_bytes=lambda l: float(
                             fused_iteration_bytes(int(n_loc), l)))
    assert res.best.model_s > 0


def test_staged_reduction_model():
    """ISSUE 5 satellite: the per-hop ladder model (stages*alpha_hop
    replacing the tree-depth alpha, DESIGN.md §14).  Monotonicity of
    the (l, stages) knob: more stages → cheaper per-iteration ladder
    wait (smaller advance burst, cheaper residual wait steps) but
    longer pipeline fill; the stall vanishes once the structural window
    covers every stage (stages <= l-1); the autotuner co-selects depth
    and stage count (deeper pipelines earn finer ladders); and wide
    slabs still favor shallower l — the PR 2 payload-amortization
    behaviour survives the staged wiring."""
    from benchmarks.timing_model import CORI, ring_hop_time
    from repro.launch.autotune import (autotune_depth, model_iteration_time,
                                       staged_reduction_terms)

    p, payload = 512, 56
    t_hop = ring_hop_time(CORI, payload)
    assert t_hop < CORI.alpha + payload / CORI.link_bw + 1e-18
    assert CORI.alpha_hop < CORI.alpha     # a ring hop is not a tree stage

    # More stages → smaller per-iteration advance burst (the hop chain
    # one step serializes into the body) and strictly longer fill.
    for l in (2, 3, 5):
        bursts, fills = [], []
        for st in (1, 2, 4, 8, 16, 32):
            t = staged_reduction_terms(CORI, p, l, st, payload)
            bursts.append(t["t_advance_burst"])
            fills.append(t["fill_iters"])
        assert all(a >= b for a, b in zip(bursts, bursts[1:])), bursts
        assert bursts[0] > bursts[-1]
        assert all(a < b for a, b in zip(fills, fills[1:])), fills

    # The wait stall is zero exactly when the pipeline covers the ladder
    # (stages <= l-1) and grows with the uncovered remainder.
    for l in (2, 3, 5):
        for st in range(1, l):
            assert staged_reduction_terms(
                CORI, p, l, st, payload)["t_wait_stall"] == 0.0, (l, st)
        s_deep = staged_reduction_terms(CORI, p, l, l + 3, payload)
        s_shallow = staged_reduction_terms(CORI, p, l, l + 1, payload)
        assert s_deep["t_wait_stall"] > 0.0
        # per-step residue is cheaper with finer stages even when both
        # stall: each remaining step is a smaller hop group
        assert s_deep["t_advance_burst"] <= s_shallow["t_advance_burst"]

    # Hop conservation: the ladder always moves P-1 hops, stages only
    # schedule them (the arithmetic-invariance twin of the bitwise
    # stage-count parity test).
    for st in (1, 3, 7, 31):
        t = staged_reduction_terms(CORI, p, 3, st, payload)
        assert t["n_hops"] == p - 1
        assert t["group_hops"] == -(-(p - 1) // min(st, p - 1))

    # Co-selection (latency-dominated regime): among staged candidates
    # the best stage count does not shrink as the pipeline deepens —
    # deeper l structurally covers more stages, so finer ladders win.
    res = autotune_depth(n=4_000_000, p=p, ls=(2, 3, 5, 8), jitter=0.0,
                         reduction="staged", include_baselines=False,
                         stages_grid=(1, 2, 4, 7))
    # Ties (several stage counts fully hidden under the body) break
    # toward the finer ladder — "free" finer staging is still finer.
    best_by_l = {}
    for c in res.candidates:
        cur = best_by_l.get(c.l)
        if cur is None or c.score < cur.score * (1 - 1e-12) or (
                abs(c.score - cur.score) <= 1e-12 * cur.score
                and c.stages > cur.stages):
            best_by_l[c.l] = c
    ls = sorted(best_by_l)
    stages_seq = [best_by_l[l].stages for l in ls]
    assert all(a <= b for a, b in zip(stages_seq, stages_seq[1:])), \
        stages_seq
    assert stages_seq[-1] > stages_seq[0], stages_seq

    # model_iteration_time integration: stages beyond the structural
    # window only add stall...
    t_stall = model_iteration_time(CORI, 4_000_000, p, "plcg", l=3,
                                   jitter=0.0, reduction="staged",
                                   stages=7)
    t_fit = model_iteration_time(CORI, 4_000_000, p, "plcg", l=3,
                                 jitter=0.0, reduction="staged", stages=2)
    assert t_fit < t_stall
    # ... and once the pipeline is deep enough to cover a FINE ladder
    # (l-1 >= stages, small hop groups hidden under the body), the
    # staged path beats the unpipelined monolithic reduction at the
    # same depth — the structural-overlap claim.  At shallow depth the
    # honest model says a 511-hop linear ring cannot win at p=512;
    # that is the (l, stages) tension the autotuner navigates.
    t_deep = model_iteration_time(CORI, 4_000_000, p, "plcg", l=8,
                                  jitter=0.0, reduction="staged", stages=7)
    t_mono_serial = model_iteration_time(CORI, 4_000_000, p, "plcg", l=8,
                                         unroll=1, jitter=0.0)
    assert t_deep < t_mono_serial

    # Wide slabs still favor shallower l under staged wiring: the s-wide
    # payload rides every hop, so the per-column optimum moves shallow
    # exactly as in the monolithic model (PR 2 test, staged edition).
    def best_staged_l(s):
        r = autotune_depth(n=1_000_000, p=p, ls=(1, 2, 3, 5, 8), s=s,
                           jitter=0.0, reduction="staged",
                           include_baselines=False)
        return r.best.l

    assert best_staged_l(4096) <= best_staged_l(1)


def test_schedule_sim_limits():
    """Steady-state checks of the event simulator against Table 1:
    p(l)-CG iteration time -> max(body, glred/l) for large glred."""
    from benchmarks.schedule_sim import iteration_time
    k = {"spmv": 10e-6, "axpy1": 0.0, "glred": 600e-6}
    t1 = iteration_time("plcg", 1, k, n_iters=500)
    t3 = iteration_time("plcg", 3, k, n_iters=500)
    assert abs(t1 - 600e-6) / 600e-6 < 0.05       # glred-bound
    assert abs(t3 - 200e-6) / 200e-6 < 0.05       # glred/3
    # classic CG: spmv + 2 glred
    tcg = iteration_time("cg", 0, k, n_iters=500)
    assert abs(tcg - (10e-6 + 1200e-6)) / 1210e-6 < 0.05


def test_recalibrate_profile_from_compiled_lane():
    """ISSUE 8: the compiled bench lane's payloads replace the profile's
    analytic stream/latency terms — and interpret/skip payloads are
    REJECTED, so interpreter wall clocks can never recalibrate an
    accelerator profile."""
    import pytest

    from benchmarks.timing_model import CORI, ring_hop_time, tree_depth
    from repro.launch.autotune import recalibrate_profile

    it = {"kernel_mode": "compiled", "fused_wall_time_comparable": True,
          "fused_bytes_per_iter": 8.0e6, "fused_time_per_iter_s": 1e-5}
    sp = {"kernel_mode": "compiled", "problem": {"nnz": 50_000},
          "kernel_spmv_s": 2e-6}
    rd = {"kernel_mode": "compiled", "mesh_devices": 8,
          "staged_hop_payload_bytes_fp64": 40,
          "measured_hop_time_s": 3e-6, "measured_allreduce_time_s": 9e-6}
    hw = recalibrate_profile(CORI, it, sp, rd)
    assert hw.name == "cori-haswell+measured"
    assert abs(hw.mem_bw - 8.0e6 / 1e-5) < 1.0
    assert abs(hw.flop_rate - 2.0 * 50_000 / 2e-6) < 1.0
    # The measured primitives must be reproduced by the model they feed:
    # ring_hop_time gives back the hop measurement, the monolithic glred
    # latency term gives back the psum measurement.
    assert abs(ring_hop_time(hw, 40) - 3e-6) < 1e-12
    assert abs(hw.alpha * tree_depth(hw, 8) + 40 / hw.link_bw
               - 9e-6) < 1e-10
    # Untouched fields inherit (no payload for link_bw).
    assert hw.link_bw == CORI.link_bw

    # Rejections: skip marker, interpret lane, no comparable wall clock.
    with pytest.raises(ValueError, match="skip marker"):
        recalibrate_profile(CORI, iter_payload={
            "skipped": True, "reason": "no accelerator"})
    with pytest.raises(ValueError, match="kernel_mode='interpret'"):
        recalibrate_profile(CORI, spmv_payload={
            "kernel_mode": "interpret", "problem": {"nnz": 1},
            "kernel_spmv_s": 1.0})
    with pytest.raises(ValueError, match="comparable fused wall clock"):
        recalibrate_profile(CORI, iter_payload={
            "kernel_mode": "compiled", "fused_wall_time_comparable": False})
    # No payloads -> the profile passes through untouched.
    assert recalibrate_profile(CORI) is CORI
