"""Table-1 cost-model assertions + HLO collective-parser unit tests."""

import numpy as np

from benchmarks import table1
from repro.utils.hlo import count_collectives, parse_shape_bytes
from repro.utils.roofline import HW_V5E, roofline_terms


def test_table1_counts():
    rows = table1.run(verbose=False)
    assert all(ok for _, _, _, ok in rows)


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[128,1024]{1,0}") == 128 * 1024 * 4
    assert parse_shape_bytes("bf16[2,3]") == 12
    assert parse_shape_bytes("(f32[10], s32[5])") == 60
    assert parse_shape_bytes("pred[7]") == 7
    assert parse_shape_bytes("f64[]") == 8


def test_count_collectives():
    hlo = """
ENTRY main {
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(bf16[1024]{0} %y), dimensions={0}
  %rs = f32[512]{0} reduce-scatter(f32[4096]{0} %z), dimensions={0}
  %cp = f32[64]{0} collective-permute-start(f32[64]{0} %w)
  %done = f32[64]{0} collective-permute-done(f32[64]{0} %cp)
}
"""
    c = count_collectives(hlo)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["bytes"] == 4096
    assert c["all-gather"]["bytes"] == 4096       # output shape
    assert c["reduce-scatter"]["bytes"] == 16384  # input shape
    assert c["collective-permute"]["count"] == 1  # -done not double counted


def test_roofline_terms_math():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    hlo = "%ar = f32[12500000]{0} all-reduce(f32[12500000]{0} %x)\n"
    t = roofline_terms(cost, hlo, chips=256, hw=HW_V5E)
    assert abs(t.t_compute - 1.0) < 1e-6      # per-device seconds
    assert abs(t.t_memory - 1.0) < 1e-6
    # 50 MB all-reduce, ring factor 2*(255/256), 50 GB/s
    expect = 2 * (255 / 256) * 50e6 / 50e9
    assert abs(t.t_collective - expect) < 1e-9
    assert t.dominant in ("compute", "memory")
    assert abs(t.useful_fraction(197e12 * 256) - 1.0) < 1e-6


def test_autotune_reduction_payload_term():
    """The cost model carries the reduction PAYLOAD, not just latency
    (ISSUE 2 satellite): glred bytes scale with (2l+1)*s, the slab's
    local work scales with s, and only the per-reduction alpha latency
    amortizes — so per-column cost falls toward the bandwidth floor and
    the depth choice stays correct as the batcher widens the slab."""
    from benchmarks.timing_model import CORI, stencil_kernel_times
    from repro.launch.autotune import (autotune_depth, model_iteration_time,
                                       reduction_payload_bytes)

    assert reduction_payload_bytes("cg", 0, s=1) == 8
    assert reduction_payload_bytes("pcg", 0, s=4) == 2 * 4 * 8
    assert reduction_payload_bytes("plcg", 3, s=8) == 7 * 8 * 8

    # Payload reaches the glred kernel time exactly as bytes/link_bw —
    # the term the latency-only model dropped.
    k0 = stencil_kernel_times(CORI, 1_000_000, 512, glred_payload=0)
    kp = stencil_kernel_times(
        CORI, 1_000_000, 512,
        glred_payload=reduction_payload_bytes("plcg", 3, s=2048))
    dp = kp["glred"] - k0["glred"]
    assert abs(dp - 7 * 2048 * 8 / CORI.link_bw) < 1e-12
    assert dp > k0["glred"]            # payload dominates latency here

    args = (CORI, 1_000_000, 512, "plcg")
    # Slab-consistent scaling: per-slab time grows with s, while the
    # per-COLUMN time on the serialized path strictly falls — the alpha
    # latency amortizes over the slab (the serving win of DESIGN.md §11).
    per_col = [model_iteration_time(*args, l=2, unroll=1, s=s,
                                    jitter=0.0) / s
               for s in (1, 8, 64, 1024)]
    assert all(a > b for a, b in zip(per_col, per_col[1:]))
    t_slab = [model_iteration_time(*args, l=2, unroll=3, s=s, jitter=0.0)
              for s in (1, 8, 64)]
    assert t_slab[0] < t_slab[1] < t_slab[2]

    # Depth direction: narrow slabs lean on deep pipelines to hide the
    # reduction latency; wide slabs amortize it and want shallower ones.
    ls = (1, 2, 3, 5, 8)
    best_narrow = autotune_depth(n=1_000_000, p=512, s=1, ls=ls,
                                 jitter=0.0).best
    best_wide = autotune_depth(n=1_000_000, p=512, s=4096, ls=ls,
                               jitter=0.0).best
    assert best_narrow.method == "plcg" and best_narrow.l >= 2
    assert best_wide.l < best_narrow.l, (best_narrow, best_wide)


def test_autotune_neighbor_bytes_term():
    """ISSUE 3 satellite: the cost model carries the point-to-point halo
    traffic of the unstructured SpMV.  Neighbour bytes ride the SPMV
    term (they serialize with local work), so they raise the floor at
    EVERY depth and leave the latency-hiding ranking intact — unlike the
    glred payload they cannot be hidden by a deeper pipeline."""
    from repro.launch.autotune import model_iteration_time

    def t(l, nb):
        from benchmarks.timing_model import CORI
        return model_iteration_time(CORI, 4_000_000, 512, "plcg", l=l,
                                    unroll=l + 1, jitter=0.0,
                                    neighbor_bytes=nb)

    for l in (1, 2, 3):
        assert t(l, 8_000_000) > t(l, 8_000)
    # the halo penalty is depth-independent: deltas match across l
    d2 = t(2, 8_000_000) - t(2, 8_000)
    d3 = t(3, 8_000_000) - t(3, 8_000)
    assert abs(d2 - d3) / d2 < 1e-9
    # neighbor_bytes=None keeps the structured surface-term default
    from benchmarks.timing_model import CORI
    base = model_iteration_time(CORI, 4_000_000, 512, "plcg", l=2,
                                unroll=3, jitter=0.0)
    assert base > 0


def test_iteration_bytes_calibration():
    """ISSUE 4: the cost model's local stream budget recalibrates
    against a measured bytes/iteration (cost_analysis-fed).  Fewer
    bytes -> faster modeled iteration at every depth; the halo and
    reduction terms are untouched (the overlap ranking logic survives
    calibration)."""
    from benchmarks.timing_model import CORI
    from repro.launch.autotune import (autotune_depth, fused_iteration_bytes,
                                       model_iteration_time)

    n, p = 4_000_000, 512
    n_loc = n / p
    unfused_b = 150 * 8 * n_loc          # ~measured multi-pass traffic
    fused_b = float(fused_iteration_bytes(int(n_loc), 2))
    assert fused_b < unfused_b / 2

    def t(l, ib):
        return model_iteration_time(CORI, n, p, "plcg", l=l, unroll=l + 1,
                                    jitter=0.0, iteration_bytes=ib)

    for l in (1, 2, 3):
        assert t(l, fused_iteration_bytes(int(n_loc), l)) < t(l, unfused_b)
    # uncalibrated == calibrated at the model's own stream budget shape:
    # passing None simply keeps the analytic terms
    assert t(2, None) > 0
    # autotune_depth accepts the per-depth callable form
    res = autotune_depth(n, p, hw=CORI, ls=(1, 2), jitter=0.0,
                         iteration_bytes=lambda l: float(
                             fused_iteration_bytes(int(n_loc), l)))
    assert res.best.model_s > 0


def test_schedule_sim_limits():
    """Steady-state checks of the event simulator against Table 1:
    p(l)-CG iteration time -> max(body, glred/l) for large glred."""
    from benchmarks.schedule_sim import iteration_time
    k = {"spmv": 10e-6, "axpy1": 0.0, "glred": 600e-6}
    t1 = iteration_time("plcg", 1, k, n_iters=500)
    t3 = iteration_time("plcg", 3, k, n_iters=500)
    assert abs(t1 - 600e-6) / 600e-6 < 0.05       # glred-bound
    assert abs(t3 - 200e-6) / 200e-6 < 0.05       # glred/3
    # classic CG: spmv + 2 glred
    tcg = iteration_time("cg", 0, k, n_iters=500)
    assert abs(tcg - (10e-6 + 1200e-6)) / 1210e-6 < 0.05
