"""Residual replacement (arXiv:1902.03100) restores attainable accuracy.

Pipelined CG variants trade synchronization for extra recurrences whose
rounding errors accumulate: past a point the recursive residual keeps
shrinking while the TRUE residual b - A x stagnates.  On an
ill-conditioned Laplace system in float32 this plateau is orders of
magnitude above classic CG's.  The opt-in ``replace_every`` step —
periodic true-residual recompute (in-place vector replacement for
Ghysels p-CG, a forced true-residual cycle re-init for p(l)-CG) —
must push the plateau down.  All solves run at tol=0 (no early exit),
well past convergence, where the drift is fully expressed.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ghysels_pcg, pipelined_cg
from repro.core.chebyshev import shifts_for_operator
from repro.core.types import SolverOps
from repro.linalg import operators as ops_mod

# Anisotropic-aspect 2D Laplacian, float32: condition ~ 4e3, far beyond
# what fp32 pipelined recurrences sustain without replacement.
OP = ops_mod.Stencil2D5(96, 24)
B32 = jnp.asarray(np.random.default_rng(0).standard_normal(OP.n),
                  jnp.float32)


def _true_rel_res(x) -> float:
    """||b - A x|| / ||b|| evaluated in float64 (the honest metric —
    the solver's own recursive residual is exactly what drifts)."""
    xd = jnp.asarray(np.asarray(x, np.float64))
    bd = np.asarray(B32, np.float64)
    return float(np.linalg.norm(bd - np.asarray(OP.apply(xd)))
                 / np.linalg.norm(bd))


def test_pcg_replacement_tightens_attainable_accuracy():
    ops = SolverOps.local(OP)
    plain = ghysels_pcg.solve(ops, B32, tol=0.0, maxit=800)
    repl = ghysels_pcg.solve(ops, B32, tol=0.0, maxit=800,
                             replace_every=50)
    res_plain = _true_rel_res(plain.x)
    res_repl = _true_rel_res(repl.x)
    # Without replacement p-CG stagnates far from convergence; with it
    # the true residual drops by orders of magnitude.
    assert res_plain > 1e-3, res_plain
    assert res_repl < 1e-3, res_repl
    assert res_repl < res_plain / 10, (res_plain, res_repl)


def test_plcg_replacement_tightens_attainable_accuracy():
    ops = SolverOps.local(OP)
    sig = jnp.asarray(shifts_for_operator(OP, 2), jnp.float32)
    kw = dict(l=2, sigmas=sig, tol=0.0, maxit=400, max_restarts=30)
    plain = pipelined_cg.solve(ops, B32, **kw)
    repl = pipelined_cg.solve(ops, B32, replace_every=60, **kw)
    res_plain = _true_rel_res(plain.x)
    res_repl = _true_rel_res(repl.x)
    # The plain run never hits a breakdown (so nothing resets its drift);
    # the RR run's restarts are exactly the periodic replacements.
    assert int(plain.restarts) == 0
    assert int(repl.restarts) >= 3
    assert res_repl < res_plain / 2, (res_plain, res_repl)
    assert res_repl < 1.5e-6, res_repl


def test_replacement_preserves_exact_arithmetic_convergence():
    """In float64 within normal tolerances, replacement must not change
    the answer — it only touches rounding-error accumulation."""
    op = ops_mod.Stencil2D5(24, 24)
    b = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
    ops = SolverOps.local(op)
    x_direct = np.linalg.solve(op.to_dense(), np.asarray(b))

    r_pcg = ghysels_pcg.solve(ops, b, tol=1e-10, maxit=2000,
                              replace_every=20)
    assert bool(r_pcg.converged)
    np.testing.assert_allclose(np.asarray(r_pcg.x), x_direct, atol=1e-7)

    sig = shifts_for_operator(op, 2)
    r_pl = pipelined_cg.solve(ops, b, l=2, sigmas=sig, tol=1e-10,
                              maxit=2000, replace_every=25,
                              max_restarts=100)
    assert bool(r_pl.converged)
    assert int(r_pl.restarts) >= 1        # replacements actually fired
    np.testing.assert_allclose(np.asarray(r_pl.x), x_direct, atol=1e-7)
