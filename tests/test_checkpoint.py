"""Checkpoint/restore tests (DESIGN.md §19): bitwise resume on the
local and shard_map substrates, the cycle-boundary invariant's HLO
footprint, typed failure modes (corruption, version skew, config
mismatch, certification), and batched slab round-trips.

The headline contract: a solve that is killed and resumed from its last
checkpoint produces THE SAME residual history as one that never died —
bit for bit from the restore iteration onward — because the segmented
driver is arithmetic-identical to the monolithic ``lax.while_loop`` of
the same effective config, and the snapshot boundary is a drained-ring
interrupt where every persisted leaf is replicated and well-defined.
Multi-device paths run in subprocesses (conftest pins one device);
the cross-process kill-a-rank drill lives in tests/test_multiprocess.py.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (LAST_RESTORE, CheckpointCertificationError,
                              CheckpointConfig, CheckpointCorruptError,
                              CheckpointMismatchError, CheckpointVersionError,
                              CKPT_VERSION, latest_checkpoint,
                              list_checkpoints, load_checkpoint,
                              load_slab_checkpoint, save_checkpoint,
                              save_slab_checkpoint)
from repro.checkpoint import solve as ckpt_solve
from repro.core.chebyshev import shifts_for_operator
from repro.linalg.operators import Stencil2D5
from repro.parallel import get_backend

RNG = np.random.default_rng(11)

ENV = dict(os.environ, PYTHONPATH="src")
ENV.pop("XLA_FLAGS", None)


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=os.getcwd(), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.linalg.operators import Stencil2D5
from repro.parallel import get_backend
from repro.checkpoint import CheckpointConfig, LAST_RESTORE
op = Stencil2D5(24, 16)
b = np.asarray(np.random.default_rng(0).standard_normal(op.n))
"""


@pytest.fixture()
def problem():
    op = Stencil2D5(24, 16)
    b = np.asarray(RNG.standard_normal(op.n))
    return op, b


# --------------------------------------------------------------------------
# Local substrate: segmented == monolithic, save -> kill -> resume bitwise.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method,kw", [
    ("plcg", dict(l=2, tol=1e-10, maxit=300)),
    ("pcg", dict(tol=1e-10, maxit=300)),
])
def test_resume_bitwise_local(tmp_path, problem, method, kw):
    """Save every 15 updates, then resume from the last snapshot: the
    resumed residual history equals the uninterrupted segmented oracle
    bitwise from the restore iteration onward, and the segmented drive
    itself changes nothing vs a never-checkpointed solve of the same
    effective config."""
    op, b = problem
    be = get_backend("local")
    oracle = be.solve(op, b, method=method,
                      checkpoint=CheckpointConfig(every=15), **kw)
    d = str(tmp_path)
    full = be.solve(op, b, method=method,
                    checkpoint=CheckpointConfig(every=15, directory=d), **kw)
    assert list_checkpoints(d), "no snapshots written"
    resumed = be.solve(op, b, method=method,
                       checkpoint=CheckpointConfig(every=15, directory=d,
                                                   resume=True), **kw)
    h_o = np.asarray(oracle.res_history)
    h_f = np.asarray(full.res_history)
    h_r = np.asarray(resumed.res_history)
    assert bool(full.converged) and bool(resumed.converged)
    # persisting must not perturb the arithmetic
    assert np.array_equal(h_o, h_f)
    # resumed == uninterrupted from the restore iteration onward
    assert LAST_RESTORE, "restore never happened"
    rtot = int(LAST_RESTORE[-1].meta["tot"])
    assert rtot > 0
    assert np.array_equal(h_o[rtot:], h_r[rtot:])
    # ... and the restored head is the saved history, so the whole
    # same-substrate resumed history is bitwise identical.
    assert np.array_equal(h_o, h_r)
    assert int(resumed.iters) == int(oracle.iters)


def test_every_zero_hlo_unchanged(problem):
    """``CheckpointConfig(every=0)`` (and ``checkpoint=None``) must
    compile to the IDENTICAL solver HLO — checkpointing off is the
    pre-§19 program, byte for byte."""
    from repro.core import ghysels_pcg, pipelined_cg
    from repro.core.types import SolverOps

    op, b = problem
    ops = SolverOps.local(op)
    bj = jnp.asarray(b)
    sig = shifts_for_operator(op, 2)

    def lower(solver, **kw):
        return jax.jit(lambda bb: solver(ops, bb, **kw)).lower(bj).as_text()

    kw = dict(l=2, sigmas=sig, tol=1e-10, maxit=300)
    assert lower(pipelined_cg.solve, **kw) == \
        lower(pipelined_cg.solve, checkpoint=CheckpointConfig(every=0), **kw)
    kw = dict(tol=1e-10, maxit=300)
    assert lower(ghysels_pcg.solve, **kw) == \
        lower(ghysels_pcg.solve, checkpoint=CheckpointConfig(every=0), **kw)


def test_effective_kw_validation():
    """The checkpoint cadence must exceed plcg's pipeline depth (the
    ring has to refill between boundaries), and every=0 never reaches
    the segmented driver."""
    with pytest.raises(ValueError):
        ckpt_solve.effective_kw("plcg", dict(l=3, maxit=100), every=3)
    with pytest.raises(ValueError):
        ckpt_solve.effective_kw("plcg", dict(l=2, maxit=100), every=0)
    # cadence folds into min(replace_every, every)
    kw = ckpt_solve.effective_kw("plcg", dict(l=2, maxit=100,
                                              replace_every=40), every=15)
    assert kw["replace_every"] == 15
    kw = ckpt_solve.effective_kw("pcg", dict(maxit=100, replace_every=10),
                                 every=25)
    assert kw["replace_every"] == 10


def test_methods_without_interrupt_rejected():
    """Classic CG has no interrupt boundary — checkpointing it is a
    typed refusal, not a silent no-op."""
    with pytest.raises(KeyError):
        ckpt_solve.make_rel_fn("cg", {})


# --------------------------------------------------------------------------
# Typed failure modes: corruption, version skew, config mismatch, failed
# certification.  (Property-based versions: test_checkpoint_properties.py.)
# --------------------------------------------------------------------------

def test_corrupt_truncated_version_errors(tmp_path):
    path = str(tmp_path / "ckpt_0000000001.npz")
    payload = {"leaf_000": np.arange(6, dtype=np.float64).reshape(2, 3)}
    meta = save_checkpoint(path, payload, {"kind": "test"})
    assert meta["version"] == CKPT_VERSION and "sha256" in meta
    back, m2 = load_checkpoint(path)
    assert np.array_equal(back["leaf_000"], payload["leaf_000"])

    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "missing.npz"))

    raw = open(path, "rb").read()
    trunc = str(tmp_path / "trunc.npz")
    with open(trunc, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(trunc)

    garbage = str(tmp_path / "garbage.npz")
    with open(garbage, "wb") as f:
        f.write(b"not a zip file at all")
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(garbage)

    # bit-flip inside the payload -> content hash refuses
    flipped = str(tmp_path / "flipped.npz")
    tampered = {k: v.copy() for k, v in payload.items()}
    tampered["leaf_000"][0, 0] += 1.0
    save_checkpoint(flipped, tampered, {"kind": "test"})
    raw_ok = load_checkpoint(flipped)[1]["sha256"]
    assert raw_ok != meta["sha256"]
    # forge: stored arrays differ from the hashed ones
    import json as _json
    import zipfile as _zip
    forged = str(tmp_path / "forged.npz")
    with _zip.ZipFile(flipped) as zin, _zip.ZipFile(forged, "w") as zout:
        for item in zin.namelist():
            data = zin.read(item)
            if item == "__meta__.npy":
                # splice the ORIGINAL meta (wrong hash) over the
                # tampered payload
                blob = np.frombuffer(
                    _json.dumps(meta, sort_keys=True).encode(),
                    dtype=np.uint8)
                import io
                buf = io.BytesIO()
                np.save(buf, blob)
                data = buf.getvalue()
            zout.writestr(item, data)
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(forged)

    # version skew refuses before anything else is trusted
    vpath = str(tmp_path / "version.npz")
    save_checkpoint(vpath, payload, {"kind": "test"})
    pl, mv = load_checkpoint(vpath)
    mv["version"] = CKPT_VERSION + 1
    blob = np.frombuffer(_json.dumps(mv, sort_keys=True).encode(),
                         dtype=np.uint8)
    with open(vpath, "wb") as f:
        np.savez(f, __meta__=blob, **pl)
    with pytest.raises(CheckpointVersionError):
        load_checkpoint(vpath)


def test_meta_mismatch_refuses_resume(tmp_path, problem):
    """A checkpoint written by one solver config refuses to resume a
    different one (different tolerance here) — typed, never silent."""
    op, b = problem
    be = get_backend("local")
    d = str(tmp_path)
    kw = dict(l=2, maxit=300)
    be.solve(op, b, method="plcg", tol=1e-10,
             checkpoint=CheckpointConfig(every=15, directory=d), **kw)
    with pytest.raises(CheckpointMismatchError):
        be.solve(op, b, method="plcg", tol=1e-8,
                 checkpoint=CheckpointConfig(every=15, directory=d,
                                             resume=True), **kw)


def test_certification_catches_tampered_state(tmp_path, problem):
    """A checkpoint whose state was altered — but whose content hash was
    recomputed, so the format layer cannot object — fails the restore-
    time true-residual certification."""
    op, b = problem
    be = get_backend("local")
    d = str(tmp_path)
    kw = dict(l=2, tol=1e-10, maxit=300)
    be.solve(op, b, method="plcg",
             checkpoint=CheckpointConfig(every=15, directory=d), **kw)
    path = latest_checkpoint(d)
    payload, meta = load_checkpoint(path)
    for k, v in payload.items():
        if v.ndim >= 1 and v.dtype == np.float64 and v.shape[-1] == op.n:
            payload[k] = v * (1.0 + 1e-3)       # perturb the iterate
    save_checkpoint(path, payload, meta)        # fresh, VALID hash
    with pytest.raises(CheckpointCertificationError):
        be.solve(op, b, method="plcg",
                 checkpoint=CheckpointConfig(every=15, directory=d,
                                             resume=True), **kw)


def test_gc_keeps_newest(tmp_path, problem):
    op, b = problem
    be = get_backend("local")
    d = str(tmp_path)
    be.solve(op, b, method="plcg", l=2, tol=1e-10, maxit=300,
             checkpoint=CheckpointConfig(every=15, directory=d, keep=2))
    paths = list_checkpoints(d)
    assert len(paths) <= 2
    tots = [int(os.path.basename(p)[5:15]) for p in paths]
    assert tots == sorted(tots)


# --------------------------------------------------------------------------
# Batched slab round-trip (same-substrate bitwise).
# --------------------------------------------------------------------------

def test_slab_checkpoint_roundtrip(tmp_path):
    """Persist a mid-flight slab at a chunk boundary, reload it onto a
    fresh template, keep solving both: bitwise-identical iterates and
    statuses — serve workers respawn without losing in-flight work."""
    op = Stencil2D5(16, 16)
    B = jnp.asarray(RNG.standard_normal((op.n, 4)))
    be = get_backend("local")
    sig = shifts_for_operator(op, 2)
    prog = be.make_slab_program(op, s=4, method="plcg", chunk_iters=20,
                                l=2, sigmas=sig, tol=1e-9, maxit=800)
    st = prog.init(B)
    for _ in range(3):
        st = prog.chunk(B, st)

    path = str(tmp_path / "slab.npz")
    meta = dict(s=4, method="plcg", n=int(op.n))
    save_slab_checkpoint(path, B, st, meta)
    B2, st2, m2 = load_slab_checkpoint(path, prog.init(B), expect_meta=meta)
    assert m2["kind"] == "slab"
    assert np.array_equal(np.asarray(B2), np.asarray(B))

    for _ in range(30):
        st = prog.chunk(B, st)
        st2 = prog.chunk(B2, st2)
    x1 = np.asarray(prog.extract(B, st).x)
    x2 = np.asarray(prog.extract(B2, st2).x)
    assert x1.tobytes() == x2.tobytes()
    s1, s2 = prog.status(B, st), prog.status(B2, st2)
    assert np.array_equal(np.asarray(s1.running), np.asarray(s2.running))

    # structural mismatch is typed: different slab meta refuses
    with pytest.raises(CheckpointMismatchError):
        load_slab_checkpoint(path, prog.init(B),
                             expect_meta=dict(s=8, method="plcg"))


# --------------------------------------------------------------------------
# shard_map substrate (subprocess: 4 fake host devices).
# --------------------------------------------------------------------------

def test_shard_map_resume_bitwise_and_elastic():
    """Staged+unfused checkpointed solves on a 4-shard mesh: bitwise vs
    the local virtual-shards segmented oracle, bitwise resume, and an
    ELASTIC restore — the distributed checkpoint restored by the local
    substrate continues bitwise (the D ring is excluded and rebuilt
    drained; vector leaves re-place onto whatever shards restore them)."""
    out = _run(HEADER + """
kw = dict(l=2, tol=1e-10, maxit=300, fused_iteration=False)
be = get_backend("shard_map", n_shards=4, reduction="staged")
beL = get_backend("local", reduction="staged", virtual_shards=4)
oracle = beL.solve(op, b, method="plcg",
                   checkpoint=CheckpointConfig(every=15), **kw)
with tempfile.TemporaryDirectory() as d:
    full = be.solve(op, b, method="plcg",
                    checkpoint=CheckpointConfig(every=15, directory=d), **kw)
    resumed = be.solve(op, b, method="plcg",
                       checkpoint=CheckpointConfig(every=15, directory=d,
                                                   resume=True), **kw)
    rtot = int(LAST_RESTORE[-1].meta["tot"])
    # elastic: the DISTRIBUTED snapshot restored on the LOCAL ladder
    res_elastic = beL.solve(op, b, method="plcg",
                            checkpoint=CheckpointConfig(every=15, directory=d,
                                                        resume=True), **kw)
h_o = np.asarray(oracle.res_history)
h_f = np.asarray(full.res_history)
h_r = np.asarray(resumed.res_history)
h_e = np.asarray(res_elastic.res_history)
assert bool(full.converged) and bool(resumed.converged)
assert np.array_equal(h_o, h_f), "staged ladder lost cross-substrate parity"
assert rtot > 0
assert np.array_equal(h_f[rtot:], h_r[rtot:])
assert np.array_equal(h_f, h_r)
assert np.array_equal(h_f[rtot:], h_e[rtot:]), "elastic restore diverged"
print("SHARD-RESUME-OK", rtot)
""")
    assert "SHARD-RESUME-OK" in out


def test_shard_map_resume_monolithic_and_fused():
    """The other reduction/iteration configs resume bitwise against
    their own uninterrupted runs (cross-substrate parity for these is
    certified, not bitwise — DESIGN.md §19 honesty notes)."""
    out = _run(HEADER + """
for red, fused in [(None, False), ("staged", True)]:
    be = get_backend("shard_map", n_shards=4,
                     **({"reduction": red} if red else {}))
    kw = dict(l=2, tol=1e-10, maxit=300, fused_iteration=fused)
    with tempfile.TemporaryDirectory() as d:
        full = be.solve(op, b, method="plcg",
                        checkpoint=CheckpointConfig(every=15, directory=d),
                        **kw)
        resumed = be.solve(op, b, method="plcg",
                           checkpoint=CheckpointConfig(every=15, directory=d,
                                                       resume=True), **kw)
    rtot = int(LAST_RESTORE[-1].meta["tot"])
    h_f = np.asarray(full.res_history)
    h_r = np.asarray(resumed.res_history)
    assert bool(full.converged) and rtot > 0
    assert np.array_equal(h_f[rtot:], h_r[rtot:]), (red, fused)
    print("CONFIG-OK", red, fused, rtot)
print("SHARD-RESUME2-OK")
""")
    assert "SHARD-RESUME2-OK" in out


def test_checkpointed_seg_keeps_one_reduction_start():
    """The cycle-boundary invariant's HLO footprint: the segmented
    driver's compiled ``seg`` piece (the between-boundaries while loop)
    still issues EXACTLY ONE tagged dot-block all-reduce per iteration —
    checkpointing must not add collectives to the iteration body, for
    either pipelined method."""
    out = _run(HEADER + """
from repro.core.chebyshev import shifts_for_operator
from repro.parallel.distributed import (distributed_checkpointed_solve,
                                        make_solver_mesh)
mesh = make_solver_mesh(4)
sig = shifts_for_operator(op, 2)

def count_glred_ar(txt):
    return sum(1 for line in txt.splitlines()
               if (" all-reduce(" in line or " all-reduce-start(" in line)
               and "glred_start" in line)

pieces = distributed_checkpointed_solve(
    mesh, op, jnp.asarray(b), method="plcg",
    checkpoint=CheckpointConfig(every=15), pieces=True,
    l=2, sigmas=sig, tol=1e-10, maxit=300)
seg_txt = pieces["seg"].lower(pieces["b_p"], pieces["state"],
                              pieces["arrays"]).compile().as_text()
n = count_glred_ar(seg_txt)
assert n == 1, f"plcg seg piece has {n} tagged reduction starts, want 1"
int_txt = pieces["interrupt"].lower(pieces["b_p"], pieces["state"],
                                    pieces["arrays"]).compile().as_text()
assert count_glred_ar(int_txt) >= 1   # true-residual recompute + re-init

pieces = distributed_checkpointed_solve(
    mesh, op, jnp.asarray(b), method="pcg",
    checkpoint=CheckpointConfig(every=15), pieces=True,
    tol=1e-10, maxit=300)
seg_txt = pieces["seg"].lower(pieces["b_p"], pieces["state"],
                              pieces["arrays"]).compile().as_text()
n = count_glred_ar(seg_txt)
assert n == 1, f"pcg seg piece has {n} tagged reduction starts, want 1"
print("SEG-HLO-OK")
""")
    assert "SEG-HLO-OK" in out
