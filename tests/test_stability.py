"""Stability-governed deep pipelines (DESIGN.md §18): the stable p(l)-CG
recurrence, the attainable-accuracy governor, and the chaos harness that
PROVES governed recovery where ungoverned deep-l p(l)-CG stagnates.

The contract under test:

* ``recurrence="stable"`` converges wherever ghysels does, behind the
  same fused/unfused calling convention;
* the governor (``GovernorConfig``) repairs injected reduction-payload
  corruption through truth-certified residual replacements — the
  recovery demonstration: governed stable reaches tol under a seeded
  fault where ungoverned ghysels stagnates ~2000x above it;
* governor-off paths are BITWISE identical to the pre-§18 solver
  (single, batched s=8, staged shard_map);
* every governed/instrumented compile still issues EXACTLY ONE
  pipelined reduction start per iteration (the paper's invariant);
* catastrophic corruption demotes down the host ladder
  (``governed_solve``) and raises a typed :class:`StagnationError`
  instead of returning silent non-convergence.

The shard_map half follows the tests/test_distributed.py subprocess
idiom (8 fake host devices configured before jax imports).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.chaos import ChaosConfig, chaos_ops                 # noqa: E402
from repro.core import batched, pipelined_cg                   # noqa: E402
from repro.core.chebyshev import shifts_for_operator           # noqa: E402
from repro.core.types import SolverOps                         # noqa: E402
from repro.linalg import Stencil2D5                            # noqa: E402
from repro.linalg.preconditioners import JacobiPrec            # noqa: E402
from repro.parallel import get_backend                         # noqa: E402
from repro.stability import (                                  # noqa: E402
    GovernorConfig,
    StagnationError,
    diagnose,
    governed_solve,
)
from repro.stability import model as gov_model                 # noqa: E402

ENV = dict(os.environ, PYTHONPATH="src")
ENV.pop("XLA_FLAGS", None)


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=os.getcwd(), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.parallel import get_backend
from repro.linalg import Stencil2D5
from repro.core.chebyshev import shifts_for_operator
from repro.stability import GovernorConfig
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(3).standard_normal(op.n))
sig = shifts_for_operator(op, 2)
"""


def _problem():
    op = Stencil2D5(48, 24)
    prec = JacobiPrec.from_operator(op)
    ops = SolverOps.local(op, prec)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))
    return op, prec, ops, b


def _true_rel(op, b, x):
    r = np.asarray(b) - np.asarray(op.apply(jnp.asarray(np.asarray(x))))
    return float(np.linalg.norm(r) / np.linalg.norm(np.asarray(b)))


# ------------------------------------------------------- stable recurrence --

def test_stable_recurrence_converges_clean():
    """The coupled-recurrence variant converges on a clean problem to the
    same tolerance as ghysels, unfused and fused, and the two variants
    agree on the solution (not bitwise — different recurrences — but to
    solver accuracy)."""
    op, prec, ops, b = _problem()
    kw = dict(l=4, tol=1e-6, maxit=400, max_restarts=60)
    rg = pipelined_cg.solve(ops, b, **kw)
    assert bool(rg.converged)
    for fused in (False, True):
        rs = pipelined_cg.solve(ops, b, recurrence="stable",
                                fused_iteration=fused, **kw)
        assert bool(rs.converged), fused
        assert _true_rel(op, b, rs.x) < 1e-5, fused
        assert abs(int(rs.iters) - int(rg.iters)) <= 40, \
            (int(rs.iters), int(rg.iters))


def test_stable_recurrence_fused_unfused_bitwise():
    """Both recurrence variants honor the fused/unfused parity contract:
    the Pallas superkernel and the reference loop produce bitwise-equal
    residual histories (the §13 invariant, extended to §18)."""
    op, prec, ops, b = _problem()
    for rec in ("ghysels", "stable"):
        kw = dict(l=3, tol=1e-8, maxit=300, recurrence=rec)
        ru = pipelined_cg.solve(ops, b, fused_iteration=False, **kw)
        rf = pipelined_cg.solve(ops, b, fused_iteration=True, **kw)
        assert np.array_equal(np.asarray(ru.res_history),
                              np.asarray(rf.res_history)), rec
        assert np.array_equal(np.asarray(ru.x), np.asarray(rf.x)), rec


def test_unknown_recurrence_rejected():
    op, prec, ops, b = _problem()
    with pytest.raises(ValueError, match="recurrence"):
        pipelined_cg.solve(ops, b, l=2, tol=1e-8, maxit=50,
                           recurrence="typo")


# ------------------------------------------------------- governed recovery --

def test_clean_governed_solve_truth_certified():
    """On a clean problem the governor costs only its periodic
    verification replacements: convergence is declared from the TRUE
    residual (never the recursion), and the certified solution meets
    tol."""
    op, prec, ops, b = _problem()
    res = pipelined_cg.solve(ops, b, l=4, tol=1e-6, maxit=400,
                             max_restarts=60, recurrence="stable",
                             governor=GovernorConfig())
    d = diagnose(res)
    assert d["converged"]
    assert d["replacements"] >= 1          # at least the certifying check
    assert not d["stagnated"]
    assert _true_rel(op, b, res.x) < 1e-6


def test_governed_recovery_where_ungoverned_stagnates():
    """THE recovery demonstration (ISSUE acceptance): under a seeded
    ULP-scale reduction-payload fault at l=4, ungoverned ghysels p(l)-CG
    stagnates orders of magnitude above tol — the recursive residual
    detaches from the true one — while the governed stable solver
    reaches tol, certified against the true residual."""
    op, prec, ops, b = _problem()
    tol = 1e-5
    kw = dict(l=4, tol=tol, maxit=400, max_restarts=120)
    cops = chaos_ops(ops, ChaosConfig(seed=7, payload_rel_amp=1e-5))

    ungov = pipelined_cg.solve(cops, b, **kw)
    assert not bool(ungov.converged)
    assert _true_rel(op, b, ungov.x) > 100 * tol        # ~2e-2 measured

    gov = pipelined_cg.solve(cops, b, recurrence="stable",
                             governor=GovernorConfig(), **kw)
    d = diagnose(gov)
    assert d["converged"]
    assert d["replacements"] >= 5           # the governor did the work
    assert _true_rel(op, b, gov.x) < tol


def test_governed_batched_per_column():
    """Batched s=4 slab with the governor armed: every column converges
    truth-certified, and the per-column governor vectors record each
    column's own replacement count (masked interrupts — no cross-column
    coupling)."""
    op, prec, ops, b = _problem()
    B = jnp.asarray(np.random.default_rng(5).standard_normal((op.n, 4)))
    res = batched.solve_batched(ops, B, method="plcg", l=4, tol=1e-6,
                                maxit=400, max_restarts=60,
                                recurrence="stable",
                                governor=GovernorConfig())
    assert res.governor is not None
    g = np.asarray(res.governor)
    assert g.shape == (4, gov_model.N_SLOTS)
    assert np.asarray(res.converged).all()
    assert (g[:, int(gov_model.REPL)] >= 1).all()
    for j in range(4):
        assert _true_rel(op, B[:, j], np.asarray(res.x)[j]) < 1e-6, j


# ------------------------------------------------------ bitwise governor-off --

def test_governor_off_bitwise_single_and_batched():
    """Passing the new kwargs at their defaults (recurrence='ghysels',
    governor=None) is BITWISE invisible: identical histories, solutions
    and telemetry to omitting them — single RHS and batched s=8."""
    op, prec, ops, b = _problem()
    kw = dict(l=3, tol=1e-8, maxit=300)
    plain = pipelined_cg.solve(ops, b, **kw)
    expl = pipelined_cg.solve(ops, b, recurrence="ghysels",
                              governor=None, **kw)
    assert plain.governor is None and expl.governor is None
    assert np.array_equal(np.asarray(plain.res_history),
                          np.asarray(expl.res_history))
    assert np.array_equal(np.asarray(plain.x), np.asarray(expl.x))

    B = jnp.asarray(np.random.default_rng(5).standard_normal((op.n, 8)))
    bp = batched.solve_batched(ops, B, method="plcg", **kw)
    be_ = batched.solve_batched(ops, B, method="plcg",
                                recurrence="ghysels", governor=None, **kw)
    assert bp.governor is None and be_.governor is None
    assert np.array_equal(np.asarray(bp.res_history),
                          np.asarray(be_.res_history))
    assert np.array_equal(np.asarray(bp.x), np.asarray(be_.x))


def test_governor_off_bitwise_staged_shard_map():
    """The staged shard_map ladder keeps the same guarantee across the
    8-device mesh: explicit-default kwargs leave staged histories
    bitwise, and a GOVERNED staged solve still converges with bitwise
    parity vs the local virtual-shards ladder oracle."""
    out = _run(HEADER + """
kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-8, maxit=400)
be_m = get_backend("shard_map", n_shards=8, reduction="staged")
plain = be_m.solve(op, b, **kw)
expl = be_m.solve(op, b, recurrence="ghysels", governor=None, **kw)
assert np.array_equal(np.asarray(plain.res_history),
                      np.asarray(expl.res_history))
assert np.array_equal(np.asarray(plain.x), np.asarray(expl.x))

gkw = dict(kw, tol=1e-6, recurrence="stable", governor=GovernorConfig())
be_o = get_backend("local", reduction="staged", virtual_shards=8)
gm = be_m.solve(op, b, **gkw)
go = be_o.solve(op, b, **gkw)
assert bool(gm.converged)
assert np.array_equal(np.asarray(gm.res_history), np.asarray(go.res_history))
assert np.array_equal(np.asarray(gm.governor), np.asarray(go.governor))
print("STAB-BITWISE-OK")
""")
    assert "STAB-BITWISE-OK" in out


# --------------------------------------------- one reduction start per iter --

def test_governed_compile_one_reduction_start_per_iteration():
    """The sacred invariant survives §18: with the governor armed and the
    stable recurrence selected, the compiled schedule still issues
    EXACTLY ONE pipelined reduction start per iteration — fused psum
    (starts_per_window) and staged ladder (staged_starts_per_window,
    zero dot-block all-reduces) alike."""
    out = _run(HEADER + """
from repro.utils.trace import plcg_overlap_report
gov = GovernorConfig()
be = get_backend("shard_map", n_shards=8)
bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
for l in (2, 3):
    rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2,
                              sigmas=shifts_for_operator(op, l),
                              recurrence="stable", governor=gov)
    assert rep.max_in_flight >= l, (l, str(rep))
    assert len(rep.starts_per_window) == rep.window, str(rep)
    assert all(v == 1 for v in rep.starts_per_window.values()), \\
        (l, rep.starts_per_window)

be_s = get_backend("shard_map", n_shards=8, reduction="staged")
rep = plcg_overlap_report(be_s, op, bspec, l=2, window=4, sigmas=sig,
                          recurrence="stable", governor=gov)
assert rep.n_collectives == 0, rep.n_collectives
assert max(rep.staged_starts_per_window.values()) == 1, \\
    rep.staged_starts_per_window
print("STAB-HLO-OK")
""")
    assert "STAB-HLO-OK" in out


# ---------------------------------------------------------- demotion ladder --

def test_catastrophic_chaos_demotes_then_raises():
    """Catastrophic payload corruption (30% relative) defeats residual
    replacement at every depth: the host ladder demotes 4 -> 2 -> 1 and
    raises a typed StagnationError carrying the per-depth diagnosis —
    never a silently non-converged result."""
    op, prec, ops0, b = _problem()
    chaos = ChaosConfig(seed=3, payload_rel_amp=3e-1)
    be = get_backend("local")
    with pytest.raises(StagnationError) as ei:
        governed_solve(be, op, b, l=4, prec=prec,
                       ops_transform=lambda o: chaos_ops(o, chaos),
                       tol=1e-6, maxit=400, max_restarts=60)
    err = ei.value
    assert "l=1" in str(err)
    tried = [a["l"] for a in err.diagnosis["attempts"]]
    assert tried == [4, 2, 1], tried
    for a in err.diagnosis["attempts"]:
        assert not a["converged"]


def test_governed_solve_recovers_without_demotion():
    """Mild injected corruption is repaired at full depth: the ladder
    returns after one attempt, converged, with the chaos wire point
    exercised through ops_transform (the same hook the bench uses)."""
    op, prec, ops0, b = _problem()
    chaos = ChaosConfig(seed=7, payload_rel_amp=1e-5)
    be = get_backend("local")
    res, attempts = governed_solve(
        be, op, b, l=4, prec=prec,
        ops_transform=lambda o: chaos_ops(o, chaos),
        tol=1e-5, maxit=400, max_restarts=120)
    assert len(attempts) == 1 and attempts[0]["l"] == 4
    assert attempts[0]["converged"]
    assert _true_rel(op, b, res.x) < 1e-5
