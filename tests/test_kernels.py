"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _arr(shape, dtype):
    a = RNG.standard_normal(shape)
    return jnp.asarray(a, dtype)


@pytest.mark.parametrize("shape", [(16, 16), (24, 100), (100, 50),
                                   (17, 130), (8, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_stencil2d5(shape, dtype):
    g = _arr(shape, dtype)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(
        ops.stencil2d5_apply(g), ref.stencil2d5_ref(g), rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(16, 16, 16), (8, 12, 50), (100, 10, 10),
                                   (4, 6, 130)])
@pytest.mark.parametrize("eps", [1.0, 0.01])
def test_stencil3d7(shape, eps):
    g = _arr(shape, jnp.float32)
    np.testing.assert_allclose(
        ops.stencil3d7_apply(g, eps), ref.stencil3d7_ref(g, eps),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,w,nx", [(16, 4, 16), (100, 7, 100),
                                    (256, 13, 300), (37, 5, 37)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_ell_spmv(r, w, nx, dtype):
    """Padded-row ELL SpMV kernel vs the jnp oracle (DESIGN.md §12).
    ``nx > r`` exercises the halo-extended local vector of the
    distributed path (x longer than the row count)."""
    x = _arr((nx,), dtype)
    cols = jnp.asarray(RNG.integers(0, nx, size=(r, w)), jnp.int32)
    vals = _arr((r, w), dtype)
    # zero out a padding tail per row, as the ELL packer produces
    nnz = RNG.integers(1, w + 1, size=(r,))
    mask = np.arange(w)[None, :] < nnz[:, None]
    vals = jnp.where(jnp.asarray(mask), vals, 0.0)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(
        ops.ell_spmv_apply(x, cols, vals), ref.ell_spmv_ref(x, cols, vals),
        rtol=tol, atol=tol)


def test_ell_spmv_matches_operator():
    """Kernel-routed SparseOp.apply == pure-jnp apply == dense matvec."""
    from repro.linalg import random_fem_mesh

    op = random_fem_mesh(3, 120)
    x = _arr((op.n,), jnp.float64)
    y_dense = op.to_dense() @ np.asarray(x)
    np.testing.assert_allclose(op.apply(x), y_dense, atol=1e-10)
    np.testing.assert_allclose(op.apply_kernel(x), y_dense, atol=1e-10)


@pytest.mark.parametrize("k,n", [(1, 128), (3, 1000), (7, 16384),
                                 (11, 100000), (2, 131072)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_dots(k, n, dtype):
    m = _arr((k, n), dtype)
    v = _arr((n,), dtype)
    # f32 dot of n ~N(0,1) terms: abs error scales with sqrt(n)*eps
    atol = 1e-4 * np.sqrt(n) if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        ops.fused_dots(m, v), ref.fused_dots_ref(m, v),
        rtol=1e-4, atol=atol)


@pytest.mark.parametrize("k,n,s", [(1, 128, 1), (5, 1000, 8), (7, 16384, 3),
                                   (3, 5000, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_dots_mrhs(k, n, s, dtype):
    """Multi-RHS dot block (the slab payload, DESIGN.md §11): (K, N) x
    (N, S) streamed in one pass == plain matmul; column 0 == the
    single-RHS kernel."""
    m = _arr((k, n), dtype)
    V = _arr((n, s), dtype)
    # the accumulator block is f32 whatever the input dtype (kernel
    # design); abs error of an n-term f32 dot scales with sqrt(n)*eps
    atol = 1e-4 * np.sqrt(n)
    out = ops.fused_dots_mrhs(m, V)
    assert out.shape == (k, s)
    np.testing.assert_allclose(out, np.asarray(m) @ np.asarray(V),
                               rtol=1e-4, atol=atol)
    # single-RHS kernel agreement (both accumulate in f32; contraction
    # order differs between the (BN, S) and (BN, 1) shapes)
    np.testing.assert_allclose(out[:, 0], ops.fused_dots(m, V[:, 0]),
                               rtol=1e-4, atol=atol)


@pytest.mark.parametrize("n", [128, 1000, 70000, 200000])
@pytest.mark.parametrize("coeffs", [(0.5, -1.25, 2.0), (0.0, 0.0, 1.0),
                                    (1e3, -1e-3, 0.1)])
def test_fused_axpy3(n, coeffs):
    a, b, c = (_arr((n,), jnp.float32) for _ in range(3))
    c1, c2, s = coeffs
    np.testing.assert_allclose(
        ops.fused_axpy3(a, b, c, c1, c2, s),
        ref.fused_axpy3_ref(a, b, c, c1, c2, s), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,h,hkv,d,s,kv_len,bs", [
    (2, 8, 2, 64, 1000, 900, 256),
    (1, 4, 4, 32, 512, 512, 128),     # MHA
    (3, 6, 1, 16, 300, 123, 512),     # MQA, padding > kv_len
])
def test_decode_attention(b, h, hkv, d, s, kv_len, bs):
    q = _arr((b, h, d), jnp.float32)
    k = _arr((b, s, hkv, d), jnp.float32)
    v = _arr((b, s, hkv, d), jnp.float32)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, block_s=bs)
    oref = ref.decode_attention_ref(
        q.reshape(b, hkv, h // hkv, d),
        jnp.transpose(k, (0, 2, 1, 3)), jnp.transpose(v, (0, 2, 1, 3)),
        kv_len).reshape(b, h, d)
    np.testing.assert_allclose(out, oref, rtol=2e-4, atol=2e-4)


def test_decode_attention_stats_combine():
    """Split-KV merge identity: combining shard stats == full attention."""
    b, h, hkv, d, s = 2, 4, 2, 32, 512
    q = _arr((b, h, d), jnp.float32)
    k = _arr((b, s, hkv, d), jnp.float32)
    v = _arr((b, s, hkv, d), jnp.float32)
    # two "shards" of the sequence
    o1, m1, l1 = ops.decode_attention_stats(q, k[:, :256], v[:, :256], 256,
                                            block_s=128)
    o2, m2, l2 = ops.decode_attention_stats(q, k[:, 256:], v[:, 256:], 256,
                                            block_s=128)
    m = jnp.maximum(m1, m2)
    num = o1 * jnp.exp(m1 - m) + o2 * jnp.exp(m2 - m)
    den = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    merged = (num / den).reshape(b, h, d)
    full = ops.decode_attention(q, k, v, kv_len=s, block_s=128)
    np.testing.assert_allclose(merged, full, rtol=2e-4, atol=2e-4)


def test_stencil_kernel_inside_operator():
    """use_kernel=True routes the operator through Pallas; same results."""
    from repro.linalg.operators import Stencil2D5, Stencil3D7
    op_a = Stencil2D5(32, 24, use_kernel=False)
    op_b = Stencil2D5(32, 24, use_kernel=True)
    x = jnp.asarray(RNG.standard_normal(op_a.n), jnp.float32)
    np.testing.assert_allclose(op_a.apply(x), op_b.apply(x),
                               rtol=1e-5, atol=1e-5)
    op_a3 = Stencil3D7(8, 12, 10, eps_z=0.3, use_kernel=False)
    op_b3 = Stencil3D7(8, 12, 10, eps_z=0.3, use_kernel=True)
    x = jnp.asarray(RNG.standard_normal(op_a3.n), jnp.float32)
    np.testing.assert_allclose(op_a3.apply(x), op_b3.apply(x),
                               rtol=1e-5, atol=1e-5)
