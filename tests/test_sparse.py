"""Unstructured sparse subsystem (DESIGN.md §12): SparseOp storage /
apply parity vs to_dense, the RCM ordering, the partition plan's
send/recv index sets (validated by a pure-numpy halo emulation), plan
caching, and solver integration — plus hypothesis-generated SPD graph
Laplacians when hypothesis is installed."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chebyshev import shifts_for_operator
from repro.linalg import (
    SparseOp,
    partition_spd,
    plan_for,
    random_fem_icesheet,
    random_fem_mesh,
    rcm_reorder,
    sparse_from_coo,
    sparse_from_dense,
)
from repro.linalg.partition import emulate_partitioned_apply
from repro.linalg.sparse import bandwidth, permute_spd
from repro.parallel import get_backend

RNG = np.random.default_rng(11)


# ----------------------------------------------------------- storage ----

def test_coo_roundtrip_and_duplicate_coalescing():
    n = 6
    rows = [0, 0, 1, 2, 5, 0]
    cols = [0, 3, 1, 2, 5, 3]          # (0,3) appears twice -> summed
    vals = [2.0, 1.0, 3.0, 4.0, 5.0, 0.5]
    op = sparse_from_coo(n, rows, cols, vals)
    a = np.zeros((n, n))
    for r, c, v in zip(rows, cols, vals):
        a[r, c] += v
    np.testing.assert_allclose(op.to_dense(), a)
    assert op.w == 2                    # row 0 has two distinct columns


def test_dense_roundtrip_apply_diag():
    a = RNG.standard_normal((20, 20))
    a = a @ a.T + 20 * np.eye(20)
    op = sparse_from_dense(a)
    np.testing.assert_allclose(op.to_dense(), a, atol=1e-12)
    x = jnp.asarray(RNG.standard_normal(20))
    np.testing.assert_allclose(op.apply(x), a @ np.asarray(x), atol=1e-10)
    np.testing.assert_allclose(op.diag(), np.diagonal(a), atol=1e-12)


@pytest.mark.parametrize("gen", [
    lambda: random_fem_mesh(0, 96, avg_degree=5),
    lambda: random_fem_mesh(1, 250),
    lambda: random_fem_icesheet(2, 8, 6, 4, eps_z=0.05),
])
def test_generators_spd_and_apply_parity(gen):
    op = gen()
    a = op.to_dense()
    np.testing.assert_allclose(a, a.T, atol=1e-12)
    w = np.linalg.eigvalsh(a)
    assert w[0] > 0, "generated operator must be SPD"
    x = jnp.asarray(RNG.standard_normal(op.n))
    np.testing.assert_allclose(op.apply(x), a @ np.asarray(x), atol=1e-9)
    # Lanczos eig ESTIMATES land in the right neighbourhood: the upper
    # bound brackets lambda_max (fast Ritz convergence + 5% margin); the
    # lower one is within a small factor of lambda_min — what the
    # Chebyshev shift schedule needs (order of magnitude, not exactness;
    # the Gershgorin bound it replaced was off by ~100x here).
    lmin, lmax = op.eig_bounds()
    assert lmax >= w[-1] * 0.999 and lmax < 1.5 * w[-1]
    assert 0.3 * w[0] < lmin <= 1.2 * w[0]


# ---------------------------------------------------------- ordering ----

def test_rcm_reduces_bandwidth_and_preserves_spectrum():
    op = random_fem_mesh(0, 300)
    oop, perm = rcm_reorder(op)
    assert bandwidth(oop) < bandwidth(op)
    a = op.to_dense()
    np.testing.assert_allclose(oop.to_dense(), a[np.ix_(perm, perm)],
                               atol=1e-12)
    w0 = np.linalg.eigvalsh(a)
    w1 = np.linalg.eigvalsh(oop.to_dense())
    np.testing.assert_allclose(w0, w1, rtol=1e-9)


def test_permute_spd_identity():
    op = random_fem_mesh(4, 64)
    perm = np.arange(64)
    np.testing.assert_allclose(permute_spd(op, perm).to_dense(),
                               op.to_dense(), atol=1e-14)


# --------------------------------------------------------- partition ----

@pytest.mark.parametrize("gen,n_shards", [
    (lambda: random_fem_mesh(0, 96, avg_degree=5), 8),   # multi-hop halo
    (lambda: random_fem_mesh(1, 400), 8),                # one-hop halo
    (lambda: random_fem_icesheet(2, 10, 6, 4, eps_z=0.05), 8),
    (lambda: random_fem_mesh(5, 120), 4),
    (lambda: random_fem_mesh(6, 75), 1),                 # degenerate S=1
])
def test_partition_plan_send_recv_sets(gen, n_shards):
    op = gen()
    plan = partition_spd(op, n_shards)
    a = op.to_dense()
    x = RNG.standard_normal(op.n)
    xp = x[plan.perm]
    y = emulate_partitioned_apply(plan, xp)
    yref = a[np.ix_(plan.perm, plan.perm)] @ xp
    np.testing.assert_allclose(y, yref, atol=1e-11)
    assert plan.halo_rows_fraction() > 0 or n_shards == 1
    assert 0 < plan.occupancy() <= 1.0
    # send-bytes convention shared with the structured operators (one
    # per-direction buffer x 2 directions; see PartitionPlan.neighbor_bytes)
    assert plan.neighbor_bytes() == 2 * plan.hops * plan.max_send * 8


def test_partition_requires_divisible_n():
    op = random_fem_mesh(0, 90)
    with pytest.raises(AssertionError, match="n % n_shards"):
        partition_spd(op, 8)


def test_plan_cache_memoizes():
    from repro.linalg.partition import _PLAN_CACHE

    op = random_fem_mesh(7, 80)
    before = len(_PLAN_CACHE)
    p1 = plan_for(op, 4)
    p2 = plan_for(SparseOp(cols=op.cols, vals=op.vals), 4)  # equal content
    assert p1 is p2
    assert len(_PLAN_CACHE) == before + 1


def test_setup_cache_partition_fingerprinting():
    from repro.serve.cache import SetupCache

    cache = SetupCache()
    op = random_fem_mesh(8, 80)
    p1 = cache.partition(op, 4)
    p2 = cache.partition(SparseOp(cols=op.cols, vals=op.vals), 4)
    assert p1 is p2
    st = cache.stats()
    assert st["hits"] == 1 and st["misses"] == 1


# ------------------------------------------------------------ solvers ----

@pytest.mark.parametrize("method", ["cg", "pcg", "plcg"])
def test_local_solver_on_sparse_operator(method):
    op = random_fem_mesh(9, 150)
    b = jnp.asarray(RNG.standard_normal(op.n))
    kw = dict(method=method, tol=1e-10, maxit=1000)
    if method == "plcg":
        kw.update(l=2, sigmas=shifts_for_operator(op, 2))
    res = get_backend("local").solve(op, b, **kw)
    assert bool(res.converged)
    xd = np.linalg.solve(op.to_dense(), np.asarray(b))
    assert np.abs(np.asarray(res.x) - xd).max() < 1e-7


def test_autotuner_neighbor_bytes_term():
    """The cost model reacts to the partition plan's halo traffic: more
    neighbour bytes -> slower modeled iteration, and the SparseOp hook
    reports exactly the plan's send/recv volume (DESIGN.md §12)."""
    from repro.launch.autotune import (model_iteration_time,
                                       operator_neighbor_bytes)
    from benchmarks.timing_model import CORI

    op = random_fem_mesh(10, 400)
    nb = operator_neighbor_bytes(op, 8)
    assert nb == plan_for(op, 8).neighbor_bytes()
    t_small = model_iteration_time(CORI, 4_000_000, 512, "plcg", l=2,
                                   unroll=3, neighbor_bytes=1_000)
    t_big = model_iteration_time(CORI, 4_000_000, 512, "plcg", l=2,
                                 unroll=3, neighbor_bytes=10_000_000)
    assert t_big > t_small


# --------------------------------------------------------- sliced ELL ----

def test_sliced_ell_matches_dense():
    """Degree-sorted sliced-ELL storage (DESIGN.md §13): the permuted
    operator reproduces P A P^T exactly, and the composed permutation is
    a valid reordering of the original rows."""
    from repro.linalg.sparse import sliced_ell_reorder

    op = random_fem_mesh(4, 300)
    sliced, perm = sliced_ell_reorder(op, slice_rows=32)
    assert sorted(perm.tolist()) == list(range(op.n))
    a = op.to_dense()
    np.testing.assert_allclose(sliced.to_dense(), a[np.ix_(perm, perm)],
                               atol=1e-12)
    x = jnp.asarray(RNG.standard_normal(op.n))
    y_ref = np.asarray(op.apply(x))
    inv = np.argsort(perm)
    y = np.asarray(sliced.apply(x[jnp.asarray(perm)]))[inv]
    np.testing.assert_allclose(y, y_ref, atol=1e-11)


def test_sliced_ell_occupancy_improves():
    """The gated bench claim: on the BENCH_spmv FEM problem class the
    sliced layout lifts slot occupancy from ~0.58 to >= 0.85, and the
    accounting is self-consistent (nnz conserved, waste = 1 - occ)."""
    from repro.linalg.sparse import sliced_ell_reorder

    op = random_fem_mesh(0, 1024)
    uniform_occ = op.nnz / (op.n * op.w)
    sliced, _perm = sliced_ell_reorder(op, slice_rows=64)
    assert sliced.nnz == op.nnz
    assert sliced.occupancy() >= max(0.85, uniform_occ)
    assert abs(sliced.padding_waste() - (1 - sliced.occupancy())) < 1e-12
    # degree sort is what tightens the slices: per-slice widths are
    # monotonically non-increasing
    widths = [c.shape[1] for c in sliced.slice_cols]
    assert widths == sorted(widths, reverse=True)


def test_sliced_ell_respects_preordering():
    """An already-RCM-ordered operator keeps its ordering as the base of
    the composition (no second RCM pass)."""
    from repro.linalg.sparse import (degree_sort_permutation, rcm_reorder,
                                     sliced_ell_reorder)

    op, rperm = rcm_reorder(random_fem_mesh(2, 200))
    sliced, perm = sliced_ell_reorder(op, slice_rows=25)
    dperm = degree_sort_permutation(op)
    np.testing.assert_array_equal(perm, dperm)
    assert sliced.n == op.n


# Hypothesis-generated SPD graph Laplacians live in
# tests/test_sparse_properties.py (whole-module skip when hypothesis is
# absent, same pattern as tests/test_properties.py).
