"""Pins the measurement conventions EXPERIMENTS.md §Roofline relies on:
(1) compiled.cost_analysis() reports the PER-DEVICE partitioned module;
(2) collective payloads parsed from the partitioned HLO are shard-sized.
Subprocess with 4 fake host devices (tests must not set XLA_FLAGS
globally)."""

import os
import subprocess
import sys


def test_cost_analysis_is_per_device():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils.hlo import count_collectives
from repro.utils.roofline import cost_analysis_dict
from repro.parallel import shard_map_compat

mesh = jax.make_mesh((4,), ("x",))
n = 512
a = jax.ShapeDtypeStruct((n, n), jnp.float32)
bsh = NamedSharding(mesh, P("x", None))
rep = NamedSharding(mesh, P())

# sharded matmul: per-device flops = 2 n^3 / 4
comp = jax.jit(lambda a, b: a @ b,
               in_shardings=(bsh, rep)).lower(a, a).compile()
flops = cost_analysis_dict(comp)["flops"]
assert abs(flops - 2 * n**3 / 4) / (2 * n**3 / 4) < 0.01, flops

# psum of a replicated (n,n): partitioned all-reduce payload = full tensor
comp2 = jax.jit(
    lambda x: shard_map_compat(
        lambda v: jax.lax.psum(v, "x"), mesh=mesh,
        in_specs=P("x", None), out_specs=P())(x),
    in_shardings=(bsh,), out_shardings=rep).lower(a).compile()
c = count_collectives(comp2.as_text())
ar = c.get("all-reduce", {"bytes": 0})
# each device contributes its (n/4, n) shard -> payload n/4*n*4 bytes
assert ar["bytes"] == n // 4 * n * 4, c
print("PER-DEVICE-OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=600)
    assert "PER-DEVICE-OK" in out.stdout, (out.stdout[-1000:],
                                           out.stderr[-2000:])
