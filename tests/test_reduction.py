"""Staged ring-reduction subsystem (repro.parallel.reduction,
DESIGN.md §14): ladder mechanics, rank-order determinism against the
monolithic psum, the eager local oracle, mixed-precision compensated
accumulation, and the SolverOps handle API.  Single-process tests here;
compiled-HLO structure and mesh parity live in tests/test_distributed.py.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import SolverOps, dot_block_rows
from repro.parallel.reduction import (
    StagedConfig,
    hop_groups,
    hop_payload_bytes,
    oracle_solver_ops,
    ordered_reduce,
    reduction_wire_bytes,
)

jax.config.update("jax_enable_x64", True)


# ------------------------------------------------------------- ladder shape --
def test_hop_groups_partition_the_ring():
    for p in (2, 3, 8, 16):
        for stages in range(1, p):
            groups = hop_groups(p, stages)
            assert len(groups) == stages
            flat = [h for g in groups for h in g]
            assert flat == list(range(p - 1)), (p, stages, groups)
            # front-loaded: earlier steps never smaller than later ones
            sizes = [len(g) for g in groups]
            assert all(a >= b for a, b in zip(sizes, sizes[1:]))
            assert max(sizes) == math.ceil((p - 1) / stages)


def test_staged_config_validation():
    with pytest.raises(ValueError):
        StagedConfig(n_shards=8, stages=0)
    with pytest.raises(ValueError):
        StagedConfig(n_shards=8, stages=8)   # max is p-1 hops
    cfg = StagedConfig(n_shards=8, stages=7)
    assert cfg.n_hops == 7
    assert StagedConfig(n_shards=1, stages=1).n_hops == 0
    f64 = jnp.zeros((), jnp.float64).dtype
    assert cfg.wire_dtype(f64) == f64
    cfg32 = StagedConfig(n_shards=8, stages=2, payload_dtype=jnp.float32)
    assert cfg32.wire_dtype(f64) == jnp.dtype(jnp.float32)
    assert cfg32.compensated(f64)
    assert not cfg.compensated(f64)


def test_wire_accounting():
    # per-hop payload: the (2l+1)[, s] block in the wire dtype
    assert hop_payload_bytes(2, dsize=8) == 5 * 8
    assert hop_payload_bytes(3, s=8, dsize=4) == 7 * 8 * 4
    # fp32 halves exactly the per-hop wire payload
    assert hop_payload_bytes(3, dsize=4) * 2 == hop_payload_bytes(3, dsize=8)
    # total per-shard wire: P-1 hops x payload
    assert reduction_wire_bytes(8, 2, dsize=8) == 7 * 5 * 8


# ------------------------------------------------- ordered / compensated sum --
def test_ordered_reduce_is_rank_order_linear():
    rng = np.random.default_rng(0)
    parts = jnp.asarray(rng.standard_normal((8, 5)))
    out = ordered_reduce(parts, parts.dtype, compensated=False)
    ref = np.asarray(parts)[0].copy()
    for k in range(1, 8):
        ref = ref + np.asarray(parts)[k]
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_compensated_reduce_beats_naive_fp32():
    # P partials with wildly mixed magnitudes: naive fp32 accumulation
    # loses the small terms; Kahan-into-fp64 of fp32-rounded partials is
    # exact up to the initial fp32 rounding of each partial (the
    # DESIGN.md §14 bound: error <= P ulps of the PARTIALS, no
    # accumulation-order growth).
    rng = np.random.default_rng(1)
    parts64 = rng.standard_normal(64) * np.logspace(0, 7, 64)
    exact = math.fsum(parts64)
    parts32 = jnp.asarray(parts64, jnp.float32).reshape(64, 1)
    kahan = float(ordered_reduce(parts32, jnp.float64, compensated=True)[0])
    naive32 = float(ordered_reduce(parts32, jnp.float32,
                                   compensated=False)[0])
    # Kahan error bounded by the sum of per-partial fp32 roundings.
    bound = np.abs(parts64).sum() * np.finfo(np.float32).eps
    assert abs(kahan - exact) <= bound
    assert abs(kahan - exact) <= abs(naive32 - exact) + 1e-30


# --------------------------------------------------------- the eager oracle --
def _poisson_ops(n_shards=1, **kw):
    from repro.linalg import Stencil2D5
    op = Stencil2D5(16, 12)
    if n_shards == 1 and not kw:
        return op, SolverOps.local(op)
    cfg = StagedConfig(n_shards=n_shards, axis=None, **kw)
    return op, oracle_solver_ops(op, None, cfg)


def test_oracle_matches_monolithic_dot_bitwise_via_rank_split():
    """The oracle's rank-sliced partials, reduced in rank order, equal
    the monolithic full-width row sum bitwise is NOT guaranteed (the
    grouping differs) — but the oracle must be self-consistent: every
    virtual shard count yields the same result as an explicit numpy
    rank-order recombination of the same slices."""
    op, _ = _poisson_ops()
    rng = np.random.default_rng(2)
    mat = jnp.asarray(rng.standard_normal((5, op.n)))
    vec = jnp.asarray(rng.standard_normal(op.n))
    for v in (2, 4, 8):
        _, ops = _poisson_ops(n_shards=v, stages=min(2, v - 1))
        dots = ops.wait(ops.start(mat, vec))
        m = np.asarray(mat).reshape(5, v, op.n // v)
        w = np.asarray(vec).reshape(v, op.n // v)
        ref = (m[:, 0, :] * w[0]).sum(axis=1)
        for r in range(1, v):
            ref = ref + (m[:, r, :] * w[r]).sum(axis=1)
        np.testing.assert_allclose(np.asarray(dots), ref, rtol=1e-14)


def test_oracle_solver_parity_with_monolithic_local():
    """End-to-end: the eager ladder oracle is a drop-in SolverOps — the
    p(l)-CG residual history it produces converges to the same solution
    as the monolithic local path (histories differ only by the dot
    block's reduction grouping, a ULP-level effect on this small SPD
    stencil)."""
    from repro.core import pipelined_cg
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5

    op = Stencil2D5(16, 12)
    b = jnp.asarray(np.random.default_rng(3).standard_normal(op.n))
    sig = shifts_for_operator(op, 2)
    kw = dict(l=2, sigmas=sig, tol=1e-10, maxit=1500)
    res_m = pipelined_cg.solve(SolverOps.local(op), b, **kw)
    for v, stages in ((4, 1), (4, 3), (8, 2)):
        cfg = StagedConfig(n_shards=v, stages=stages, axis=None)
        res_o = pipelined_cg.solve(oracle_solver_ops(op, None, cfg), b, **kw)
        assert bool(res_o.converged)
        assert abs(int(res_o.iters) - int(res_m.iters)) <= 2
        np.testing.assert_allclose(np.asarray(res_o.x), np.asarray(res_m.x),
                                   atol=1e-9)


def test_oracle_stage_count_invariance_is_bitwise():
    """The ladder's defining property (DESIGN.md §14): stages only
    regroups the hops — the wait's rank-order summation is identical —
    so residual histories across stage counts agree BITWISE."""
    from repro.core import pipelined_cg
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5

    op = Stencil2D5(16, 12)
    b = jnp.asarray(np.random.default_rng(4).standard_normal(op.n))
    sig = shifts_for_operator(op, 3)
    kw = dict(l=3, sigmas=sig, tol=1e-9, maxit=1500)
    hists = []
    for stages in (1, 2, 3, 7):
        cfg = StagedConfig(n_shards=8, stages=stages, axis=None)
        res = pipelined_cg.solve(oracle_solver_ops(op, None, cfg), b, **kw)
        hists.append(np.asarray(res.res_history))
    for h in hists[1:]:
        np.testing.assert_array_equal(h, hists[0])


def test_oracle_fp32_payload_bounded_tail():
    """fp32 wire + fp64 compensated accumulation: the solver still
    converges to the same solution at the same iteration count +-2, the
    early history matches at fp32-rounding level, and the tail is
    bounded (Krylov recurrences amplify the payload rounding, the PR 3
    convention)."""
    from repro.core import pipelined_cg
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5

    op = Stencil2D5(16, 12)
    b = jnp.asarray(np.random.default_rng(5).standard_normal(op.n))
    sig = shifts_for_operator(op, 2)
    kw = dict(l=2, sigmas=sig, tol=1e-8, maxit=1500)
    res64 = pipelined_cg.solve(
        oracle_solver_ops(op, None, StagedConfig(n_shards=8, stages=2,
                                                 axis=None)), b, **kw)
    res32 = pipelined_cg.solve(
        oracle_solver_ops(op, None, StagedConfig(
            n_shards=8, stages=2, axis=None,
            payload_dtype=jnp.float32)), b, **kw)
    assert bool(res32.converged)
    assert abs(int(res32.iters) - int(res64.iters)) <= 2
    h64, h32 = np.asarray(res64.res_history), np.asarray(res32.res_history)
    n0 = float(res64.norm0)
    m = (h64 >= 0) & (h32 >= 0)
    diff = np.abs(h64[m] - h32[m]) / n0
    assert diff[:10].max() < 1e-5          # head: fp32 payload rounding
    assert diff.max() < 5e-2               # tail: bounded amplification
    np.testing.assert_allclose(np.asarray(res32.x), np.asarray(res64.x),
                               atol=1e-6)


def test_fp32_solver_with_fp32_wire():
    """Regression (review finding): a float32 SOLVER with
    reduction_dtype=float32 must trace and converge — the staged wait
    accumulates in the widest available dtype and the solvers normalize
    the payload back to their own dtype at the consumption sites."""
    from repro.core import ghysels_pcg, pipelined_cg
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5

    op = Stencil2D5(16, 12)
    b32 = jnp.asarray(np.random.default_rng(7).standard_normal(op.n),
                      jnp.float32)
    sig32 = jnp.asarray(shifts_for_operator(op, 2), jnp.float32)
    cfg = StagedConfig(n_shards=4, stages=2, axis=None,
                       payload_dtype=jnp.float32)
    ops = oracle_solver_ops(op, None, cfg)
    res = pipelined_cg.solve(ops, b32, l=2, sigmas=sig32, tol=1e-5,
                             maxit=400)
    assert res.res_history.dtype == jnp.float32
    assert bool(res.converged)
    res_p = ghysels_pcg.solve(ops, b32, tol=1e-5, maxit=400)
    assert res_p.res_history.dtype == jnp.float32
    assert bool(res_p.converged)


# ------------------------------------------------------- handle API surface --
def test_handle_zeros_shapes():
    op, mono = _poisson_ops()
    assert mono.handle_zeros((5,), jnp.float64).shape == (5,)
    _, staged = _poisson_ops(n_shards=8, stages=2)
    h = staged.handle_zeros((5,), jnp.float64)
    assert h.shape == (8, 5) and h.dtype == jnp.float64
    _, staged32 = _poisson_ops(n_shards=8, stages=2,
                               payload_dtype=jnp.float32)
    h32 = staged32.handle_zeros((7,), jnp.float64)
    assert h32.shape == (8, 7) and h32.dtype == jnp.float32


def test_advance_is_identity_on_monolithic_ops():
    op, mono = _poisson_ops()
    h = jnp.arange(5.0)
    np.testing.assert_array_equal(np.asarray(mono.advance(h, 0)),
                                  np.asarray(h))
    # wait accepts (and ignores) the advanced count on monolithic ops
    rng = np.random.default_rng(6)
    mat = jnp.asarray(rng.standard_normal((3, op.n)))
    vec = jnp.asarray(rng.standard_normal(op.n))
    d0 = mono.wait(mono.start(mat, vec), advanced=0)
    np.testing.assert_array_equal(np.asarray(d0),
                                  np.asarray(dot_block_rows(mat, vec)))


def test_local_backend_staged_registry():
    from repro.parallel import get_backend

    be = get_backend("local", reduction="staged", virtual_shards=8,
                     reduction_stages=3)
    assert be.reduction_mode == "staged"
    assert be.reduction_fallback is None
    assert be.supports_staged_reduction
    cfg = be.reduction_cfg
    assert cfg.n_shards == 8 and cfg.stages == 3
    with pytest.raises(ValueError):
        get_backend("local", reduction="banana")
