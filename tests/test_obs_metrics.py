"""Metrics registry (DESIGN.md §16): registration semantics, labeled
series, exporters, deterministic snapshots under a VirtualClock, and —
the regression half — parity between the registry series and the serve
layer's pre-§16 stat attributes, which are now thin views onto it."""

import json

import numpy as np
import pytest

from repro.linalg import operators as ops_mod
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.parallel import get_backend
from repro.serve import SolverService, VirtualClock
from repro.serve.replay import TrafficClass, poisson_trace, replay


# ---------------------------------------------------------------- registry --

def test_registration_idempotent_and_kind_checked():
    r = MetricsRegistry()
    c = r.counter("a_total", "help text")
    assert r.counter("a_total") is c
    with pytest.raises(TypeError):
        r.gauge("a_total")
    with pytest.raises(TypeError):
        r.histogram("a_total")
    assert isinstance(r.gauge("g"), Gauge)
    assert isinstance(r.histogram("h"), Histogram)
    assert r.get("a_total") is c
    assert r.get("missing") is None


def test_counter_semantics():
    c = MetricsRegistry().counter("n_total", label_names=("kind",))
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    c.labels(kind="x").inc()
    c.labels(kind="x").inc()
    c.labels(kind="y").inc()
    assert c.labels(kind="x").value() == 2
    assert c.labels(kind="y").value() == 1
    assert c.value() == 3.5                 # unlabeled series untouched
    with pytest.raises(KeyError):
        c.labels(bogus="z").inc()
    c.reset()
    assert c.value() == 0


def test_gauge_semantics():
    g = MetricsRegistry().gauge("g")
    g.set(4.0)
    g.inc()
    g.dec(2.0)
    assert g.value() == 3.0


def test_histogram_quantile_matches_service_formula():
    """The nearest-rank arithmetic is exactly the old SolverService
    percentile: sorted reservoir indexed at int(p/100 * n)."""
    h = MetricsRegistry().histogram("lat", maxlen=100)
    vals = list(np.random.default_rng(0).standard_normal(37))
    for v in vals:
        h.observe(v)
    s = sorted(vals)
    for p in (50, 90, 99):
        assert h.quantile(p) == s[min(int(p / 100 * len(s)), len(s) - 1)]
    assert h.count_() == 37
    assert h.sum_() == pytest.approx(sum(vals))
    # bounded reservoir: count/sum stay exact past maxlen
    h2 = MetricsRegistry().histogram("lat2", maxlen=4)
    for v in range(10):
        h2.observe(float(v))
    assert h2.count_() == 10 and h2.sum_() == 45.0
    assert list(h2.reservoir()) == [6.0, 7.0, 8.0, 9.0]
    h2.clear()
    assert h2.count_() == 0 and h2.quantile(50) == 0.0


def test_exporters():
    r = MetricsRegistry()
    r.counter("req_total", "requests").labels(kind="a").inc(3)
    r.gauge("depth").set(2)
    h = r.histogram("lat")
    h.observe(1.0)
    h.observe(3.0)
    text = r.to_prometheus_text()
    assert "# TYPE req_total counter" in text
    assert 'req_total{kind="a"} 3.0' in text
    assert "# TYPE depth gauge" in text
    assert "# TYPE lat summary" in text
    assert 'lat{quantile="0.5"}' in text
    assert "lat_count 2" in text and "lat_sum 4.0" in text
    snap = r.snapshot(VirtualClock(start=7.0))
    assert snap["time"] == 7.0
    assert snap["metrics"]["req_total"]["series"]['{kind="a"}'] == 3.0
    assert snap["metrics"]["lat"]["series"][""]["count"] == 2
    json.loads(r.to_json())                 # round-trips


def test_default_registry_singleton():
    assert default_registry() is default_registry()
    assert isinstance(default_registry().counter("smoke_total"), Counter)


# ------------------------------------------------------------ serve parity --

def _small_service():
    op = ops_mod.Stencil2D5(8, 8)
    svc = SolverService(get_backend("local"), s=2, method="plcg", l=2,
                        chunk_iters=40, maxit=300, clock=VirtualClock())
    svc.register_operator("lap", op)
    return op, svc


def _replay_once():
    op, svc = _small_service()
    classes = [TrafficClass(op_key="lap", n=op.n, tol=1e-8,
                            deadline_s=0.5)]
    trace = poisson_trace(classes, rate_per_s=50.0, n_requests=12, seed=4)
    rep = replay(svc, trace, iter_time_s=1e-4, tick_overhead_s=1e-4)
    return svc, rep


def test_service_views_equal_registry_series():
    """The pre-§16 attributes are thin views: every count the service,
    scheduler and cache expose equals its backing registry series, and
    stats() reports the same numbers."""
    svc, rep = _replay_once()
    r = svc.registry
    assert svc.retired == r.get("serve_requests_retired_total").value()
    assert svc.rejected == r.get("serve_requests_rejected_total").value()
    assert svc.shed == r.get("serve_requests_shed_total").value()
    assert svc.slo_met == r.get("serve_requests_slo_met_total").value()
    h = r.get("serve_request_latency_seconds")
    assert list(svc._latencies) == list(h.reservoir())
    assert svc.retired == rep.n_retired > 0
    # scheduler: logs stay the determinism witnesses, counters agree
    sched = svc.scheduler
    assert sched.registry is r
    assert len(sched.shed_log) == r.get("serve_sheds_total").value()
    steals = r.get("serve_steals_total")
    assert len(sched.steal_log) == sum(
        v[0] for v in steals.series().values())
    assert sched.ticks == r.get("serve_ticks_total").value()
    assert sched.chunks_run == r.get("serve_chunks_total").value()
    # cache: hit/miss views
    cache = svc.cache
    assert cache.registry is r
    assert cache.hits == sum(
        v[0] for v in r.get("serve_setup_cache_hits_total").series().values())
    assert cache.misses == sum(
        v[0] for v in
        r.get("serve_setup_cache_misses_total").series().values())
    # stats() numbers come FROM the registry now
    st = svc.stats()
    assert st["retired"] == svc.retired
    assert st["shed"] == svc.shed
    assert st["latency_p50_s"] == h.quantile(50)
    assert st["setup_cache"]["hits"] == cache.hits


def test_reset_stats_zeroes_views_and_registry():
    svc, _rep = _replay_once()
    assert svc.retired > 0
    svc.reset_stats()
    assert svc.retired == 0 and svc.shed == 0 and svc.slo_met == 0
    assert len(svc._latencies) == 0
    assert svc.stats()["latency_p50_s"] == 0.0
    assert svc.scheduler.chunks_run == 0
    assert svc.registry.get("serve_chunks_total").value() == 0
    assert not svc.scheduler.steal_log and not svc.scheduler.shed_log


def test_metrics_snapshot_deterministic_across_replays():
    """Two replays of the same seeded trace on fresh services export
    byte-identical snapshots and Prometheus text (VirtualClock: no wall
    time anywhere in the export)."""
    svc1, _ = _replay_once()
    svc2, _ = _replay_once()
    assert json.dumps(svc1.metrics_snapshot(), sort_keys=True) == \
        json.dumps(svc2.metrics_snapshot(), sort_keys=True)
    assert svc1.metrics_text() == svc2.metrics_text()
    # the snapshot carries the serve gauges refreshed at export
    snap = svc1.metrics_snapshot()
    assert "serve_pending_requests" in snap["metrics"]
    assert "serve_slot_utilization" in snap["metrics"]
