"""Fused-iteration superkernel (DESIGN.md §13): bitwise parity against
the unfused reference path, the >= 2x modeled-HBM-bytes reduction, and
the donated / in-place slab state."""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import pipelined_cg
from repro.core.batched import solve_batched
from repro.core.chebyshev import shifts_for_operator
from repro.core.types import SolverOps
from repro.kernels import ref
from repro.kernels.fused_iter import SlabLayout, idx_layout, scal_layout
from repro.kernels.ops import fused_iteration_factory
from repro.launch.autotune import (fused_iteration_bytes,
                                   measured_iteration_bytes)
from repro.linalg.operators import DiagonalOp, Stencil2D5, Stencil3D7
from repro.linalg.preconditioners import BlockJacobi, JacobiPrec
from repro.linalg.sparse import random_fem_mesh, rcm_reorder
from repro.parallel import get_backend

RNG = np.random.default_rng(11)


def _solve_pair(op, prec, l, maxit=800, tol=1e-9):
    ops = SolverOps.local(op, prec)
    sig = shifts_for_operator(op, l)
    b = jnp.asarray(RNG.standard_normal(op.n))

    def run(fused):
        return jax.jit(lambda bb: pipelined_cg.solve(
            ops, bb, l, sigmas=sig, tol=tol, maxit=maxit,
            fused_iteration=fused))(b)

    return run(False), run(True)


# ------------------------------------------------------- kernel vs oracle --

@pytest.mark.parametrize("l", [1, 2, 3])
def test_kernel_matches_unfused_oracle(l):
    """One vector phase, random (valid-shaped) slab/idx/scal: the
    superkernel must reproduce ref.fused_iter_ref BITWISE — same
    expressions, same operands, one pass."""
    op = Stencil2D5(16, 12)
    layout = SlabLayout(l=l, RB=max(l + 1, 3))
    factory = fused_iteration_factory(op)
    fiter = factory(layout)
    IX, IS = idx_layout(l), scal_layout(l)
    S = jnp.asarray(RNG.standard_normal((layout.nv, op.n)))
    # plausible late-phase index bundle (i = l + 2)
    i = jnp.int32(l + 2)
    idx = jnp.zeros((IX["size"],), jnp.int32)
    for k in range(l):
        idx = idx.at[IX["fill"] + k].set(layout.zk_row(k, i + 1))
        idx = idx.at[IX["rec_w"] + k].set(layout.zk_row(k, i - l + k + 1))
        idx = idx.at[IX["rec_a"] + k].set(layout.zk_row(k + 1, i - l + k + 1))
        idx = idx.at[IX["rec_b"] + k].set(layout.zk_row(k, i - l + k))
        idx = idx.at[IX["rec_c"] + k].set(layout.zk_row(k, i - l + k - 1))
        idx = idx.at[IX["mat_v"] + k].set(layout.zk_row(0, i - 2 * l + 1 + k))
    for t in range(l - 1):
        idx = idx.at[IX["mat_z"] + t].set(layout.zk_row(l, i - l + 2 + t))
    idx = idx.at[IX["z_top"]].set(layout.zk_row(l, i))
    idx = idx.at[IX["zl_im1"]].set(layout.zk_row(l, i - 1))
    idx = idx.at[IX["z_w"]].set(layout.zk_row(l, i + 1))
    idx = idx.at[IX["u_i"]].set(layout.u_row(i))
    idx = idx.at[IX["u_im1"]].set(layout.u_row(i - 1))
    idx = idx.at[IX["u_w"]].set(layout.u_row(i + 1))
    idx = idx.at[IX["p_im"]].set(layout.zk_row(0, i - l))
    idx = idx.at[IX["f_late"]].set(1)
    idx = idx.at[IX["f_upd"]].set(1)
    scal = jnp.asarray(RNG.standard_normal(IS["size"]))
    scal = scal.at[IS["dlt_safe"]].set(1.25)
    scal = scal.at[IS["eta_new_safe"]].set(0.75)
    scal = scal.at[IS["eta0_safe"]].set(1.5)

    S_k, d_k = jax.jit(fiter)(S, idx, scal)
    S_r, d_r = jax.jit(lambda a, b_, c: ref.fused_iter_ref(
        a, b_, c, op.apply, lambda v: v, layout))(S, idx, scal)
    np.testing.assert_array_equal(np.asarray(S_k), np.asarray(S_r))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


def test_kernel_multi_tile_rows_bitwise():
    """Tiling the slab over columns must not change any ROW update
    bitwise; only the dot-partial summation order moves (documented
    tight-tail policy, DESIGN.md §13)."""
    op = Stencil2D5(16, 12)
    l = 2
    layout = SlabLayout(l=l, RB=3)
    factory = fused_iteration_factory(op)
    f1 = factory(layout)                       # single tile (default)
    f4 = factory(layout, block_n=op.n // 4)    # 4 tiles
    IX, IS = idx_layout(l), scal_layout(l)
    S = jnp.asarray(RNG.standard_normal((layout.nv, op.n)))
    idx = jnp.asarray(RNG.integers(0, layout.nv, IX["size"]), jnp.int32)
    idx = idx.at[IX["f_late"]].set(1).at[IX["f_upd"]].set(1)
    for k in range(l):
        idx = idx.at[IX["f_fill"] + k].set(0)
    scal = jnp.asarray(RNG.standard_normal(IS["size"]))
    scal = scal.at[IS["dlt_safe"]].set(1.1)
    scal = scal.at[IS["eta_new_safe"]].set(0.9)
    scal = scal.at[IS["eta0_safe"]].set(1.2)
    S1, d1 = jax.jit(f1)(S, idx, scal)
    S4, d4 = jax.jit(f4)(S, idx, scal)
    np.testing.assert_array_equal(np.asarray(S1), np.asarray(S4))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d4),
                               rtol=1e-12, atol=1e-12)


# ------------------------------------------------- solver-level parity ----

@pytest.mark.parametrize("l", [1, 2, 3])
def test_bitwise_parity_stencil2d(l):
    ru, rf = _solve_pair(Stencil2D5(32, 24), None, l)
    assert bool(ru.converged) and bool(rf.converged)
    assert int(ru.iters) == int(rf.iters)
    np.testing.assert_array_equal(np.asarray(ru.res_history),
                                  np.asarray(rf.res_history))
    np.testing.assert_array_equal(np.asarray(ru.x), np.asarray(rf.x))


def test_bitwise_parity_stencil3d_jacobi():
    op = Stencil3D7(8, 8, 8, eps_z=0.1)
    ru, rf = _solve_pair(op, JacobiPrec.from_operator(op), 2)
    assert bool(ru.converged)
    np.testing.assert_array_equal(np.asarray(ru.res_history),
                                  np.asarray(rf.res_history))
    np.testing.assert_array_equal(np.asarray(ru.x), np.asarray(rf.x))


def test_bitwise_parity_sparse():
    """Unstructured ELL rows through the superkernel: the in-kernel
    gather + explicit rowsum chain mirrors SparseOp.apply, so even the
    sparse path holds bitwise on a single device."""
    op, _perm = rcm_reorder(random_fem_mesh(0, 400))
    ru, rf = _solve_pair(op, None, 2, maxit=900)
    assert bool(ru.converged)
    np.testing.assert_array_equal(np.asarray(ru.res_history),
                                  np.asarray(rf.res_history))
    np.testing.assert_array_equal(np.asarray(ru.x), np.asarray(rf.x))


@pytest.mark.parametrize("l", [1, 2, 3])
def test_bitwise_parity_batched_s8(l):
    """The s=8 slab: vmapped superkernel vs vmapped unfused path, every
    column bitwise (the trailing-axis dot-block reduction is what makes
    this hold under batching — types.dot_block_rows)."""
    op = Stencil2D5(32, 24)
    ops = SolverOps.local(op)
    sig = shifts_for_operator(op, l)
    B = jnp.asarray(RNG.standard_normal((op.n, 8)))

    def run(fused):
        return jax.jit(lambda BB: solve_batched(
            ops, BB, "plcg", l=l, sigmas=sig, tol=1e-9, maxit=600,
            fused_iteration=fused))(B)

    ru, rf = run(False), run(True)
    np.testing.assert_array_equal(np.asarray(ru.res_history),
                                  np.asarray(rf.res_history))
    np.testing.assert_array_equal(np.asarray(ru.x), np.asarray(rf.x))
    assert np.array_equal(np.asarray(ru.iters), np.asarray(rf.iters))


def test_unsupported_combination_raises():
    import dataclasses

    op = Stencil3D7(8, 8, 8)
    bj = BlockJacobi.from_operator(op, block_size=8)
    ops = SolverOps.local(op, bj)
    with pytest.raises(ValueError, match="fused_iter_factory"):
        pipelined_cg.build(ops, jnp.zeros((op.n,)), 2, fused_iteration=True)
    # kernel-routed operators have no fused mirror either (their
    # standalone-kernel reductions round differently from the jnp
    # expressions the superkernel mirrors) — must fail loudly, not
    # silently break the bitwise contract
    sop, _ = rcm_reorder(random_fem_mesh(1, 200))
    sop_k = dataclasses.replace(sop, use_kernel=True)
    ops_k = SolverOps.local(sop_k)
    with pytest.raises(ValueError, match="fused_iter_factory"):
        pipelined_cg.build(ops_k, jnp.zeros((sop_k.n,)), 2,
                           fused_iteration=True)


# ------------------------------------------------------- HBM bytes gate ---

@pytest.mark.parametrize("l", [1, 2, 3])
def test_fused_hbm_bytes_at_least_2x_smaller(l):
    """ISSUE 4 acceptance: modeled HBM bytes per iteration drop >= 2x.

    Unfused side: XLA cost_analysis of the compiled iteration (the
    ~dozen separate slab passes, measured).  Fused side: the TPU
    accounting of the superkernel — an opaque custom call reads its
    operands and writes its results once (slab in/out + resident SPMV
    operand; ``fused_iteration_bytes``).  The interpret-mode
    cost_analysis of the fused path is NOT used here: the interpreter
    re-materializes kernel-interior temporaries that Mosaic keeps in
    VMEM (documented in benchmarks/iter_bench.py, which records all
    three numbers)."""
    op = Stencil2D5(128, 128)
    sig = shifts_for_operator(op, l)
    unfused = measured_iteration_bytes(op, l, sigmas=sig, fused=False)
    fused = fused_iteration_bytes(op.n, l)
    assert fused * 2 <= unfused, (l, fused, unfused, fused / unfused)


def test_iteration_bytes_grow_with_depth():
    n = 4096
    vals = [fused_iteration_bytes(n, l) for l in (1, 2, 3)]
    assert vals[0] < vals[1] < vals[2]
    # dominated by slab in + out: 2 * NV * n * 8, NV = (l+1)*RB + 5
    for l, v in zip((1, 2, 3), vals):
        nv = (l + 1) * max(l + 1, 3) + 5
        assert v >= 2 * nv * n * 8


# ----------------------------------------------------------- donation -----

def _slab_copy_count(prog, B, st):
    txt = prog.chunk.lower(B, st).compile().as_text()
    s, nv, n = st.cyc.S.shape
    shape = f"f64[{s},{nv},{n}]"
    alias = "input_output_alias" in txt.splitlines()[0]
    copies = sum(line.count(" copy(") for line in txt.splitlines()
                 if shape in line)
    return copies, alias


@pytest.mark.parametrize("fused", [False, True])
def test_slab_program_donation(fused):
    """The slab program's chunk donates its state: the jit boundary
    aliases the (s, NV, N) slab (input_output_alias in the compiled
    module), the while loop carries it with NO per-iteration copy (the
    slab-shaped copy count is INVARIANT to chunk length — a per-
    iteration copy would scale it), and the donated buffer is actually
    consumed (the old state is unusable afterwards)."""
    op = Stencil2D5(16, 12)
    be = get_backend("local")
    kw = dict(method="plcg", l=2, sigmas=shifts_for_operator(op, 2),
              tol=1e-9, maxit=200, fused_iteration=fused)
    B = jnp.asarray(RNG.standard_normal((op.n, 4)))

    prog1 = be.make_slab_program(op, s=4, chunk_iters=1, **kw)
    prog16 = be.make_slab_program(op, s=4, chunk_iters=16, **kw)
    st = prog1.init(B)
    c1, alias1 = _slab_copy_count(prog1, B, st)
    c16, alias16 = _slab_copy_count(prog16, B, prog16.init(B))
    assert alias1 and alias16
    assert c1 == c16, (c1, c16)        # no per-iteration state copy

    # Donation is live: the consumed state must be unusable afterwards.
    st2 = prog16.chunk(B, st)
    assert st2.cyc.S.shape == st.cyc.S.shape
    with pytest.raises(RuntimeError):
        np.asarray(st.cyc.S)


def test_fused_kernel_aliases_slab_buffer():
    """input_output_aliases on the pallas call: the compiled single
    iteration (state donated) reports the slab param aliased through to
    the output in the module's alias table."""
    op = Stencil2D5(16, 12)
    ops = SolverOps.local(op)
    b = jnp.zeros((op.n,), jnp.float64)
    prog = pipelined_cg.build(ops, b, 2, sigmas=shifts_for_operator(op, 2),
                              fused_iteration=True)
    st0 = jax.eval_shape(prog.init, b)
    txt = jax.jit(lambda st: prog.iteration(st, static_phase="late"),
                  donate_argnums=(0,)).lower(st0).compile().as_text()
    header = txt.splitlines()[0]
    assert "input_output_alias" in header
    # the (NV, N) slab itself appears in the alias table (shape-matched
    # param aliased to a shape-matched output)
    assert re.search(r"f64\[14,192\]", txt)
