"""Docs consistency: every ``DESIGN.md §N`` citation in the tree must
resolve to an existing section (scripts/check_docs.py wired into the
suite), and the checker itself must catch dangling citations."""

import importlib.util
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_docs():
    path = os.path.join(REPO_ROOT, "scripts", "check_docs.py")
    spec = importlib.util.spec_from_file_location("check_docs", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_design_citations_resolve():
    cd = _load_check_docs()
    assert cd.check(REPO_ROOT, verbose=True) == 0


def test_design_md_has_cited_sections():
    cd = _load_check_docs()
    sections = cd.design_sections(REPO_ROOT)
    assert sections is not None, "DESIGN.md missing"
    # The sections the seed tree already cited must stay present.
    assert {2, 4, 5, 7, 8, 10} <= sections, sections


def test_checker_flags_dangling_citation(tmp_path):
    cd = _load_check_docs()
    (tmp_path / "DESIGN.md").write_text("## §1 — only section\n")
    src = tmp_path / "src"
    src.mkdir()
    # assembled so this literal doesn't itself trip the repo-wide check
    cite = "DESIGN" + ".md §"
    (src / "mod.py").write_text(
        f'"""Cites {cite}1 (fine) and {cite}99 (dangling)."""\n')
    assert cd.check(str(tmp_path), verbose=False) == 1


def test_checker_flags_missing_design(tmp_path):
    cd = _load_check_docs()
    src = tmp_path / "src"
    src.mkdir()
    cite = "DESIGN" + ".md §"
    (src / "mod.py").write_text(f'"""See {cite}2/§8."""\n')
    assert cd.check(str(tmp_path), verbose=False) >= 1
