# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# ONE device (system prompt, MULTI-POD DRY-RUN §0).  Multi-device tests
# spawn subprocesses that set --xla_force_host_platform_device_count.
import jax

jax.config.update("jax_enable_x64", True)
