"""Multi-controller backend test (2 jax.distributed processes x 4 forced
host devices), driven by ``scripts/multiprocess_parity.py``.

Spawning a 2-process gloo-collectives job is too heavy for every local
tier-1 run, so this is opt-in: the CI ``multiprocess`` job sets
``RUN_MULTIPROCESS=1`` (see .github/workflows/ci.yml); locally run

    RUN_MULTIPROCESS=1 PYTHONPATH=src python -m pytest tests/test_multiprocess.py
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.skipif(
    os.environ.get("RUN_MULTIPROCESS") != "1",
    reason="set RUN_MULTIPROCESS=1 to exercise the jax.distributed "
           "multi-controller backend (CI 'multiprocess' job)",
)


def test_two_process_parity():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join("scripts", "multiprocess_parity.py")],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-3000:], out.stderr[-2000:])
    assert out.stdout.count("MULTIPROC-PARITY-OK") == 2, out.stdout[-3000:]


def test_scaling_study_smoke(tmp_path):
    """One 2-process strong-scaling point end to end (DESIGN.md §17):
    the study must emit a gateable BENCH payload whose deterministic
    columns hold — bitwise ladder parity across the process boundary,
    zero dot-block all-reduces, a populated hop schedule — plus the
    per-process timeline artifacts.  (The full 1->4 sweep with timing
    budgets runs in the CI ``scaling-study`` job, not here.)"""
    import json

    # The study runs from tmp_path (its TIMELINE_scaling_proc*.json land
    # in the cwd), so the script and src tree need absolute paths.
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    out_json = tmp_path / "BENCH_scaling_smoke.json"
    out = subprocess.run(
        [sys.executable,
         os.path.join(repo, "scripts", "multiprocess_parity.py"),
         "--study", "--procs", "2", "--repeats", "2",
         "--budget-lo", "5", "--budget-hi", "15",
         "--out", str(out_json)],
        capture_output=True, text=True, env=env, cwd=str(tmp_path),
        timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-3000:], out.stderr[-2000:])
    assert out.stdout.count("SCALING-OK") == 2, out.stdout[-3000:]
    payload = json.loads(out_json.read_text())
    assert payload["scaling_parity_bitwise"] == 1
    assert payload["scaling_staged_allreduces_max"] == 0
    assert payload["scaling_hops_per_window_min"] >= 1
    assert payload["staged_iter_time_p2_s"] > 0
    assert payload["monolithic_iter_time_p2_s"] > 0
    [row] = payload["rows"]
    assert row["wire"] == "gloo" and row["cross_process_edges"] == 2
    for k in range(2):
        assert (tmp_path / f"TIMELINE_scaling_proc{k}.json").exists()


def test_recovery_drill(tmp_path):
    """The kill-a-rank drill end to end (DESIGN.md §19): rank 1 of a
    2-process fabric is SIGKILLed mid-solve by the chaos plan,
    ``run_resilient`` tears down the survivor and respawns a clean
    fabric that resumes from the last checkpoint; the resumed residual
    history must be BITWISE against the local virtual-shards oracle
    that never died, with at most one checkpoint interval recomputed."""
    import json

    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join("scripts", "multiprocess_parity.py"),
         "--recovery", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-3000:], out.stderr[-2000:])
    assert "RECOVERY OK" in out.stdout, out.stdout[-3000:]
    [line] = [ln for ln in out.stdout.splitlines()
              if ln.startswith("RECOVERY-RESULT ")]
    row = json.loads(line[len("RECOVERY-RESULT "):])
    assert row["parity_bitwise"] == 1 and row["converged"] == 1
    assert row["attempts"] == 2
    assert 0 < row["recomputed_iters"] <= row["checkpoint_every"]
