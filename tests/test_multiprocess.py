"""Multi-controller backend test (2 jax.distributed processes x 4 forced
host devices), driven by ``scripts/multiprocess_parity.py``.

Spawning a 2-process gloo-collectives job is too heavy for every local
tier-1 run, so this is opt-in: the CI ``multiprocess`` job sets
``RUN_MULTIPROCESS=1`` (see .github/workflows/ci.yml); locally run

    RUN_MULTIPROCESS=1 PYTHONPATH=src python -m pytest tests/test_multiprocess.py
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.skipif(
    os.environ.get("RUN_MULTIPROCESS") != "1",
    reason="set RUN_MULTIPROCESS=1 to exercise the jax.distributed "
           "multi-controller backend (CI 'multiprocess' job)",
)


def test_two_process_parity():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join("scripts", "multiprocess_parity.py")],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-3000:], out.stderr[-2000:])
    assert out.stdout.count("MULTIPROC-PARITY-OK") == 2, out.stdout[-3000:]
