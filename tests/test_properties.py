"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional test dependency (declared in
pyproject.toml's ``test`` extra); environments without it skip this
module instead of failing collection.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core import classic_cg, pipelined_cg
from repro.core.chebyshev import chebyshev_shifts
from repro.core.types import SolverOps
from repro.kernels import ops as kops, ref as kref
from repro.linalg import operators as ops_mod

SET = dict(max_examples=20, deadline=None)


@given(n=st.integers(4, 24), cond=st.floats(1.0, 1e4),
       l=st.integers(1, 3), seed=st.integers(0, 2**16))
@settings(**SET)
def test_plcg_solves_any_spd(n, cond, l, seed):
    """INVARIANT: p(l)-CG solves every SPD system to tolerance (possibly
    via restarts)."""
    op = ops_mod.random_spd(jax.random.PRNGKey(seed), n, cond=cond)
    b = jnp.asarray(np.random.default_rng(seed).standard_normal(n))
    lmin, lmax = op.eig_bounds()
    sig = chebyshev_shifts(lmin, lmax, l)
    res = pipelined_cg.solve(SolverOps.local(op), b, l=l, tol=1e-8,
                             maxit=20 * n, sigmas=sig, max_restarts=30)
    x_direct = np.linalg.solve(op.to_dense(), np.asarray(b))
    denom = np.linalg.norm(x_direct) + 1e-30
    assert np.linalg.norm(np.asarray(res.x) - x_direct) / denom < 1e-4


@given(nx=st.integers(4, 20), ny=st.integers(4, 20),
       seed=st.integers(0, 2**16))
@settings(**SET)
def test_stencil_spd_invariants(nx, ny, seed):
    """INVARIANT: the 2D stencil operator is symmetric positive definite:
    (x, Ay) == (Ax, y) and (x, Ax) > 0 for x != 0."""
    op = ops_mod.Stencil2D5(nx, ny)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(op.n))
    y = jnp.asarray(rng.standard_normal(op.n))
    lhs = float(jnp.dot(x, op.apply(y)))
    rhs = float(jnp.dot(op.apply(x), y))
    assert abs(lhs - rhs) < 1e-8 * (abs(lhs) + 1)
    assert float(jnp.dot(x, op.apply(x))) > 0


@given(nx=st.integers(2, 8), ny=st.integers(2, 8), nz=st.integers(2, 8),
       eps=st.floats(0.01, 1.0), seed=st.integers(0, 2**16))
@settings(**SET)
def test_stencil3d_kernel_matches_ref(nx, ny, nz, eps, seed):
    g = jnp.asarray(
        np.random.default_rng(seed).standard_normal((nx, ny, nz)),
        jnp.float32)
    np.testing.assert_allclose(
        kops.stencil3d7_apply(g, eps), kref.stencil3d7_ref(g, eps),
        rtol=1e-4, atol=1e-4)


@given(k=st.integers(1, 9), n=st.integers(1, 4000), seed=st.integers(0, 2**16))
@settings(**SET)
def test_fused_dots_matches_ref(k, n, seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    np.testing.assert_allclose(kops.fused_dots(m, v), kref.fused_dots_ref(m, v),
                               rtol=1e-3, atol=1e-3)


@given(l=st.integers(1, 4))
@settings(max_examples=4, deadline=None)
def test_chebyshev_minimax_bound(l):
    """INVARIANT: Chebyshev-shifted P_l stays within the minimax bound
    2^(1-l) * ((lmax-lmin)/4)^l... practical check: |P_l| on [lmin, lmax]
    with Chebyshev shifts is <= |P_l| with zero shifts (for A^l)."""
    lmin, lmax = 0.1, 2.0
    ts = np.linspace(lmin, lmax, 201)
    sig = np.asarray(chebyshev_shifts(lmin, lmax, l))
    p_cheb = np.ones_like(ts)
    p_zero = np.ones_like(ts)
    for i in range(l):
        p_cheb *= (ts - sig[i])
        p_zero *= ts
    assert np.abs(p_cheb).max() <= np.abs(p_zero).max() + 1e-12


@given(b=st.integers(1, 3), t=st.integers(1, 33), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_flash_attention_matches_naive(b, t, seed):
    """INVARIANT: blocked causal flash == naive masked softmax attention."""
    from repro.models.attention import flash_attention
    rng = np.random.default_rng(seed)
    h, hkv, d = 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_block=8, kv_block=8)

    # naive reference
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(d)
    mask = np.tril(np.ones((t, t), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v).reshape(b, t, h, d)
    np.testing.assert_allclose(out, o, rtol=2e-3, atol=2e-3)


@given(seed=st.integers(0, 2**16), steps=st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_data_pipeline_deterministic(seed, steps):
    """INVARIANT: batch_at(step) is a pure function — recomputable by any
    worker after restart."""
    from repro.train.data import SyntheticData
    d1 = SyntheticData(vocab=128, seq_len=16, batch=4, seed=seed)
    d2 = SyntheticData(vocab=128, seq_len=16, batch=4, seed=seed)
    b1 = d1.batch_at(steps)
    b2 = d2.batch_at(steps)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
    assert (np.asarray(b1["tokens"]) < 128).all()
    assert (np.asarray(b1["tokens"]) >= 0).all()
