"""Training substrate: optimizer, pipelined gradient reduction, checkpoint
/restore with elastic resharding, delayed grad-norm clipping."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticData
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, \
    global_norm, lr_schedule
from repro.train.train_step import (init_grad_ring, make_pipelined_train_step,
                                    make_train_step, run_steps)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticData.for_config(cfg, seq_len=16, batch=4)
    return cfg, model, params, data


def test_adamw_decreases_loss(setup):
    cfg, model, params, data = setup
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    opt = adamw_init(params)
    params2, _, _, hist = run_steps(
        make_pipelined_train_step(model, opt_cfg, 0), params, opt, data,
        n_steps=30)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_pipelined_l0_equals_sync(setup):
    """l=0 pipelined step is bit-identical to the synchronous step."""
    cfg, model, params, data = setup
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = data.batch_at(0)
    p1, o1, m1 = jax.jit(make_train_step(model, opt_cfg))(
        params, adamw_init(params), batch)
    ring = init_grad_ring(params, 0)
    p2, o2, ring, m2 = jax.jit(make_pipelined_train_step(model, opt_cfg, 0))(
        params, adamw_init(params), ring, jnp.int32(0), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_delay_semantics(setup):
    """With depth l, the gradients applied at step i are those computed at
    step i-l; the first l updates are zero (warmup)."""
    cfg, model, params, data = setup
    l = 2
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    step_fn = jax.jit(make_pipelined_train_step(model, opt_cfg, l))
    ring = init_grad_ring(params, l)
    opt = adamw_init(params)
    p = params
    leaves0 = jax.tree.leaves(params)
    for i in range(l):
        p, opt, ring, m = step_fn(p, opt, ring, jnp.int32(i), data.batch_at(i))
    # after l steps only zero-grads were applied -> params unchanged
    for a, b in zip(leaves0, jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-12)
    p, opt, ring, m = step_fn(p, opt, ring, jnp.int32(l), data.batch_at(l))
    diff = max(float(jnp.abs(a - b).max())
               for a, b in zip(leaves0, jax.tree.leaves(p)))
    assert diff > 0          # the step-0 gradients finally landed


def test_pipelined_converges_like_sync(setup):
    """Bounded staleness: l=2 training still reduces the loss (the
    accuracy-vs-overlap trade the paper makes, DESIGN.md §4)."""
    cfg, model, params, data = setup
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    _, _, _, hist = run_steps(
        make_pipelined_train_step(model, opt_cfg, 2), params,
        adamw_init(params), data, n_steps=40, l=2)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.05


def test_delayed_norm_clipping(setup):
    cfg, model, params, data = setup
    opt_cfg = AdamWConfig(lr=1e-3, delayed_norm=True, clip_norm=1e-6)
    batch = data.batch_at(0)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt = adamw_init(params)
    # first step: prev_norm = 1 -> clip scale = min(1, 1e-6/1) tiny
    _, opt, m = step(params, opt, batch)
    assert float(m["clip_scale"]) < 1e-5
    # prev_norm now the real grad norm
    assert abs(float(opt["prev_norm"]) - float(m["grad_norm"])) < 1e-6


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 1.0) < 1e-6
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_checkpoint_roundtrip_and_gc(tmp_path, setup):
    cfg, model, params, data = setup
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    opt = adamw_init(params)
    state = {"params": params, "opt": opt}
    for step in (1, 2, 3):
        mgr.save(step, state, meta={"mesh": [1], "seed": 0}, block=True)
    assert mgr.steps() == [2, 3]          # keep_n GC pruned step 1
    template = jax.eval_shape(lambda: state)
    restored, meta = mgr.restore(template)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_elastic_reshard(tmp_path):
    """A checkpoint saved under one device layout restores under another:
    the npz is layout-free; shardings are applied at load (subprocess
    proves an 8-device reshard of a 1-device save)."""
    import subprocess
    import sys
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager

state = {{"w": np.arange(64, dtype=np.float32).reshape(8, 8)}}
mgr = CheckpointManager({str(tmp_path)!r}, keep_n=1)
mgr.save(7, state, block=True)
template = jax.eval_shape(lambda: {{"w": jnp.zeros((8, 8), jnp.float32)}})
restored, meta = mgr.restore(template)
mesh = jax.make_mesh((8,), ("x",))
sharded = jax.device_put(restored["w"], NamedSharding(mesh, P("x", None)))
assert len(sharded.addressable_shards) == 8
np.testing.assert_array_equal(np.asarray(sharded), state["w"])
print("RESHARD-OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.getcwd())
    assert "RESHARD-OK" in out.stdout, out.stderr[-2000:]


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
