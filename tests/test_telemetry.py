"""On-device iteration telemetry (DESIGN.md §16): the ``(cap, K)`` ring
appended to the solver state must be free — zero extra collectives, zero
host transfers inside the loop, bitwise-invisible to the arithmetic —
and deterministic: the same seeded solve writes the same ring bitwise,
on every substrate, fused or not, single or batched.

Local-backend assertions run in-process; the shard_map half follows the
tests/test_distributed.py subprocess idiom (8 fake host devices must be
configured before jax imports)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core.chebyshev import shifts_for_operator   # noqa: E402
from repro.core.types import TelemetrySlab             # noqa: E402
from repro.kernels.fused_iter import tel_layout        # noqa: E402
from repro.linalg import Stencil2D5                    # noqa: E402
from repro.parallel import get_backend                 # noqa: E402

ENV = dict(os.environ, PYTHONPATH="src")
ENV.pop("XLA_FLAGS", None)


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=os.getcwd(), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.parallel import get_backend
from repro.linalg import Stencil2D5
from repro.core.chebyshev import shifts_for_operator
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(3).standard_normal(op.n))
sig = shifts_for_operator(op, 2)
"""


def _problem():
    op = Stencil2D5(32, 24)
    b = jnp.asarray(np.random.default_rng(3).standard_normal(op.n))
    return op, b, shifts_for_operator(op, 2)


# ---------------------------------------------------------------- layout --

def test_telemetry_slab_layout():
    """TelemetrySlab mirrors tel_layout: K = 2l+10, unpack exposes every
    column plus the (2l+1)-wide dot block."""
    for l in (1, 2, 3):
        ts = TelemetrySlab(cap=32, l=l)
        tl = tel_layout(l)
        assert ts.k == tl["size"] == 2 * l + 10
        assert ts.shape == (32, ts.k)
        assert ts.bytes_per_iter() == ts.k * 8
        cols = ts.unpack(np.zeros(ts.shape))
        assert cols["dots"].shape == (32, 2 * l + 1)
        for name in ("iter", "upd", "rnorm", "age", "breakdown",
                     "restart", "replacement", "gap", "action"):
            assert cols[name].shape == (32,)


def test_ring_contents_match_history():
    """The recorded rnorm column IS the solver's residual history (same
    accepted iterations, same values bitwise), and the ring wraps at
    cap without disturbing either."""
    op, b, sig = _problem()
    be = get_backend("local")
    res = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-10,
                   maxit=400, telemetry_cap=512)
    assert res.telemetry is not None
    cols = TelemetrySlab(cap=512, l=2).unpack(np.asarray(res.telemetry))
    it = np.asarray(cols["iter"])
    written = it >= 0
    assert written.sum() >= int(res.iters)          # one row per loop trip
    hist = np.asarray(res.res_history)
    for r in np.nonzero(written)[0]:
        k = int(it[r])
        if cols["upd"][r] >= 0 and cols["rnorm"][r] >= 0:
            assert hist[int(cols["upd"][r])] == cols["rnorm"][r], k
    # small cap: ring wraps, arithmetic untouched
    res_w = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-10,
                     maxit=400, telemetry_cap=8)
    assert res_w.telemetry.shape == (8, 14)
    assert np.array_equal(np.asarray(res_w.res_history), hist)
    assert int(res_w.iters) == int(res.iters)


# ----------------------------------------------------------- determinism --

def test_instrumented_solve_is_bitwise_invisible():
    """Instrumentation must not perturb the arithmetic: residual history
    and solution are BITWISE identical with and without the ring, fused
    and unfused."""
    op, b, sig = _problem()
    be = get_backend("local")
    for fused in (False, True):
        kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=400,
                  fused_iteration=fused)
        plain = be.solve(op, b, **kw)
        inst = be.solve(op, b, telemetry_cap=256, **kw)
        assert plain.telemetry is None
        assert inst.telemetry is not None
        assert np.array_equal(np.asarray(plain.res_history),
                              np.asarray(inst.res_history)), fused
        assert np.array_equal(np.asarray(plain.x), np.asarray(inst.x)), fused


def test_telemetry_deterministic_and_fused_parity():
    """Same seeded solve twice -> bitwise-identical rings; the fused
    superkernel writes the SAME ring as the unfused loop."""
    op, b, sig = _problem()
    be = get_backend("local")
    kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=400,
              telemetry_cap=256)
    t1 = np.asarray(be.solve(op, b, **kw).telemetry)
    t2 = np.asarray(be.solve(op, b, **kw).telemetry)
    assert np.array_equal(t1, t2)
    tf = np.asarray(be.solve(op, b, fused_iteration=True, **kw).telemetry)
    assert np.array_equal(t1, tf)


def test_batched_telemetry_deterministic():
    """Batched s=8 slab: one (s, cap, K) ring, run-twice bitwise, and
    column j's ring equals the single-RHS ring of column j's problem."""
    op, b, sig = _problem()
    be = get_backend("local")
    s = 8
    B = jnp.asarray(
        np.random.default_rng(5).standard_normal((op.n, s)))
    kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=400,
              telemetry_cap=128)
    r1 = be.solve_batched(op, B, **kw)
    r2 = be.solve_batched(op, B, **kw)
    assert r1.telemetry.shape == (s, 128, 14)
    assert np.array_equal(np.asarray(r1.telemetry),
                          np.asarray(r2.telemetry))
    plain = be.solve_batched(op, B, method="plcg", l=2, sigmas=sig,
                             tol=1e-10, maxit=400)
    assert plain.telemetry is None
    assert np.array_equal(np.asarray(plain.res_history),
                          np.asarray(r1.res_history))


# ------------------------------------------------------------ HLO hygiene --

_TRANSFER_TOKENS = ("infeed", "outfeed", " send(", " recv(",
                    "send-done", "recv-done")


def _transfer_counts(text: str) -> dict:
    return {t: text.count(t) for t in _TRANSFER_TOKENS}


def test_no_new_host_transfers():
    """The instrumented compiled module contains exactly the same
    host-transfer instruction counts as the uninstrumented one — the
    ring lives and dies on device until the caller fetches the result."""
    op, b, sig = _problem()
    be = get_backend("local")
    texts = {}
    for cap in (0, 256):
        solver = be.make_solver(op, method="plcg", l=2, sigmas=sig,
                                tol=1e-10, maxit=400, telemetry_cap=cap)
        texts[cap] = solver.lower(b).compile().as_text()
    assert _transfer_counts(texts[0]) == _transfer_counts(texts[256])


def test_shard_map_telemetry_determinism_and_hlo():
    """shard_map half (8 fake devices, subprocess): distributed rings are
    run-twice bitwise (single and batched s=8), instrumentation leaves
    distributed histories bitwise, and the instrumented schedule still
    issues EXACTLY ONE reduction start per iteration window with no new
    host transfers."""
    out = _run(HEADER + """
from repro.utils.trace import plcg_overlap_report
be = get_backend("shard_map", n_shards=8)
kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=400)

plain = be.solve(op, b, **kw)
r1 = be.solve(op, b, telemetry_cap=256, **kw)
r2 = be.solve(op, b, telemetry_cap=256, **kw)
assert plain.telemetry is None
assert r1.telemetry.shape == (256, 14)
assert np.array_equal(np.asarray(r1.telemetry), np.asarray(r2.telemetry))
assert np.array_equal(np.asarray(plain.res_history),
                      np.asarray(r1.res_history))
assert np.array_equal(np.asarray(plain.x), np.asarray(r1.x))

B = jnp.asarray(np.random.default_rng(5).standard_normal((op.n, 8)))
b1 = be.solve_batched(op, B, telemetry_cap=128, **kw)
b2 = be.solve_batched(op, B, telemetry_cap=128, **kw)
assert b1.telemetry.shape == (8, 128, 14)
assert np.array_equal(np.asarray(b1.telemetry), np.asarray(b2.telemetry))

# instrumented schedule: still exactly one reduction start per window
bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
for l in (2, 3):
    rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2,
                              sigmas=shifts_for_operator(op, l),
                              telemetry_cap=64)
    assert rep.max_in_flight >= l, (l, str(rep))
    assert len(rep.starts_per_window) == rep.window, str(rep)
    assert all(v == 1 for v in rep.starts_per_window.values()), \\
        (l, rep.starts_per_window)
print("SHARD-TEL-OK")
""")
    assert "SHARD-TEL-OK" in out


def test_staged_reduction_telemetry_bitwise():
    """The staged ring-ladder substrate records the same determinism:
    run-twice bitwise rings under reduction='staged' on the 8-device
    mesh, and local-oracle vs mesh ladder rings bitwise (the oracle
    property extended to telemetry)."""
    out = _run(HEADER + """
kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=400,
          telemetry_cap=128)
be_m = get_backend("shard_map", n_shards=8, reduction="staged")
be_o = get_backend("local", reduction="staged", virtual_shards=8)
m1 = np.asarray(be_m.solve(op, b, **kw).telemetry)
m2 = np.asarray(be_m.solve(op, b, **kw).telemetry)
o1 = np.asarray(be_o.solve(op, b, **kw).telemetry)
assert np.array_equal(m1, m2)
assert np.array_equal(m1, o1)
print("STAGED-TEL-OK")
""")
    assert "STAGED-TEL-OK" in out
