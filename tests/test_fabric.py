"""Multi-node fabric launcher + multiprocess staged-capability tests
(DESIGN.md §17).

Two layers, both tier-1 (no RUN_MULTIPROCESS gate):

* ``repro.parallel.fabric`` — pure host-side process plumbing, tested
  with throwaway ``python -c`` children so every case runs in seconds:
  clean success, a rank dying mid-run (typed error, survivors killed —
  NOT a hang at the collective's timeout), the wall-clock watchdog, and
  the coordinator-port bind-collision retry.
* the PR 8 fallback removal — ``multiprocess`` now RUNS the staged hop
  ladder instead of downgrading it: capability flag True, no
  ``ReductionFallbackWarning``, the ``backend_reduction_fallback`` gauge
  pinned 0, and the single-process degradation bitwise against the
  ``local`` virtual-shards oracle.  The cross-process version of the
  same assertions lives in scripts/multiprocess_parity.py (CI
  ``multiprocess`` job).
"""

import sys
import time
import warnings

import numpy as np
import pytest

from repro.parallel.fabric import (
    ENV_HEARTBEAT,
    SIGTERM_EXIT_CODE,
    FabricProcessError,
    FabricResult,
    FabricTimeoutError,
    free_port,
    launch_fabric,
    pick_coordinator,
    run_resilient,
    touch_heartbeat,
)


def _argv_script(body: str):
    """child_argv factory: every rank runs ``body`` with COORD/RANK
    interpolated (no jax import — fabric children here are throwaway)."""
    def child_argv(coordinator, k):
        code = body.replace("COORD", coordinator).replace("RANK", str(k))
        return [sys.executable, "-c", code]
    return child_argv


def test_free_port_is_bindable():
    import socket

    port = free_port()
    with socket.socket() as s:
        s.bind(("127.0.0.1", port))     # still free — nothing claimed it
    host, _, p = pick_coordinator().partition(":")
    assert host == "127.0.0.1" and int(p) > 0


def test_launch_fabric_success_collects_all_ranks():
    res = launch_fabric(
        _argv_script("print('rank RANK on COORD ok')"), 3, timeout_s=60,
        poll_s=0.05)
    assert isinstance(res, FabricResult)
    assert res.attempts == 1
    assert len(res.outputs) == 3
    for k, out in enumerate(res.outputs):
        assert f"rank {k} on {res.coordinator} ok" in out


def test_kill_one_process_raises_typed_error_not_hang():
    # Rank 1 dies almost immediately; rank 0 would sleep far past any
    # reasonable test budget — exactly a rank blocked in a collective
    # whose peer died.  The watchdog must kill it and raise the typed
    # error within ~poll_s of the death, never wait out the sleep.
    body = ("import sys, time\n"
            "if RANK == 1:\n"
            "    print('rank 1 dying', flush=True); sys.exit(3)\n"
            "time.sleep(120)\n")
    t0 = time.monotonic()
    with pytest.raises(FabricProcessError) as ei:
        launch_fabric(_argv_script(body), 2, timeout_s=300, poll_s=0.05)
    elapsed = time.monotonic() - t0
    assert elapsed < 30, f"watchdog took {elapsed:.1f}s — a hang"
    msg = str(ei.value)
    assert "rank 1 of 2 exited 3" in msg
    assert "survivors killed" in msg
    assert "rank 1 dying" in msg          # per-rank output tail attached
    # §18 forensics: every rank's detail line carries its exit status
    # and heartbeat age, so wedged vs dead is readable from the error.
    assert "last heartbeat" in msg
    assert "(exit 3," in msg


def test_wedged_rank_distinguished_from_slow_one():
    # Rank 0 heartbeats once at startup then blocks "in a collective";
    # rank 1 dies after the heartbeat has gone stale.  With a tight
    # wedge threshold the error must report rank 0 as WEDGED (alive but
    # heartbeat-silent) and rank 1 with its exit status.
    body = ("import os, sys, time\n"
            "hb = os.environ.get('" + ENV_HEARTBEAT + "')\n"
            "open(hb, 'a').close(); os.utime(hb, None)\n"
            "if RANK == 1:\n"
            "    time.sleep(1.5); sys.exit(5)\n"
            "time.sleep(120)\n")
    with pytest.raises(FabricProcessError) as ei:
        launch_fabric(_argv_script(body), 2, timeout_s=300, poll_s=0.05,
                      wedge_after_s=0.5)
    msg = str(ei.value)
    assert "rank 1 of 2 exited 5" in msg
    assert "(wedged," in msg              # rank 0: alive, heartbeat stale
    assert "(exit 5," in msg


def test_touch_heartbeat_helper(tmp_path):
    # Outside a fabric: no env var, clean no-op.
    assert touch_heartbeat({}) is None
    # Inside: touches (creates) the assigned file and returns its path.
    p = str(tmp_path / "rank0.hb")
    assert touch_heartbeat({ENV_HEARTBEAT: p}) == p
    import os
    assert os.path.exists(p)


def test_timeout_raises_typed_error_and_kills_group():
    t0 = time.monotonic()
    with pytest.raises(FabricTimeoutError) as ei:
        launch_fabric(_argv_script("import time; time.sleep(120)"), 2,
                      timeout_s=1.0, poll_s=0.05)
    assert time.monotonic() - t0 < 30
    assert "exceeded 1s" in str(ei.value)
    assert "[0, 1]" in str(ei.value)      # both ranks were still running


def test_bind_collision_retries_on_fresh_port(tmp_path):
    # First attempt: rank 0 reports the coordinator bind failure and
    # dies (the parallel-CI port race).  The launcher must relaunch the
    # WHOLE group on a fresh port; second attempt succeeds.  A flag file
    # makes the failure one-shot.
    flag = tmp_path / "collided_once"
    body = (f"import pathlib, sys\n"
            f"flag = pathlib.Path({str(flag)!r})\n"
            f"if RANK == 0 and not flag.exists():\n"
            f"    flag.touch()\n"
            f"    print('RuntimeError: Address already in use')\n"
            f"    sys.exit(1)\n"
            f"print('rank RANK up on COORD')\n")
    res = launch_fabric(_argv_script(body), 2, timeout_s=60, poll_s=0.05)
    assert res.attempts == 2
    assert all("up on" in o for o in res.outputs)
    assert res.coordinator in res.outputs[0]


def test_persistent_bind_collision_exhausts_retries():
    body = ("import sys\n"
            "print('bind address in use: errno: 98'); sys.exit(1)\n")
    with pytest.raises(FabricProcessError, match="persisted through"):
        launch_fabric(_argv_script(body), 1, timeout_s=60, poll_s=0.05,
                      max_port_retries=2)


# ---------------------------------------------------------------------------
# Graceful shutdown: SIGTERM flush handler + SIGKILL escalation (§19).
# ---------------------------------------------------------------------------

def test_sigterm_handler_flushes_before_exit(tmp_path):
    # Rank 1 dies; the launcher SIGTERMs the survivor, whose installed
    # handler must run its flush callbacks (telemetry/timeline in prod —
    # a sentinel file here) before exiting with the distinct 143 status.
    sentinel = tmp_path / "flushed_rank0"
    body = (f"import sys, time\n"
            f"from repro.parallel.fabric import install_sigterm_handler\n"
            f"if RANK == 1:\n"
            f"    sys.exit(7)\n"
            f"install_sigterm_handler(\n"
            f"    lambda: open({str(sentinel)!r}, 'w').write('flushed'))\n"
            f"print('handler armed', flush=True)\n"
            f"time.sleep(120)\n")
    import os

    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    with pytest.raises(FabricProcessError, match="rank 1 of 2 exited 7"):
        launch_fabric(_argv_script(body), 2, timeout_s=60, poll_s=0.05,
                      env=dict(os.environ, PYTHONPATH=src),
                      term_grace_s=5.0)
    # The survivor was torn down via SIGTERM within the grace window, so
    # its flush ran — the sentinel proves buffered observability state
    # would have hit disk.
    assert sentinel.exists() and sentinel.read_text() == "flushed"
    assert SIGTERM_EXIT_CODE == 143


def test_sigkill_escalation_for_sigterm_ignoring_rank(tmp_path):
    # A rank that ignores SIGTERM (wedged in native code, masked signal)
    # must not hang teardown: after ``term_grace_s`` the watchdog
    # escalates to SIGKILL and the typed error still surfaces promptly.
    body = ("import signal, sys, time\n"
            "if RANK == 1:\n"
            "    sys.exit(9)\n"
            "signal.signal(signal.SIGTERM, signal.SIG_IGN)\n"
            "print('sigterm ignored', flush=True)\n"
            "time.sleep(120)\n")
    t0 = time.monotonic()
    with pytest.raises(FabricProcessError, match="rank 1 of 2 exited 9"):
        launch_fabric(_argv_script(body), 2, timeout_s=300, poll_s=0.05,
                      term_grace_s=0.5)
    elapsed = time.monotonic() - t0
    assert elapsed < 30, (f"teardown took {elapsed:.1f}s — SIGKILL "
                          "escalation did not fire")


# ---------------------------------------------------------------------------
# run_resilient: respawn-and-resume orchestration (DESIGN.md §19).
# ---------------------------------------------------------------------------

def _resilient_argv(body: str):
    """child_argv factory for run_resilient: COORD/RANK/NPROC/ATTEMPT
    interpolated into a throwaway ``python -c`` child."""
    def child_argv(coordinator, k, num_processes, attempt):
        code = (body.replace("COORD", coordinator).replace("RANK", str(k))
                .replace("NPROC", str(num_processes))
                .replace("ATTEMPT", str(attempt)))
        return [sys.executable, "-c", code]
    return child_argv


def test_run_resilient_respawns_after_one_failure():
    # Attempt 1: rank 1 dies (the drill's killed rank).  run_resilient
    # must tear the fabric down, record the typed failure, and relaunch
    # the FULL group; attempt 2 succeeds.
    body = ("import sys\n"
            "if ATTEMPT == 1 and RANK == 1:\n"
            "    sys.exit(11)\n"
            "print('rank RANK attempt ATTEMPT ok', flush=True)\n")
    rr = run_resilient(_resilient_argv(body), 2, max_failures=1,
                       timeout_s=60, poll_s=0.05)
    assert rr.attempts == 2
    assert len(rr.failures) == 1
    assert isinstance(rr.failures[0], FabricProcessError)
    assert rr.failures[0].failed_rank == 1
    assert rr.procs_per_attempt == [2, 2]       # no shrink: full respawn
    assert isinstance(rr.result, FabricResult)
    assert all("attempt 2 ok" in o for o in rr.result.outputs)


def test_run_resilient_attempt_env_arms_first_attempt_only():
    # The drill pattern: the chaos fault plan is injected via env on
    # attempt 1 ONLY, so the respawned fabric runs clean.
    body = ("import os, sys\n"
            "if os.environ.get('FAULT_ARMED') and RANK == 0:\n"
            "    sys.exit(13)\n"
            "print('rank RANK clean', flush=True)\n")
    seen = []

    def attempt_env(attempt):
        seen.append(attempt)
        return {"FAULT_ARMED": "1"} if attempt == 1 else {}

    rr = run_resilient(_resilient_argv(body), 2, max_failures=1,
                       attempt_env=attempt_env, timeout_s=60, poll_s=0.05)
    assert seen == [1, 2]
    assert rr.attempts == 2 and len(rr.failures) == 1
    assert rr.failures[0].failed_rank == 0
    assert all("clean" in o for o in rr.result.outputs)


def test_run_resilient_shrink_drops_to_min_processes():
    # Degraded-capacity mode: every attempt with >1 rank fails, so the
    # fabric shrinks one rank per failure until it reaches
    # ``min_processes`` and succeeds there.
    body = ("import sys\n"
            "if NPROC > 1:\n"
            "    sys.exit(17)\n"
            "print('rank RANK solo ok', flush=True)\n")
    rr = run_resilient(_resilient_argv(body), 3, max_failures=2,
                       shrink=True, min_processes=1, timeout_s=60,
                       poll_s=0.05)
    assert rr.procs_per_attempt == [3, 2, 1]
    assert rr.attempts == 3 and len(rr.failures) == 2
    assert len(rr.result.outputs) == 1
    assert "solo ok" in rr.result.outputs[0]


def test_run_resilient_exhausted_budget_reraises():
    body = "import sys; sys.exit(19)\n"
    t0 = time.monotonic()
    with pytest.raises(FabricProcessError, match="exited 19"):
        run_resilient(_resilient_argv(body), 2, max_failures=1,
                      timeout_s=60, poll_s=0.05)
    assert time.monotonic() - t0 < 60


# ---------------------------------------------------------------------------
# Fallback removal: multiprocess RUNS the staged ladder (DESIGN.md §17).
# ---------------------------------------------------------------------------

def test_multiprocess_supports_staged_reduction_flag():
    from repro.parallel.backends.multiprocess import MultiprocessBackend

    # THE PR 8 regression guard: the PR 5–7 capability downgrade
    # (supports_staged_reduction = False + warning + monolithic fallback)
    # is gone for good.
    assert MultiprocessBackend.supports_staged_reduction is True


def test_multiprocess_staged_runs_without_fallback():
    import jax.numpy as jnp

    from repro.obs.metrics import default_registry
    from repro.parallel import get_backend
    from repro.parallel.reduction import ReductionFallbackWarning

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        be = get_backend("multiprocess", reduction="staged",
                         reduction_stages=1)
    assert not any(isinstance(w.message, ReductionFallbackWarning)
                   for w in caught), [str(w.message) for w in caught]
    assert be.reduction_mode == "staged"
    assert be.reduction_fallback is None
    assert be.reduction_cfg is not None
    gauge = default_registry().get("backend_reduction_fallback")
    assert gauge is not None
    assert gauge.value(labels={"backend": "multiprocess"}) == 0.0
    # Single-process degradation: no second controller in tier-1, so the
    # wire introspection reports the degenerate case honestly.
    assert be.n_processes == 1
    assert be.hop_wire() == "intra-process"
    assert be.cross_process_edges() == 0
    assert "staged ring dot block" in be.describe()

    # ... and the ladder actually runs: bitwise vs the local
    # virtual-shards oracle at the same ring size and stage count.
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5

    op = Stencil2D5(16, 12)
    b = jnp.asarray(np.random.default_rng(5).standard_normal(op.n))
    sig = shifts_for_operator(op, 2)
    kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=400)
    res = be.solve(op, b, **kw)
    oracle = get_backend("local", reduction="staged",
                         virtual_shards=be.n_shards, reduction_stages=1)
    res_o = oracle.solve(op, b, **kw)
    h, ho = np.asarray(res.res_history), np.asarray(res_o.res_history)
    assert np.array_equal(h, ho)
    assert bool(res.converged)
