"""Unit tests for the overlap tracer's schedule analysis on synthetic
HLO text: the in-flight metric must be falsifiable — a serialized
schedule reports 1, a staggered one reports l — independent of any real
compilation (those live in tests/test_distributed.py)."""

from repro.utils.trace import analyze_overlap


def _instr(name, opcode, op_name):
    return (f'  %{name} = f32[3]{{0}} {opcode}(%p0), '
            f'metadata={{op_name="jit(h)/{op_name}/psum"}}')


def _module(body_lines):
    return "\n".join(
        ["HloModule synthetic", "", "ENTRY %main (p0: f32[3]) -> f32[3] {",
         "  %p0 = f32[3]{0} parameter(0)"] + body_lines + ["}"])


def _start(k, i):
    return _instr(f"ar.{i}", "all-reduce", f"plwin{k}/glred_start")


def _wait(k, i):
    return _instr(f"w.{i}", "fusion", f"plwin{k}/glred_wait")


def test_serialized_schedule_reports_one():
    """start/wait strictly alternating (no overlap): each consumption
    point sees exactly one outstanding chain, whatever l claims."""
    l = 3
    lines, i = [], 0
    # chain k issued at window k, consumed (window k+l) BEFORE chain k+1
    # is issued — a fully collapsed pipeline.
    for k in range(5):
        lines.append(_start(k, i)); i += 1
        lines.append(_wait(k + l, i)); i += 1
    rep = analyze_overlap(_module(lines), l=l, window=5)
    assert rep.max_in_flight == 1, str(rep)


def test_staggered_schedule_reports_l():
    """l starts before the first consumption -> peak l."""
    l = 3
    window = l + 2
    lines, i = [], 0
    for k in range(window):                       # all issues first
        lines.append(_start(k, i)); i += 1
    for k in range(l, window):                    # then the waits
        lines.append(_wait(k, i)); i += 1
    rep = analyze_overlap(_module(lines), l=l, window=window)
    assert rep.max_in_flight == window, str(rep)  # all issued chains seen

    # interleaved steady state: wait(k) then start(k) per window
    lines, i = [], 0
    for k in range(l):
        lines.append(_start(k, i)); i += 1
    for k in range(l, window):
        lines.append(_wait(k, i)); i += 1         # consume chain k-l
        lines.append(_start(k, i)); i += 1
    rep = analyze_overlap(_module(lines), l=l, window=window)
    assert rep.max_in_flight == l, str(rep)


def test_no_waits_reports_zero():
    """A window too short to contain any consumption (window <= l)
    yields no measurement points, not a fabricated peak."""
    lines = [_start(k, k) for k in range(2)]
    rep = analyze_overlap(_module(lines), l=3, window=2)
    assert rep.max_in_flight == 0, str(rep)
