"""Hypothesis property tests for the batcher/queue layer (DESIGN.md §15).

The request queue is the admission boundary of the serving layer; its
invariants — FIFO per slab key, globally monotone request ids, ``take``
never over-popping, insertion-order key fairness — are what make the
multi-slab scheduler deterministic, so they get property coverage here
rather than example coverage in test_serve.py.  The zero-padded
partial-slab property (a padding column retires at iteration 0, exactly)
is checked through a real slab program at the bottom.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.core.chebyshev import shifts_for_operator
from repro.linalg import operators as ops_mod
from repro.parallel import get_backend
from repro.serve import AdmissionPolicy, RequestQueue, SolveRequest

SET = dict(max_examples=50, deadline=None)

# A submission script: sequence of (key_index, tol_index) pairs over a
# small alphabet of op keys and tolerances — enough to exercise multiple
# slab keys with interleaved traffic.
SUBMITS = st.lists(st.tuples(st.integers(0, 3), st.integers(0, 2)),
                   min_size=0, max_size=40)
KEYS = ["opA", "opB", "opC", "opD"]
TOLS = [1e-6, 1e-8, 1e-10]


def _fill(script):
    q = RequestQueue()
    reqs = []
    for i, (ki, ti) in enumerate(script):
        reqs.append(q.submit(KEYS[ki], np.asarray([float(i)]), TOLS[ti],
                             now=float(i)))
    return q, reqs


@given(script=SUBMITS)
@settings(**SET)
def test_monotone_req_ids_and_fifo_per_key(script):
    """INVARIANT: req_ids are globally monotone in submission order, and
    draining any slab key returns its requests in FIFO order."""
    q, reqs = _fill(script)
    assert [r.req_id for r in reqs] == list(range(len(script)))
    for key in set(r.slab_key for r in reqs):
        expect = [r.req_id for r in reqs if r.slab_key == key]
        got = [r.req_id for r in q.take(key, len(script) + 1)]
        assert got == expect


@given(script=SUBMITS, k=st.integers(0, 10))
@settings(**SET)
def test_take_never_over_pops(script, k):
    """INVARIANT: take(key, k) returns min(k, pending) requests, removes
    exactly those, and total pending is conserved."""
    q, reqs = _fill(script)
    total = len(q)
    assert total == len(script)
    for key in {r.slab_key for r in reqs}:
        before = q.pending(key)
        got = q.take(key, k)
        assert len(got) == min(k, before)
        assert q.pending(key) == before - len(got)
        total -= len(got)
        assert len(q) == total


@given(script=SUBMITS)
@settings(**SET)
def test_insertion_order_key_fairness(script):
    """INVARIANT: keys() iterates slab keys in FIRST-submission order —
    a hot new operator can never starve the oldest queued traffic of its
    place in the packing scan."""
    q, reqs = _fill(script)
    first_seen = []
    for r in reqs:
        if r.slab_key not in first_seen:
            first_seen.append(r.slab_key)
    assert q.keys() == first_seen
    # ... and the order is stable under a partial drain of a middle key.
    if len(first_seen) >= 2:
        mid = first_seen[len(first_seen) // 2]
        q.take(mid, 1)
        survivors = [key for key in first_seen if q.pending(key)]
        assert q.keys() == survivors


@given(deadline=st.one_of(st.none(), st.floats(0.01, 10.0)),
       waited=st.floats(0.0, 20.0))
@settings(**SET)
def test_deadline_expiry(deadline, waited):
    """INVARIANT: expired() is exactly 'waited longer than deadline_s';
    requests without a deadline never expire."""
    req = SolveRequest(req_id=0, op_key="k", b=np.zeros(1), tol=1e-8,
                      deadline_s=deadline)
    req.submitted_at = 100.0
    assert req.expired(100.0 + waited) == \
        (deadline is not None and waited > deadline)


@given(pending=st.integers(0, 50),
       max_pending=st.one_of(st.none(), st.integers(1, 40)),
       deadline=st.one_of(st.none(), st.floats(-1.0, 5.0)))
@settings(**SET)
def test_admission_policy_verdicts(pending, max_pending, deadline):
    """INVARIANT: admission rejects exactly (queue at/over ceiling) or
    (deadline at/below the feasibility floor), queue-depth first."""
    pol = AdmissionPolicy(max_pending=max_pending, min_deadline_s=0.0)
    verdict = pol.check(pending, deadline)
    if max_pending is not None and pending >= max_pending:
        assert verdict == "queue_full"
    elif deadline is not None and deadline <= 0.0:
        assert verdict == "deadline_infeasible"
    else:
        assert verdict is None


def test_zero_padded_partial_slab_retires_at_iter_zero():
    """A partial slab's padding columns (zero RHS) retire at iteration 0
    EXACTLY (norm0 == 0), never surface as results, and contribute zero
    occupied-slot-iterations to the utilization accounting."""
    op = ops_mod.Stencil2D5(12, 12)
    be = get_backend("local")
    prog = be.make_slab_program(op, s=4, method="plcg", chunk_iters=20,
                                l=2, sigmas=shifts_for_operator(op, 2),
                                tol=1e-9, maxit=400)
    rng = np.random.default_rng(0)
    B = np.zeros((op.n, 4))
    B[:, 1] = rng.standard_normal(op.n)          # one real request
    Bd = jnp.asarray(B)
    st_slab = prog.init(Bd)
    stat0 = prog.status(Bd, st_slab)
    running0 = np.asarray(stat0.running)
    assert not running0[0] and not running0[2] and not running0[3], \
        "padding columns must retire immediately"
    assert np.asarray(stat0.iters)[[0, 2, 3]].tolist() == [0, 0, 0]
    for _ in range(40):
        st_slab = prog.chunk(Bd, st_slab)
        if not np.asarray(prog.status(Bd, st_slab).running).any():
            break
    res = prog.extract(Bd, st_slab)
    iters = np.asarray(res.iters)
    assert iters[1] > 0
    assert iters[[0, 2, 3]].tolist() == [0, 0, 0]
    # padding solutions are exactly zero (not approximately)
    x = np.asarray(res.x)
    for j in (0, 2, 3):
        assert not x[j].any()
