"""Serving-layer tests (DESIGN.md §11): batched-vs-sequential parity over
ALL THREE reduction backends, masked-retirement freezing, slot recycling
without recompilation, the setup cache, and the end-to-end service loop.

Everything here runs in-process on one device: ``shard_map`` uses a
1-device mesh and ``multiprocess`` its single-process degradation (no
coordinator), both of which exercise the full psum/spec staging paths.
The 8-device slab paths live in tests/test_distributed.py (subprocess).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import METHODS
from repro.core.chebyshev import shifts_for_operator
from repro.core.types import SolverOps
from repro.linalg import operators as ops_mod
from repro.parallel import get_backend
from repro.serve import (BadRequestError, ConfigError, ServeError,
                         SetupCache, SolverService, UnknownOperatorError,
                         VirtualClock, operator_fingerprint)

RNG = np.random.default_rng(7)

# All three reduction backends, in-process (DESIGN.md §3).
ALL_BACKENDS = ["local", "shard_map", "multiprocess"]


def _backend(name):
    if name == "local":
        return get_backend(name)
    if name == "shard_map":
        return get_backend(name, n_shards=1)
    return get_backend(name)        # multiprocess, single-process mode


@pytest.fixture(scope="module")
def lap2d():
    op = ops_mod.Stencil2D5(16, 16)
    B = jnp.asarray(RNG.standard_normal((op.n, 4)))
    B = B.at[:, 2].set(0.0)         # a padding column: must retire at 0
    return op, B


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("method", ["cg", "pcg", "plcg"])
def test_batched_sequential_history_parity(lap2d, backend, method):
    """Each column of the batched solve reproduces the sequential
    single-RHS residual history and iteration count on every backend —
    batching amortizes the reduction, it never changes the arithmetic."""
    op, B = lap2d
    kw = dict(tol=1e-9, maxit=800)
    if method == "plcg":
        kw.update(l=2, sigmas=shifts_for_operator(op, 2))
    res_b = _backend(backend).solve_batched(op, B, method=method, **kw)
    sops = SolverOps.local(op)
    for j in range(B.shape[1]):
        res_j = METHODS[method](sops, B[:, j], kw)
        assert int(res_b.iters[j]) == int(res_j.iters)
        np.testing.assert_allclose(
            np.asarray(res_b.res_history[j]), np.asarray(res_j.res_history),
            rtol=1e-8, atol=1e-11)
    # the zero column retired instantly (exact padding semantics)
    assert int(res_b.iters[2]) == 0 and bool(res_b.converged[2])


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_retired_column_bitwise_frozen(lap2d, backend):
    """Masked retirement: once a column's loop stops, further chunks must
    not perturb its iterate by a single bit while slab-mates keep
    iterating."""
    op, B = lap2d
    # Column 0 = an exact eigenmode of the Laplacian: its Krylov space is
    # one-dimensional, so it converges within a couple of iterations and
    # sits retired for the many chunks its random slab-mates still need.
    ii, jj = np.meshgrid(np.arange(1, op.nx + 1), np.arange(1, op.ny + 1),
                         indexing="ij")
    mode = np.sin(np.pi * ii / (op.nx + 1)) * np.sin(np.pi * jj / (op.ny + 1))
    B = B.at[:, 0].set(jnp.asarray(mode.reshape(-1)))
    be = _backend(backend)
    prog = be.make_slab_program(op, s=4, method="plcg", chunk_iters=10,
                                l=2, sigmas=shifts_for_operator(op, 2),
                                tol=1e-9, maxit=800)
    st = prog.init(B)
    seen_frozen = False
    snapshot = {}
    for _ in range(40):
        st = prog.chunk(B, st)
        stat = prog.status(B, st)
        running = np.asarray(stat.running)
        x = np.asarray(prog.extract(B, st).x)
        for j in range(4):
            if not running[j]:
                if j in snapshot:
                    assert x[j].tobytes() == snapshot[j], \
                        f"column {j} mutated after retirement"
                    seen_frozen = True
                else:
                    snapshot[j] = x[j].tobytes()
        if not running.any():
            break
    assert seen_frozen          # at least one frozen column was re-checked
    assert not np.asarray(prog.status(B, st).running).any()


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_slot_recycling_no_recompile(lap2d, backend):
    """Retire a column, inject a fresh RHS into its slot, keep solving:
    the recycled solve must match a direct solve, other columns stay
    bitwise frozen, and no slab kernel retraces."""
    op, B = lap2d
    be = _backend(backend)
    sig = shifts_for_operator(op, 2)
    prog = be.make_slab_program(op, s=4, method="plcg", chunk_iters=50,
                                l=2, sigmas=sig, tol=1e-9, maxit=800)
    st = prog.init(B)
    for _ in range(6):
        st = prog.chunk(B, st)
    assert not np.asarray(prog.status(B, st).running).any()
    res0 = prog.extract(B, st)

    b_new = jnp.asarray(RNG.standard_normal(op.n))
    B2 = B.at[:, 1].set(b_new)
    st = prog.inject(B2, st, jnp.asarray([False, True, False, False]))
    stat = np.asarray(prog.status(B2, st).iters)
    assert stat[1] == 0                       # slot 1 re-initialized
    for _ in range(6):
        st = prog.chunk(B2, st)
    res1 = prog.extract(B2, st)
    x_direct = np.linalg.solve(op.to_dense(), np.asarray(b_new))
    np.testing.assert_allclose(np.asarray(res1.x[1]), x_direct, atol=1e-6)
    for j in (0, 2, 3):                       # untouched slots frozen
        assert np.asarray(res1.x[j]).tobytes() == \
            np.asarray(res0.x[j]).tobytes()

    # Fixed shapes end-to-end: each kernel compiled exactly once (the jit
    # cache is visible on the local backend, where the program pieces ARE
    # the jit wrappers; distributed backends wrap them in closures).
    for fn in (prog.chunk, prog.inject, prog.status, prog.extract):
        cache_size = getattr(fn, "_cache_size", None)
        if cache_size is not None:
            assert cache_size() <= 1


def test_operator_fingerprint_and_setup_cache():
    op_a = ops_mod.Stencil2D5(16, 16)
    op_b = ops_mod.Stencil2D5(16, 16)     # distinct object, same content
    op_c = ops_mod.Stencil2D5(16, 8)
    assert operator_fingerprint(op_a) == operator_fingerprint(op_b)
    assert operator_fingerprint(op_a) != operator_fingerprint(op_c)
    d = jnp.asarray(RNG.standard_normal(8) ** 2 + 1.0)
    assert operator_fingerprint(ops_mod.DiagonalOp(d)) == \
        operator_fingerprint(ops_mod.DiagonalOp(d.copy()))

    cache = SetupCache()
    p1 = cache.block_jacobi(op_a, 16)
    p2 = cache.block_jacobi(op_b, 16)     # hit: same fingerprint
    assert p1 is p2
    cache.block_jacobi(op_c, 8)           # miss: different operator
    assert cache.stats() == {"hits": 1, "misses": 2, "entries": 2}
    s1 = cache.sigmas(op_a, 2)
    assert cache.sigmas(op_b, 2) is s1


@pytest.mark.parametrize("method", ["cg", "plcg"])
def test_service_end_to_end(method):
    """More requests than slots, two operators: the scheduler packs,
    retires, recycles, and every retired solution solves its system."""
    ops = {"lap": ops_mod.Stencil2D5(16, 16),
           "toy": ops_mod.DiagonalOp(
               ops_mod.laplacian_2d_spectrum(12, 12))}
    svc = SolverService(get_backend("local"), s=3, method=method, l=2,
                        chunk_iters=25, maxit=800)
    for key, op in ops.items():
        svc.register_operator(key, op)
    rng = np.random.default_rng(3)
    sent = {}
    for i in range(8):
        key = "lap" if i % 2 == 0 else "toy"
        b = rng.standard_normal(ops[key].n)
        sent[svc.submit(key, b, tol=1e-8)] = (key, b)
    results = svc.drain()
    assert set(results) == set(sent)
    for rid, (key, b) in sent.items():
        r = results[rid]
        assert r.converged, (rid, key)
        rel = np.linalg.norm(
            b - np.asarray(ops[key].apply(jnp.asarray(r.x)))
        ) / np.linalg.norm(b)
        assert rel < 1e-6, (rid, key, rel)
        assert r.latency_s > 0 and r.res_history[0] > 0
    st = svc.stats()
    assert st["retired"] == 8 and st["pending"] == 0
    assert st["slabs"] == 2
    assert st["latency_p99_s"] >= st["latency_p50_s"] > 0


def test_typed_serve_errors():
    """Malformed traffic raises the typed ServeError hierarchy — one
    distinct exception per failure mode, all catchable as ServeError and
    still catchable under the stdlib ancestor they shadow."""
    op = ops_mod.Stencil2D5(8, 8)
    svc = SolverService(get_backend("local"), s=2)
    svc.register_operator("lap", op)

    with pytest.raises(UnknownOperatorError):
        svc.submit("nope", np.ones(op.n))
    with pytest.raises(BadRequestError):
        svc.submit("lap", np.ones(op.n - 1))          # wrong shape
    with pytest.raises(BadRequestError):
        svc.submit("lap", np.ones(op.n, dtype=np.int64))
    bad = np.ones(op.n)
    bad[3] = np.nan
    with pytest.raises(BadRequestError):
        svc.submit("lap", bad)                        # non-finite RHS
    with pytest.raises(BadRequestError):
        svc.submit("lap", np.ones(op.n), tol=-1.0)
    with pytest.raises(BadRequestError):
        svc.submit("lap", np.ones(op.n), tol=float("nan"))
    with pytest.raises(BadRequestError):
        svc.submit("lap", np.ones(op.n), deadline_s=float("inf"))
    assert svc.pending == 0                           # nothing leaked in

    with pytest.raises(ConfigError):
        svc.register_operator("bad", object())        # no .n / .apply
    with pytest.raises(ConfigError):
        SolverService(get_backend("local"),
                      prec="block_jacobi").register_operator("lap", op)
    with pytest.raises(ConfigError):
        SolverService(get_backend("local"),
                      prec="weird").register_operator("lap", op)

    # hierarchy: every serve failure is a ServeError, and each subclass
    # keeps the stdlib lineage callers may already catch
    assert issubclass(UnknownOperatorError, ServeError)
    assert issubclass(UnknownOperatorError, KeyError)
    assert issubclass(BadRequestError, ServeError)
    assert issubclass(BadRequestError, ValueError)
    assert issubclass(ConfigError, ServeError)


def test_column_granular_uploads():
    """Host->device transfer regression (DESIGN.md §15): the full (n, s)
    slab uploads exactly once; afterwards only the columns an inject
    changed cross the host boundary, and idle ticks transfer nothing."""
    op = ops_mod.Stencil2D5(8, 8)
    svc = SolverService(get_backend("local"), s=4, method="plcg", l=2,
                        chunk_iters=60, maxit=400, clock=VirtualClock())
    svc.register_operator("lap", op)
    rng = np.random.default_rng(0)
    for _ in range(4):
        svc.submit("lap", rng.standard_normal(op.n))
    svc.drain()
    st = svc.stats()
    assert st["full_uploads"] == 1
    assert st["uploaded_cols"] == 4                   # the one full upload

    svc.step()                                        # idle ticks: no work,
    svc.step()                                        # no transfer
    st = svc.stats()
    assert (st["full_uploads"], st["uploaded_cols"]) == (1, 4)

    for _ in range(2):                                # refill 2 of 4 slots
        svc.submit("lap", rng.standard_normal(op.n))
    svc.drain()
    st = svc.stats()
    assert st["full_uploads"] == 1, "re-upload of the whole slab"
    assert st["uploaded_cols"] == 6, "only changed columns may transfer"
