"""Distributed paths (subprocess with 8 fake host devices): CG domain
decomposition vs single-device, one-fused-reduction structure in HLO,
reduction-backend parity (local vs shard_map residual histories), the
overlap tracer's in-flight chain count, and split-KV decode merge under
shard_map."""

import os
import subprocess
import sys

import pytest

ENV = dict(os.environ, PYTHONPATH="src")
ENV.pop("XLA_FLAGS", None)


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=os.getcwd(), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.parallel import distributed_solve, make_solver_mesh
from repro.linalg import Stencil2D5, Stencil3D7
from repro.core.chebyshev import shifts_for_operator
"""


def test_distributed_plcg_matches_local():
    out = _run(HEADER + """
from repro.core import pipelined_cg
from repro.core.types import SolverOps
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
mesh = make_solver_mesh(8)
sig = shifts_for_operator(op, 2)
res_d = distributed_solve(mesh, op, b, method="plcg", l=2, sigmas=sig,
                          tol=1e-10, maxit=2000)
res_l = pipelined_cg.solve(SolverOps.local(op), b, l=2, sigmas=sig,
                           tol=1e-10, maxit=2000)
assert int(res_d.iters) == int(res_l.iters)
np.testing.assert_allclose(np.asarray(res_d.x), np.asarray(res_l.x),
                           atol=1e-9)
print("DIST-MATCH-OK")
""")
    assert "DIST-MATCH-OK" in out


def test_distributed_3d_blockjacobi():
    out = _run(HEADER + """
from repro.linalg.preconditioners import BlockJacobi
op = Stencil3D7(16, 8, 8, eps_z=0.1)
b = jnp.asarray(np.random.default_rng(2).standard_normal(op.n))
bj = BlockJacobi.from_operator(op, block_size=8)
mesh = make_solver_mesh(8)
res = distributed_solve(mesh, op, b, method="plcg", prec=bj, l=1,
                        sigmas=shifts_for_operator(op, 1),
                        tol=1e-9, maxit=3000)
x_direct = np.linalg.solve(op.to_dense(), np.asarray(b))
assert np.abs(np.asarray(res.x) - x_direct).max() < 1e-5
print("DIST-3D-OK")
""")
    assert "DIST-3D-OK" in out


def test_single_fused_reduction_per_iteration():
    """The paper's key structure: ONE all-reduce site in the iteration
    body (plus init/restart), vs TWO for classic CG."""
    out = _run(HEADER + """
op = Stencil2D5(32, 24)
b = jax.ShapeDtypeStruct((op.n,), jnp.float64)
mesh = make_solver_mesh(8)
from jax.sharding import NamedSharding, PartitionSpec as P
def hlo_for(method, **kw):
    fn, arrays = distributed_solve(mesh, op, b, method=method, jit=False,
                                   maxit=50, **kw)
    bsh = NamedSharding(mesh, P("shards"))
    ash = jax.tree.map(lambda _: bsh, arrays)
    return jax.jit(fn, in_shardings=(bsh, ash)).lower(b, arrays)\
        .compile().as_text()

def count_ar(txt):
    return sum(line.count(" all-reduce(") + line.count(" all-reduce-start(")
               for line in txt.splitlines())

n_cg = count_ar(hlo_for("cg"))
n_pl = count_ar(hlo_for("plcg", l=2,
                        sigmas=shifts_for_operator(op, 2)))
# classic CG: 2 body + 1 init = 3; p(l)-CG: 1 body + 1 init + 1 restart = 3
# but the BODY difference is what matters: CG body has 2, plcg body has 1.
# The compiled while-body appears once; total sites: cg >= 3, plcg <= 3
assert n_cg >= 3, n_cg
assert n_pl <= n_cg, (n_pl, n_cg)
print("HLO-SITES-OK", n_cg, n_pl)
""")
    assert "HLO-SITES-OK" in out


@pytest.mark.parametrize("method", ["cg", "plcg"])
def test_backend_residual_history_parity(method):
    """The reduction backends are drop-in replacements: `local` and
    `shard_map` produce identical residual histories (fp32 tolerance) —
    ISSUE 1 acceptance, via the registry API."""
    kw = "l=2, sigmas=sig," if method == "plcg" else ""
    out = _run(HEADER + f"""
from repro.parallel import get_backend
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(3).standard_normal(op.n), jnp.float32)
sig = jnp.asarray(shifts_for_operator(op, 2), jnp.float32)
kw = dict(method="{method}", {kw} tol=1e-5, maxit=400)
res_l = get_backend("local").solve(op, b, **kw)
res_s = get_backend("shard_map", n_shards=8).solve(op, b, **kw)
h_l = np.asarray(res_l.res_history)
h_s = np.asarray(res_s.res_history)
assert (h_l >= 0).sum() > 5
np.testing.assert_allclose(h_s, h_l, rtol=2e-4, atol=1e-5)
assert int(res_l.iters) == int(res_s.iters)
print("BACKEND-PARITY-OK")
""")
    assert "BACKEND-PARITY-OK" in out


def test_overlap_tracer_reports_inflight_chains():
    """The overlap tracer recovers >= l in-flight reduction chains from
    the compiled schedule of a window of l+2 p(l)-CG iterations on the
    8-device mesh (the paper's Fig. 4 staggering), while classic CG's
    blocking structure yields exactly 1."""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.utils.trace import plcg_overlap_report
op = Stencil2D5(32, 24)
bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
be = get_backend("shard_map", n_shards=8)
for l in (1, 2, 3):
    rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2,
                              sigmas=shifts_for_operator(op, l))
    assert rep.max_in_flight >= l, (l, rep.max_in_flight, str(rep))
    assert rep.n_collectives >= l + 2, str(rep)
print("TRACER-OK")
""")
    assert "TRACER-OK" in out


def test_batched_slab_single_reduction_per_iteration():
    """ISSUE 2 acceptance: batched p(l)-CG with s=8 RHS on the 8-device
    mesh issues EXACTLY ONE reduction handle per iteration — the whole
    (2l+1, 8) dot-block matrix rides one all-reduce — while keeping the
    staggered in-flight depth >= l of the single-RHS pipeline."""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.utils.trace import batched_plcg_overlap_report
op = Stencil2D5(32, 24)
be = get_backend("shard_map", n_shards=8)
s = 8
for l in (2, 3):
    Bspec = jax.ShapeDtypeStruct((op.n, s), jnp.float64)
    rep = batched_plcg_overlap_report(be, op, Bspec, l=l,
                                      sigmas=shifts_for_operator(op, l))
    assert rep.max_in_flight >= l, (l, rep.max_in_flight, str(rep))
    assert len(rep.starts_per_window) == rep.window, str(rep)
    assert all(v == 1 for v in rep.starts_per_window.values()), \\
        (l, rep.starts_per_window)
    # the window payload really is the full (2l+1, s) f64 matrix
    assert rep.collective_bytes >= rep.window * (2 * l + 1) * s * 8, str(rep)

# The PRODUCTION batched loop (not just the flat trace window) keeps the
# one-reduction structure: in the compiled solve_batched module no HLO
# computation — in particular no while body — carries more than one
# all-reduce.  (The restart/replacement interrupt reduction lives in its
# own per-segment computation; a vmapped in-loop lax.cond would instead
# inline a second all-reduce into the iteration body.)
import re
from repro.parallel import distributed_solve_batched
Bspec = jax.ShapeDtypeStruct((op.n, s), jnp.float64)
fn, arrays = distributed_solve_batched(
    be.mesh, op, Bspec, method="plcg", l=2,
    sigmas=shifts_for_operator(op, 2), tol=1e-9, maxit=300, jit=False)
hlo = jax.jit(fn).lower(Bspec, arrays).compile().as_text()
counts, cur = {}, None
for line in hlo.splitlines():
    m = re.match(r"^%?([\\w.\\-]+)\\s*\\(.*\\)\\s*->.*{", line) \\
        or re.match(r"^ENTRY\\s+%?([\\w.\\-]+)", line)
    if m:
        cur = m.group(1)
    if " all-reduce(" in line or " all-reduce-start(" in line:
        counts[cur] = counts.get(cur, 0) + 1
assert counts and max(counts.values()) <= 1, counts
print("BATCHED-TRACE-OK")
""")
    assert "BATCHED-TRACE-OK" in out


def test_batched_slab_parity_on_mesh():
    """Batched solve on the 8-device mesh == batched solve on one device,
    column by column (residual histories + iteration counts)."""
    out = _run(HEADER + """
from repro.parallel import get_backend
op = Stencil2D5(32, 24)
B = jnp.asarray(np.random.default_rng(5).standard_normal((op.n, 4)))
sig = shifts_for_operator(op, 2)
kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-9, maxit=600)
res_s = get_backend("shard_map", n_shards=8).solve_batched(op, B, **kw)
res_l = get_backend("local").solve_batched(op, B, **kw)
assert np.array_equal(np.asarray(res_s.iters), np.asarray(res_l.iters))
np.testing.assert_allclose(np.asarray(res_s.res_history),
                           np.asarray(res_l.res_history),
                           rtol=1e-9, atol=1e-12)
np.testing.assert_allclose(np.asarray(res_s.x), np.asarray(res_l.x),
                           atol=1e-8)
print("BATCHED-PARITY-OK")
""")
    assert "BATCHED-PARITY-OK" in out


def test_unstructured_distributed_parity_three_backends():
    """ISSUE 3 tentpole: a general SparseOp (random FEM mesh) solved
    through the partition layer — RCM ordering, contiguous row blocks,
    ppermute halo gather — matches the single-device oracle on all three
    reduction backends and the direct dense solve.  (multiprocess in its
    single-process degradation shares shard_map's mesh: those two must
    agree bitwise; local vs sharded is compared on a tight head /
    bounded tail, since Krylov recurrences chaotically amplify
    reduction-order ULPs — a 1-ULP b perturbation alone moves the late
    history by ~0.5 relative on this operator class.)"""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.linalg import random_fem_mesh, rcm_reorder
op, _perm = rcm_reorder(random_fem_mesh(0, 400))
b = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
sig = shifts_for_operator(op, 2)
xd = np.linalg.solve(op.to_dense(), np.asarray(b))
for method in ("cg", "pcg", "plcg"):
    kw = dict(method=method, tol=1e-9, maxit=900)
    if method == "plcg":
        kw.update(l=2, sigmas=sig)
    res = {name: get_backend(name, **(dict(n_shards=8)
                                      if name != "local" else {}))
           .solve(op, b, **kw)
           for name in ("local", "shard_map", "multiprocess")}
    for name, r in res.items():
        assert bool(r.converged), (method, name)
        assert np.abs(np.asarray(r.x) - xd).max() < 1e-6, (method, name)
    h_s = np.asarray(res["shard_map"].res_history)
    h_m = np.asarray(res["multiprocess"].res_history)
    np.testing.assert_array_equal(h_s, h_m)        # same mesh -> bitwise
    h_l = np.asarray(res["local"].res_history)
    n0 = float(res["local"].norm0)
    m = (h_l >= 0) & (h_s >= 0)
    diff = np.abs(h_s[m] - h_l[m]) / n0
    # Tight head (pre-amplification; a wrong halo/remap errs at O(1)
    # here), bounded tail (Krylov chaos, see docstring).  The head bound
    # leaves room for XLA CPU thread-level reduction-order jitter.
    assert diff[:10].max() < 1e-8, (method, diff[:10].max())
    assert diff.max() < 5e-2, (method, diff.max())
    assert abs(int(res["local"].iters) - int(res["shard_map"].iters)) <= 5
print("UNSTRUCTURED-PARITY-OK")
""")
    assert "UNSTRUCTURED-PARITY-OK" in out


def test_unstructured_overlap_and_halo_staggering():
    """ISSUE 3 acceptance: unstructured p(l)-CG keeps EXACTLY ONE
    allreduce per iteration with >= l reductions in flight, and the halo
    ppermutes are scheduled INSIDE the in-flight reduction windows —
    all asserted on compiled HLO via utils/trace.py."""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.utils.trace import plcg_overlap_report, batched_plcg_overlap_report
from repro.linalg import random_fem_mesh, rcm_reorder
op, _perm = rcm_reorder(random_fem_mesh(0, 400))
be = get_backend("shard_map", n_shards=8)
bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
for l in (2, 3):
    rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2,
                              sigmas=shifts_for_operator(op, l))
    assert rep.max_in_flight >= l, (l, str(rep))
    # exactly one reduction handle per iteration window
    assert len(rep.starts_per_window) == rep.window, str(rep)
    assert all(v == 1 for v in rep.starts_per_window.values()), \\
        (l, rep.starts_per_window)
    # halo ppermutes present and riding inside reduction windows
    assert rep.n_halo_permutes >= 2 * rep.window, str(rep)
    assert rep.halos_in_flight >= l, (l, str(rep))
# batched slab keeps the same structure (one handle, staggered halos)
Bspec = jax.ShapeDtypeStruct((op.n, 8), jnp.float64)
rep = batched_plcg_overlap_report(be, op, Bspec, l=2,
                                  sigmas=shifts_for_operator(op, 2))
assert rep.max_in_flight >= 2, str(rep)
assert all(v == 1 for v in rep.starts_per_window.values()), \\
    rep.starts_per_window
assert rep.halos_in_flight >= 2, str(rep)
print("UNSTRUCTURED-TRACE-OK")
""")
    assert "UNSTRUCTURED-TRACE-OK" in out


def test_fused_iteration_on_mesh():
    """ISSUE 4: the fused superkernel path on the 8-device mesh —
    bitwise-identical residual history to the unfused distributed path
    (stencil operator), and the overlap tracer still reports EXACTLY ONE
    reduction handle per iteration with >= l chains in flight: fusing
    the local phase must not change the communication structure."""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.utils.trace import plcg_overlap_report, batched_plcg_overlap_report
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(4).standard_normal(op.n))
be = get_backend("shard_map", n_shards=8)
for l in (1, 2, 3):
    kw = dict(method="plcg", l=l, sigmas=shifts_for_operator(op, l),
              tol=1e-9, maxit=600)
    ru = be.solve(op, b, **kw)
    rf = be.solve(op, b, fused_iteration=True, **kw)
    np.testing.assert_array_equal(np.asarray(ru.res_history),
                                  np.asarray(rf.res_history))
    np.testing.assert_array_equal(np.asarray(ru.x), np.asarray(rf.x))

bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
for l in (2, 3):
    rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2,
                              sigmas=shifts_for_operator(op, l),
                              fused_iteration=True)
    assert rep.max_in_flight >= l, (l, rep.max_in_flight, str(rep))
    assert len(rep.starts_per_window) == rep.window, str(rep)
    assert all(v == 1 for v in rep.starts_per_window.values()), \\
        (l, rep.starts_per_window)

# batched slab, fused: still one handle per iteration, >= l in flight
Bspec = jax.ShapeDtypeStruct((op.n, 8), jnp.float64)
rep = batched_plcg_overlap_report(be, op, Bspec, l=2,
                                  sigmas=shifts_for_operator(op, 2),
                                  fused_iteration=True)
assert rep.max_in_flight >= 2, str(rep)
assert all(v == 1 for v in rep.starts_per_window.values()), \\
    rep.starts_per_window
print("FUSED-MESH-OK")
""")
    assert "FUSED-MESH-OK" in out


def test_staged_reduction_hlo_structure():
    """ISSUE 5 tentpole acceptance: with ``reduction="staged"`` the dot
    block compiles to REDUCE_TAG'd collective-permute hops and the
    module carries ZERO all-reduces; the tracer still sees >= l chains
    in flight, >= l ladder hops in every traced window, EXACTLY one
    logical reduction (hop-0 permute) per iteration, and the hop/halo
    staggering — ladder hops scheduled inside open reduction windows."""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.utils.trace import plcg_overlap_report, batched_plcg_overlap_report
op = Stencil2D5(32, 24)
bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
for stages in (1, 2):
    be = get_backend("shard_map", n_shards=8, reduction="staged",
                     reduction_stages=stages)
    for l in (2, 3):
        rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2,
                                  sigmas=shifts_for_operator(op, l))
        # no all-reduce anywhere in the staged dot-block schedule
        assert rep.n_collectives == 0, (stages, l, rep.n_collectives)
        assert rep.max_in_flight >= l, (stages, l, str(rep))
        # ladder hops present in every window, >= l per window
        assert len(rep.reduce_hops_per_window) == rep.window, str(rep)
        assert min(rep.reduce_hops_per_window.values()) >= l, \\
            (stages, l, rep.reduce_hops_per_window)
        # exactly ONE logical reduction handle enters the wire per
        # iteration, whatever the stage grouping
        assert all(v == 1 for v in rep.staged_starts_per_window.values()), \\
            (stages, l, rep.staged_starts_per_window)
        assert len(rep.staged_starts_per_window) == rep.window
        # hop/halo staggering: ladder hops AND halo permutes ride inside
        # the open reduction windows
        assert rep.hops_in_flight >= l, (stages, l, rep.hops_in_flight)
        assert rep.halos_in_flight >= l, (stages, l, str(rep))

# batched slab (s=8): same structure — one hop-0 permute per window (the
# vmapped ladder collapses to ONE permute per hop carrying the whole
# (2l+1, s) payload), zero all-reduce, >= l in flight.
be = get_backend("shard_map", n_shards=8, reduction="staged")
Bspec = jax.ShapeDtypeStruct((op.n, 8), jnp.float64)
rep = batched_plcg_overlap_report(be, op, Bspec, l=2,
                                  sigmas=shifts_for_operator(op, 2))
assert rep.n_collectives == 0, rep.n_collectives
assert rep.max_in_flight >= 2, str(rep)
assert all(v == 1 for v in rep.staged_starts_per_window.values()), \\
    rep.staged_starts_per_window
assert min(rep.reduce_hops_per_window.values()) >= 2
print("STAGED-HLO-OK")
""")
    assert "STAGED-HLO-OK" in out


def test_staged_reduction_parity():
    """Staged-vs-monolithic residual histories are BITWISE identical on
    stencils (the ladder's rank-order sum reproduces the monolithic
    all-reduce's deterministic linear order), across stage counts and
    for the batched slab; the local eager ladder oracle with
    virtual_shards=8 matches the 8-shard mesh bitwise too.  FEM
    SparseOp follows the PR 3 convention (tight head, bounded tail:
    local partials differ at ULP level between substrates)."""
    out = _run(HEADER + """
from repro.parallel import get_backend
op = Stencil2D5(32, 24)
b = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
sig = shifts_for_operator(op, 2)
kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-10, maxit=2000)
r_mono = get_backend("shard_map", n_shards=8).solve(op, b, **kw)
hm = np.asarray(r_mono.res_history)
for stages in (1, 2, 7):
    r = get_backend("shard_map", n_shards=8, reduction="staged",
                    reduction_stages=stages).solve(op, b, **kw)
    np.testing.assert_array_equal(np.asarray(r.res_history), hm)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(r_mono.x))
# eager ladder oracle == staged mesh, bitwise
r_o = get_backend("local", reduction="staged", virtual_shards=8).solve(
    op, b, **kw)
np.testing.assert_array_equal(np.asarray(r_o.res_history), hm)
# ghysels p-CG staged (one advance inside its overlap window)
kw_p = dict(method="pcg", tol=1e-10, maxit=2000)
r_pm = get_backend("shard_map", n_shards=8).solve(op, b, **kw_p)
r_ps = get_backend("shard_map", n_shards=8, reduction="staged").solve(
    op, b, **kw_p)
np.testing.assert_array_equal(np.asarray(r_ps.res_history),
                              np.asarray(r_pm.res_history))
# batched slab staged == batched monolithic, bitwise
B = jnp.asarray(np.random.default_rng(5).standard_normal((op.n, 4)))
kwb = dict(method="plcg", l=2, sigmas=sig, tol=1e-9, maxit=600)
rb_m = get_backend("shard_map", n_shards=8).solve_batched(op, B, **kwb)
rb_s = get_backend("shard_map", n_shards=8,
                   reduction="staged").solve_batched(op, B, **kwb)
np.testing.assert_array_equal(np.asarray(rb_s.res_history),
                              np.asarray(rb_m.res_history))
np.testing.assert_array_equal(np.asarray(rb_s.x), np.asarray(rb_m.x))
# fused superkernel on the staged mesh: vector phase fuses, the ladder
# carries the VMEM-accumulated partials — still bitwise vs unfused.
r_f = get_backend("shard_map", n_shards=8, reduction="staged").solve(
    op, b, fused_iteration=True, **kw)
np.testing.assert_array_equal(np.asarray(r_f.res_history), hm)
print("STAGED-PARITY-OK")
""")
    assert "STAGED-PARITY-OK" in out


def test_staged_reduction_fem_and_fp32():
    """Staged reduction on an unstructured FEM SparseOp (bounded-tail
    vs monolithic, PR 3 convention) and the fp32-payload wire with fp64
    compensated accumulation (halved hop bytes; bounded-tail parity,
    converges at the same iteration count +-2)."""
    out = _run(HEADER + """
from repro.parallel import get_backend
from repro.linalg import random_fem_mesh, rcm_reorder
op, _perm = rcm_reorder(random_fem_mesh(0, 400))
b = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
sig = shifts_for_operator(op, 2)
kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-9, maxit=900)
r_m = get_backend("shard_map", n_shards=8).solve(op, b, **kw)
r_s = get_backend("shard_map", n_shards=8, reduction="staged").solve(
    op, b, **kw)
xd = np.linalg.solve(op.to_dense(), np.asarray(b))
for r in (r_m, r_s):
    assert bool(r.converged)
    assert np.abs(np.asarray(r.x) - xd).max() < 1e-6
hm, hs = np.asarray(r_m.res_history), np.asarray(r_s.res_history)
n0 = float(r_m.norm0)
m = (hm >= 0) & (hs >= 0)
diff = np.abs(hs[m] - hm[m]) / n0
assert diff[:10].max() < 1e-8, diff[:10].max()
assert diff.max() < 5e-2, diff.max()
assert abs(int(r_s.iters) - int(r_m.iters)) <= 5

# fp32 payload on the stencil: bounded tail, same convergence
op2 = Stencil2D5(32, 24)
b2 = jnp.asarray(np.random.default_rng(2).standard_normal(op2.n))
sig2 = shifts_for_operator(op2, 2)
kw2 = dict(method="plcg", l=2, sigmas=sig2, tol=1e-9, maxit=2000)
r64 = get_backend("shard_map", n_shards=8, reduction="staged").solve(
    op2, b2, **kw2)
r32 = get_backend("shard_map", n_shards=8, reduction="staged",
                  reduction_dtype=jnp.float32).solve(op2, b2, **kw2)
assert bool(r32.converged)
assert abs(int(r32.iters) - int(r64.iters)) <= 2
h64, h32 = np.asarray(r64.res_history), np.asarray(r32.res_history)
n0 = float(r64.norm0)
m = (h64 >= 0) & (h32 >= 0)
diff = np.abs(h64[m] - h32[m]) / n0
assert diff[:10].max() < 1e-5, diff[:10].max()
assert diff.max() < 5e-2, diff.max()
# the fp32 wire really is half-width in the compiled HLO: the hop
# permutes carry f32 payloads
from repro.parallel.distributed import distributed_solve
from jax.sharding import NamedSharding, PartitionSpec as P
be32 = get_backend("shard_map", n_shards=8, reduction="staged",
                   reduction_dtype=jnp.float32)
bspec = jax.ShapeDtypeStruct((op2.n,), jnp.float64)
fn, arrays = distributed_solve(be32.mesh, op2, bspec, method="plcg", l=2,
                               sigmas=sig2, tol=1e-9, maxit=100, jit=False,
                               reduction=be32.reduction_cfg)
bsh = NamedSharding(be32.mesh, P("shards"))
ash = jax.tree.map(lambda _: bsh, arrays)
hlo = jax.jit(fn, in_shardings=(bsh, ash)).lower(bspec, arrays)\\
    .compile().as_text()
hop_lines = [ln for ln in hlo.splitlines()
             if "collective-permute" in ln and "glred_hop" in ln
             and "-done" not in ln]
assert hop_lines and all(" f32[" in ln for ln in hop_lines), \\
    hop_lines[:3]
assert not any(" all-reduce(" in ln or " all-reduce-start(" in ln
               for ln in hlo.splitlines())
print("STAGED-FEM-FP32-OK")
""")
    assert "STAGED-FEM-FP32-OK" in out


def test_splitkv_merge_under_shard_map():
    """Cross-shard split-KV decode: sequence sharded over 8 devices,
    merged with one pmax + one fused psum == unsharded attention."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.models.attention import decode_attention_jnp, merge_decode_shards
from repro.kernels import ops as kops
from repro.parallel import shard_map_compat

b, h, hkv, d, s = 2, 8, 4, 32, 512
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
mesh = jax.make_mesh((8,), ("kv",))

def shard_fn(q, k, v):
    o, m, l = kops.decode_attention_stats(q, k, v, k.shape[1], block_s=64)
    return merge_decode_shards(o, m, l, "kv")

fn = shard_map_compat(shard_fn, mesh=mesh,
                      in_specs=(P(), P(None, "kv", None, None),
                                P(None, "kv", None, None)),
                      out_specs=P())
merged = jax.jit(fn)(q, k, v).reshape(b, h, d)
full = kops.decode_attention(q, k, v, kv_len=s, block_s=64)
np.testing.assert_allclose(merged, np.asarray(full), rtol=3e-4, atol=3e-4)
print("SPLITKV-OK")
""")
    assert "SPLITKV-OK" in out
