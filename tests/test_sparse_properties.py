"""Hypothesis property tests for the unstructured sparse subsystem
(DESIGN.md §12): SparseOp parity vs ``to_dense()`` and partition-plan
correctness on arbitrary generated SPD graph Laplacians.

``hypothesis`` is an optional test dependency (pyproject's ``test``
extra); environments without it skip this module instead of failing
collection — same pattern as tests/test_properties.py.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[test])")
import hypothesis as hyp  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.chebyshev import shifts_for_operator  # noqa: E402
from repro.core.types import SolverOps  # noqa: E402
from repro.core import pipelined_cg  # noqa: E402
from repro.linalg import partition_spd  # noqa: E402
from repro.linalg.partition import emulate_partitioned_apply  # noqa: E402
from repro.linalg.sparse import _graph_laplacian  # noqa: E402


@st.composite
def graph_laplacians(draw):
    """Random SPD graph Laplacians: an arbitrary undirected edge set
    with positive weights + a positive diagonal (mass) shift — the FEM
    stiffness-matrix class of arXiv:1801.04728's test set."""
    n = draw(st.integers(min_value=4, max_value=24))
    n_edges = draw(st.integers(min_value=n - 1, max_value=3 * n))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        min_size=n_edges, max_size=n_edges))
    weights = draw(st.lists(
        st.floats(min_value=0.1, max_value=10.0, allow_nan=False),
        min_size=n_edges, max_size=n_edges))
    shift = draw(st.floats(min_value=0.05, max_value=2.0))
    i = np.array([min(e) for e in edges])
    j = np.array([max(e) for e in edges])
    keep = i != j
    i, j, w = i[keep], j[keep], np.asarray(weights)[keep]
    hyp.assume(keep.sum() >= 1)
    return _graph_laplacian(n, i, j, w, shift, jnp.float64)


@given(graph_laplacians())
@settings(max_examples=30, deadline=None)
def test_sparse_apply_matches_dense(op):
    """INVARIANT: SparseOp.apply == to_dense() @ x, the operator is SPD,
    and the 4-shard partition plan reproduces the dense product through
    its send/recv sets (when n divides)."""
    a = op.to_dense()
    np.testing.assert_allclose(a, a.T, atol=1e-12)
    assert np.linalg.eigvalsh(a)[0] > 0
    x = np.random.default_rng(0).standard_normal(op.n)
    np.testing.assert_allclose(op.apply(jnp.asarray(x)), a @ x, atol=1e-9)
    if op.n % 4 == 0:
        plan = partition_spd(op, 4)
        xp = x[plan.perm]
        y = emulate_partitioned_apply(plan, xp)
        np.testing.assert_allclose(y, a[np.ix_(plan.perm, plan.perm)] @ xp,
                                   atol=1e-9)


@given(graph_laplacians(), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_plcg_solves_generated_laplacians(op, l):
    """INVARIANT: p(l)-CG solves every generated SPD graph Laplacian to
    tolerance (breakdown restarts included)."""
    b = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))
    res = pipelined_cg.solve(SolverOps.local(op), b, l=l,
                             sigmas=shifts_for_operator(op, l),
                             tol=1e-9, maxit=50 * op.n)
    xd = np.linalg.solve(op.to_dense(), np.asarray(b))
    assert np.abs(np.asarray(res.x) - xd).max() < 1e-5
