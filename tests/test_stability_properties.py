"""Hypothesis property tests for the stability layer (DESIGN.md §18).

Three families of invariants that example tests cannot pin down:

* the **gap estimator** (``repro.stability.model.gap_step``) is monotone
  — never decreasing across an iteration, and non-decreasing in every
  magnitude input (larger Hessenberg entries / basis norms / injected
  perturbation can only WIDEN the predicted true-vs-recursive gap,
  never shrink it) — the property that makes "governor fires no later
  under more corruption" a theorem rather than a tuning accident;
* the **demotion ladder** (``governed_solve``) never tries a depth
  below ``min_l >= 1``, follows the exact halving schedule, and always
  terminates in either a converged result or a typed
  :class:`StagnationError` — proven against a stub backend so the
  ladder arithmetic gets thousands of cheap examples;
* the serve :class:`RetryPolicy` backoff is non-negative, monotone in
  the retry count, and capped — the arithmetic the deterministic-replay
  test (tests/test_serve_replay.py) relies on.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.serve import RetryPolicy
from repro.stability import (
    GovernorConfig,
    StagnationError,
    gov_init,
    governed_solve,
)
from repro.stability import model as M
from repro.stability.governor import diagnose

SET = dict(max_examples=200, deadline=None)

FINITE = st.floats(min_value=-1e12, max_value=1e12,
                   allow_nan=False, allow_infinity=False)
MAG = st.floats(min_value=0.0, max_value=1e12,
                allow_nan=False, allow_infinity=False)
GAP = st.floats(min_value=0.0, max_value=1e6,
                allow_nan=False, allow_infinity=False)
EPS = st.floats(min_value=1e-20, max_value=1e-3,
                allow_nan=False, allow_infinity=False)


def _gap(gap, gam, d2, dlt, basis, eps, kappa=1.0):
    import jax.numpy as jnp

    from repro.stability.model import gap_step
    return float(gap_step(jnp.float64(gap), jnp.float64(gam),
                          jnp.float64(d2), jnp.float64(dlt),
                          jnp.float64(basis), jnp.float64(eps), kappa))


# ------------------------------------------------------------ gap estimator --

@settings(**SET)
@given(gap=GAP, gam=FINITE, d2=FINITE, dlt=FINITE, basis=MAG, eps=EPS)
def test_gap_step_never_decreases(gap, gam, d2, dlt, basis, eps):
    """One governed iteration can only widen the predicted gap: the
    estimator is an accumulator of non-negative rounding mass."""
    out = _gap(gap, gam, d2, dlt, basis, eps)
    assert out >= gap
    assert np.isfinite(out)


@settings(**SET)
@given(gap=GAP, gam=MAG, d2=MAG, dlt=FINITE, basis=MAG, eps=EPS,
       scale=st.floats(min_value=1.0, max_value=1e6))
def test_gap_step_monotone_in_perturbation_magnitude(gap, gam, d2, dlt,
                                                     basis, eps, scale):
    """Scaling the magnitude inputs up — larger Hessenberg entries or a
    larger basis norm, the signature of injected perturbation — never
    shrinks the increment: the governor fires no LATER under more
    corruption."""
    lo = _gap(gap, gam, d2, dlt, basis, eps)
    hi = _gap(gap, gam * scale, d2 * scale, dlt, basis * scale, eps)
    assert hi >= lo


@settings(**SET)
@given(gap=GAP, gam=FINITE, d2=FINITE, basis=MAG, eps=EPS)
def test_gap_step_breakdown_safe(gap, gam, d2, basis, eps):
    """A vanishing pivot (dlt == 0, the breakdown the restart machinery
    handles) must not poison the estimator with inf/nan."""
    out = _gap(gap, gam, d2, 0.0, basis, eps)
    assert np.isfinite(out)
    assert out >= gap


# ---------------------------------------------------------- demotion ladder --

class _StubResult:
    """Shape-compatible stand-in for SolveResult: just the fields
    diagnose()/governed_solve() consume."""

    def __init__(self, converged, l):
        g = np.array(np.asarray(gov_init(np.float64)))
        g[M.STAGNATED] = 0.0 if converged else 1.0
        self.governor = g
        self.converged = np.asarray(converged)
        self.iters = np.asarray(7)
        self.x = np.zeros(3)


class _StubBackend:
    """Records every depth the ladder tries; converges only at depths in
    ``succeed_at``."""

    def __init__(self, succeed_at=()):
        self.succeed_at = set(succeed_at)
        self.tried = []

    def solve(self, op, b, method, prec=None, **kw):
        l = kw["l"]
        self.tried.append(l)
        return _StubResult(l in self.succeed_at, l)


def _ladder(l, min_l):
    """Expected halving schedule from l down to min_l."""
    seq, cur = [], l
    while True:
        seq.append(cur)
        if cur <= min_l:
            return seq
        cur = max(min_l, cur // 2)


@settings(max_examples=300, deadline=None)
@given(l=st.integers(min_value=1, max_value=64),
       min_l=st.integers(min_value=1, max_value=64))
def test_governed_solve_never_below_min_l(l, min_l):
    """Whatever the starting depth, a fully-stagnating ladder tries
    EXACTLY the halving schedule, never a depth below min_l (>= 1), and
    raises a typed StagnationError at the floor."""
    min_l = min(min_l, l)
    be = _StubBackend(succeed_at=())
    with pytest.raises(StagnationError) as ei:
        governed_solve(be, object(), np.zeros(3), l=l, min_l=min_l)
    assert be.tried == _ladder(l, min_l)
    assert min(be.tried) >= min_l >= 1
    assert len(ei.value.diagnosis["attempts"]) == len(be.tried)


@settings(max_examples=300, deadline=None)
@given(l=st.integers(min_value=1, max_value=64),
       min_l=st.integers(min_value=1, max_value=64),
       stop=st.integers(min_value=0, max_value=6))
def test_governed_solve_stops_at_first_convergence(l, min_l, stop):
    """Converging at any rung stops the ladder right there: no further
    demotion, result returned, attempts list exactly the rungs tried."""
    min_l = min(min_l, l)
    sched = _ladder(l, min_l)
    stop = min(stop, len(sched) - 1)
    be = _StubBackend(succeed_at={sched[stop]})
    res, attempts = governed_solve(be, object(), np.zeros(3), l=l,
                                   min_l=min_l)
    assert be.tried == sched[:stop + 1]
    assert attempts[-1]["converged"]
    assert attempts[-1]["l"] == sched[stop]
    assert diagnose(res)["converged"]


# ------------------------------------------------------------- retry policy --

@settings(**SET)
@given(base=st.floats(min_value=1e-6, max_value=10.0),
       factor=st.floats(min_value=1.0, max_value=10.0),
       cap=st.floats(min_value=1e-6, max_value=100.0),
       r1=st.integers(min_value=0, max_value=60),
       r2=st.integers(min_value=0, max_value=60))
def test_retry_backoff_monotone_capped(base, factor, cap, r1, r2):
    """Exponential backoff is non-negative, monotone in the retry count
    and never exceeds the cap — the arithmetic deterministic replay
    depends on."""
    pol = RetryPolicy(backoff_base_s=base, backoff_factor=factor,
                      backoff_cap_s=cap)
    lo, hi = sorted((r1, r2))
    assert 0.0 <= pol.backoff(lo) <= pol.backoff(hi) <= cap
