"""Hypothesis property tests for the checkpoint format (DESIGN.md §19).

The on-disk format's contract, stated as properties over ARBITRARY
payloads rather than the solver states the integration tests use:

* save -> load is bitwise lossless (arrays and meta);
* any truncation of the file raises a typed :class:`CheckpointError`
  (never a partial payload);
* any single-byte corruption either raises a typed error or provably
  changed nothing (a flip in redundant zip metadata that the reader
  never trusts) — corrupted STATE can never load silently;
* a foreign format version always refuses with
  :class:`CheckpointVersionError`.

``hypothesis`` is an optional test dependency (declared in
pyproject.toml's ``test`` extra); environments without it skip this
module instead of failing collection.
"""

import json
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (CKPT_VERSION, CheckpointCorruptError,
                              CheckpointError, CheckpointVersionError,
                              content_hash, load_checkpoint, save_checkpoint)

SET = dict(max_examples=25, deadline=None)

_DTYPES = [np.float64, np.float32, np.int64, np.int32, np.bool_]


@st.composite
def payloads(draw):
    """A checkpoint payload: 1..5 named arrays of arbitrary small shapes
    and mixed dtypes, deterministic from a drawn seed."""
    n_leaves = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_leaves):
        dt = _DTYPES[draw(st.integers(0, len(_DTYPES) - 1))]
        ndim = draw(st.integers(0, 3))
        shape = tuple(draw(st.integers(1, 5)) for _ in range(ndim))
        a = rng.standard_normal(shape)
        out[f"leaf_{i:03d}"] = (a > 0) if dt is np.bool_ \
            else a.astype(dt) if np.issubdtype(dt, np.floating) \
            else (a * 100).astype(dt)
    return out


@given(payload=payloads(), tag=st.text(
    alphabet=st.characters(min_codepoint=32, max_codepoint=126), max_size=20))
@settings(**SET)
def test_save_load_bitwise(tmp_path_factory, payload, tag):
    d = tmp_path_factory.mktemp("ckpt")
    path = str(d / "ckpt_0000000001.npz")
    meta_in = {"kind": "prop", "tag": tag, "count": len(payload)}
    stored = save_checkpoint(path, payload, meta_in)
    assert stored["version"] == CKPT_VERSION
    assert stored["sha256"] == content_hash(payload)
    back, meta = load_checkpoint(path)
    assert set(back) == set(payload)
    for k in payload:
        assert back[k].dtype == payload[k].dtype
        assert back[k].shape == payload[k].shape
        assert back[k].tobytes() == payload[k].tobytes()
    assert meta["tag"] == tag and meta["count"] == len(payload)


@given(payload=payloads(), frac=st.floats(0.01, 0.99))
@settings(**SET)
def test_truncation_is_typed(tmp_path_factory, payload, frac):
    d = tmp_path_factory.mktemp("ckpt")
    path = str(d / "ckpt_0000000001.npz")
    save_checkpoint(path, payload, {"kind": "prop"})
    raw = open(path, "rb").read()
    cut = max(1, int(len(raw) * frac))
    with open(path, "wb") as f:
        f.write(raw[:cut])
    with pytest.raises(CheckpointError):
        load_checkpoint(path)


@given(payload=payloads(), pos=st.floats(0.0, 1.0), delta=st.integers(1, 255))
@settings(**SET)
def test_single_byte_corruption_never_loads_silently(tmp_path_factory,
                                                     payload, pos, delta):
    """Flip one byte anywhere: either a typed refusal, or — when the
    flip hit redundant zip bookkeeping the reader never trusts — a load
    that is BITWISE identical to the original.  A changed payload or
    meta sneaking through would fail this property."""
    d = tmp_path_factory.mktemp("ckpt")
    path = str(d / "ckpt_0000000001.npz")
    save_checkpoint(path, payload, {"kind": "prop"})
    clean, clean_meta = load_checkpoint(path)
    raw = bytearray(open(path, "rb").read())
    i = min(int(pos * len(raw)), len(raw) - 1)
    raw[i] = (raw[i] + delta) % 256
    with open(path, "wb") as f:
        f.write(bytes(raw))
    try:
        back, meta = load_checkpoint(path)
    except CheckpointError:
        return                         # typed refusal: the contract
    assert set(back) == set(clean)
    for k in clean:
        assert back[k].tobytes() == clean[k].tobytes()
        assert back[k].dtype == clean[k].dtype
    assert meta == clean_meta


@given(payload=payloads(), version=st.integers(-5, 50))
@settings(**SET)
def test_foreign_version_refused(tmp_path_factory, payload, version):
    if version == CKPT_VERSION:
        version += 1
    d = tmp_path_factory.mktemp("ckpt")
    path = str(d / "ckpt_0000000001.npz")
    save_checkpoint(path, payload, {"kind": "prop"})
    _, meta = load_checkpoint(path)
    meta["version"] = version
    blob = np.frombuffer(json.dumps(meta, sort_keys=True).encode(),
                         dtype=np.uint8)
    arrays = {k: np.asarray(v) for k, v in payload.items()}
    with open(path, "wb") as f:
        np.savez(f, __meta__=blob, **arrays)
    with pytest.raises(CheckpointVersionError):
        load_checkpoint(path)


def test_reserved_keys_rejected(tmp_path):
    with pytest.raises(ValueError):
        save_checkpoint(str(tmp_path / "x.npz"),
                        {"__meta__": np.zeros(1)}, {})


def test_missing_file_is_filenotfound(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "nope.npz"))
    # FileNotFoundError is deliberately NOT a CheckpointError: "no
    # checkpoint yet" is the caller's normal cold-start signal.
    assert not issubclass(FileNotFoundError, CheckpointCorruptError)
