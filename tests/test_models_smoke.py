"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
same-family config runs one forward/train step on CPU — output shapes and
no NaNs — plus prefill→decode consistency against the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, lm_arch_ids
from repro.models import LM

RNG = np.random.default_rng(3)
B, T = 2, 32


def make_batch(cfg, t=T):
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, t)), jnp.int32),
         "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, t)), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        b["enc_embeds"] = jnp.asarray(
            RNG.standard_normal((B, t // cfg.enc_frames_ratio, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", lm_arch_ids())
def test_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, T, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = float(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)) ** 0.5)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", lm_arch_ids())
def test_prefill_decode_consistency(arch):
    """Decoding token t+1 after prefill(t) must match the full forward of
    t+1 tokens — the KV cache / recurrent state is exact."""
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t = 16
    batch = make_batch(cfg, t + 1)
    full_logits, _ = model.forward(params, batch)

    prompt = {k: (v[:, :t] if k in ("tokens", "labels") else v)
              for k, v in batch.items()}
    max_seq = t + 8 + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_p, cache = model.prefill(params, prompt, max_seq)
    # prefill last-position logits == forward at position t-1
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, t - 1]),
        rtol=2e-4, atol=2e-4)
    # decode the (t+1)-th token
    logits_d, cache = model.decode_step(
        params, batch["tokens"][:, t:t + 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "rwkv6-7b"])
def test_ssm_state_is_constant_size(arch):
    """The long_500k rationale: decode state does not grow with seq len."""
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    c1 = jax.eval_shape(lambda: model.init_cache(1, 64))
    c2 = jax.eval_shape(lambda: model.init_cache(1, 128))
    if cfg.family == "ssm":
        s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1))
        s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2))
        assert s1 == s2          # rwkv: O(1) in sequence length
    else:
        # hybrid: only the (few) shared-attn caches grow
        s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1["layers"]))
        s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2["layers"]))
        assert s1 == s2


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced-ish routing, most tokens are
    dispatched; the combine weights are bounded by the router probs."""
    from repro.models import moe as moe_mod
    cfg = get_config("deepseek-moe-16b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p_l = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(RNG.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    out, aux = moe_mod.moe_apply(p_l["moe"], cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # load-balance loss ~ 1 for a near-uniform softmax router; finite
    # samples (64 tokens, 8 experts) wander around it
    assert 0.3 <= float(aux) <= 3.0


def test_param_counts_match_config():
    """Analytic param_count tracks actual init within 20% (dense/moe)."""
    for arch in ["smollm-135m", "qwen3-1.7b", "deepseek-moe-16b"]:
        cfg = get_config(arch, smoke=True)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        ana = cfg.param_count()
        assert 0.6 < ana / actual < 1.4, (arch, ana, actual)
