"""The CI benchmark regression gate (scripts/check_bench.py): the gate
must pass within budget, trip on a >threshold drop, fail loudly on
missing metrics, and its CLI must exit nonzero on an injected
regression — the 'demonstrably fails' half of the ISSUE 3 acceptance."""

import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import check_bench  # noqa: E402


BASE = {"slab_speedup_vs_sequential": 6.0, "ell_occupancy": 0.6,
        "plan_halo_fraction": 0.5, "plan_hops": 1}
GATE = [("slab_speedup_vs_sequential", 0.20, True)]


def test_within_budget_passes():
    assert check_bench.check(
        BASE, {"slab_speedup_vs_sequential": 5.0}, GATE, verbose=False) == 0
    assert check_bench.check(
        BASE, {"slab_speedup_vs_sequential": 9.0}, GATE, verbose=False) == 0


def test_injected_regression_fails():
    assert check_bench.check(
        BASE, {"slab_speedup_vs_sequential": 4.0}, GATE, verbose=False) == 1


def test_boundary_is_20_percent():
    ok = {"slab_speedup_vs_sequential": 6.0 * 0.801}
    bad = {"slab_speedup_vs_sequential": 6.0 * 0.799}
    assert check_bench.check(BASE, ok, GATE, verbose=False) == 0
    assert check_bench.check(BASE, bad, GATE, verbose=False) == 1


def test_missing_metric_fails():
    assert check_bench.check(BASE, {}, GATE, verbose=False) == 1
    assert check_bench.check({}, {"slab_speedup_vs_sequential": 6.0},
                             GATE, verbose=False) == 1


def test_lower_is_better_gates():
    gates = [check_bench.parse_gate("-plan_halo_fraction:0.20"),
             check_bench.parse_gate("-plan_hops:0.0")]
    assert check_bench.check(
        BASE, {"plan_halo_fraction": 0.55, "plan_hops": 1}, gates,
        verbose=False) == 0
    assert check_bench.check(
        BASE, {"plan_halo_fraction": 0.65, "plan_hops": 1}, gates,
        verbose=False) == 1
    assert check_bench.check(
        BASE, {"plan_halo_fraction": 0.5, "plan_hops": 2}, gates,
        verbose=False) == 1


def test_selftest_and_cli_exit_codes(tmp_path):
    script = os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "check_bench.py")
    out = subprocess.run([sys.executable, script, "--selftest"],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr

    basef = tmp_path / "base.json"
    freshf = tmp_path / "fresh.json"
    basef.write_text(json.dumps(BASE))
    # CLI exit 1 on a 30% drop, 0 when within budget
    freshf.write_text(json.dumps({"slab_speedup_vs_sequential": 4.2}))
    out = subprocess.run(
        [sys.executable, script, "--baseline", str(basef), "--fresh",
         str(freshf), "--gate=slab_speedup_vs_sequential:0.20"],
        capture_output=True, text=True)
    assert out.returncode == 1, out.stdout
    freshf.write_text(json.dumps({"slab_speedup_vs_sequential": 5.9}))
    out = subprocess.run(
        [sys.executable, script, "--baseline", str(basef), "--fresh",
         str(freshf), "--gate=slab_speedup_vs_sequential:0.20"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout
