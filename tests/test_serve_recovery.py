"""Self-healing serve workers (DESIGN.md §19).

A faulted :class:`SlabWorker` (injected :class:`WorkerFault` here; a
real device/runtime error in prod) must be torn down — never left
half-alive holding slab capacity — and every in-flight column
resubmitted through the retry policy with a fresh SLO window.  The
contract under test:

* all in-flight requests of the dead worker retire CONVERGED after the
  respawn (healing is invisible to the client, just slower);
* the resubmission path is metrics-counted (``worker_deaths``,
  ``resubmitted``) and forensically logged (:class:`DeathEvent`);
* respawn reuses the compiled-program cache — a worker death must not
  pay a recompile;
* the whole sequence is deterministic under :class:`VirtualClock`
  (two identical runs produce identical metrics snapshots);
* exhausted retries shed (typed, accounted) instead of looping forever.
"""

import numpy as np
import pytest

from repro.linalg import Stencil2D5
from repro.parallel import get_backend
from repro.serve import RetryPolicy, SolverService, VirtualClock
from repro.serve.errors import WorkerFault
from repro.serve.scheduler import WORKER_FAULT_TYPES, DeathEvent


def _run(fault_tick, max_retries=3, n_req=4):
    """One full drain with a one-shot WorkerFault at ``fault_tick``."""
    op = Stencil2D5(12, 12)
    state = {"fired": False}

    def injector(tick, worker):
        if tick == fault_tick and not state["fired"]:
            state["fired"] = True
            raise WorkerFault(f"injected at tick {tick}")

    svc = SolverService(get_backend("local"), s=4, method="plcg", l=2,
                        chunk_iters=25, maxit=600, clock=VirtualClock(),
                        retry=RetryPolicy(max_retries=max_retries),
                        fault_injector=injector)
    svc.register_operator("lap", op)
    rng = np.random.default_rng(3)
    ids = [svc.submit("lap", rng.standard_normal(op.n))
           for _ in range(n_req)]
    results = svc.drain()
    return svc, ids, results, state


def test_worker_fault_heals_and_all_requests_converge():
    svc, ids, results, state = _run(fault_tick=2)
    assert state["fired"], "injector never fired"
    # One death, all four in-flight columns resubmitted, none shed.
    assert svc.worker_deaths == 1
    assert svc.resubmitted == 4
    for rid in ids:
        rr = results[rid]
        assert rr.converged and not rr.shed, (rid, rr.shed)
    st = svc.stats()
    assert st["worker_deaths"] == 1 and st["resubmitted"] == 4
    assert st["retired"] == 4 and st["shed"] == 0


def test_death_event_forensics():
    svc, ids, _, _ = _run(fault_tick=2)
    log = svc.scheduler.death_log
    assert len(log) == 1
    ev = log[0]
    assert isinstance(ev, DeathEvent)
    assert ev.tick == 2
    assert sorted(ev.req_ids) == sorted(ids)    # every in-flight column
    assert "injected at tick 2" in ev.reason


def test_respawn_reuses_compiled_programs():
    # The respawned worker must not recompile: the key's program stays
    # in the scheduler's program table across the death, and the run
    # pays exactly as many setup-cache misses (unique compilations) as a
    # fault-free run of the same shape.
    svc, _, _, _ = _run(fault_tick=2)
    assert svc.worker_deaths == 1               # a respawn happened...
    assert len(svc.scheduler._programs) == 1    # ...off the cached program
    svc_clean, _, _, _ = _run(fault_tick=-1)    # never fires
    assert (svc.stats()["setup_cache"]["misses"]
            == svc_clean.stats()["setup_cache"]["misses"])


def test_recovery_is_deterministic_under_virtual_clock():
    svc1, _, _, _ = _run(fault_tick=2)
    svc2, _, _, _ = _run(fault_tick=2)
    assert svc1.metrics_snapshot() == svc2.metrics_snapshot()


def test_exhausted_retries_shed_not_loop():
    svc, ids, results, state = _run(fault_tick=2, max_retries=0)
    assert state["fired"]
    shed = [rid for rid in ids if results[rid].shed]
    assert len(shed) == 4
    assert svc.resubmitted == 0                 # no budget: straight to shed
    assert svc.shed == 4
    assert svc.worker_deaths == 1


def test_worker_fault_is_typed_and_classified():
    # WorkerFault must be catchable as a ServeError AND recognised by the
    # scheduler's fault taxonomy (heal), unlike a programming bug
    # (propagate).
    from repro.serve.errors import ServeError

    assert issubclass(WorkerFault, ServeError)
    assert WorkerFault in WORKER_FAULT_TYPES
    assert not any(issubclass(TypeError, t) for t in WORKER_FAULT_TYPES)


def test_programming_bug_propagates_not_healed():
    op = Stencil2D5(12, 12)

    def injector(tick, worker):
        if tick == 1:
            raise TypeError("a bug, not a fault")

    svc = SolverService(get_backend("local"), s=4, method="plcg", l=2,
                        chunk_iters=25, maxit=600, clock=VirtualClock(),
                        retry=RetryPolicy(max_retries=3),
                        fault_injector=injector)
    svc.register_operator("lap", op)
    svc.submit("lap", np.random.default_rng(0).standard_normal(op.n))
    with pytest.raises(TypeError, match="a bug"):
        svc.drain()


def test_reset_stats_clears_recovery_counters():
    svc, _, _, _ = _run(fault_tick=2)
    assert svc.resubmitted == 4 and svc.worker_deaths == 1
    svc.reset_stats()
    assert svc.resubmitted == 0
    assert svc.stats()["resubmitted"] == 0
