"""Scheduler determinism under open-loop traffic replay (DESIGN.md §15).

Everything here runs on the VIRTUAL clock: no ``time.sleep``, no
``perf_counter`` — scheduler behavior (packing order, steal decisions,
shed decisions, latency percentiles) is a pure function of the seeded
trace, so two replays must agree BITWISE.  That is the test-archetype
point of this layer: latency/goodput numbers in CI carry no timing
flake at all.

In-process coverage runs the local backend and the 1-device shard_map
backend (full psum/spec staging path); the 8-device multi-slab HLO
invariant — exactly ONE reduction handle per iteration per slab, even
with replicated slabs under the work-stealing scheduler — runs in a
subprocess like the rest of the distributed suite.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.linalg import operators as ops_mod
from repro.parallel import get_backend
from repro.serve import (AdmissionPolicy, AdmissionRejected, SolverService,
                         TrafficClass, VirtualClock, poisson_trace, replay)

BACKENDS = ["local", "shard_map"]
OP = ops_mod.Stencil2D5(8, 8)          # n=64: small enough to replay fast


def _backend(name):
    if name == "local":
        return get_backend(name)
    return get_backend(name, n_shards=1)


def _service(backend_name, **over):
    kw = dict(s=4, method="plcg", l=2, chunk_iters=8, maxit=300,
              clock=VirtualClock(),
              admission=AdmissionPolicy(max_pending=64),
              max_replicas=2, replicate_watermark=0.5)
    kw.update(over)
    svc = SolverService(_backend(backend_name), **kw)
    svc.register_operator("lap", OP)
    return svc


def _mixed_trace(seed=7, n_requests=24, rate=40.0):
    """Heavy-tail mix: mostly loose-tol (cheap) solves, a tail of
    tight-tol (expensive) ones — two slab keys, so the scheduler runs
    multiple slabs."""
    classes = [
        TrafficClass("lap", OP.n, weight=4.0, tol=1e-4, deadline_s=2.0),
        TrafficClass("lap", OP.n, weight=1.0, tol=1e-10, deadline_s=8.0),
    ]
    return poisson_trace(classes, rate_per_s=rate, n_requests=n_requests,
                         seed=seed)


def _run_replay(backend_name, trace, **over):
    svc = _service(backend_name, **over)
    rep = replay(svc, trace, iter_time_s=1e-3, tick_overhead_s=1e-3)
    return svc, rep


@pytest.mark.parametrize("backend", BACKENDS)
def test_replay_bitwise_deterministic(backend):
    """Two replays of the same seeded trace on fresh services agree
    BITWISE: identical retirement logs (ids, workers, ticks, virtual
    times), identical steal and shed decisions, identical latency
    percentiles."""
    trace = _mixed_trace()
    svc1, rep1 = _run_replay(backend, trace)
    svc2, rep2 = _run_replay(backend, trace)
    assert rep1.retirement_log == rep2.retirement_log
    assert rep1.retirement_log, "replay must retire something"
    assert rep1.steal_log == rep2.steal_log
    assert rep1.shed_ids == rep2.shed_ids
    assert rep1.rejected_arrivals == rep2.rejected_arrivals
    st1, st2 = svc1.stats(), svc2.stats()
    assert st1["latency_p50_s"] == st2["latency_p50_s"]
    assert st1["latency_p99_s"] == st2["latency_p99_s"]
    assert rep1.metrics() == rep2.metrics()
    # the replay really ran open-loop work
    assert rep1.n_retired + rep1.n_shed + rep1.n_rejected == len(trace)
    assert rep1.n_converged == rep1.n_retired


def test_replay_seed_sensitivity():
    """Different seeds produce different traces (the determinism above
    is not vacuous)."""
    t1, t2 = _mixed_trace(seed=1), _mixed_trace(seed=2)
    assert [a.t for a in t1] != [a.t for a in t2]


@pytest.mark.parametrize("backend", BACKENDS)
def test_work_stealing_and_replication(backend):
    """A hot key scales out to a replica (sharing ONE compiled program)
    and idle replicas steal queued work from their sibling's tail; every
    retired solution still solves its system."""
    svc = _service(backend, replicate_watermark=0.25)
    rng = np.random.default_rng(0)
    # Mixed difficulty against one slab key: eigenmode RHS columns
    # converge in a couple of iterations, random ones take dozens —
    # retirement imbalance is what makes stealing happen.
    ii, jj = np.meshgrid(np.arange(1, 9), np.arange(1, 9), indexing="ij")
    mode = (np.sin(np.pi * ii / 9) * np.sin(np.pi * jj / 9)).reshape(-1)
    sent = {}
    for i in range(16):
        b = mode * (1.0 + i) if i % 2 == 0 else rng.standard_normal(OP.n)
        sent[svc.submit("lap", b, tol=1e-8)] = b
    results = svc.drain()
    sched = svc.scheduler
    assert len(sched._programs) == 1, "one slab key -> one compiled program"
    assert sched.replicas(("lap", 1e-8)) == 2, "hot key must scale out"
    w0, w1 = sched._by_key[("lap", 1e-8)]
    assert w0.program is w1.program, "replicas share the compiled program"
    assert sched.steal_log, "expected at least one steal"
    # stolen requests really were solved by the thief
    stolen = {ev.req_id for ev in sched.steal_log}
    for rid in stolen:
        assert results[rid].worker == next(
            ev.thief for ev in sched.steal_log if ev.req_id == rid)
    for rid, b in sent.items():
        r = results[rid]
        assert r.converged and not r.shed
        rel = np.linalg.norm(b - np.asarray(OP.apply(r.x))) \
            / np.linalg.norm(b)
        assert rel < 1e-6, (rid, rel)


def test_shedding_and_admission_under_overload():
    """Open-loop overload: hopeless deadlines are shed at pack time (not
    packed into slots), a full queue rejects at the door, and goodput
    counts only SLO-met solves."""
    classes = [TrafficClass("lap", OP.n, weight=1.0, tol=1e-10,
                            deadline_s=0.012)]
    trace = poisson_trace(classes, rate_per_s=400.0, n_requests=40, seed=3)
    # ONE slab (4 slots) and a 12-deep admission ceiling: a backlog
    # really builds behind the busy slab, so queued requests outlive
    # their 12 ms deadline while later ones bounce off the full queue.
    svc = _service("local", admission=AdmissionPolicy(max_pending=12),
                   max_replicas=1)
    rep = replay(svc, trace, iter_time_s=1e-3, tick_overhead_s=1e-3)
    assert rep.n_rejected > 0, "queue ceiling must reject under overload"
    assert rep.n_shed > 0, "expired deadlines must shed"
    assert rep.n_shed == len(rep.shed_ids) == svc.shed
    assert rep.n_retired + rep.n_shed + rep.n_rejected == len(trace)
    for rid in rep.shed_ids:
        r = svc.results[rid]
        assert r.shed and r.x is None and not r.slo_met
    # goodput numerator == SLO-met count, never more than retired
    assert rep.n_slo_met <= rep.n_retired
    assert rep.goodput_per_s == rep.n_slo_met / rep.makespan_s
    # shed decisions are logged with the wait that killed them
    assert all(ev.waited_s > 0.012 for ev in svc.scheduler.shed_log)


def test_retry_requeues_shed_request_to_success():
    """A shed request with the retry policy armed is REQUEUED with
    backoff instead of dropped: the retry releases with a fresh SLO
    window and retires converged — no shed result, one retried count,
    all on the virtual clock."""
    from repro.serve import RetryPolicy, VirtualClock

    clock = VirtualClock()
    svc = _service("local", clock=clock,
                   retry=RetryPolicy(max_retries=2, backoff_base_s=0.05))
    req = svc.submit("lap", np.ones(OP.n), tol=1e-8, deadline_s=0.01)
    clock.advance(0.05)          # deadline blows while queued
    out = svc.step()             # pack-time shed -> requeue, not drop
    assert out == []             # nothing retired OR shed this tick
    assert svc.retried == 1 and svc.shed == 0
    assert svc.pending == 1      # still owned by the service (backoff)
    results = svc.drain()        # drain sleeps the clock to the due time
    r = results[req]
    assert r.converged and not r.shed and r.slo_met
    assert svc.stats()["retried"] == 1 and svc.stats()["shed"] == 0


def test_retry_exhaustion_finally_sheds():
    """Bounded give-up: a request whose deadline blows on every attempt
    is requeued exactly ``max_retries`` times, then shed for real."""
    from repro.serve import RetryPolicy, VirtualClock

    clock = VirtualClock()
    svc = _service("local", clock=clock,
                   retry=RetryPolicy(max_retries=2, backoff_base_s=0.05))
    req = svc.submit("lap", np.ones(OP.n), tol=1e-8, deadline_s=0.01)
    shed_seen = []
    for _ in range(6):           # initial + 2 retries, with slack
        clock.advance(1.0)       # every wait blows the (fresh) window
        # advance again between release and pack so the re-anchored
        # deadline is ALSO expired by pack time
        svc._release_due_retries(clock.now())
        clock.advance(1.0)
        shed_seen += [r for r in svc.step() if r.shed]
        if shed_seen:
            break
    assert svc.retried == 2      # both retry budget entries consumed
    assert [r.req_id for r in shed_seen] == [req]
    assert svc.results[req].shed and svc.results[req].x is None


def test_retry_replay_deterministic():
    """The overload trace of test_shedding_and_admission_under_overload
    with the retry policy armed: retries fire (> 0), every request still
    accounts exactly once (retired/shed/rejected partition the trace),
    and two fresh replays agree on every count and id — the backoff is
    pure service-clock arithmetic."""
    from repro.serve import RetryPolicy

    classes = [TrafficClass("lap", OP.n, weight=1.0, tol=1e-10,
                            deadline_s=0.012)]
    trace = poisson_trace(classes, rate_per_s=400.0, n_requests=40, seed=3)

    def run():
        svc = _service("local", admission=AdmissionPolicy(max_pending=12),
                       max_replicas=1,
                       retry=RetryPolicy(max_retries=1,
                                         backoff_base_s=0.005))
        rep = replay(svc, trace, iter_time_s=1e-3, tick_overhead_s=1e-3)
        return svc, rep

    svc1, rep1 = run()
    svc2, rep2 = run()
    assert svc1.retried > 0
    assert svc1.retried == svc2.retried
    assert rep1.shed_ids == rep2.shed_ids
    assert rep1.n_retired == rep2.n_retired
    assert rep1.n_rejected == rep2.n_rejected
    assert rep1.n_retired + rep1.n_shed + rep1.n_rejected == len(trace)
    assert svc1.stats()["retried"] == svc1.retried


def test_continuous_injection_beats_drain_to_empty():
    """The continuous-batching claim: refilling retired slots at chunk
    boundaries keeps slot-utilization (occupied-slot-iterations /
    capacity) strictly above the drain-to-empty baseline on the same
    trace."""
    trace = _mixed_trace(seed=11, n_requests=32, rate=60.0)
    _svc_c, rep_c = _run_replay("local", trace, continuous=True)
    _svc_d, rep_d = _run_replay("local", trace, continuous=False)
    assert rep_c.n_converged == rep_c.n_retired
    assert rep_d.n_converged == rep_d.n_retired
    assert rep_c.slot_utilization > rep_d.slot_utilization, \
        (rep_c.slot_utilization, rep_d.slot_utilization)


def test_no_wall_clock_dependence():
    """The replay path must be wall-clock-free: the harness, scheduler
    and service never read the wall clock directly (the injectable clock
    is the only time source — SystemClock holds the only real reads)."""
    import inspect

    from repro.serve import clock as clock_mod
    from repro.serve import replay as replay_mod
    from repro.serve import scheduler as scheduler_mod
    from repro.serve import service as service_mod

    for mod in (replay_mod, scheduler_mod, service_mod):
        src = inspect.getsource(mod)
        assert "perf_counter" not in src, mod.__name__
        assert "time.sleep" not in src, mod.__name__
    # the only wall-clock reads live in SystemClock, behind the seam
    assert "perf_counter" in inspect.getsource(clock_mod)


def test_admission_rejection_is_typed():
    svc = _service("local", admission=AdmissionPolicy(max_pending=1))
    svc.submit("lap", np.ones(OP.n), tol=1e-8)
    with pytest.raises(AdmissionRejected) as ei:
        svc.submit("lap", np.ones(OP.n), tol=1e-8)
    assert ei.value.reason == "queue_full"
    assert ei.value.retry_after_s is None       # no retry policy: no hint
    assert svc.stats()["rejected"] == 1
    with pytest.raises(AdmissionRejected) as ei:
        svc.submit("lap", np.ones(OP.n), tol=1e-8, deadline_s=0.0)
    assert ei.value.reason in ("queue_full", "deadline_infeasible")


def test_queue_full_rejection_carries_retry_hint():
    """With the retry policy armed, queue-full rejections carry the
    backoff hint (resubmit no sooner than backoff(0)); infeasible
    deadlines never do — waiting cannot fix those."""
    from repro.serve import RetryPolicy

    pol = RetryPolicy(max_retries=2, backoff_base_s=0.05)
    svc = _service("local",
                   admission=AdmissionPolicy(max_pending=1,
                                             min_deadline_s=0.001),
                   retry=pol)
    svc.submit("lap", np.ones(OP.n), tol=1e-8)
    with pytest.raises(AdmissionRejected) as ei:
        svc.submit("lap", np.ones(OP.n), tol=1e-8)
    assert ei.value.reason == "queue_full"
    assert ei.value.retry_after_s == pol.backoff(0)
    assert "retry after" in str(ei.value)
    svc2 = _service("local",
                    admission=AdmissionPolicy(min_deadline_s=0.001),
                    retry=pol)
    with pytest.raises(AdmissionRejected) as ei:
        svc2.submit("lap", np.ones(OP.n), tol=1e-8, deadline_s=0.0005)
    assert ei.value.reason == "deadline_infeasible"
    assert ei.value.retry_after_s is None


# ---------------------------------------------------------------------------
# 8-device multi-slab HLO invariant (subprocess, like test_distributed).
# ---------------------------------------------------------------------------

ENV = dict(os.environ, PYTHONPATH="src")
ENV.pop("XLA_FLAGS", None)


def test_multi_slab_one_reduction_handle_per_iteration():
    """The paper's amortized-reduction invariant survives multi-slab
    scheduling: with TWO slab keys live and a replicated hot key, every
    compiled slab program still issues exactly ONE reduction handle per
    iteration carrying its whole (2l+1, s) payload (tracer-asserted on
    compiled HLO), and replicas share the compiled program."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.linalg import Stencil2D5
from repro.parallel import get_backend
from repro.serve import AdmissionPolicy, SolverService, VirtualClock
from repro.utils.trace import batched_plcg_overlap_report

op = Stencil2D5(8, 8)
be = get_backend("shard_map", n_shards=8)
svc = SolverService(be, s=4, method="plcg", l=2, chunk_iters=8, maxit=300,
                    clock=VirtualClock(), max_replicas=2,
                    replicate_watermark=0.25)
svc.register_operator("lap", op)
rng = np.random.default_rng(0)
for i in range(10):
    tol = 1e-8 if i % 3 else 1e-4      # two slab keys
    svc.submit("lap", rng.standard_normal(op.n), tol=tol)
results = svc.drain()
assert all(r.converged for r in results.values())
sched = svc.scheduler
assert len(sched._programs) == 2, sched._programs.keys()
assert len(sched.workers) >= 3, "hot key should have replicated"
for key, group in sched._by_key.items():
    for w in group:
        assert w.program is sched._programs[key], "replica must share program"
# Tracer: ONE reduction handle per iteration per slab, depth >= l in flight.
Bspec = jax.ShapeDtypeStruct((op.n, 4), jnp.float64)
rep = batched_plcg_overlap_report(be, op, Bspec, l=2, window=5)
assert len(rep.starts_per_window) == rep.window, str(rep)
assert all(v == 1 for v in rep.starts_per_window.values()), \\
    rep.starts_per_window
assert rep.max_in_flight >= 2, str(rep)
print("MULTI-SLAB-HLO-OK", len(sched.workers))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=os.getcwd(), timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "MULTI-SLAB-HLO-OK" in out.stdout
