"""Batched serving demo: prefill a batch of prompts, then decode tokens
step by step with the KV-cache/recurrent-state machinery (same code paths
the decode_32k / long_500k dry-run cells lower).

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-7b] [--tokens 32]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, lm_arch_ids
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen3-1.7b",
                    choices=lm_arch_ids())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal(
                (args.batch, args.prompt_len // cfg.enc_frames_ratio,
                 cfg.d_model)), jnp.float32)

    max_seq = args.prompt_len + args.tokens + \
        (cfg.n_patches if cfg.family == "vlm" else 0)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t1 = time.time()
    print(f"[{cfg.name}] prefill {args.batch}x{args.prompt_len}: "
          f"{(t1-t0)*1e3:.0f} ms (incl. compile)")

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t1 = time.time()
    gen = jnp.concatenate(outs, axis=1)
    print(f"decoded {args.tokens} tokens/seq: "
          f"{(t1-t0)/max(args.tokens-1,1)*1e3:.1f} ms/token (CPU, reduced "
          f"config)")
    print("sample token ids:", np.asarray(gen[0])[:16])
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
