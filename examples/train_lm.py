"""End-to-end LM training with the paper's technique as a first-class
feature: data-parallel gradient-reduction pipelining (depth l) + delayed
grad-norm clipping + checkpoint/restart.

Trains a reduced smollm-family model on the synthetic pipeline and
compares the loss curves of synchronous (l=0) vs pipelined (l=2) training
— the bounded-staleness trade the paper makes for CG (DESIGN.md §4).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--l 2]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticData
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_pipelined_train_step, run_steps


def train(arch_cfg, steps, l, ckpt_dir=None, seed=0):
    model = LM(arch_cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    data = SyntheticData.for_config(arch_cfg, seq_len=128, batch=8, seed=seed)
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=steps,
                          delayed_norm=(l > 0))
    opt = adamw_init(params)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    t0 = time.time()
    params, opt, ring, hist = run_steps(
        make_pipelined_train_step(model, opt_cfg, l), params, opt, data,
        n_steps=steps, l=l)
    dt = time.time() - t0
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt, "ring": ring},
                 meta={"arch": arch_cfg.name, "l": l}, block=True)
    return n, hist, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").replace(
        n_layers=args.layers, d_model=args.width,
        n_heads=max(args.width // 64, 1), n_kv=max(args.width // 128, 1),
        d_ff=args.width * 3, vocab=2048)

    print(f"== synchronous baseline (l=0) ==")
    n, hist0, dt0 = train(cfg, args.steps, 0, ckpt_dir=None)
    print(f"params {n/1e6:.1f}M | {args.steps} steps in {dt0:.0f}s | "
          f"loss {hist0[0]['loss']:.3f} -> {hist0[-1]['loss']:.3f}")

    print(f"== pipelined gradient reduction (l={args.l}) ==")
    n, hist2, dt2 = train(cfg, args.steps, args.l, ckpt_dir=args.ckpt)
    print(f"params {n/1e6:.1f}M | {args.steps} steps in {dt2:.0f}s | "
          f"loss {hist2[0]['loss']:.3f} -> {hist2[-1]['loss']:.3f}")

    f0 = np.mean([h["loss"] for h in hist0[-10:]])
    f2 = np.mean([h["loss"] for h in hist2[-10:]])
    print(f"\nfinal-10 mean loss: sync {f0:.4f} vs pipelined {f2:.4f} "
          f"(staleness penalty {f2-f0:+.4f}) — the l-step-delayed psum "
          f"frees the reduction from the critical path on a pod")


if __name__ == "__main__":
    main()
