"""Quickstart: solve a 2D Poisson problem with classic CG, Ghysels p-CG,
and deep-pipelined p(l)-CG — the paper's solver family side by side.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import classic_cg, ghysels_pcg, pipelined_cg
from repro.core.chebyshev import shifts_for_operator
from repro.core.types import SolverOps
from repro.linalg import Stencil2D5
from repro.linalg.preconditioners import BlockJacobi


def main():
    nx = ny = 64
    op = Stencil2D5(nx, ny)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(op.n))
    ops = SolverOps.local(op)

    print(f"2D 5-point Laplacian, {nx}x{ny} = {op.n} unknowns, tol 1e-8\n")
    res = classic_cg.solve(ops, b, tol=1e-8, maxit=2000)
    print(f"classic CG : {int(res.iters):4d} iters, converged={bool(res.converged)}")
    res = ghysels_pcg.solve(ops, b, tol=1e-8, maxit=2000)
    print(f"p-CG       : {int(res.iters):4d} iters, converged={bool(res.converged)}")
    for l in (1, 2, 3):
        sig = shifts_for_operator(op, l)
        res = pipelined_cg.solve(ops, b, l=l, tol=1e-8, maxit=2000, sigmas=sig)
        r = np.linalg.norm(np.asarray(b) - np.asarray(op.apply(res.x)))
        print(f"p({l})-CG    : {int(res.iters):4d} iters, "
              f"restarts={int(res.restarts)}, true residual {r:.2e}")

    print("\nwith block-Jacobi preconditioner (the paper's setup):")
    bj = BlockJacobi.from_operator(op, block_size=ny)
    opsp = SolverOps.local(op, bj)
    for l in (1, 2):
        # shifts for the PRECONDITIONED spectrum (paper: lmin/lmax options)
        sig = shifts_for_operator(op, l, prec=bj)
        res = pipelined_cg.solve(opsp, b, l=l, tol=1e-8, maxit=2000, sigmas=sig)
        print(f"p({l})-CG+BJ : {int(res.iters):4d} iters, "
              f"restarts={int(res.restarts)}")


if __name__ == "__main__":
    main()
