"""End-to-end driver (the paper's own workload): solve the 4M-unknown 2D
Laplacian system of Fig. 3 with p(l)-CG, matrix-free stencil SPMV (Pallas
kernel path available with --kernel), Jacobi preconditioning, Chebyshev
shifts, breakdown-restart, and checkpointed restart of the solver loop.

    PYTHONPATH=src python examples/solve_poisson_4m.py [--n 1024] [--l 2]
"""

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import classic_cg, pipelined_cg
from repro.core.chebyshev import shifts_for_operator
from repro.core.types import SolverOps
from repro.linalg import Stencil2D5
from repro.linalg.preconditioners import JacobiPrec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024,
                    help="grid side (default 1024 -> ~1M unknowns; the "
                         "paper's Fig. 3 uses 2000x2000 ~ 4M)")
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--kernel", action="store_true",
                    help="route SPMV through the Pallas stencil kernel "
                         "(interpret mode on CPU)")
    args = ap.parse_args()

    op = Stencil2D5(args.n, args.n, use_kernel=args.kernel)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(op.n))
    ops = SolverOps.local(op, JacobiPrec.from_operator(op))
    print(f"problem: 2D Laplacian {args.n}x{args.n} = {op.n/1e6:.2f}M unknowns")

    sig = shifts_for_operator(op, args.l)
    solve = jax.jit(lambda bb: pipelined_cg.solve(
        ops, bb, l=args.l, tol=args.tol, maxit=5000, sigmas=sig))
    t0 = time.time()
    res = solve(b)
    jax.block_until_ready(res.x)
    t1 = time.time()
    r = np.linalg.norm(np.asarray(b) - np.asarray(op.apply(res.x)))
    rel = r / np.linalg.norm(np.asarray(b))
    print(f"p({args.l})-CG: {int(res.iters)} iters, "
          f"restarts={int(res.restarts)}, {t1-t0:.1f}s wall, "
          f"true rel residual {rel:.2e}")
    assert rel < 10 * args.tol

    solve_cg = jax.jit(lambda bb: classic_cg.solve(
        ops, bb, tol=args.tol, maxit=5000))
    t0 = time.time()
    res2 = solve_cg(b)
    jax.block_until_ready(res2.x)
    t1 = time.time()
    print(f"classic CG: {int(res2.iters)} iters, {t1-t0:.1f}s wall "
          f"(identical math; the pipelined win shows up on a pod, "
          f"see benchmarks/fig2)")


if __name__ == "__main__":
    main()
