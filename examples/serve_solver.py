"""Solver-service quickstart: batched multi-RHS serving with masked
retirement and a setup cache (DESIGN.md §11).

Registers two operators, streams a burst of solve requests through an
s-wide slab, drains the scheduler, and verifies every retired solution
against the operator.  Works on one CPU device; pass --shards 8 after
setting XLA_FLAGS=--xla_force_host_platform_device_count=8 to serve from
a simulated mesh.

    PYTHONPATH=src python examples/serve_solver.py [--requests 12] [--s 4]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.linalg import Stencil2D5, random_fem_icesheet, rcm_reorder
from repro.parallel import get_backend
from repro.serve import SolverService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--s", type=int, default=4, help="slab width")
    ap.add_argument("--l", type=int, default=2, help="pipeline depth")
    ap.add_argument("--shards", type=int, default=0,
                    help="0 = local backend, else shard_map over N devices")
    args = ap.parse_args()

    be = get_backend("local") if args.shards == 0 else \
        get_backend("shard_map", n_shards=args.shards)
    svc = SolverService(be, s=args.s, method="plcg", l=args.l,
                        chunk_iters=20, maxit=600,
                        prec="block_jacobi", block_size=24)

    ops = {
        "poisson2d": Stencil2D5(24, 24),
        # Unstructured FEM ice-sheet (DESIGN.md §12) — RCM pre-ordered so
        # the block-Jacobi blocks are factored in the partitioned basis.
        "icesheet3d": rcm_reorder(random_fem_icesheet(48, 12, 4, 4,
                                                      eps_z=0.1))[0],
    }
    for key, op in ops.items():
        svc.register_operator(key, op)
    # Re-registering a structurally identical operator hits the cache.
    svc.register_operator("poisson2d_alias", Stencil2D5(24, 24))

    rng = np.random.default_rng(0)
    keys = list(ops)
    sent = {}
    for i in range(args.requests):
        key = keys[i % len(keys)]
        b = rng.standard_normal(ops[key].n)
        sent[svc.submit(key, b, tol=1e-9)] = (key, b)

    t0 = time.perf_counter()
    results = svc.drain()
    wall = time.perf_counter() - t0

    for rid, (key, b) in sent.items():
        r = results[rid]
        x = jnp.asarray(r.x)
        rel = float(jnp.linalg.norm(jnp.asarray(b) - ops[key].apply(x))
                    / np.linalg.norm(b))
        status = "ok" if r.converged and rel < 1e-7 else "FAIL"
        print(f"req {rid:>3d} [{key:>10s}] iters={r.iters:>4d} "
              f"true-rel-res={rel:.2e} latency={r.latency_s * 1e3:7.1f} ms "
              f"{status}")
        assert status == "ok", (rid, rel)

    st = svc.stats()
    print(f"\ndrained {st['retired']} requests in {wall:.2f} s "
          f"({st['retired'] / wall:.1f} solves/s incl. compile) over "
          f"{st['chunks_run']} chunks, {st['slabs']} slab(s)")
    print(f"latency p50 {st['latency_p50_s'] * 1e3:.1f} ms, "
          f"p99 {st['latency_p99_s'] * 1e3:.1f} ms")
    print("setup cache:", st["setup_cache"], "(alias registration hit)")


if __name__ == "__main__":
    main()
