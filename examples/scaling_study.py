"""Scaling study: pick the pipeline depth l for YOUR problem, then verify
the pipeline actually overlaps on a simulated 8-device mesh.

Three stages (the paper: 'the pipeline length is a parameter that can be
chosen depending on the problem and hardware setup'):

  1. analytic sweep of depth l vs node count (schedule simulator +
     hardware profile, Figs. 2-3 regime);
  2. the pipeline-depth autotuner (repro.launch.autotune, DESIGN.md §6)
     ranking (l, unroll) candidates for one (problem, mesh) cell;
  3. a LIVE check through the reduction-backend API (DESIGN.md §3) on 8
     simulated host devices: the `local` and `shard_map` backends must
     produce bitwise-comparable (fp32-tolerance) residual histories, and
     the overlap tracer must see >= l reduction chains in flight for
     p(l)-CG with a window of unroll >= l+1.

    PYTHONPATH=src python examples/scaling_study.py --n 8000000 --hw cori
    PYTHONPATH=src python examples/scaling_study.py --skip-live   # model only
"""

# The live stage needs 8 simulated host devices — must be set before jax
# initializes (same pattern as repro.launch.dryrun: PREPEND so an existing
# XLA_FLAGS doesn't silently drop the device forcing).
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.schedule_sim import iteration_time
from benchmarks.timing_model import CORI, V5E, stencil_kernel_times


def analytic_sweep(args, hw):
    nodes_list = [8, 32, 128, 512, 1024, 4096]
    print(f"problem: {args.n/1e6:.0f}M unknowns, {args.stencil}-pt stencil, "
          f"{hw.name}, glred jitter {args.jitter}")
    print(f"{'nodes':>6s} | {'CG':>9s} | " +
          " | ".join(f"{f'p({l})-CG':>9s}" for l in (1, 2, 3, 5)) +
          " | best")
    for nodes in nodes_list:
        p = nodes * 16 if hw is CORI else nodes
        k = stencil_kernel_times(hw, args.n, p, stencil_pts=args.stencil,
                                 prec_factor=3.0)
        t_cg = iteration_time("cg", 0, k, jitter=args.jitter)
        ts = {l: iteration_time("plcg", l, k, jitter=args.jitter)
              for l in (1, 2, 3, 5)}
        best = min(ts, key=ts.get)
        print(f"{nodes:>6d} | {t_cg*1e6:>7.1f}us | " +
              " | ".join(f"{ts[l]*1e6:>7.1f}us" for l in (1, 2, 3, 5)) +
              f" | l={best} ({t_cg/ts[best]:.1f}x CG)")


def autotune_cell(args, hw):
    from repro.launch.autotune import autotune_depth

    p = 512 * 16 if hw is CORI else 512
    res = autotune_depth(n=args.n, p=p, hw=hw, stencil_pts=args.stencil,
                         jitter=args.jitter, prec_factor=3.0)
    print()
    print(res.table())
    print(f"-> autotuned depth for this cell: l={res.best.l} "
          f"unroll={res.best.unroll} ({res.best.method})")
    return res.best


def live_verify(args):
    """Backend parity + overlap trace on the simulated 8-device mesh."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5
    from repro.parallel import get_backend
    from repro.utils.trace import plcg_overlap_report

    n_dev = max(len(jax.devices()), 1)
    l = args.live_l
    op = Stencil2D5(32, 24)
    b = jnp.asarray(
        np.random.default_rng(0).standard_normal(op.n), jnp.float32)
    sig = jnp.asarray(shifts_for_operator(op, l), jnp.float32)

    print(f"\nlive check: {op.n} unknowns, p({l})-CG, fp32, "
          f"{n_dev} simulated device(s)")

    # --- residual-history parity: local vs shard_map -------------------
    kw = dict(method="plcg", l=l, sigmas=sig, tol=1e-5, maxit=400)
    res_local = get_backend("local").solve(op, b, **kw)
    res_shard = get_backend("shard_map", n_shards=n_dev).solve(op, b, **kw)
    h_l = np.asarray(res_local.res_history)
    h_s = np.asarray(res_shard.res_history)
    np.testing.assert_allclose(h_s, h_l, rtol=2e-4, atol=1e-5)
    n_rec = int((h_l >= 0).sum())
    print(f"  residual-history parity local vs shard_map: OK "
          f"({n_rec} recorded norms, fp32 tolerance, "
          f"iters {int(res_local.iters)}/{int(res_shard.iters)})")

    # --- overlap trace: >= l chains in flight for window >= l+1 --------
    be = get_backend("shard_map", n_shards=n_dev)
    bspec = jax.ShapeDtypeStruct((op.n,), jnp.float32)
    rep = plcg_overlap_report(be, op, bspec, l=l, window=l + 2, sigmas=sig)
    print("  " + str(rep).replace("\n", "\n  "))
    assert rep.max_in_flight >= l, (
        f"pipeline collapsed: only {rep.max_in_flight} chain(s) in flight "
        f"for l={l}")
    print(f"  overlap: {rep.max_in_flight} >= l={l} chains in flight — "
          f"the Fig. 4 staggering is present in the compiled schedule")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8_000_000)
    ap.add_argument("--hw", choices=["cori", "v5e"], default="cori")
    ap.add_argument("--stencil", type=int, default=7)
    ap.add_argument("--jitter", type=float, default=0.15)
    ap.add_argument("--live-l", type=int, default=2,
                    help="pipeline depth for the live backend check")
    ap.add_argument("--skip-live", action="store_true",
                    help="model-only run (no jax compilation)")
    args = ap.parse_args()
    hw = CORI if args.hw == "cori" else V5E

    analytic_sweep(args, hw)
    autotune_cell(args, hw)
    if not args.skip_live:
        live_verify(args)


if __name__ == "__main__":
    main()
