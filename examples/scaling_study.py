"""Scaling study: sweep pipeline depth l and node count with the
schedule-simulator + hardware profiles, for YOUR problem size — a planning
tool for picking l (the paper: 'the pipeline length is a parameter that
can be chosen depending on the problem and hardware setup').

    PYTHONPATH=src python examples/scaling_study.py --n 8000000 --hw cori
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.schedule_sim import iteration_time
from benchmarks.timing_model import CORI, V5E, stencil_kernel_times


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8_000_000)
    ap.add_argument("--hw", choices=["cori", "v5e"], default="cori")
    ap.add_argument("--stencil", type=int, default=7)
    ap.add_argument("--jitter", type=float, default=0.15)
    args = ap.parse_args()
    hw = CORI if args.hw == "cori" else V5E

    nodes_list = [8, 32, 128, 512, 1024, 4096]
    print(f"problem: {args.n/1e6:.0f}M unknowns, {args.stencil}-pt stencil, "
          f"{hw.name}, glred jitter {args.jitter}")
    print(f"{'nodes':>6s} | {'CG':>9s} | " +
          " | ".join(f"{f'p({l})-CG':>9s}" for l in (1, 2, 3, 5)) +
          " | best")
    for nodes in nodes_list:
        p = nodes * 16 if hw is CORI else nodes
        k = stencil_kernel_times(hw, args.n, p, stencil_pts=args.stencil,
                                 prec_factor=3.0)
        t_cg = iteration_time("cg", 0, k, jitter=args.jitter)
        ts = {l: iteration_time("plcg", l, k, jitter=args.jitter)
              for l in (1, 2, 3, 5)}
        best = min(ts, key=ts.get)
        print(f"{nodes:>6d} | {t_cg*1e6:>7.1f}us | " +
              " | ".join(f"{ts[l]*1e6:>7.1f}us" for l in (1, 2, 3, 5)) +
              f" | l={best} ({t_cg/ts[best]:.1f}x CG)")


if __name__ == "__main__":
    main()
