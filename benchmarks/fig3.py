"""Paper Fig. 3: kernel timing breakdown, Laplacian vs diagonal toy.

Left panel : 2D 5-point Laplacian, 4M unknowns, 128 nodes (KSP ex2-like).
Right panel: diagonal system with the same spectrum — the extreme
             communication-bound regime.

Reproduced with the analytic kernel model + schedule simulator; the key
claims (paper §4.2):
  L1  Laplacian: p(1)-CG beats CG, but l >= 2 adds little (glred ~ spmv)
  R1  diagonal : p(2)-CG significantly beats p(1)-CG (staggering), and
  R2  l >= 3 adds little beyond l = 2
"""

from __future__ import annotations

from benchmarks.schedule_sim import iteration_time
from benchmarks.timing_model import CORI, diagonal_kernel_times, \
    stencil_kernel_times

N = 4_000_000
NODES = 128
RANKS = NODES * 16
METHODS = [("cg", 0), ("pcg", 0), ("plcg", 1), ("plcg", 2), ("plcg", 3)]


def breakdown(kernels, verbose, title):
    if verbose:
        print(f"-- {title}: spmv {kernels['spmv']*1e6:.1f}us | "
              f"axpy {kernels['axpy1']*1e6:.2f}us | "
              f"glred {kernels['glred']*1e6:.1f}us")
    out = {}
    for m, l in METHODS:
        t = iteration_time(m, l, kernels, jitter=0.15)
        out[(m, l)] = t
        if verbose:
            nm = {"cg": "CG", "pcg": "p-CG"}.get(m, f"p({l})-CG")
            print(f"   {nm:>9s}: {t*1e6:8.1f} us/iter")
    return out


def run(verbose=True):
    lap = breakdown(
        stencil_kernel_times(CORI, N, RANKS, stencil_pts=5, prec_factor=3.0),
        verbose, f"2D Laplacian {N/1e6:.0f}M on {NODES} nodes")
    dia = breakdown(
        diagonal_kernel_times(CORI, N, RANKS),
        verbose, f"diagonal toy {N/1e6:.0f}M on {NODES} nodes")

    l1 = lap[("plcg", 1)] < lap[("cg", 0)] and \
        lap[("plcg", 2)] > 0.85 * lap[("plcg", 1)]
    r1 = dia[("plcg", 2)] < 0.8 * dia[("plcg", 1)]
    r2 = dia[("plcg", 3)] > 0.8 * dia[("plcg", 2)]
    if verbose:
        print(f"  L1 (l=1 enough for Laplacian): {l1} | "
              f"R1 (staggering pays on diagonal): {r1} | "
              f"R2 (l=3 ~ l=2): {r2}")
    assert l1 and r1 and r2, "Fig. 3 qualitative claims failed"
    return {"laplacian": lap, "diagonal": dia}


if __name__ == "__main__":
    run()
