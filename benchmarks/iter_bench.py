"""Fused-iteration benchmark: HBM bytes and wall time per p(l)-CG
iteration, fused superkernel vs the unfused reference path
(DESIGN.md §13).  Emits ``BENCH_iter.json``; CI gates two STRUCTURAL
ratios (``scripts/check_bench.py --ratio-gate``), deterministic where
container timing noise is not: modeled fused bytes <= 0.6x measured
unfused (trips on slab-layout growth or unfused drift), and measured
interpret-mode fused bytes <= 1.15x unfused (fully measured — trips
when an extra slab pass sneaks INTO the kernel body).

Byte accounting (the DESIGN.md §13 roofline, asserted in
tests/test_fused_iter.py):

* ``unfused_bytes_per_iter`` — XLA ``cost_analysis`` 'bytes accessed'
  of the compiled unfused iteration
  (``launch.autotune.measured_iteration_bytes``): the ~dozen separate
  passes over the (NV, N) slab, measured, not estimated.
* ``fused_bytes_per_iter`` — the TPU accounting of the compiled
  superkernel (``launch.autotune.fused_iteration_bytes``): an opaque
  custom call reads its operands and writes its results ONCE — slab in,
  slab out (aliased), resident SPMV operand, O(l) scalars.
* ``fused_bytes_interpret_measured`` — honesty column: cost_analysis of
  the interpret-mode fused iteration as it runs on THIS container,
  where the interpreter re-materializes kernel-interior temporaries
  (expected ~= unfused; the kernel's one-pass property is a property of
  the Mosaic compilation, not of the interpreter).

Wall clocks (informational, not gated): seconds/iteration of the
compiled local solver, fused vs unfused, measured by differencing two
iteration budgets as in ``launch.autotune.measured_runner``.  The run
records ``kernel_mode`` exactly like ``spmv_bench``: without a real
accelerator backend the fused path's Pallas superkernel executes in
INTERPRET mode, so its wall clock measures the interpreter, not the
kernel — the fused timing is then emitted under the explicit
``fused_time_per_iter_s_interpret`` key (with
``fused_wall_time_comparable: false`` and a note) instead of a key
that invites an apples-to-oranges comparison against the compiled
unfused path.

    PYTHONPATH=src python -m benchmarks.iter_bench [--nx 256] [--out PATH]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from benchmarks.lane import (  # noqa: E402
    compiled_out,
    resolve_kernel_mode,
    write_payload,
)
from repro.core import pipelined_cg  # noqa: E402
from repro.core.chebyshev import shifts_for_operator  # noqa: E402
from repro.core.types import SolverOps  # noqa: E402
from repro.launch.autotune import (  # noqa: E402
    fused_iteration_bytes,
    measured_iteration_bytes,
)
from repro.linalg.operators import Stencil2D5  # noqa: E402


def time_per_iter(op, b, sig, l, fused, iters=(20, 60), repeats=3):
    ops = SolverOps.local(op)

    def run(maxit):
        fn = jax.jit(lambda bb: pipelined_cg.solve(
            ops, bb, l, sigmas=sig, tol=0.0, maxit=maxit,
            fused_iteration=fused))
        jax.block_until_ready(fn(b).x)       # compile + warmup
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(b).x)
            best = min(best, time.perf_counter() - t0)
        return best

    lo, hi = iters
    t_lo, t_hi = run(lo), run(hi)
    if t_hi <= t_lo:
        return t_hi / hi
    return (t_hi - t_lo) / (hi - lo)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=256)
    ap.add_argument("--ny", type=int, default=256)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--skip-timing", action="store_true",
                    help="structural bytes only (fast CI path)")
    ap.add_argument("--kernel-mode", choices=("auto", "compiled"),
                    default="auto",
                    help="'compiled' demands a real accelerator and "
                         "writes a machine-readable skip payload to "
                         "--out when there is none (benchmarks.lane)")
    args = ap.parse_args()

    out = compiled_out(args.kernel_mode, args.out, "BENCH_iter.json")
    mode, skip = resolve_kernel_mode(args.kernel_mode)
    if skip is not None:
        write_payload(out, skip)
        return

    op = Stencil2D5(args.nx, args.ny)
    l = args.l
    sig = shifts_for_operator(op, l)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))

    unfused_bytes = measured_iteration_bytes(op, l, sigmas=sig, fused=False)
    fused_meas = measured_iteration_bytes(op, l, sigmas=sig, fused=True)
    fused_bytes = float(fused_iteration_bytes(op.n, l))

    # Like spmv_bench: the Pallas superkernel compiles only on a real
    # accelerator backend; on CPU CI it runs under the interpreter.
    interpret = mode == "interpret"

    payload = {
        "problem": {"n": op.n, "nx": args.nx, "ny": args.ny, "l": l},
        # structural (gated): the fused one-pass traffic vs the measured
        # unfused multi-pass traffic — deterministic given shapes.
        "unfused_bytes_per_iter": unfused_bytes,
        "fused_bytes_per_iter": fused_bytes,
        "fused_over_unfused_bytes": fused_bytes / unfused_bytes,
        "fused_bytes_interpret_measured": fused_meas,
        "slab_passes_unfused": unfused_bytes / (op.n * 8),
        "slab_passes_fused": fused_bytes / (op.n * 8),
        "kernel_mode": mode,
        "jax_backend": jax.default_backend(),
    }
    if not args.skip_timing:
        payload["unfused_time_per_iter_s"] = time_per_iter(
            op, b, sig, l, fused=False)
        t_fused = time_per_iter(op, b, sig, l, fused=True)
        if interpret:
            # The fused wall clock times the Pallas INTERPRETER — a
            # correctness vehicle, not the kernel.  Emit it under an
            # explicit key so nobody reads "fused slower than unfused"
            # off a number that never ran the kernel.
            payload["fused_time_per_iter_s_interpret"] = t_fused
            payload["fused_wall_time_comparable"] = False
            payload["wall_time_note"] = (
                "fused path ran in Pallas interpret mode (no TPU/GPU in "
                "this container): its wall clock is interpreter "
                "overhead and MUST NOT be compared against the compiled "
                "unfused time; the gated byte ratios above are the "
                "machine-independent fused-vs-unfused comparison")
        else:
            payload["fused_time_per_iter_s"] = t_fused
            payload["fused_wall_time_comparable"] = True
    write_payload(out, payload)


if __name__ == "__main__":
    main()
