"""§Roofline report: renders the dry-run JSON records into the
EXPERIMENTS.md table (per arch × shape × mesh: three terms, dominant
bottleneck, MODEL_FLOPS ratio, roofline-bound MFU)."""

from __future__ import annotations

import json
import os


def load(paths):
    recs = []
    for p in paths:
        if os.path.exists(p):
            with open(p) as f:
                recs.extend(json.load(f))
    return recs


def fmt_row(r) -> str:
    uf = r.get("useful_fraction")
    mfu = r.get("mfu")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | {r['dominant']} "
            f"| {uf:.3f} | {mfu:.3f} |"
            if uf is not None and mfu is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.2e} | {r['t_memory']:.2e} "
            f"| {r['t_collective']:.2e} | {r['dominant']} | - | - |")


def render(recs) -> str:
    hdr = ("| arch | shape | mesh | t_compute (s) | t_memory (s) "
           "| t_collective (s) | dominant | useful | MFU-bound |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in recs)


def run(paths=("results/roofline_baseline.json",
              "results/roofline_optimized.json"), verbose=True):
    recs = load(paths)
    if not recs:
        if verbose:
            print("== Roofline report: no dry-run JSON found (run "
                  "`python -m repro.launch.dryrun --all --roofline --out "
                  "results/roofline_baseline.json` first) ==")
        return None
    txt = render(recs)
    if verbose:
        print("== Roofline report ==")
        print(txt)
    return txt


if __name__ == "__main__":
    run()
