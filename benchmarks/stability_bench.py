"""Stability-governor benchmark: governed vs ungoverned attainable
accuracy at deep pipeline depth under seeded fault injection
(DESIGN.md §18).  Emits ``BENCH_stability.json``; CI gates it via
``scripts/check_bench.py``.  Every gated column is DETERMINISTIC —
seeded chaos, fixed shapes, compiled-HLO structure — so container
timing noise cannot move any of them:

* ``stability_governed_recovered``    — 1 when the governed stable
                                        p(l)-CG solve reaches tol under
                                        the injected reduction-payload
                                        fault, certified against the
                                        TRUE residual.  Floor-gated: the
                                        recovery claim is the PR.
* ``stability_ungoverned_stagnated``  — 1 when the same fault defeats
                                        ungoverned ghysels p(l)-CG at
                                        the same depth (it must: this is
                                        the failure the governor exists
                                        for).
* ``stability_recovery_ratio``        — ungoverned / governed final TRUE
                                        relative residual: the
                                        attainable-accuracy gap the
                                        governor closes (~10^3 here).
* ``stability_governor_replacements`` — governed replacement count; the
                                        gap/patience split rides along
                                        from the telemetry ring's action
                                        column (§16: every governor
                                        action is exported).
* ``stability_reduction_starts_per_iter_max`` / ``_staged_*`` — the
                                        sacred ceiling: the GOVERNED
                                        compiled schedule still issues
                                        exactly ONE pipelined reduction
                                        start per iteration (fused psum
                                        and staged ladder), zero staged
                                        dot-block all-reduces.
* ``stability_ladder_depths_tried`` / ``stability_ladder_typed_error``
                                      — catastrophic corruption (30%
                                        payload noise) demotes the host
                                        ladder 4 -> 2 -> 1 and raises a
                                        typed StagnationError: governed
                                        solves never return silent
                                        non-convergence.

    PYTHONPATH=src python -m benchmarks.stability_bench [--out PATH]
"""

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after XLA_FLAGS)

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.chaos import ChaosConfig, chaos_ops  # noqa: E402
from repro.core import pipelined_cg  # noqa: E402
from repro.core.types import SolverOps, TelemetrySlab  # noqa: E402
from repro.linalg import Stencil2D5  # noqa: E402
from repro.linalg.preconditioners import JacobiPrec  # noqa: E402
from repro.parallel import get_backend  # noqa: E402
from repro.stability import (  # noqa: E402
    GovernorConfig,
    StagnationError,
    diagnose,
    governed_solve,
)
from repro.stability import model as gov_model  # noqa: E402
from repro.utils.trace import plcg_overlap_report  # noqa: E402

L = 4
TOL = 1e-5
CHAOS = ChaosConfig(seed=7, payload_rel_amp=1e-5)
CATASTROPHIC = ChaosConfig(seed=3, payload_rel_amp=3e-1)
TEL_CAP = 512


def _problem():
    op = Stencil2D5(48, 24)
    prec = JacobiPrec.from_operator(op)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))
    return op, prec, SolverOps.local(op, prec), b


def _true_rel(op, b, x):
    r = np.asarray(b) - np.asarray(op.apply(jnp.asarray(np.asarray(x))))
    return float(np.linalg.norm(r) / np.linalg.norm(np.asarray(b)))


def recovery_rows() -> dict:
    """The governed-vs-ungoverned recovery point: same operator, same
    seeded fault, same depth; only the recurrence + governor differ."""
    op, prec, ops, b = _problem()
    cops = chaos_ops(ops, CHAOS)
    kw = dict(l=L, tol=TOL, maxit=400, max_restarts=120)

    ungov = pipelined_cg.solve(cops, b, **kw)
    ungov_rel = _true_rel(op, b, ungov.x)

    gov = pipelined_cg.solve(cops, b, recurrence="stable",
                             governor=GovernorConfig(),
                             telemetry_cap=TEL_CAP, **kw)
    gov_rel = _true_rel(op, b, gov.x)
    d = diagnose(gov)

    # Governor action counts straight from the telemetry ring (§16):
    # the ring's action column is the exported audit trail, so the bench
    # counts what an operator's dashboard would see.
    cols = TelemetrySlab(cap=TEL_CAP, l=L).unpack(np.asarray(gov.telemetry))
    written = np.asarray(cols["iter"]) >= 0
    act = np.asarray(cols["action"])[written]
    return {
        "stability_l": L,
        "stability_tol": TOL,
        "stability_chaos_seed": CHAOS.seed,
        "stability_chaos_payload_rel_amp": CHAOS.payload_rel_amp,
        "stability_ungoverned_true_rel": ungov_rel,
        "stability_governed_true_rel": gov_rel,
        "stability_ungoverned_stagnated": int(not bool(ungov.converged)
                                              and ungov_rel > TOL),
        "stability_governed_recovered": int(d["converged"]
                                            and gov_rel < TOL),
        "stability_recovery_ratio": ungov_rel / gov_rel,
        "stability_governed_iters": d["iters"],
        "stability_ungoverned_iters": int(ungov.iters),
        "stability_governor_replacements": d["replacements"],
        "stability_gap_replacements":
            int((act == gov_model.ACTION_GAP_REPLACE).sum()),
        "stability_patience_replacements":
            int((act == gov_model.ACTION_PATIENCE_REPLACE).sum()),
    }


def ladder_rows() -> dict:
    """Catastrophic corruption: the demotion ladder walks 4 -> 2 -> 1
    and raises the typed error — proven, not assumed."""
    op, prec, _ops, b = _problem()
    be = get_backend("local")
    try:
        governed_solve(be, op, b, l=L, prec=prec,
                       ops_transform=lambda o: chaos_ops(o, CATASTROPHIC),
                       tol=1e-6, maxit=400, max_restarts=60)
    except StagnationError as e:
        tried = [a["l"] for a in e.diagnosis["attempts"]]
        return {
            "stability_ladder_depths_tried": len(tried),
            "stability_ladder_final_l": tried[-1],
            "stability_ladder_typed_error": 1,
        }
    return {"stability_ladder_depths_tried": 0,
            "stability_ladder_final_l": -1,
            "stability_ladder_typed_error": 0}


def hlo_rows() -> dict:
    """The governed compiled schedule on the 8-device mesh: exactly one
    reduction start per iteration, fused psum and staged ladder alike,
    zero staged dot-block all-reduces."""
    op = Stencil2D5(32, 24)
    from repro.core.chebyshev import shifts_for_operator

    sig = shifts_for_operator(op, L)
    bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
    gov = GovernorConfig()

    be = get_backend("shard_map", n_shards=8)
    rep = plcg_overlap_report(be, op, bspec, l=L, window=L + 2, sigmas=sig,
                              recurrence="stable", governor=gov)
    be_s = get_backend("shard_map", n_shards=8, reduction="staged")
    rep_s = plcg_overlap_report(be_s, op, bspec, l=L, window=L + 2,
                                sigmas=sig, recurrence="stable",
                                governor=gov)
    return {
        "stability_reduction_starts_per_iter_max":
            max(rep.starts_per_window.values()),
        "stability_in_flight_min": rep.max_in_flight,
        "stability_staged_starts_per_iter_max":
            max(rep_s.staged_starts_per_window.values()),
        "stability_staged_allreduces": rep_s.n_collectives,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=str, default="BENCH_stability.json")
    args = ap.parse_args(argv)

    payload = {}
    payload.update(recovery_rows())
    payload.update(ladder_rows())
    payload.update(hlo_rows())
    for k, v in payload.items():
        print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
