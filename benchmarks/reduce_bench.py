"""Staged ring-reduction benchmark: ladder structure and wire accounting
on the simulated 8-device mesh (DESIGN.md §14).  Emits
``BENCH_reduce.json``; CI gates the STRUCTURAL metrics
(``scripts/check_bench.py``), all deterministic given shapes — container
timing noise cannot move any of them:

* ``staged_dotblock_allreduces``   — all-reduce count in the compiled
                                     staged p(l)-CG trace window.  MUST
                                     be 0: the dot block is tagged
                                     ppermute hops, nothing else (the
                                     tentpole's HLO acceptance).
* ``hops_per_window_min``          — ladder hops in the thinnest traced
                                     iteration window; >= l means the
                                     hop-per-iteration schedule really
                                     spreads the reduction across the
                                     in-flight window.
* ``staged_starts_per_window_max`` — hop-0 permutes per window (the
                                     logical-reduction count); 1 means
                                     one handle enters the wire per
                                     iteration, batching widens the
                                     payload, never the handle count.
* ``fp32_hop_payload_over_monolithic`` — per-hop wire bytes of the fp32
                                     payload ladder vs the fp64
                                     monolithic reduction payload: the
                                     mixed-precision option halves the
                                     latency-bound message size, gated
                                     at <= 0.55x.
* parity columns                   — staged-vs-monolithic residual
                                     histories on a stencil solve
                                     (bitwise: max |dh| == 0.0) and the
                                     fp32-payload bounded tail.

Honest accounting rides alongside: ``staged_total_wire_bytes`` is the
(P-1)-hop ring allgather's TOTAL per-shard traffic, which exceeds a
bandwidth-optimal tree all-reduce's — the ladder targets the
latency-bound small-payload regime (K = 2l+1 entries), where per-hop
message size and hop count dominate and aggregate bytes do not.

Measured primitive wall clocks (``measured_hop_time_s`` /
``measured_allreduce_time_s``) ride along too: one ring hop vs one
monolithic psum of the same K-entry payload on the live mesh.  On the
opt-in compiled lane (``--kernel-mode compiled``, accelerator required —
CPU containers get a machine-readable skip payload instead, see
``benchmarks.lane``) these time the real interconnect and feed
``launch.autotune.recalibrate_profile`` (alpha / alpha_hop).

    PYTHONPATH=src python -m benchmarks.reduce_bench [--l 2] [--out PATH]
        [--kernel-mode auto|compiled]
"""

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

jax.config.update("jax_enable_x64", True)

from benchmarks.lane import (  # noqa: E402
    compiled_out,
    resolve_kernel_mode,
    write_payload,
)
from repro.core.chebyshev import shifts_for_operator  # noqa: E402
from repro.linalg import Stencil2D5  # noqa: E402
from repro.parallel import get_backend  # noqa: E402
from repro.parallel.distributed import (  # noqa: E402
    make_solver_mesh,
    shard_map_compat,
)
from repro.parallel.reduction import (  # noqa: E402
    hop_payload_bytes,
    reduction_wire_bytes,
)
from repro.utils.trace import plcg_overlap_report  # noqa: E402


def _time_best(fn, repeats=7):
    jax.block_until_ready(fn())              # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def measured_collective_times(n_dev: int, l: int) -> dict:
    """Wall clock of the two reduction primitives on THIS mesh: one ring
    hop (``lax.ppermute`` of the K-entry dot-block payload — the ladder's
    unit cost, ``timing_model.ring_hop_time``) and one monolithic
    ``lax.psum`` of the same payload.  These feed
    ``launch.autotune.recalibrate_profile`` (alpha_hop / alpha): on a
    real accelerator mesh they time the interconnect; on the simulated
    CPU mesh they time XLA's intra-process collectives — the
    ``collective_timing_basis`` key says which one a reader is holding.
    """
    mesh = make_solver_mesh(n_dev)
    k = 2 * l + 1
    x = jnp.asarray(np.arange(n_dev * k, dtype=np.float64))
    ring = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    hop = jax.jit(shard_map_compat(
        lambda v: lax.ppermute(v, "shards", ring), mesh=mesh,
        in_specs=P("shards"), out_specs=P("shards")))
    allred = jax.jit(shard_map_compat(
        lambda v: lax.psum(v, "shards"), mesh=mesh,
        in_specs=P("shards"), out_specs=P()))
    return {
        "measured_hop_time_s": _time_best(lambda: hop(x)),
        "measured_allreduce_time_s": _time_best(lambda: allred(x)),
        "collective_timing_basis": (
            "accelerator interconnect"
            if jax.default_backend() in ("tpu", "gpu")
            else "XLA CPU intra-process collectives (simulated mesh)"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--ny", type=int, default=24)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--kernel-mode", choices=("auto", "compiled"),
                    default="auto",
                    help="'compiled' demands a real accelerator mesh "
                         "and writes a machine-readable skip payload "
                         "to --out when there is none (benchmarks.lane)")
    args = ap.parse_args()

    out = compiled_out(args.kernel_mode, args.out, "BENCH_reduce.json")
    mode, skip = resolve_kernel_mode(args.kernel_mode)
    if skip is not None:
        write_payload(out, skip)
        return

    n_dev = len(jax.devices())
    op = Stencil2D5(args.nx, args.ny)
    l = args.l
    sig = shifts_for_operator(op, l)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))
    bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)

    be_staged = get_backend("shard_map", n_shards=n_dev, reduction="staged",
                            reduction_stages=args.stages)
    be_mono = get_backend("shard_map", n_shards=n_dev)

    # --- traced schedule structure (compiled HLO, deterministic) ---------
    rep = plcg_overlap_report(be_staged, op, bspec, l=l, window=l + 2,
                              sigmas=sig)
    hops_min = min(rep.reduce_hops_per_window.values())
    starts_max = max(rep.staged_starts_per_window.values())

    # --- solve parity (bitwise on stencils; deterministic) ---------------
    kw = dict(method="plcg", l=l, sigmas=sig, tol=1e-10, maxit=2000)
    r_mono = be_mono.solve(op, b, **kw)
    r_staged = be_staged.solve(op, b, **kw)
    hm = np.asarray(r_mono.res_history)
    hs = np.asarray(r_staged.res_history)
    parity_max_abs = float(np.abs(hm - hs).max())

    be_fp32 = get_backend("shard_map", n_shards=n_dev, reduction="staged",
                          reduction_stages=args.stages,
                          reduction_dtype=jnp.float32)
    r_fp32 = be_fp32.solve(op, b, **kw)
    h32 = np.asarray(r_fp32.res_history)
    m = (hm >= 0) & (h32 >= 0)
    fp32_tail = float((np.abs(hm[m] - h32[m]) / float(r_mono.norm0)).max())

    # --- wire accounting (analytic, shape-determined) --------------------
    mono_payload = hop_payload_bytes(l, dsize=8)        # (2l+1) f64 entries
    hop64 = hop_payload_bytes(l, dsize=8)
    hop32 = hop_payload_bytes(l, dsize=4)

    payload = {
        "mesh_devices": n_dev,
        "kernel_mode": mode,
        "jax_backend": jax.default_backend(),
        "problem": {"n": op.n, "nx": args.nx, "ny": args.ny, "l": l,
                    "stages": args.stages},
        # structural gates (deterministic):
        "staged_dotblock_allreduces": rep.n_collectives,
        "hops_per_window_min": hops_min,
        "staged_starts_per_window_max": starts_max,
        "max_in_flight": rep.max_in_flight,
        "hops_in_flight": rep.hops_in_flight,
        "halos_in_flight": rep.halos_in_flight,
        # wire bytes (analytic; the fp32 ratio is gated <= 0.55):
        "monolithic_payload_bytes_fp64": mono_payload,
        "staged_hop_payload_bytes_fp64": hop64,
        "staged_hop_payload_bytes_fp32": hop32,
        "fp32_hop_payload_over_monolithic": hop32 / mono_payload,
        "staged_total_wire_bytes_fp64": reduction_wire_bytes(n_dev, l,
                                                             dsize=8),
        "staged_total_wire_bytes_fp32": reduction_wire_bytes(n_dev, l,
                                                             dsize=4),
        # parity (deterministic given seed/mesh):
        "staged_vs_monolithic_hist_max_abs": parity_max_abs,
        "staged_bitwise_parity": parity_max_abs == 0.0,
        "fp32_payload_tail_rel": fp32_tail,
        "fp32_converged": bool(r_fp32.converged),
        "iters_monolithic": int(r_mono.iters),
        "iters_staged": int(r_staged.iters),
        "iters_fp32": int(r_fp32.iters),
    }
    # Measured primitive wall clocks (informational here, the
    # recalibration inputs on the compiled lane):
    payload.update(measured_collective_times(n_dev, l))
    write_payload(out, payload)


if __name__ == "__main__":
    main()
