"""Bench-lane resolution: the ``kernel_mode`` honesty convention shared
by iter/spmv/reduce benches (DESIGN.md §13/§17).

Every bench JSON carries ``kernel_mode``: ``"compiled"`` when the Pallas
kernels ran as real Mosaic/Triton compilations on an accelerator,
``"interpret"`` when they ran under the Pallas interpreter (CPU CI) —
a correctness vehicle whose wall clocks time the interpreter, not the
kernel.  The default lane (``--kernel-mode auto``) takes whatever the
container offers and labels it; the opt-in accelerator lane
(``--kernel-mode compiled``, CI job ``compiled-bench``) DEMANDS the real
thing and, when the container has no accelerator, refuses loudly but
machine-readably: the bench writes a skip payload (``skipped: true`` +
reason) to its ``--out`` and exits 0, so the CI lane stays green on
CPU-only runners while making it impossible to mistake a skipped lane
for measured compiled numbers (``scripts/check_bench.py --skip-ok``
prints the reason; ``launch.autotune.recalibrate_profile`` rejects skip
payloads outright).
"""

from __future__ import annotations

import json

import jax

ACCEL_BACKENDS = ("tpu", "gpu")


def resolve_kernel_mode(requested: str) -> tuple[str, dict | None]:
    """Resolve a ``--kernel-mode`` request against the live jax backend.

    Returns ``(mode, skip_payload)``: ``mode`` is the kernel mode that
    can actually run here (``"compiled"`` iff an accelerator backend is
    present), and ``skip_payload`` is None unless ``requested ==
    "compiled"`` on a CPU-only container — then it is the machine-
    readable refusal the bench must write instead of numbers.
    """
    backend = jax.default_backend()
    accel = backend in ACCEL_BACKENDS
    if requested not in ("auto", "compiled"):
        raise ValueError(f"unknown kernel mode {requested!r}")
    if requested == "compiled" and not accel:
        return "interpret", {
            "skipped": True,
            "requested_kernel_mode": "compiled",
            "jax_backend": backend,
            "reason": (
                f"kernel_mode='compiled' requested but the jax backend "
                f"is '{backend}' — no TPU/GPU in this container, so the "
                f"Pallas kernels can only run under the interpreter, "
                f"whose wall clocks are not kernel numbers"),
        }
    return ("compiled" if accel else "interpret"), None


def compiled_out(requested: str, out: str | None, default: str) -> str:
    """Default output path per lane: ``BENCH_x.json`` for the auto lane,
    ``BENCH_x_compiled.json`` for the opt-in compiled lane — the two
    lanes must never overwrite each other's committed files."""
    if out is not None:
        return out
    if requested == "compiled":
        root, ext = default.rsplit(".", 1)
        return f"{root}_compiled.{ext}"
    return default


def write_payload(out: str, payload: dict) -> None:
    for k, v in payload.items():
        print(f"{k}: {v}")
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {out}")
