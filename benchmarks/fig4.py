"""Paper Fig. 4 + §4.2: schedule visualisation and jitter absorption.

Two scenarios from the paper:
  left : glred ~ spmv       -> l = 1 already hides everything
  right: glred >> spmv      -> staggered reductions make l >= 2 pay

Plus the robustness claim: with log-normal glred jitter, deeper pipelines
absorb run-time variance (mean iteration time grows slower with jitter).
"""

from __future__ import annotations

import numpy as np

from benchmarks.schedule_sim import iteration_time

BAL = {"spmv": 100e-6, "axpy1": 2e-6, "glred": 100e-6}    # balanced (left)
COMM = {"spmv": 10e-6, "axpy1": 1e-6, "glred": 300e-6}    # comm-bound (right)


def ascii_schedule(l, kernels, n=4):
    """Textual Fig. 4: per-iteration [issue ... wait] spans of reductions."""
    t_body = kernels["spmv"] + (2 * l + 3) * kernels["axpy1"]
    lines = []
    for i in range(n):
        issue = (i + 1) * t_body
        use = (i + l) * t_body
        lines.append(
            f"  iter {i}: body [{i*t_body*1e6:7.1f},{issue*1e6:7.1f}]us  "
            f"glred req({i}) in flight until iter {i+l} "
            f"(~{(use-issue)*1e6:.1f}us window)")
    return "\n".join(lines)


def run(verbose=True):
    if verbose:
        print("== Fig. 4 schedule scenarios ==")
    res = {}
    for name, k in (("balanced", BAL), ("comm-bound", COMM)):
        ts = {}
        for m, l in [("cg", 0), ("plcg", 1), ("plcg", 2), ("plcg", 3)]:
            ts[(m, l)] = iteration_time(m, l, k, jitter=0.0)
        res[name] = ts
        if verbose:
            print(f"-- {name}: glred/spmv = {k['glred']/k['spmv']:.1f}")
            for (m, l), t in ts.items():
                nm = "CG" if m == "cg" else f"p({l})-CG"
                print(f"   {nm:>8s}: {t*1e6:7.1f} us/iter")
    # left: l>=2 adds <10% over l=1; right: l=2 gives >25% over l=1
    left_ok = res["balanced"][("plcg", 2)] > 0.9 * res["balanced"][("plcg", 1)]
    right_ok = res["comm-bound"][("plcg", 2)] < 0.75 * res["comm-bound"][("plcg", 1)]

    if verbose:
        print("-- staggering window (comm-bound, l=2):")
        print(ascii_schedule(2, COMM))
        print("== jitter absorption (comm-bound) ==")
    jit_ok = True
    for jitter in (0.0, 0.25, 0.5, 1.0):
        t1 = iteration_time("plcg", 1, COMM, jitter=jitter, n_iters=2000)
        t3 = iteration_time("plcg", 3, COMM, jitter=jitter, n_iters=2000)
        if verbose:
            print(f"   jitter {jitter:4.2f}: p(1) {t1*1e6:7.1f} us | "
                  f"p(3) {t3*1e6:7.1f} us | ratio {t1/t3:.2f}")
        if jitter >= 0.5:
            jit_ok &= t3 < t1
    assert left_ok and right_ok and jit_ok, "Fig. 4 claims failed"
    return res


if __name__ == "__main__":
    run()
