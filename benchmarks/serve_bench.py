"""Serving-layer benchmark: slab throughput vs sequential solves,
request latency percentiles through the full service loop, and an
open-loop traffic replay (DESIGN.md §11/§15).  Emits
``BENCH_serve.json`` for the perf trajectory.

Three measurements on a simulated 8-device mesh (host platform devices):

* **throughput** — the same ``s`` right-hand sides solved (a) one by one
  through a compiled single-RHS solver and (b) as one slab through the
  batched solver.  The slab amortizes every per-iteration global
  reduction over s columns (one (2l+1, s) allreduce instead of s
  (2l+1,)-allreduces), so slab throughput must be >= 3x sequential on a
  collective-latency-dominated mesh (the PR acceptance bar).
* **latency** — a burst of requests streamed through ``SolverService``
  (pack -> chunk -> retire), reporting p50/p99 retirement latency.
* **open-loop replay** — a seeded Poisson trace with a heavy-tail
  tolerance mix replayed on the VIRTUAL clock through the multi-slab
  scheduler, continuous injection vs a drain-to-empty baseline.  Every
  ``replay_*`` metric is exact deterministic arithmetic (same seed ->
  same numbers on any machine), so CI gates goodput, p99 and
  slot-utilization with ZERO timing tolerance, alongside the HLO-level
  ceiling ``replay_reduction_starts_per_iter_max`` (one reduction
  handle per iteration per slab, tracer-asserted).

    PYTHONPATH=src python -m benchmarks.serve_bench [--s 8] [--out PATH]
"""

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_enable_x64", True)

from repro.core.chebyshev import shifts_for_operator  # noqa: E402
from repro.core.types import TelemetrySlab  # noqa: E402
from repro.launch.autotune import fused_iteration_bytes  # noqa: E402
from repro.linalg import Stencil2D5  # noqa: E402
from repro.obs import replay_timeline, solve_timeline  # noqa: E402
from repro.parallel import get_backend  # noqa: E402
from repro.serve import (AdmissionPolicy, SolverService,  # noqa: E402
                         TrafficClass, VirtualClock, poisson_trace, replay)
from repro.utils.trace import batched_plcg_overlap_report  # noqa: E402


def time_best(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def replay_section(be, op, args):
    """Open-loop replay on the virtual clock: deterministic goodput /
    p99 / slot-utilization numbers (DESIGN.md §15)."""
    classes = [
        # Heavy-tail cost mix through the tolerance (a slab-key
        # ingredient): mostly cheap loose-tol solves, a tail of
        # expensive tight-tol ones -> two live slab keys.
        TrafficClass("bench", op.n, weight=4.0, tol=1e-6, deadline_s=1.0),
        TrafficClass("bench", op.n, weight=1.0, tol=1e-10, deadline_s=4.0),
    ]
    trace = poisson_trace(classes, rate_per_s=args.replay_rate,
                          n_requests=args.replay_requests,
                          seed=args.replay_seed)

    def run(continuous, telemetry_cap=0):
        # chunk_iters=8: retirement scans every 8 iterations keep the
        # partial-chunk tail waste (a column converging mid-chunk stops
        # contributing) small relative to ~30-60-iteration solves.
        svc = SolverService(be, s=args.s, method="plcg", l=args.l,
                            chunk_iters=8, maxit=600,
                            clock=VirtualClock(),
                            admission=AdmissionPolicy(max_pending=8 * args.s),
                            max_replicas=2, replicate_watermark=1.0,
                            continuous=continuous,
                            telemetry_cap=telemetry_cap)
        svc.register_operator("bench", op)
        return svc, replay(svc, trace, iter_time_s=1e-4,
                           tick_overhead_s=1e-4)

    svc_c, rep_c = run(continuous=True)
    _svc_d, rep_d = run(continuous=False)
    assert rep_c.n_converged == rep_c.n_retired, "replay solves must converge"

    # Instrumented replay (DESIGN.md §16): every slab carries the
    # on-device telemetry ring.  In deterministic virtual time the
    # instrumented makespan must stay within the CI overhead gate of the
    # plain one (the ring adds no collectives and no host syncs — the
    # schedules tick identically).
    cap = 64
    svc_t, rep_t = run(continuous=True, telemetry_cap=cap)
    assert rep_t.n_retired == rep_c.n_retired

    # HLO invariant, tracer-asserted on the compiled slab schedule: ONE
    # reduction handle per iteration carrying the whole (2l+1, s)
    # payload — the amortization the whole serving layer exists for.
    # Asserted on BOTH the plain and the instrumented schedule: the
    # ring must not add a handle.
    Bspec = jax.ShapeDtypeStruct((op.n, args.s), jnp.float64)
    sig = shifts_for_operator(op, args.l)
    hlo = batched_plcg_overlap_report(
        be, op, Bspec, l=args.l, window=args.l + 3, sigmas=sig)
    starts_max = max(hlo.starts_per_window.values())
    hlo_t = batched_plcg_overlap_report(
        be, op, Bspec, l=args.l, window=args.l + 3, sigmas=sig,
        telemetry_cap=cap)
    starts_max_t = max(hlo_t.starts_per_window.values())

    # Telemetry byte accounting: one ring row per iteration vs the
    # modeled HBM traffic of one fused iteration (per column).
    tel_bytes = TelemetrySlab(cap=cap, l=args.l).bytes_per_iter()
    iter_bytes = fused_iteration_bytes(op.n, args.l)

    metrics = rep_c.metrics()
    metrics["replay_slot_utilization_drain"] = rep_d.slot_utilization
    metrics["replay_reduction_starts_per_iter_max"] = starts_max
    metrics["replay_makespan_instrumented_s"] = rep_t.makespan_s
    metrics["instrumented_reduction_starts_per_iter_max"] = starts_max_t
    metrics["telemetry_bytes_per_iter"] = tel_bytes
    metrics["telemetry_iteration_bytes_ratio"] = tel_bytes / iter_bytes
    st = svc_c.stats()
    metrics["replay_workers"] = st["workers"]
    metrics["replay_stolen"] = st["stolen"]
    print(f"replay     : {rep_c.n_arrivals} arrivals @ "
          f"{rep_c.offered_per_s:.0f}/s (virtual), goodput "
          f"{rep_c.goodput_per_s:.1f}/s, p50 {rep_c.latency_p50_s * 1e3:.1f} "
          f"ms / p99 {rep_c.latency_p99_s * 1e3:.1f} ms, shed "
          f"{rep_c.n_shed}, rejected {rep_c.n_rejected}")
    print(f"             slot-utilization {rep_c.slot_utilization:.3f} "
          f"continuous vs {rep_d.slot_utilization:.3f} drain-to-empty; "
          f"{st['workers']} workers, {st['stolen']} steals; "
          f"reduction starts/iter (HLO max) = {starts_max}")
    print(f"instrumented: makespan {rep_t.makespan_s:.4f} s vs "
          f"{rep_c.makespan_s:.4f} s plain (virtual), starts/iter "
          f"{starts_max_t}, ring row {tel_bytes} B/iter "
          f"({100 * tel_bytes / iter_bytes:.3f}% of iteration HBM)")

    # Timeline artifact: the instrumented replay as catapult JSON.
    out_dir = os.path.dirname(os.path.abspath(args.out))
    tl_path = os.path.join(out_dir, "TIMELINE_replay.json")
    replay_timeline(svc_t, rep_t).save(tl_path)
    print(f"wrote {tl_path}")
    return metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--s", type=int, default=8, help="slab width")
    ap.add_argument("--l", type=int, default=2, help="pipeline depth")
    # Default problem size keeps the per-iteration local work small
    # relative to the 8-way collective — the communication-bound regime
    # of the paper's Fig. 3, where amortization has something to amortize.
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--ny", type=int, default=24)
    ap.add_argument("--maxit", type=int, default=120)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replay-requests", type=int, default=128)
    # ~2x the sustainable service rate: the open-loop trace keeps a
    # standing backlog (slot-utilization >= 0.8) and exercises the
    # admission ceiling, while deadlines stay comfortably met.
    ap.add_argument("--replay-rate", type=float, default=1600.0,
                    help="open-loop arrival rate (virtual req/s)")
    ap.add_argument("--replay-seed", type=int, default=0)
    ap.add_argument("--out", type=str, default="BENCH_serve.json")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    op = Stencil2D5(args.nx, args.ny)
    sig = shifts_for_operator(op, args.l)
    be = get_backend("shard_map", n_shards=n_dev)
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((op.n, args.s)))
    # Fixed iteration budget (tol=0): throughput compares identical work.
    kw = dict(method="plcg", l=args.l, sigmas=sig, tol=0.0, maxit=args.maxit)

    print(f"mesh: {n_dev} device(s); problem: {args.nx}x{args.ny} "
          f"Laplacian (n={op.n}); p({args.l})-CG, {args.maxit} iters/solve")

    # --- sequential baseline: one compiled single-RHS solver, s calls ----
    solver1 = be.make_solver(op, **kw)
    jax.block_until_ready(solver1(B[:, 0]).x)        # compile + warmup
    t_seq = time_best(lambda: [
        jax.block_until_ready(solver1(B[:, j]).x) for j in range(args.s)])

    # --- batched slab: one compiled s-wide solver, one call --------------
    solver_s = be.make_batched_solver(op, **kw)
    jax.block_until_ready(solver_s(B).x)             # compile + warmup
    t_slab = time_best(lambda: jax.block_until_ready(solver_s(B).x))

    seq_sps = args.s / t_seq
    slab_sps = args.s / t_slab
    speedup = t_seq / t_slab
    print(f"sequential : {t_seq * 1e3:8.1f} ms for {args.s} solves "
          f"({seq_sps:7.2f} solves/s)")
    print(f"slab s={args.s:<3d}: {t_slab * 1e3:8.1f} ms for {args.s} solves "
          f"({slab_sps:7.2f} solves/s)  -> {speedup:.2f}x")

    # --- service loop latency percentiles --------------------------------
    svc = SolverService(be, s=args.s, method="plcg", l=args.l,
                        chunk_iters=24, maxit=600)
    svc.register_operator("bench", op)
    # Warm the slab program (compile outside the timed stream).
    warm = svc.submit("bench", np.asarray(B[:, 0]), tol=1e-8)
    svc.drain()
    svc.pop_result(warm)
    svc.reset_stats()
    for i in range(args.requests):
        svc.submit("bench", rng.standard_normal(op.n), tol=1e-8)
    t0 = time.perf_counter()
    results = svc.drain()
    service_wall = time.perf_counter() - t0
    st = svc.stats()
    assert all(r.converged for r in results.values())
    print(f"service    : {len(results)} requests in {service_wall:.2f} s "
          f"({len(results) / service_wall:.2f} solves/s), latency "
          f"p50 {st['latency_p50_s'] * 1e3:.1f} ms / "
          f"p99 {st['latency_p99_s'] * 1e3:.1f} ms")

    payload = {
        "mesh_devices": n_dev,
        "problem": {"nx": args.nx, "ny": args.ny, "n": op.n},
        "method": "plcg", "l": args.l, "s": args.s, "maxit": args.maxit,
        "sequential_s_per_solve": t_seq / args.s,
        "slab_s_per_solve": t_slab / args.s,
        "sequential_solves_per_sec": seq_sps,
        "slab_solves_per_sec": slab_sps,
        "slab_speedup_vs_sequential": speedup,
        "service_requests": len(results),
        "service_solves_per_sec": len(results) / service_wall,
        "latency_p50_s": st["latency_p50_s"],
        "latency_p99_s": st["latency_p99_s"],
    }
    payload.update(replay_section(be, op, args))

    # Scaling-study timeline artifact (DESIGN.md §16): the l=args.l
    # STAGED solve's overlap figure — reduction windows over vector/
    # halo/hop work, plus measured phases and the telemetry track.
    be_staged = get_backend("shard_map", n_shards=n_dev, reduction="staged")
    tl, _res = solve_timeline(be_staged, op, B[:, 0], l=args.l, sigmas=sig,
                              tol=1e-10, maxit=args.maxit,
                              telemetry_cap=128)
    tl_path = os.path.join(os.path.dirname(os.path.abspath(args.out)),
                           "TIMELINE_staged_solve.json")
    tl.save(tl_path)
    print(f"wrote {tl_path}")

    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
