"""Serving-layer benchmark: slab throughput vs sequential solves, and
request latency percentiles through the full service loop
(DESIGN.md §11).  Emits ``BENCH_serve.json`` for the perf trajectory.

Two measurements on a simulated 8-device mesh (host platform devices):

* **throughput** — the same ``s`` right-hand sides solved (a) one by one
  through a compiled single-RHS solver and (b) as one slab through the
  batched solver.  The slab amortizes every per-iteration global
  reduction over s columns (one (2l+1, s) allreduce instead of s
  (2l+1,)-allreduces), so slab throughput must be >= 3x sequential on a
  collective-latency-dominated mesh (the PR acceptance bar).
* **latency** — a burst of requests streamed through ``SolverService``
  (pack -> chunk -> retire), reporting p50/p99 retirement latency.

    PYTHONPATH=src python -m benchmarks.serve_bench [--s 8] [--out PATH]
"""

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_enable_x64", True)

from repro.core.chebyshev import shifts_for_operator  # noqa: E402
from repro.linalg import Stencil2D5  # noqa: E402
from repro.parallel import get_backend  # noqa: E402
from repro.serve import SolverService  # noqa: E402


def time_best(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--s", type=int, default=8, help="slab width")
    ap.add_argument("--l", type=int, default=2, help="pipeline depth")
    # Default problem size keeps the per-iteration local work small
    # relative to the 8-way collective — the communication-bound regime
    # of the paper's Fig. 3, where amortization has something to amortize.
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--ny", type=int, default=24)
    ap.add_argument("--maxit", type=int, default=120)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--out", type=str, default="BENCH_serve.json")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    op = Stencil2D5(args.nx, args.ny)
    sig = shifts_for_operator(op, args.l)
    be = get_backend("shard_map", n_shards=n_dev)
    rng = np.random.default_rng(0)
    B = jnp.asarray(rng.standard_normal((op.n, args.s)))
    # Fixed iteration budget (tol=0): throughput compares identical work.
    kw = dict(method="plcg", l=args.l, sigmas=sig, tol=0.0, maxit=args.maxit)

    print(f"mesh: {n_dev} device(s); problem: {args.nx}x{args.ny} "
          f"Laplacian (n={op.n}); p({args.l})-CG, {args.maxit} iters/solve")

    # --- sequential baseline: one compiled single-RHS solver, s calls ----
    solver1 = be.make_solver(op, **kw)
    jax.block_until_ready(solver1(B[:, 0]).x)        # compile + warmup
    t_seq = time_best(lambda: [
        jax.block_until_ready(solver1(B[:, j]).x) for j in range(args.s)])

    # --- batched slab: one compiled s-wide solver, one call --------------
    solver_s = be.make_batched_solver(op, **kw)
    jax.block_until_ready(solver_s(B).x)             # compile + warmup
    t_slab = time_best(lambda: jax.block_until_ready(solver_s(B).x))

    seq_sps = args.s / t_seq
    slab_sps = args.s / t_slab
    speedup = t_seq / t_slab
    print(f"sequential : {t_seq * 1e3:8.1f} ms for {args.s} solves "
          f"({seq_sps:7.2f} solves/s)")
    print(f"slab s={args.s:<3d}: {t_slab * 1e3:8.1f} ms for {args.s} solves "
          f"({slab_sps:7.2f} solves/s)  -> {speedup:.2f}x")

    # --- service loop latency percentiles --------------------------------
    svc = SolverService(be, s=args.s, method="plcg", l=args.l,
                        chunk_iters=24, maxit=600)
    svc.register_operator("bench", op)
    # Warm the slab program (compile outside the timed stream).
    warm = svc.submit("bench", np.asarray(B[:, 0]), tol=1e-8)
    svc.drain()
    svc.pop_result(warm)
    svc.reset_stats()
    for i in range(args.requests):
        svc.submit("bench", rng.standard_normal(op.n), tol=1e-8)
    t0 = time.perf_counter()
    results = svc.drain()
    service_wall = time.perf_counter() - t0
    st = svc.stats()
    assert all(r.converged for r in results.values())
    print(f"service    : {len(results)} requests in {service_wall:.2f} s "
          f"({len(results) / service_wall:.2f} solves/s), latency "
          f"p50 {st['latency_p50_s'] * 1e3:.1f} ms / "
          f"p99 {st['latency_p99_s'] * 1e3:.1f} ms")

    payload = {
        "mesh_devices": n_dev,
        "problem": {"nx": args.nx, "ny": args.ny, "n": op.n},
        "method": "plcg", "l": args.l, "s": args.s, "maxit": args.maxit,
        "sequential_s_per_solve": t_seq / args.s,
        "slab_s_per_solve": t_slab / args.s,
        "sequential_solves_per_sec": seq_sps,
        "slab_solves_per_sec": slab_sps,
        "slab_speedup_vs_sequential": speedup,
        "service_requests": len(results),
        "service_solves_per_sec": len(results) / service_wall,
        "latency_p50_s": st["latency_p50_s"],
        "latency_p99_s": st["latency_p99_s"],
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
