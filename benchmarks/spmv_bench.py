"""Unstructured SpMV benchmark: ELL kernel throughput + partition-plan
structure on a random FEM mesh (DESIGN.md §12/§13).  Emits
``BENCH_spmv.json`` for the perf trajectory; CI gates the STRUCTURAL
metrics (``scripts/check_bench.py``), which a partitioner/ordering
regression moves and container timing noise cannot:

* ``ell_occupancy``        — useful fraction of stored ELL slots in the
                             production SLICED-ELL layout (degree-sorted
                             row buckets, per-slice padding;
                             ``sparse.sliced_ell_reorder``).  The
                             uniform padded-row number rides along as
                             ``ell_occupancy_padded``.
* ``plan_halo_fraction``   — halo rows shipped per shard / rows owned
                             (RCM quality: a worse ordering inflates the
                             send sets).
* ``plan_hops``            — neighbour-hop count (1 == the structured-
                             stencil regime; more means the ordering
                             failed to localize the band).

The Pallas kernel is timed COMPILED when a real accelerator backend is
present (``kernel_mode: "compiled"``); on CPU CI it falls back to
interpret mode (``"interpret"`` — a correctness vehicle, not a speed
number).  Modeled HBM bytes per SpMV ride alongside the wall clocks so
the trajectory has a machine-independent roofline column.

    PYTHONPATH=src python -m benchmarks.spmv_bench [--n 4096] [--out PATH]
"""

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_enable_x64", True)

from jax.sharding import PartitionSpec as P  # noqa: E402

from benchmarks.lane import (  # noqa: E402
    compiled_out,
    resolve_kernel_mode,
    write_payload,
)
from repro.kernels import ops as kops  # noqa: E402
from repro.linalg import plan_for, random_fem_mesh  # noqa: E402
from repro.linalg.sparse import sliced_ell_reorder  # noqa: E402
from repro.parallel.distributed import (  # noqa: E402
    make_solver_mesh,
    partitioned_solver_ops,
    shard_map_compat,
)


def time_best(fn, repeats=5):
    fn()                                     # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def spmv_hbm_bytes(nnz: int, n: int, occupancy: float = 1.0,
                   dsize: int = 8) -> int:
    """Modeled HBM traffic of one ELL SpMV: every STORED slot streams a
    value (dsize) + column index (4B); x is gathered (~n reads) and y
    written once.  ``occupancy`` < 1 inflates the stored slots over nnz
    — the padding-waste term sliced ELL removes (DESIGN.md §13)."""
    slots = int(round(nnz / max(occupancy, 1e-9)))
    return slots * (dsize + 4) + 2 * n * dsize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096, help="mesh nodes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slice-rows", type=int, default=64)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--kernel-mode", choices=("auto", "compiled"),
                    default="auto",
                    help="'compiled' demands a real accelerator and "
                         "writes a machine-readable skip payload to "
                         "--out when there is none (benchmarks.lane)")
    args = ap.parse_args()

    out = compiled_out(args.kernel_mode, args.out, "BENCH_spmv.json")
    mode, skip = resolve_kernel_mode(args.kernel_mode)
    if skip is not None:
        write_payload(out, skip)
        return

    n_dev = len(jax.devices())
    op = random_fem_mesh(args.seed, args.n)
    # plan_for populates the memo partitioned_solver_ops reads below —
    # RCM + send-set construction runs once, not twice.
    plan = plan_for(op, n_dev)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(op.n))

    # --- single-device applies -------------------------------------------
    # x always passes as a real argument — a zero-arg jitted closure
    # would constant-fold the whole SpMV and time a cached fetch.
    apply_jnp = jax.jit(op.apply)
    t_jnp = time_best(lambda: apply_jnp(x))
    # Time the COMPILED kernel on a real backend; interpret on CPU CI.
    interpret = mode == "interpret"
    kern = jax.jit(lambda xx: kops.ell_spmv_apply(
        xx, op.cols, op.vals, interpret=interpret))
    t_kern = time_best(lambda: kern(x))

    # --- sliced ELL (degree-sorted buckets, per-slice padding) -----------
    sliced, sperm = sliced_ell_reorder(op, args.slice_rows)
    xs = x[jnp.asarray(sperm)]
    sliced_apply = jax.jit(sliced.apply)
    t_sliced = time_best(lambda: sliced_apply(xs))

    # --- distributed halo SpMV on the simulated mesh ---------------------
    mesh = make_solver_mesh(n_dev)
    arrays, build, _perm = partitioned_solver_ops(op, None, n_dev, "shards")
    arr_specs = jax.tree.map(lambda _: P("shards"), arrays)
    fn = shard_map_compat(
        lambda xl, loc: build(loc).apply_a(xl), mesh=mesh,
        in_specs=(P("shards"), arr_specs), out_specs=P("shards"))
    xp = x[jnp.asarray(plan.perm)]
    dist = jax.jit(fn)
    t_dist = time_best(lambda: dist(xp, arrays))

    nnz = op.nnz
    occ_padded = float(nnz / (op.n * op.w))
    occ_sliced = sliced.occupancy()
    payload = {
        "mesh_devices": n_dev,
        "problem": {"n": op.n, "nnz": nnz, "ell_width": op.w},
        # structural metrics (gated — deterministic given the seed):
        "ell_occupancy": occ_sliced,
        "ell_occupancy_padded": occ_padded,
        "sliced_padding_waste": sliced.padding_waste(),
        "sliced_rows_per_slice": args.slice_rows,
        "sliced_n_slices": len(sliced.slice_cols),
        "plan_halo_fraction": plan.halo_rows_fraction(),
        "plan_hops": plan.hops,
        "plan_bandwidth": plan.band,
        "plan_neighbor_bytes": plan.neighbor_bytes(),
        # modeled HBM traffic (machine-independent roofline column):
        "spmv_hbm_bytes_padded": spmv_hbm_bytes(nnz, op.n, occ_padded),
        "spmv_hbm_bytes_sliced": spmv_hbm_bytes(nnz, op.n, occ_sliced),
        # informational wall-clock (not gated — container noise):
        "kernel_mode": mode,
        "jax_backend": jax.default_backend(),
        "jnp_spmv_s": t_jnp,
        "kernel_spmv_s": t_kern,
        "sliced_spmv_s": t_sliced,
        "distributed_spmv_s": t_dist,
        "jnp_spmv_gnnz_per_s": nnz / t_jnp / 1e9,
    }
    write_payload(out, payload)


if __name__ == "__main__":
    main()
