"""Analytic per-kernel timing models for the CG benchmarks.

Two hardware profiles:

  * ``cori``  — Cori Phase-I-like (Haswell + Aries dragonfly, 16 ranks/node):
    used to REPRODUCE the paper's Figs. 2-4 regime (µs-scale software
    all-reduce latency growing ~log2(P), memory-bound SPMV).
  * ``v5e``   — TPU v5e pod (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI,
    hardware collectives): the adaptation target; per-hop ICI latency with
    mesh-diameter tree depth.

Kernel times for a stencil problem with N unknowns on P workers:
  t_spmv  = max(flops/peak, bytes/hbm_bw) + halo_bytes/link_bw + t_msg
  t_axpy  = vector stream bytes / hbm_bw            (perfectly parallel)
  t_glred = alpha * ceil(log2 P) + payload/link_bw  (latency dominated)

These are MODELS (this container cannot time a pod); every parameter is
explicit and the benchmarks print them alongside results.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HWProfile:
    name: str
    flop_rate: float        # per worker, FLOP/s (f64 for cori)
    mem_bw: float           # per worker, bytes/s
    link_bw: float          # network per worker, bytes/s
    alpha: float            # per-TREE-STAGE latency of the software
                            # all-reduce (s) — the monolithic glred term
    hops: str = "log2"      # tree depth model: log2 | mesh2d
    # Per-RING-HOP latency of one point-to-point neighbour message (s):
    # the staged ladder's unit cost (DESIGN.md §14).  A ring hop is a
    # bare nearest-neighbour send — no software tree stage, no
    # async-progress thread hand-off — so it is substantially cheaper
    # than ``alpha``; None falls back to ``alpha`` (pessimistic).
    alpha_hop: float | None = None

    @property
    def hop_latency(self) -> float:
        return self.alpha if self.alpha_hop is None else self.alpha_hop


CORI = HWProfile(
    name="cori-haswell",
    flop_rate=36.8e9,       # 2.3 GHz Haswell core * 16 flops/cycle (f64 AVX2)
    mem_bw=7.2e9,           # ~115 GB/s per node / 16 ranks
    link_bw=1.0e9,          # Aries per-rank effective
    alpha=10e-6,            # MPI software latency per tree stage incl. the
                            # async-progress/thread-safety overhead the
                            # paper itself flags as significant (§5)
    alpha_hop=2.0e-6,       # Aries nearest-neighbour put latency: no MPI
                            # software tree stage on the critical path
)

V5E = HWProfile(
    name="tpu-v5e",
    flop_rate=197e12 * 0.03,  # stencils are VPU/memory bound, not MXU: ~3%
    mem_bw=819e9,
    link_bw=50e9,
    alpha=1.0e-6,
    hops="mesh2d",
    alpha_hop=1.0e-6,         # ICI is already per-hop
)


def ring_hop_time(hw: HWProfile, payload: int) -> float:
    """Seconds for ONE staged-ladder hop: a point-to-point neighbour
    message carrying the full dot-block payload (DESIGN.md §14)."""
    return hw.hop_latency + payload / hw.link_bw


def tree_depth(hw: HWProfile, p: int) -> float:
    if hw.hops == "mesh2d":
        side = max(int(math.sqrt(p)), 1)
        return 2 * (side - 1) or 1
    return max(math.ceil(math.log2(max(p, 2))), 1)


def stencil_kernel_times(hw: HWProfile, n: int, p: int,
                         stencil_pts: int = 5, dsize: int = 8,
                         halo_elems: int | None = None,
                         glred_payload: int = 64,
                         prec_factor: float = 1.0) -> dict:
    """Per-iteration kernel times (seconds) for a CG iteration on an
    N-unknown stencil problem over P workers.  ``prec_factor`` scales the
    local-solve cost of the preconditioner relative to the bare SPMV
    (block-Jacobi + per-block ILU ~ 3x, as in the paper's SNES ex48 runs)."""
    n_loc = n / p
    flops = 2.0 * stencil_pts * n_loc
    if hw.name.startswith("cori"):
        # PETSc AIJ (CSR): per row, nnz*(8B value + 4B col idx) + x + y.
        # The TPU port is MATRIX-FREE (stencil weights in registers), which
        # is the DESIGN.md §2 hardware adaptation — ~4x fewer bytes.
        bytes_spmv = n_loc * (stencil_pts * 12.0 + 2 * dsize)
    else:
        bytes_spmv = 3.0 * dsize * n_loc        # read x, write y (+halo reuse)
    if halo_elems is None:
        halo_elems = int(n_loc ** (1 / 2)) if stencil_pts == 5 \
            else int(n_loc ** (2 / 3))
    t_spmv_stream = prec_factor * max(flops / hw.flop_rate,
                                      bytes_spmv / hw.mem_bw)
    t_spmv_comm = 2 * halo_elems * dsize / hw.link_bw + 2 * hw.alpha
    t_spmv = t_spmv_stream + t_spmv_comm
    # one AXPY/DOT pass = 3 streams (2 read + 1 write) over n_loc
    t_axpy1 = 3.0 * dsize * n_loc / hw.mem_bw
    t_glred = hw.alpha * tree_depth(hw, p) + glred_payload / hw.link_bw
    # spmv_stream / spmv_comm expose the split so the autotuner can
    # recalibrate the HBM-stream part against a MEASURED bytes/iteration
    # (cost_analysis; launch.autotune.model_iteration_time) while the
    # halo/latency part stays analytic.
    return {"spmv": t_spmv, "axpy1": t_axpy1, "glred": t_glred,
            "spmv_stream": t_spmv_stream, "spmv_comm": t_spmv_comm}


def diagonal_kernel_times(hw: HWProfile, n: int, p: int, dsize: int = 8,
                          glred_payload: int = 64) -> dict:
    """The paper's "one-point stencil" communication-bound toy: SPMV is a
    single elementwise stream, no halo."""
    n_loc = n / p
    t_spmv = 3.0 * dsize * n_loc / hw.mem_bw
    t_axpy1 = 3.0 * dsize * n_loc / hw.mem_bw
    t_glred = hw.alpha * tree_depth(hw, p) + glred_payload / hw.link_bw
    return {"spmv": t_spmv, "axpy1": t_axpy1, "glred": t_glred}
