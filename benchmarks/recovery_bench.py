"""Elastic-recovery benchmark: the kill-a-rank drill's outcome plus the
serve self-healing replay (DESIGN.md §19).  Emits
``BENCH_recovery.json``; CI gates it via ``scripts/check_bench.py``.

Every GATED column is DETERMINISTIC — seeded kills, fixed shapes,
virtual-clock replay, bitwise parity flags — so container timing noise
cannot move any of them.  The two wall-clock columns (detection and
respawn latency) ride along informationally.

* ``recovery_parity_bitwise``      — 1 when the cross-process drill's
                                     resumed residual history is BITWISE
                                     identical to the local
                                     virtual-shards oracle that never
                                     died.  Floor-gated at +0: the
                                     resume-exactly claim IS the PR.
* ``recovery_recomputed_iters``    — solution updates replayed after the
                                     kill; ratio-gated <= 1x
                                     ``recovery_checkpoint_every`` (the
                                     §19 bound: a kill costs at most one
                                     checkpoint interval of rework).
* ``recovery_attempts``            — fabric launches (2: killed + clean).
* ``recovery_detection_s`` / ``recovery_respawn_s``
                                   — wall-clock from kill to teardown,
                                     and teardown to restored state
                                     (informational, not gated).
* ``recovery_resume_bitwise``      — 1 when the single-process
                                     save -> kill -> resume history is
                                     bitwise equal to the uninterrupted
                                     solve (the substrate-level half of
                                     the same claim, cheap enough to
                                     re-prove here).
* ``recovery_serve_worker_deaths`` / ``_resubmitted`` / ``_shed`` /
  ``_all_converged``               — the self-healing serve replay: one
                                     injected WorkerFault, four
                                     in-flight columns resubmitted with
                                     fresh SLO windows, none shed, all
                                     converged.
* ``recovery_serve_deterministic_replay``
                                   — 1 when two identical fault replays
                                     produce identical metrics
                                     snapshots under VirtualClock.
* ``recovery_serve_exhausted_shed`` — with a zero retry budget the same
                                     fault sheds all four (typed,
                                     accounted — never an infinite
                                     resubmit loop).

    PYTHONPATH=src python -m benchmarks.recovery_bench [--out PATH]
        [--skip-drill]   # substrate + serve columns only (the
                         # cross-process drill needs ~4 min and its own
                         # fabric; CI's recovery-drill job runs it)
"""

import argparse
import json
import os
import subprocess
import sys

import numpy as np

DRILL_TIMEOUT_S = 900
RESULT_MARKER = "RECOVERY-RESULT "


def drill_rows(timeout_s: float = DRILL_TIMEOUT_S) -> dict:
    """Run the cross-process kill-a-rank drill (2 fabric processes, rank
    1 killed mid-solve) and lift its RECOVERY-RESULT summary into bench
    columns."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)          # children pick their own device split
    out = subprocess.run(
        [sys.executable, "scripts/multiprocess_parity.py", "--recovery"],
        capture_output=True, text=True, env=env, timeout=timeout_s)
    if out.returncode != 0:
        raise SystemExit(f"recovery drill failed (exit {out.returncode}):\n"
                         f"{out.stdout[-3000:]}\n{out.stderr[-3000:]}")
    row = None
    for line in out.stdout.splitlines():
        if line.startswith(RESULT_MARKER):
            row = json.loads(line[len(RESULT_MARKER):])
    if row is None:
        raise SystemExit("drill printed no RECOVERY-RESULT line:\n"
                         + out.stdout[-3000:])
    return {
        "recovery_procs": row["procs"],
        "recovery_devices_per_process": row["devices_per_process"],
        "recovery_kill_rank": row["kill_rank"],
        "recovery_kill_upd": row["kill_upd"],
        "recovery_resumed_upd": row["resumed_upd"],
        "recovery_recomputed_iters": row["recomputed_iters"],
        "recovery_checkpoint_every": row["checkpoint_every"],
        "recovery_detection_s": row["detection_s"],
        "recovery_respawn_s": row["respawn_s"],
        "recovery_attempts": row["attempts"],
        "recovery_iters": row["iters"],
        "recovery_parity_bitwise": row["parity_bitwise"],
        "recovery_converged": row["converged"],
    }


def resume_rows() -> dict:
    """Single-process half of the bitwise-resume claim: save -> kill ->
    resume equals the uninterrupted solve, bit for bit."""
    import tempfile

    from repro.checkpoint import LAST_RESTORE, CheckpointConfig
    from repro.linalg import Stencil2D5
    from repro.parallel import get_backend

    op = Stencil2D5(32, 24)
    b = np.asarray(np.random.default_rng(0).standard_normal(op.n))
    be = get_backend("local")
    kw = dict(method="plcg", l=2, tol=1e-10, maxit=400)
    with tempfile.TemporaryDirectory(prefix="repro-recovery-bench-") as d:
        full = be.solve(op, b, checkpoint=CheckpointConfig(
            every=20, directory=d), **kw)
        resumed = be.solve(op, b, checkpoint=CheckpointConfig(
            every=20, directory=d, resume=True), **kw)
    h_f = np.asarray(full.res_history)
    h_r = np.asarray(resumed.res_history)
    bitwise = bool(np.array_equal(h_f, h_r)) and bool(LAST_RESTORE)
    return {
        "recovery_resume_bitwise": int(bitwise and bool(resumed.converged)),
        "recovery_resume_upd": int(LAST_RESTORE[-1].meta["upd"])
        if LAST_RESTORE else -1,
    }


def _serve_replay(fault_tick: int, max_retries: int):
    from repro.linalg import Stencil2D5
    from repro.parallel import get_backend
    from repro.serve import RetryPolicy, SolverService, VirtualClock
    from repro.serve.errors import WorkerFault

    op = Stencil2D5(12, 12)
    state = {"fired": False}

    def injector(tick, worker):
        if tick == fault_tick and not state["fired"]:
            state["fired"] = True
            raise WorkerFault(f"injected at tick {tick}")

    svc = SolverService(get_backend("local"), s=4, method="plcg", l=2,
                        chunk_iters=25, maxit=600, clock=VirtualClock(),
                        retry=RetryPolicy(max_retries=max_retries),
                        fault_injector=injector)
    svc.register_operator("lap", op)
    rng = np.random.default_rng(3)
    ids = [svc.submit("lap", rng.standard_normal(op.n)) for _ in range(4)]
    results = svc.drain()
    return svc, ids, results


def serve_rows() -> dict:
    """Self-healing serve under a one-shot WorkerFault: heal, account,
    replay deterministically; shed only when the retry budget is zero."""
    svc, ids, results = _serve_replay(fault_tick=2, max_retries=3)
    all_conv = all(results[r].converged and not results[r].shed for r in ids)
    svc2, _, _ = _serve_replay(fault_tick=2, max_retries=3)
    deterministic = svc.metrics_snapshot() == svc2.metrics_snapshot()
    svc0, ids0, res0 = _serve_replay(fault_tick=2, max_retries=0)
    exhausted_shed = sum(1 for r in ids0 if res0[r].shed)
    return {
        "recovery_serve_worker_deaths": int(svc.worker_deaths),
        "recovery_serve_resubmitted": int(svc.resubmitted),
        "recovery_serve_shed": int(svc.shed),
        "recovery_serve_all_converged": int(all_conv),
        "recovery_serve_deterministic_replay": int(deterministic),
        "recovery_serve_exhausted_shed": int(exhausted_shed),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=str, default="BENCH_recovery.json")
    ap.add_argument("--skip-drill", action="store_true",
                    help="omit the cross-process drill columns (~4 min); "
                         "substrate + serve columns only")
    args = ap.parse_args(argv)

    # jax import deferred past argparse; single host device is all the
    # in-process columns need (the drill children pick their own split).
    import jax

    jax.config.update("jax_enable_x64", True)

    payload = {}
    payload.update(resume_rows())
    payload.update(serve_rows())
    if not args.skip_drill:
        payload.update(drill_rows())
    for k, v in payload.items():
        print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
