"""Event-driven schedule simulator — paper Fig. 4 + §4.2 'staggering'.

Simulates the per-iteration kernel schedule of CG / p-CG / p(l)-CG:

  CG     : SPMV ; GLRED(block) ; AXPY ; GLRED(block)
  p-CG   : one fused GLRED overlapping the SAME iteration's SPMV+PREC
  p(l)-CG: GLRED initiated at end of iter i (after K5), first READ at the
           start of iter i+l (K2); body work = SPMV + (2l+2) AXPYs + SCALAR.
           Up to l reductions are IN FLIGHT simultaneously (staggering).

Optional log-normal jitter on each reduction models OS/network noise; the
paper's observation that l >= 2 'absorbs' glred run-time variance is
reproduced quantitatively (mean iteration time vs jitter).
"""

from __future__ import annotations

import numpy as np


def simulate_cg(n_iters, t_spmv, t_axpy1, t_glred, jitter=0.0, rng=None):
    rng = rng or np.random.default_rng(0)
    dur = _glred_samples(n_iters * 2, t_glred, jitter, rng)
    # 2 blocking reductions + spmv + ~3 axpy/dot passes
    t = 0.0
    for i in range(n_iters):
        t += t_spmv + 3 * t_axpy1 + dur[2 * i] + dur[2 * i + 1]
    return t


def simulate_pcg(n_iters, t_spmv, t_axpy1, t_glred, jitter=0.0, rng=None):
    rng = rng or np.random.default_rng(0)
    dur = _glred_samples(n_iters, t_glred, jitter, rng)
    # fused reduction overlaps the iteration's own SPMV; 8 AXPY updates
    t = 0.0
    for i in range(n_iters):
        t += max(dur[i], t_spmv) + 8 * t_axpy1
    return t


def simulate_plcg(n_iters, l, t_spmv, t_axpy1, t_glred, jitter=0.0, rng=None,
                  body_l=None):
    """Event-driven Alg. 2 schedule: the K1 SPMV runs FIRST, then
    MPI_Wait(req(i-l)) before K2, then the AXPY/SCALAR tail; the new
    reduction is issued at the end of the body (K5) and progresses
    asynchronously.

    ``body_l`` sizes the AXPY tail when the *overlap* depth differs from
    the algorithmic depth (the autotuner models XLA's effective depth
    min(l, unroll-1) while the solver still pays the full 2l+3-pass
    body); defaults to ``l``."""
    rng = rng or np.random.default_rng(0)
    dur = _glred_samples(n_iters, t_glred, jitter, rng)
    body_l = l if body_l is None else body_l
    t_rest = (2 * body_l + 2 + 1) * t_axpy1          # K2-K6 AXPYs + dots
    glred_done = [-np.inf] * n_iters
    body_end = 0.0
    for i in range(n_iters):
        spmv_end = body_end + t_spmv                 # K1
        start_rest = spmv_end
        if i >= l:
            start_rest = max(start_rest, glred_done[i - l])  # MPI_Wait
        body_end = start_rest + t_rest
        glred_done[i] = body_end + dur[i]            # MPI_Iallreduce(req(i))
    return body_end


def _glred_samples(k, t_glred, jitter, rng):
    if jitter <= 0:
        return np.full(k, t_glred)
    sigma = np.sqrt(np.log(1 + jitter ** 2))
    return t_glred * rng.lognormal(-sigma ** 2 / 2, sigma, size=k)


def reduction_samples(k, t_red, jitter, rng):
    """Mean-preserving log-normal jitter on a reduction duration — the
    SAME noise model every reduction flavour is scored under, so the
    autotuner's monolithic-vs-staged ranking (launch.autotune,
    DESIGN.md §14) compares like with like."""
    return _glred_samples(k, t_red, jitter, rng)


def iteration_time(method, l, kernels, n_iters=200, jitter=0.0, seed=0,
                   body_l=None):
    rng = np.random.default_rng(seed)
    k = kernels
    if method == "cg":
        tot = simulate_cg(n_iters, k["spmv"], k["axpy1"], k["glred"], jitter, rng)
    elif method == "pcg":
        tot = simulate_pcg(n_iters, k["spmv"], k["axpy1"], k["glred"], jitter, rng)
    else:
        tot = simulate_plcg(n_iters, l, k["spmv"], k["axpy1"], k["glred"],
                            jitter, rng, body_l=body_l)
    return tot / n_iters
