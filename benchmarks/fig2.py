"""Paper Fig. 2: strong-scaling speedup over 8-node classic CG.

Reproduces the paper's three ice-sheet problem sizes (100x100x50 /
150x150x100 / 200x200x150 FEM ~ 3D stencil unknowns x ~2 dofs) on the
Cori-like profile, then repeats the study on the TPU-v5e profile (the
hardware adaptation).  Times come from the event-driven schedule simulator
fed by the analytic kernel model (this container cannot time 1024 nodes;
the paper's Fig. 4 is the same kind of schedule model).

Claims checked programmatically:
  C1  classic CG stops scaling at a problem-size-dependent node count
  C2  pipelined variants keep scaling beyond it
  C3  p(l)-CG peak speedup approaches O(l) x CG in the glred-bound regime
"""

from __future__ import annotations

import numpy as np

from benchmarks.schedule_sim import iteration_time
from benchmarks.timing_model import CORI, V5E, stencil_kernel_times

SIZES = {
    "100x100x50": 100 * 100 * 50 * 2,
    "150x150x100": 150 * 150 * 100 * 2,
    "200x200x150": 200 * 200 * 150 * 2,
}
NODES = [8, 16, 32, 64, 128, 256, 512, 1024]
RANKS_PER_NODE = 16
METHODS = [("cg", 0), ("pcg", 0), ("plcg", 1), ("plcg", 2), ("plcg", 3)]


def scaling_table(hw, n_unknowns, jitter=0.15):
    rows = {}
    for method, l in METHODS:
        ts = []
        for nodes in NODES:
            p = nodes * RANKS_PER_NODE if hw is CORI else nodes
            k = stencil_kernel_times(hw, n_unknowns, p, stencil_pts=7,
                                     glred_payload=8 * (2 * max(l, 1) + 1),
                                     prec_factor=3.0)
            ts.append(iteration_time(method, l, k, jitter=jitter))
        rows[(method, l)] = np.asarray(ts)
    return rows


def speedups(rows):
    base = rows[("cg", 0)][0]          # 8-node classic CG
    return {k: base / v for k, v in rows.items()}


def check_claims(rows, verbose=True):
    sp = speedups(rows)
    cg = sp[("cg", 0)]
    # C1: CG saturates (max speedup reached before the last node count)
    c1 = int(np.argmax(cg)) < len(NODES) - 1 or cg[-1] < cg[-2] * 1.1
    # C2: best pipelined keeps scaling where CG has stopped
    best_pl = np.maximum.reduce([sp[("plcg", l)] for l in (1, 2, 3)])
    c2 = best_pl[-1] > cg[-1] * 1.2
    # C3: peak pipelined speedup vs CG at same node count approaches O(l)
    gain3 = (sp[("plcg", 3)] / cg).max()
    c3 = gain3 > 1.5
    if verbose:
        print(f"  C1 CG saturates: {c1} | C2 pipelined keeps scaling: {c2} "
              f"(x{best_pl[-1] / cg[-1]:.2f} at {NODES[-1]} nodes) | "
              f"C3 p(3) peak gain x{gain3:.2f}: {c3}")
    return c1 and c2 and c3


def run(verbose=True):
    ok = True
    for hw in (CORI, V5E):
        if verbose:
            print(f"== Fig. 2 strong scaling [{hw.name}] "
                  f"(speedup over 8-node CG) ==")
        for name, n in SIZES.items():
            rows = scaling_table(hw, n)
            sp = speedups(rows)
            if verbose:
                print(f"-- {name} ({n / 1e6:.1f}M unknowns)")
                hdr = "nodes:    " + " ".join(f"{x:>7d}" for x in NODES)
                print(hdr)
                for (m, l), v in sp.items():
                    nm = {"cg": "CG", "pcg": "p-CG"}.get(m, f"p({l})-CG")
                    print(f"{nm:>9s} " + " ".join(f"{x:>7.2f}" for x in v))
            ok &= check_claims(rows, verbose)
    assert ok, "Fig. 2 qualitative claims failed"
    return ok


if __name__ == "__main__":
    run()
