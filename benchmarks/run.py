"""Benchmark aggregator: one entry per paper table/figure + roofline report.

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time

from benchmarks import fig2, fig3, fig4, roofline_report, table1


def main():
    t0 = time.time()
    failures = []
    for name, mod in [("table1", table1), ("fig2", fig2), ("fig3", fig3),
                      ("fig4", fig4)]:
        print(f"\n{'='*70}\nBENCH {name}\n{'='*70}")
        try:
            mod.run(verbose=True)
            print(f"[{name}] PASS")
        except AssertionError as e:
            failures.append((name, str(e)))
            print(f"[{name}] FAIL: {e}")
    print(f"\n{'='*70}\nBENCH roofline report\n{'='*70}")
    roofline_report.run()
    print(f"\nTotal: {time.time()-t0:.1f}s; "
          f"{'ALL PASS' if not failures else f'FAILURES: {failures}'}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
