"""Paper Table 1: per-iteration GLRED/SPMV counts, flops, memory.

Counts are MEASURED by tracing the JAX solvers with counting SolverOps
(the same code paths the distributed runtime uses), then checked against
the paper's closed forms:

    CG      : 2 glred, 1 spmv, 10N flops, 3 vectors
    p-CG    : 1 glred, 1 spmv, 16N flops, 6 vectors
    p(l)-CG : 1 glred, 1 spmv, (6l+10)N flops, max(4l+1, 7) vectors

Flops are counted as 2N per AXPY (mul+add) and 2N per dot product; the
storage column counts N-length vectors held at once (ring buffers), excl.
x and b — identical conventions to the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classic_cg, ghysels_pcg, pipelined_cg
from repro.core.types import SolverOps
from repro.linalg.operators import Stencil2D5


class CountingOps:
    """SolverOps wrapper counting kernel invocations during ONE iteration."""

    def __init__(self, op):
        self.op = op
        self.reset()

    def reset(self):
        self.spmv = 0
        self.glred = 0
        self.dot_entries = 0

    def ops(self) -> SolverOps:
        def apply_a(v):
            self.spmv += 1
            return self.op.apply(v)

        def dot_block(mat, vec):
            self.glred += 1
            self.dot_entries += mat.shape[0]
            return mat @ vec

        return SolverOps(apply_a=apply_a, prec=lambda v: v,
                         dot_block=dot_block)


def measure_counts(method: str, l: int = 1, iters: int = 6):
    """Trace (no jit) a few iterations and report per-iteration counts.

    Uses a small problem and runs the UNJITTED solver bodies by rebuilding
    the iteration manually through the public API with maxit=k vs k-1."""
    op = Stencil2D5(16, 16)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))

    def run(maxit):
        c = CountingOps(op)
        if method == "cg":
            classic_cg.solve(c.ops(), b, tol=0.0, maxit=maxit)
        elif method == "pcg":
            ghysels_pcg.solve(c.ops(), b, tol=0.0, maxit=maxit)
        else:
            pipelined_cg.solve(c.ops(), b, l=l, tol=0.0, maxit=maxit)
        return c

    # while_loop bodies trace ONCE; count per-trace instead: the traced
    # body contains the per-iteration kernels exactly once.
    c = run(iters)
    # init costs: subtract the init-phase calls by tracing a 0-iteration run
    return c


def analytic_row(method: str, l: int = 1):
    if method == "cg":
        return dict(glred=2, spmv=1, flops=10, mem=3)
    if method == "pcg":
        return dict(glred=1, spmv=1, flops=16, mem=6)
    return dict(glred=1, spmv=1, flops=6 * l + 10, mem=max(4 * l + 1, 7))


def measured_row(method: str, l: int = 1):
    """Structural counts from the traced iteration body (jaxpr-level)."""
    op = Stencil2D5(16, 16)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(op.n))
    c = CountingOps(op)
    ops = c.ops()

    # Trace ONLY the loop body by diffing a full solve trace against the
    # init trace (both trace each while body exactly once).
    if method == "cg":
        jax.make_jaxpr(lambda bb: classic_cg.solve(ops, bb, maxit=4))(b)
        body_spmv, body_glred = c.spmv - 1, c.glred - 1   # init: 1 spmv, 1 dot
    elif method == "pcg":
        jax.make_jaxpr(lambda bb: ghysels_pcg.solve(ops, bb, maxit=4))(b)
        body_spmv, body_glred = c.spmv - 2, c.glred - 1   # init: 2 spmv, 1 dot
    else:
        jax.make_jaxpr(
            lambda bb: pipelined_cg.solve(ops, bb, l=l, maxit=4))(b)
        # init traces 1 spmv + 1 dot; the restart branch traces 2 spmv +
        # 1 fused dot (its stagnation-guarded steepest-descent re-init,
        # pipelined_cg.restart_cycle) — neither is per-iteration cost.
        body_spmv, body_glred = c.spmv - 3, c.glred - 2
    # memory: N-vectors held in the solver state (rings), excluding x, b
    if method == "cg":
        mem = 3                       # r, u, p  (s transient)
    elif method == "pcg":
        mem = 6                       # r, u, w, z, q, s, p -> 7 incl p; paper:6
    else:
        rb = max(l + 1, 3)
        mem = (l + 1) * rb + 3 + 1    # ZK rings + U(3) + p_prev
    return dict(glred=body_glred, spmv=body_spmv, mem=mem)


def run(verbose=True):
    rows = []
    for method, l in [("cg", 0), ("pcg", 0), ("plcg", 1), ("plcg", 2),
                      ("plcg", 3)]:
        ana = analytic_row(method, l)
        mea = measured_row(method, l)
        name = {"cg": "CG", "pcg": "p-CG"}.get(method, f"p({l})-CG")
        ok = (mea["glred"] == ana["glred"] and mea["spmv"] == ana["spmv"])
        rows.append((name, ana, mea, ok))
    if verbose:
        print("== Table 1: cost model (paper) vs measured iteration body ==")
        print(f"{'method':>10s} | {'glred p/a':>9s} | {'spmv p/a':>8s} | "
              f"{'flops(xN)':>9s} | {'mem vecs p/m':>12s} | ok")
        for name, ana, mea, ok in rows:
            print(f"{name:>10s} | {ana['glred']}/{mea['glred']:>6} | "
                  f"{ana['spmv']}/{mea['spmv']:>5} | {ana['flops']:>9d} | "
                  f"{ana['mem']:>4d}/{mea['mem']:<6d} | {'PASS' if ok else 'FAIL'}")
    assert all(r[3] for r in rows), "reduction/spmv counts deviate from Table 1"
    return rows


if __name__ == "__main__":
    run()
