from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.data import SyntheticData
from repro.train.train_step import make_train_step, make_pipelined_train_step

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "SyntheticData", "make_train_step", "make_pipelined_train_step",
]
