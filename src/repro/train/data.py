"""Deterministic-by-step synthetic data pipeline.

Every batch is a pure function of (seed, step): any worker can recompute
any shard of any step — no shuffle-buffer state to lose on restart
(DESIGN.md §7).  Token streams follow a Zipf-like distribution so losses
have realistic structure; modality stubs (audio frames / image patches)
are folded-in Gaussians per the assignment.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticData:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    family: str = "dense"
    d_model: int = 0
    n_patches: int = 0
    enc_frames_ratio: int = 4

    def _key(self, step):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), step)

    def batch_at(self, step: int | jax.Array) -> dict:
        key = self._key(step)
        k1, k2, k3 = jax.random.split(key, 3)
        # Zipf-ish marginal: exponential-transformed uniforms
        u = jax.random.uniform(k1, (self.batch, self.seq_len + 1),
                               minval=1e-6, maxval=1.0)
        alpha = 1.1
        ranks = jnp.floor(
            (u ** (-1.0 / (alpha - 1.0)) - 1.0)) .astype(jnp.int32)
        toks = jnp.clip(ranks, 0, self.vocab - 1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.family == "vlm":
            out["patch_embeds"] = 0.02 * jax.random.normal(
                k2, (self.batch, self.n_patches, self.d_model))
        if self.family == "encdec":
            out["enc_embeds"] = 0.02 * jax.random.normal(
                k3, (self.batch, self.seq_len // self.enc_frames_ratio,
                     self.d_model))
        return out

    @staticmethod
    def for_config(cfg, seq_len: int, batch: int, seed: int = 0):
        return SyntheticData(
            vocab=cfg.vocab, seq_len=seq_len, batch=batch, seed=seed,
            family=cfg.family, d_model=cfg.d_model, n_patches=cfg.n_patches,
            enc_frames_ratio=cfg.enc_frames_ratio,
        )
