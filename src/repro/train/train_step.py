"""Train steps: synchronous baseline + the paper's technique transferred to
data-parallel training (global-reduction pipelining of the gradient psum).

``make_train_step``      — standard: grads -> clip -> AdamW, one fused
                           gradient all-reduce on the critical path.
``make_pipelined_train_step`` — the p(l)-CG transform (DESIGN.md §4):
  a depth-l ring buffer of in-flight gradient trees rides in the training
  state; the gradients computed at step i are APPLIED at step i+l.  The
  gradient all-reduce of step i therefore has l full train-step bodies of
  forward/backward compute (and l-1 other reductions) between issue and
  first use — the Iallreduce/Wait window of Alg. 2, realized through
  XLA's latency-hiding scheduler when the driver unrolls l+1 steps.
  l=0 recovers synchronous training bit-exactly.

Staleness note (recorded, not hidden): delayed application is *stale
gradient descent* with bounded staleness l — the same
accuracy-vs-synchronization trade the paper makes for CG (its deep
pipelines delay convergence via restarts, §4.2).  examples/train_lm.py
measures the loss-curve effect.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(model, opt_cfg: AdamWConfig) -> Callable:
    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **om}
    return step_fn


def init_grad_ring(params, l: int):
    """l in-flight gradient slots (zeros = warmup no-ops)."""
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return jax.tree.map(
        lambda z: jnp.broadcast_to(z[None], (max(l, 1), *z.shape)).copy()
        if l > 0 else z[None][:0], zeros)


def make_pipelined_train_step(model, opt_cfg: AdamWConfig, l: int) -> Callable:
    """Returns step_fn(params, opt_state, ring, step_idx, batch).

    ring holds the l most recent gradient trees; the tree POPPED (slot
    step_idx % l) is applied, the fresh tree is PUSHED into its place."""
    if l == 0:
        base = make_train_step(model, opt_cfg)

        def sync_fn(params, opt_state, ring, step_idx, batch):
            params, opt_state, m = base(params, opt_state, batch)
            return params, opt_state, ring, m
        return sync_fn

    def step_fn(params, opt_state, ring, step_idx, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        slot = jnp.mod(step_idx, l)
        # pop the l-steps-old gradients — MPI_Wait(req(i-l))
        old = jax.tree.map(
            lambda r: jax.lax.dynamic_index_in_dim(r, slot, 0, keepdims=False),
            ring)
        # push fresh gradients — MPI_Iallreduce(req(i)); their reduction is
        # not consumed for another l steps
        ring = jax.tree.map(
            lambda r, g: jax.lax.dynamic_update_index_in_dim(
                r, g.astype(jnp.float32), slot, 0),
            ring, grads)
        params, opt_state, om = adamw_update(opt_cfg, old, opt_state, params)
        return params, opt_state, ring, {"loss": loss, **metrics, **om}
    return step_fn


def run_steps(step_fn, params, opt_state, data, n_steps: int, l: int = 0,
              start_step: int = 0, unroll: int = 1):
    """Host-side driver used by examples/tests (jits one step)."""
    jfn = jax.jit(step_fn)
    ring = init_grad_ring(params, l)
    history = []
    for i in range(start_step, start_step + n_steps):
        batch = data.batch_at(i)
        params, opt_state, ring, m = jfn(
            params, opt_state, ring, jnp.asarray(i, jnp.int32), batch)
        history.append({k: float(v) for k, v in m.items()})
    return params, opt_state, ring, history
