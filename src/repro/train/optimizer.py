"""AdamW with f32 master weights and the paper's technique applied to the
gradient-norm reduction.

Mixed precision: model params may be bf16; the optimizer keeps an f32
master copy + (m, v) — all three ZeRO-1-sharded over the "data" axis by the
sharding rules in repro.launch.sharding (the *placement* is a sharding
concern, the math here is substrate-agnostic).

Pipelined (delayed) gradient-norm clipping — the p(l)-CG transfer: the
global grad-norm is a fused all-reduce whose value is only needed for a
*scalar* clip factor.  With ``delayed_norm=True`` the clip factor of step i
uses the norm initiated at step i-1 (carried in the state), removing the
norm reduction from the critical path exactly as Alg. 2 moves MPI_Wait l
iterations past MPI_Iallreduce.  ``delayed_norm=False`` recovers the
synchronous baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    delayed_norm: bool = False      # the paper's technique on the norm glred


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {
        "master": f32(params),
        "m": zeros(params),
        "v": zeros(params),
        "step": jnp.zeros((), jnp.int32),
        "prev_norm": jnp.ones((), jnp.float32),   # delayed-norm carry
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    norm = global_norm(grads)
    # --- clip factor: synchronous (norm) or pipelined (prev step's norm) --
    norm_for_clip = jnp.where(
        jnp.asarray(cfg.delayed_norm), opt_state["prev_norm"], norm)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norm_for_clip, 1e-12))
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, mast):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        mast = mast - lr * (u + cfg.weight_decay * mast)
        return m, v, mast

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_w = tdef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    new_master = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda mast, p: mast.astype(p.dtype), new_master, params)
    new_state = {
        "master": new_master, "m": new_m, "v": new_v,
        "step": step, "prev_norm": norm,
    }
    return new_params, new_state, {"grad_norm": norm, "lr": lr,
                                   "clip_scale": scale}
