"""Checkpoint/restart with elastic resharding (DESIGN.md §7).

Layout per step:  <dir>/step_<n>.tmp -> (atomic rename) -> step_<n>/
    manifest.json   step, mesh shape, PRNG seed, data cursor, tree structure
    arrays.npz      flat {path: array} of params + opt state + grad ring

Save is asynchronous (background thread) with an atomic rename commit, so
a preemption mid-save never corrupts the latest checkpoint; keep_n garbage
collection prunes old steps.  Restore returns host numpy trees that the
caller ``device_put``s with the CURRENT mesh's shardings — restoring on a
different device count / mesh shape (elastic scale up/down) is therefore
the default path, not a special case.  The grad ring is part of the state
so a restart reproduces the exact delayed-gradient stream of the paper's
technique.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------- save ----
    def save(self, step: int, state: Any, meta: dict | None = None,
             block: bool = False):
        """state: any pytree (params/opt/ring/...).  Async by default."""
        flat = _flatten(state)          # device->host copy happens here
        meta = dict(meta or {}, step=int(step))
        self.wait()                     # one in-flight save at a time

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)       # atomic commit
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -------------------------------------------------------- restore ----
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: int | None = None):
        """Returns (state_host_numpy, manifest).  ``template`` provides the
        tree structure & shapes (e.g. jax.eval_shape of the init fn) so
        restore works onto ANY mesh — shard with device_put afterwards."""
        step = self.latest() if step is None else step
        assert step is not None, "no checkpoints found"
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(template, flat), meta
