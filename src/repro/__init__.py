"""repro — production-grade JAX framework reproducing and extending

  "Improving strong scaling of the Conjugate Gradient method for solving
   large linear systems using global reduction pipelining"
  (Cools, Ghysels, Cornelis, Vanroose — EuroMPI'19)

Layers
------
core/      p(l)-CG (deep pipelined CG, Alg. 1), classic CG, Ghysels p-CG,
           Chebyshev shifts, pipelined-reduction runtime.
linalg/    Stencil / diagonal / dense SPD operators, preconditioners,
           domain-decomposed (halo-exchange) variants.
kernels/   Pallas TPU kernels (stencil SpMV, fused dot-block, fused AXPY,
           split-KV decode attention) with jnp oracles.
models/    LM architecture zoo (dense GQA / MoE / SSM / hybrid / enc-dec / VLM).
configs/   The 10 assigned architecture configs + reduced smoke variants.
train/     AdamW + ZeRO-1, pipelined gradient reduction (the paper's technique
           applied to data-parallel training), checkpointing, data pipeline.
serve/     KV-cache decode path.
launch/    Production meshes, multi-pod dry-run, train/serve drivers.
utils/     HLO collective analysis, roofline terms.
"""

__version__ = "1.0.0"
