"""Stand-in for the Blatter/Pattyn ice-sheet system (PETSc SNES ex48):
anisotropic 3D 7-point stencil, thin-sheet eps_z (DESIGN.md §10).
Paper sizes: 100x100x50 / 150x150x100 / 200x200x150 finite elements."""
from repro.configs.laplace2d import CGProblem


def config():
    return CGProblem(name="icesheet3d", kind="stencil3d",
                     nx=256, ny=200, nz=152, eps_z=0.01, prec="blockjacobi")


def smoke_config():
    return CGProblem(name="icesheet3d-smoke", kind="stencil3d",
                     nx=16, ny=12, nz=8, eps_z=0.01)
