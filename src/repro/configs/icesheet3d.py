"""The Blatter/Pattyn ice-sheet system (PETSc SNES ex48) as an
UNSTRUCTURED problem (DESIGN.md §12): a random extruded FEM mesh with
thin-sheet vertical/horizontal anisotropy, solved through the
``SparseOp`` / partition / halo-staggering path — the workload class of
Cornelis/Cools/Vanroose (arXiv:1801.04728) this config previously faked
with an anisotropic stencil.  The stencil stand-in survives as the
explicit ``icesheet3d-stencil`` fallback (``icesheet3d_stencil.py``) for
runs that want the matrix-free kernel at the paper's larger grid sizes.

Size: the paper's smallest ice-sheet run (100x100x50 finite elements).
"""
from repro.configs.laplace2d import CGProblem


def config():
    return CGProblem(name="icesheet3d", kind="unstructured",
                     nx=100, ny=100, nz=50, eps_z=0.01, prec="blockjacobi",
                     seed=48)


def smoke_config():
    return CGProblem(name="icesheet3d-smoke", kind="unstructured",
                     nx=10, ny=6, nz=4, eps_z=0.01, seed=48)
