"""The paper's own benchmark problem: 2D 5-point Laplacian (PETSc KSP ex2)
+ the diagonal "communication-bound toy" with the same spectrum (Fig. 3)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class CGProblem:
    name: str
    kind: str          # stencil2d | stencil3d | diagonal | unstructured
    nx: int
    ny: int
    nz: int = 1
    eps_z: float = 1.0
    l: int = 2
    tol: float = 1e-6
    maxit: int = 2000
    prec: str = "none"  # none | jacobi | blockjacobi
    seed: int = 0       # mesh-generator seed (unstructured kinds only)


def config():
    # 2000x2000 = 4M unknowns, the paper's Fig. 3 problem size
    return CGProblem(name="laplace2d", kind="stencil2d", nx=2048, ny=2048)


def smoke_config():
    return CGProblem(name="laplace2d-smoke", kind="stencil2d", nx=32, ny=24)
