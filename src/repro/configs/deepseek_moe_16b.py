"""deepseek-moe-16b [moe]: 28L, d_model=2048, 16H (kv=16), expert
d_ff=1408, vocab=102400, 64 fine-grained routed experts top-6 + 2 shared
(always-on) experts [arXiv:2401.06066; hf].  (The published model's first
layer is dense; we use the uniform-MoE stack and note the simplification.)"""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
        vocab=102400, n_experts=64, top_k=6, n_shared_experts=2,
        capacity_factor=1.25,
    )


def smoke_config():
    return ArchConfig(
        name="deepseek-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=32,
        vocab=512, n_experts=8, top_k=3, n_shared_experts=2,
        capacity_factor=1.5,
    )
