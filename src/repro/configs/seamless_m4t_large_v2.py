"""seamless-m4t-large-v2 [audio]: enc-dec multimodal, 24L enc + 24L dec,
d_model=1024, 16H (kv=16), d_ff=8192, vocab=256206 [arXiv:2308.11596; hf].
The speech frontend is a STUB: input_specs() supplies precomputed frame
embeddings (seq//4 frames), per the assignment."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, n_enc_layers=24,
        d_model=1024, n_heads=16, n_kv=16, d_ff=8192, vocab=256206,
        act="relu", norm="layer", bias=True, enc_frames_ratio=4,
    )


def smoke_config():
    return ArchConfig(
        name="seamless-smoke", family="encdec",
        n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=512,
        act="relu", norm="layer", bias=True, enc_frames_ratio=4,
    )
