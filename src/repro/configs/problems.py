"""CGProblem -> LinearOperator builder (the one place ``kind`` strings
are interpreted; DESIGN.md §10/§12).

``unstructured`` problems route through the sparse-operator subsystem:
the random-FEM-mesh generators build an SPD graph Laplacian which is
RCM-pre-ordered here (``rcm_reorder``), so block-structured
preconditioners can be factored directly on the operator that the
distributed partitioner will shard (the partition then runs with an
identity permutation — see ``repro.parallel.distributed``).
"""

from __future__ import annotations

from repro.configs.laplace2d import CGProblem
from repro.linalg.operators import (
    DiagonalOp,
    LinearOperator,
    Stencil2D5,
    Stencil3D7,
    laplacian_2d_spectrum,
)
from repro.linalg.sparse import (
    random_fem_icesheet,
    random_fem_mesh,
    rcm_reorder,
)


def build_operator(prob: CGProblem) -> LinearOperator:
    if prob.kind == "stencil2d":
        return Stencil2D5(prob.nx, prob.ny)
    if prob.kind == "stencil3d":
        return Stencil3D7(prob.nx, prob.ny, prob.nz, eps_z=prob.eps_z)
    if prob.kind == "diagonal":
        return DiagonalOp(laplacian_2d_spectrum(prob.nx, prob.ny))
    if prob.kind == "unstructured":
        if prob.nz > 1:
            op = random_fem_icesheet(prob.seed, prob.nx, prob.ny, prob.nz,
                                     eps_z=prob.eps_z)
        else:
            op = random_fem_mesh(prob.seed, prob.nx * prob.ny)
        return rcm_reorder(op)[0]
    raise ValueError(f"unknown problem kind {prob.kind!r}")
