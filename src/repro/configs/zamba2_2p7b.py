"""zamba2-2.7b [hybrid]: 54 Mamba2 layers, d_model=2560, ssm_state=64,
plus a weight-SHARED full transformer block (32H MHA over concat[h, embed],
d_ff=10240) applied every 6 layers [arXiv:2411.15242; hf]."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
        vocab=32000, ssm_state=64, ssm_head_dim=64, shared_attn_period=6,
    )


def smoke_config():
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=128,
        vocab=512, ssm_state=16, ssm_head_dim=16, shared_attn_period=2,
    )
