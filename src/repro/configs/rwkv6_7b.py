"""rwkv6-7b "Finch" [ssm]: 32L, d_model=4096, attention-free with
data-dependent decay; channel-mix hidden 14336 = 3.5*d, vocab=65536
[arXiv:2404.05892; hf]."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336,
        vocab=65536, ssm_head_dim=64,
    )


def smoke_config():
    return ArchConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=224,
        vocab=512, ssm_head_dim=16,
    )
