"""stablelm-12b [dense]: 40L, d_model=5120, 32H (GQA kv=8), d_ff=13824,
vocab=100352, per-head qk-norm, LayerNorm [hf:stabilityai/stablelm-2-12b; hf]."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=13824,
        vocab=100352, norm="layer", qk_norm=True,
    )


def smoke_config():
    return ArchConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, norm="layer", qk_norm=True,
    )
