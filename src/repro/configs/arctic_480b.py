"""arctic-480b [moe]: 35L, d_model=7168, 56H (GQA kv=8), expert d_ff=4864,
vocab=32000, MoE 128 experts top-2 PLUS a dense-FFN residual branch
(dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base; hf].
dense_ff=8192 approximates the published ~10B dense component."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
        vocab=32000, n_experts=128, top_k=2,
        dense_residual=True, dense_ff=8192, capacity_factor=1.25,
    )


def smoke_config():
    # generous capacity so CPU smoke tests exercise drop-free routing
    # (the full config keeps the production 1.25)
    return ArchConfig(
        name="arctic-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=64,
        vocab=512, n_experts=8, top_k=2,
        dense_residual=True, dense_ff=96, capacity_factor=6.0,
    )
