"""qwen3-1.7b [dense]: 28L, d_model=2048, 16H (GQA kv=8), d_ff=6144,
vocab=151936, qk-norm, head_dim=128 [hf:Qwen/Qwen3-8B family; hf]."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="qwen3-1.7b", family="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv=8, d_ff=6144,
        vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    )


def smoke_config():
    return ArchConfig(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16, qk_norm=True,
    )
