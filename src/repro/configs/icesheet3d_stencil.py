"""Structured STAND-IN for the ice-sheet system: anisotropic 3D 7-point
stencil, thin-sheet eps_z (DESIGN.md §10).  ``icesheet3d`` proper now
routes through the unstructured operator path (DESIGN.md §12); this
fallback keeps the matrix-free stencil kernel available at the paper's
larger grid sizes (100x100x50 / 150x150x100 / 200x200x150 elements).
"""
from repro.configs.laplace2d import CGProblem


def config():
    return CGProblem(name="icesheet3d-stencil", kind="stencil3d",
                     nx=256, ny=200, nz=152, eps_z=0.01, prec="blockjacobi")


def smoke_config():
    return CGProblem(name="icesheet3d-stencil-smoke", kind="stencil3d",
                     nx=16, ny=12, nz=8, eps_z=0.01)
