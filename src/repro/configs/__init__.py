"""Assigned architecture registry: ``--arch <id>`` resolves here.

Each module exposes ``config()`` (the exact published hyperparameters) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "seamless-m4t-large-v2",
    "qwen3-1.7b",
    "command-r-plus-104b",
    "smollm-135m",
    "stablelm-12b",
    "qwen2-vl-7b",
    "arctic-480b",
    "deepseek-moe-16b",
    "zamba2-2.7b",
    "rwkv6-7b",
    # the paper's own "architectures" — CG benchmark problems
    "laplace2d",
    "icesheet3d",
    "icesheet3d-stencil",
]

_MOD = {i: i.replace("-", "_").replace(".", "p") for i in ARCH_IDS}


def get_config(arch_id: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.smoke_config() if smoke else mod.config()


CG_ARCH_IDS = ("laplace2d", "icesheet3d", "icesheet3d-stencil")


def lm_arch_ids():
    return [i for i in ARCH_IDS if i not in CG_ARCH_IDS]
