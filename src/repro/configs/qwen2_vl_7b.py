"""qwen2-vl-7b [vlm]: 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064, M-RoPE (sections 16/24/24 over half-dim 64), dynamic
resolution [arXiv:2409.12191; hf].  Vision frontend is a STUB: input_specs()
supplies precomputed patch embeddings (256 tokens prepended)."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="qwen2-vl-7b", family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv=4, d_ff=18944,
        vocab=152064, head_dim=128, bias=True,
        mrope_sections=(16, 24, 24), n_patches=256, rope_theta=1e6,
    )


def smoke_config():
    return ArchConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16, bias=True,
        mrope_sections=(4, 2, 2), n_patches=16,
    )
