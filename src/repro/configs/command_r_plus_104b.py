"""command-r-plus-104b [dense]: 64L, d_model=12288, 96H (GQA kv=8),
d_ff=33792, vocab=256000, no-bias, parallel attn+FFN blocks, LayerNorm
[hf:CohereForAI/c4ai-command-r-plus; unverified]."""
from repro.models.config import ArchConfig


def config():
    return ArchConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv=8, d_ff=33792,
        vocab=256000, bias=False, parallel_block=True, norm="layer",
        rope_theta=75e6,
    )


def smoke_config():
    return ArchConfig(
        name="command-r-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, parallel_block=True, norm="layer",
    )
