"""SPD linear operators used by the CG solvers.

The paper's benchmark problems are (a) a 2D 5-point finite-difference
Laplacian (PETSc KSP ex2), (b) a diagonal "toy" matrix carrying the 2D
Laplacian spectrum (the extremely communication-bound regime of Fig. 3/4),
and (c) a 3D FEM ice-sheet system (SNES ex48), which we stand in for with
anisotropic 3D stencils (see DESIGN.md §10).

All operators act on flat vectors of length ``n`` and are pure-JAX; the
stencil operators optionally route their hot loop through the Pallas
kernels in ``repro.kernels`` (``use_kernel=True``).

TPU adaptation note: the paper's PETSc backend stores general CSR (AIJ)
matrices; CSR SpMV is gather-bound and TPU-hostile.  Every benchmark matrix
in the paper is structurally a stencil, so we implement stencils natively
(shift-add on the grid; contiguous VMEM tiles in the kernel) — the
TPU-idiomatic equivalent of the same operator.  GENERAL sparse SPD
matrices (FEM meshes, SuiteSparse-class systems) live in
``repro.linalg.sparse.SparseOp`` — padded-row ELL storage with an RCM
partitioning layer (``repro.linalg.partition``) for the distributed
halo-gather SpMV (DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class LinearOperator:
    """SPD operator interface consumed by the solvers.

    Attributes
    ----------
    n : global problem size (flat vector length).
    """

    n: int

    def apply(self, x: jax.Array) -> jax.Array:  # A @ x
        raise NotImplementedError

    def diag(self) -> jax.Array:  # diagonal of A (for Jacobi-type preconditioners)
        raise NotImplementedError

    def to_dense(self) -> np.ndarray:  # small problems only (tests)
        eye = np.eye(self.n, dtype=np.float64)
        cols = [np.asarray(self.apply(jnp.asarray(eye[:, j]))) for j in range(self.n)]
        return np.stack(cols, axis=1)

    # Analytic spectral bounds where known; used for Chebyshev shifts.
    def eig_bounds(self) -> tuple[float, float]:
        raise NotImplementedError

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)


@dataclasses.dataclass(frozen=True)
class DiagonalOp(LinearOperator):
    """A = diag(d).  The paper's "one-point stencil" communication-bound toy."""

    d: jax.Array

    @property
    def n(self) -> int:  # type: ignore[override]
        return int(self.d.shape[0])

    def apply(self, x: jax.Array) -> jax.Array:
        return self.d.astype(x.dtype) * x

    def diag(self) -> jax.Array:
        return self.d

    def eig_bounds(self) -> tuple[float, float]:
        return float(jnp.min(self.d)), float(jnp.max(self.d))


def laplacian_2d_spectrum(nx: int, ny: int, dtype=jnp.float64) -> jax.Array:
    """Eigenvalues of the unscaled 2D 5-point Laplacian (Dirichlet), as a flat
    vector of length nx*ny:  4 - 2cos(i pi/(nx+1)) - 2cos(j pi/(ny+1))."""
    i = jnp.arange(1, nx + 1, dtype=dtype)
    j = jnp.arange(1, ny + 1, dtype=dtype)
    li = 2.0 - 2.0 * jnp.cos(i * jnp.pi / (nx + 1))
    lj = 2.0 - 2.0 * jnp.cos(j * jnp.pi / (ny + 1))
    return (li[:, None] + lj[None, :]).reshape(-1)


@dataclasses.dataclass(frozen=True)
class Stencil2D5(LinearOperator):
    """Unscaled 2D 5-point Laplacian with homogeneous Dirichlet BCs on an
    nx-by-ny grid (row-major, x outer / y inner):  (A x)_{ij} =
    4 x_{ij} - x_{i±1,j} - x_{i,j±1}.  PETSc KSP ex2's matrix."""

    nx: int
    ny: int
    use_kernel: bool = False

    @property
    def n(self) -> int:  # type: ignore[override]
        return self.nx * self.ny

    def apply(self, x: jax.Array) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.stencil2d5_apply(x.reshape(self.nx, self.ny)).reshape(-1)
        g = x.reshape(self.nx, self.ny)
        p = jnp.pad(g, 1)
        out = (
            4.0 * g
            - p[:-2, 1:-1]
            - p[2:, 1:-1]
            - p[1:-1, :-2]
            - p[1:-1, 2:]
        )
        return out.reshape(-1)

    def diag(self) -> jax.Array:
        return jnp.full((self.n,), 4.0)

    def eig_bounds(self) -> tuple[float, float]:
        lmin = (2 - 2 * np.cos(np.pi / (self.nx + 1))) + (2 - 2 * np.cos(np.pi / (self.ny + 1)))
        lmax = (2 - 2 * np.cos(self.nx * np.pi / (self.nx + 1))) + (
            2 - 2 * np.cos(self.ny * np.pi / (self.ny + 1))
        )
        return float(lmin), float(lmax)


@dataclasses.dataclass(frozen=True)
class Stencil3D7(LinearOperator):
    """Anisotropic 3D 7-point Laplacian, Dirichlet BCs, nx×ny×nz grid.

    ``eps_z`` < 1 mimics the thin-sheet vertical/horizontal aspect-ratio
    anisotropy of the Blatter/Pattyn ice-sheet system (SNES ex48 stand-in):
    (A x) = 2(1+1+eps_z) x - x_{i±1} - x_{j±1} - eps_z x_{k±1}.
    """

    nx: int
    ny: int
    nz: int
    eps_z: float = 1.0
    use_kernel: bool = False

    @property
    def n(self) -> int:  # type: ignore[override]
        return self.nx * self.ny * self.nz

    def apply(self, x: jax.Array) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.stencil3d7_apply(
                x.reshape(self.nx, self.ny, self.nz), self.eps_z
            ).reshape(-1)
        g = x.reshape(self.nx, self.ny, self.nz)
        p = jnp.pad(g, 1)
        ez = jnp.asarray(self.eps_z, dtype=x.dtype)
        out = (
            (4.0 + 2.0 * ez) * g
            - p[:-2, 1:-1, 1:-1]
            - p[2:, 1:-1, 1:-1]
            - p[1:-1, :-2, 1:-1]
            - p[1:-1, 2:, 1:-1]
            - ez * p[1:-1, 1:-1, :-2]
            - ez * p[1:-1, 1:-1, 2:]
        )
        return out.reshape(-1)

    def diag(self) -> jax.Array:
        return jnp.full((self.n,), 4.0 + 2.0 * self.eps_z)

    def eig_bounds(self) -> tuple[float, float]:
        def b(n):
            return 2 - 2 * np.cos(np.pi / (n + 1)), 2 - 2 * np.cos(n * np.pi / (n + 1))

        (ax, bx), (ay, by), (az, bz) = b(self.nx), b(self.ny), b(self.nz)
        return float(ax + ay + self.eps_z * az), float(bx + by + self.eps_z * bz)


@dataclasses.dataclass(frozen=True)
class Stencil3D27(LinearOperator):
    """3D 27-point stencil (trilinear FEM mass-like coupling): centre weight
    ``c``, face -1, edge -1/2, corner -1/4, scaled to stay SPD.  The denser
    stencil regime of FEM discretizations such as SNES ex48."""

    nx: int
    ny: int
    nz: int
    centre: float = 13.0  # > sum(|off-diag|) = 6 + 12/2 + 8/4 = 14 ⇒ use diag-dominant 14.5
    use_kernel: bool = False

    def __post_init__(self):
        if self.centre <= 14.0:
            object.__setattr__(self, "centre", 14.5)

    @property
    def n(self) -> int:  # type: ignore[override]
        return self.nx * self.ny * self.nz

    def apply(self, x: jax.Array) -> jax.Array:
        g = x.reshape(self.nx, self.ny, self.nz)
        p = jnp.pad(g, 1)
        out = self.centre * g
        for di in (-1, 0, 1):
            for dj in (-1, 0, 1):
                for dk in (-1, 0, 1):
                    order = abs(di) + abs(dj) + abs(dk)
                    if order == 0:
                        continue
                    w = {1: 1.0, 2: 0.5, 3: 0.25}[order]
                    out = out - w * p[
                        1 + di : 1 + di + self.nx,
                        1 + dj : 1 + dj + self.ny,
                        1 + dk : 1 + dk + self.nz,
                    ]
        return out.reshape(-1)

    def diag(self) -> jax.Array:
        return jnp.full((self.n,), self.centre)

    def eig_bounds(self) -> tuple[float, float]:
        # Gershgorin: centre ± 14 (loose but safe for Chebyshev shifts).
        return float(self.centre - 14.0), float(self.centre + 14.0)


@dataclasses.dataclass(frozen=True)
class DenseSPD(LinearOperator):
    """Explicit dense SPD matrix (property tests / oracles)."""

    a: jax.Array

    @property
    def n(self) -> int:  # type: ignore[override]
        return int(self.a.shape[0])

    def apply(self, x: jax.Array) -> jax.Array:
        return self.a @ x

    def diag(self) -> jax.Array:
        return jnp.diagonal(self.a)

    def to_dense(self) -> np.ndarray:
        return np.asarray(self.a, dtype=np.float64)

    def eig_bounds(self) -> tuple[float, float]:
        w = np.linalg.eigvalsh(np.asarray(self.a, dtype=np.float64))
        return float(w[0]), float(w[-1])


def random_spd(key: jax.Array, n: int, cond: float = 1e3, dtype=jnp.float64) -> DenseSPD:
    """Random SPD matrix with prescribed condition number (log-uniform spectrum)."""
    k1, k2 = jax.random.split(key)
    q, _ = jnp.linalg.qr(jax.random.normal(k1, (n, n), dtype=dtype))
    lam = jnp.logspace(0.0, jnp.log10(cond), n, dtype=dtype)
    lam = lam * (1.0 + 0.01 * jax.random.uniform(k2, (n,), dtype=dtype))
    return DenseSPD(a=(q * lam) @ q.T)
