from repro.linalg.operators import (
    DenseSPD,
    DiagonalOp,
    Stencil2D5,
    Stencil3D7,
    Stencil3D27,
    laplacian_2d_spectrum,
)
from repro.linalg.partition import PartitionPlan, partition_spd, plan_for
from repro.linalg.preconditioners import (
    BlockJacobi,
    IdentityPrec,
    JacobiPrec,
)
from repro.linalg.sparse import (
    SparseOp,
    random_fem_icesheet,
    random_fem_mesh,
    rcm_reorder,
    sparse_from_coo,
    sparse_from_dense,
)

__all__ = [
    "DenseSPD",
    "DiagonalOp",
    "Stencil2D5",
    "Stencil3D7",
    "Stencil3D27",
    "laplacian_2d_spectrum",
    "BlockJacobi",
    "IdentityPrec",
    "JacobiPrec",
    "SparseOp",
    "PartitionPlan",
    "partition_spd",
    "plan_for",
    "random_fem_icesheet",
    "random_fem_mesh",
    "rcm_reorder",
    "sparse_from_coo",
    "sparse_from_dense",
]
