from repro.linalg.operators import (
    DenseSPD,
    DiagonalOp,
    Stencil2D5,
    Stencil3D7,
    Stencil3D27,
    laplacian_2d_spectrum,
)
from repro.linalg.preconditioners import (
    BlockJacobi,
    IdentityPrec,
    JacobiPrec,
)

__all__ = [
    "DenseSPD",
    "DiagonalOp",
    "Stencil2D5",
    "Stencil3D7",
    "Stencil3D27",
    "laplacian_2d_spectrum",
    "BlockJacobi",
    "IdentityPrec",
    "JacobiPrec",
]
