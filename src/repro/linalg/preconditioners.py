"""Preconditioners M^{-1} for the CG family.

The paper pairs p(l)-CG with *limited-communication* preconditioners
(block Jacobi / no-overlap DDM — §1: "The argument for a longer pipeline
use case is stronger for preconditioners that use limited communication").
We provide:

  IdentityPrec  — unpreconditioned.
  JacobiPrec    — pointwise diagonal scaling.
  BlockJacobi   — contiguous row blocks, each solved with a precomputed
                  dense inverse of the block's diagonal sub-matrix.  For
                  grid-ordered stencil operators the blocks are (block-)
                  tridiagonal; one block per "processor" is the paper's
                  setup.  Application is a batched (nb, b, b) @ (nb, b)
                  matmul — MXU-friendly and communication-free, the TPU
                  equivalent of the per-rank ILU block solves on Cori.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.linalg.operators import LinearOperator


class Preconditioner:
    def apply(self, x: jax.Array) -> jax.Array:  # M^{-1} x
        raise NotImplementedError

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)


@dataclasses.dataclass(frozen=True)
class IdentityPrec(Preconditioner):
    def apply(self, x: jax.Array) -> jax.Array:
        return x


@dataclasses.dataclass(frozen=True)
class JacobiPrec(Preconditioner):
    inv_diag: jax.Array

    @staticmethod
    def from_operator(op: LinearOperator) -> "JacobiPrec":
        return JacobiPrec(inv_diag=1.0 / op.diag())

    def apply(self, x: jax.Array) -> jax.Array:
        return self.inv_diag.astype(x.dtype) * x


@dataclasses.dataclass(frozen=True)
class BlockJacobi(Preconditioner):
    """Block-Jacobi with precomputed dense block inverses.

    inv_blocks: (nb, b, b) — inverse of each diagonal block of A.
    """

    inv_blocks: jax.Array

    @staticmethod
    def from_operator(op: LinearOperator, block_size: int,
                      coupling_reach: int | None = None) -> "BlockJacobi":
        """Extract diagonal blocks by probing A with COLORED block-local
        basis vectors.

        Probing every block simultaneously would alias cross-block
        couplings that land at the same intra-block offset (e.g. the
        Laplacian's -1 at column r±ny) into the extracted blocks; colored
        probing activates only every ``n_colors``-th block so that all
        blocks within the operator's coupling reach of an active block are
        silent.  Cost: ``n_colors * block_size`` operator applications
        (independent of n).

        coupling_reach: max |i-j| with A[i,j] != 0.  Defaults to
        ``block_size`` (nearest-neighbour blocks — correct for the
        grid-ordered stencils here when the block spans >= one grid
        line), except for unstructured :class:`~repro.linalg.sparse.
        SparseOp` operators, whose true (post-RCM) bandwidth is measured
        instead — probing an irregular matrix with the stencil default
        would silently alias cross-block couplings into the extracted
        blocks (DESIGN.md §12).
        """
        n = op.n
        assert n % block_size == 0, (n, block_size)
        nb = n // block_size
        if coupling_reach is None:
            from repro.linalg.sparse import SparseOp, bandwidth

            reach = bandwidth(op) if isinstance(op, SparseOp) \
                else block_size
        else:
            reach = coupling_reach
        n_colors = min((reach + block_size - 1) // block_size + 2, nb)
        cols = []
        for j in range(block_size):
            col = jnp.zeros((nb, block_size))
            for c in range(n_colors):
                e = jnp.zeros((nb, block_size))
                e = e.at[c::n_colors, j].set(1.0)
                ae = op.apply(e.reshape(-1)).reshape(nb, block_size)
                col = col.at[c::n_colors].set(ae[c::n_colors])
            cols.append(col)
        blocks = jnp.stack(cols, axis=-1)  # (nb, b, b): rows×cols within block
        inv = jnp.linalg.inv(blocks.astype(jnp.float64))
        return BlockJacobi(inv_blocks=inv)

    def apply(self, x: jax.Array) -> jax.Array:
        nb, b, _ = self.inv_blocks.shape
        y = jnp.einsum(
            "nij,nj->ni", self.inv_blocks.astype(x.dtype), x.reshape(nb, b)
        )
        return y.reshape(-1)


def spd_check_blockjacobi(op: LinearOperator, block_size: int) -> bool:
    """Sanity helper (tests): block-Jacobi of an SPD matrix is SPD."""
    bj = BlockJacobi.from_operator(op, block_size)
    w = np.linalg.eigvalsh(np.asarray(bj.inv_blocks, dtype=np.float64))
    return bool((w > 0).all())
