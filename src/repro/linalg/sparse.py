"""General unstructured SPD sparse operators in block-ELL storage
(DESIGN.md §12).

The structured stencils in ``operators.py`` cover the paper's own
benchmark matrices, but the pipelining literature the reproduction tracks
— Cornelis/Cools/Vanroose (arXiv:1801.04728), Cools/Vanroose
(arXiv:1706.05988) — targets *general* SPD systems (FEM ice sheets,
SuiteSparse-style matrices) whose SpMV is an irregular gather plus
neighbour exchange.  ``SparseOp`` closes that gap:

* **Storage** is ELL (padded-row): every row holds exactly ``w`` =
  max-nnz-per-row (column, value) slots, padded slots carrying value 0
  and column 0.  Dense rectangular ``(n, w)`` arrays instead of CSR's
  ragged gather — the TPU-idiomatic layout (contiguous, (8,128)-tileable;
  the Pallas kernel in ``repro.kernels.ell_spmv`` consumes it directly).
* **Apply** is ``(vals * x[cols]).sum(-1)`` — one gather, one
  elementwise multiply, one small-axis reduction.  ``use_kernel=True``
  routes through the Pallas kernel (interpret mode off-TPU, as for the
  stencil kernels).
* **Distribution**: ``repro.linalg.partition`` orders rows with a
  bandwidth-reducing RCM pass, splits them into contiguous per-shard
  blocks, and precomputes the send/recv index sets that make the
  shard-level SpMV a local ELL product over [own rows | halo buffer]
  (DESIGN.md §12; wired in ``repro.parallel.distributed``).

Mesh generators at the bottom build random FEM-style SPD graph
Laplacians — the workload class ``configs/icesheet3d.py`` now routes
through instead of the anisotropic-stencil stand-in.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.linalg.operators import LinearOperator


def ell_rowsum(vals: jax.Array, gathered: jax.Array) -> jax.Array:
    """sum_s vals[..., s] * gathered[..., s] with an EXPLICIT left-to-right
    add chain over the (static, small) slot axis.

    ``.sum(axis=-1)`` lets XLA pick a reassociation that depends on the
    leading shape — the single-device apply and the shard-level apply
    would then round differently, and CG amplifies per-apply ULPs into
    visibly diverging residual histories.  A fixed chain keeps the local
    and distributed SpMV bitwise-identical on identical row data (the
    backend-parity contract of tests/test_distributed.py).
    """
    w = vals.shape[-1]
    acc = vals[..., 0] * gathered[..., 0]
    for s in range(1, w):
        acc = acc + vals[..., s] * gathered[..., s]
    return acc


@dataclasses.dataclass(frozen=True)
class SparseOp(LinearOperator):
    """SPD sparse operator in padded-row ELL storage.

    cols : (n, w) int32 — column index per slot (padded slots: 0).
    vals : (n, w)        — value per slot (padded slots: 0.0).
    ordered : True when the rows are already bandwidth-ordered (set by
        :func:`rcm_reorder`); the partitioner then skips its RCM pass.
    use_kernel : route ``apply`` through the Pallas ELL kernel
        (interpret mode off-TPU), as for the stencil operators.
    """

    cols: jax.Array
    vals: jax.Array
    ordered: bool = False
    use_kernel: bool = False

    @property
    def n(self) -> int:  # type: ignore[override]
        return int(self.cols.shape[0])

    @property
    def w(self) -> int:
        return int(self.cols.shape[1])

    @property
    def nnz(self) -> int:
        return int(np.count_nonzero(np.asarray(self.vals)))

    def apply(self, x: jax.Array) -> jax.Array:
        if self.use_kernel:
            return self.apply_kernel(x)
        return ell_rowsum(self.vals.astype(x.dtype), x[self.cols])

    def apply_kernel(self, x: jax.Array) -> jax.Array:
        """Route the hot loop through the Pallas ELL kernel
        (``repro.kernels.ops.ell_spmv_apply``; interpret mode off-TPU)."""
        from repro.kernels import ops as kops

        return kops.ell_spmv_apply(x, self.cols, self.vals)

    def diag(self) -> jax.Array:
        row = jnp.arange(self.n, dtype=self.cols.dtype)[:, None]
        return jnp.where(self.cols == row, self.vals, 0.0).sum(axis=-1)

    def to_dense(self) -> np.ndarray:
        cols = np.asarray(self.cols)
        vals = np.asarray(self.vals, dtype=np.float64)
        a = np.zeros((self.n, self.n))
        rows = np.repeat(np.arange(self.n), self.w)
        # += via add.at: padded slots accumulate 0.0 into column 0 — exact.
        np.add.at(a, (rows, cols.reshape(-1)), vals.reshape(-1))
        return a

    def eig_bounds(self) -> tuple[float, float]:
        """Lanczos estimates of the extremal eigenvalues (setup-time
        numpy; ~40 operator applies).

        Gershgorin is useless here — a graph Laplacian's lower disc edge
        sits at ~0 while the true lambda_min is O(shift), and the
        Chebyshev shift schedule (``core.chebyshev``) mis-scaled that way
        destabilizes the p(l)-CG basis (the sensitivity studied in
        arXiv:1706.05988).  A short Lanczos recurrence nails both
        extremes of an SPD matrix; the Ritz values are then widened
        (15% down, 5% up) because un-reorthogonalized Lanczos approaches
        lambda_min from above — Chebyshev shifts prefer slightly loose
        bounds over crossing the spectrum edge.
        """
        cols = np.asarray(self.cols)
        vals = np.asarray(self.vals, dtype=np.float64)

        def av(x):
            return (vals * x[cols]).sum(axis=-1)

        n = self.n
        m = min(max(2, n - 1), 60)
        rng = np.random.default_rng(0)
        v = rng.standard_normal(n)
        v /= np.linalg.norm(v)
        alphas, betas = [], []
        v_prev = np.zeros(n)
        beta = 0.0
        for _ in range(m):
            w = av(v) - beta * v_prev
            alpha = float(v @ w)
            w -= alpha * v
            alphas.append(alpha)
            beta = float(np.linalg.norm(w))
            if beta < 1e-12:
                break
            betas.append(beta)
            v_prev, v = v, w / beta
        t = np.diag(alphas)
        if betas:
            k = len(alphas)
            b = np.asarray(betas[: k - 1])
            t = t + np.diag(b, 1) + np.diag(b, -1)
        ritz = np.linalg.eigvalsh(t)
        lmin, lmax = float(ritz[0]), float(ritz[-1])
        return max(lmin * 0.85, 1e-10 * lmax), lmax * 1.05


def sparse_from_coo(n: int, rows, cols, vals, dtype=jnp.float64,
                    ordered: bool = False) -> SparseOp:
    """Build a :class:`SparseOp` from COO triplets (duplicates summed)."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    assert rows.shape == cols.shape == vals.shape
    assert rows.size == 0 or (rows.min() >= 0 and rows.max() < n)
    assert cols.size == 0 or (cols.min() >= 0 and cols.max() < n)
    # Coalesce duplicates, then pack rows into padded-ELL slots.
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    uniq, inv = np.unique(key, return_inverse=True)
    v = np.zeros(uniq.shape[0])
    np.add.at(v, inv, vals)
    r, c = uniq // n, uniq % n
    keep = v != 0.0
    r, c, v = r[keep], c[keep], v[keep]
    counts = np.bincount(r, minlength=n)
    w = max(int(counts.max(initial=0)), 1)
    slot = np.arange(r.size) - np.concatenate(
        ([0], np.cumsum(counts)))[r]
    ecols = np.zeros((n, w), dtype=np.int32)
    evals = np.zeros((n, w))
    ecols[r, slot] = c
    evals[r, slot] = v
    return SparseOp(cols=jnp.asarray(ecols),
                    vals=jnp.asarray(evals, dtype=dtype), ordered=ordered)


def sparse_from_dense(a: np.ndarray, dtype=jnp.float64,
                      tol: float = 0.0) -> SparseOp:
    """ELL-pack a dense matrix (tests / oracles)."""
    a = np.asarray(a, dtype=np.float64)
    r, c = np.nonzero(np.abs(a) > tol)
    return sparse_from_coo(a.shape[0], r, c, a[r, c], dtype=dtype)


# --------------------------------------------------------------------------
# Bandwidth-reducing ordering (reverse Cuthill–McKee, pure numpy).
# --------------------------------------------------------------------------

def _neighbor_csr(op: SparseOp) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Symmetrized adjacency in CSR-ish form, built with vectorized
    numpy (no per-edge Python loop): returns (deg, nbrs, starts) where
    node u's neighbours are ``nbrs[starts[u]:starts[u+1]]``, presorted
    by (degree, index) — the visit order Cuthill–McKee wants."""
    cols = np.asarray(op.cols)
    vals = np.asarray(op.vals)
    n = op.n
    rr, ss = np.nonzero(vals)
    cc = cols[rr, ss].astype(np.int64)
    keep = rr != cc
    i = np.concatenate([rr[keep], cc[keep]])
    j = np.concatenate([cc[keep], rr[keep]])     # symmetrize (A is SPD)
    key = np.unique(i * n + j)                   # dedupe directed pairs
    i, j = key // n, key % n
    deg = np.bincount(i, minlength=n)
    order = np.lexsort((j, deg[j], i))           # per-node (deg, idx) order
    nbrs = j[order]
    starts = np.concatenate(([0], np.cumsum(deg)))
    return deg, nbrs, starts


def rcm_permutation(op: SparseOp) -> np.ndarray:
    """Reverse Cuthill–McKee ordering: ``perm[new] = old``.

    BFS from a minimum-degree seed per connected component, neighbours
    visited in increasing-degree order, final order reversed — the
    classic bandwidth-reducing heuristic that makes contiguous row blocks
    a good partition (remote columns concentrate in the adjacent blocks).
    Adjacency construction is vectorized and the queue is a deque, so
    config-scale meshes (the 500k-node ``icesheet3d``) order in seconds.
    """
    from collections import deque

    n = op.n
    deg, nbrs, starts = _neighbor_csr(op)
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    for seed in np.argsort(deg, kind="stable"):
        if visited[seed]:
            continue
        visited[seed] = True
        queue = deque([int(seed)])
        while queue:
            u = queue.popleft()
            order[pos] = u
            pos += 1
            for v in nbrs[starts[u]:starts[u + 1]]:
                if not visited[v]:
                    visited[v] = True
                    queue.append(int(v))
    assert pos == n
    return order[::-1].copy()


def bandwidth(op: SparseOp) -> int:
    """max |i - j| over structural nonzeros."""
    cols = np.asarray(op.cols)
    vals = np.asarray(op.vals)
    rows = np.arange(op.n)[:, None]
    d = np.abs(rows - cols)
    return int(np.where(vals != 0.0, d, 0).max(initial=0))


def permute_spd(op: SparseOp, perm: np.ndarray,
                ordered: bool = False) -> SparseOp:
    """Symmetric permutation P A P^T with ``perm[new] = old``."""
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    cols = np.asarray(op.cols)
    vals = np.asarray(op.vals)
    rows = np.repeat(np.arange(op.n), op.w)
    keep = vals.reshape(-1) != 0.0
    r = inv[rows[keep]]
    c = inv[cols.reshape(-1)[keep]]
    return sparse_from_coo(op.n, r, c, vals.reshape(-1)[keep],
                           dtype=op.vals.dtype, ordered=ordered)


def rcm_reorder(op: SparseOp) -> tuple[SparseOp, np.ndarray]:
    """(RCM-ordered operator, perm) with ``perm[new] = old``.  The
    returned operator has ``ordered=True`` so the partitioner skips its
    own RCM pass.  Solve the permuted system with ``b[perm]`` and map the
    solution back with ``x_orig = x_perm[inv_perm]`` (``np.argsort(perm)``)
    — ``repro.parallel.distributed`` does this automatically."""
    perm = rcm_permutation(op)
    return permute_spd(op, perm, ordered=True), perm


# --------------------------------------------------------------------------
# Sliced ELL: degree-sorted row buckets, per-slice padding (DESIGN.md §13).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SlicedEllOp(LinearOperator):
    """Sliced-ELL storage: rows sorted by nonzero count and cut into
    slices of ``slice_rows`` rows, each slice padded only to ITS OWN max
    row length instead of the global max.

    Uniform padded-row ELL pays ``w_max`` slots for every row; on
    irregular FEM meshes (degree spread ~4..14) that left ~42% of the
    streamed bytes as padding (``BENCH_spmv.json`` showed occupancy
    0.58).  Degree sorting concentrates equal-length rows into the same
    slice, so per-slice widths hug the true row lengths — occupancy
    rises to >= 0.85 on the same mesh and the SpMV streams proportionally
    fewer value/column bytes.  The permutation COMPOSES with the RCM
    ordering (:func:`sliced_ell_reorder`), and the slice table is static,
    so ``apply`` is one small fixed set of gather+rowsum ops.
    """

    slice_rows: int
    slice_cols: tuple        # per-slice (rows_s, w_s) int32 arrays
    slice_vals: tuple        # per-slice (rows_s, w_s) value arrays

    @property
    def n(self) -> int:  # type: ignore[override]
        return sum(int(c.shape[0]) for c in self.slice_cols)

    @property
    def nnz(self) -> int:
        return int(sum(np.count_nonzero(np.asarray(v))
                       for v in self.slice_vals))

    @property
    def padded_slots(self) -> int:
        return int(sum(c.shape[0] * c.shape[1] for c in self.slice_cols))

    def occupancy(self) -> float:
        """Useful fraction of stored slots (the gated bench metric)."""
        return self.nnz / max(self.padded_slots, 1)

    def padding_waste(self) -> float:
        """Fraction of streamed slots that are padding (1 - occupancy)."""
        return 1.0 - self.occupancy()

    def apply(self, x: jax.Array) -> jax.Array:
        parts = [ell_rowsum(v.astype(x.dtype), x[c])
                 for c, v in zip(self.slice_cols, self.slice_vals)]
        return jnp.concatenate(parts)

    def diag(self) -> jax.Array:
        offs = np.cumsum([0] + [int(c.shape[0]) for c in self.slice_cols])
        parts = []
        for s, (c, v) in enumerate(zip(self.slice_cols, self.slice_vals)):
            row = jnp.arange(offs[s], offs[s + 1], dtype=c.dtype)[:, None]
            parts.append(jnp.where(c == row, v, 0.0).sum(axis=-1))
        return jnp.concatenate(parts)

    def to_dense(self) -> np.ndarray:
        n = self.n
        a = np.zeros((n, n))
        off = 0
        for c, v in zip(self.slice_cols, self.slice_vals):
            cc = np.asarray(c)
            vv = np.asarray(v, dtype=np.float64)
            rows = np.repeat(np.arange(off, off + cc.shape[0]), cc.shape[1])
            np.add.at(a, (rows, cc.reshape(-1)), vv.reshape(-1))
            off += cc.shape[0]
        return a


def degree_sort_permutation(op: SparseOp) -> np.ndarray:
    """Stable row permutation by DESCENDING nonzero count
    (``perm[new] = old``): whatever bucket size the caller slices with,
    rows of similar length end up adjacent, which is what makes
    per-slice padding tight.  Stability preserves the relative (RCM)
    order within each degree class, keeping gather locality."""
    lengths = np.count_nonzero(np.asarray(op.vals), axis=1)
    return np.argsort(-lengths, kind="stable").astype(np.int64)


def sliced_ell_reorder(op: SparseOp, slice_rows: int = 64
                       ) -> tuple[SlicedEllOp, np.ndarray]:
    """(sliced operator, perm) with ``perm[new] = old`` in the ORIGINAL
    row numbering: the degree-sort permutation composed with the
    operator's RCM ordering (applied first when ``op`` is not already
    ``ordered``).  Solve with ``b[perm]`` / un-permute with
    ``np.argsort(perm)`` exactly as for :func:`rcm_reorder`."""
    if op.ordered:
        base, base_perm = op, np.arange(op.n, dtype=np.int64)
    else:
        base, base_perm = rcm_reorder(op)
    dperm = degree_sort_permutation(base)
    perm = base_perm[dperm]
    sorted_op = permute_spd(base, dperm, ordered=False)
    cols = np.asarray(sorted_op.cols)
    vals = np.asarray(sorted_op.vals)
    lengths = np.count_nonzero(vals, axis=1)
    sc, sv = [], []
    for r0 in range(0, op.n, slice_rows):
        r1 = min(r0 + slice_rows, op.n)
        w_s = max(int(lengths[r0:r1].max(initial=1)), 1)
        sc.append(jnp.asarray(cols[r0:r1, :w_s]))
        sv.append(jnp.asarray(vals[r0:r1, :w_s], dtype=op.vals.dtype))
    return SlicedEllOp(slice_rows=slice_rows, slice_cols=tuple(sc),
                       slice_vals=tuple(sv)), perm


# --------------------------------------------------------------------------
# Random FEM-style meshes (SPD graph Laplacians).
# --------------------------------------------------------------------------

def random_fem_mesh(seed: int, n_nodes: int, avg_degree: float = 6.0,
                    shift: float = 0.05, dtype=jnp.float64) -> SparseOp:
    """Random FEM-style SPD system: weighted graph Laplacian + mass shift.

    Nodes are random points in the unit square; each connects to its
    nearest neighbours (symmetrized) with weights 1/distance — the
    stiffness pattern of an unstructured 2D triangulation.  ``shift``
    adds ``shift * mean(diag) * I`` (the mass/boundary term) so the
    operator is strictly SPD.
    """
    rng = np.random.default_rng(seed)
    pts = rng.uniform(size=(n_nodes, 2))
    k = max(int(round(avg_degree)), 2)
    # k-nearest-neighbour graph via brute-force distances (setup-time
    # numpy; fine for the config/test sizes this serves).
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    nbr = np.argsort(d2, axis=1)[:, :k]
    rows = np.repeat(np.arange(n_nodes), k)
    cols = nbr.reshape(-1)
    wgt = 1.0 / np.sqrt(d2[rows, cols] + 1e-12)
    # Symmetrize: keep max weight per undirected edge.
    i = np.minimum(rows, cols)
    j = np.maximum(rows, cols)
    key = i * n_nodes + j
    order = np.argsort(key, kind="stable")
    key, i, j, wgt = key[order], i[order], j[order], wgt[order]
    uniq, first = np.unique(key, return_index=True)
    i, j, wgt = i[first], j[first], wgt[first]
    return _graph_laplacian(n_nodes, i, j, wgt, shift, dtype)


def random_fem_icesheet(seed: int, nx: int, ny: int, nz: int,
                        eps_z: float = 0.01, shift: float = 0.05,
                        dtype=jnp.float64) -> SparseOp:
    """Unstructured thin-sheet stand-in for SNES ex48 (DESIGN.md §12):
    a jittered nx×ny footprint mesh extruded through nz layers, with
    horizontal conductances O(1) and vertical conductances ``eps_z`` —
    the vertical/horizontal aspect-ratio anisotropy of the Blatter/Pattyn
    ice-sheet system, on an *irregular* graph instead of a stencil."""
    rng = np.random.default_rng(seed)
    # Jittered structured footprint: irregular geometry, mesh-like topology.
    gx, gy = np.meshgrid(np.arange(nx, dtype=float),
                         np.arange(ny, dtype=float), indexing="ij")
    pts = np.stack([gx, gy], axis=-1).reshape(-1, 2)
    pts += rng.uniform(-0.35, 0.35, size=pts.shape)
    nf = nx * ny

    def fid(ix, iy):
        return ix * ny + iy

    fi, fj = [], []
    for ix in range(nx):
        for iy in range(ny):
            if ix + 1 < nx:
                fi.append(fid(ix, iy)); fj.append(fid(ix + 1, iy))
            if iy + 1 < ny:
                fi.append(fid(ix, iy)); fj.append(fid(ix, iy + 1))
            # Random diagonal per cell — breaks the structured stencil
            # pattern the same way an unstructured triangulation would.
            if ix + 1 < nx and iy + 1 < ny:
                if rng.uniform() < 0.5:
                    fi.append(fid(ix, iy)); fj.append(fid(ix + 1, iy + 1))
                else:
                    fi.append(fid(ix + 1, iy)); fj.append(fid(ix, iy + 1))
    fi = np.asarray(fi); fj = np.asarray(fj)
    dist = np.sqrt(((pts[fi] - pts[fj]) ** 2).sum(-1))
    fw = 1.0 / (dist + 1e-6)

    # Extrude: node (f, iz) = f * nz + iz; horizontal edges per layer,
    # weak vertical edges between layers.
    i = (fi[:, None] * nz + np.arange(nz)[None, :]).reshape(-1)
    j = (fj[:, None] * nz + np.arange(nz)[None, :]).reshape(-1)
    w = np.repeat(fw, nz)
    vf = np.arange(nf)
    vi = (vf[:, None] * nz + np.arange(nz - 1)[None, :]).reshape(-1)
    i = np.concatenate([i, vi])
    j = np.concatenate([j, vi + 1])
    w = np.concatenate([w, np.full(vi.shape, eps_z)])
    return _graph_laplacian(nf * nz, i, j, w, shift, dtype)


def _graph_laplacian(n: int, i, j, w, shift: float, dtype) -> SparseOp:
    """SPD operator  L + shift*mean(deg)*I  from undirected edges."""
    rows = np.concatenate([i, j, i, j])
    cols = np.concatenate([j, i, i, j])
    vals = np.concatenate([-w, -w, w, w])
    deg = np.zeros(n)
    np.add.at(deg, i, w)
    np.add.at(deg, j, w)
    c = shift * float(deg.mean())
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    vals = np.concatenate([vals, np.full(n, c)])
    return sparse_from_coo(n, rows, cols, vals, dtype=dtype)
