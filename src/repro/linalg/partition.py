"""Distributed partitioning of unstructured sparse operators
(DESIGN.md §12).

The structured stencils get their halo for free — one boundary plane per
neighbour.  A general :class:`~repro.linalg.sparse.SparseOp` needs the
same thing *computed*: which of my rows do my neighbours reference, and
where do their values land in my local gather?  This module turns an
operator into a :class:`PartitionPlan`:

1.  **Order** — a bandwidth-reducing RCM pass
    (``sparse.rcm_permutation``) so that contiguous row blocks are a good
    partition: after ordering, the remote columns of shard ``i``
    concentrate in the few adjacent shards (exactly the role the domain
    decomposition plays in the paper's MPI runs).
2.  **Split** — ``n_shards`` contiguous row blocks of ``nxl = n/S`` rows.
3.  **Index sets** — per shard and per hop distance ``h`` (1..hops,
    where ``hops = ceil(bandwidth / nxl)``), the *send sets*: the local
    row indices shard ``i±h`` actually references, padded to the global
    max so every shard ships fixed-size buffers (shard_map needs uniform
    shapes).  Column indices of the local ELL blocks are remapped into
    the *extended local vector*  ``[own rows | recv-from-prev (hops
    slabs) | recv-from-next (hops slabs)]``, so the shard-level SpMV is:
    gather send buffers → one ``lax.ppermute`` per (direction, hop) —
    the MPI neighbour send/recv — → one local ELL product.  No global
    gather; RCM keeps ``hops`` at 1 for mesh-like matrices, the
    multi-hop path is the correctness fallback for wide-bandwidth rows.

The ppermutes are tagged with ``HALO_TAG`` so the overlap tracer
(``repro.utils.trace``) can verify they are scheduled *inside* the
in-flight reduction windows — the paper's Iallreduce/neighbour-exchange
staggering, measured on compiled HLO (DESIGN.md §12).

Plans are memoized by operator fingerprint (:func:`plan_for`); the
serving layer's :class:`repro.serve.cache.SetupCache` fronts the same
cache with its own hit/miss stats.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.types import HALO_TAG
from repro.linalg.sparse import (
    SparseOp,
    bandwidth,
    ell_rowsum,
    permute_spd,
    rcm_permutation,
)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Static per-shard data for a distributed unstructured SpMV.

    All per-shard arrays are stacked on a leading shard axis (sharded by
    ``P(axis)`` under shard_map) and padded to uniform sizes.

    cols : (S, nxl, w) int32 — ELL column slots remapped into the
        extended local vector [0, nxl + 2*hops*max_send).
    vals : (S, nxl, w) — ELL values (padded slots 0.0).
    send_up : (S, hops, max_send) int32 — local rows shard i ships to
        shard i+h (hop slab h-1); send_dn symmetrically to i-h.
    perm : (n,) int64 — global ordering used (``perm[new] = old``);
        identity when the operator was pre-ordered.
    """

    n_shards: int
    n: int
    nxl: int
    hops: int
    max_send: int
    cols: jax.Array
    vals: jax.Array
    send_up: jax.Array
    send_dn: jax.Array
    perm: np.ndarray
    band: int                      # post-ordering bandwidth (diagnostics)

    @property
    def inv_perm(self) -> np.ndarray:
        inv = np.empty_like(self.perm)
        inv[self.perm] = np.arange(self.perm.size)
        return inv

    @property
    def identity_perm(self) -> bool:
        return bool((self.perm == np.arange(self.perm.size)).all())

    def neighbor_bytes(self, dsize: int = 8) -> int:
        """Per-iteration halo SEND bytes of one shard (both directions,
        all hops; receives overlap on a full-duplex link) — the term the
        autotuner cost model folds in
        (``launch.autotune.model_iteration_time``'s ``neighbor_bytes``,
        DESIGN.md §12).  Same convention as the structured operators:
        a Stencil2D5 shard reports 2*ny*dsize (one plane per direction),
        matching ``timing_model.stencil_kernel_times``'s
        per-direction ``halo_elems`` with its built-in 2x multiplier."""
        return 2 * self.hops * self.max_send * dsize

    def occupancy(self) -> float:
        """Useful fraction of ELL slots (1.0 = no padding waste)."""
        v = np.asarray(self.vals)
        return float(np.count_nonzero(v) / v.size)

    def halo_rows_fraction(self) -> float:
        """Halo rows shipped per shard relative to rows owned."""
        return 2.0 * self.hops * self.max_send / self.nxl


def partition_spd(op: SparseOp, n_shards: int) -> PartitionPlan:
    """Build the :class:`PartitionPlan` for ``op`` over ``n_shards``.

    Requires ``op.n % n_shards == 0`` (the mesh generators take arbitrary
    node counts — pad there).  The hop count is ``ceil(band / nxl)`` with
    ``band`` the post-RCM bandwidth; mesh-like matrices order to
    ``hops == 1`` (the structured-stencil regime), anything wider pays
    proportionally more ppermutes but stays correct.
    """
    n = op.n
    assert n % n_shards == 0, (
        f"unstructured partition needs n % n_shards == 0 (n={n}, "
        f"S={n_shards}); pad the mesh generator's node count")
    if op.ordered or n_shards == 1:
        perm = np.arange(n, dtype=np.int64)
        oop = op
    else:
        perm = rcm_permutation(op)
        oop = permute_spd(op, perm, ordered=True)
    nxl = n // n_shards
    band = bandwidth(oop)
    hops = min(max(-(-band // nxl), 1), n_shards - 1) if n_shards > 1 else 1

    cols = np.asarray(oop.cols)
    vals = np.asarray(oop.vals)
    w = oop.w
    nz = vals != 0.0
    starts = np.arange(n_shards) * nxl

    # --- send sets: which of shard s's rows does shard s±h touch? -------
    def _referenced(reader: int, owner: int) -> np.ndarray:
        """Column indices (local to ``owner``) that ``reader`` references."""
        rlo, rhi = starts[reader], starts[reader] + nxl
        olo, ohi = starts[owner], starts[owner] + nxl
        c = cols[rlo:rhi][nz[rlo:rhi]]
        c = c[(c >= olo) & (c < ohi)]
        return np.unique(c) - olo

    empty = np.empty(0, dtype=np.int64)
    send_up = [[_referenced(s + h, s) if s + h < n_shards else empty
                for h in range(1, hops + 1)] for s in range(n_shards)]
    send_dn = [[_referenced(s - h, s) if s - h >= 0 else empty
                for h in range(1, hops + 1)] for s in range(n_shards)]
    max_send = max(
        1, max((len(a) for row in send_up + send_dn for a in row),
               default=1))

    # --- remap ELL columns into the extended local vector ----------------
    # Layout per shard: [own rows (nxl) | from-prev hop 1..hops |
    # from-next hop 1..hops], each halo slab max_send wide.  from-prev
    # slab h-1 holds the up(h)-send buffer of shard s-h, so a column
    # owned by s-h maps to nxl + (h-1)*max_send + its position in
    # send_up[s-h][h-1]; symmetrically for s+h via send_dn[s+h][h-1].
    ext = nxl + 2 * hops * max_send
    cols_l = np.zeros((n_shards, nxl, w), dtype=np.int32)
    vals_l = np.zeros((n_shards, nxl, w))
    for s in range(n_shards):
        lo, hi = starts[s], starts[s] + nxl
        c = cols[lo:hi].astype(np.int64)
        v = vals[lo:hi]
        rnz = v != 0.0
        local = (c >= lo) & (c < hi)
        out = np.zeros_like(c)
        out[local] = c[local] - lo
        covered = local | ~rnz
        for h in range(1, hops + 1):
            if s - h >= 0:
                olo = starts[s - h]
                m = rnz & (c >= olo) & (c < olo + nxl)
                pos = np.searchsorted(send_up[s - h][h - 1], c[m] - olo)
                out[m] = nxl + (h - 1) * max_send + pos
                covered |= m
            if s + h < n_shards:
                olo = starts[s + h]
                m = rnz & (c >= olo) & (c < olo + nxl)
                pos = np.searchsorted(send_dn[s + h][h - 1], c[m] - olo)
                out[m] = nxl + (hops + h - 1) * max_send + pos
                covered |= m
        assert covered.all(), "halo remap missed a referenced column"
        assert (out[rnz] < ext).all()
        cols_l[s] = out
        vals_l[s] = v

    def _pad(sets):
        a = np.zeros((n_shards, hops, max_send), dtype=np.int32)
        for s in range(n_shards):
            for h in range(hops):
                idx = sets[s][h]
                a[s, h, :len(idx)] = idx
        return a

    dtype = oop.vals.dtype
    return PartitionPlan(
        n_shards=n_shards, n=n, nxl=nxl, hops=hops, max_send=max_send,
        cols=jnp.asarray(cols_l), vals=jnp.asarray(vals_l, dtype=dtype),
        send_up=jnp.asarray(_pad(send_up)), send_dn=jnp.asarray(_pad(send_dn)),
        perm=perm, band=band,
    )


# --------------------------------------------------------------------------
# Shard-level apply (runs INSIDE shard_map).
# --------------------------------------------------------------------------

def halo_exchange(x_local: jax.Array, send_up: jax.Array,
                  send_dn: jax.Array, axis: str) -> jax.Array:
    """Extended local vector via the precomputed send sets.

    One ``lax.ppermute`` per (direction, hop) — the MPI neighbour
    send/recv — wrapped in the ``HALO_TAG`` scope so the overlap tracer
    can locate the exchanges in the compiled schedule and assert they
    ride inside the in-flight reduction windows (DESIGN.md §12).
    ``ppermute`` yields zeros where no peer exists, which is exactly the
    empty halo at the domain ends.
    """
    hops, max_send = send_up.shape
    with jax.named_scope(HALO_TAG):
        n = int(lax.psum(1, axis)) if not hasattr(lax, "axis_size") \
            else lax.axis_size(axis)
        slabs = [x_local]
        from_prev, from_next = [], []
        for h in range(1, hops + 1):
            up_buf = x_local[send_up[h - 1]]   # rows shard i+h needs
            dn_buf = x_local[send_dn[h - 1]]   # rows shard i-h needs
            if n > h:
                from_prev.append(lax.ppermute(
                    up_buf, axis, [(i, i + h) for i in range(n - h)]))
                from_next.append(lax.ppermute(
                    dn_buf, axis, [(i, i - h) for i in range(h, n)]))
            else:
                z = jnp.zeros((max_send,), x_local.dtype)
                from_prev.append(z)
                from_next.append(z)
        return jnp.concatenate(slabs + from_prev + from_next)


def apply_local(x_local: jax.Array, cols: jax.Array, vals: jax.Array,
                send_up: jax.Array, send_dn: jax.Array, axis: str,
                use_kernel: bool = False) -> jax.Array:
    """Shard-level unstructured SpMV: halo exchange + local ELL product."""
    xe = halo_exchange(x_local, send_up, send_dn, axis)
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.ell_spmv_apply(xe, cols, vals)
    # ell_rowsum (not .sum) so this rounds bitwise-identically to the
    # single-device SparseOp.apply — see sparse.ell_rowsum.
    return ell_rowsum(vals.astype(x_local.dtype), xe[cols])


def emulate_partitioned_apply(plan: PartitionPlan,
                              xp: np.ndarray) -> np.ndarray:
    """Pure-numpy reference of halo_exchange + apply_local (the oracle
    the partition tests compare against): gather each shard's send sets,
    'ppermute' them by array slicing, ELL-multiply.  ``xp`` must already
    be in the plan's ordering (``x[plan.perm]``)."""
    cols = np.asarray(plan.cols)
    vals = np.asarray(plan.vals)
    su = np.asarray(plan.send_up)
    sd = np.asarray(plan.send_dn)
    S, nxl, H, ms = plan.n_shards, plan.nxl, plan.hops, plan.max_send
    y = np.zeros(plan.n)
    for s in range(S):
        xl = xp[s * nxl:(s + 1) * nxl]
        fp, fn = [], []
        for h in range(1, H + 1):
            fp.append(xp[(s - h) * nxl:(s - h + 1) * nxl][su[s - h, h - 1]]
                      if s - h >= 0 else np.zeros(ms))
            fn.append(xp[(s + h) * nxl:(s + h + 1) * nxl][sd[s + h, h - 1]]
                      if s + h < S else np.zeros(ms))
        xe = np.concatenate([xl] + fp + fn)
        y[s * nxl:(s + 1) * nxl] = (vals[s] * xe[cols[s]]).sum(axis=1)
    return y


# --------------------------------------------------------------------------
# Plan memoization (the serving layer's SetupCache fronts this).
# --------------------------------------------------------------------------

_PLAN_CACHE: dict[tuple, PartitionPlan] = {}


def plan_for(op: SparseOp, n_shards: int) -> PartitionPlan:
    """Memoized :func:`partition_spd` keyed by operator fingerprint —
    RCM + send-set construction is setup-time numpy work that must be
    paid once per operator, not once per solve (DESIGN.md §11/§12)."""
    from repro.serve.cache import operator_fingerprint

    key = (operator_fingerprint(op), n_shards)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = partition_spd(op, n_shards)
        _PLAN_CACHE[key] = plan
    return plan
