"""Shared building blocks: norms, MLPs, rotary embeddings, initializers."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(cfg, x, p):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p.get("bias"))


def norm_params(cfg, d, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layer":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ------------------------------------------------------------------ MLP ---

def mlp_params(key, d_model, d_ff, act, dtype, bias=False, out_scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    p = {}
    if act == "swiglu":
        p["wi"] = jax.random.normal(k1, (d_model, d_ff), dtype) * std
        p["wg"] = jax.random.normal(k2, (d_model, d_ff), dtype) * std
    else:
        p["wi"] = jax.random.normal(k1, (d_model, d_ff), dtype) * std
    p["wo"] = jax.random.normal(k3, (d_ff, d_model), dtype) * std * out_scale
    if bias:
        p["bi"] = jnp.zeros((d_ff,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(p, x, act: str):
    h = x @ p["wi"].astype(x.dtype)
    if "bi" in p:
        h = h + p["bi"].astype(x.dtype)
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"].astype(x.dtype))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    out = h @ p["wo"].astype(x.dtype)
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out


# ---------------------------------------------------------------- rotary --

def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> tuple:
    """positions (..., T) -> cos/sin (..., T, head_dim//2) in f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, T, H, D); cos/sin (B, T, half) or (T, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def mrope_freqs(head_dim: int, theta: float, pos3: jax.Array, sections) -> tuple:
    """M-RoPE (qwen2-vl): pos3 (B, 3, T) = (t, h, w) position ids; the
    half-dim frequency bands are split into ``sections`` (sum = head_dim//2),
    each band rotated by its own coordinate."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos3.astype(jnp.float32)[..., None] * inv          # (B, 3, T, half)
    pieces_c, pieces_s = [], []
    start = 0
    for axis, sec in enumerate(sections):
        a = ang[:, axis, :, start : start + sec]
        pieces_c.append(jnp.cos(a))
        pieces_s.append(jnp.sin(a))
        start += sec
    return jnp.concatenate(pieces_c, -1), jnp.concatenate(pieces_s, -1)


def text_pos3(positions: jax.Array) -> jax.Array:
    """(B, T) -> (B, 3, T): text tokens use t = h = w = pos (qwen2-vl)."""
    return jnp.broadcast_to(positions[:, None, :], (positions.shape[0], 3, positions.shape[1]))


# ------------------------------------------------------------- embedding --

def embed_params(key, vocab_padded, d_model, dtype):
    return {"table": jax.random.normal(key, (vocab_padded, d_model), dtype) * 0.02}


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Logits (B, T, Vp). Vocab-padded entries are masked by the loss."""
    return x @ p["table"].astype(x.dtype).T


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean CE over all positions; padded vocab tail masked out."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab:
        neg = jnp.full((vp - vocab,), -1e30, jnp.float32)
        logits = logits.at[..., vocab:].add(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
