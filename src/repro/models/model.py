"""Model assembly for all assigned architecture families.

Parameters are nested dicts with per-layer leaves STACKED on a leading L
dimension, consumed by ``lax.scan`` over layers (+ ``jax.checkpoint``) so
the lowered HLO is O(1) in depth — this is what keeps the 64-layer
command-r dry-run compile tractable and is the production remat policy.

Entry points (uniform across families):
    init(key)                          -> params
    loss_fn(params, batch)             -> (loss, metrics)      [train_4k]
    prefill(params, batch)             -> (logits_last, cache) [prefill_32k]
    decode_step(params, token, cache)  -> (logits, cache)      [decode_*]
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.config import ArchConfig

Params = Any

# Layer-scan unroll control.  The dry-run's roofline pass sets this to True
# on REDUCED-depth configs so XLA cost_analysis counts every layer (a rolled
# scan body is counted once); production/smoke paths keep the rolled scan.
SCAN_UNROLL: int | bool = 1


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=SCAN_UNROLL)


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def _stack(key, n, make):
    return jax.vmap(make)(jax.random.split(key, n))


# ===========================================================================
@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ArchConfig

    # ------------------------------------------------------------- init --
    def init(self, key) -> Params:
        cfg = self.cfg
        pdt = _dt(cfg.param_dtype)
        k_emb, k_lyr, k_head, k_extra = jax.random.split(key, 4)
        out_scale = 1.0 / max(1.0, (2.0 * cfg.n_layers) ** 0.5)
        p: dict = {
            "embed": cm.embed_params(k_emb, cfg.vocab_padded, cfg.d_model, pdt),
            "head": cm.embed_params(k_head, cfg.vocab_padded, cfg.d_model, pdt),
            "final_norm": cm.norm_params(cfg, cfg.d_model, pdt),
        }

        def dense_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "attn": attn.attn_params(k1, cfg, dtype=pdt, out_scale=out_scale),
                "mlp": cm.mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, pdt,
                                     bias=cfg.bias, out_scale=out_scale),
                "ln1": cm.norm_params(cfg, cfg.d_model, pdt),
                "ln2": cm.norm_params(cfg, cfg.d_model, pdt),
            }

        fam = cfg.family
        if fam in ("dense", "vlm"):
            p["layers"] = _stack(k_lyr, cfg.n_layers, dense_layer)
        elif fam == "moe":
            def moe_layer(k):
                k1, k2 = jax.random.split(k)
                return {
                    "attn": attn.attn_params(k1, cfg, dtype=pdt, out_scale=out_scale),
                    "moe": moe_mod.moe_params(k2, cfg, pdt, out_scale=out_scale),
                    "ln1": cm.norm_params(cfg, cfg.d_model, pdt),
                    "ln2": cm.norm_params(cfg, cfg.d_model, pdt),
                }
            p["layers"] = _stack(k_lyr, cfg.n_layers, moe_layer)
        elif fam == "ssm":
            p["layers"] = _stack(k_lyr, cfg.n_layers,
                                 lambda k: rk.rwkv6_params(k, cfg, pdt, out_scale))
        elif fam == "hybrid":
            p["layers"] = _stack(k_lyr, cfg.n_layers,
                                 lambda k: mb.mamba2_params(k, cfg, pdt, out_scale))
            # weight-SHARED attention block over concat([h, embed]) (2d)
            ks = jax.random.split(k_extra, 3)
            shared_cfg = cfg.replace(head_dim=2 * cfg.d_model // cfg.n_heads)
            p["shared"] = {
                "attn": attn.attn_params(ks[0], shared_cfg, d_model=2 * cfg.d_model,
                                         dtype=pdt, out_scale=out_scale),
                "ln": cm.norm_params(cfg, 2 * cfg.d_model, pdt),
                "proj": jax.random.normal(
                    ks[1], (shared_cfg.n_heads * shared_cfg.hd, cfg.d_model), pdt
                ) * 0.02 * out_scale,
                "mlp": cm.mlp_params(ks[2], cfg.d_model, cfg.d_ff, cfg.act,
                                     pdt, out_scale=out_scale),
                "ln2": cm.norm_params(cfg, cfg.d_model, pdt),
            }
        elif fam == "encdec":
            p["enc_layers"] = _stack(k_extra, cfg.n_enc_layers, dense_layer)
            p["enc_norm"] = cm.norm_params(cfg, cfg.d_model, pdt)

            def dec_layer(k):
                k1, k2 = jax.random.split(k)
                d = dense_layer(k1)
                d["cross"] = attn.attn_params(k2, cfg, dtype=pdt, out_scale=out_scale)
                d["ln3"] = cm.norm_params(cfg, cfg.d_model, pdt)
                return d
            p["layers"] = _stack(k_lyr, cfg.n_layers, dec_layer)
        else:
            raise ValueError(fam)
        return p

    # ------------------------------------------------------- positional --
    def _cos_sin(self, positions, batch_shape=None, pos3=None):
        cfg = self.cfg
        if cfg.mrope_sections:
            assert pos3 is not None
            return cm.mrope_freqs(cfg.hd, cfg.rope_theta, pos3, cfg.mrope_sections)
        return cm.rope_freqs(cfg.hd, cfg.rope_theta, positions)

    # --------------------------------------------------------- forward ---
    def _dense_block(self, p, x, cos_sin, enc_out=None):
        cfg = self.cfg
        if cfg.parallel_block:
            h = cm.apply_norm(cfg, x, p["ln1"])
            x = x + attn.attention_train(p["attn"], cfg, h, cos_sin) \
                + cm.mlp_apply(p["mlp"], h, cfg.act)
            return x, 0.0
        x = x + attn.attention_train(
            p["attn"], cfg, cm.apply_norm(cfg, x, p["ln1"]), cos_sin)
        if "cross" in p:
            x = x + attn.attention_train(
                p["cross"], cfg, cm.apply_norm(cfg, x, p["ln3"]),
                None, kv_override=enc_out, causal=False)
        if "moe" in p:
            y, aux = moe_mod.moe_apply(
                p["moe"], cfg, cm.apply_norm(cfg, x, p["ln2"]))
            return x + y, aux
        x = x + cm.mlp_apply(
            p["mlp"], cm.apply_norm(cfg, x, p["ln2"]), cfg.act)
        return x, 0.0

    def _backbone(self, params, x, cos_sin, enc_kv=None):
        """Scan-over-layers trunk.  Returns (x, aux_loss)."""
        cfg = self.cfg
        fam = cfg.family

        if fam in ("dense", "vlm", "moe", "encdec"):
            def body(carry, p_l):
                x = carry
                x, aux = self._dense_block(p_l, x, cos_sin, enc_kv)
                return x, aux
            x, auxs = _scan(
                jax.checkpoint(body), x, params["layers"])
            return x, jnp.sum(jnp.asarray(auxs))

        if fam == "ssm":
            b = x.shape[0]
            st = rk.rwkv6_init_state(cfg, b)

            def body(x, p_l):
                y, _ = rk.rwkv6_block(p_l, cfg, x, st)
                return y, 0.0
            x, _ = _scan(jax.checkpoint(body), x, params["layers"])
            return x, jnp.zeros(())

        if fam == "hybrid":
            x0 = x                                           # original embeds
            period = cfg.shared_attn_period
            n_groups = cfg.n_layers // period

            def mamba_body(x, p_l):
                return x + mb.mamba2_apply(p_l, cfg, x), None

            layers = params["layers"]
            for gi in range(n_groups):
                grp = jax.tree.map(
                    lambda a: a[gi * period : (gi + 1) * period], layers)
                x, _ = _scan(jax.checkpoint(mamba_body), x, grp)
                # shared attention block on concat([h, embed])
                sh = params["shared"]
                hcat = jnp.concatenate([x, x0], axis=-1)
                hcat = cm.apply_norm(cfg, hcat, sh["ln"])
                scfg = cfg.replace(head_dim=2 * cfg.d_model // cfg.n_heads)
                q, k, v = attn.qkv(sh["attn"], scfg, hcat)
                cos, sin = cm.rope_freqs(
                    scfg.hd, cfg.rope_theta, jnp.arange(x.shape[1]))
                q = cm.apply_rope(q, cos, sin)
                k = cm.apply_rope(k, cos, sin)
                o = attn.flash_attention(q, k, v, causal=True)
                o = o.reshape(x.shape[0], x.shape[1], -1)
                x = x + o @ sh["proj"].astype(x.dtype)
                x = x + cm.mlp_apply(
                    sh["mlp"], cm.apply_norm(cfg, x, sh["ln2"]), cfg.act)
            return x, jnp.zeros(())
        raise ValueError(fam)

    def _encode(self, params, enc_embeds):
        """Encoder stack (full self-attention) -> hidden states."""
        cfg = self.cfg
        t = enc_embeds.shape[1]
        cos_sin = cm.rope_freqs(cfg.hd, cfg.rope_theta, jnp.arange(t))

        def body(x, p_l):
            x = x + attn.attention_train(
                p_l["attn"], cfg, cm.apply_norm(cfg, x, p_l["ln1"]),
                cos_sin, causal=False)
            x = x + cm.mlp_apply(
                p_l["mlp"], cm.apply_norm(cfg, x, p_l["ln2"]), cfg.act)
            return x, None
        x, _ = _scan(jax.checkpoint(body), enc_embeds,
                            params["enc_layers"])
        return cm.apply_norm(cfg, x, params["enc_norm"])

    def forward(self, params, batch):
        """Logits for the full sequence.  Returns (logits, aux)."""
        cfg = self.cfg
        cdt = _dt(cfg.compute_dtype)
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = cm.embed_lookup(params["embed"], tokens).astype(cdt)
        pos = jnp.arange(t)
        pos3 = None
        enc_kv = None

        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(cdt)      # (B, P, D)
            np_ = patches.shape[1]
            x = jnp.concatenate([patches, x], axis=1)
            side = int(np_ ** 0.5) or 1
            grid = jnp.arange(np_)
            img3 = jnp.stack([jnp.zeros((np_,), jnp.int32),
                              grid // side, grid % side])
            txt3 = cm.text_pos3(jnp.broadcast_to(np_ + pos, (b, t)))
            pos3 = jnp.concatenate(
                [jnp.broadcast_to(img3[None], (b, 3, np_)), txt3], axis=-1)
            cos_sin = self._cos_sin(None, pos3=pos3)
        elif cfg.family == "encdec":
            enc_hidden = self._encode(
                params, batch["enc_embeds"].astype(cdt))
            # cross-attention K/V computed per layer from enc_hidden; pass
            # hidden states and let each layer project (kv_override path
            # projects inside attention_train via its own wk/wv)
            enc_kv = enc_hidden
            cos_sin = self._cos_sin(pos)
        elif cfg.family in ("ssm",):
            cos_sin = None
        else:
            cos_sin = self._cos_sin(pos)

        if cfg.family == "encdec":
            x, aux = self._backbone_encdec(params, x, cos_sin, enc_kv)
        else:
            x, aux = self._backbone(params, x, cos_sin)

        if cfg.family == "vlm":
            x = x[:, batch["patch_embeds"].shape[1]:]
        x = cm.apply_norm(cfg, x, params["final_norm"])
        logits = cm.unembed(params["head"], x)
        return logits, aux

    def _backbone_encdec(self, params, x, cos_sin, enc_hidden):
        cfg = self.cfg

        def body(x, p_l):
            h = cm.apply_norm(cfg, x, p_l["ln1"])
            x = x + attn.attention_train(p_l["attn"], cfg, h, cos_sin)
            # cross attention: project enc_hidden with this layer's k/v
            hq = cm.apply_norm(cfg, x, p_l["ln3"])
            q, _, _ = attn.qkv(p_l["cross"], cfg, hq)
            _, k, v = attn.qkv(p_l["cross"], cfg, enc_hidden)
            o = attn.flash_attention(q, k, v, causal=False)
            x = x + o.reshape(*x.shape[:2], -1) @ p_l["cross"]["wo"].astype(x.dtype)
            x = x + cm.mlp_apply(
                p_l["mlp"], cm.apply_norm(cfg, x, p_l["ln2"]), cfg.act)
            return x, None
        x, _ = _scan(jax.checkpoint(body), x, params["layers"])
        return x, jnp.zeros(())

    # ------------------------------------------------------------ loss ---
    def loss_fn(self, params, batch):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        ce = cm.cross_entropy(logits, batch["labels"], cfg.vocab)
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    # ========================================================== serving ===
    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        cdt = _dt(cfg.compute_dtype)
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            shape = (cfg.n_layers, batch_size, max_seq, cfg.n_kv, cfg.hd)
            return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt),
                    "pos": jnp.zeros((), jnp.int32)}
        if fam == "ssm":
            st = rk.rwkv6_init_state(cfg, batch_size)
            return {
                "layers": jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (cfg.n_layers, *a.shape)).copy(), st),
                "pos": jnp.zeros((), jnp.int32),
            }
        if fam == "hybrid":
            st = mb.mamba2_init_state(cfg, batch_size, cdt)
            n_groups = cfg.n_layers // cfg.shared_attn_period
            scfg = cfg.replace(head_dim=2 * cfg.d_model // cfg.n_heads)
            kv = (n_groups, batch_size, max_seq, cfg.n_kv, scfg.hd)
            return {
                "layers": jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (cfg.n_layers, *a.shape)).copy(), st),
                "shared_k": jnp.zeros(kv, cdt),
                "shared_v": jnp.zeros(kv, cdt),
                "pos": jnp.zeros((), jnp.int32),
            }
        if fam == "encdec":
            shape = (cfg.n_layers, batch_size, max_seq, cfg.n_kv, cfg.hd)
            enc_t = max_seq // cfg.enc_frames_ratio
            cross = (cfg.n_layers, batch_size, enc_t, cfg.n_kv, cfg.hd)
            return {"k": jnp.zeros(shape, cdt), "v": jnp.zeros(shape, cdt),
                    "ck": jnp.zeros(cross, cdt), "cv": jnp.zeros(cross, cdt),
                    "pos": jnp.zeros((), jnp.int32)}
        raise ValueError(fam)

    def prefill(self, params, batch, max_seq: int):
        """Process the full prompt, returning (last-position logits, cache)
        ready for decode_step.  batch as in loss_fn (no labels needed)."""
        cfg = self.cfg
        cdt = _dt(cfg.compute_dtype)
        fam = cfg.family
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = cm.embed_lookup(params["embed"], tokens).astype(cdt)
        pos = jnp.arange(t)
        n_pre = 0

        if fam == "vlm":
            patches = batch["patch_embeds"].astype(cdt)
            n_pre = patches.shape[1]
            x = jnp.concatenate([patches, x], axis=1)
            side = int(n_pre ** 0.5) or 1
            grid = jnp.arange(n_pre)
            img3 = jnp.stack([jnp.zeros((n_pre,), jnp.int32),
                              grid // side, grid % side])
            txt3 = cm.text_pos3(jnp.broadcast_to(n_pre + pos, (b, t)))
            pos3 = jnp.concatenate(
                [jnp.broadcast_to(img3[None], (b, 3, n_pre)), txt3], -1)
            cos_sin = self._cos_sin(None, pos3=pos3)
        elif fam in ("ssm",):
            cos_sin = None
        else:
            cos_sin = self._cos_sin(pos)
        tt = t + n_pre

        def pad_cache(k):          # (B, T, Hkv, hd) -> (B, S, Hkv, hd)
            return jnp.pad(k, ((0, 0), (0, max_seq - tt), (0, 0), (0, 0)))

        if fam in ("dense", "vlm", "moe", "encdec"):
            enc_hidden = None
            if fam == "encdec":
                enc_hidden = self._encode(
                    params, batch["enc_embeds"].astype(cdt))

            def body(x, p_l):
                h = cm.apply_norm(cfg, x, p_l["ln1"])
                q, k, v = attn.qkv(p_l["attn"], cfg, h)
                if cos_sin is not None:
                    q = cm.apply_rope(q, *cos_sin)
                    k = cm.apply_rope(k, *cos_sin)
                o = attn.flash_attention(q, k, v, causal=True)
                o = o.reshape(b, tt, -1) @ p_l["attn"]["wo"].astype(x.dtype)
                ys = {"k": pad_cache(k), "v": pad_cache(v)}
                if cfg.parallel_block:
                    x = x + o + cm.mlp_apply(p_l["mlp"], h, cfg.act)
                    return x, ys
                x = x + o
                if "cross" in p_l:
                    hq = cm.apply_norm(cfg, x, p_l["ln3"])
                    qc, _, _ = attn.qkv(p_l["cross"], cfg, hq)
                    _, ck, cv = attn.qkv(p_l["cross"], cfg, enc_hidden)
                    oc = attn.flash_attention(qc, ck, cv, causal=False)
                    x = x + oc.reshape(b, tt, -1) \
                        @ p_l["cross"]["wo"].astype(x.dtype)
                    ys["ck"], ys["cv"] = ck, cv
                h2 = cm.apply_norm(cfg, x, p_l["ln2"])
                if "moe" in p_l:
                    y, _ = moe_mod.moe_apply(p_l["moe"], cfg, h2)
                    x = x + y
                else:
                    x = x + cm.mlp_apply(p_l["mlp"], h2, cfg.act)
                return x, ys

            x, caches = _scan(jax.checkpoint(body), x, params["layers"])
            cache = {"k": caches["k"], "v": caches["v"],
                     "pos": jnp.asarray(tt, jnp.int32)}
            if fam == "encdec":
                cache["ck"], cache["cv"] = caches["ck"], caches["cv"]

        elif fam == "ssm":
            st0 = rk.rwkv6_init_state(cfg, b)

            def body(x, p_l):
                y, st = rk.rwkv6_block(p_l, cfg, x, st0)
                return y, st
            x, sts = _scan(jax.checkpoint(body), x, params["layers"])
            cache = {"layers": sts, "pos": jnp.asarray(tt, jnp.int32)}

        elif fam == "hybrid":
            x0 = x
            period = cfg.shared_attn_period
            n_groups = cfg.n_layers // period
            scfg = cfg.replace(head_dim=2 * cfg.d_model // cfg.n_heads)
            states, sks, svs = [], [], []
            for gi in range(n_groups):
                sl = slice(gi * period, (gi + 1) * period)
                grp = jax.tree.map(lambda a: a[sl], params["layers"])

                def body(x, p_l):
                    y, st = mb.mamba2_apply(p_l, cfg, x, return_state=True)
                    return x + y, st
                x, st = _scan(jax.checkpoint(body), x, grp)
                states.append(st)
                sh = params["shared"]
                hcat = cm.apply_norm(
                    cfg, jnp.concatenate([x, x0], -1), sh["ln"])
                q, k, v = attn.qkv(sh["attn"], scfg, hcat)
                cos, sin = cm.rope_freqs(scfg.hd, cfg.rope_theta, pos)
                q = cm.apply_rope(q, cos, sin)
                k = cm.apply_rope(k, cos, sin)
                o = attn.flash_attention(q, k, v, causal=True)
                x = x + o.reshape(b, tt, -1) @ sh["proj"].astype(x.dtype)
                x = x + cm.mlp_apply(
                    sh["mlp"], cm.apply_norm(cfg, x, sh["ln2"]), cfg.act)
                sks.append(pad_cache(k))
                svs.append(pad_cache(v))
            cache = {
                "layers": jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *states),
                "shared_k": jnp.stack(sks), "shared_v": jnp.stack(svs),
                "pos": jnp.asarray(tt, jnp.int32),
            }
        else:
            raise ValueError(fam)

        xl = cm.apply_norm(cfg, x[:, -1:], params["final_norm"])
        return cm.unembed(params["head"], xl), cache

    def decode_step(self, params, token, cache):
        """token (B, 1) int32 -> (logits (B, 1, Vp), new cache)."""
        cfg = self.cfg
        cdt = _dt(cfg.compute_dtype)
        fam = cfg.family
        pos = cache["pos"]
        b = token.shape[0]
        x = cm.embed_lookup(params["embed"], token).astype(cdt)
        posb = jnp.full((1,), pos, jnp.int32)
        if cfg.mrope_sections:
            pos3 = jnp.broadcast_to(posb[None, None, :], (b, 3, 1))
            cos_sin = cm.mrope_freqs(cfg.hd, cfg.rope_theta, pos3,
                                     cfg.mrope_sections)
        else:
            cos_sin = cm.rope_freqs(cfg.hd, cfg.rope_theta, posb)

        if fam in ("dense", "vlm", "moe"):
            def body(x, layer):
                p_l, kc, vc = layer
                h = cm.apply_norm(cfg, x, p_l["ln1"])
                o, kc, vc = attn.decode_step(p_l["attn"], cfg, h, kc, vc,
                                             pos, cos_sin)
                if cfg.parallel_block:
                    x = x + o + cm.mlp_apply(p_l["mlp"], h, cfg.act)
                    return x, (kc, vc)
                x = x + o
                h2 = cm.apply_norm(cfg, x, p_l["ln2"])
                if "moe" in p_l:
                    y, _ = moe_mod.moe_apply(p_l["moe"], cfg, h2)
                    x = x + y
                else:
                    x = x + cm.mlp_apply(p_l["mlp"], h2, cfg.act)
                return x, (kc, vc)

            x, (kc, vc) = _scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=kc, v=vc, pos=pos + 1)

        elif fam == "ssm":
            def body(x, layer):
                p_l, st = layer
                y, st = rk.rwkv6_block(p_l, cfg, x, st)
                return y, st
            x, st = _scan(body, x, (params["layers"], cache["layers"]))
            cache = dict(cache, layers=st, pos=pos + 1)

        elif fam == "hybrid":
            period = cfg.shared_attn_period
            n_groups = cfg.n_layers // period
            x0 = x
            scfg = cfg.replace(head_dim=2 * cfg.d_model // cfg.n_heads)
            new_states = []
            sk, sv = cache["shared_k"], cache["shared_v"]
            sks, svs = [], []
            for gi in range(n_groups):
                sl = slice(gi * period, (gi + 1) * period)
                grp = jax.tree.map(lambda a: a[sl], params["layers"])
                sts = jax.tree.map(lambda a: a[sl], cache["layers"])

                def body(x, layer):
                    p_l, st = layer
                    y, st = mb.mamba2_decode(p_l, cfg, x, st)
                    return x + y, st
                x, st_new = _scan(body, x, (grp, sts))
                new_states.append(st_new)
                sh = params["shared"]
                hcat = cm.apply_norm(
                    cfg, jnp.concatenate([x, x0], -1), sh["ln"])
                q, k, v = attn.qkv(sh["attn"], scfg, hcat)
                cos, sin = cm.rope_freqs(scfg.hd, cfg.rope_theta, posb)
                q = cm.apply_rope(q, cos, sin)
                k = cm.apply_rope(k, cos, sin)
                kg = jax.lax.dynamic_update_slice_in_dim(
                    sk[gi], k.astype(sk.dtype), pos, axis=1)
                vg = jax.lax.dynamic_update_slice_in_dim(
                    sv[gi], v.astype(sv.dtype), pos, axis=1)
                o = attn.decode_attention_jnp(q[:, 0], kg, vg, pos + 1)
                x = x + o.reshape(b, 1, -1) @ sh["proj"].astype(x.dtype)
                x = x + cm.mlp_apply(
                    sh["mlp"], cm.apply_norm(cfg, x, sh["ln2"]), cfg.act)
                sks.append(kg)
                svs.append(vg)
            cache = dict(
                cache,
                layers=jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *new_states),
                shared_k=jnp.stack(sks), shared_v=jnp.stack(svs),
                pos=pos + 1,
            )

        elif fam == "encdec":
            def body(x, layer):
                p_l, kc, vc, ck, cv = layer
                h = cm.apply_norm(cfg, x, p_l["ln1"])
                o, kc, vc = attn.decode_step(p_l["attn"], cfg, h, kc, vc,
                                             pos, cos_sin)
                x = x + o
                hq = cm.apply_norm(cfg, x, p_l["ln3"])
                q, _, _ = attn.qkv(p_l["cross"], cfg, hq)
                oc = attn.decode_attention_jnp(q[:, 0], ck, cv, ck.shape[1])
                x = x + oc.reshape(b, 1, -1) @ p_l["cross"]["wo"].astype(x.dtype)
                x = x + cm.mlp_apply(
                    p_l["mlp"], cm.apply_norm(cfg, x, p_l["ln2"]), cfg.act)
                return x, (kc, vc)
            x, (kc, vc) = _scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["ck"], cache["cv"]))
            cache = dict(cache, k=kc, v=vc, pos=pos + 1)
        else:
            raise ValueError(fam)

        x = cm.apply_norm(cfg, x, params["final_norm"])
        return cm.unembed(params["head"], x), cache
