"""Mixture-of-Experts FFN (GShard-style dispatch, TPU-native).

Covers both assigned MoE flavours:
  arctic-480b    : 128 experts, top-2, PLUS a dense-FFN residual branch
  deepseek-moe   : 64 fine-grained routed experts top-6 PLUS 2 shared
                   (always-on) experts

Dispatch is the capacity-based einsum formulation (no sorting/gather):
top-k masks -> position-in-expert by cumsum -> one-hot capacity slot ->
dispatch/combine einsums.  Experts are EP-sharded over the "model" mesh
axis (weights (E, ...) with E split); GSPMD turns the dispatch einsums
into all-to-alls.  Tokens over capacity are dropped (residual passes them
through) — standard GShard semantics.

The router's load-balance aux loss is a *global* reduction over the batch;
under the paper's technique it joins the same delayed-reduction window as
the gradient psum (train/pipelined.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def moe_params(key, cfg, dtype, out_scale=1.0):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    std = 0.02
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * std,
        "wi": jax.random.normal(ks[1], (e, d, f), dtype) * std,
        "wg": jax.random.normal(ks[2], (e, d, f), dtype) * std,
        "wo": jax.random.normal(ks[3], (e, f, d), dtype) * std * out_scale,
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["shared"] = cm.mlp_params(ks[4], d, fs, "swiglu", dtype, out_scale=out_scale)
    if cfg.dense_residual:
        fd = cfg.dense_ff or f
        p["dense"] = cm.mlp_params(ks[5], d, fd, "swiglu", dtype, out_scale=out_scale)
    return p


GROUP_SIZE = 1024        # tokens per dispatch group (GShard "S")

# §Perf hillclimb flag: when True, the dispatch/expert tensors carry
# explicit sharding constraints (experts -> "model") so the expert compute
# is local to the EP shard and the only collective left is the combine
# psum (row-parallel pattern).  Baseline False = GSPMD decides alone.
CONSTRAIN_EP = False


def _constrain(x, spec):
    if not CONSTRAIN_EP:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _top_k_dispatch(probs: jax.Array, k: int, capacity: int):
    """probs (G, S, E) -> (dispatch, combine) both (G, S, E, C).

    Position-in-expert via per-GROUP cumsum (GShard top-2 generalized to
    top-k by sequential choice peeling) — no cross-group coordination, so
    groups shard freely over the DP axes."""
    g, s, e = probs.shape
    remaining = probs
    fill = jnp.zeros((g, e), jnp.int32)
    dispatch = jnp.zeros((g, s, e, capacity), probs.dtype)
    combine = jnp.zeros((g, s, e, capacity), probs.dtype)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                 # (G, S)
        mask = jax.nn.one_hot(idx, e, dtype=probs.dtype)     # (G, S, E)
        pos = jnp.cumsum(mask, axis=1) - mask + fill[:, None, :]
        in_cap = pos < capacity
        mask_kept = mask * in_cap
        slot = jax.nn.one_hot(
            (pos * mask).sum(-1).astype(jnp.int32), capacity,
            dtype=probs.dtype)                               # (G, S, C)
        sel = mask_kept[..., None] * slot[:, :, None, :]     # (G, S, E, C)
        gate = (probs * mask).sum(-1, keepdims=True)         # (G, S, 1)
        dispatch = dispatch + sel
        combine = combine + sel * gate[..., None]
        fill = fill + mask_kept.sum(1).astype(jnp.int32)
        remaining = remaining * (1.0 - mask)
    return dispatch, combine


def moe_apply(p, cfg, x):
    """x (B, T, D) -> (out (B, T, D), aux_loss scalar)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * t
    sg = min(GROUP_SIZE, n)
    assert n % sg == 0, (n, sg)
    ng = n // sg
    xg = x.reshape(ng, sg, d)

    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, S, E)

    # load-balance aux loss (Switch/GShard): E * sum_e(frac_e * prob_e),
    # averaged over groups; == 1 exactly at perfect balance
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), e, dtype=jnp.float32)
    aux = e * jnp.mean(jnp.sum(
        jnp.mean(top1, axis=1) * jnp.mean(probs, axis=1), axis=-1))

    capacity = max(int(cfg.capacity_factor * k * sg / e), 4)
    dispatch, combine = _top_k_dispatch(probs.astype(x.dtype), k, capacity)

    xe = jnp.einsum("gsd,gsec->gecd", xg, dispatch)          # all-to-all in
    xe = _constrain(xe, (None, "model", None, None))
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(x.dtype))
    gt = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(x.dtype))
    h = jax.nn.silu(h) * gt
    h = _constrain(h, (None, "model", None, None))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    ye = _constrain(ye, (None, "model", None, None))
    out = jnp.einsum("gecd,gsec->gsd", ye, combine)          # combine psum

    if "shared" in p:
        out = out + cm.mlp_apply(p["shared"], xg, "swiglu")
    if "dense" in p:
        out = out + cm.mlp_apply(p["dense"], xg, "swiglu")
    return out.reshape(b, t, d), aux
