"""Mamba2 (SSD) block — the zamba2-2.7b backbone.

State-space recurrence per head (P = head channels, N = ssm_state):

    S_t = a_t · S_{t-1} + dt_t · (x_t ⊗ B_t)        a_t = exp(-dt_t·exp(A_log))
    y_t = S_t · C_t + D ⊙ x_t

Training uses a `lax.scan` over time (compile-friendly, O(1) HLO in T);
decode carries S explicitly — O(1) state per token, which is why zamba2
RUNS the long_500k shape that full-attention archs must skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm

CONV_W = 4


def mamba2_params(key, cfg, dtype, out_scale=1.0):
    d = cfg.d_model
    d_in = 2 * d
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim
    h = d_in // hp
    ks = jax.random.split(key, 8)
    std = 0.02
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": jax.random.normal(ks[0], (d, 2 * d_in + 2 * n + h), dtype) * std,
        "conv_x": jax.random.normal(ks[1], (CONV_W, d_in), dtype) * std,
        "conv_b": jax.random.normal(ks[2], (CONV_W, n), dtype) * std,
        "conv_c": jax.random.normal(ks[3], (CONV_W, n), dtype) * std,
        "a_log": jnp.zeros((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "d_skip": jnp.ones((h,), dtype),
        "norm": jnp.ones((d_in,), dtype),
        "w_out": jax.random.normal(ks[4], (d_in, d), dtype) * std * out_scale,
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x (B, T, C), w (W, C)."""
    pads = [jnp.zeros_like(x[:, :1])] * (CONV_W - 1)
    xs = jnp.concatenate(pads + [x], axis=1)
    out = sum(
        xs[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(CONV_W)
    )
    return jax.nn.silu(out)


def _split_in(cfg, proj):
    d_in = 2 * cfg.d_model
    n = cfg.ssm_state
    h = d_in // cfg.ssm_head_dim
    z, xi, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    return z, xi, bmat, cmat, dt


def mamba2_apply(p, cfg, x, return_state: bool = False):
    """Training/prefill pass.  x (B, T, D) -> (B, T, D)
    (+ decode-ready state when ``return_state``)."""
    b, t, d = x.shape
    d_in = 2 * d
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim
    h = d_in // hp

    proj = x @ p["w_in"].astype(x.dtype)
    z, xi, bm, cmat, dt = _split_in(cfg, proj)
    xbc_raw = jnp.concatenate([xi, bm, cmat], axis=-1)   # pre-conv history
    xi = _causal_conv(xi, p["conv_x"].astype(x.dtype))
    bm = _causal_conv(bm, p["conv_b"].astype(x.dtype))
    cmat = _causal_conv(cmat, p["conv_c"].astype(x.dtype))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-dt * jnp.exp(p["a_log"].astype(jnp.float32)))      # (B,T,H)
    xh = xi.reshape(b, t, h, hp).astype(jnp.float32)
    bm32, cm32 = bm.astype(jnp.float32), cmat.astype(jnp.float32)

    def step(s, inp):
        a_t, dt_t, x_t, b_t, c_t = inp
        s = s * a_t[:, :, None, None] + (
            dt_t[:, :, None, None] * x_t[..., None] * b_t[:, None, None, :]
        )
        y = jnp.einsum("bhpn,bn->bhp", s, c_t)
        return s, y

    s0 = jnp.zeros((b, h, hp, n), jnp.float32)
    xs = (
        jnp.moveaxis(a, 1, 0), jnp.moveaxis(dt, 1, 0), jnp.moveaxis(xh, 1, 0),
        jnp.moveaxis(bm32, 1, 0), jnp.moveaxis(cm32, 1, 0),
    )
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                      # (B,T,H,P)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["w_out"].astype(x.dtype)
    if not return_state:
        return out
    pad = jnp.zeros((b, max(CONV_W - 1 - t, 0), xbc_raw.shape[-1]), x.dtype)
    conv_hist = jnp.concatenate([pad, xbc_raw[:, -(CONV_W - 1):]], axis=1)
    return out, {"ssm": s_fin, "conv": conv_hist}


def mamba2_init_state(cfg, batch, dtype=jnp.float32):
    d_in = 2 * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, d_in + 2 * cfg.ssm_state), dtype),
    }


def mamba2_decode(p, cfg, x, state):
    """One-token step.  x (B, 1, D) -> ((B, 1, D), new_state)."""
    b, _, d = x.shape
    d_in = 2 * d
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim
    h = d_in // hp

    proj = x @ p["w_in"].astype(x.dtype)
    z, xi, bm, cmat, dt = _split_in(cfg, proj)
    xbc = jnp.concatenate([xi, bm, cmat], axis=-1)[:, 0]            # (B, C)
    hist = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)   # (B, W, C)
    wfull = jnp.concatenate(
        [p["conv_x"], p["conv_b"], p["conv_c"]], axis=-1
    ).astype(x.dtype)
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, wfull))
    xi, bm, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                               # (B, H)
    a = jnp.exp(-dt * jnp.exp(p["a_log"].astype(jnp.float32)))
    xh = xi.reshape(b, h, hp).astype(jnp.float32)
    s = state["ssm"] * a[:, :, None, None] + (
        dt[:, :, None, None] * xh[..., None] * bm.astype(jnp.float32)[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", s, cmat.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["w_out"].astype(x.dtype)
    return out, {"ssm": s, "conv": hist[:, 1:]}
