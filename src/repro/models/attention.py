"""GQA attention: training (blocked causal flash, exact T²/2 flops), prefill,
and single-token decode with KV cache (+ cross-shard split-KV merge).

The training path blocks queries with a static python loop and scans only
the causally-needed KV blocks per query block, so compiled FLOPs match the
T²/2 causal ideal (no masked-out wasted compute) and peak activation memory
is O(B·H·qblock·kvblock) — this is what lets prefill_32k fit per-device.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import common as cm

_NEG = -1e30


def attn_params(key, cfg, d_model=None, dtype=jnp.float32, out_scale=1.0):
    d = d_model or cfg.d_model
    hd, h, hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * std,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * std,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * std,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) * std * out_scale,
    }
    if cfg.bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def qkv(p, cfg, x):
    b, t, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, h, hd)
    k = k.reshape(b, t, hkv, hd)
    v = v.reshape(b, t, hkv, hd)
    if cfg.qk_norm:
        q = cm.rms_norm(q, p["q_norm"])
        k = cm.rms_norm(k, p["k_norm"])
    return q, k, v


# ------------------------------------------------- blocked causal attn ----

def _block_attn(q, k, v, *, causal_offset=None):
    """q (B,Hkv,G,Tq,D), k/v (B,Hkv,Tk,D) -> (out, m, l) online-softmax stats.
    causal_offset: (q_start, k_start) for the causal mask, or None (full)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k).astype(jnp.float32) * scale
    if causal_offset is not None:
        q0, k0 = causal_offset
        qi = q0 + jnp.arange(q.shape[3])
        ki = k0 + jnp.arange(k.shape[2])
        s = jnp.where(qi[:, None] >= ki[None, :], s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(q.dtype), v)
    return out, m[..., 0], l[..., 0]


def flash_attention(
    q: jax.Array,            # (B, T, H, D)
    k: jax.Array,            # (B, Tk, Hkv, D)
    v: jax.Array,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Memory-efficient exact attention.  Static python loop over query
    blocks; each block scans only its causally-visible KV blocks."""
    b, t, h, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q_block = min(q_block, t)
    kv_block = min(kv_block, tk)
    # pad to block multiples (padded queries discarded; padded keys masked
    # by the causal offset / explicit length mask)
    tp = ((t + q_block - 1) // q_block) * q_block
    tkp = ((tk + kv_block - 1) // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tkp - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tkp - tk), (0, 0), (0, 0)))

    qg = jnp.transpose(qp.reshape(b, tp, hkv, g, d), (0, 2, 3, 1, 4))
    kg = jnp.transpose(kp, (0, 2, 1, 3))             # (B, Hkv, Tk, D)
    vg = jnp.transpose(vp, (0, 2, 1, 3))

    nq = tp // q_block
    outs = []
    for qi in range(nq):
        q0 = qi * q_block
        qblk = jax.lax.slice_in_dim(qg, q0, q0 + q_block, axis=3)
        # causally visible KV prefix for this query block
        k_hi = min(tkp, ((q0 + q_block + kv_block - 1) // kv_block) * kv_block) \
            if causal else tkp
        nkv = k_hi // kv_block

        def kv_step(carry, idx):
            acc, m_run, l_run = carry
            k0 = idx * kv_block
            kblk = jax.lax.dynamic_slice_in_dim(kg, k0, kv_block, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(vg, k0, kv_block, axis=2)
            if causal:
                o, m_new, l_new = _block_attn(
                    qblk, kblk, vblk, causal_offset=(q0, k0)
                )
            else:
                # full attention; mask key padding explicitly
                scale = 1.0 / math.sqrt(d)
                s = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", qblk, kblk
                ).astype(jnp.float32) * scale
                valid = (k0 + jnp.arange(kv_block)) < tk
                s = jnp.where(valid[None, None, None, None, :], s, _NEG)
                m_new = jnp.max(s, axis=-1)
                pw = jnp.exp(s - m_new[..., None])
                l_new = jnp.sum(pw, axis=-1)
                o = jnp.einsum("bhgqk,bhkd->bhgqd", pw.astype(qblk.dtype), vblk)
            m_tot = jnp.maximum(m_run, m_new)
            a_old = jnp.exp(m_run - m_tot)
            a_new = jnp.exp(m_new - m_tot)
            acc = acc * a_old[..., None].astype(acc.dtype) \
                + o * a_new[..., None].astype(o.dtype)
            l_run = l_run * a_old + l_new * a_new
            return (acc, m_tot, l_run), None

        acc0 = jnp.zeros((b, hkv, g, q_block, d), q.dtype)
        m0 = jnp.full((b, hkv, g, q_block), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(nkv)
        )
        outs.append(acc / jnp.maximum(l_run, 1e-30)[..., None].astype(acc.dtype))

    out = jnp.concatenate(outs, axis=3)              # (B, Hkv, G, Tp, D)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, tp, h, d)
    return out[:, :t]


def attention_train(p, cfg, x, cos_sin=None, kv_override=None, causal=True):
    """Full attention sub-block: qkv -> rope -> flash -> out proj.
    kv_override: (k, v) from the encoder for cross-attention."""
    b, t, _ = x.shape
    q, k, v = qkv(p, cfg, x)
    if kv_override is not None:
        k, v = kv_override
    if cos_sin is not None:
        cos, sin = cos_sin
        q = cm.apply_rope(q, cos, sin)
        if kv_override is None:
            k = cm.apply_rope(k, cos, sin)
    o = flash_attention(q, k, v, causal=causal)
    return o.reshape(b, t, -1) @ p["wo"].astype(x.dtype)


# -------------------------------------------------------------- decode ----

# Baseline decode upcasts the cache operands to f32 before the einsums
# (explicit f32 math).  The §Perf hillclimb flips this to False: operands
# stay bf16 (MXU-native) with f32 ACCUMULATION via preferred_element_type —
# same numerics class, half the HBM traffic on the O(S) cache reads.
DECODE_UPCAST = True


def decode_attention_jnp(q, k_cache, v_cache, kv_len):
    """One-token GQA decode, pure jnp (GSPMD-shardable baseline).
    q (B, H, D); caches (B, S, Hkv, D); kv_len: valid prefix length."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scale = 1.0 / math.sqrt(d)
    if DECODE_UPCAST:
        s_ = jnp.einsum(
            "bhgd,bshd->bhgs", qg.astype(jnp.float32),
            k_cache.astype(jnp.float32),
        ) * scale
    else:
        s_ = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s) < kv_len
    s_ = jnp.where(mask[None, None, None, :], s_, _NEG)
    w = jax.nn.softmax(s_, axis=-1)
    if DECODE_UPCAST:
        o = jnp.einsum("bhgs,bshd->bhgd", w, v_cache.astype(jnp.float32))
    else:
        o = jnp.einsum("bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    return o.reshape(b, h, d).astype(q.dtype)


# When set (e.g. "model"), single-token decode attention runs as a MANUAL
# split-KV over that mesh axis: each shard computes partial softmax stats
# over its local slice of the sequence-sharded cache, and shards merge with
# ONE pmax + ONE fused psum of O(H·D) — the paper's fused-single-reduction
# discipline applied to serving (DESIGN.md §8).  None = let GSPMD choose
# (the baseline the §Perf hillclimb measures against).
SPLIT_KV_AXIS: str | None = None
# Older jax (<= 0.4.x) has no meshless jax.shard_map(axis_names=...); its
# experimental shard_map needs the concrete mesh for the partial-auto
# form.  Drivers that flip SPLIT_KV_AXIS (launch/dryrun) set this
# alongside it; newer jax ignores it.
SPLIT_KV_MESH = None


def split_kv_decode(q, k_cache, v_cache, kv_len, axis: str):
    """Explicit split-KV decode: caches sequence-sharded over ``axis``.
    Runs under jit via partial-manual shard_map (manual only on ``axis``)."""
    def local(qf, kf, vf, kvl):
        b, h, d = qf.shape
        s_loc, hkv = kf.shape[1], kf.shape[2]
        g = h // hkv
        qg = qf.reshape(b, hkv, g, d)
        scale = 1.0 / math.sqrt(d)
        if DECODE_UPCAST:
            s_ = jnp.einsum(
                "bhgd,bshd->bhgs", qg.astype(jnp.float32),
                kf.astype(jnp.float32)) * scale
        else:
            s_ = jnp.einsum("bhgd,bshd->bhgs", qg, kf,
                            preferred_element_type=jnp.float32) * scale
        idx0 = jax.lax.axis_index(axis) * s_loc
        mask = (idx0 + jnp.arange(s_loc)) < kvl
        s_ = jnp.where(mask[None, None, None, :], s_, _NEG)
        m = jnp.max(s_, axis=-1, keepdims=True)              # (B,Hkv,G,1)
        p = jnp.exp(s_ - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        if DECODE_UPCAST:
            o = jnp.einsum("bhgs,bshd->bhgd", p, vf.astype(jnp.float32))
        else:
            o = jnp.einsum("bhgs,bshd->bhgd", p.astype(vf.dtype), vf,
                           preferred_element_type=jnp.float32)
        out = merge_decode_shards(o, m, l, axis)             # 1 pmax + 1 psum
        return out.reshape(b, h, d).astype(qf.dtype)

    from jax.sharding import PartitionSpec as P
    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None),
                P())
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            local, axis_names={axis}, in_specs=in_specs, out_specs=P(),
        )(q, k_cache, v_cache, kv_len)
    # jax 0.4.x fallback: experimental shard_map, partial-auto over the
    # remaining mesh axes (needs the concrete mesh — SPLIT_KV_MESH).
    from jax.experimental.shard_map import shard_map as _shard_map
    if SPLIT_KV_MESH is None:
        raise RuntimeError(
            "split-KV decode on this jax version needs "
            "repro.models.attention.SPLIT_KV_MESH set to the active mesh")
    auto = frozenset(SPLIT_KV_MESH.axis_names) - {axis}
    return _shard_map(
        local, mesh=SPLIT_KV_MESH, in_specs=in_specs, out_specs=P(),
        check_rep=False, auto=auto,
    )(q, k_cache, v_cache, kv_len)


def merge_decode_shards(o, m, l, axis):
    """Split-KV cross-shard combine for the Pallas decode kernel
    (DESIGN.md §8): per-shard unnormalized (o, m, l) -> exact softmax
    combine with ONE pmax + ONE fused psum of O(H·D), never O(S)."""
    m_glob = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - m_glob)
    num_den = jax.lax.psum(
        jnp.concatenate([o * scale, l * scale], axis=-1), axis
    )
    o_sum = num_den[..., : o.shape[-1]]
    l_sum = num_den[..., o.shape[-1] :]
    return o_sum / jnp.maximum(l_sum, 1e-30)


def decode_step(p, cfg, x, k_cache, v_cache, pos, cos_sin):
    """Append one token to the cache and attend.  x (B, 1, D); pos scalar.
    Returns (out (B,1,D), k_cache, v_cache)."""
    b = x.shape[0]
    q, k, v = qkv(p, cfg, x)                        # (B,1,H,D)/(B,1,Hkv,D)
    cos, sin = cos_sin
    q = cm.apply_rope(q, cos, sin)
    k = cm.apply_rope(k, cos, sin)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    if SPLIT_KV_AXIS is not None:
        o = split_kv_decode(q[:, 0], k_cache, v_cache, pos + 1, SPLIT_KV_AXIS)
    else:
        o = decode_attention_jnp(q[:, 0], k_cache, v_cache, pos + 1)
    out = o.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache
