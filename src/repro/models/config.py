"""Architecture configuration — one dataclass covers all 10 assigned archs.

Families:
  dense   — decoder-only GQA transformer (qwen3, command-r+, smollm, stablelm)
  vlm     — dense decoder + M-RoPE + stub patch-embedding frontend (qwen2-vl)
  moe     — decoder with MoE FFN (arctic: +dense residual; deepseek: shared
            experts + fine-grained routed)
  hybrid  — Mamba2 backbone with a weight-SHARED attention block applied
            every ``shared_attn_period`` layers (zamba2)
  ssm     — RWKV6 "Finch" (attention-free, data-dependent decay)
  encdec  — encoder-decoder with cross-attention + stub audio frontend
            (seamless-m4t; the 24L budget is split 24 enc + 24 dec per the
            published model card)
"""

from __future__ import annotations

import dataclasses
from typing import Optional


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | vlm | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    # encoder-decoder
    n_enc_layers: int = 0          # encdec only
    enc_frames_ratio: int = 4      # stub audio frames = seq // ratio

    # attention details
    head_dim: Optional[int] = None # default d_model // n_heads
    qk_norm: bool = False          # qwen3
    bias: bool = False
    parallel_block: bool = False   # command-r parallel attn+FFN
    rope_theta: float = 10000.0
    mrope_sections: tuple = ()     # qwen2-vl M-RoPE (t,h,w) half-dim split

    # FFN
    act: str = "swiglu"            # swiglu | gelu | relu
    norm: str = "rms"              # rms | layer

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0      # deepseek shared experts
    dense_residual: bool = False   # arctic dense FFN residual
    dense_ff: int = 0              # d_ff of the dense residual / first dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2 / rwkv6)
    ssm_state: int = 0             # mamba2 d_state
    ssm_head_dim: int = 64
    shared_attn_period: int = 6    # zamba2: attn block every N mamba layers

    # vlm stub
    n_patches: int = 256           # stub image tokens prepended

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # sizing used by roofline bookkeeping
    max_seq: int = 4096

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 256)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ --
    def param_count(self) -> float:
        """Analytic parameter count (embeddings included once; used for
        MODEL_FLOPS = 6·N·D bookkeeping in §Roofline)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + d * (2 * self.n_kv * hd) \
            + (self.n_heads * hd) * d
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        emb = self.vocab_padded * d

        if self.family == "ssm":          # rwkv6
            dk = self.d_model             # inner == d_model
            tm = 4 * d * dk + 2 * 32 * d + d * dk   # r,k,v,g (+w lora) + out
            cm = 2 * d * int(3.5 * d)
            return self.n_layers * (tm + cm) + 2 * emb

        if self.family == "hybrid":       # zamba2
            d_in = 2 * d
            mamba = d * (2 * d_in + 2 * self.n_heads * 0) \
                + d * d_in + d_in * d \
                + d_in * (2 * self.ssm_state) + d_in
            n_attn = self.n_layers // self.shared_attn_period
            shared = 2 * d * (self.n_heads * hd) * 2 + 3 * (2 * d) * self.d_ff
            return self.n_layers * (mamba + d * 2 * self.ssm_state * 2) \
                + shared + 2 * emb

        per_layer = attn + mlp
        if self.family == "moe":
            moe_mlp = self.n_experts * 3 * d * self.d_ff
            shared = self.n_shared_experts * 3 * d * self.d_ff
            dense = 3 * d * (self.dense_ff or self.d_ff) if self.dense_residual else 0
            per_layer = attn + moe_mlp + shared + dense + d * self.n_experts
        n = self.n_layers * per_layer + 2 * emb
        if self.family == "encdec":
            n += self.n_enc_layers * (attn + mlp) \
                + self.n_layers * (attn + mlp) * 0  # cross attn counted below
            n += self.n_layers * attn               # cross-attention blocks
        return float(n)

    def active_param_count(self) -> float:
        """Active params per token (MoE: top-k + shared + dense residual)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = d * (self.n_heads * self.hd) + d * (2 * self.n_kv * self.hd) \
            + (self.n_heads * self.hd) * d
        act_mlp = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        dense = 3 * d * (self.dense_ff or self.d_ff) if self.dense_residual else 0
        return float(
            self.n_layers * (attn + act_mlp + dense + d * self.n_experts)
            + 2 * self.vocab_padded * d
        )
