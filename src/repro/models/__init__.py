from repro.models.config import ArchConfig
from repro.models.model import LM

__all__ = ["ArchConfig", "LM"]
