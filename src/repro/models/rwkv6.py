"""RWKV6 "Finch" block (rwkv6-7b) — attention-free, data-dependent decay.

Time mixing per head (N = head dim, state S is N×N):

    y_t = r_t · (diag(u)·k_t v_tᵀ + S_{t-1})
    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ          w_t = exp(-exp(w0 + lora(x)))

The decay w_t is per-channel and DATA-DEPENDENT (the Finch contribution
over RWKV5).  Token-shift interpolations use the ddlerp form with low-rank
adapters.  Training scans over time; decode carries (S, x_prev) — O(1)
state, so rwkv6 RUNS long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm

LORA_SHIFT = 32
LORA_DECAY = 64
_MIX = ("r", "k", "v", "g", "w")


def rwkv6_params(key, cfg, dtype, out_scale=1.0):
    d = cfg.d_model
    ks = jax.random.split(key, 16)
    std = 0.02
    p = {
        "mu_base": jnp.full((d,), 0.5, dtype),
        "lora_a": jax.random.normal(ks[0], (d, 5 * LORA_SHIFT), dtype) * std,
        "lora_b": jax.random.normal(ks[1], (5, LORA_SHIFT, d), dtype) * std,
        "w0": jnp.full((d,), -2.0, dtype),
        "wlora_a": jax.random.normal(ks[2], (d, LORA_DECAY), dtype) * std,
        "wlora_b": jax.random.normal(ks[3], (LORA_DECAY, d), dtype) * std,
        "u": jax.random.normal(ks[4], (d,), dtype) * std,   # bonus
        "wr": jax.random.normal(ks[5], (d, d), dtype) * std,
        "wk": jax.random.normal(ks[6], (d, d), dtype) * std,
        "wv": jax.random.normal(ks[7], (d, d), dtype) * std,
        "wg": jax.random.normal(ks[8], (d, d), dtype) * std,
        "wo": jax.random.normal(ks[9], (d, d), dtype) * std * out_scale,
        "ln_x": jnp.ones((d,), dtype),
        # channel mix
        "mu_ck": jnp.full((d,), 0.5, dtype),
        "mu_cr": jnp.full((d,), 0.5, dtype),
        "cm_k": jax.random.normal(ks[10], (d, int(3.5 * d)), dtype) * std,
        "cm_v": jax.random.normal(ks[11], (int(3.5 * d), d), dtype) * std * out_scale,
        "cm_r": jax.random.normal(ks[12], (d, d), dtype) * std,
        "mu_mix": jax.random.normal(ks[13], (5, d), dtype) * std,
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift: five mixed inputs (r,k,v,g,w)."""
    xx = x_prev - x
    base = x + xx * p["mu_base"].astype(x.dtype)
    lo = jnp.tanh(base @ p["lora_a"].astype(x.dtype))        # (..., 5*R)
    lo = lo.reshape(*lo.shape[:-1], 5, LORA_SHIFT)
    dyn = jnp.einsum("...fr,frd->...fd", lo, p["lora_b"].astype(x.dtype))
    mu = p["mu_mix"].astype(x.dtype) + dyn                   # (..., 5, D)
    return x[..., None, :] + xx[..., None, :] * mu           # (..., 5, D)


def _decay(p, xw):
    lo = jnp.tanh(xw @ p["wlora_a"].astype(xw.dtype)) @ p["wlora_b"].astype(xw.dtype)
    return jnp.exp(
        -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + lo.astype(jnp.float32), -8.0, 2.0))
    )                                                        # (..., D) in (0,1)


def time_mix(p, cfg, x, x_prev, state):
    """Sequence form.  x (B, T, D); x_prev (B, D) last token of prev chunk;
    state (B, H, N, N) f32.  Returns (y, x_last, state)."""
    b, t, d = x.shape
    n = cfg.ssm_head_dim if cfg.ssm_head_dim else 64
    h = d // n

    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, xs)                                # (B,T,5,D)
    xr, xk, xv, xg, xw = (mixed[:, :, i] for i in range(5))
    r = (xr @ p["wr"].astype(x.dtype)).reshape(b, t, h, n).astype(jnp.float32)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(b, t, h, n).astype(jnp.float32)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(b, t, h, n).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    w = _decay(p, xw).reshape(b, t, h, n)                    # f32
    u = p["u"].astype(jnp.float32).reshape(h, n)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                             # (B,H,N)
        kv = k_t[..., None] * v_t[..., None, :]              # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, u[None, :, :, None] * kv + s)
        s = w_t[..., None] * s + kv
        return s, y

    xs_t = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs_t)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, d)              # f32
    y = cm.rms_norm(y.astype(x.dtype), p["ln_x"])            # group-norm stand-in
    y = (y * g) @ p["wo"].astype(x.dtype)
    return y, x[:, -1], state


def channel_mix(p, x, x_prev):
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = xs - x
    xk = x + xx * p["mu_ck"].astype(x.dtype)
    xr = x + xx * p["mu_cr"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"].astype(x.dtype)))
    return jax.nn.sigmoid(xr @ p["cm_r"].astype(x.dtype)) * (
        kk @ p["cm_v"].astype(x.dtype)
    ), x[:, -1]


def rwkv6_init_state(cfg, batch):
    d = cfg.d_model
    n = cfg.ssm_head_dim if cfg.ssm_head_dim else 64
    h = d // n
    return {
        "s": jnp.zeros((batch, h, n, n), jnp.float32),
        "x_tm": jnp.zeros((batch, d), jnp.float32),
        "x_cm": jnp.zeros((batch, d), jnp.float32),
    }


def rwkv6_block(p, cfg, x, state):
    """Full block (time mix + channel mix) in sequence form."""
    dt = x.dtype
    y, x_tm, s = time_mix(
        p, cfg, x, state["x_tm"].astype(dt), state["s"]
    )
    x = x + y
    y2, x_cm = channel_mix(p, x, state["x_cm"].astype(dt))
    return x + y2, {"s": s, "x_tm": x_tm.astype(jnp.float32),
                    "x_cm": x_cm.astype(jnp.float32)}
