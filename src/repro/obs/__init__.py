"""Unified runtime observability (DESIGN.md §16).

Three pieces, one subsystem:

* :mod:`repro.obs.metrics` — counters / gauges / histograms with labeled
  series behind a :class:`MetricsRegistry`, snapshotted deterministically
  (inject a ``VirtualClock``) and exported as Prometheus text or JSON.
  The serve layer (scheduler, service, admission, setup cache) and the
  backend capability-fallback path all report through it.
* :mod:`repro.obs.timeline` — Chrome-trace (catapult JSON) timelines:
  measured host-side phase spans (``jax.profiler.TraceAnnotation`` +
  wall clock), the static HLO overlap schedule from
  ``repro.utils.trace``, virtual-time replay timelines, and telemetry
  tracks decoded from the on-device ring.
* the **on-device telemetry ring** itself lives with the solver
  (``repro.core.pipelined_cg`` / ``repro.core.types.TelemetrySlab`` /
  ``repro.kernels.fused_iter.tel_layout``) — this package only decodes
  and renders it.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.obs.timeline import (Timeline, hlo_schedule_track, replay_timeline,
                                solve_timeline, telemetry_track)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "Timeline",
    "hlo_schedule_track",
    "replay_timeline",
    "solve_timeline",
    "telemetry_track",
]
