"""Chrome-trace timelines: measured phase spans merged with the static
HLO overlap schedule (DESIGN.md §16).

The paper's core figure is a *timeline*: global reductions staggered in
flight while SPMV and neighbour communication run under them.  Our
overlap tracer (``repro.utils.trace``) proves that structure statically
from compiled HLO; this module renders it — plus measured host-side
phase timings, per-iteration telemetry decoded from the on-device ring,
and virtual-time serve replays — as catapult JSON that loads directly in
``chrome://tracing`` / Perfetto.

Honesty model (the benches' ``kernel_mode`` discipline, applied to
traces):

* **measured spans** (``Timeline.span``) are host wall-clock around
  dispatched device work, annotated via ``jax.profiler.TraceAnnotation``
  so the same regions appear in a full device profile; on this repo's
  CPU/interpret lane they time the interpreter, and the exported
  metadata says so (``kernel_mode``);
* the **HLO schedule track** (``hlo_schedule_track``) has time units of
  *instruction positions in the compiled schedule*, not seconds — it
  shows WHAT overlaps what (reduction windows vs halo/ladder traffic),
  never how long anything took.  Its process is labeled accordingly;
* **replay tracks** (``replay_timeline``) are virtual-clock arithmetic:
  exact, deterministic, and not wall time.

Every process in the exported trace is labeled with its time base, and
the trace-level ``metadata`` block carries ``kernel_mode`` plus whatever
the caller adds — a timeline that cannot mislead is the point.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager

import jax
import numpy as np

from repro.core.types import TelemetrySlab
from repro.utils.trace import OverlapReport

# Process ids (one per time base) for the merged trace.
PID_HOST = 1        # measured host wall-clock (microseconds)
PID_SCHEDULE = 2    # HLO schedule positions (instruction index)
PID_TELEMETRY = 3   # solver iterations (index)
PID_REPLAY = 4      # virtual-clock replay (microseconds of virtual time)

_PROCESS_NAMES = {
    PID_HOST: "host phases [measured wall-clock]",
    PID_SCHEDULE: "hlo schedule [instruction positions, NOT time]",
    PID_TELEMETRY: "solver telemetry [iteration index, NOT time]",
    PID_REPLAY: "serve replay [virtual clock]",
}


class Timeline:
    """A mutable catapult-JSON trace (chrome://tracing / Perfetto).

    ``span``/``instant``/``counter`` append events; ``merge`` combines
    timelines (e.g. measured host phases + the static schedule track);
    ``to_chrome_trace``/``save`` export.  Metadata passed here (and by
    the track builders) rides in the trace's ``metadata`` block.
    """

    def __init__(self, meta: dict | None = None):
        self.events: list[dict] = []
        self.meta: dict = dict(meta or {})
        self._pids: set[int] = set()

    # ------------------------------------------------------------ events --
    def _use(self, pid: int) -> None:
        self._pids.add(pid)

    @contextmanager
    def span(self, name: str, pid: int = PID_HOST, tid: int = 1,
             cat: str = "phase", args: dict | None = None):
        """Measured host-side span: wall-clock around the block, plus a
        ``jax.profiler.TraceAnnotation`` so a device profile taken of
        the same run shows the same region names.  NOTE: jax dispatch is
        async — wrap a ``block_until_ready`` inside the block when the
        span should cover device completion, not just dispatch."""
        t0 = time.perf_counter()
        with jax.profiler.TraceAnnotation(name):
            try:
                yield self
            finally:
                dur = time.perf_counter() - t0
                self.add_span(name, ts_s=t0, dur_s=dur, pid=pid, tid=tid,
                              cat=cat, args=args)

    def add_span(self, name: str, ts_s: float, dur_s: float,
                 pid: int = PID_HOST, tid: int = 1, cat: str = "phase",
                 args: dict | None = None) -> None:
        """Explicit complete-event span; ``ts_s``/``dur_s`` in the pid's
        time base (seconds for measured/virtual tracks, raw units for
        position-based tracks — see module docstring)."""
        self._use(pid)
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": ts_s * 1e6, "dur": dur_s * 1e6,
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, ts_s: float, pid: int = PID_HOST,
                tid: int = 1, cat: str = "event",
                args: dict | None = None) -> None:
        self._use(pid)
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": ts_s * 1e6, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_s: float, values: dict,
                pid: int = PID_HOST, tid: int = 1) -> None:
        """Counter sample (rendered as a stacked chart row)."""
        self._use(pid)
        self.events.append({"name": name, "ph": "C", "ts": ts_s * 1e6,
                            "pid": pid, "tid": tid, "args": values})

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        self._use(pid)
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    def merge(self, other: "Timeline") -> "Timeline":
        self.events.extend(other.events)
        self.meta.update(other.meta)
        self._pids |= other._pids
        return self

    # ------------------------------------------------------------ export --
    def to_chrome_trace(self) -> dict:
        meta = dict(self.meta)
        meta.setdefault("kernel_mode", "interpret" if jax.default_backend()
                        not in ("tpu", "gpu") else "compiled")
        meta.setdefault(
            "time_bases",
            {str(pid): _PROCESS_NAMES.get(pid, "custom")
             for pid in sorted(self._pids)})
        events = [{"name": "process_name", "ph": "M", "pid": pid,
                   "args": {"name": _PROCESS_NAMES.get(pid, f"pid {pid}")}}
                  for pid in sorted(self._pids)]
        return {"traceEvents": events + self.events,
                "displayTimeUnit": "ms", "metadata": meta}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
            f.write("\n")
        return path


# ---------------------------------------------------------------- tracks --

# Thread ids inside the schedule process.
TID_REDUCTIONS = 1
TID_SPMV = 2
TID_HALO = 3
TID_LADDER = 4


def hlo_schedule_track(report: OverlapReport) -> Timeline:
    """Static overlap track from one :class:`OverlapReport`.

    Renders, in *schedule position* units (instruction index of the
    compiled entry computation — explicitly not time):

    * one span per reduction chain: issued at its start position, open
      until its wait position (unconsumed trailing chains run to the
      last event) — the paper's l-deep in-flight windows;
    * one span per iteration window's vector phase (between consecutive
      window starts) on the SPMV row — where the SPMV + recurrence work
      the reduction hides under is scheduled;
    * instants for every tagged halo permute and staged ladder hop, on
      their own rows — landing *inside* the reduction spans above them
      is the staggering claim, now visible.
    """
    tl = Timeline()
    tl.name_thread(PID_SCHEDULE, TID_REDUCTIONS, "reduction windows")
    tl.name_thread(PID_SCHEDULE, TID_SPMV, "vector phase / SPMV")
    tl.name_thread(PID_SCHEDULE, TID_HALO, "halo exchange")
    tl.name_thread(PID_SCHEDULE, TID_LADDER, "staged ladder hops")
    # Timeline.add_span multiplies by 1e6 (seconds -> us); position
    # tracks pre-divide so exported ts == instruction position.
    u = 1e-6
    end = max((e.pos for e in report.events), default=0) + 1
    for k, spos, wpos in report.chains:
        tl.add_span(f"glred chain {k}", ts_s=spos * u,
                    dur_s=((wpos if wpos is not None else end) - spos) * u,
                    pid=PID_SCHEDULE, tid=TID_REDUCTIONS, cat="reduction",
                    args={"window": k, "consumed": wpos is not None})
    starts = sorted((e.pos, e.window) for e in report.events
                    if e.kind == "start")
    for j, (pos, k) in enumerate(starts):
        nxt = starts[j + 1][0] if j + 1 < len(starts) else end
        tl.add_span(f"vector phase {k}", ts_s=pos * u, dur_s=(nxt - pos) * u,
                    pid=PID_SCHEDULE, tid=TID_SPMV, cat="vector",
                    args={"window": k})
    for e in report.events:
        if e.kind == "halo":
            tl.instant("halo permute", ts_s=e.pos * u, pid=PID_SCHEDULE,
                       tid=TID_HALO, cat="halo", args={"window": e.window})
        elif e.kind == "hop":
            tl.instant(f"hop {e.hop}", ts_s=e.pos * u, pid=PID_SCHEDULE,
                       tid=TID_LADDER, cat="hop",
                       args={"window": e.window, "hop": e.hop})
    tl.meta["hlo_schedule"] = {
        "units": "instruction positions in the compiled entry computation "
                 "(schedule order), NOT time",
        "l": report.l, "window": report.window,
        "max_in_flight": report.max_in_flight,
        "halos_in_flight": report.halos_in_flight,
        "hops_in_flight": report.hops_in_flight,
    }
    return tl


def telemetry_track(telemetry, l: int) -> Timeline:
    """Per-iteration counter rows decoded from the on-device telemetry
    ring (one solve's ``SolveResult.telemetry``): residual norm,
    in-flight handle age and (on governed solves, DESIGN.md §18) the
    governor's gap estimate per iteration index; restart/replacement
    and governor-action instants.  Rows are emitted in iteration order
    (the ring's "iter" column), skipping never-written slots."""
    tel = np.asarray(telemetry)
    ts = TelemetrySlab(cap=tel.shape[-2], l=l)
    cols = ts.unpack(tel)
    tl = Timeline()
    tl.name_thread(PID_TELEMETRY, 1, "per-iteration telemetry")
    u = 1e-6
    order = np.argsort(cols["iter"], kind="stable")
    for r in order:
        it = float(cols["iter"][r])
        if it < 0:
            continue                      # never written
        vals = {"age": float(cols["age"][r])}
        if cols["rnorm"][r] >= 0:
            vals["rnorm"] = float(cols["rnorm"][r])
        if cols["gap"][r] > 0:
            vals["gap"] = float(cols["gap"][r])
        tl.counter("iteration", ts_s=it * u, values=vals,
                   pid=PID_TELEMETRY, tid=1)
        if cols["restart"][r] > 0:
            kind = ("replacement" if cols["replacement"][r] > 0
                    else "breakdown restart")
            tl.instant(kind, ts_s=it * u, pid=PID_TELEMETRY, tid=1,
                       cat="restart")
        act = float(cols["action"][r])
        if act > 0:
            kind = {1.0: "governor: gap-arm replacement",
                    2.0: "governor: patience-arm replacement",
                    3.0: "governor: stagnation declared"}.get(
                        act, f"governor: action {act:g}")
            tl.instant(kind, ts_s=it * u, pid=PID_TELEMETRY, tid=1,
                       cat="governor", args={"action": act})
    tl.meta["telemetry"] = {
        "units": "solver iteration index, NOT time",
        "cap": ts.cap, "k": ts.k, "l": l,
    }
    return tl


def solve_timeline(backend, op, b, l: int = 2, window: int | None = None,
                   sigmas=None, prec=None, fused_iteration: bool = False,
                   telemetry_cap: int = 256, **solver_kwargs):
    """Measured + static timeline for one instrumented solve.

    Runs ``backend.solve(..., telemetry_cap=...)`` with measured host
    phase spans (build/compile+warmup vs steady-state solve), then
    merges (a) the static HLO overlap schedule of the same configuration
    (``repro.utils.trace.plcg_overlap_report``) and (b) the telemetry
    track decoded from the ring.  Returns ``(timeline, result)``.

    This is the runtime reproduction of the paper's overlap figure: the
    schedule track shows the l-deep staggering, the telemetry track what
    the solver did per iteration, the host track what the whole solve
    cost on THIS machine (see the trace metadata for ``kernel_mode`` —
    on the CPU/interpret lane those spans time the interpreter).
    """
    from repro.utils.trace import plcg_overlap_report

    tl = Timeline()
    tl.name_thread(PID_HOST, 1, "solve phases")
    kw = dict(solver_kwargs, l=l, sigmas=sigmas,
              telemetry_cap=telemetry_cap,
              fused_iteration=fused_iteration)
    with tl.span("solve[first-call: compile + run]"):
        res = backend.solve(op, b, method="plcg", prec=prec, **kw)
        jax.block_until_ready(res.x)
    with tl.span("solve[steady-state]"):
        res = backend.solve(op, b, method="plcg", prec=prec, **kw)
        jax.block_until_ready(res.x)
    with tl.span("trace[lower + schedule analysis]"):
        report = plcg_overlap_report(
            backend, op, jax.ShapeDtypeStruct(b.shape, b.dtype), l=l,
            window=window, sigmas=sigmas, prec=prec,
            fused_iteration=fused_iteration, telemetry_cap=telemetry_cap,
            recurrence=solver_kwargs.get("recurrence", "ghysels"),
            governor=solver_kwargs.get("governor"))
    tl.merge(hlo_schedule_track(report))
    if res.telemetry is not None:
        tl.merge(telemetry_track(res.telemetry, l=l))
    tl.meta["solver"] = {"method": "plcg", "l": l, "n": int(op.n),
                         "fused_iteration": fused_iteration,
                         "telemetry_cap": telemetry_cap,
                         "backend": type(backend).name}
    return tl, res


def replay_timeline(svc, rep=None) -> Timeline:
    """Virtual-time serve timeline from a service's retirement log.

    One row per slab worker; each retired request renders as a span from
    submission to retirement (its measured-by-arithmetic latency on the
    virtual clock), sheds and steals as instants.  Built purely from the
    deterministic logs (``retirement_log``, ``steal_log``, ``shed_log``)
    — same seed, same trace, byte-identical timeline JSON on any machine
    (tests/test_obs_timeline.py)."""
    tl = Timeline()
    tid_of: dict[int, int] = {}
    # Steal events carry a tick, not a timestamp — anchor them to the
    # first retirement time seen at/after their tick (deterministic).
    tick_t: dict[int, float] = {}
    for _req, _w, tick, t in svc.retirement_log:
        tick_t.setdefault(tick, t)

    def tid(worker: int) -> int:
        if worker not in tid_of:
            tid_of[worker] = worker + 1
            tl.name_thread(PID_REPLAY, worker + 1,
                           f"worker {worker}" if worker >= 0 else "shed")
        return tid_of[worker]

    for req_id, worker, tick, t in svc.retirement_log:
        rr = svc.results.get(req_id)
        lat = rr.latency_s if rr is not None else 0.0
        args = {"req_id": req_id, "tick": tick}
        if rr is not None:
            args.update(iters=rr.iters, converged=bool(rr.converged),
                        slo_met=bool(rr.slo_met))
        tl.add_span(f"req {req_id}", ts_s=t - lat, dur_s=lat,
                    pid=PID_REPLAY, tid=tid(worker), cat="request",
                    args=args)
    for ev in svc.scheduler.shed_log:
        tl.instant(f"shed req {ev.req_id}", ts_s=ev.t, pid=PID_REPLAY,
                   tid=tid(-1), cat="shed",
                   args={"waited_s": ev.waited_s, "worker": ev.worker})
    for ev in svc.scheduler.steal_log:
        anchors = [t for k, t in tick_t.items() if k >= ev.tick]
        tl.instant(f"steal req {ev.req_id}", ts_s=min(anchors, default=0.0),
                   pid=PID_REPLAY, tid=tid(ev.thief), cat="steal",
                   args={"tick": ev.tick, "victim": ev.victim})
    tl.meta["replay"] = {
        "units": "virtual-clock seconds (deterministic arithmetic, "
                 "not wall time)",
        "retired": len(svc.retirement_log),
        "shed": len(svc.scheduler.shed_log),
        "stolen": len(svc.scheduler.steal_log),
    }
    if rep is not None:
        tl.meta["replay"].update(goodput_per_s=rep.goodput_per_s,
                                 p99_s=rep.latency_p99_s,
                                 slot_utilization=rep.slot_utilization)
    return tl
