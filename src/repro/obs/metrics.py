"""Metrics registry: counters / gauges / histograms with labeled series
(DESIGN.md §16).

The serve layer used to keep per-module stat state — ints on
``SolverService``, event lists on ``SlabScheduler``, hit/miss pairs on
``SetupCache`` — with no unified export.  This module is the one place
they all report through now:

* a :class:`MetricsRegistry` holds named metrics; each metric holds
  LABELED series (``counter.labels(worker="3").inc()``), the Prometheus
  data model without the client-library dependency (none is available in
  this environment, and none is needed for ~a hundred series);
* everything is plain deterministic arithmetic — no wall-clock reads, no
  background threads.  ``snapshot(clock=...)`` stamps the export with an
  injectable clock, so under a ``VirtualClock`` two replays of the same
  trace export byte-identical snapshots (tests/test_obs_metrics.py);
* :class:`Histogram` is a bounded reservoir (the service's old latency
  deque, generalized) whose ``quantile`` reproduces the service's
  percentile arithmetic exactly — swapping the reservoir under
  ``SolverService.stats`` changed no reported number;
* exporters: ``to_prometheus_text`` (text exposition format; histograms
  rendered as summaries with p50/p90/p99 quantiles) and ``to_json``.

Ownership: a ``SolverService`` creates its OWN registry by default (so
two services never share counters and replay determinism is per-service);
pass ``registry=`` to aggregate several components onto one.  The module
``default_registry()`` is reserved for process-global signals with no
natural owner — e.g. the reduction-capability fallback gauge set by
``repro.parallel.reduction.resolve_backend_reduction``.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Iterable, Mapping

LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: Mapping[str, str] | None) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class _Metric:
    """Shared labeled-series machinery; subclasses define the series
    payload and exposition."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._series: dict[LabelKey, object] = {}

    def _new_series(self):
        raise NotImplementedError

    def _get(self, labels: Mapping[str, str] | None = None):
        key = _label_key(labels)
        if labels and self.label_names:
            extra = set(dict(key)) - set(self.label_names)
            if extra:
                raise KeyError(f"{self.name}: unknown label(s) {sorted(extra)}")
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = self._new_series()
        return s

    def labels(self, **labels):
        """Bound view on one labeled series (created on first use)."""
        return _Bound(self, labels)

    def series(self) -> dict[LabelKey, object]:
        return dict(self._series)

    def reset(self) -> None:
        self._series.clear()


class _Bound:
    """A metric bound to one label set: forwards the write/read API."""

    def __init__(self, metric: _Metric, labels: Mapping[str, str]):
        self._metric = metric
        self._labels = dict(labels)

    def __getattr__(self, attr):
        fn = getattr(type(self._metric), attr)
        return lambda *a, **kw: fn(self._metric, *a,
                                   labels=self._labels, **kw)


class Counter(_Metric):
    """Monotone counter.  ``inc`` only — a decreasing counter is a bug."""

    kind = "counter"

    def _new_series(self):
        return [0.0]

    def inc(self, amount: float = 1.0, *, labels=None) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counter inc must be >= 0")
        self._get(labels)[0] += amount

    def value(self, *, labels=None) -> float:
        return self._get(labels)[0]


class Gauge(_Metric):
    """Point-in-time value (set/inc/dec)."""

    kind = "gauge"

    def _new_series(self):
        return [0.0]

    def set(self, value: float, *, labels=None) -> None:
        self._get(labels)[0] = float(value)

    def inc(self, amount: float = 1.0, *, labels=None) -> None:
        self._get(labels)[0] += amount

    def dec(self, amount: float = 1.0, *, labels=None) -> None:
        self._get(labels)[0] -= amount

    def value(self, *, labels=None) -> float:
        return self._get(labels)[0]


class _Reservoir:
    __slots__ = ("obs", "count", "sum")

    def __init__(self, maxlen: int):
        self.obs: deque[float] = deque(maxlen=maxlen)
        self.count = 0
        self.sum = 0.0


class Histogram(_Metric):
    """Bounded-reservoir distribution metric.

    ``count``/``sum`` are exact over all observations; quantiles come
    from the most recent ``maxlen`` (the service's pre-§16 latency deque
    semantics, kept so long-lived services don't grow stats state).
    ``quantile(p)`` is the nearest-rank arithmetic ``SolverService.stats``
    always used — sorted reservoir indexed at ``int(p/100 * n)`` — so
    the registry-backed percentiles are bitwise those of the old code
    (tests/test_serve.py parity).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 label_names: Iterable[str] = (), maxlen: int = 4096):
        super().__init__(name, help, label_names)
        self.maxlen = int(maxlen)

    def _new_series(self):
        return _Reservoir(self.maxlen)

    def observe(self, value: float, *, labels=None) -> None:
        r = self._get(labels)
        r.obs.append(float(value))
        r.count += 1
        r.sum += float(value)

    def count_(self, *, labels=None) -> int:
        return self._get(labels).count

    def sum_(self, *, labels=None) -> float:
        return self._get(labels).sum

    def reservoir(self, *, labels=None) -> deque[float]:
        return self._get(labels).obs

    def quantile(self, p: float, *, labels=None) -> float:
        obs = sorted(self._get(labels).obs)
        if not obs:
            return 0.0
        return obs[min(int(p / 100 * len(obs)), len(obs) - 1)]

    def clear(self, *, labels=None) -> None:
        r = self._get(labels)
        r.obs.clear()
        r.count = 0
        r.sum = 0.0


class MetricsRegistry:
    """Named metrics with idempotent registration.

    ``counter/gauge/histogram`` return the existing metric when the name
    is already registered with the same kind (so components can declare
    their metrics independently against a shared registry) and raise on
    a kind mismatch — silently returning a counter where a gauge was
    asked for is how stats go quietly wrong.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _register(self, cls, name, help, label_names, **kw):
        cur = self._metrics.get(name)
        if cur is not None:
            if not isinstance(cur, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{cur.kind}, requested {cls.kind}")
            return cur
        m = self._metrics[name] = cls(name, help, label_names, **kw)
        return m

    def counter(self, name: str, help: str = "",
                label_names: Iterable[str] = ()) -> Counter:
        return self._register(Counter, name, help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Iterable[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, label_names)

    def histogram(self, name: str, help: str = "",
                  label_names: Iterable[str] = (),
                  maxlen: int = 4096) -> Histogram:
        return self._register(Histogram, name, help, label_names,
                              maxlen=maxlen)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def metrics(self) -> list[_Metric]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def reset(self) -> None:
        """Zero every series of every metric (metric objects survive —
        held references stay valid, e.g. across ``reset_stats``)."""
        for m in self._metrics.values():
            m.reset()

    # ------------------------------------------------------------ export --
    def snapshot(self, clock=None) -> dict:
        """Deterministic export: sorted metrics, sorted series, stamped
        with the injected clock (None -> no timestamp; never reads the
        wall clock itself)."""
        out: dict = {"time": clock.now() if clock is not None else None,
                     "metrics": {}}
        for m in self.metrics():
            series = {}
            for key in sorted(m.series()):
                if isinstance(m, Histogram):
                    r = m._series[key]
                    series[_label_str(key)] = {
                        "count": r.count, "sum": r.sum,
                        "p50": m.quantile(50, labels=dict(key)),
                        "p90": m.quantile(90, labels=dict(key)),
                        "p99": m.quantile(99, labels=dict(key)),
                    }
                else:
                    series[_label_str(key)] = m._series[key][0]
            out["metrics"][m.name] = {"type": m.kind, "help": m.help,
                                      "series": series}
        return out

    def to_json(self, clock=None, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(clock), indent=indent,
                          sort_keys=True)

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format.  Histograms are rendered as
        summaries (reservoir quantiles + exact _count/_sum) — honest
        about what a bounded reservoir can report, instead of faking
        cumulative buckets it doesn't keep."""
        lines: list[str] = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            kind = "summary" if isinstance(m, Histogram) else m.kind
            lines.append(f"# TYPE {m.name} {kind}")
            for key in sorted(m.series()):
                if isinstance(m, Histogram):
                    for q in (0.5, 0.9, 0.99):
                        qkey = key + (("quantile", repr(q)),)
                        lines.append(
                            f"{m.name}{_label_str(qkey)} "
                            f"{m.quantile(q * 100, labels=dict(key))}")
                    r = m._series[key]
                    lines.append(f"{m.name}_count{_label_str(key)} {r.count}")
                    lines.append(f"{m.name}_sum{_label_str(key)} {r.sum}")
                else:
                    lines.append(
                        f"{m.name}{_label_str(key)} {m._series[key][0]}")
        return "\n".join(lines) + "\n"


# Process-global registry for signals with no natural owner (backend
# capability fallbacks).  Component-local stats should use their own
# registry — see the module docstring.
_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _DEFAULT
