"""Content-hashed, versioned on-disk checkpoint format (DESIGN.md §19).

One checkpoint = one ``.npz`` file holding the solver-state payload
(flattened pytree leaves as named numpy arrays) plus a ``__meta__``
JSON blob carrying the format version, a sha256 content hash over every
payload array (name + dtype + shape + bytes, in sorted key order), and
the solver configuration the state belongs to.  Writes are atomic
(temp file + ``os.replace``), so a rank killed mid-save can never leave
a half-written file that a later restore would silently trust.

Every failure mode surfaces as a typed :class:`CheckpointError`
subclass — a truncated zip, a flipped bit, an old format version or a
mismatched solver config all refuse loudly instead of resuming wrong
(tests/test_checkpoint_properties.py).
"""

from __future__ import annotations

import hashlib
import json
import os
import zipfile

import numpy as np

# Format version of the on-disk layout.  Bump on ANY incompatible change
# to the payload naming, meta schema, or hash recipe; loads of other
# versions raise CheckpointVersionError (never a best-effort parse).
CKPT_VERSION = 1

_META_KEY = "__meta__"


class CheckpointError(RuntimeError):
    """Base class for every checkpoint save/restore failure."""


class CheckpointCorruptError(CheckpointError):
    """The file is unreadable, truncated, or fails its content hash."""


class CheckpointVersionError(CheckpointError):
    """The file's format version differs from :data:`CKPT_VERSION`."""


class CheckpointMismatchError(CheckpointError):
    """The stored state does not match the restoring solver's
    configuration (different l / maxit / state structure / dtype /
    operator size)."""


class CheckpointCertificationError(CheckpointError):
    """The restored iterate failed the true-residual certification
    check — the state decoded cleanly but does not reproduce the
    residual recorded at save time (DESIGN.md §19)."""


def content_hash(payload: dict[str, np.ndarray]) -> str:
    """sha256 over the payload arrays: key, dtype, shape and raw bytes
    in sorted key order — one flipped byte anywhere changes the hash."""
    h = hashlib.sha256()
    for k in sorted(payload):
        a = np.ascontiguousarray(payload[k])
        h.update(k.encode())
        h.update(b"\x00")
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def save_checkpoint(path: str, payload: dict[str, np.ndarray],
                    meta: dict) -> dict:
    """Write ``payload`` + ``meta`` atomically to ``path``.

    The stored meta gains ``version`` and ``sha256`` keys; the enriched
    dict is returned.  Keys starting with ``__`` are reserved.
    """
    for k in payload:
        if k.startswith("__"):
            raise ValueError(f"payload key {k!r} is reserved")
    arrays = {k: np.asarray(v) for k, v in payload.items()}
    meta = dict(meta)
    meta["version"] = CKPT_VERSION
    meta["sha256"] = content_hash(arrays)
    blob = np.frombuffer(json.dumps(meta, sort_keys=True).encode("utf-8"),
                         dtype=np.uint8)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{_META_KEY: blob}, **arrays)
        os.replace(tmp, path)                       # atomic commit
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return meta


def load_checkpoint(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Load and verify one checkpoint; returns ``(payload, meta)``.

    Raises FileNotFoundError for a missing file (the caller's "no
    checkpoint yet" signal), :class:`CheckpointCorruptError` for
    anything unreadable or hash-mismatched, and
    :class:`CheckpointVersionError` for a foreign format version.
    """
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with np.load(path, allow_pickle=False) as z:
            files = list(z.files)
            if _META_KEY not in files:
                raise CheckpointCorruptError(f"{path}: no {_META_KEY} entry")
            meta = json.loads(bytes(np.asarray(z[_META_KEY])))
            payload = {k: np.asarray(z[k]) for k in files if k != _META_KEY}
    except CheckpointError:
        raise
    except (zipfile.BadZipFile, OSError, ValueError, EOFError, KeyError,
            json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"{path}: unreadable checkpoint ({type(e).__name__}: {e})"
        ) from e
    version = meta.get("version")
    if version != CKPT_VERSION:
        raise CheckpointVersionError(
            f"{path}: format version {version!r} != {CKPT_VERSION}")
    recorded = meta.get("sha256")
    actual = content_hash(payload)
    if recorded != actual:
        raise CheckpointCorruptError(
            f"{path}: content hash mismatch (stored {recorded!r}, "
            f"computed {actual!r})")
    return payload, meta
