"""Elastic checkpoint/restore for pipelined solves (DESIGN.md §19).

``CheckpointConfig(every=k)`` arms a segmented host driver that
snapshots the solver state at drained-ring cycle boundaries to a
content-hashed, versioned on-disk format, and resumes bitwise on the
same substrate (truth-certified via one true-residual recompute on
restore).  ``every=0`` leaves the solvers' compiled path untouched.
"""

from repro.checkpoint.format import (CKPT_VERSION,
                                     CheckpointCertificationError,
                                     CheckpointCorruptError, CheckpointError,
                                     CheckpointMismatchError,
                                     CheckpointVersionError, content_hash,
                                     load_checkpoint, save_checkpoint)
from repro.checkpoint.solve import (LAST_RESTORE, CheckpointConfig,
                                    checkpoint_path, checkpointed_solve,
                                    effective_kw, latest_checkpoint,
                                    list_checkpoints, load_slab_checkpoint,
                                    make_rel_fn, run_segmented,
                                    save_slab_checkpoint, state_payload,
                                    state_restore)

__all__ = [
    "CKPT_VERSION",
    "CheckpointError",
    "CheckpointCorruptError",
    "CheckpointVersionError",
    "CheckpointMismatchError",
    "CheckpointCertificationError",
    "CheckpointConfig",
    "content_hash",
    "save_checkpoint",
    "load_checkpoint",
    "checkpoint_path",
    "list_checkpoints",
    "latest_checkpoint",
    "checkpointed_solve",
    "effective_kw",
    "make_rel_fn",
    "run_segmented",
    "state_payload",
    "state_restore",
    "save_slab_checkpoint",
    "load_slab_checkpoint",
    "LAST_RESTORE",
]
