"""Segmented checkpointed solve drivers (DESIGN.md §19).

The cycle-boundary invariant
----------------------------
A p(l)-CG state is only host-snapshotable where the in-flight D ring is
EMPTY: mid-cycle, l reduction handles are in flight, and on a staged
substrate each shard's gather buffer holds a different rotation of the
ladder — per-device state that no host copy can represent.  The solver
already has exactly such points: every interrupt (breakdown restart,
periodic residual replacement, governor-scheduled replacement) re-inits
the cycle with ``ops.handle_zeros`` — a drained ring — and recomputes
the TRUE residual from the current iterate.  Checkpointing therefore
rides the interrupt machinery: ``CheckpointConfig(every=k)`` arms an
effective residual-replacement period of at most ``k`` solution
updates, and the driver snapshots AFTER each interrupt, where

* the ring is drained (no half-arrived handles are persisted — the ring
  is rebuilt as ``handle_zeros`` for whatever substrate restores it);
* every non-vector leaf is genuinely replicated (post-reduction
  scalars), so a host copy is well-defined under shard_map;
* the recorded residual is a clean true-residual recompute, which is
  what restore re-derives for the certification check.

The segmented driver below is bitwise-equivalent to the sequential
``lax.while_loop(cond, body)`` drive of the SAME program: the plain
body is ``cond(needs_interrupt, interrupt, step)``, and the segmented
form runs ``step`` under ``while (cond & ~needs_interrupt)`` then
applies ``interrupt`` on the host side — the identical arithmetic in
the identical order (tests/test_checkpoint.py pins this bitwise, fused
and unfused, single and batched, local and shard_map).

``every=0`` (or ``checkpoint=None``) takes the solvers' untouched
``lax.while_loop`` path — the compiled HLO is byte-identical to the
pre-§19 solver (asserted in tests/test_checkpoint.py).
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.format import (CheckpointCertificationError,
                                     CheckpointError, CheckpointMismatchError,
                                     load_checkpoint, save_checkpoint)

_CKPT_RE = re.compile(r"^ckpt_(\d{10})\.npz$")

# Meta keys that must match between a checkpoint and the restoring
# solver — a disagreement is a config mismatch, never a silent resume.
_STRUCT_KEYS = ("kind", "method", "n", "dtype", "treedef", "maxit", "tol",
                "replace_every", "max_restarts", "l", "recurrence",
                "telemetry_cap", "governed", "every")


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """Checkpoint policy for a solve (DESIGN.md §19).

    every:        snapshot at least every ``every`` solution updates
                  (0 disables checkpointing entirely — the solver
                  compiles to its pre-§19 HLO unchanged).  Arming
                  checkpoints forces an effective residual-replacement
                  period of ``min(replace_every or inf, every)``: a
                  checkpoint boundary IS a true-residual replacement.
    directory:    where snapshots go (``ckpt_<tot>.npz``); None keeps
                  the segmented drive without persisting (useful as
                  the uninterrupted oracle for parity tests).  Under
                  multi-process meshes only process 0 writes; the
                  directory must be shared (or replicated) for restore.
    keep:         on-disk snapshots retained (oldest GC'd first).
    resume:       load the latest checkpoint in ``directory`` before
                  solving (no-op when none exists yet).
    certify_rtol: tolerance for the restore-time true-residual
                  certification.  Same-substrate restores reproduce the
                  saved value bitwise; an elastic restore (different
                  shard count) re-reduces the same vectors in a
                  different order, so ULP-level slack is allowed.
    on_boundary:  host callback invoked with the global solution-update
                  count at every segment boundary (before the interrupt
                  is applied) — the fabric drills hang heartbeat touches
                  and deterministic fault injection here.  Updates, not
                  raw iterations: boundaries land at exact multiples of
                  ``every`` updates (plcg's ring-refill iterations after
                  each restart advance ``tot`` but not ``upd``).
    """

    every: int = 0
    directory: str | None = None
    keep: int = 2
    resume: bool = False
    certify_rtol: float = 1e-8
    on_boundary: Callable[[int], None] | None = None

    @property
    def armed(self) -> bool:
        return self.every > 0


# --------------------------------------------------------------------------
# Directory layout.
# --------------------------------------------------------------------------

def checkpoint_path(directory: str, tot: int) -> str:
    return os.path.join(directory, f"ckpt_{tot:010d}.npz")


def list_checkpoints(directory: str) -> list[str]:
    """Checkpoint files in ``directory``, oldest first."""
    if not os.path.isdir(directory):
        return []
    names = sorted(n for n in os.listdir(directory) if _CKPT_RE.match(n))
    return [os.path.join(directory, n) for n in names]


def latest_checkpoint(directory: str) -> str | None:
    paths = list_checkpoints(directory)
    return paths[-1] if paths else None


def _gc(directory: str, keep: int) -> None:
    paths = list_checkpoints(directory)
    for p in paths[:-keep] if keep > 0 else paths:
        try:
            os.remove(p)
        except OSError:
            pass


# --------------------------------------------------------------------------
# State <-> payload.  Leaves are keyed by flatten order ("leaf_NNN"); the
# meta records the treedef string, so a structural change between save
# and restore is a typed mismatch, not an index aliasing bug.
# --------------------------------------------------------------------------

def _flatten_with_mask(state, exclude_mask):
    vals, treedef = jax.tree_util.tree_flatten(state)
    if exclude_mask is None:
        exc = [False] * len(vals)
    else:
        exc, mdef = jax.tree_util.tree_flatten(exclude_mask)
        assert mdef == treedef, "exclude mask must match the state pytree"
    return vals, exc, treedef


def state_payload(state, exclude_mask=None) -> dict[str, np.ndarray]:
    """Flatten a (host-readable) state pytree into named numpy arrays.

    Leaves where ``exclude_mask`` is True are dropped — the restore
    side rebuilds them from its own template (the drained D ring, which
    is substrate-shaped and all zeros at a boundary by construction).
    """
    vals, exc, _ = _flatten_with_mask(state, exclude_mask)
    return {f"leaf_{i:03d}": np.asarray(v)
            for i, (v, e) in enumerate(zip(vals, exc)) if not e}


def state_treedef_str(state) -> str:
    return str(jax.tree_util.tree_structure(state))


def _place_like(template_leaf, value: np.ndarray):
    """Device-place ``value`` with the template leaf's sharding — this
    is what makes restore elastic: the bytes come from the checkpoint,
    the placement from whatever substrate is restoring."""
    if isinstance(template_leaf, jax.Array):
        try:
            return jax.make_array_from_callback(
                value.shape, template_leaf.sharding,
                lambda idx: value[idx])
        except Exception:
            return jnp.asarray(value)
    return jnp.asarray(value)


def state_restore(template, payload: dict[str, np.ndarray],
                  exclude_mask=None):
    """Rebuild a state pytree from ``payload``: excluded leaves come
    from ``template`` (shape-/sharding-correct for the restoring
    substrate), everything else from the checkpoint, shape- and
    dtype-checked against the template."""
    vals, exc, treedef = _flatten_with_mask(template, exclude_mask)
    out = []
    for i, (tv, e) in enumerate(zip(vals, exc)):
        if e:
            out.append(tv)
            continue
        key = f"leaf_{i:03d}"
        if key not in payload:
            raise CheckpointMismatchError(
                f"checkpoint payload is missing {key} "
                f"({len(payload)} stored leaves)")
        a = payload[key]
        tshape, tdtype = tuple(np.shape(tv)), np.asarray(tv).dtype \
            if not isinstance(tv, jax.Array) else tv.dtype
        if isinstance(tv, jax.Array):
            tshape = tuple(tv.shape)
        if tuple(a.shape) != tshape or a.dtype != tdtype:
            raise CheckpointMismatchError(
                f"{key}: stored {a.dtype}{tuple(a.shape)} != expected "
                f"{tdtype}{tshape}")
        out.append(_place_like(tv, a))
    extra = [k for k in payload if k.startswith("leaf_")
             and int(k[5:]) >= len(vals)]
    if extra:
        raise CheckpointMismatchError(
            f"checkpoint payload has unexpected leaves {sorted(extra)}")
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# Per-method hooks.  Only interrupt-capable methods can checkpoint: the
# boundary IS the interrupt.
# --------------------------------------------------------------------------

def exclude_mask(method: str, state):
    """Leaves to drop from the payload: the in-flight D ring for plcg
    (drained at every boundary; its shape is substrate-specific), and
    nothing for pcg (whose state carries no in-flight handles)."""
    m = jax.tree.map(lambda _: False, state)
    if method == "plcg":
        return m._replace(cyc=m.cyc._replace(D=True))
    return m


def iter_count(method: str, state):
    return state.tot if method == "plcg" else state.it


def upd_count(method: str, state):
    return state.upd if method == "plcg" else state.it


def make_rel_fn(method: str, kw: dict) -> Callable:
    """``rel(ops, b, st) -> scalar``: the true relative residual M-norm
    of the state's iterate, recomputed from scratch (r = b - A x,
    z = M^{-1} r, ||r||_M / ||r0||_M).  Evaluated through the SAME ops
    at save and at restore, so a same-substrate restore certifies
    bitwise and an elastic one to reduction-order ULPs."""
    from repro.core.types import dot1

    if method == "plcg":
        from repro.kernels.fused_iter import SlabLayout

        layout = SlabLayout(l=int(kw["l"]),
                            RB=max(int(kw["l"]) + 1, 3),
                            recurrence=kw.get("recurrence", "ghysels"))

        def rel(ops, b, st):
            x = st.cyc.S[layout.x_row]
            r = b - ops.apply_a(x)
            z = ops.prec(r)
            return jnp.sqrt(jnp.abs(dot1(ops, r, z))) / st.norm0

        return rel
    if method == "pcg":
        from repro.core.ghysels_pcg import X_ROW

        def rel(ops, b, st):
            x = st.S[X_ROW]
            r = b - ops.apply_a(x)
            u = ops.prec(r)
            return jnp.sqrt(jnp.abs(dot1(ops, r, u))) / st.hist[0]

        return rel
    raise KeyError(f"method {method!r} does not support checkpointing "
                   "(no interrupt boundary)")


def effective_kw(method: str, kw: dict, every: int) -> dict:
    """Builder kwargs with the checkpoint cadence folded in.

    Two ``since_rr`` thresholds OR'd in ``needs_interrupt`` equal the
    smaller one, so the effective replacement period is
    ``min(replace_every or inf, every)``.  plcg's restart budget (and
    with it the history length) grows to cover the extra scheduled
    restarts — applied identically by every driver of the same config,
    which is what keeps resumed-vs-uninterrupted histories bitwise.
    """
    if every <= 0:
        raise ValueError(f"checkpoint.every must be > 0 (got {every})")
    kw = dict(kw)
    base = int(kw.get("replace_every", 0) or 0)
    eff = every if base == 0 else min(base, every)
    kw["replace_every"] = eff
    if method == "plcg":
        if eff <= int(kw["l"]):
            raise ValueError(
                f"checkpoint interval {eff} must exceed the pipeline "
                f"depth l={kw['l']} (the ring must refill between "
                "boundaries)")
        maxit = int(kw.get("maxit", 1000))
        kw["max_restarts"] = (int(kw.get("max_restarts", 10))
                              + maxit // eff + 1)
    return kw


def solver_meta(method: str, n: int, dtype, kw: dict, every: int) -> dict:
    """Config identity stored with every snapshot and checked on
    restore (see ``_STRUCT_KEYS``)."""
    return {
        "kind": "solve",
        "method": method,
        "n": int(n),
        "dtype": str(np.dtype(dtype)),
        "maxit": int(kw.get("maxit", 1000)),
        "tol": float(kw.get("tol", 1e-6)),
        "replace_every": int(kw.get("replace_every", 0)),
        "max_restarts": int(kw.get("max_restarts", 10)),
        "l": int(kw.get("l", 0)),
        "recurrence": kw.get("recurrence", "ghysels"),
        "telemetry_cap": int(kw.get("telemetry_cap", 0)),
        "governed": kw.get("governor") is not None,
        "every": int(every),
    }


def check_meta(meta: dict, expect: dict) -> None:
    bad = {k: (meta.get(k), expect.get(k)) for k in _STRUCT_KEYS
           if meta.get(k) != expect.get(k)}
    if bad:
        detail = ", ".join(f"{k}: stored {s!r} != expected {e!r}"
                           for k, (s, e) in sorted(bad.items()))
        raise CheckpointMismatchError(f"checkpoint/config mismatch: {detail}")


# --------------------------------------------------------------------------
# The segmented drive loop — substrate-agnostic.  ``seg``/``interrupt``
# are compiled callables (plain jit locally, shard_map-wrapped jits on a
# mesh); ``cond``/``needs`` read only replicated scalar leaves, so the
# host evaluates them directly (every process takes the same branch —
# the loop is SPMD-safe).
# --------------------------------------------------------------------------

def run_segmented(st, *, cond, needs, seg, interrupt, method: str,
                  cfg: CheckpointConfig,
                  snapshot: Callable[[Any], None] | None):
    while bool(np.asarray(cond(st))):
        st = seg(st)
        if bool(np.asarray(cond(st))):
            # The inner loop only exits with cond still true when an
            # interrupt is due (its cond is ``cond & ~needs``).
            assert bool(np.asarray(needs(st)))
            if cfg.on_boundary is not None:
                cfg.on_boundary(int(np.asarray(upd_count(method, st))))
            st = interrupt(st)
            if snapshot is not None:
                snapshot(st)
    return st


class _Restored:
    """Record of a successful restore (host bookkeeping for drills)."""

    def __init__(self, path: str, meta: dict):
        self.path = path
        self.meta = meta


#: Most recent successful restore in this process (path + meta), for
#: recovery drills that report which iteration they resumed from.
LAST_RESTORE: list[_Restored] = []


def try_restore(template, cfg: CheckpointConfig, expect_meta: dict,
                mask, rel_of_state: Callable[[Any], Any]):
    """Load + certify the latest checkpoint in ``cfg.directory`` onto
    ``template``'s substrate; returns the template unchanged when no
    checkpoint exists yet."""
    path = latest_checkpoint(cfg.directory) if cfg.directory else None
    if path is None:
        return template
    payload, meta = load_checkpoint(path)
    check_meta(meta, expect_meta)
    st = state_restore(template, payload, mask)
    rel_now = float(np.asarray(rel_of_state(st)))
    rel_saved = float(meta["rel_true"])
    tol = cfg.certify_rtol * max(abs(rel_saved), np.finfo(np.float64).tiny)
    if not abs(rel_now - rel_saved) <= tol:
        raise CheckpointCertificationError(
            f"{path}: true-residual certification failed — recomputed "
            f"rel {rel_now:.17e} vs saved {rel_saved:.17e} "
            f"(rtol {cfg.certify_rtol:g})")
    LAST_RESTORE.append(_Restored(path, meta))
    return st


def make_snapshot_fn(cfg: CheckpointConfig, meta_base: dict, mask,
                     method: str, rel_of_state, gather=None,
                     is_writer: bool = True):
    """Build the per-boundary snapshot callback (None when ``cfg`` has
    no directory).  ``gather`` (distributed substrates) turns the
    device state into a fully host-readable one first."""
    if cfg.directory is None:
        return None
    os.makedirs(cfg.directory, exist_ok=True)

    def snapshot(st):
        # rel BEFORE gathering: one reduction on the live substrate.
        rel = float(np.asarray(rel_of_state(st)))
        host = gather(st) if gather is not None else st
        if not is_writer:
            return
        meta = dict(meta_base)
        tot = int(np.asarray(iter_count(method, host)))
        meta["tot"] = tot
        meta["upd"] = int(np.asarray(upd_count(method, host)))
        meta["rel_true"] = rel
        save_checkpoint(checkpoint_path(cfg.directory, tot),
                        state_payload(host, mask), meta)
        _gc(cfg.directory, cfg.keep)

    return snapshot


# --------------------------------------------------------------------------
# Local (single-substrate) checkpointed solve — entered from
# pipelined_cg.solve / ghysels_pcg.solve when checkpoint.every > 0.
# --------------------------------------------------------------------------

def checkpointed_solve(ops, b, method: str, x0, cfg: CheckpointConfig,
                       kw: dict):
    from repro.core.batched import BUILDERS

    kw = effective_kw(method, kw, cfg.every)
    build_kw = {k: v for k, v in kw.items() if k != "unroll"}
    prog = BUILDERS[method](ops, b, **build_kw)
    if prog.needs_interrupt is None or prog.interrupt is None:
        raise CheckpointError(
            f"method {method!r} exposes no interrupt boundary to "
            "checkpoint at")
    st = prog.init(jnp.zeros_like(b) if x0 is None else x0.astype(b.dtype))
    mask = exclude_mask(method, st)
    rel = make_rel_fn(method, kw)
    rel_j = jax.jit(lambda s: rel(ops, b, s))
    meta_base = solver_meta(method, b.shape[0], b.dtype, kw, cfg.every)
    meta_base["treedef"] = state_treedef_str(st)
    if cfg.resume:
        st = try_restore(st, cfg, meta_base, mask, rel_j)
    seg = jax.jit(lambda s: jax.lax.while_loop(
        lambda t: prog.cond(t) & ~prog.needs_interrupt(t), prog.step, s))
    interrupt = jax.jit(prog.interrupt)
    snapshot = make_snapshot_fn(cfg, meta_base, mask, method, rel_j)
    st = run_segmented(st, cond=prog.cond, needs=prog.needs_interrupt,
                       seg=seg, interrupt=interrupt, method=method,
                       cfg=cfg, snapshot=snapshot)
    return prog.finish(st)


# --------------------------------------------------------------------------
# Batched slab snapshots (DESIGN.md §19).  Slab states are persisted
# as-is at CHUNK boundaries — including in-flight ring slots — so these
# round-trips are same-substrate bitwise only: valid on the local
# backend always, and on distributed slabs only where every leaf is
# host-faithful (monolithic reduction; a staged slab's gather buffers
# are per-device mid-ladder).  The honest scope is documented in §19.
# --------------------------------------------------------------------------

def save_slab_checkpoint(path: str, B, state, meta: dict) -> dict:
    payload = dict(state_payload(state))
    payload["slab_B"] = np.asarray(B)
    meta = dict(meta)
    meta["kind"] = "slab"
    meta["treedef"] = state_treedef_str(state)
    return save_checkpoint(path, payload, meta)


def load_slab_checkpoint(path: str, template_state, expect_meta: dict
                         | None = None):
    """Returns ``(B, state, meta)`` restored onto ``template_state``'s
    substrate; ``expect_meta`` keys (plus kind/treedef) must match."""
    payload, meta = load_checkpoint(path)
    if meta.get("kind") != "slab":
        raise CheckpointMismatchError(
            f"{path}: kind {meta.get('kind')!r} is not a slab checkpoint")
    expect = dict(expect_meta or {})
    expect["treedef"] = state_treedef_str(template_state)
    bad = {k: (meta.get(k), v) for k, v in expect.items()
           if meta.get(k) != v}
    if bad:
        detail = ", ".join(f"{k}: stored {s!r} != expected {e!r}"
                           for k, (s, e) in sorted(bad.items()))
        raise CheckpointMismatchError(f"slab checkpoint mismatch: {detail}")
    if "slab_B" not in payload:
        raise CheckpointMismatchError(f"{path}: no slab_B entry")
    B = jnp.asarray(payload.pop("slab_B"))
    state = state_restore(template_state, payload)
    return B, state, meta
