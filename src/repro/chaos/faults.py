"""Process-level fault plans for the multi-controller fabric
(DESIGN.md §18).

The in-jax injection layer (``repro.chaos.inject``) perturbs VALUES; it
cannot make a rank slow or dead — those faults live at the process
level, where ``repro.parallel.fabric`` already supervises the group.  A
:class:`FaultPlan` describes one scripted fault per group and ships it
to the chosen rank via environment variables; the child calls
:func:`apply_from_env` once at startup.

Honesty notes (DESIGN.md §18): a *per-hop* delay inside a compiled XLA
collective is not injectable without recompiling the program, so the
delay fault is a **startup skew** — the delayed rank enters the SPMD
program late, which (lockstep collectives) stalls every subsequent
collective the group runs, the observable signature of one straggler
rank.  The kill fault is a hard ``os._exit`` from a daemon timer — the
process dies mid-collective without unwinding, exactly what the fabric
watchdog must convert into a typed error with heartbeat ages
(tests/test_fabric.py).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time

ENV_KILL_RANK = "REPRO_CHAOS_KILL_RANK"
ENV_KILL_AFTER = "REPRO_CHAOS_KILL_AFTER_S"
ENV_DELAY_RANK = "REPRO_CHAOS_DELAY_RANK"
ENV_DELAY_S = "REPRO_CHAOS_DELAY_S"
ENV_JITTER_S = "REPRO_CHAOS_JITTER_S"
ENV_SEED = "REPRO_CHAOS_SEED"

KILL_EXIT_CODE = 137          # mimic SIGKILL's conventional exit status


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One scripted process-level fault for a fabric launch.

    ``kill_rank``/``kill_after_s``   hard-kill that rank after the delay;
    ``delay_rank``/``delay_s``       startup skew for that rank, plus a
                                     deterministic seed-derived jitter of
                                     up to ``jitter_s``.
    """

    kill_rank: int | None = None
    kill_after_s: float = 1.0
    delay_rank: int | None = None
    delay_s: float = 0.0
    jitter_s: float = 0.0
    seed: int = 0

    def env(self) -> dict[str, str]:
        """Environment fragment encoding this plan (same for all ranks —
        each child matches its own process id against the plan)."""
        out = {ENV_SEED: str(self.seed)}
        if self.kill_rank is not None:
            out[ENV_KILL_RANK] = str(self.kill_rank)
            out[ENV_KILL_AFTER] = repr(float(self.kill_after_s))
        if self.delay_rank is not None:
            out[ENV_DELAY_RANK] = str(self.delay_rank)
            out[ENV_DELAY_S] = repr(float(self.delay_s))
            out[ENV_JITTER_S] = repr(float(self.jitter_s))
        return out


def _jitter(seed: int, rank: int, cap: float) -> float:
    if cap <= 0:
        return 0.0
    h = (seed * 2654435761 + rank * 40503) & 0xFFFFFFFF
    h ^= h >> 16
    return cap * ((h & 0xFFFF) / float(1 << 16))


def apply_from_env(process_id: int, environ=None) -> dict:
    """Install this rank's share of the fault plan (child-side).

    Reads the ``REPRO_CHAOS_*`` variables; sleeps the startup skew
    inline and arms the kill timer on a daemon thread.  Returns a small
    dict describing what was installed (for child-side logging).
    Harmless no-op when no plan is present.
    """
    env = os.environ if environ is None else environ
    seed = int(env.get(ENV_SEED, "0"))
    installed: dict = {}

    delay_rank = env.get(ENV_DELAY_RANK)
    if delay_rank is not None and int(delay_rank) == process_id:
        delay = float(env.get(ENV_DELAY_S, "0"))
        delay += _jitter(seed, process_id, float(env.get(ENV_JITTER_S, "0")))
        time.sleep(delay)
        installed["delayed_s"] = delay

    kill_rank = env.get(ENV_KILL_RANK)
    if kill_rank is not None and int(kill_rank) == process_id:
        after = float(env.get(ENV_KILL_AFTER, "1.0"))

        def _die():
            time.sleep(after)
            os._exit(KILL_EXIT_CODE)

        threading.Thread(target=_die, daemon=True).start()
        installed["kill_after_s"] = after

    return installed
