"""Process-level fault plans for the multi-controller fabric
(DESIGN.md §18).

The in-jax injection layer (``repro.chaos.inject``) perturbs VALUES; it
cannot make a rank slow or dead — those faults live at the process
level, where ``repro.parallel.fabric`` already supervises the group.  A
:class:`FaultPlan` describes one scripted fault per group and ships it
to the chosen rank via environment variables; the child calls
:func:`apply_from_env` once at startup.

Honesty notes (DESIGN.md §18): a *per-hop* delay inside a compiled XLA
collective is not injectable without recompiling the program, so the
delay fault is a **startup skew** — the delayed rank enters the SPMD
program late, which (lockstep collectives) stalls every subsequent
collective the group runs, the observable signature of one straggler
rank.  The kill fault is a hard ``os._exit`` from a daemon timer — the
process dies mid-collective without unwinding, exactly what the fabric
watchdog must convert into a typed error with heartbeat ages
(tests/test_fabric.py).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time

ENV_KILL_RANK = "REPRO_CHAOS_KILL_RANK"
ENV_KILL_AFTER = "REPRO_CHAOS_KILL_AFTER_S"
ENV_KILL_AT_ITER = "REPRO_CHAOS_KILL_AT_ITER"
ENV_STALL_RANK = "REPRO_CHAOS_STALL_RANK"
ENV_STALL_AT_ITER = "REPRO_CHAOS_STALL_AT_ITER"
ENV_STALL_FOR_S = "REPRO_CHAOS_STALL_FOR_S"
ENV_DELAY_RANK = "REPRO_CHAOS_DELAY_RANK"
ENV_DELAY_S = "REPRO_CHAOS_DELAY_S"
ENV_JITTER_S = "REPRO_CHAOS_JITTER_S"
ENV_SEED = "REPRO_CHAOS_SEED"

KILL_EXIT_CODE = 137          # mimic SIGKILL's conventional exit status


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One scripted process-level fault for a fabric launch.

    ``kill_rank``/``kill_after_s``   hard-kill that rank after the delay;
    ``kill_rank``/``kill_at_iter``   hard-kill that rank at the first
                                     segment boundary whose iteration
                                     count reaches ``kill_at_iter``
                                     (iteration-deterministic, for
                                     recovery drills; overrides the
                                     time-based kill);
    ``stall_rank``/``stall_at_iter``/``stall_for_s``
                                     one-shot sleep of ``stall_for_s``
                                     (plus seeded jitter) at the first
                                     boundary reaching ``stall_at_iter``
                                     — the wedged-but-alive rank the
                                     heartbeat watchdog must flag;
    ``delay_rank``/``delay_s``       startup skew for that rank, plus a
                                     deterministic seed-derived jitter of
                                     up to ``jitter_s``.

    Iteration-indexed faults fire from :func:`iteration_fault_tick`,
    which the checkpointing driver invokes at every drained-ring segment
    boundary (``CheckpointConfig.on_boundary``, DESIGN.md §19) — the
    only host-visible points of a compiled solve.
    """

    kill_rank: int | None = None
    kill_after_s: float = 1.0
    kill_at_iter: int | None = None
    stall_rank: int | None = None
    stall_at_iter: int = 0
    stall_for_s: float = 0.0
    delay_rank: int | None = None
    delay_s: float = 0.0
    jitter_s: float = 0.0
    seed: int = 0

    def env(self) -> dict[str, str]:
        """Environment fragment encoding this plan (same for all ranks —
        each child matches its own process id against the plan)."""
        out = {ENV_SEED: str(self.seed)}
        if self.kill_rank is not None:
            out[ENV_KILL_RANK] = str(self.kill_rank)
            if self.kill_at_iter is not None:
                out[ENV_KILL_AT_ITER] = str(self.kill_at_iter)
            else:
                out[ENV_KILL_AFTER] = repr(float(self.kill_after_s))
        if self.stall_rank is not None:
            out[ENV_STALL_RANK] = str(self.stall_rank)
            out[ENV_STALL_AT_ITER] = str(self.stall_at_iter)
            out[ENV_STALL_FOR_S] = repr(float(self.stall_for_s))
        if self.delay_rank is not None:
            out[ENV_DELAY_RANK] = str(self.delay_rank)
            out[ENV_DELAY_S] = repr(float(self.delay_s))
            out[ENV_JITTER_S] = repr(float(self.jitter_s))
        return out


def _jitter(seed: int, rank: int, cap: float) -> float:
    if cap <= 0:
        return 0.0
    h = (seed * 2654435761 + rank * 40503) & 0xFFFFFFFF
    h ^= h >> 16
    return cap * ((h & 0xFFFF) / float(1 << 16))


def apply_from_env(process_id: int, environ=None) -> dict:
    """Install this rank's share of the fault plan (child-side).

    Reads the ``REPRO_CHAOS_*`` variables; sleeps the startup skew
    inline and arms the kill timer on a daemon thread.  Returns a small
    dict describing what was installed (for child-side logging).
    Harmless no-op when no plan is present.
    """
    env = os.environ if environ is None else environ
    seed = int(env.get(ENV_SEED, "0"))
    installed: dict = {}

    delay_rank = env.get(ENV_DELAY_RANK)
    if delay_rank is not None and int(delay_rank) == process_id:
        delay = float(env.get(ENV_DELAY_S, "0"))
        delay += _jitter(seed, process_id, float(env.get(ENV_JITTER_S, "0")))
        time.sleep(delay)
        installed["delayed_s"] = delay

    kill_rank = env.get(ENV_KILL_RANK)
    if (kill_rank is not None and int(kill_rank) == process_id
            and ENV_KILL_AT_ITER not in env):
        after = float(env.get(ENV_KILL_AFTER, "1.0"))

        def _timed_die():
            time.sleep(after)
            _die()

        threading.Thread(target=_timed_die, daemon=True).start()
        installed["kill_after_s"] = after

    return installed


def _die() -> None:
    """Hard process death without unwinding (no atexit, no flushes) —
    what an OOM-killed or power-lost rank looks like to its peers.
    Module-level so tests can monkeypatch it."""
    os._exit(KILL_EXIT_CODE)


class IterationFaults:
    """This rank's iteration-indexed faults (kill_at_iter / stall),
    decoded from the environment by :func:`install_iteration_faults`.

    ``tick(it)`` is shaped for ``CheckpointConfig.on_boundary``: the
    checkpointing driver calls it with the global iteration count at
    every drained-ring segment boundary.  Faults are deterministic in
    the ITERATION index, not in wall time — two drill runs kill at the
    same boundary bit-for-bit.
    """

    def __init__(self, kill_at_iter: int | None = None,
                 stall_at_iter: int | None = None,
                 stall_for_s: float = 0.0):
        self.kill_at_iter = kill_at_iter
        self.stall_at_iter = stall_at_iter
        self.stall_for_s = stall_for_s
        self.stalled = False

    @property
    def armed(self) -> bool:
        return self.kill_at_iter is not None or self.stall_at_iter is not None

    def tick(self, it: int) -> None:
        if (self.stall_at_iter is not None and not self.stalled
                and it >= self.stall_at_iter):
            self.stalled = True          # one-shot: a wedge, not a crawl
            time.sleep(self.stall_for_s)
        if self.kill_at_iter is not None and it >= self.kill_at_iter:
            _die()


def install_iteration_faults(process_id: int, environ=None) -> IterationFaults:
    """Decode this rank's iteration-indexed faults (child-side).

    Returns an :class:`IterationFaults` whose ``tick`` the caller wires
    into ``CheckpointConfig.on_boundary``; unarmed (no-op ticks) when
    the plan names another rank or no plan is present.
    """
    env = os.environ if environ is None else environ
    seed = int(env.get(ENV_SEED, "0"))
    kill_at = None
    kill_rank = env.get(ENV_KILL_RANK)
    if kill_rank is not None and int(kill_rank) == process_id:
        at = env.get(ENV_KILL_AT_ITER)
        kill_at = int(at) if at is not None else None
    stall_at, stall_for = None, 0.0
    stall_rank = env.get(ENV_STALL_RANK)
    if stall_rank is not None and int(stall_rank) == process_id:
        stall_at = int(env.get(ENV_STALL_AT_ITER, "0"))
        stall_for = float(env.get(ENV_STALL_FOR_S, "0"))
        stall_for += _jitter(seed, process_id,
                             float(env.get(ENV_JITTER_S, "0")))
    return IterationFaults(kill_at_iter=kill_at, stall_at_iter=stall_at,
                           stall_for_s=stall_for)
