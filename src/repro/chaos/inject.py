"""Deterministic reduction-payload fault injection (DESIGN.md §18).

The one place the paper's algorithm is exposed to the network is the
pipelined global reduction: a corrupted or rounding-noisy allreduce
payload poisons the scalar phase, which poisons the recurrences, which
caps attainable accuracy.  ``chaos_ops`` wraps a backend-built
:class:`~repro.core.types.SolverOps` so that every reduction WAIT —
the consumption point where the combined payload becomes scalar-phase
input — returns a deterministically perturbed value:

* the perturbation is **multiplicative and relative**
  (``x * (1 + amp * noise)``), so ULP-scale (``amp ~ 1e-16``) through
  catastrophic (``amp ~ 1``) corruption shares one knob;
* ``noise`` is a pure **value hash** of the payload bits mixed with the
  seed — no RNG state, no trace-time randomness, and (crucially) the
  SAME noise on every rank: the wait's output is the post-combine
  payload, replicated across shards, so a replicated input hashes to a
  replicated perturbation and the scalar phase — hence all control flow
  (breakdown, governor arms, convergence) — stays rank-identical.  The
  cross-process assertion lives in ``scripts/multiprocess_parity.py
  --chaos``.

Only the wait is wrapped.  ``apply_a`` / ``prec`` stay clean, which is
what makes governed recovery possible: a residual replacement recomputes
``b - A x`` in clean arithmetic, so each governor action discards the
accumulated payload corruption (tests/test_stability.py,
benchmarks/stability_bench.py).  Process-level faults (slow ranks, rank
kills) are ``repro.chaos.faults``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.types import SolverOps


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded reduction-payload perturbation + process-level faults.

    Value level (this module):

    ``payload_rel_amp``  relative perturbation amplitude (0 disables);
    ``payload_prob``     fraction of payload entries perturbed (gated by
                         a second value hash, so the choice of WHICH
                         entries is as deterministic as the noise);
    ``seed``             mixes into both hashes (and into the stall
                         jitter below).

    Process level (executed by ``repro.chaos.faults`` in fabric
    children; iteration-indexed faults fire at checkpoint segment
    boundaries, so recovery drills are deterministic and CI-runnable):

    ``kill_rank``/``kill_rank_at_iter``  hard-kill that rank at the
                         first boundary reaching the iteration index;
    ``stall_rank``/``stall_rank_at_iter``/``stall_rank_for_s``
                         one-shot seeded-jitter sleep at a boundary —
                         the wedged-rank signature for the heartbeat
                         watchdog.

    ``fault_plan()`` converts the process-level fields into the
    :class:`repro.chaos.faults.FaultPlan` a fabric launch ships to its
    children.
    """

    seed: int = 0
    payload_rel_amp: float = 0.0
    payload_prob: float = 1.0
    kill_rank: int | None = None
    kill_rank_at_iter: int | None = None
    stall_rank: int | None = None
    stall_rank_at_iter: int = 0
    stall_rank_for_s: float = 0.0

    def fault_plan(self):
        from repro.chaos.faults import FaultPlan

        return FaultPlan(kill_rank=self.kill_rank,
                         kill_at_iter=self.kill_rank_at_iter,
                         stall_rank=self.stall_rank,
                         stall_at_iter=self.stall_rank_at_iter,
                         stall_for_s=self.stall_rank_for_s,
                         seed=self.seed)


def _mix(h: jax.Array) -> jax.Array:
    """32-bit integer finalizer (splitmix-style avalanche)."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return h


def _value_hash(x: jax.Array, seed: int, salt: int) -> jax.Array:
    """uint32 hash of each element's float32 bit pattern + seed + salt."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    key = (seed * 2654435761 + salt * 40503) & 0xFFFFFFFF
    return _mix(bits ^ jnp.uint32(key))


def perturb_payload(x: jax.Array, cfg: ChaosConfig) -> jax.Array:
    """Deterministically perturb a reduction payload, dtype-preserving."""
    if cfg.payload_rel_amp == 0.0:
        return x
    # noise in [-1, 1): top 24 hash bits -> uniform [0, 1) -> shift.
    h = _value_hash(x, cfg.seed, salt=1)
    noise = (h >> 8).astype(x.dtype) * (1.0 / (1 << 24)) * 2.0 - 1.0
    if cfg.payload_prob < 1.0:
        g = _value_hash(x, cfg.seed, salt=2)
        gate = ((g >> 8).astype(x.dtype) * (1.0 / (1 << 24))
                < cfg.payload_prob)
        noise = jnp.where(gate, noise, jnp.zeros_like(noise))
    amp = jnp.asarray(cfg.payload_rel_amp, x.dtype)
    return (x * (1.0 + amp * noise)).astype(x.dtype)


def chaos_ops(ops: SolverOps, cfg: ChaosConfig) -> SolverOps:
    """Wrap ``ops`` so every reduction wait returns a perturbed payload.

    The wrap sits AFTER the substrate's own wait (staged ladders finish
    their remaining hops first), i.e. on the replicated post-combine
    value — the injection point that models a corrupted wire without
    desynchronizing ranks.  Everything else (SPMV, preconditioner, the
    start/advance half of the handle life cycle, tracer tags) passes
    through untouched, so the compiled solve keeps exactly one reduction
    start per iteration (asserted in tests/test_stability.py).
    """
    base_wait = ops.dot_block_wait

    if base_wait is None:
        def wrapped(dots, advanced=0):
            return perturb_payload(dots, cfg)
    else:
        def wrapped(dots, advanced=0, _wait=base_wait):
            return perturb_payload(_wait(dots, advanced=advanced), cfg)

    return dataclasses.replace(ops, dot_block_wait=wrapped)
