"""Deterministic fault injection for the pipelined-reduction stack
(DESIGN.md §18): seeded reduction-payload perturbation (``inject``) and
process-level fault plans — slow ranks, rank kills — for the fabric
watchdog (``faults``).  Used by tests/test_stability.py,
benchmarks/stability_bench.py and ``scripts/multiprocess_parity.py
--chaos`` to PROVE governed recovery rather than assume it.
"""

from repro.chaos.inject import ChaosConfig, chaos_ops, perturb_payload
from repro.chaos.faults import (KILL_EXIT_CODE, FaultPlan, IterationFaults,
                                apply_from_env, install_iteration_faults)

__all__ = [
    "ChaosConfig", "chaos_ops", "perturb_payload",
    "FaultPlan", "apply_from_env", "KILL_EXIT_CODE",
    "IterationFaults", "install_iteration_faults",
]
