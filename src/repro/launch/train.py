"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --seq 128 --batch 8 --l 2 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (1 CPU here; a pod via the same code —
the mesh axes and shardings come from repro.launch.sharding).  Features:
pipelined gradient reduction (--l), delayed grad-norm clipping, async
checkpointing with atomic commit + keep-N GC, automatic RESTART from the
latest checkpoint (including the in-flight gradient ring, so the delayed
gradient stream resumes exactly), elastic restore onto a different device
count.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticData
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import init_grad_ring, make_pipelined_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--l", type=int, default=0,
                    help="gradient-reduction pipeline depth (paper's l)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg)
    data = SyntheticData.for_config(cfg, seq_len=args.seq, batch=args.batch,
                                    seed=args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps, delayed_norm=args.l > 0)

    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    ring = init_grad_ring(params, args.l)
    start_step = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and mgr.latest() is not None:
        template = jax.eval_shape(lambda: {"params": params, "opt": opt,
                                           "ring": ring})
        state, meta = mgr.restore(template)
        params, opt, ring = state["params"], state["opt"], state["ring"]
        start_step = meta["step"]
        print(f"[restart] restored step {start_step} from {args.ckpt_dir} "
              f"(elastic: restores onto any device layout)")

    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, l={args.l}, "
          f"{len(jax.devices())} device(s)")

    step_fn = jax.jit(make_pipelined_train_step(model, opt_cfg, args.l))
    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = data.batch_at(i)
        params, opt, ring, m = step_fn(params, opt, ring,
                                       jnp.asarray(i, jnp.int32), batch)
        if (i + 1) % args.log_every == 0:
            print(f"  step {i+1:5d} | loss {float(m['loss']):.4f} | "
                  f"gnorm {float(m['grad_norm']):.3f} | "
                  f"lr {float(m['lr']):.2e} | "
                  f"{(time.time()-t0)/(i-start_step+1):.2f}s/step")
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt, "ring": ring},
                     meta={"arch": cfg.name, "l": args.l, "seed": args.seed})
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt, "ring": ring},
                 meta={"arch": cfg.name, "l": args.l, "seed": args.seed},
                 block=True)
    print(f"[train] done: {args.steps - start_step} steps in "
          f"{time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
