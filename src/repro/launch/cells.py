"""(architecture × input-shape × mesh) cell construction for the dry-run.

A *cell* is a concrete lowering target: step function + ShapeDtypeStruct
arguments + in/out shardings.  Shapes per the assignment:

    train_4k      seq 4 096,   global_batch 256   -> train_step
    prefill_32k   seq 32 768,  global_batch 32    -> prefill
    decode_32k    seq 32 768,  global_batch 128   -> serve_step (1 token)
    long_500k     seq 524 288, global_batch 1     -> serve_step; ONLY for
                  sub-quadratic-state archs (zamba2, rwkv6) — the 8 pure
                  full-attention archs skip it (DESIGN.md §5)

No arrays are ever allocated here (eval_shape / ShapeDtypeStruct only).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, lm_arch_ids
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes
from repro.models import LM
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.train_step import make_train_step

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

SUBQUADRATIC = ("ssm", "hybrid")


def applicable(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.family in SUBQUADRATIC
    return True


def fsdp_for(cfg, mesh) -> bool:
    """FSDP weight sharding when TP alone cannot hold bf16 params in HBM."""
    n = cfg.param_count()
    per_chip = 2.0 * n / mesh.shape["model"]
    return per_chip > 4e9


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    cfg: Any
    tokens_per_step: float
    model_flops: float


def _batch_structs(cfg, seq: int, batch: int):
    b = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, seq // cfg.enc_frames_ratio, cfg.d_model), jnp.bfloat16)
    return b


def layer_unit(cfg) -> int:
    """Depth of one homogeneous repeat unit (hybrid: a full period)."""
    return cfg.shared_attn_period if cfg.family == "hybrid" else 1


def build_cell(arch: str, shape_name: str, mesh,
               kv_seq_shard: bool = False, pipeline_l: int = 0,
               depth_units: int | None = None,
               pure_dp: bool = False) -> Cell:
    """Assemble one dry-run cell (no allocation).  ``depth_units`` reduces
    the model to that many repeat units (roofline extrapolation pass) —
    sharding decisions (FSDP etc.) still follow the FULL config.
    ``pure_dp`` replicates all weights and data-parallelizes the batch over
    EVERY mesh axis (the §Perf hillclimb for small collective-bound archs:
    no tensor parallelism means no per-layer activation all-reduces)."""
    spec = SHAPES[shape_name]
    seq, batch, kind = spec["seq"], spec["batch"], spec["kind"]
    cfg = get_config(arch).replace(
        param_dtype="bfloat16", compute_dtype="bfloat16", max_seq=seq)
    assert applicable(cfg, shape_name), (arch, shape_name)
    fsdp = fsdp_for(cfg, mesh)          # decided on the FULL config
    if depth_units is not None:
        u = layer_unit(cfg)
        cfg = cfg.replace(
            n_layers=depth_units * u,
            n_enc_layers=(depth_units if cfg.family == "encdec"
                          else cfg.n_enc_layers))
    model = LM(cfg)
    dsz = mesh.shape.get("data", 1)

    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = shd.param_specs(params_shape, fsdp=fsdp, data_size=dsz)
    dp = dp_axes(mesh)
    dpx = dp if len(dp) > 1 else dp[0]
    if pure_dp:
        pspecs = jax.tree.map(
            lambda s: P(), pspecs, is_leaf=lambda x: isinstance(x, P))
        dpx = tuple(mesh.axis_names)        # batch over ALL axes
    psh = shd.to_shardings(mesh, pspecs)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    if kind == "train":
        opt_cfg = AdamWConfig()
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        ospecs = shd.opt_state_specs(params_shape, mesh, fsdp=fsdp)
        bstructs = _batch_structs(cfg, seq, batch)
        bspecs = shd.batch_specs(cfg, mesh)
        if pure_dp:
            flat_p = jax.tree_util.tree_flatten_with_path(params_shape)[0]
            tdef = jax.tree_util.tree_structure(params_shape)
            ztree = jax.tree_util.tree_unflatten(
                tdef, [shd.zero1_spec(P(), l.shape, mesh) for _, l in flat_p])
            ospecs = {"master": ztree, "m": ztree, "v": ztree,
                      "step": P(), "prev_norm": P()}
            bspecs = jax.tree.map(
                lambda s: P(dpx, *s[1:]), bspecs,
                is_leaf=lambda x: isinstance(x, P))
        osh = shd.to_shardings(mesh, ospecs)
        bsh = shd.to_shardings(mesh, bspecs)
        msh = NamedSharding(mesh, P())
        metrics_sh = {"loss": msh, "ce": msh, "aux": msh,
                      "grad_norm": msh, "lr": msh, "clip_scale": msh}
        tokens = float(batch * seq)
        if pipeline_l > 0:
            # the paper's technique on the training loop: depth-l delayed
            # gradient ring (ZeRO-sharded -> push is a reduce-scatter, the
            # paper's glred, consumed l steps later)
            from repro.train.train_step import (init_grad_ring,
                                                make_pipelined_train_step)
            opt_cfg = AdamWConfig(delayed_norm=True)
            step = make_pipelined_train_step(model, opt_cfg, pipeline_l)
            ring_shape = jax.eval_shape(
                lambda p: init_grad_ring(p, pipeline_l), params_shape)
            rspecs = shd.grad_ring_specs(params_shape, mesh, fsdp=fsdp)
            rsh = shd.to_shardings(mesh, rspecs)
            idx = jax.ShapeDtypeStruct((), jnp.int32)
            return Cell(arch, shape_name, step,
                        (params_shape, opt_shape, ring_shape, idx, bstructs),
                        (psh, osh, rsh, msh, bsh),
                        (psh, osh, rsh, metrics_sh), cfg,
                        tokens, 6.0 * n_active * tokens)
        step = make_train_step(model, opt_cfg)
        return Cell(arch, shape_name, step,
                    (params_shape, opt_shape, bstructs),
                    (psh, osh, bsh), (psh, osh, metrics_sh), cfg,
                    tokens, 6.0 * n_active * tokens)

    if kind == "prefill":
        prompt = seq - (cfg.n_patches if cfg.family == "vlm" else 0)
        bstructs = _batch_structs(cfg, prompt, batch)
        bstructs.pop("labels")
        bspecs = shd.batch_specs(cfg, mesh)
        bspecs.pop("labels")
        bsh = shd.to_shardings(mesh, bspecs)
        cache_shape = jax.eval_shape(lambda: model.init_cache(batch, seq))
        cspecs = shd.cache_specs(cfg, mesh, batch, kv_seq_shard)
        csh = shd.to_shardings(mesh, cspecs)

        def fn(params, b):
            return model.prefill(params, b, seq)

        lsh = NamedSharding(mesh, P(dpx, None, "model"))
        tokens = float(batch * seq)
        return Cell(arch, shape_name, fn, (params_shape, bstructs),
                    (psh, bsh), (lsh, csh), cfg,
                    tokens, 2.0 * n_active * tokens)

    # decode: one new token against a seq-length cache
    cache_shape = jax.eval_shape(lambda: model.init_cache(batch, seq))
    cspecs = shd.cache_specs(cfg, mesh, batch, kv_seq_shard)
    csh = shd.to_shardings(mesh, cspecs)
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tsh = NamedSharding(mesh, P(dpx if batch > 1 else None, None))
    lsh = NamedSharding(mesh, P(dpx if batch > 1 else None, None, "model"))

    def fn(params, token, cache):
        return model.decode_step(params, token, cache)

    tokens = float(batch)
    return Cell(arch, shape_name, fn, (params_shape, tok, cache_shape),
                (psh, tsh, csh), (lsh, csh), cfg,
                tokens, 2.0 * n_active * tokens)


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in lm_arch_ids():
        cfg = get_config(arch)
        for shape_name in SHAPES:
            if applicable(cfg, shape_name):
                out.append((arch, shape_name))
    return out
