"""Production meshes (DESIGN.md §2/§5).

Single pod : (16, 16)    = 256 chips, axes ("data", "model")
Multi-pod  : (2, 16, 16) = 512 chips, axes ("pod", "data", "model")

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets --xla_force_host_platform_device_count=512 before
first jax init; tests/benches must keep seeing 1 device).

The solver path flattens these meshes to a 1-D "shards" axis via
``repro.parallel.make_solver_mesh`` — the shard_map reduction backend
(``get_backend("shard_map", mesh=...)``, DESIGN.md §3) accepts either.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh (gradient-reduction
    domain — the paper's 'global' communicator)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_chips(mesh) -> int:
    return mesh.devices.size
