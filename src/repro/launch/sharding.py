"""Sharding rules: param/optimizer/batch/cache PartitionSpecs per mesh.

Megatron-style tensor parallelism over "model" (column-parallel in-proj,
row-parallel out-proj, vocab-sharded embeddings, EP for experts), data
parallelism over ("pod","data"), and ZeRO-1 optimizer-state sharding that
greedily places the DP axes on the largest still-unsharded divisible dim
of each state leaf (this is what lets command-r/arctic optimizer state fit
16 GB HBM).

The rules are heuristic per leaf NAME+shape; GSPMD propagates the rest.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

# leaf names whose LAST dim is column-parallel (output features on "model")
_COL = {"wq", "wk", "wv", "wi", "wg", "wr", "w_in", "router", "cm_k",
        "cm_r", "lora_a", "wlora_a"}
# leaf names whose SECOND-TO-LAST dim is row-parallel (input features)
_ROW = {"wo", "w_out", "cm_v", "proj"}
_COL_BIAS = {"bq", "bk", "bv", "bi"}
_EP = {"wi", "wg", "wo"}          # under a "moe" subtree: dim 1 = experts


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(f"#{p.idx}")
    return out


# §Perf hillclimb flag: shard MoE experts with TP on the expert hidden dim
# (column/row-parallel INSIDE each expert) instead of EP on the expert dim.
# Keeps the dispatched activations replicated over "model" and turns the
# per-layer expert-weight all-gather into a (much smaller) output psum.
MOE_TP = False


def spec_for_param(path, shape, fsdp: bool = False, data_size: int = 16) -> P:
    names = _path_names(path)
    leaf = names[-1]
    nd = len(shape)
    if leaf == "table":                       # (V, D) vocab-sharded embed
        spec = [
            "model", None]
    elif "moe" in names and leaf in _EP and nd == 4:
        if MOE_TP:
            # (L, E, D, F) column-parallel / (L, E, F, D) row-parallel
            spec = [None, None, None, "model"] if leaf in ("wi", "wg") \
                else [None, None, "model", None]
        else:
            spec = [None, "model", None, None]  # (L, E, D, F): EP on experts
    elif leaf in _COL and nd >= 2:
        spec = [None] * (nd - 1) + ["model"]
    elif leaf in _ROW and nd >= 2:
        spec = [None] * (nd - 2) + ["model", None]
    elif leaf in _COL_BIAS and nd >= 1:
        spec = [None] * (nd - 1) + ["model"]
    else:
        return P()                             # small: replicated
    if fsdp and nd >= 2:
        # weight-storage sharding over "data" (ZeRO-3/FSDP): skip the
        # stacked-layer dim (scan slices it), pick the largest free dim
        start = 1 if names[0] in ("layers", "enc_layers") else 0
        cands = [i for i in range(start, nd)
                 if spec[i] is None and shape[i] % data_size == 0]
        if cands:
            spec[max(cands, key=lambda i: shape[i])] = "data"
    return P(*spec)


def param_specs(params_shape, fsdp: bool = False, data_size: int = 16) -> Any:
    """Pytree of PartitionSpec matching a params (shape) tree."""
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    treedef = jax.tree_util.tree_structure(params_shape)
    return jax.tree_util.tree_unflatten(
        treedef,
        [spec_for_param(p, l.shape, fsdp, data_size) for p, l in flat])


def zero1_spec(spec: P, shape, mesh: Mesh) -> P:
    """Add DP-axis sharding to an optimizer-state leaf: place the still-
    unused DP axes (combined, else "data") on the largest dim that is
    unsharded and divisible — ZeRO-1."""
    cur = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for c in cur if c is not None
            for a in (c if isinstance(c, tuple) else (c,))}
    dp = tuple(a for a in dp_axes(mesh) if a not in used)
    if not dp:
        return P(*cur)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    tries = [(dp, dp_total)]
    if len(dp) > 1:
        tries.append(((dp[-1],), mesh.shape[dp[-1]]))
    for axes, size in tries:
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if cur[i] is None and shape[i] % size == 0 and shape[i] >= size:
                cur[i] = axes if len(axes) > 1 else axes[0]
                return P(*cur)
    return P(*cur)


def opt_state_specs(params_shape, mesh: Mesh, fsdp: bool = False) -> Any:
    """Specs for the AdamW state {master, m, v, step, prev_norm}."""
    pspecs = param_specs(params_shape, fsdp=fsdp,
                         data_size=mesh.shape.get("data", 1))
    flat_p = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    treedef = jax.tree_util.tree_structure(params_shape)
    zl = [zero1_spec(s, l.shape, mesh)
          for (_, l), s in zip(flat_p, flat_s)]
    ztree = jax.tree_util.tree_unflatten(treedef, zl)
    return {
        "master": ztree, "m": ztree, "v": ztree,
        "step": P(), "prev_norm": P(),
    }


def grad_ring_specs(params_shape, mesh: Mesh, fsdp: bool = False) -> Any:
    """The in-flight gradient ring (l, *param): ZeRO-sharded like the
    optimizer state (the push is then a reduce-scatter — the paper's glred
    — and the pop an all-gather, both in the delayed window)."""
    pspecs = param_specs(params_shape, fsdp=fsdp,
                         data_size=mesh.shape.get("data", 1))
    flat_p = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    treedef = jax.tree_util.tree_structure(params_shape)
    out = [P(None, *zero1_spec(s, l.shape, mesh))
           for (_, l), s in zip(flat_p, flat_s)]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs(cfg, mesh: Mesh) -> Any:
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    out = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "vlm":
        out["patch_embeds"] = P(dp, None, None)
    if cfg.family == "encdec":
        out["enc_embeds"] = P(dp, None, None)
    return out


def cache_specs(cfg, mesh: Mesh, batch: int, kv_seq_axis: bool = False) -> Any:
    """KV caches: heads on "model" (baseline, when n_kv divides the model
    axis) or sequence on "model" (split-KV — also the fallback for archs
    with few KV heads, e.g. GQA kv=8 on model=16).  SSM states: heads on
    "model"."""
    dp = dp_axes(mesh)
    dp = (dp if len(dp) > 1 else dp[0]) if batch > 1 else None
    fam = cfg.family
    msz = mesh.shape.get("model", 1)
    if not kv_seq_axis and cfg.n_kv % msz != 0:
        kv_seq_axis = True                       # heads don't divide: split-KV
    kv = P(None, dp, "model", None, None) if kv_seq_axis \
        else P(None, dp, None, "model", None)
    if fam in ("dense", "vlm", "moe"):
        return {"k": kv, "v": kv, "pos": P()}
    if fam == "encdec":
        return {"k": kv, "v": kv, "ck": kv, "cv": kv, "pos": P()}
    if fam == "ssm":
        return {"layers": {"s": P(None, dp, "model", None, None),
                           "x_tm": P(None, dp, None),
                           "x_cm": P(None, dp, None)},
                "pos": P()}
    if fam == "hybrid":
        return {"layers": {"ssm": P(None, dp, "model", None, None),
                           "conv": P(None, dp, None, "model")},
                "shared_k": kv, "shared_v": kv, "pos": P()}
    raise ValueError(fam)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
