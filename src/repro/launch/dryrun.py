"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multipod] [--kv-seq-shard] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]
    PYTHONPATH=src python -m repro.launch.dryrun --cg   # solver-path cells

Emits per cell: memory_analysis, cost_analysis FLOPs/bytes, collective
byte/count breakdown parsed from the optimized HLO, and the §Roofline
terms (TPU v5e constants).  Success of .lower().compile() for every cell
on the 16x16 and 2x16x16 meshes is deliverable (e).

The ``--cg`` cells run the solver path through ``distributed_solve`` (the
shard_map reduction backend, DESIGN.md §3).  Pick the pipeline depth for
a cell with the autotuner before dry-running it::

    from repro.launch.autotune import autotune_depth
    from benchmarks.timing_model import V5E
    best = autotune_depth(n=4_000_000, p=256, hw=V5E).best
    # -> run_cg_cell(mesh, l=best.l, unroll=best.unroll)

(DESIGN.md §5/§6.)
"""

# The 512 placeholder devices MUST be claimed before jax initializes —
# keep these two lines first (system prompt, MULTI-POD DRY-RUN §0).
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.launch.cells import SHAPES, all_cells, build_cell
from repro.launch.mesh import make_production_mesh, n_chips
from repro.utils.hlo import summarize_collectives
from repro.utils.roofline import HW_V5E, cost_analysis_dict, roofline_terms


def run_cell(arch: str, shape_name: str, mesh, kv_seq_shard=False,
             verbose=True, pure_dp=False, split_kv=False,
             pipeline_l=0, decode_bf16=False) -> dict:
    from repro.models import attention as attn_mod
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, kv_seq_shard=kv_seq_shard,
                      pure_dp=pure_dp, pipeline_l=pipeline_l)
    attn_mod.SPLIT_KV_AXIS = "model" if split_kv else None
    attn_mod.SPLIT_KV_MESH = mesh if split_kv else None
    attn_mod.DECODE_UPCAST = not decode_bf16
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    attn_mod.SPLIT_KV_AXIS = None
    attn_mod.SPLIT_KV_MESH = None
    attn_mod.DECODE_UPCAST = True
    t1 = time.time()

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    colls = summarize_collectives(hlo)
    chips = n_chips(mesh)
    terms = roofline_terms(cost, hlo, chips, HW_V5E)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "compile_s": round(t1 - t0, 1),
        "flops": terms.flops,
        "hbm_bytes": terms.hbm_bytes,
        "coll_bytes": terms.coll_bytes,
        "coll_per_kind": colls.per_kind,
        "t_compute": terms.t_compute,
        "t_memory": terms.t_memory,
        "t_collective": terms.t_collective,
        "dominant": terms.dominant,
        "model_flops": cell.model_flops,
        "tokens": cell.tokens_per_step,
        "useful_fraction": terms.useful_fraction(cell.model_flops),
        "mfu": terms.mfu(cell.model_flops),
        "memory": mem_info,
        "kv_seq_shard": kv_seq_shard,
        "split_kv": split_kv,
        "pure_dp": pure_dp,
        "pipeline_l": pipeline_l,
        "decode_bf16": decode_bf16,
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] "
              f"compile {rec['compile_s']}s | {terms.row()} | "
              f"useful {rec['useful_fraction']:.3f} | MFU-bound {rec['mfu']:.3f}")
        print("  collectives:\n" + str(colls))
        print(f"  memory: {mem_info}")
    return rec


def _compile_costs(arch, shape_name, mesh, depth_units, kv_seq_shard,
                   pure_dp=False, split_kv=False, decode_bf16=False,
                   moe_constrain=False):
    """Compile a reduced-depth FULL-WIDTH cell with the layer scan
    unrolled, so cost_analysis counts every layer."""
    from repro.models import model as model_mod
    from repro.models import attention as attn_mod
    from repro.models import moe as moe_mod
    from repro.launch.cells import build_cell as _bc
    old = model_mod.SCAN_UNROLL
    model_mod.SCAN_UNROLL = True
    attn_mod.SPLIT_KV_AXIS = "model" if split_kv else None
    attn_mod.SPLIT_KV_MESH = mesh if split_kv else None
    attn_mod.DECODE_UPCAST = not decode_bf16
    moe_mod.CONSTRAIN_EP = moe_constrain
    try:
        cell = _bc(arch, shape_name, mesh, kv_seq_shard=kv_seq_shard,
                   depth_units=depth_units, pure_dp=pure_dp)
        with jax.set_mesh(mesh):
            lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                              out_shardings=cell.out_shardings).lower(*cell.args)
            compiled = lowered.compile()
    finally:
        model_mod.SCAN_UNROLL = old
        attn_mod.SPLIT_KV_AXIS = None
        attn_mod.SPLIT_KV_MESH = None
        attn_mod.DECODE_UPCAST = True
        moe_mod.CONSTRAIN_EP = False
    cost = cost_analysis_dict(compiled)
    per_kind = summarize_collectives(compiled.as_text()).per_kind
    return (float(cost.get("flops", 0.0) or 0.0),
            float(cost.get("bytes accessed", 0.0) or 0.0),
            per_kind)


def run_cell_roofline(arch: str, shape_name: str, mesh, kv_seq_shard=False,
                      verbose=True, units=(2, 4), pure_dp=False,
                      split_kv=False, decode_bf16=False,
                      moe_constrain=False) -> dict:
    """Roofline terms via per-layer extrapolation: XLA counts a rolled scan
    body once, so the full-depth compile undercounts FLOPs.  We compile the
    model at ``units`` repeat-units UNROLLED (full width, full batch) and
    extrapolate linearly in depth:  X(L) = fixed + L·per_unit.  The time
    scans inside Mamba2/RWKV6 stay rolled: their recurrence FLOPs are <1%
    of the projection FLOPs (noted in EXPERIMENTS.md)."""
    from repro.configs import get_config
    from repro.launch.cells import build_cell as _bc, layer_unit

    cfg_full = get_config(arch)
    n_units_full = cfg_full.n_layers // layer_unit(cfg_full)
    a, b = units
    t0 = time.time()
    fa, ba, ca = _compile_costs(arch, shape_name, mesh, a, kv_seq_shard,
                                pure_dp, split_kv, decode_bf16, moe_constrain)
    fb, bb, cb = _compile_costs(arch, shape_name, mesh, b, kv_seq_shard,
                                pure_dp, split_kv, decode_bf16, moe_constrain)
    t1 = time.time()

    def extrap(xa, xb):
        per = (xb - xa) / (b - a)
        fixed = xa - a * per
        return fixed + n_units_full * per

    flops = extrap(fa, fb)
    hbm = extrap(ba, bb)
    kinds = sorted(set(ca) | set(cb))
    per_kind = {}
    for k in kinds:
        va = ca.get(k, {"count": 0, "bytes": 0})
        vb = cb.get(k, {"count": 0, "bytes": 0})
        per_kind[k] = {"count": extrap(va["count"], vb["count"]),
                       "bytes": extrap(va["bytes"], vb["bytes"])}

    # synthesize roofline terms from the extrapolated numbers
    from repro.utils.roofline import _RING_FACTOR
    chips = n_chips(mesh)
    hw = HW_V5E
    t_coll = sum(_RING_FACTOR[k](chips) * v["bytes"] / hw.link_bw
                 for k, v in per_kind.items())
    coll_bytes = sum(v["bytes"] for v in per_kind.values())
    # model flops of the FULL cell; HLO numbers are PER-DEVICE
    cell_full = _bc(arch, shape_name, mesh, kv_seq_shard=kv_seq_shard,
                    pure_dp=pure_dp)
    t_compute = flops / hw.peak_flops
    t_memory = hbm / hw.hbm_bw
    t_bound = max(t_compute, t_memory, t_coll)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "compile_s": round(t1 - t0, 1),
        "flops": flops, "hbm_bytes": hbm, "coll_bytes": coll_bytes,
        "coll_per_kind": per_kind,
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
        "model_flops": cell_full.model_flops,
        "tokens": cell_full.tokens_per_step,
        "useful_fraction": (cell_full.model_flops / (flops * chips)
                            if flops else None),
        "mfu": (cell_full.model_flops / (t_bound * chips * hw.peak_flops)
                if t_bound else None),
        "kv_seq_shard": kv_seq_shard,
        "pure_dp": pure_dp,
        "split_kv": split_kv,
        "decode_bf16": decode_bf16,
        "moe_constrain": moe_constrain,
        "extrapolated_from_units": list(units),
    }
    if verbose:
        print(f"[ROOFLINE {arch} × {shape_name} × {rec['mesh']}] "
              f"compile {rec['compile_s']}s | compute {t_compute:.3e}s | "
              f"memory {t_memory:.3e}s | collective {t_coll:.3e}s | "
              f"dominant={rec['dominant']} | useful "
              f"{rec['useful_fraction']:.3f} | MFU-bound {rec['mfu']:.3f}")
    return rec


def run_cg_cell(mesh, problem="laplace2d", l=2, verbose=True,
                method="plcg", unroll=1) -> dict:
    """Dry-run of the paper's own solver path on the production mesh
    (flattened to 1-D domain decomposition)."""
    from repro.configs import get_config
    from repro.configs.problems import build_operator
    from repro.core.chebyshev import chebyshev_shifts
    from repro.parallel.distributed import (
        distributed_solve, make_solver_mesh)
    import jax.numpy as jnp

    prob = get_config(problem)
    n_dev = mesh.devices.size
    smesh = make_solver_mesh(n_dev)
    op = build_operator(prob)
    lmin, lmax = op.eig_bounds()
    kw = {}
    if method == "plcg":
        kw = dict(l=l, sigmas=chebyshev_shifts(lmin, lmax, l,
                                               dtype=jnp.float32),
                  unroll=unroll)
    b = jax.ShapeDtypeStruct((op.n,), jnp.float32)
    fn, arrays = distributed_solve(
        smesh, op, b, method=method,
        maxit=prob.maxit, tol=prob.tol, jit=False, **kw)
    t0 = time.time()
    from jax.sharding import NamedSharding, PartitionSpec as P
    bsh = NamedSharding(smesh, P("shards"))
    ash = jax.tree.map(lambda _: NamedSharding(smesh, P("shards")), arrays)
    lowered = jax.jit(fn, in_shardings=(bsh, ash)).lower(b, arrays)
    compiled = lowered.compile()
    t1 = time.time()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    colls = summarize_collectives(hlo)
    terms = roofline_terms(cost, hlo, n_dev, HW_V5E)
    name = {"cg": f"cg-{problem}", "pcg": f"pcg-{problem}"}.get(
        method, f"plcg-{problem}-l{l}" + (f"-u{unroll}" if unroll > 1 else ""))
    rec = {
        "arch": name, "shape": f"n={op.n}",
        "mesh": str(n_dev), "chips": n_dev,
        "compile_s": round(t1 - t0, 1),
        "flops": terms.flops, "hbm_bytes": terms.hbm_bytes,
        "coll_bytes": terms.coll_bytes, "coll_per_kind": colls.per_kind,
        "t_compute": terms.t_compute, "t_memory": terms.t_memory,
        "t_collective": terms.t_collective, "dominant": terms.dominant,
    }
    if verbose:
        print(f"[{name} × {n_dev} shards] compile "
              f"{rec['compile_s']}s | {terms.row()}")
        print("  collectives:\n" + str(colls))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cg", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kv-seq-shard", action="store_true")
    ap.add_argument("--pure-dp", action="store_true",
                    help="replicate weights; batch over every mesh axis")
    ap.add_argument("--split-kv", action="store_true",
                    help="explicit split-KV decode merge (manual shard_map)")
    ap.add_argument("--pipeline-l", type=int, default=0,
                    help="train cells: delayed-gradient ring depth l")
    ap.add_argument("--decode-bf16", action="store_true",
                    help="decode: bf16 operands + f32 accumulation")
    ap.add_argument("--moe-constrain", action="store_true",
                    help="MoE: explicit EP sharding constraints")
    ap.add_argument("--moe-tp", action="store_true",
                    help="MoE: TP inside experts instead of EP")
    ap.add_argument("--roofline", action="store_true",
                    help="reduced-depth unrolled compiles + extrapolation")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multipod)]

    records, failures = [], []
    for mesh in meshes:
        if args.cg:
            # The dry-run matrix sticks to the stencil ice-sheet variant:
            # the unstructured `icesheet3d` partitions 500k FEM nodes
            # (setup-time RCM) — meaningful for a real launch, noise for
            # a compile-only sweep.
            for prob in ("laplace2d", "icesheet3d-stencil"):
                records.append(run_cg_cell(mesh, prob, method="cg"))
                records.append(run_cg_cell(mesh, prob, method="pcg"))
                for l in (1, 2, 3):
                    records.append(run_cg_cell(mesh, prob, l))
                records.append(run_cg_cell(mesh, prob, l=2, unroll=3))
            continue
        cells = all_cells() if args.all else [(args.arch, args.shape)]
        runner = run_cell_roofline if args.roofline else run_cell
        for arch, shape_name in cells:
            try:
                kw = dict(kv_seq_shard=args.kv_seq_shard,
                          pure_dp=args.pure_dp)
                if runner is run_cell:
                    kw["pipeline_l"] = args.pipeline_l
                kw["split_kv"] = args.split_kv
                kw["decode_bf16"] = args.decode_bf16
                if runner is run_cell_roofline:
                    kw["moe_constrain"] = args.moe_constrain
                from repro.launch import sharding as shd_mod
                shd_mod.MOE_TP = args.moe_tp
                records.append(runner(arch, shape_name, mesh, **kw))
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape_name,
                                 "x".join(map(str, mesh.devices.shape)),
                                 repr(e)[:200]))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1, default=float)
        print(f"wrote {len(records)} records -> {args.out}")
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nDRY-RUN OK: {len(records)} cells compiled")


if __name__ == "__main__":
    main()
