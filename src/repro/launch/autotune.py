"""Pipeline-depth autotuner: pick (l, unroll) per problem size and mesh
shape (DESIGN.md §6).

The paper leaves the pipeline length as "a parameter that can be chosen
depending on the problem and hardware setup"; Cornelis et al.
(arXiv:1801.04728) and Cools & Vanroose (arXiv:1706.05988) show the
choice interacts with stability, so depth must be a *measured* quantity,
not a guess.  Two signal sources, combined:

* **model** — the event-driven schedule simulator
  (``benchmarks.schedule_sim``) driven by the analytic kernel times
  (``benchmarks.timing_model``) for the target hardware profile.  On XLA
  the while-loop body serializes collectives unless the iteration window
  is unrolled, so a chain can only stay in flight across
  ``min(l, unroll-1)`` iterations — the model is evaluated at that
  *effective* depth (DESIGN.md §2).
* **measured** — optional wall-clock per iteration of the real solver on
  a real backend (``measured_runner``), which captures whatever the model
  misses (compilation choices, fusion, cache effects).

Usage (model only)::

    from repro.launch.autotune import autotune_depth
    from benchmarks.timing_model import CORI
    res = autotune_depth(n=8_000_000, p=512 * 16, hw=CORI)
    print(res.table());  res.best.l, res.best.unroll

Usage (model + measurement through a reduction backend)::

    from repro.parallel import get_backend
    from repro.launch.autotune import autotune_depth, measured_runner
    be = get_backend("shard_map", n_shards=8)
    measure = measured_runner(be, op, b, sigmas_for=lambda l:
                              shifts_for_operator(op, l))
    res = autotune_depth(n=op.n, p=8, hw=V5E, measure=measure)
"""

from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Callable

# benchmarks/ sits next to src/ in the source checkout and is NOT part of
# the installed package; resolve it when present, and degrade to a clear
# error at *use* time otherwise (the measured path and the backends keep
# working without it — only the analytic model needs benchmarks/).
_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
if os.path.isdir(os.path.join(_ROOT, "benchmarks")) and _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

try:
    from benchmarks.schedule_sim import iteration_time, reduction_samples
    from benchmarks.timing_model import (CORI, HWProfile, ring_hop_time,
                                         stencil_kernel_times, tree_depth)
    _BENCH_IMPORT_ERROR = None
except ImportError as _e:               # pragma: no cover - installed tree
    iteration_time = stencil_kernel_times = ring_hop_time = None
    reduction_samples = tree_depth = None
    CORI, HWProfile = None, object
    _BENCH_IMPORT_ERROR = _e


def _require_timing_model():
    if _BENCH_IMPORT_ERROR is not None:
        raise ImportError(
            "the autotuner's analytic model needs the benchmarks/ package, "
            "which ships with the source checkout (run from the repo root) "
            f"— original error: {_BENCH_IMPORT_ERROR}"
        )


def reduction_payload_bytes(method: str, l: int, s: int = 1,
                            dsize: int = 8) -> int:
    """Bytes carried by ONE global reduction of the given method.

    Classic CG reduces a single scalar per reduction phase, Ghysels p-CG
    a fused {gamma, delta} pair, p(l)-CG the fused 2l+1-entry dot block;
    batching s right-hand sides multiplies every payload by s — the
    (2l+1, s) slab matrix of DESIGN.md §11.  This is the term the cost
    model was missing: with the default 64-byte payload the model was
    latency-only and the autotuned depth could not react to batch width.
    """
    entries = {"cg": 1, "pcg": 2}.get(method, 2 * l + 1)
    return entries * max(s, 1) * dsize


def operator_neighbor_bytes(op, n_shards: int, dsize: int = 8) -> int:
    """Per-iteration point-to-point halo traffic of one shard.

    Structured stencils ship one boundary plane per direction; an
    unstructured :class:`~repro.linalg.sparse.SparseOp` ships its
    partition plan's precomputed send/recv sets
    (``PartitionPlan.neighbor_bytes``, DESIGN.md §12).  This is the
    ``neighbor_bytes`` input of :func:`model_iteration_time` /
    :func:`autotune_depth` — the cost-model term that makes the tuned
    depth react to how gather-heavy the operator's halo actually is.
    """
    from repro.linalg.operators import (DiagonalOp, Stencil2D5, Stencil3D7,
                                        Stencil3D27)
    from repro.linalg.partition import plan_for
    from repro.linalg.sparse import SparseOp

    if isinstance(op, SparseOp):
        return plan_for(op, n_shards).neighbor_bytes(dsize)
    if isinstance(op, DiagonalOp):
        return 0
    if isinstance(op, Stencil2D5):
        return 2 * op.ny * dsize
    if isinstance(op, (Stencil3D7, Stencil3D27)):
        return 2 * op.ny * op.nz * dsize
    n_loc = op.n / max(n_shards, 1)
    return int(2 * n_loc ** (2 / 3)) * dsize    # generic surface/volume


def measured_iteration_bytes(op, l: int, prec=None, sigmas=None,
                             fused: bool = False, dtype=None) -> float:
    """XLA ``cost_analysis`` 'bytes accessed' of ONE compiled p(l)-CG
    iteration (late phase, local substrate) — the measured input of the
    ``iteration_bytes`` cost-model term and of the fused-vs-unfused HBM
    gate (DESIGN.md §13; benchmarks/iter_bench.py).

    Off-TPU caveat, stated where it matters: the fused path's Pallas
    superkernel runs in interpret mode here, whose lowering re-
    materializes kernel-interior temporaries — XLA then reports
    essentially the unfused traffic for it.  The TPU accounting of the
    compiled kernel (an opaque custom call: operands + results once) is
    :func:`fused_iteration_bytes`; use THIS function for the unfused
    side and that one for the fused side when modeling the TPU target.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import pipelined_cg
    from repro.core.types import SolverOps

    dtype = jnp.zeros(()).dtype if dtype is None else dtype
    ops = SolverOps.local(op, prec)
    b = jnp.zeros((op.n,), dtype)
    prog = pipelined_cg.build(ops, b, l, sigmas=sigmas,
                              fused_iteration=fused)
    st0 = jax.eval_shape(prog.init, b)
    compiled = jax.jit(
        lambda st: prog.iteration(st, static_phase="late")
    ).lower(st0).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    return float(ca["bytes accessed"])


def fused_iteration_bytes(n: int, l: int, dsize: int = 8,
                          extra_bytes: int = 0) -> int:
    """Modeled HBM bytes of one FUSED p(l)-CG iteration on the TPU
    target: the superkernel is an opaque custom call to XLA's cost
    analysis — operand bytes + result bytes, i.e. the (NV, N) slab once
    in / once out (aliased), the resident SPMV operand, and the O(l)
    scalar bundles (``kernels.fused_iter.custom_call_hbm_bytes``).
    ``extra_bytes`` adds operator-side operands (ELL cols/vals, halo
    slabs)."""
    from repro.kernels.fused_iter import SlabLayout, custom_call_hbm_bytes

    layout = SlabLayout(l=l, RB=max(l + 1, 3))
    return custom_call_hbm_bytes(layout, n, dsize=dsize,
                                 extra_bytes=extra_bytes)


def staged_reduction_terms(hw: HWProfile, p: int, l: int, stages: int,
                           payload: int) -> dict:
    """Per-iteration cost pieces of the staged ring ladder
    (``repro.parallel.reduction``, DESIGN.md §14).

    The ladder's P-1 allgather hops split into ``stages`` advance steps;
    the solver runs one step per in-flight handle per iteration, so a
    handle consumed at pipeline age l has run min(stages, l-1) steps —
    the rest execute back-to-back at the wait.  The model replaces the
    monolithic term's ``alpha * tree_depth`` with the per-hop ladder
    schedule (``stages * alpha_hop``-shaped, per the hop grouping):

      * ``t_hop``            — one point-to-point hop: ``alpha_hop`` +
                               payload wire time (``ring_hop_time``).
      * ``t_advance_burst``  — the serialized hop chain ONE advance step
                               adds inside the iteration body
                               (ceil((P-1)/stages) hops).  Steps of
                               DIFFERENT in-flight handles are
                               independent chains (separate gather
                               buffers) and overlap each other, so this
                               burst — not the sum over live handles —
                               is the ladder's per-iteration critical
                               path: more stages → smaller burst, i.e.
                               cheaper per-iteration ladder wait, the
                               knob's first arm.
      * ``t_wait_stall``     — the exposed stall at the consumption
                               point: max(0, stages-(l-1)) remaining
                               steps; zero once the pipeline is deep
                               enough to advance every step (l-1 >=
                               stages), the knob's second arm.
      * ``fill_iters``       — iterations from issue until the ladder
                               can have completed (the pipeline-fill
                               cost a restart/replacement pays): more
                               stages → longer fill.

    The (l, stages) tension these terms encode is what
    :func:`autotune_depth` co-selects over (tests/test_costs.py).
    """
    _require_timing_model()
    n_hops = max(p - 1, 0)
    stages = max(1, min(stages, max(n_hops, 1)))
    t_hop = ring_hop_time(hw, payload)
    group_hops = -(-n_hops // stages) if n_hops else 0     # ceil division
    advance_steps = min(stages, max(l - 1, 0))
    wait_steps = stages - advance_steps
    return {
        "t_hop": t_hop,
        "n_hops": n_hops,
        "group_hops": group_hops,
        "advance_steps": advance_steps,
        "t_advance_burst": group_hops * t_hop,
        "t_advance_total": advance_steps * group_hops * t_hop,
        "t_wait_stall": wait_steps * group_hops * t_hop,
        "fill_iters": stages + 1,
    }


def recalibrate_profile(
    hw: HWProfile,
    iter_payload: dict | None = None,
    spmv_payload: dict | None = None,
    reduce_payload: dict | None = None,
) -> HWProfile:
    """Replace an :class:`HWProfile`'s stream/latency terms with numbers
    MEASURED by the compiled bench lane (DESIGN.md §17): the payloads are
    the parsed ``BENCH_iter_compiled.json`` / ``BENCH_spmv_compiled.json``
    / ``BENCH_reduce_compiled.json`` emitted by
    ``benchmarks.* --kernel-mode compiled`` on a real accelerator.

    * ``iter_payload``  → ``mem_bw``: the fused superkernel's one-pass
      HBM bytes over its compiled wall clock — the achieved (not
      datasheet) stream rate the body model divides by.
    * ``spmv_payload``  → ``flop_rate``: 2*nnz FLOPs over the compiled
      ELL kernel's wall clock (the gather-bound achieved rate).
    * ``reduce_payload`` → ``alpha_hop`` / ``alpha``: the measured
      single-hop ppermute and monolithic psum wall clocks, with the
      payload wire term backed out so ``ring_hop_time`` /
      ``alpha * tree_depth`` reproduce the measurements.

    kernel-mode honesty is ENFORCED, not assumed: a payload whose
    ``skipped`` flag is set (the compiled lane's machine-readable refusal
    on CPU-only containers, ``benchmarks.lane``) or whose ``kernel_mode``
    is not ``"compiled"`` raises — interpreter wall clocks must never
    recalibrate an accelerator profile.  Fields without a payload keep
    the profile's analytic values; the returned profile is renamed
    ``<name>+measured`` so downstream tables show which numbers are live.
    """
    _require_timing_model()

    def usable(payload, name):
        if payload is None:
            return None
        if payload.get("skipped"):
            raise ValueError(
                f"{name} payload is a skip marker, not measurements "
                f"({payload.get('reason', 'no reason recorded')}) — "
                "recalibration needs the compiled lane's numbers")
        if payload.get("kernel_mode") != "compiled":
            raise ValueError(
                f"{name} payload has kernel_mode="
                f"{payload.get('kernel_mode')!r}: interpret-lane wall "
                "clocks time the Pallas interpreter / simulated mesh, "
                "not the hardware — run --kernel-mode compiled on an "
                "accelerator")
        return payload

    updates: dict = {}
    it = usable(iter_payload, "iter_bench")
    if it is not None:
        if not it.get("fused_wall_time_comparable"):
            raise ValueError(
                "iter_bench payload carries no comparable fused wall "
                "clock (fused_wall_time_comparable is false)")
        updates["mem_bw"] = (it["fused_bytes_per_iter"]
                             / it["fused_time_per_iter_s"])
    sp = usable(spmv_payload, "spmv_bench")
    if sp is not None:
        updates["flop_rate"] = (2.0 * sp["problem"]["nnz"]
                                / sp["kernel_spmv_s"])
    rd = usable(reduce_payload, "reduce_bench")
    if rd is not None:
        payload_bytes = rd.get("staged_hop_payload_bytes_fp64", 0)
        wire = payload_bytes / hw.link_bw
        updates["alpha_hop"] = max(
            rd["measured_hop_time_s"] - wire, 1e-9)
        depth = tree_depth(hw, rd.get("mesh_devices", 2))
        updates["alpha"] = max(
            (rd["measured_allreduce_time_s"] - wire) / max(depth, 1),
            1e-9)
    if not updates:
        return hw
    return dataclasses.replace(hw, name=f"{hw.name}+measured", **updates)


def governed_overhead(l: int, stages: int | None = None,
                      replace_period: int = 256) -> float:
    """Multiplicative per-iteration factor a GOVERNED solve pays at
    depth l (DESIGN.md §18).

    The stability governor (``repro.stability``) periodically replaces
    the recursive residual; each replacement re-enters the pipeline fill
    — ``l + 1`` iterations produce no solution update (plus the staged
    ladder's own fill, ``stages + 1``, when the reduction is staged).
    The attainable-accuracy analysis (arXiv:1804.02962) says the
    true-vs-recursive gap grows with the recurrence depth, so the
    replacement period SHRINKS with l — modeled first-order as
    ``replace_period / l`` (``replace_period`` is the calibration point:
    the l=1 period, either the default or measured from a governed
    solve's telemetry ``replacements / iters``).  The overhead factor

        1 + refill_iters / period(l)

    is what tilts the autotuner's (l, stages) co-selection when the
    governor is armed: deep pipelines stop being free once every
    replacement pays their refill — the stability/latency trade the
    paper flags, now priced into the sweep (tests/test_costs.py).
    """
    refill = (l + 1) + (stages + 1 if stages else 0)
    period = max(replace_period / max(l, 1), 1.0)
    return 1.0 + refill / period


def xla_effective_depth(l: int, unroll: int) -> int:
    """Reductions a while-loop body can keep in flight under XLA.

    The body is one computation: a collective issued inside it must
    complete before the backward edge, so chains only stagger across the
    ``unroll``-iteration window — depth saturates at ``unroll - 1``
    (verified by the overlap tracer, DESIGN.md §6).
    """
    return max(min(l, unroll - 1), 0)


@dataclasses.dataclass(frozen=True)
class Candidate:
    method: str
    l: int
    unroll: int
    model_s: float                 # modeled seconds / iteration
    measured_s: float | None = None  # wall-clock seconds / iteration
    # Reduction wiring of this candidate (DESIGN.md §14): "monolithic"
    # all-reduce, or "staged" ring ladder with this many advance stages.
    reduction: str = "monolithic"
    stages: int | None = None

    @property
    def score(self) -> float:
        return self.model_s if self.measured_s is None else self.measured_s


@dataclasses.dataclass
class AutotuneResult:
    best: Candidate
    candidates: list[Candidate]
    n: int
    p: int
    hw_name: str

    def table(self) -> str:
        hdr = (f"autotune: n={self.n:,} unknowns, p={self.p} workers, "
               f"{self.hw_name}")
        rows = [hdr, f"{'method':>10s} {'l':>3s} {'unroll':>6s} "
                     f"{'red':>6s} {'stg':>3s} "
                     f"{'model/us':>9s} {'meas/us':>9s}"]
        for c in sorted(self.candidates, key=lambda c: c.score):
            meas = f"{c.measured_s * 1e6:9.1f}" if c.measured_s is not None \
                else f"{'-':>9s}"
            star = " *" if c == self.best else ""
            red = "staged" if c.reduction == "staged" else "mono"
            stg = f"{c.stages:3d}" if c.stages is not None else "  -"
            rows.append(f"{c.method:>10s} {c.l:>3d} {c.unroll:>6d} "
                        f"{red:>6s} {stg} "
                        f"{c.model_s * 1e6:9.1f} {meas}{star}")
        return "\n".join(rows)


def model_iteration_time(
    hw: HWProfile,
    n: int,
    p: int,
    method: str,
    l: int = 0,
    unroll: int = 1,
    stencil_pts: int = 5,
    jitter: float = 0.15,
    prec_factor: float = 1.0,
    s: int = 1,
    dsize: int = 8,
    neighbor_bytes: int | None = None,
    iteration_bytes: float | None = None,
    reduction: str = "monolithic",
    stages: int | None = None,
) -> float:
    """Modeled seconds per SLAB iteration at the XLA-effective depth.

    ``reduction="staged"`` (p(l)-CG only) replaces the monolithic glred
    term — ``alpha * tree_depth + payload/link_bw``, hidden across the
    XLA-effective window — with the hop-per-iteration ring ladder of
    DESIGN.md §14 (:func:`staged_reduction_terms`): the body runs its
    advance steps' hop bursts (overlapping local work) and the
    consumption point pays the stall of whatever ``stages`` exceed the
    structural window l-1.  The staged path needs no ``unroll`` credit:
    its overlap is dataflow-forced by the solver's advance schedule, not
    recovered by the scheduler, which is exactly the point.

    ``iteration_bytes`` (p(l)-CG only) recalibrates the model's local
    HBM-stream budget against a MEASURED per-worker bytes/iteration —
    XLA ``cost_analysis`` of the compiled iteration
    (:func:`measured_iteration_bytes`) or the fused superkernel's
    custom-call accounting (:func:`fused_iteration_bytes`), DESIGN.md
    §13.  The analytic stream terms (SPMV stream + 2l+3 AXPY passes) are
    scaled so their total equals ``iteration_bytes / mem_bw``; the
    halo/latency parts of the SPMV and the reduction term stay analytic
    — measured traffic changes how fast the body runs, not the overlap
    structure.

    ``s`` is the multi-RHS slab width (DESIGN.md §11); both sides of the
    overlap balance scale with it, consistently: the local work (SPMV /
    AXPY streams) is s columns per iteration, and the single reduction
    carries the (2l+1)*s*dsize payload (``reduction_payload_bytes``).
    The per-reduction LATENCY (alpha * tree depth) does not scale — that
    is the amortization: per-column time t(s)/s falls toward the
    bandwidth floor ``local + payload_1/link_bw`` as s grows, and the
    latency-hiding value of depth l shrinks with it (wide slabs want
    shallower pipelines; narrow ones deeper).  s=1 recovers the
    single-RHS model exactly.

    ``neighbor_bytes`` is the per-iteration point-to-point halo traffic
    of one shard (``operator_neighbor_bytes``; DESIGN.md §12).  It rides
    the SPMV term — neighbour exchange serializes with the local stencil
    /gather work, NOT with the hidden global reduction, so heavy halos
    raise the iteration floor for every depth while leaving the
    latency-hiding argument intact (the paper's Iallreduce/halo
    staggering).  None keeps the structured surface-area default.
    """
    _require_timing_model()
    halo_elems = None if neighbor_bytes is None \
        else max(neighbor_bytes // (2 * dsize), 0)
    k = stencil_kernel_times(
        hw, n, p, stencil_pts=stencil_pts, prec_factor=prec_factor,
        halo_elems=halo_elems,
        glred_payload=reduction_payload_bytes(method, l, s, dsize))
    if iteration_bytes is not None and method == "plcg":
        # Calibrate the stream budget: scale SPMV-stream + AXPY passes so
        # their modeled total matches the measured bytes/iteration.
        model_stream = k["spmv_stream"] + (2 * l + 3) * k["axpy1"]
        scale = (iteration_bytes / hw.mem_bw) / max(model_stream, 1e-30)
        k = {**k,
             "axpy1": k["axpy1"] * scale,
             "spmv": k["spmv_comm"] + k["spmv_stream"] * scale,
             "spmv_stream": k["spmv_stream"] * scale}
    if s > 1:
        # Slab-consistent local terms: s columns stream per iteration
        # (the halo/latency parts of the SPMV amortize like the glred
        # alpha does, but modeling them per-column errs conservative).
        k = {**k, "spmv": k["spmv"] * s, "axpy1": k["axpy1"] * s}
    if method != "plcg":
        return iteration_time(method, 0, k, jitter=jitter)
    if reduction == "staged":
        st = staged_reduction_terms(
            hw, p, l, stages if stages is not None else max(l - 1, 1),
            payload=reduction_payload_bytes(method, l, s, dsize))
        body = k["spmv"] + (2 * l + 3) * k["axpy1"]
        # One advance step's hop burst rides the body (concurrent across
        # the distinct in-flight handles, hidden under local work until
        # it outgrows it); the wait stall is exposed by construction.
        if jitter <= 0:
            return max(body, st["t_advance_burst"]) + st["t_wait_stall"]
        # Same mean-preserving log-normal noise the monolithic event sim
        # applies to its reductions (schedule_sim.reduction_samples) —
        # staged candidates must not win ties merely by being scored
        # noise-free; the max() against the body amplifies burst noise
        # exactly as the event sim's MPI_Wait does.
        import numpy as _np
        rng = _np.random.default_rng(0)
        bursts = reduction_samples(200, st["t_advance_burst"], jitter, rng)
        stalls = reduction_samples(200, st["t_wait_stall"], jitter, rng)
        return float(_np.mean(_np.maximum(body, bursts) + stalls))
    l_eff = xla_effective_depth(l, unroll)
    if l_eff == 0:
        # No in-flight window: the reduction serializes with the body —
        # SPMV + (2l+2+1) AXPY passes + blocking glred.
        return k["spmv"] + (2 * l + 3) * k["axpy1"] + k["glred"]
    # Overlap at the XLA-effective depth, but the body still pays the
    # full algorithmic-depth AXPY tail (2l+3 passes).
    return iteration_time("plcg", l_eff, k, jitter=jitter, body_l=l)


def autotune_depth(
    n: int,
    p: int,
    hw: HWProfile | None = None,
    ls: tuple[int, ...] = (1, 2, 3, 5),
    unrolls: tuple[int, ...] | None = None,
    stencil_pts: int = 5,
    jitter: float = 0.15,
    prec_factor: float = 1.0,
    include_baselines: bool = True,
    measure: Callable[[str, int, int], float] | None = None,
    s: int = 1,
    neighbor_bytes: int | None = None,
    iteration_bytes: Callable[[int], float] | float | None = None,
    reduction: str = "monolithic",
    stages_grid: tuple[int, ...] | None = None,
    governed: bool = False,
    replace_period: int = 256,
) -> AutotuneResult:
    """Sweep (l, unroll) — and, with ``reduction="staged"`` or
    ``"both"``, the ladder stage count — and pick the fastest candidate.

    Staged candidates (DESIGN.md §14) sweep ``stages_grid`` (default:
    {1, 2, l-1, l} clipped to the ladder's p-1 hops) at every depth l,
    scoring with the per-hop latency model
    (:func:`staged_reduction_terms`): the co-selection captures the
    (l, stages) tension — more stages shrink the per-iteration hop burst
    but stall at the wait once stages exceed l-1, so deeper pipelines
    EARN finer ladders.  Staged candidates are model-ranked only
    (``measure`` covers the monolithic solver path).

    ``measure(method, l, unroll) -> seconds/iter`` (see
    :func:`measured_runner`) overrides the model for ranking wherever it
    is provided; candidates are ranked by measured time when available,
    modeled time otherwise.  ``hw`` defaults to the Cori-like
    reproduction profile.  ``s`` is the serving slab width — it scales
    both the reduction payload and the per-iteration local work
    (``model_iteration_time``), so the autotuned depth stays correct when
    the batcher widens the dot block: wide slabs amortize the reduction
    latency and favor shallower pipelines (DESIGN.md §11).
    ``neighbor_bytes`` (``operator_neighbor_bytes``) injects the
    partition plan's measured halo traffic for unstructured operators
    (DESIGN.md §12).  ``iteration_bytes`` calibrates the p(l)-CG local
    stream budget against measured per-worker HBM traffic — a float, or
    a callable ``l -> bytes`` since the slab (and hence the traffic)
    grows with depth (:func:`measured_iteration_bytes` /
    :func:`fused_iteration_bytes`, DESIGN.md §13).

    ``governed=True`` scores p(l)-CG candidates for a solve with the
    stability governor armed (DESIGN.md §18): the modeled time is
    multiplied by :func:`governed_overhead` — the refill cost of the
    depth-dependent replacement period (calibrate ``replace_period``
    from a governed run's telemetry).  Deep-l candidates lose their
    free lunch, and staged candidates additionally pay their ladder
    fill per replacement, so the co-selection shifts toward shallower
    (l, stages) exactly when robustness is being bought.
    """
    _require_timing_model()
    if reduction not in ("monolithic", "staged", "both"):
        raise ValueError(f"unknown reduction sweep {reduction!r}")
    if hw is None:
        hw = CORI
    cands: list[Candidate] = []

    def add(method, l, unroll, red="monolithic", stages=None):
        ib = None
        if method == "plcg" and iteration_bytes is not None:
            ib = iteration_bytes(l) if callable(iteration_bytes) \
                else iteration_bytes
        mdl = model_iteration_time(hw, n, p, method, l, unroll,
                                   stencil_pts=stencil_pts, jitter=jitter,
                                   prec_factor=prec_factor, s=s,
                                   neighbor_bytes=neighbor_bytes,
                                   iteration_bytes=ib,
                                   reduction=red, stages=stages)
        if governed and method == "plcg":
            mdl *= governed_overhead(
                l, stages if red == "staged" else None, replace_period)
        meas = measure(method, l, unroll) \
            if measure is not None and red == "monolithic" else None
        cands.append(Candidate(method, l, unroll, mdl, meas,
                               reduction=red, stages=stages))

    if include_baselines:
        add("cg", 0, 1)
        add("pcg", 0, 1)
    for l in ls:
        if reduction in ("monolithic", "both"):
            for u in (unrolls if unrolls is not None else (1, l + 1)):
                add("plcg", l, u)
        if reduction in ("staged", "both"):
            grid = stages_grid if stages_grid is not None \
                else tuple(sorted({1, 2, max(l - 1, 1), l}))
            for st in grid:
                add("plcg", l, l + 1, red="staged",
                    stages=max(1, min(st, max(p - 1, 1))))

    best = min(cands, key=lambda c: c.score)
    return AutotuneResult(best=best, candidates=cands, n=n, p=p,
                          hw_name=hw.name)


def measured_runner(
    backend,
    op,
    b,
    sigmas_for: Callable[[int], object] | None = None,
    prec=None,
    iters: tuple[int, int] = (20, 60),
    repeats: int = 3,
) -> Callable[[str, int, int], float]:
    """Wall-clock seconds/iteration of the real solver on ``backend``.

    Each configuration is compiled ONCE (``backend.make_solver`` returns
    a callable with a persistent jit cache); timing then covers pure
    re-execution.  The solver runs at two fixed iteration budgets (tol=0
    disables early exit) and the difference removes the constant
    init/launch overhead; the minimum over ``repeats`` suppresses noise.
    Intended for small calibration problems — the autotuner extrapolates
    shape via the analytic model, not by timing the production size.
    """
    import jax

    lo, hi = iters
    assert hi > lo

    def time_solve(method, l, unroll, maxit) -> float:
        kw = dict(tol=0.0, maxit=maxit)
        if method == "plcg":
            kw.update(l=l, unroll=unroll)
            if sigmas_for is not None:
                kw.update(sigmas=sigmas_for(l))
        solver = backend.make_solver(op, method, prec, **kw)
        jax.block_until_ready(solver(b).x)          # compile + warmup
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(solver(b).x)
            best = min(best, time.perf_counter() - t0)
        return best

    def measure(method: str, l: int, unroll: int) -> float:
        t_lo = time_solve(method, l, unroll, lo)
        t_hi = time_solve(method, l, unroll, hi)
        if t_hi <= t_lo:
            # Noise swallowed the budget difference; a 0.0 score would
            # win the ranking outright.  Fall back to the per-iteration
            # upper bound of the larger run (includes launch overhead —
            # pessimistic, never a free win).
            return t_hi / hi
        return (t_hi - t_lo) / (hi - lo)

    return measure
