"""Deep pipelined Conjugate Gradients — p(l)-CG (Alg. 1 of the paper).

Faithful JAX implementation with production storage: ALL vector state —
the l+1 auxiliary bases Z^(0..l) in ring buffers (window max(l+1,3) per
basis), the 3-deep u ring, the search direction p and the iterate x —
lives in ONE contiguous structure-of-arrays slab ``S`` of shape (NV, N)
(:class:`repro.kernels.fused_iter.SlabLayout`), total vector storage O(l)
irrespective of iteration count (cf. the paper's 4l+1-vector budget,
Table 1).  One array with one trailing N axis is what the fused-iteration
superkernel tiles (DESIGN.md §13), what ``donate_argnums`` aliases across
slab-program chunks, and what the G matrix / Hessenberg windows ride
alongside as O(l^2) scalars.

The communication structure per iteration i is exactly the paper's:

  * ONE SPMV (+ preconditioner)                                (K1)
  * ONE fused dot-product block of 2l+1 entries — the single
    MPI_Iallreduce of G(i-2l+1:i+1, i+1)                       (K5)
  * its result is FIRST READ at iteration i+l (lines 8-10)     (MPI_Wait)

The reduction is issued through the backend handle API
(``ops.start``, DESIGN.md §3) and its raw 2l+1-entry payload parked in an
explicit in-flight ring ``D`` of depth l — the JAX analogue of the paper's
l outstanding ``MPI_Request`` objects.  Only at iteration i+l is the slot
consumed (``ops.wait``) and scattered into the G window, so the reduction
initiated at iteration i has l iterations of SPMVs, AXPYs and l-1 other
in-flight reductions between initiation and first use.  On TPU the overlap
is realized by XLA's latency-hiding scheduler when the iteration window is
unrolled (``unroll`` parameter; see DESIGN.md §2) — the lowered HLO then
carries l independent all-reduce chains in flight, the staggering of
Fig. 4 (bottom), which ``repro.utils.trace`` measures (DESIGN.md §6).

Each iteration is split into a *scalar phase* (MPI_Wait arrival scatter
into G, the K2 column correction and K3 Hessenberg column — O(l^2)
scalars) and a *vector phase* (K1 SPMV + preconditioner, pipeline-fill
copies, K4 recurrence AXPYs, the K5 dot block and the K6 x/p updates).
The vector phase has two interchangeable implementations sharing one
index/coefficient calling convention:

  * **unfused** (default) — ``repro.kernels.ref.fused_iter_unfused``:
    one jnp op per pass, dots via ``ops.start`` (the reference path);
  * **fused** (``fused_iteration=True``) — the Pallas superkernel
    (``repro.kernels.fused_iter``): slab read once / written once per
    row tile, dot partials accumulated in VMEM, the single global
    reduction issued on the partials via ``ops.start_partials``.  Both
    paths evaluate identical expressions on identical operands, so
    stencil-operator residual histories agree BITWISE
    (tests/test_fused_iter.py; DESIGN.md §13).

Breakdown handling: square-root breakdown (line 10/11) triggers an explicit
restart from the current iterate (§2.2), implemented as a state re-init
inside the while-loop.  Convergence uses the recursive residual M-norm
|zeta_{i-l}| relative to the *original* residual norm.

Residual replacement (``replace_every > 0``): long pipelines let the
recursive residual drift from the true residual (rounding-error
propagation, Cools/Cornelis/Vanroose arXiv:1902.03100), capping attainable
accuracy.  Every ``replace_every`` solution updates the solver forces a
cycle re-init from the current iterate — ``init_cycle`` recomputes the
TRUE residual b - A x and restarts the basis from it, the p(l)-CG
equivalent of the paper's periodic true-residual recompute (the
counterpart for Ghysels p-CG replaces the recurred vectors in place; see
``ghysels_pcg``).  Each replacement costs the l+1-iteration pipeline
refill, so choose ``replace_every >> l``; replacement restarts share the
breakdown-restart budget ``max_restarts``
(tests/test_residual_replacement.py).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import GLRED_WAIT_TAG, SolveResult, SolverOps, dot1
from repro.kernels.fused_iter import (SlabLayout, idx_layout, scal_layout,
                                      tel_layout)
from repro.kernels.ref import fused_iter_unfused
from repro.stability import model as gov_model


class _Cycle(NamedTuple):
    """Per-restart-cycle state (re-initialized on breakdown)."""

    S: jax.Array        # (NV, N) vector slab: ZK rings | U ring | p | x
    G: jax.Array        # (W, W) sliding window of the basis-transform matrix
    D: jax.Array        # (l, *handle) in-flight dot blocks (reduction
                        # handles: the raw (2l+1,) payload on monolithic
                        # substrates, a (P, 2l+1) wire-dtype gather buffer
                        # on staged ones — ops.handle_zeros decides)
    gam: jax.Array      # (W,) gamma ring  (Hessenberg diagonal)
    dlt: jax.Array      # (W,) delta ring  (Hessenberg off-diagonal)
    eta_prev: jax.Array # scalar eta_{i-l-1}
    zet_prev: jax.Array # scalar zeta_{i-l-1}
    i: jax.Array        # cycle-local iteration counter
    norm0_cycle: jax.Array


class _State(NamedTuple):
    cyc: _Cycle
    tot: jax.Array        # global iteration counter (monotone — termination)
    upd: jax.Array        # number of solution updates (CG-comparable iters)
    restarts: jax.Array
    converged: jax.Array
    breakdown: jax.Array
    hist: jax.Array
    norm0: jax.Array      # original residual M-norm (stopping reference)
    since_rr: jax.Array   # solution updates since the last (re)start —
                          # drives periodic residual replacement
    tel: jax.Array        # (telemetry_cap, K) on-device telemetry ring
                          # (row layout: kernels.fused_iter.tel_layout;
                          # (0, K) when uninstrumented — writes are
                          # statically skipped, DESIGN.md §16)
    gov: jax.Array        # (gov_model.N_SLOTS,) stability-governor state
                          # (repro.stability.model; zeros and statically
                          # untouched when ungoverned, DESIGN.md §18)


class PlcgProgram(NamedTuple):
    """The p(l)-CG iteration decomposed for external drivers.

    ``solve`` runs ``body`` under ``lax.while_loop``; the overlap tracer
    (``repro.utils.trace``) instead unrolls ``iteration`` into a flat
    window so the staggered reduction chains are visible in one HLO
    schedule (DESIGN.md §6).

    ``step`` / ``needs_interrupt`` / ``interrupt`` decompose ``body`` for
    the batched multi-RHS drivers (DESIGN.md §11): under vmap a batched
    ``lax.cond`` executes BOTH branches, so running ``body`` per slab
    iteration would pay the restart's extra SPMV + reduction every
    iteration.  Batched drivers instead run ``step`` (the bare iteration,
    ONE reduction) until ``needs_interrupt`` (breakdown or a due residual
    replacement) stops the column, then apply ``interrupt`` (the cycle
    re-init) as a masked boundary step — same arithmetic per column as
    the sequential path, with the restart's reduction amortized to chunk
    boundaries.  ``step`` mutates only the slab's touched rows (in place
    under the fused path's ``input_output_aliases``), so the slab-program
    drivers that jit it with ``donate_argnums`` carry NO per-iteration
    state copy (tests/test_fused_iter.py::test_slab_program_donation).
    """

    init: Callable[[jax.Array], "_State"]        # x0 -> st0
    iteration: Callable[..., "_State"]           # raw iteration (no restart)
    body: Callable[["_State"], "_State"]         # breakdown-aware step
    cond: Callable[["_State"], jax.Array]
    finish: Callable[["_State"], SolveResult]
    step: Callable[["_State"], "_State"] | None = None
    needs_interrupt: Callable[["_State"], jax.Array] | None = None
    interrupt: Callable[["_State"], "_State"] | None = None


def build(
    ops: SolverOps,
    b: jax.Array,
    l: int,
    tol: float = 1e-6,
    maxit: int = 1000,
    sigmas: jax.Array | None = None,
    max_restarts: int = 10,
    replace_every: int = 0,
    fused_iteration: bool = False,
    telemetry_cap: int = 0,
    recurrence: str = "ghysels",
    governor: "gov_model.GovernorConfig | None" = None,
) -> PlcgProgram:
    """Construct the p(l)-CG iteration pieces for ``b`` (depth ``l`` static).

    ``fused_iteration=True`` routes the vector phase through the Pallas
    superkernel built by the substrate's ``ops.fused_iter_factory``
    (DESIGN.md §13); raises if the (operator, preconditioner, backend)
    combination has no fused path.

    ``telemetry_cap > 0`` appends a (cap, K) on-device telemetry ring to
    the solver state (DESIGN.md §16): each iteration stores one row of
    already-computed replicated scalars (residual norm, the arrived dot
    block, restart/replacement flags, handle age) at ring slot
    ``tot % cap`` — zero extra collectives, zero host syncs, and the
    uninstrumented arithmetic is untouched (instrumented-vs-plain residual
    histories are bitwise identical, tests/test_telemetry.py).  The ring
    is returned as ``SolveResult.telemetry``.

    ``recurrence`` selects the vector-phase basis recurrence
    (:class:`~repro.kernels.fused_iter.SlabLayout`): ``"ghysels"`` (the
    paper's formulation, the default) or ``"stable"`` (the coupled
    variant of arXiv:1902.03100, DESIGN.md §18).  Both run the identical
    one-reduction-per-iteration communication structure.

    ``governor`` (a :class:`repro.stability.model.GovernorConfig`) arms
    the stability governor (DESIGN.md §18): each late iteration updates
    a first-order attainable-accuracy gap estimate from the already
    replicated scalar phase (zero extra reductions) and, when the gap
    or a patience stall trips, schedules a residual replacement through
    the SAME interrupt machinery as breakdowns — per-column masked in
    the batched drivers.  Replacements that keep failing to improve the
    true residual flip the terminal STAGNATED flag, which stops the
    loop early (``repro.stability.governor`` turns it into pipeline
    demotion / :class:`~repro.stability.governor.StagnationError`).
    ``None`` (the default) statically skips every governor computation:
    ungoverned solves are bitwise identical to the pre-governor solver
    (tests/test_stability.py).
    """
    assert l >= 1
    assert telemetry_cap >= 0
    assert replace_every == 0 or replace_every > l, \
        "residual replacement must be rarer than the pipeline refill"
    n = b.shape[0]
    dtype = b.dtype
    sig = jnp.zeros((l,), dtype) if sigmas is None else jnp.asarray(sigmas, dtype)
    assert sig.shape == (l,)
    if recurrence not in ("ghysels", "stable"):
        raise ValueError(
            f"unknown recurrence {recurrence!r}: expected 'ghysels' "
            f"(paper Alg. 1) or 'stable' (coupled recurrence, "
            f"DESIGN.md §18)")

    RB = max(l + 1, 3)        # per-basis ring length
    W = 3 * l + 4             # G / Hessenberg window
    tot_max = maxit + (max_restarts + 1) * (l + 1)
    H = tot_max + 2

    layout = SlabLayout(l=l, RB=RB, recurrence=recurrence)
    NV = layout.nv
    IX = idx_layout(l)
    IS = scal_layout(l)
    TL = tel_layout(l)
    TK = TL["size"]

    fiter = None
    if fused_iteration:
        if ops.fused_iter_factory is None:
            raise ValueError(
                "fused_iteration=True but this SolverOps has no "
                "fused_iter_factory — unsupported operator/preconditioner "
                "for the superkernel (DESIGN.md §13)")
        fiter = ops.fused_iter_factory(layout)

    # ----------------------------------------------------------- helpers --
    def g_get(G, r, c, valid=True):
        v = G[jnp.mod(r, W), jnp.mod(c, W)]
        return jnp.where(valid, v, jnp.zeros((), dtype))

    def g_set(G, r, c, val):
        return G.at[jnp.mod(r, W), jnp.mod(c, W)].set(val)

    def ring_get(arr, idx, valid=True):  # 1-D scalar rings (gam / dlt)
        return jnp.where(valid, arr[jnp.mod(idx, W)], jnp.zeros((), dtype))

    zk_row, u_row = layout.zk_row, layout.u_row

    def tel_write(tel, tot, **cols):
        """Store one telemetry row at ring slot ``tot % cap``.

        Statically a no-op when uninstrumented (telemetry_cap == 0 is a
        Python-level check — the plain solve's HLO is unchanged).  Every
        value passed in is an already-computed replicated scalar, so the
        write is one K-wide row store: no collectives, no host syncs
        (DESIGN.md §16; invariants asserted in tests/test_telemetry.py).
        """
        if not telemetry_cap:
            return tel
        row = jnp.zeros((TK,), dtype)
        for name, val in cols.items():
            if name == "dots":
                row = row.at[TL["dots"]:TL["size"]].set(val.astype(dtype))
            else:
                row = row.at[TL[name]].set(
                    jnp.asarray(val).astype(dtype))
        return jax.lax.dynamic_update_slice(
            tel, row[None, :],
            (jnp.mod(tot, telemetry_cap), jnp.int32(0)))

    # ------------------------------------------------------------- init ---
    def _make_cycle(x, u0_raw, r0_raw, eta0) -> _Cycle:
        safe = jnp.where(eta0 == 0, jnp.ones((), dtype), eta0)
        v0 = r0_raw / safe
        S = jnp.zeros((NV, n), dtype)
        for k in range(l + 1):
            S = S.at[k * RB].set(v0)          # z_0^(k) = v_0 for all k
        S = S.at[layout.u_off].set(u0_raw / safe)
        S = S.at[layout.x_row].set(x)
        h0 = ops.handle_zeros((2 * l + 1,), dtype)
        return _Cycle(
            S=S, G=jnp.zeros((W, W), dtype).at[0, 0].set(1.0),
            D=jnp.zeros((l,) + h0.shape, h0.dtype),
            gam=jnp.zeros((W,), dtype), dlt=jnp.zeros((W,), dtype),
            eta_prev=jnp.ones((), dtype), zet_prev=jnp.zeros((), dtype),
            i=jnp.int32(0), norm0_cycle=eta0,
        )

    def init_cycle(x) -> _Cycle:
        u0_raw = b - ops.apply_a(x)
        r0_raw = ops.prec(u0_raw)
        eta0 = jnp.sqrt(jnp.abs(dot1(ops, u0_raw, r0_raw)))
        return _make_cycle(x, u0_raw, r0_raw, eta0)

    def restart_cycle(x, stagnant) -> _Cycle:
        """Cycle re-init for breakdown restarts, with a stagnation guard.

        A square-root breakdown at the FIRST late iteration (i == l,
        before any solution update) restarts into the identical cycle —
        on operator/preconditioner pairs whose preconditioned Krylov
        space is (nearly) one-dimensional (e.g. Jacobi on a diagonal
        operator: M^{-1}A = I) that loop never makes progress and burns
        the whole restart budget.  When the dying cycle produced NO
        updates (``stagnant``), fold ONE steepest-descent step into the
        re-init: x' = x + alpha z with alpha = (r, z)/(z, A z) — a
        guaranteed A-norm error reduction, and in the 1-D-Krylov case
        the exact solution, which the lucky-breakdown check then
        detects.  Everything is arranged as a SINGLE fused reduction
        (the restart's communication structure is unchanged — asserted
        on compiled HLO in tests/test_distributed.py), and a
        non-stagnant restart (alpha = 0) reproduces ``init_cycle``'s
        arithmetic bitwise: the post-step residual/eta0 recurrences
        collapse to the plain expressions when alpha == 0.
        """
        r = b - ops.apply_a(x)
        z = ops.prec(r)
        az = ops.apply_a(z)
        pz = ops.prec(az)
        # One fused reduction of the three inner products {(r,z), (az,z),
        # (az,pz)} as row-sums against ones — same payload discipline as
        # the iteration's dot block.
        dots = ops.wait(ops.start(
            jnp.stack([r * z, az * z, az * pz]),
            jnp.ones_like(z))).astype(dtype)
        a, c, e = dots[0], dots[1], dots[2]
        ok = stagnant & (c > 0) & jnp.isfinite(c)
        alpha = jnp.where(ok, a / jnp.where(c == 0, jnp.ones((), dtype), c),
                          jnp.zeros((), dtype))
        x1 = x + alpha * z
        u0_raw = r - alpha * az
        r0_raw = z - alpha * pz               # prec is linear
        # eta0^2 = (u0, r0) via the step recurrence ((r,pz) = (z,az) by
        # M^{-1}-symmetry); alpha = 0 collapses it to (r, z) exactly.
        eta0 = jnp.sqrt(jnp.abs(a - 2 * alpha * c + alpha * alpha * e))
        return _make_cycle(x1, u0_raw, r0_raw, eta0)

    # -------------------------------------------------------- iteration ---
    def iteration(st: _State, static_phase: str | None = None) -> _State:
        """One p(l)-CG iteration.

        ``static_phase`` ('early' | 'late' | None) lets flat drivers (the
        overlap tracer) bypass the ``lax.cond`` on i >= l with a
        trace-time choice, so the arrival path is inlined in the HLO
        entry computation.  ``None`` (the while-loop path) keeps the
        runtime conditional.
        """
        c = st.cyc
        i = c.i
        im = i - l                     # index of the Hessenberg column built
        ge_l = i >= l

        # ===== scalar phase: MPI_Wait arrival + K2 + K3 ===================
        # O(l^2) scalar work on the G / Hessenberg windows — no vector
        # traffic; produces the coefficients the vector phase consumes.
        def late_scal(args):
            G, gam, dlt = args
            col = i - l + 1            # G column whose dots arrived

            # ---- MPI_Wait(req(i-l)): consume the in-flight dot block -----
            # The raw 2l+1 payload initiated l iterations ago is pulled out
            # of the D ring and scattered into G column `col` only NOW —
            # the consumption point the overlap tracer keys on (GLRED_WAIT
            # scope; DESIGN.md §6).
            with jax.named_scope(GLRED_WAIT_TAG):
                # advanced=l-1: the solver ran one ladder step per
                # iteration on this handle (ages 1..l-1, below); a staged
                # substrate finishes any remaining steps here, monolithic
                # ones ignore the count (DESIGN.md §14).
                # .astype(dtype): staged substrates may accumulate the
                # payload wider than the solver dtype (fp32 wire + fp64
                # compensated wait, DESIGN.md §14) — normalize so the
                # scalar recurrences keep the solver's dtype (no-op on
                # monolithic substrates).
                arrived = ops.wait(jax.lax.dynamic_index_in_dim(
                    c.D, jnp.mod(im, l), axis=0, keepdims=False),
                    advanced=l - 1).astype(dtype)
                for t in range(2 * l + 1):         # rows im-2l+1 .. im+1
                    row = im - 2 * l + 1 + t
                    rv = row >= 0
                    G = g_set(G, row, col,
                              jnp.where(rv, arrived[t], g_get(G, row, col)))

            # ---- (K2) lines 9-10: correct column `col` -------------------
            for t in range(l - 1):     # j = i-2l+2 .. i-l   (sequential in j)
                j = i - 2 * l + 2 + t
                jv = j >= 0
                ssum = jnp.zeros((), dtype)
                for s in range(l + 1 + t):          # k = i-3l+1+s  (<= j-1)
                    k_ = i - 3 * l + 1 + s
                    kv = (k_ >= 0) & jv
                    ssum += g_get(G, k_, j, kv) * g_get(G, k_, col, kv)
                denom = jnp.where(jv, g_get(G, j, j, jv), jnp.ones((), dtype))
                denom = jnp.where(denom == 0, jnp.ones((), dtype), denom)
                val = (g_get(G, j, col, jv) - ssum) / denom
                G = g_set(G, j, col, jnp.where(jv, val, g_get(G, j, col, jv)))

            ssum = jnp.zeros((), dtype)
            for s in range(2 * l):                   # k = i-3l+1 .. i-l
                k_ = i - 3 * l + 1 + s
                kv = k_ >= 0
                ssum += jnp.square(g_get(G, k_, col, kv))
            arg = g_get(G, col, col) - ssum
            breakdown = (arg <= 0) | ~jnp.isfinite(arg)       # line 11
            sq = jnp.sqrt(jnp.where(breakdown, jnp.ones((), dtype), arg))
            G = g_set(G, col, col, sq)

            # ---- (K3) lines 12-18: new Hessenberg column -----------------
            g_mm = g_get(G, im, im)
            g_mm_safe = jnp.where(g_mm == 0, jnp.ones((), dtype), g_mm)
            g_mp = g_get(G, im, im + 1)
            g_prev = g_get(G, im - 1, im, im >= 1)
            d_prev = ring_get(dlt, im - 1, im >= 1)
            sig_im = sig[jnp.clip(im, 0, l - 1)]
            gam_early = (g_mp + sig_im * g_mm - g_prev * d_prev) / g_mm_safe
            dlt_early = sq / g_mm_safe
            gam_late = (
                g_mm * ring_get(gam, im - l) + g_mp * ring_get(dlt, im - l)
                - g_prev * d_prev
            ) / g_mm_safe
            dlt_late = sq * ring_get(dlt, im - l) / g_mm_safe
            early = i < 2 * l
            gam_new = jnp.where(early, gam_early, gam_late)
            dlt_new = jnp.where(early, dlt_early, dlt_late)
            gam = gam.at[jnp.mod(im, W)].set(gam_new)
            dlt = dlt.at[jnp.mod(im, W)].set(dlt_new)
            dlt_safe = jnp.where(dlt_new == 0, jnp.ones((), dtype), dlt_new)
            # ``arrived`` rides along for the telemetry row (the consumed
            # dot block is replicated scalar state) — unused and DCE'd
            # when uninstrumented.
            return (G, gam, dlt, gam_new, dlt_safe, arrived), breakdown

        def early_scal(args):
            G, gam, dlt = args
            return (G, gam, dlt, jnp.zeros((), dtype), jnp.ones((), dtype),
                    jnp.zeros((2 * l + 1,), dtype)), jnp.asarray(False)

        scal_args = (c.G, c.gam, c.dlt)
        if static_phase is None:
            (G, gam, dlt, gam_new, dlt_safe, arrived), breakdown = \
                jax.lax.cond(ge_l, late_scal, early_scal, scal_args)
        elif static_phase == "late":
            (G, gam, dlt, gam_new, dlt_safe, arrived), breakdown = \
                late_scal(scal_args)
        else:
            (G, gam, dlt, gam_new, dlt_safe, arrived), breakdown = \
                early_scal(scal_args)

        d2 = ring_get(dlt, im - 1, im >= 1)       # delta_{i-l-1}

        # ---- (K6) scalar updates (lines 24-32, D-Lanczos factors) --------
        gam0 = ring_get(gam, jnp.int32(0))
        gam_im = ring_get(gam, im, ge_l)
        d_prev = ring_get(dlt, im - 1, im >= 1)
        is_first = i == l
        eta0_safe = jnp.where(gam0 == 0, jnp.ones((), dtype), gam0)
        do_upd = i >= l + 1
        eta_prev_safe = jnp.where(c.eta_prev == 0, jnp.ones((), dtype),
                                  c.eta_prev)
        lam = d_prev / eta_prev_safe
        eta_new = gam_im - lam * d_prev
        eta_new_safe = jnp.where(eta_new == 0, jnp.ones((), dtype), eta_new)
        zet_new = -lam * c.zet_prev

        # ===== vector phase ===============================================
        # Ring-row indices + coefficients for the one-pass calling
        # convention shared by the unfused reference and the superkernel
        # (repro.kernels.fused_iter; DESIGN.md §13).
        sig_i = jnp.where(i < l, sig[jnp.clip(i, 0, l - 1)],
                          jnp.zeros((), dtype))
        idx = jnp.zeros((IX["size"],), jnp.int32)
        for k in range(l):
            idx = idx.at[IX["fill"] + k].set(zk_row(k, i + 1))
            idx = idx.at[IX["rec_w"] + k].set(zk_row(k, i - l + k + 1))
            idx = idx.at[IX["rec_a"] + k].set(zk_row(k + 1, i - l + k + 1))
            idx = idx.at[IX["rec_b"] + k].set(zk_row(k, i - l + k))
            idx = idx.at[IX["rec_c"] + k].set(zk_row(k, i - l + k - 1))
            idx = idx.at[IX["f_fill"] + k].set(
                ((i < l - 1) & (k >= i + 1)).astype(jnp.int32))
            idx = idx.at[IX["mat_v"] + k].set(zk_row(0, i - 2 * l + 1 + k))
        for t in range(l - 1):
            idx = idx.at[IX["mat_z"] + t].set(zk_row(l, i - l + 2 + t))
        idx = idx.at[IX["z_top"]].set(zk_row(l, i))
        idx = idx.at[IX["zl_im1"]].set(zk_row(l, i - 1))
        idx = idx.at[IX["z_w"]].set(zk_row(l, i + 1))
        idx = idx.at[IX["u_i"]].set(u_row(i))
        idx = idx.at[IX["u_im1"]].set(u_row(i - 1))
        idx = idx.at[IX["u_w"]].set(u_row(i + 1))
        idx = idx.at[IX["p_im"]].set(zk_row(0, im))
        idx = idx.at[IX["f_late"]].set(ge_l.astype(jnp.int32))
        idx = idx.at[IX["f_first"]].set(is_first.astype(jnp.int32))
        idx = idx.at[IX["f_upd"]].set(do_upd.astype(jnp.int32))

        scal = jnp.zeros((IS["size"],), dtype)
        scal = scal.at[IS["sig_i"]].set(sig_i)
        scal = scal.at[IS["gam_new"]].set(gam_new)
        scal = scal.at[IS["d2"]].set(d2)
        scal = scal.at[IS["dlt_safe"]].set(dlt_safe)
        scal = scal.at[IS["zet_prev"]].set(c.zet_prev)
        scal = scal.at[IS["d_prev"]].set(d_prev)
        scal = scal.at[IS["eta_new_safe"]].set(eta_new_safe)
        scal = scal.at[IS["eta0_safe"]].set(eta0_safe)
        for k in range(l):
            scal = scal.at[IS["c1"] + k].set(sig[k] - gam_new)

        if fiter is not None:
            # One HBM pass: SPMV + prec + fills + K4 + K6 + local dot
            # partials in the superkernel, then ONE global reduction on
            # the partials (K5's MPI_Iallreduce, same payload as ever).
            S, partials = fiter(c.S, idx, scal)
            dots = ops.start_partials(partials)
        else:
            S, mat, u_new = fused_iter_unfused(c.S, idx, scal, ops.apply_a,
                                               ops.prec, layout)
            # ---- (K5) line 23: initiate the dot block — ONE reduction ----
            # The raw payload (rows i-2l+1 .. i+1 of G column i+1) is
            # parked in the D ring; it is only consumed — and scattered
            # into G — at iteration i+l (MPI_Wait above).  Between the two
            # sites up to l reductions are simultaneously in flight.
            dots = ops.start(mat, u_new)
        D = c.D.at[jnp.mod(i, l)].set(dots)

        # ---- staged-reduction progress: one ladder hop per iteration ----
        # Every in-flight handle (pipeline age t = 1..l-1) advances by
        # exactly one ladder step — the hop-per-iteration pipeline of
        # DESIGN.md §14.  The step index is the handle's age minus one,
        # STATIC under the while loop (only the ring slot is dynamic), so
        # each ppermute's permutation is fixed at trace time.  Monolithic
        # substrates make advance the identity and XLA folds the loop
        # away; zero handles (early fill, post-restart) advance harmlessly
        # (permuting zeros writes zeros).
        for t in range(1, l):
            slot = jnp.mod(i - t, l)
            h = jax.lax.dynamic_index_in_dim(D, slot, axis=0,
                                             keepdims=False)
            D = jax.lax.dynamic_update_index_in_dim(
                D, ops.advance(h, t - 1), slot, axis=0)

        eta_prev = jnp.where(is_first, gam0,
                             jnp.where(do_upd, eta_new, c.eta_prev))
        zet_prev = jnp.where(is_first, c.norm0_cycle,
                             jnp.where(do_upd, zet_new, c.zet_prev))

        n_upd = jnp.where(do_upd, 1, 0).astype(jnp.int32)
        upd = st.upd + n_upd
        rnorm = jnp.abs(zet_new)
        # On a breakdown iteration the freshly computed scalars are garbage
        # (the restart discards them) — never record/converge on them.
        ok = do_upd & ~breakdown
        hist = jax.lax.cond(
            ok,
            lambda h: h.at[jnp.clip(upd, 0, H - 1)].set(rnorm),
            lambda h: h,
            st.hist,
        )
        # ---- stability governor: detection arms (DESIGN.md §18) ----------
        # Pure replicated-scalar work on values the scalar phase already
        # produced (the arrived dot block, the fresh Hessenberg entries) —
        # zero extra reductions, statically absent when ungoverned.
        gov = st.gov
        gov_cols = {}
        if governor is None:
            converged = st.converged | (ok & (rnorm / st.norm0 < tol))
        else:
            M = gov_model
            eps_c = jnp.asarray(governor.resolved_eps(dtype), dtype)
            # G(col, col) is the arrived block's last entry — the squared
            # scale of the newest basis vector.
            basis = jnp.sqrt(jnp.abs(arrived[2 * l]))
            # Grow by whichever is larger: the first-order eps model or
            # the per-iteration drift rate MEASURED over the previous
            # cycle (RATE; 0 until a restart measures one).  Under
            # injected corruption far beyond eps the measured rate
            # dominates and the gap arm fires within ~one cycle.
            inc = M.gap_step(jnp.zeros((), dtype), gam_new, d2, dlt_safe,
                             basis, eps_c, governor.kappa)
            gap_acc = gov[M.GAP] + jnp.maximum(inc, gov[M.RATE])
            gap = jnp.where(ge_l, gap_acc, gov[M.GAP])
            rel = rnorm / st.norm0
            improved = ok & (rel < governor.improve_ratio * gov[M.BEST])
            best = jnp.where(improved, rel, gov[M.BEST])
            best_upd = jnp.where(improved, upd.astype(dtype),
                                 gov[M.BEST_UPD])
            # Gap arm: the recursive residual is within ``safety`` of the
            # (modeled + measured) gap — it can no longer be trusted.
            # The recursion claiming convergence (rel < tol) is the same
            # situation: both schedule a replacement, whose clean
            # true-residual recompute either certifies convergence (the
            # restart's lucky check) or re-seeds the gap with the
            # measured discrepancy.  A governed solve therefore never
            # sets ``converged`` from the recursion alone.
            gap_due = ok & ((governor.safety * gap >= rel) | (rel < tol))
            pat_due = ok & (rel >= tol) & (
                upd.astype(dtype) - best_upd
                >= governor.resolved_patience(l))
            code = jnp.where(
                gap_due, jnp.asarray(M.ACTION_GAP_REPLACE, dtype),
                jnp.where(pat_due,
                          jnp.asarray(M.ACTION_PATIENCE_REPLACE, dtype),
                          jnp.zeros((), dtype)))
            due = jnp.where(gov[M.DUE] > 0, gov[M.DUE], code)
            gov = (gov.at[M.GAP].set(gap).at[M.BEST].set(best)
                      .at[M.BEST_UPD].set(best_upd).at[M.DUE].set(due))
            gov_cols = {"gap": gap, "action": code}
            converged = st.converged

        tel = tel_write(
            st.tel, st.tot,
            iter=st.tot, upd=upd,
            rnorm=jnp.where(ok, rnorm, -jnp.ones((), dtype)),
            age=jnp.minimum(i + 1, l),       # in-flight handles after park
            breakdown=breakdown, dots=arrived, **gov_cols,
        )

        cyc = _Cycle(
            S=S, G=G, D=D, gam=gam, dlt=dlt,
            eta_prev=eta_prev, zet_prev=zet_prev, i=i + 1,
            norm0_cycle=c.norm0_cycle,
        )
        return _State(
            cyc=cyc, tot=st.tot + 1, upd=upd, restarts=st.restarts,
            converged=converged, breakdown=breakdown, hist=hist, norm0=st.norm0,
            since_rr=st.since_rr + n_upd, tel=tel, gov=gov,
        )

    def do_restart(st: _State) -> _State:
        # Stagnation guard: a breakdown before the cycle's first solution
        # update (since_rr == 0) re-inits with a steepest-descent step so
        # the restart is guaranteed to make progress (see restart_cycle).
        cyc = restart_cycle(st.cyc.S[layout.x_row],
                            st.breakdown & (st.since_rr == 0))
        # A breakdown at a converged iterate is a "lucky breakdown": the
        # freshly computed residual M-norm at restart tells us directly.
        lucky = cyc.norm0_cycle / st.norm0 < tol

        # ---- governor accounting: consume the pending action ------------
        # ``norm0_cycle`` IS the true residual M-norm at the re-init, so
        # the fruitfulness of a governor-triggered replacement is judged
        # against clean arithmetic, not the (possibly corrupted)
        # recursive residual.  demote_after consecutive fruitless
        # replacements flip the terminal STAGNATED flag (DESIGN.md §18).
        gov = st.gov
        gov_cols = {}
        if governor is not None:
            M = gov_model
            was_due = gov[M.DUE]
            fired = was_due > 0
            rel_now = cyc.norm0_cycle / st.norm0   # TRUE rel residual
            # Measured true-vs-recursive gap: the recursion's latest
            # claim vs what the clean recompute actually found.  This
            # re-seeds the gap model on EVERY restart (breakdowns too),
            # so corruption far beyond the first-order eps model —
            # injected payload noise, a sick reduction wire — is
            # captured the first time a restart measures it, and the
            # gap arm then stops trusting recursive claims below it.
            eps_c = jnp.asarray(governor.resolved_eps(dtype), dtype)
            rec_rel = jnp.abs(st.cyc.zet_prev) / st.norm0
            measured = jnp.maximum(rel_now - rec_rel, jnp.zeros((), dtype))
            # The fresh cycle starts from a clean residual, so its gap
            # restarts near zero — but grows at the drift RATE this cycle
            # just exhibited (total measured gap / cycle length), which
            # sets the next replacement period adaptively.
            i_f = jnp.maximum(st.cyc.i.astype(dtype), jnp.ones((), dtype))
            rate_new = measured / i_f
            gap_new = eps_c
            fruitful = rel_now < governor.improve_ratio * gov[M.LAST_REL]
            fruitless = jnp.where(
                fired,
                jnp.where(fruitful, jnp.zeros((), dtype),
                          gov[M.FRUITLESS] + 1),
                gov[M.FRUITLESS])
            stag = jnp.where(fruitless >= governor.demote_after,
                             jnp.ones((), dtype), gov[M.STAGNATED])
            action = jnp.where(stag > gov[M.STAGNATED],
                               jnp.asarray(M.ACTION_STAGNATED, dtype),
                               was_due)
            gov = (gov.at[M.DUE].set(jnp.zeros((), dtype))
                      .at[M.REPL].set(gov[M.REPL]
                                      + fired.astype(dtype))
                      .at[M.FRUITLESS].set(fruitless)
                      .at[M.STAGNATED].set(stag)
                      .at[M.GAP].set(gap_new)
                      .at[M.RATE].set(rate_new)
                      .at[M.LAST_REL].set(jnp.where(fired, rel_now,
                                                    gov[M.LAST_REL]))
                      # Track the true residual as BEST too (it is the
                      # honest one), and restart the patience clock: the
                      # refill produces no updates, so the arm must wait
                      # a full window before escalating again.
                      .at[M.BEST].set(jnp.minimum(gov[M.BEST], rel_now))
                      .at[M.BEST_UPD].set(st.upd.astype(dtype)))
            gov_cols = {"gap": gov[M.GAP], "action": action}

        tel = tel_write(
            st.tel, st.tot,
            iter=st.tot, upd=st.upd,
            rnorm=cyc.norm0_cycle,           # TRUE residual M-norm at re-init
            age=jnp.int32(0),                # D-ring cleared by the restart
            breakdown=st.breakdown, restart=jnp.ones((), dtype),
            replacement=(~st.breakdown).astype(dtype), **gov_cols,
        )
        return _State(
            cyc=cyc, tot=st.tot + 1, upd=st.upd, restarts=st.restarts + 1,
            converged=st.converged | lucky, breakdown=jnp.asarray(False),
            hist=st.hist, norm0=st.norm0, since_rr=jnp.int32(0), tel=tel,
            gov=gov,
        )

    def needs_interrupt(st: _State) -> jax.Array:
        due = st.breakdown
        if replace_every > 0:
            # Periodic residual replacement: re-init the cycle from the
            # current iterate (true-residual recompute) once enough
            # solution updates have accumulated since the last (re)start.
            due = due | (st.since_rr >= replace_every)
        if governor is not None:
            # Governor-scheduled replacement: same interrupt machinery,
            # so batched drivers apply it per-column masked at segment
            # boundaries (DESIGN.md §18).
            due = due | (st.gov[gov_model.DUE] > 0)
        return due

    def body(st: _State) -> _State:
        return jax.lax.cond(needs_interrupt(st), do_restart, iteration, st)

    def cond(st: _State) -> jax.Array:
        keep = (
            (~st.converged)
            & (st.tot < tot_max)
            & (st.upd < maxit)
            & (st.restarts <= max_restarts)
        )
        if governor is not None:
            # Terminal stagnation: stop burning iterations; the host
            # ladder (repro.stability.governor) demotes l or raises a
            # typed StagnationError from the returned governor vector.
            keep = keep & ~(st.gov[gov_model.STAGNATED] > 0)
        return keep

    def init(x0: jax.Array) -> _State:
        cyc0 = init_cycle(x0)
        norm0 = cyc0.norm0_cycle
        hist0 = jnp.full((H,), -1.0, dtype).at[0].set(norm0)
        return _State(
            cyc=cyc0, tot=jnp.int32(0), upd=jnp.int32(0), restarts=jnp.int32(0),
            converged=norm0 == 0.0, breakdown=jnp.asarray(False),
            hist=hist0, norm0=norm0, since_rr=jnp.int32(0),
            tel=jnp.full((telemetry_cap, TK), -1.0, dtype),
            gov=(gov_model.gov_init(dtype) if governor is not None
                 else jnp.zeros((gov_model.N_SLOTS,), dtype)),
        )

    def finish(final: _State) -> SolveResult:
        return SolveResult(
            x=final.cyc.S[layout.x_row], iters=final.upd,
            restarts=final.restarts, converged=final.converged,
            res_history=final.hist, norm0=final.norm0,
            telemetry=final.tel if telemetry_cap else None,
            governor=final.gov if governor is not None else None,
        )

    return PlcgProgram(init=init, iteration=iteration, body=body, cond=cond,
                       finish=finish, step=iteration,
                       needs_interrupt=needs_interrupt, interrupt=do_restart)


def solve(
    ops: SolverOps,
    b: jax.Array,
    l: int,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxit: int = 1000,
    sigmas: jax.Array | None = None,
    max_restarts: int = 10,
    unroll: int = 1,
    replace_every: int = 0,
    fused_iteration: bool = False,
    telemetry_cap: int = 0,
    recurrence: str = "ghysels",
    governor: "gov_model.GovernorConfig | None" = None,
    checkpoint=None,
) -> SolveResult:
    """Solve A x = b with p(l)-CG.  ``l`` is the pipeline depth (static);
    ``fused_iteration=True`` runs the vector phase through the one-pass
    superkernel (DESIGN.md §13); ``telemetry_cap > 0`` records the
    on-device per-iteration telemetry ring (DESIGN.md §16);
    ``recurrence="stable"`` selects the coupled basis recurrence,
    ``governor`` arms the stability governor (DESIGN.md §18) and
    ``checkpoint`` (a ``repro.checkpoint.CheckpointConfig`` with
    ``every > 0``) arms the segmented checkpointing driver
    (DESIGN.md §19; ``every=0``/None leaves this compiled path
    untouched)."""
    if checkpoint is not None and checkpoint.armed:
        from repro.checkpoint import checkpointed_solve

        return checkpointed_solve(
            ops, b, "plcg", x0, checkpoint,
            dict(l=l, tol=tol, maxit=maxit, sigmas=sigmas,
                 max_restarts=max_restarts, replace_every=replace_every,
                 fused_iteration=fused_iteration,
                 telemetry_cap=telemetry_cap, recurrence=recurrence,
                 governor=governor))
    prog = build(ops, b, l, tol=tol, maxit=maxit, sigmas=sigmas,
                 max_restarts=max_restarts, replace_every=replace_every,
                 fused_iteration=fused_iteration, telemetry_cap=telemetry_cap,
                 recurrence=recurrence, governor=governor)
    dtype = b.dtype
    st0 = prog.init(jnp.zeros_like(b) if x0 is None else x0.astype(dtype))

    if unroll > 1:
        # Unrolled driver: expose an (unroll)-iteration window to XLA so the
        # latency-hiding scheduler can stagger the in-flight reductions
        # (DESIGN.md §2).  Semantics identical to unroll=1.
        def body_u(st: _State) -> _State:
            for k in range(unroll):
                with jax.named_scope(f"plu{k}"):
                    st = jax.lax.cond(prog.cond(st), prog.body,
                                      lambda s: s, st)
            return st

        final = jax.lax.while_loop(prog.cond, body_u, st0)
    else:
        final = jax.lax.while_loop(prog.cond, prog.body, st0)

    return prog.finish(final)
