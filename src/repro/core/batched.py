"""Batched multi-RHS solvers — the amortized-reduction layer (DESIGN.md §11).

The paper hides the latency of the per-iteration global reduction behind
local work; a solver *service* additionally amortizes it: solving s
right-hand sides against the same operator in lock-step turns the fused
2l+1-entry dot block into ONE (2l+1, s) payload reduced in a single
allreduce — s× the work per reduction latency without any extra
synchronization, the same lever as deepening the pipeline (Cornelis/
Cools/Vanroose, arXiv:1801.04728).

Mechanically this module is a thin, principled layer over the per-column
programs exposed by the three solvers (``classic_cg.build``,
``ghysels_pcg.build``, ``pipelined_cg.build``): each column runs the
UNMODIFIED per-column arithmetic and ``jax.vmap`` over the s-axis does the
batching —

* every ``ops.start`` dot block picks up a trailing batch dimension, so
  the backend's single ``lax.psum`` becomes a single psum of the full
  (2l+1, s) matrix payload (verified against the compiled HLO by
  ``repro.utils.trace.batched_plcg_overlap_report``);
* ``lax.while_loop``'s batching rule applies per-column conds as selects
  on the carry, so a column whose cond goes false is **bitwise frozen**
  while its neighbours keep iterating — this IS masked retirement, by
  construction rather than by bespoke masking code
  (tests/test_serve.py::test_retired_column_bitwise_frozen).

The staged ring reduction (DESIGN.md §14) batches the same way: the
per-column program's ``ops.advance`` ladder hops vmap into ONE
``ppermute`` per hop carrying the whole (P, 2l+1, s) gather buffer, and
the D-ring slots widen to the staged handle shape transparently — so
the amortization claim (one logical reduction per iteration, payload
wide, handle count 1) holds verbatim in staged mode, asserted on
compiled HLO by ``trace.batched_plcg_overlap_report``'s
``staged_starts_per_window``.

One vmap caveat shapes the loop structure: a batched ``lax.cond`` lowers
to select-with-both-branches, so the sequential drivers' in-loop
restart/replacement cond would execute its extra SPMV + reduction EVERY
slab iteration.  The batched drivers therefore run the program's bare
``step`` (one reduction) and pause a column at ``needs_interrupt``
(breakdown, due residual replacement); the ``interrupt`` (cycle re-init
/ vector replacement) is applied as a masked segment-boundary step —
same per-column arithmetic and restart schedule as the sequential path,
with the interrupt's reduction amortized to boundaries (asserted on
compiled HLO in tests/test_distributed.py: no computation carries more
than one all-reduce).

Two entry points:

``solve_batched(ops, B, method, **kw)``
    run every column to completion; returns a ``SolveResult`` whose
    leaves carry a leading s-axis (x: (s, n), res_history: (s, H), ...).
    Zero columns have norm0 == 0 and retire at iteration 0 — padding a
    partial slab with zeros is exact, not approximate.

``column_kernels`` / ``batched_init`` / ``batched_chunk`` / ...
    the chunked serving interface: init / chunk / inject / status /
    extract pieces over an explicit slab state, stepped ``chunk_iters``
    iterations at a time so the service layer (``repro.serve``) can
    retire converged columns and recycle their slots between chunks
    without recompiling.  Backends wrap these in their SPMD context
    (``make_slab_program`` -> :class:`SlabProgram`).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classic_cg, ghysels_pcg, pipelined_cg
from repro.core.types import SolveResult, SolverOps

# Per-column program builders — the batched layer shares THE solver
# arithmetic with the sequential path (same dispatch keys as
# repro.core.METHODS), so batched-vs-sequential residual histories agree
# bitwise per backend (tests/test_serve.py).
BUILDERS: dict[str, Callable] = {
    "cg": classic_cg.build,
    "pcg": ghysels_pcg.build,
    "plcg": pipelined_cg.build,
}


def vector_mask(method: str, kw: dict | None = None):
    """Pytree (matching the method's state) of bools: True for leaves
    whose TRAILING axis is the domain-decomposed vector axis n.

    Distributed backends use this to build shard_map partition specs for
    the slab state (vector leaves sharded on their last axis, everything
    else — windows, scalars, histories — replicated).
    """
    if method == "cg":
        return classic_cg.CgState(
            x=True, r=True, u=True, p=True,
            gamma=False, it=False, conv=False, hist=False)
    if method == "pcg":
        return ghysels_pcg.PcgState(
            S=True, gamma=False, alpha=False, it=False, conv=False,
            hist=False, since_rr=False)
    if method == "plcg":
        cyc = pipelined_cg._Cycle(
            S=True, G=False, D=False, gam=False, dlt=False,
            eta_prev=False, zet_prev=False, i=False, norm0_cycle=False)
        return pipelined_cg._State(
            cyc=cyc, tot=False, upd=False, restarts=False, converged=False,
            breakdown=False, hist=False, norm0=False, since_rr=False,
            tel=False, gov=False)
    raise KeyError(method)


class SlabStatus(NamedTuple):
    """Cheap per-chunk slab view (everything replicated / O(s))."""

    running: jax.Array      # (s,) bool — column's loop cond still true
    converged: jax.Array    # (s,) bool
    iters: jax.Array        # (s,) solution updates so far


class ColumnKernels(NamedTuple):
    """Per-column (unbatched) slab pieces; backends vmap + stage these."""

    init: Callable[[jax.Array], Any]                    # bcol -> st
    chunk: Callable[[jax.Array, Any], Any]              # (bcol, st) -> st
    status: Callable[[jax.Array, Any], SlabStatus]
    extract: Callable[[jax.Array, Any], SolveResult]


def _masked_interrupt(p, st):
    """Apply the program's interrupt (restart / residual replacement) as
    a per-column masked boundary step: the interrupt computation runs
    once and a select keeps it only where due.  Under vmap this costs ONE
    extra reduction per boundary — never per iteration — which is why the
    batched drivers run ``step`` (bare iteration) instead of ``body``
    (whose lax.cond would lower to select-both-branches per iteration)."""
    if p.needs_interrupt is None:
        return st
    due = p.needs_interrupt(st)
    fresh = p.interrupt(st)
    return jax.tree.map(lambda f, o: jnp.where(due, f, o), fresh, st)


def _col_cond(p):
    """Per-column loop cond for batched drivers: a column pauses at an
    interrupt boundary (breakdown / due replacement) instead of running
    the interrupt in-loop."""
    if p.needs_interrupt is None:
        return p.cond
    return lambda st: p.cond(st) & ~p.needs_interrupt(st)


def column_kernels(
    ops: SolverOps, method: str, kw: dict, chunk_iters: int
) -> ColumnKernels:
    """Build the per-column program pieces for one (method, kwargs) pair.

    Every piece takes the column's RHS ``bcol`` explicitly (the solver
    builders close over b), so the serve layer can swap a slot's RHS at
    inject time and the very same compiled computation serves the new
    request.
    """
    assert chunk_iters >= 1

    def prog(bcol):
        return BUILDERS[method](ops, bcol, **kw)

    def init(bcol):
        p = prog(bcol)
        return p.init(jnp.zeros_like(bcol))

    def chunk(bcol, st):
        p = prog(bcol)
        inner_cond = _col_cond(p)

        def cond(carry):
            st, j = carry
            return inner_cond(st) & (j < chunk_iters)

        def body(carry):
            st, j = carry
            return p.step(st), j + 1

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        # Boundary interrupts: a column that paused mid-chunk (breakdown,
        # due replacement) restarts here and resumes next chunk.
        return _masked_interrupt(p, st)

    def status(bcol, st):
        p = prog(bcol)
        res = p.finish(st)
        return SlabStatus(running=p.cond(st), converged=res.converged,
                          iters=res.iters)

    def extract(bcol, st):
        return prog(bcol).finish(st)

    return ColumnKernels(init=init, chunk=chunk, status=status,
                         extract=extract)


# --------------------------------------------------------------------------
# Batched (vmapped) forms.  B is (n, s) column-major-by-request; states and
# results carry a LEADING s-axis (vmap out_axes=0).
# --------------------------------------------------------------------------

def _select_columns(mask: jax.Array, new, old):
    """Per-column pytree select: leaf[i] <- new[i] where mask[i]."""

    def sel(f, o):
        m = mask.reshape(mask.shape + (1,) * (f.ndim - 1))
        return jnp.where(m, f, o)

    return jax.tree.map(sel, new, old)


def batched_init(ops, B, method: str, kw: dict, chunk_iters: int = 1):
    ck = column_kernels(ops, method, kw, chunk_iters)
    return jax.vmap(ck.init, in_axes=1)(B)


def batched_chunk(ops, B, st, method: str, kw: dict, chunk_iters: int):
    ck = column_kernels(ops, method, kw, chunk_iters)
    return jax.vmap(ck.chunk, in_axes=(1, 0))(B, st)


def batched_inject(ops, B, st, refresh, method: str, kw: dict,
                   chunk_iters: int = 1):
    """Re-initialize the columns flagged in ``refresh`` (s,) from the
    CURRENT columns of B, leaving every other column bitwise untouched —
    the slot-recycling primitive (retired slot -> fresh request)."""
    fresh = batched_init(ops, B, method, kw, chunk_iters)
    return _select_columns(refresh, fresh, st)


def batched_status(ops, B, st, method: str, kw: dict,
                   chunk_iters: int = 1) -> SlabStatus:
    ck = column_kernels(ops, method, kw, chunk_iters)
    return jax.vmap(ck.status, in_axes=(1, 0))(B, st)


def batched_extract(ops, B, st, method: str, kw: dict,
                    chunk_iters: int = 1) -> SolveResult:
    ck = column_kernels(ops, method, kw, chunk_iters)
    return jax.vmap(ck.extract, in_axes=(1, 0))(B, st)


def solve_batched(ops: SolverOps, B: jax.Array, method: str = "plcg",
                  **kw) -> SolveResult:
    """Solve A X = B for all s columns of B (n, s) in lock-step.

    Per-iteration communication: ONE fused reduction of the full
    (K, s) dot-block matrix (K = 2l+1 for p(l)-CG), whatever s is.
    Leaves of the result carry a leading s-axis.  Column i reproduces
    the sequential ``METHODS[method](ops, B[:, i], kw)`` result exactly
    (converged columns are frozen by the while-loop batching rule while
    the rest run on).
    """
    kw = dict(kw)
    kw.pop("unroll", None)          # window unrolling is a solve()-driver knob

    def col(bcol):
        p = BUILDERS[method](ops, bcol, **kw)
        st = p.init(jnp.zeros_like(bcol))
        if p.needs_interrupt is None:
            return p.finish(jax.lax.while_loop(p.cond, p.body, st))
        # Interrupt-aware methods: bare steps in the inner loop (ONE
        # reduction per slab iteration under vmap), interrupts applied
        # masked between segments.  Outer rounds advance every column by
        # at least one segment, so termination mirrors the sequential
        # restart budget.
        inner_cond = _col_cond(p)

        def outer(st):
            st = jax.lax.while_loop(inner_cond, p.step, st)
            return _masked_interrupt(p, st)

        return p.finish(jax.lax.while_loop(p.cond, outer, st))

    return jax.vmap(col, in_axes=1)(B)


# --------------------------------------------------------------------------
# Multi-slab step hooks (DESIGN.md §15).  The continuous-batching
# scheduler (repro.serve.scheduler) runs SEVERAL slabs per tick; these
# helpers keep the cross-slab concerns — dispatch overlap and
# slot-utilization accounting — next to the slab machinery they measure.
# --------------------------------------------------------------------------

def dispatch_slab_chunks(slabs) -> list:
    """Issue the chunk computation of EVERY slab before synchronizing on
    any of them.

    ``slabs`` yields ``(program, B_dev, state)`` triples; returns the new
    states in order.  jax dispatch is asynchronous, so enqueueing all
    chunks back-to-back lets XLA overlap independent slabs on the device
    stream — the scheduler ticks in three phases (pack all / chunk all /
    poll all) precisely so no slab's host-side status read serializes its
    neighbours' device work.  Each slab still reduces its own dot block
    as ONE (K, s) handle per iteration; running slabs concurrently
    multiplies slabs, never handles per slab (asserted on compiled HLO in
    tests/test_serve_replay.py).
    """
    return [prog.chunk(B, st) for prog, B, st in slabs]


def slab_slot_iterations(iters_before, iters_after) -> int:
    """Occupied-slot-iterations advanced between two status polls.

    ``SlabStatus.iters`` counts solution updates per column, so the
    element-wise delta across a chunk is exactly the number of
    iterations each slot spent doing useful work: free/zero-padded slots
    and bitwise-frozen converged columns contribute 0.  Summed against a
    capacity of ``s * chunk_iters`` per chunk this yields the slab
    slot-utilization metric the continuous-batching scheduler reports
    (occupied-slot-iterations / total slot-iterations) — the quantity
    that decays as a slab drains and that mid-flight injection keeps
    high (gated in BENCH_serve.json).
    """
    return int(np.sum(np.asarray(iters_after) - np.asarray(iters_before)))


class SlabProgram(NamedTuple):
    """Compiled slab-solver handles (built once per slab shape by a
    reduction backend's ``make_slab_program``; DESIGN.md §11).

    All callables are jit-compiled with fixed shapes (n, s) — the serve
    lifecycle (init -> [chunk -> retire -> inject]* -> extract) never
    retraces, whatever mix of requests flows through the slots.
    """

    method: str
    s: int
    n: int
    chunk_iters: int
    init: Callable[[jax.Array], Any]                      # B -> state
    chunk: Callable[[jax.Array, Any], Any]                # (B, st) -> st
    inject: Callable[[jax.Array, Any, jax.Array], Any]    # (B, st, mask) -> st
    status: Callable[[jax.Array, Any], SlabStatus]
    extract: Callable[[jax.Array, Any], SolveResult]
