from repro.core.types import SolveResult, SolverOps
from repro.core import classic_cg, ghysels_pcg, pipelined_cg, reference
from repro.core import batched
from repro.core.batched import solve_batched
from repro.core.chebyshev import chebyshev_shifts, power_method, shifts_for_operator

SOLVERS = {
    "cg": classic_cg.solve,
    "pcg": ghysels_pcg.solve,          # Ghysels p-CG (~p(1)-CG)
    "pipelcg": pipelined_cg.solve,     # deep pipelined p(l)-CG (Alg. 1)
}

# Canonical kwargs-dict dispatch used by every substrate (distributed_solve
# and all reduction backends share THIS dict, so a method added here works
# identically everywhere — DESIGN.md §3).
METHODS = {
    "cg": lambda ops, b, kw: classic_cg.solve(ops, b, **kw),
    "pcg": lambda ops, b, kw: ghysels_pcg.solve(ops, b, **kw),
    "plcg": lambda ops, b, kw: pipelined_cg.solve(ops, b, **kw),
}

__all__ = [
    "SolveResult",
    "SolverOps",
    "batched",
    "solve_batched",
    "classic_cg",
    "ghysels_pcg",
    "pipelined_cg",
    "reference",
    "chebyshev_shifts",
    "power_method",
    "shifts_for_operator",
    "SOLVERS",
    "METHODS",
]
