from repro.core.types import SolveResult, SolverOps
from repro.core import classic_cg, ghysels_pcg, pipelined_cg, reference
from repro.core.chebyshev import chebyshev_shifts, power_method, shifts_for_operator

SOLVERS = {
    "cg": classic_cg.solve,
    "pcg": ghysels_pcg.solve,          # Ghysels p-CG (~p(1)-CG)
    "pipelcg": pipelined_cg.solve,     # deep pipelined p(l)-CG (Alg. 1)
}

__all__ = [
    "SolveResult",
    "SolverOps",
    "classic_cg",
    "ghysels_pcg",
    "pipelined_cg",
    "reference",
    "chebyshev_shifts",
    "power_method",
    "shifts_for_operator",
    "SOLVERS",
]
