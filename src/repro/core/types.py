"""Common solver interfaces.

``SolverOps`` abstracts what a Krylov solver needs from the execution
substrate, so the *same* solver code runs single-device or under
``shard_map`` on a production mesh (DESIGN.md §3):

  apply_a    A @ x          (distributed: halo exchange + local stencil)
  prec       M^{-1} x       (distributed: communication-free block solve)
  dot_block  (K,N)@(N,)->(K,)  ALL inner products of one iteration fused
             into ONE global reduction — this is the paper's single
             ``MPI_Iallreduce`` of the G-column (distributed: one psum).

On top of the fused block, the reduction is exposed as an *async-friendly
handle pair* — the paper's MPI_Iallreduce / MPI_Wait split:

  start(mat, vec) -> dots   initiate the fused reduction.  The returned
                            array is a lazy handle: nothing forces its
                            completion until a consumer reads it.
  wait(dots)      -> dots   declare the consumption point.  Backends tag
                            both sites with named scopes (GLRED_START_TAG /
                            GLRED_WAIT_TAG) so the overlap tracer
                            (``repro.utils.trace``, DESIGN.md §6) can
                            recover the staggered in-flight chains from the
                            compiled HLO schedule, and insert an
                            ``optimization_barrier`` so XLA cannot collapse
                            the issue→consume window.

The solvers never call more than one ``dot_block`` per iteration (p-CG,
p(l)-CG) or two (classic CG) — exactly the reduction counts of Table 1.
``SolverOps`` instances are normally built by a reduction backend
(``repro.parallel.backends.get_backend``); ``SolverOps.local`` remains the
single-device shortcut used by tests and examples.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

# Named-scope tags attached by ``SolverOps.create`` at the reduction issue
# and consumption sites.  They flow into HLO instruction metadata
# (op_name), which is how the overlap tracer identifies the chains after
# XLA optimization — see DESIGN.md §6.
GLRED_START_TAG = "glred_start"
GLRED_WAIT_TAG = "glred_wait"

# Scope tag on the point-to-point halo exchange (``lax.ppermute``) of the
# distributed SpMV — both the structured stencil halo and the unstructured
# send/recv-set exchange (``repro.linalg.partition``).  The overlap tracer
# uses it to verify the paper's second staggering claim: neighbour
# communication rides INSIDE the in-flight reduction windows
# (DESIGN.md §6/§12).
HALO_TAG = "halo_xchg"

# Scope tag prefix on the staged ring-reduction ladder hops
# (``repro.parallel.reduction``, DESIGN.md §14): hop k of a staged dot
# block is one ``lax.ppermute`` inside a ``f"{REDUCE_TAG}{k}"`` scope.
# The overlap tracer counts these per iteration window and checks they
# interleave with HALO_TAG traffic inside the open reduction windows —
# the hop/halo staggering invariant.
REDUCE_TAG = "glred_hop"


# ``lax.optimization_barrier`` has no batching rule (jax <= 0.4.x), which
# would break the batched multi-RHS solvers (repro.core.batched vmaps the
# per-column programs over the s-axis).  The barrier is semantically
# transparent to vmap — a batched barrier is just a barrier on the batched
# array — so declare exactly that.
@jax.custom_batching.custom_vmap
def _opt_barrier(dots: jax.Array) -> jax.Array:
    return jax.lax.optimization_barrier(dots)


@_opt_barrier.def_vmap
def _opt_barrier_vmap(axis_size, in_batched, dots):
    # Recurse through _opt_barrier (not the raw primitive) so nested
    # vmaps peel one batch axis at a time instead of re-hitting the
    # missing batching rule.
    return _opt_barrier(dots), in_batched[0]


def dot_block_rows(mat: jax.Array, vec: jax.Array) -> jax.Array:
    """The fused dot block (K, N) x (N,) -> (K,) as an elementwise
    product + trailing-axis reduction instead of ``mat @ vec``.

    Semantically identical; chosen because it is bitwise-REPRODUCIBLE
    across every execution shape this repo runs the block in: a vmapped
    ``dot_general`` (the batched multi-RHS slab) and the Pallas
    interpreter's per-grid-step dot (the fused superkernel off-TPU) hit
    different gemm kernels whose reduction order differs at the ULP
    level, while a trailing-axis reduce lowers to the same per-row chain
    everywhere.  Every substrate's ``dot_block`` and the superkernel's
    in-VMEM partials use THIS expression, which is what makes
    fused/unfused and batched/sequential residual histories bitwise
    comparable (DESIGN.md §13; tests/test_fused_iter.py).
    """
    return (mat * vec[None, :]).sum(axis=1)


class SolveResult(NamedTuple):
    x: jax.Array           # approximate solution
    iters: jax.Array       # number of solution updates (CG-comparable count)
    restarts: jax.Array    # breakdown restarts performed (p(l)-CG only)
    converged: jax.Array   # bool
    res_history: jax.Array # recursive residual M-norms, -1 padded
    norm0: jax.Array       # initial residual M-norm
    # On-device iteration telemetry ring (cap, K), or None when the solve
    # was not instrumented (telemetry_cap=0, the default).  Row layout is
    # ``repro.kernels.fused_iter.tel_layout``; ``TelemetrySlab.unpack``
    # decodes it.  None is an EMPTY pytree subtree, so uninstrumented
    # results keep their pre-telemetry pytree structure — shard_map
    # out_specs, vmap axes and donation contracts are unchanged
    # (DESIGN.md §16).
    telemetry: jax.Array | None = None
    # Final stability-governor state vector (repro.stability.GOV_SLOTS) or
    # None when the solve ran ungoverned (governor=None, the default).
    # Same empty-subtree contract as ``telemetry``: ungoverned results
    # keep the pre-governor pytree structure (DESIGN.md §18).
    governor: jax.Array | None = None


@dataclasses.dataclass(frozen=True)
class TelemetrySlab:
    """Descriptor of the per-iteration telemetry ring (DESIGN.md §16).

    The instrumented p(l)-CG solve appends a small ``(cap, K)`` ring to
    its donated state: one row per iteration holding the already-computed
    per-iteration scalars (residual norm, the arrived 2l+1-entry dot
    block, restart/replacement flags, hop-group age).  Every recorded
    value is replicated scalar state on distributed substrates — the ring
    adds ZERO collectives and ZERO host syncs; it is drained only where
    the state already crosses the host boundary (solve end / chunk
    boundaries).  ``cap`` rows wrap: row ``tot % cap`` belongs to global
    iteration ``tot`` (the "iter" column disambiguates after wrap).
    """

    cap: int
    l: int

    @property
    def k(self) -> int:
        from repro.kernels.fused_iter import tel_layout

        return tel_layout(self.l)["size"]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.cap, self.k)

    def bytes_per_iter(self, dtype=jnp.float64) -> int:
        """HBM bytes the ring write adds per iteration (one K-row store
        + the ring-index arithmetic) — the overhead-accounting input of
        the instrumented-replay gate (DESIGN.md §16)."""
        return self.k * jnp.dtype(dtype).itemsize

    def unpack(self, tel) -> dict:
        """Decode a telemetry ring (…, cap, K) into named columns.

        Returns a dict of (…, cap) arrays for the scalar columns plus
        ``dots`` of shape (…, cap, 2l+1).  Rows never written (ring not
        yet full) carry the -1.0 fill in every column.
        """
        from repro.kernels.fused_iter import tel_layout

        tl = tel_layout(self.l)
        out = {name: tel[..., :, tl[name]]
               for name in ("iter", "upd", "rnorm", "age", "breakdown",
                            "restart", "replacement", "gap", "action")}
        out["dots"] = tel[..., :, tl["dots"]:tl["size"]]
        return out


@dataclasses.dataclass(frozen=True)
class SolverOps:
    apply_a: Callable[[jax.Array], jax.Array]
    prec: Callable[[jax.Array], jax.Array]
    dot_block: Callable[[jax.Array, jax.Array], jax.Array]
    # Async reduction-handle pair.  None means "derive from dot_block":
    # start falls back to a plain (synchronous) dot_block and wait to the
    # identity, which keeps hand-rolled SolverOps (benchmarks/table1.py)
    # working unchanged.
    dot_block_start: Callable[[jax.Array, jax.Array], jax.Array] | None = None
    dot_block_wait: Callable[..., jax.Array] | None = None
    # Staged-reduction extension (repro.parallel.reduction, DESIGN.md
    # §14).  ``dot_block_advance(handle, step)`` runs ONE ladder step of
    # an in-flight reduction — the solvers call it once per iteration per
    # outstanding handle, which is what spreads the reduction's latency
    # structurally over min(l, stages) iterations instead of leaving the
    # overlap to XLA's scheduler.  None (monolithic substrates) makes
    # ``advance`` the identity.  ``dot_block_handle_zeros(shape, dtype)``
    # builds the zero in-flight handle for a dot block of the given
    # payload shape — staged substrates return a (P, K[, s]) wire-dtype
    # gather buffer; None keeps the plain (K[, s]) payload array.
    dot_block_advance: Callable[[jax.Array, int], jax.Array] | None = None
    dot_block_handle_zeros: Callable[..., jax.Array] | None = None
    # Global combine of LOCALLY accumulated dot-block partials — the
    # reduction half of the fused-iteration superkernel path
    # (DESIGN.md §13).  The megakernel computes each shard's (2l+1)
    # partial dots in VMEM during its single pass over the basis slab;
    # ``start_partials`` then issues the same single global reduction as
    # ``start`` would (one psum on distributed substrates, a tagged
    # barrier locally) without re-reading any basis vector from HBM.
    combine_partials: Callable[[jax.Array], jax.Array] | None = None
    # Factory for the fused-iteration superkernel: called by
    # ``pipelined_cg.build(..., fused_iteration=True)`` with the solver's
    # :class:`repro.kernels.fused_iter.SlabLayout`; returns the
    # per-iteration vector-phase callable (slab, idx, scal) ->
    # (new slab, local dot partials).  None means the substrate/operator
    # combination has no fused path (the solver raises).
    fused_iter_factory: Callable[..., Callable] | None = None

    def start(self, mat: jax.Array, vec: jax.Array) -> jax.Array:
        """Initiate the fused dot block (the MPI_Iallreduce)."""
        if self.dot_block_start is None:
            return self.dot_block(mat, vec)
        return self.dot_block_start(mat, vec)

    def advance(self, handle: jax.Array, step: int) -> jax.Array:
        """Run ladder step ``step`` of an in-flight reduction handle —
        the hop-per-iteration progress call of the staged subsystem
        (DESIGN.md §14).  ``step`` is static (the handle's pipeline age
        minus one); monolithic substrates are already complete at issue,
        so the default is the identity."""
        if self.dot_block_advance is None:
            return handle
        return self.dot_block_advance(handle, step)

    def handle_zeros(self, shape: tuple, dtype) -> jax.Array:
        """Zero in-flight handle for a dot block with payload ``shape``
        — what a p(l)-CG D-ring slot holds before its first start.
        Staged substrates widen this to their (P, K[, s]) wire-dtype
        gather buffer."""
        if self.dot_block_handle_zeros is None:
            return jnp.zeros(shape, dtype)
        return self.dot_block_handle_zeros(shape, dtype)

    def start_partials(self, partials: jax.Array) -> jax.Array:
        """Initiate the global combine of locally-accumulated dot-block
        partials (the fused-iteration analogue of :meth:`start`): ONE
        reduction carrying the same 2l+1-entry payload, issued at the
        same tagged site so the overlap tracer sees an identical chain
        structure (DESIGN.md §6/§13)."""
        with jax.named_scope(GLRED_START_TAG):
            if self.combine_partials is None:
                # Single-device: nothing to combine, but the barrier (a)
                # marks the issue site for the tracer and (b) keeps XLA
                # from folding the handle into its consumer.
                return _opt_barrier(partials)
            return self.combine_partials(partials)

    def wait(self, dots: jax.Array, advanced: int = 0) -> jax.Array:
        """Consumption point of a previously started block (MPI_Wait).

        ``advanced`` (static) is how many ladder steps the solver already
        ran on this handle via :meth:`advance` — p(l)-CG passes l-1, a
        blocking start+wait passes 0; staged substrates finish the
        remaining steps here, monolithic ones ignore it."""
        if self.dot_block_wait is None:
            return dots
        return self.dot_block_wait(dots, advanced=advanced)

    @staticmethod
    def create(
        apply_a: Callable[[jax.Array], jax.Array],
        prec: Callable[[jax.Array], jax.Array],
        dot_block: Callable[[jax.Array, jax.Array], jax.Array],
        combine_partials: Callable[[jax.Array], jax.Array] | None = None,
        fused_iter_factory: Callable[..., Callable] | None = None,
        dot_block_start: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
        dot_block_wait: Callable[..., jax.Array] | None = None,
        dot_block_advance: Callable[[jax.Array, int], jax.Array] | None = None,
        handle_zeros: Callable[..., jax.Array] | None = None,
    ) -> "SolverOps":
        """Build SolverOps with tracer-tagged start/wait around dot_block.

        Every reduction backend funnels through here so the issue and
        consumption sites of each reduction carry GLRED_START_TAG /
        GLRED_WAIT_TAG scopes in the lowered HLO (DESIGN.md §6).
        ``combine_partials``/``fused_iter_factory`` wire the
        fused-iteration superkernel path (DESIGN.md §13) where the
        substrate supports it.  Staged substrates override the whole
        handle life cycle (``dot_block_start`` / ``dot_block_advance`` /
        ``dot_block_wait`` / ``handle_zeros``,
        ``repro.parallel.reduction.staged_ops_pieces``); the overrides
        are wrapped in the same tracer scopes as the monolithic pair.
        """

        if dot_block_start is None:
            def dot_block_start(mat, vec):  # noqa: F811 - default impl
                return dot_block(mat, vec)

        def start(mat, vec, _start=dot_block_start):
            with jax.named_scope(GLRED_START_TAG):
                return _start(mat, vec)

        if dot_block_wait is None:
            def dot_block_wait(dots, advanced=0):  # noqa: F811
                return dots

        def wait(dots, advanced=0, _wait=dot_block_wait):
            with jax.named_scope(GLRED_WAIT_TAG):
                return _opt_barrier(_wait(dots, advanced=advanced))

        return SolverOps(
            apply_a=apply_a,
            prec=prec,
            dot_block=dot_block,
            dot_block_start=start,
            dot_block_wait=wait,
            dot_block_advance=dot_block_advance,
            dot_block_handle_zeros=handle_zeros,
            combine_partials=combine_partials,
            fused_iter_factory=fused_iter_factory,
        )

    @staticmethod
    def local(op, prec=None) -> "SolverOps":
        """Single-device ops (tests, small problems)."""
        from repro.kernels.ops import fused_iteration_factory

        pfun = (lambda v: v) if prec is None else (lambda v: prec.apply(v))
        return SolverOps.create(
            apply_a=lambda v: op.apply(v),
            prec=pfun,
            dot_block=dot_block_rows,
            fused_iter_factory=fused_iteration_factory(op, prec),
        )


def dot1(ops: SolverOps, a: jax.Array, b: jax.Array) -> jax.Array:
    """Single global dot through the fused-block path, started and
    immediately waited — a blocking reduction (classic CG's
    synchronization point).  The result is normalized to the operand
    dtype: a staged substrate may accumulate a narrowed wire payload in
    a wider dtype (DESIGN.md §14)."""
    return ops.wait(ops.start(a[None, :], b))[0].astype(a.dtype)
