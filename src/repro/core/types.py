"""Common solver interfaces.

``SolverOps`` abstracts the three things a Krylov solver needs from the
execution substrate, so the *same* solver code runs single-device or under
``shard_map`` on a production mesh:

  apply_a    A @ x          (distributed: halo exchange + local stencil)
  prec       M^{-1} x       (distributed: communication-free block solve)
  dot_block  (K,N)@(N,)->(K,)  ALL inner products of one iteration fused
             into ONE global reduction — this is the paper's single
             ``MPI_Iallreduce`` of the G-column (distributed: one psum).

The solvers never call more than one ``dot_block`` per iteration (p-CG,
p(l)-CG) or two (classic CG) — exactly the reduction counts of Table 1.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class SolveResult(NamedTuple):
    x: jax.Array           # approximate solution
    iters: jax.Array       # number of solution updates (CG-comparable count)
    restarts: jax.Array    # breakdown restarts performed (p(l)-CG only)
    converged: jax.Array   # bool
    res_history: jax.Array # recursive residual M-norms, -1 padded
    norm0: jax.Array       # initial residual M-norm


@dataclasses.dataclass(frozen=True)
class SolverOps:
    apply_a: Callable[[jax.Array], jax.Array]
    prec: Callable[[jax.Array], jax.Array]
    dot_block: Callable[[jax.Array, jax.Array], jax.Array]

    @staticmethod
    def local(op, prec=None) -> "SolverOps":
        """Single-device ops (tests, small problems)."""
        pfun = (lambda v: v) if prec is None else (lambda v: prec.apply(v))
        return SolverOps(
            apply_a=lambda v: op.apply(v),
            prec=pfun,
            dot_block=lambda mat, vec: mat @ vec,
        )


def dot1(ops: SolverOps, a: jax.Array, b: jax.Array) -> jax.Array:
    """Single global dot through the fused-block path."""
    return ops.dot_block(a[None, :], b)[0]
