"""Ghysels & Vanroose pipelined CG (p-CG) [19].

ONE fused global reduction per iteration ({gamma=(r,u), delta=(w,u)} in a
single dot-block = a single MPI_Iallreduce), overlapped with the iteration's
own SPMV + preconditioner application: ``Time = max(glred, spmv)``
(Table 1, row 'p-CG').  Conceptually p(1)-CG, derived differently; kept as
the reference pipelined method the paper benchmarks against.

Rounding-error behaviour: the auxiliary recurrences (s = Ap, q = M^{-1}s,
z = Aq, and the recurred r/u/w) drift from their true values, so the
attainable accuracy of p-CG is strictly worse than classic CG on
ill-conditioned systems.  ``replace_every > 0`` enables the *residual
replacement* countermeasure of Cools/Cornelis/Vanroose (arXiv:1902.03100):
every ``replace_every`` iterations the recurred vectors are replaced by
their true values (r = b - Ax, u = M^{-1}r, w = Au, s = Ap, q = M^{-1}s,
z = Aq) at the cost of four extra SPMVs and two extra preconditioner
applies per replacement — restoring CG-level attainable accuracy while
keeping the single-reduction structure of every other iteration
(tests/test_residual_replacement.py).

The iteration is exposed as a ``build()`` program (init/body/cond/finish)
for external drivers — the batched multi-RHS layer (``repro.core.batched``,
DESIGN.md §11).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import SolveResult, SolverOps, dot1


# Rows of the contiguous p-CG vector slab S (NV_PCG, N) — the same
# structure-of-arrays layout as p(l)-CG's basis slab (DESIGN.md §13):
# one array, one trailing N axis, so slab-program drivers can
# ``donate_argnums`` the whole vector state and the while-loop updates
# it row-wise in place instead of copying eight separate buffers.
X_ROW, R_ROW, U_ROW, W_ROW, Z_ROW, Q_ROW, S_ROW, P_ROW = range(8)
NV_PCG = 8


class PcgState(NamedTuple):
    S: jax.Array         # (NV_PCG, N) slab: [x, r, u, w, z, q, s, p]
    gamma: jax.Array
    alpha: jax.Array
    it: jax.Array
    conv: jax.Array
    hist: jax.Array      # hist[0] is norm0 (the stopping reference)
    since_rr: jax.Array  # iterations since the last residual replacement


class PcgProgram(NamedTuple):
    """p-CG pieces.  ``body`` is the sequential driver (one iteration +
    in-loop residual replacement behind a runtime-exclusive ``lax.cond``);
    ``step`` is the bare iteration and ``needs_interrupt``/``interrupt``
    the replacement pair, for drivers (the batched multi-RHS layer,
    DESIGN.md §11) where a vmapped ``lax.cond`` would execute BOTH
    branches every iteration — those stop a column at the interrupt
    boundary and apply the replacement as a masked out-of-loop step."""

    init: Callable[[jax.Array], "PcgState"]
    body: Callable[["PcgState"], "PcgState"]
    cond: Callable[["PcgState"], jax.Array]
    finish: Callable[["PcgState"], SolveResult]
    step: Callable[["PcgState"], "PcgState"]
    needs_interrupt: Callable[["PcgState"], jax.Array] | None = None
    interrupt: Callable[["PcgState"], "PcgState"] | None = None


def build(
    ops: SolverOps,
    b: jax.Array,
    tol: float = 1e-6,
    maxit: int = 1000,
    replace_every: int = 0,
) -> PcgProgram:
    dtype = b.dtype

    def init(x0: jax.Array) -> PcgState:
        x = x0.astype(dtype)
        r = b - ops.apply_a(x)
        u = ops.prec(r)
        w = ops.apply_a(u)
        norm0 = jnp.sqrt(jnp.abs(dot1(ops, r, u)))
        hist0 = jnp.full((maxit + 2,), -1.0, dtype=dtype).at[0].set(norm0)
        S = jnp.zeros((NV_PCG, b.shape[0]), dtype)
        S = S.at[X_ROW].set(x).at[R_ROW].set(r).at[U_ROW].set(u)
        S = S.at[W_ROW].set(w)
        one = jnp.asarray(1.0, dtype)
        return PcgState(S=S, gamma=one, alpha=one, it=jnp.int32(0),
                        conv=norm0 == 0.0, hist=hist0, since_rr=jnp.int32(0))

    def cond(st: PcgState) -> jax.Array:
        return (~st.conv) & (st.it < maxit)

    def step(st: PcgState) -> PcgState:
        norm0 = st.hist[0]
        S = st.S
        # --- ONE fused reduction: {(r,u), (w,u)}, initiated through the
        # backend handle (MPI_Iallreduce) and only waited on AFTER the
        # iteration's own preconditioner + SPMV — the overlap window of
        # Table 1, row 'p-CG' (DESIGN.md §3/§6).
        pending = ops.start(S[(R_ROW, W_ROW), :], S[U_ROW])
        # --- overlapped work: preconditioner + SPMV of this iteration.
        # On a staged substrate the ladder's first step advances between
        # the two local kernels — the reduction hops interleave with the
        # SPMV's halo traffic inside the overlap window (DESIGN.md §14);
        # monolithic substrates make advance the identity.
        m = ops.prec(S[W_ROW])
        pending = ops.advance(pending, 0)
        nvec = ops.apply_a(m)
        # MPI_Wait; .astype: a staged wait may return the payload in a
        # wider accumulation dtype (fp64-compensated fp32 wire) — keep
        # the scalar recurrences in the solver dtype.
        gd = ops.wait(pending, advanced=1).astype(dtype)
        gamma, delta = gd[0], gd[1]
        first = st.it == 0
        beta = jnp.where(first, 0.0, gamma / st.gamma)
        denom = jnp.where(
            first, delta,
            delta - beta * gamma / jnp.where(first, 1.0, st.alpha)
        )
        alpha = gamma / denom
        z = nvec + beta * S[Z_ROW]
        q = m + beta * S[Q_ROW]
        s = S[W_ROW] + beta * S[S_ROW]
        p = S[U_ROW] + beta * S[P_ROW]
        x = S[X_ROW] + alpha * p
        r = S[R_ROW] - alpha * s
        u = S[U_ROW] - alpha * q
        w = S[W_ROW] - alpha * z
        S = S.at[Z_ROW].set(z).at[Q_ROW].set(q).at[S_ROW].set(s)
        S = S.at[P_ROW].set(p).at[X_ROW].set(x).at[R_ROW].set(r)
        S = S.at[U_ROW].set(u).at[W_ROW].set(w)
        rnorm = jnp.sqrt(jnp.abs(gamma))  # ||r||_M of the *pre-update* residual
        hist = st.hist.at[st.it + 1].set(rnorm)
        conv = rnorm / norm0 < tol
        return PcgState(S=S, gamma=gamma, alpha=alpha, it=st.it + 1,
                        conv=conv, hist=hist, since_rr=st.since_rr + 1)

    # Residual replacement (arXiv:1902.03100): swap every recurred vector
    # for its true value.  The scalars (gamma/alpha) are kept —
    # replacement resets the error of the vector recurrences, not the
    # Krylov coefficients.
    def replace(st: PcgState) -> PcgState:
        S = st.S
        r = b - ops.apply_a(S[X_ROW])
        u = ops.prec(r)
        w = ops.apply_a(u)
        s = ops.apply_a(S[P_ROW])
        q = ops.prec(s)
        z = ops.apply_a(q)
        S = S.at[R_ROW].set(r).at[U_ROW].set(u).at[W_ROW].set(w)
        S = S.at[S_ROW].set(s).at[Q_ROW].set(q).at[Z_ROW].set(z)
        return st._replace(S=S, since_rr=jnp.int32(0))

    def needs_replace(st: PcgState) -> jax.Array:
        return st.since_rr >= replace_every

    def body(st: PcgState) -> PcgState:
        st = step(st)
        if replace_every > 0:
            # Runtime-exclusive in the sequential while-loop (scalar
            # predicate): the 4-SPMV replacement runs only on its due
            # iteration.
            st = jax.lax.cond(needs_replace(st), replace, lambda s: s, st)
        return st

    def finish(st: PcgState) -> SolveResult:
        return SolveResult(
            x=st.S[X_ROW], iters=st.it, restarts=jnp.int32(0),
            converged=st.conv, res_history=st.hist, norm0=st.hist[0],
        )

    return PcgProgram(
        init=init, body=body, cond=cond, finish=finish, step=step,
        needs_interrupt=needs_replace if replace_every > 0 else None,
        interrupt=replace if replace_every > 0 else None,
    )


def solve(
    ops: SolverOps,
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxit: int = 1000,
    replace_every: int = 0,
    checkpoint=None,
) -> SolveResult:
    if checkpoint is not None and checkpoint.armed:
        # Segmented checkpointing driver (DESIGN.md §19): snapshots at
        # residual-replacement boundaries.  every=0/None keeps the
        # compiled while-loop below byte-identical to pre-§19.
        from repro.checkpoint import checkpointed_solve

        return checkpointed_solve(
            ops, b, "pcg", x0, checkpoint,
            dict(tol=tol, maxit=maxit, replace_every=replace_every))
    prog = build(ops, b, tol=tol, maxit=maxit, replace_every=replace_every)
    st0 = prog.init(jnp.zeros_like(b) if x0 is None else x0)
    return prog.finish(jax.lax.while_loop(prog.cond, prog.body, st0))
