"""Ghysels & Vanroose pipelined CG (p-CG) [19].

ONE fused global reduction per iteration ({gamma=(r,u), delta=(w,u)} in a
single dot-block = a single MPI_Iallreduce), overlapped with the iteration's
own SPMV + preconditioner application: ``Time = max(glred, spmv)``
(Table 1, row 'p-CG').  Conceptually p(1)-CG, derived differently; kept as
the reference pipelined method the paper benchmarks against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SolveResult, SolverOps, dot1


def solve(
    ops: SolverOps,
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxit: int = 1000,
) -> SolveResult:
    dtype = b.dtype
    x = jnp.zeros_like(b) if x0 is None else x0.astype(dtype)

    r = b - ops.apply_a(x)
    u = ops.prec(r)
    w = ops.apply_a(u)
    norm0 = jnp.sqrt(jnp.abs(dot1(ops, r, u)))
    hist0 = jnp.full((maxit + 2,), -1.0, dtype=dtype).at[0].set(norm0)
    z = jnp.zeros_like(b)

    def cond(st):
        *_, it, conv, hist = st
        return (~conv) & (it < maxit)

    def body(st):
        x, r, u, w, z, q, s, p, gamma_old, alpha_old, it, conv, hist = st
        # --- ONE fused reduction: {(r,u), (w,u)}, initiated through the
        # backend handle (MPI_Iallreduce) and only waited on AFTER the
        # iteration's own preconditioner + SPMV — the overlap window of
        # Table 1, row 'p-CG' (DESIGN.md §3/§6).
        pending = ops.start(jnp.stack([r, w]), u)
        # --- overlapped work: preconditioner + SPMV of this iteration
        m = ops.prec(w)
        nvec = ops.apply_a(m)
        gd = ops.wait(pending)                    # MPI_Wait
        gamma, delta = gd[0], gd[1]
        first = it == 0
        beta = jnp.where(first, 0.0, gamma / gamma_old)
        denom = jnp.where(
            first, delta, delta - beta * gamma / jnp.where(first, 1.0, alpha_old)
        )
        alpha = gamma / denom
        z = nvec + beta * z
        q = m + beta * q
        s = w + beta * s
        p = u + beta * p
        x = x + alpha * p
        r = r - alpha * s
        u = u - alpha * q
        w = w - alpha * z
        rnorm = jnp.sqrt(jnp.abs(gamma))  # ||r||_M of the *pre-update* residual
        hist = hist.at[it + 1].set(rnorm)
        conv = rnorm / norm0 < tol
        return (x, r, u, w, z, q, s, p, gamma, alpha, it + 1, conv, hist)

    st = (x, r, u, w, z, z, z, z, jnp.asarray(1.0, dtype), jnp.asarray(1.0, dtype),
          jnp.int32(0), norm0 == 0.0, hist0)
    out = jax.lax.while_loop(cond, body, st)
    x, r, u, w, z, q, s, p, gamma, alpha, it, conv, hist = out
    return SolveResult(
        x=x, iters=it, restarts=jnp.int32(0), converged=conv,
        res_history=hist, norm0=norm0,
    )
