"""Classic preconditioned Conjugate Gradients (Hestenes–Stiefel).

The baseline of the paper: TWO global reduction phases per iteration
((s,p) for alpha, then (r,u) for beta/convergence), each a synchronization
point that cannot overlap with the SPMV — ``Time = 2 glred + 1 spmv``
(Table 1, row 'CG').

Both reductions go through the backend handle API (start + immediate
wait): the overlap tracer therefore sees exactly one chain in flight at a
time for classic CG — the baseline against which p(l)-CG's staggering is
measured (DESIGN.md §6).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import SolveResult, SolverOps, dot1


def solve(
    ops: SolverOps,
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxit: int = 1000,
) -> SolveResult:
    n = b.shape[0]
    dtype = b.dtype
    x = jnp.zeros_like(b) if x0 is None else x0.astype(dtype)

    r = b - ops.apply_a(x)
    u = ops.prec(r)
    gamma = dot1(ops, r, u)                       # reduction (init)
    norm0 = jnp.sqrt(jnp.abs(gamma))
    hist0 = jnp.full((maxit + 2,), -1.0, dtype=dtype).at[0].set(norm0)

    def cond(st):
        x, r, u, p, gamma, it, conv, hist = st
        return (~conv) & (it < maxit)

    def body(st):
        x, r, u, p, gamma, it, conv, hist = st
        s = ops.apply_a(p)
        alpha = gamma / dot1(ops, s, p)           # reduction 1 — sync point
        # (start+wait back-to-back: classic CG cannot hide this latency)
        x = x + alpha * p
        r = r - alpha * s
        u = ops.prec(r)
        gamma_new = dot1(ops, r, u)               # reduction 2 — sync point
        rnorm = jnp.sqrt(jnp.abs(gamma_new))
        hist = hist.at[it + 1].set(rnorm)
        conv = rnorm / norm0 < tol
        beta = gamma_new / gamma
        p = u + beta * p
        return (x, r, u, p, gamma_new, it + 1, conv, hist)

    st = (x, r, u, u, gamma, jnp.int32(0), norm0 == 0.0, hist0)
    x, r, u, p, gamma, it, conv, hist = jax.lax.while_loop(cond, body, st)
    return SolveResult(
        x=x, iters=it, restarts=jnp.int32(0), converged=conv,
        res_history=hist, norm0=norm0,
    )
