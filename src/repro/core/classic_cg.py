"""Classic preconditioned Conjugate Gradients (Hestenes–Stiefel).

The baseline of the paper: TWO global reduction phases per iteration
((s,p) for alpha, then (r,u) for beta/convergence), each a synchronization
point that cannot overlap with the SPMV — ``Time = 2 glred + 1 spmv``
(Table 1, row 'CG').

Both reductions go through the backend handle API (start + immediate
wait): the overlap tracer therefore sees exactly one chain in flight at a
time for classic CG — the baseline against which p(l)-CG's staggering is
measured (DESIGN.md §6).

Like the other two solvers, the iteration is exposed as a ``build()``
program (init/body/cond/finish) so external drivers — the batched
multi-RHS layer (``repro.core.batched``, DESIGN.md §11) and the overlap
tracer — can step it without the ``lax.while_loop`` wrapper.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import SolveResult, SolverOps, dot1


class CgState(NamedTuple):
    x: jax.Array
    r: jax.Array
    u: jax.Array
    p: jax.Array
    gamma: jax.Array
    it: jax.Array
    conv: jax.Array
    hist: jax.Array      # hist[0] is norm0 (the stopping reference)


class CgProgram(NamedTuple):
    init: Callable[[jax.Array], "CgState"]
    body: Callable[["CgState"], "CgState"]
    cond: Callable[["CgState"], jax.Array]
    finish: Callable[["CgState"], SolveResult]
    # Uniform program surface with pcg/plcg (batched drivers): classic CG
    # has no restart/replacement interrupts — step IS body.
    step: Callable[["CgState"], "CgState"] | None = None
    needs_interrupt: Callable[["CgState"], jax.Array] | None = None
    interrupt: Callable[["CgState"], "CgState"] | None = None


def build(
    ops: SolverOps,
    b: jax.Array,
    tol: float = 1e-6,
    maxit: int = 1000,
) -> CgProgram:
    dtype = b.dtype

    def init(x0: jax.Array) -> CgState:
        x = x0.astype(dtype)
        r = b - ops.apply_a(x)
        u = ops.prec(r)
        gamma = dot1(ops, r, u)                   # reduction (init)
        norm0 = jnp.sqrt(jnp.abs(gamma))
        hist0 = jnp.full((maxit + 2,), -1.0, dtype=dtype).at[0].set(norm0)
        return CgState(x=x, r=r, u=u, p=u, gamma=gamma, it=jnp.int32(0),
                       conv=norm0 == 0.0, hist=hist0)

    def cond(st: CgState) -> jax.Array:
        return (~st.conv) & (st.it < maxit)

    def body(st: CgState) -> CgState:
        norm0 = st.hist[0]
        s = ops.apply_a(st.p)
        alpha = st.gamma / dot1(ops, s, st.p)     # reduction 1 — sync point
        # (start+wait back-to-back: classic CG cannot hide this latency)
        x = st.x + alpha * st.p
        r = st.r - alpha * s
        u = ops.prec(r)
        gamma_new = dot1(ops, r, u)               # reduction 2 — sync point
        rnorm = jnp.sqrt(jnp.abs(gamma_new))
        hist = st.hist.at[st.it + 1].set(rnorm)
        conv = rnorm / norm0 < tol
        beta = gamma_new / st.gamma
        p = u + beta * st.p
        return CgState(x=x, r=r, u=u, p=p, gamma=gamma_new, it=st.it + 1,
                       conv=conv, hist=hist)

    def finish(st: CgState) -> SolveResult:
        return SolveResult(
            x=st.x, iters=st.it, restarts=jnp.int32(0), converged=st.conv,
            res_history=st.hist, norm0=st.hist[0],
        )

    return CgProgram(init=init, body=body, cond=cond, finish=finish,
                     step=body)


def solve(
    ops: SolverOps,
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-6,
    maxit: int = 1000,
) -> SolveResult:
    prog = build(ops, b, tol=tol, maxit=maxit)
    st0 = prog.init(jnp.zeros_like(b) if x0 is None else x0)
    return prog.finish(jax.lax.while_loop(prog.cond, prog.body, st0))
