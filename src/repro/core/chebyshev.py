"""Auxiliary-basis shifts for p(l)-CG (paper §2.2, Eq. 25).

The auxiliary basis Z = P_l(A) V is not orthogonal; its conditioning is
governed by ||P_l(A)||_2.  Chebyshev shifts on [lambda_min, lambda_max]
minimize that norm; the spectral interval is estimated a priori with a few
power-method iterations (as the paper prescribes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chebyshev_shifts(lam_min: float, lam_max: float, l: int, dtype=jnp.float64):
    """sigma_i = (lmax+lmin)/2 + (lmax-lmin)/2 * cos((2i+1)pi/(2l)),  i=0..l-1."""
    i = jnp.arange(l, dtype=dtype)
    mid = (lam_max + lam_min) / 2.0
    rad = (lam_max - lam_min) / 2.0
    return mid + rad * jnp.cos((2.0 * i + 1.0) * jnp.pi / (2.0 * l))


def power_method(apply_a, n: int, iters: int = 20, key=None, dtype=jnp.float64):
    """Estimate lambda_max of the SPD operator with a few power iterations.
    Returns (lam_max_estimate, final_vector)."""
    key = jax.random.PRNGKey(0) if key is None else key
    v0 = jax.random.normal(key, (n,), dtype)
    v0 = v0 / jnp.linalg.norm(v0)

    def body(_, carry):
        v, lam = carry
        w = apply_a(v)
        lam = jnp.vdot(v, w)
        nw = jnp.linalg.norm(w)
        return w / jnp.where(nw == 0, 1.0, nw), lam

    v, lam = jax.lax.fori_loop(0, iters, body, (v0, jnp.zeros((), dtype)))
    return lam, v


def shifts_for_operator(op, l: int, safety: float = 1.05, dtype=jnp.float64,
                        prec=None):
    """Shift vector for an operator: analytic bounds if available, else a
    power-method lambda_max and lambda_min ~ 0 (the paper's PETSc runs use
    the conservative interval [0, 2] after Jacobi-type scaling).

    With ``prec`` the bounds are estimated for the PRECONDITIONED operator
    M^{-1}A (similar to an SPD matrix, so the power method applies) — the
    basis polynomial P_l acts on M^{-1}A in preconditioned p(l)-CG, so
    shifts from the unpreconditioned spectrum would be badly mis-scaled."""
    if prec is not None:
        apply = lambda v: prec.apply(op.apply(v))
        lam, _ = power_method(apply, op.n, iters=30, dtype=dtype)
        return chebyshev_shifts(0.0, float(lam) * safety, l, dtype=dtype)
    try:
        lmin, lmax = op.eig_bounds()
    except NotImplementedError:
        lam, _ = power_method(op.apply, op.n, dtype=dtype)
        lmin, lmax = 0.0, float(lam) * safety
    return chebyshev_shifts(lmin, lmax, l, dtype=dtype)
