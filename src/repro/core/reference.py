"""Plain NumPy reference implementations (oracles) of the CG family.

These follow the paper's pseudo-code as literally as possible, with full
(non-ring-buffer) storage, and exist purely for validation: the JAX
implementations in ``classic_cg.py`` / ``ghysels_pcg.py`` /
``pipelined_cg.py`` are tested element-wise against them.

``pl_cg_reference`` is Alg. 1 of the paper (preconditioned l-length
pipelined CG) line-by-line, including the pipeline-fill copies (line 5-7),
dot-product finalization (8-10), square-root breakdown check + explicit
restart (10-11, §2.2), Hessenberg updates (12-18), stable multi-basis
recurrences (19-21), dot-product initiation (23), and the D-Lanczos
solution update (24-32).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np


Apply = Callable[[np.ndarray], np.ndarray]


@dataclasses.dataclass
class RefResult:
    x: np.ndarray
    iters: int            # number of *solution* updates performed (CG-equivalent its)
    restarts: int
    converged: bool
    res_history: list     # recursive residual norms |zeta_j| (M-norm for prec.)
    true_res: float       # final true residual ||b - A x||_2


def _dot(a, b):
    return float(np.dot(a, b))


def classic_cg_reference(
    apply_a: Apply,
    b: np.ndarray,
    x0: Optional[np.ndarray] = None,
    prec: Optional[Apply] = None,
    tol: float = 1e-6,
    maxit: int = 1000,
) -> RefResult:
    """Textbook preconditioned CG (2 global reductions per iteration)."""
    n = b.shape[0]
    x = np.zeros(n) if x0 is None else x0.copy()
    minv = (lambda v: v) if prec is None else prec
    r = b - apply_a(x)
    u = minv(r)
    p = u.copy()
    gamma = _dot(r, u)
    norm0 = np.sqrt(gamma)
    hist = [norm0]
    converged = False
    it = 0
    for it in range(1, maxit + 1):
        s = apply_a(p)
        alpha = gamma / _dot(s, p)          # reduction 1
        x += alpha * p
        r -= alpha * s
        u = minv(r)
        gamma_new = _dot(r, u)              # reduction 2
        hist.append(np.sqrt(abs(gamma_new)))
        if np.sqrt(abs(gamma_new)) / norm0 < tol:
            converged = True
            break
        beta = gamma_new / gamma
        gamma = gamma_new
        p = u + beta * p
    return RefResult(x, it, 0, converged, hist, float(np.linalg.norm(b - apply_a(x))))


def ghysels_pcg_reference(
    apply_a: Apply,
    b: np.ndarray,
    x0: Optional[np.ndarray] = None,
    prec: Optional[Apply] = None,
    tol: float = 1e-6,
    maxit: int = 1000,
) -> RefResult:
    """Ghysels & Vanroose pipelined CG [19] (p-CG): 1 fused reduction + 1 SPMV
    per iteration; reduction overlaps the SPMV of the same iteration."""
    n = b.shape[0]
    x = np.zeros(n) if x0 is None else x0.copy()
    minv = (lambda v: v) if prec is None else prec
    r = b - apply_a(x)
    u = minv(r)
    w = apply_a(u)
    gamma_old, alpha = 0.0, 0.0
    z = q = s = p = np.zeros(n)
    norm0 = np.sqrt(_dot(r, u))
    hist = [norm0]
    converged = False
    it = 0
    for it in range(1, maxit + 1):
        gamma = _dot(r, u)
        delta = _dot(w, u)                  # fused single reduction {gamma, delta, ||r||}
        m = minv(w)                         # overlapped with the reduction
        nvec = apply_a(m)                   # overlapped with the reduction (the SPMV)
        if it > 1:
            beta = gamma / gamma_old
            alpha = gamma / (delta - beta * gamma / alpha)
        else:
            beta = 0.0
            alpha = gamma / delta
        z = nvec + beta * z
        q = m + beta * q
        s = w + beta * s
        p = u + beta * p
        x = x + alpha * p
        r = r - alpha * s
        u = u - alpha * q
        w = w - alpha * z
        gamma_old = gamma
        hist.append(np.sqrt(abs(_dot(r, minv(r)))))
        if hist[-1] / norm0 < tol:
            converged = True
            break
    return RefResult(x, it, 0, converged, hist, float(np.linalg.norm(b - apply_a(x))))


class SqrtBreakdown(Exception):
    pass


def pl_cg_reference(
    apply_a: Apply,
    b: np.ndarray,
    l: int,
    x0: Optional[np.ndarray] = None,
    prec: Optional[Apply] = None,
    tol: float = 1e-6,
    maxit: int = 1000,
    sigmas: Optional[np.ndarray] = None,
    max_restarts: int = 10,
) -> RefResult:
    """Alg. 1 (preconditioned p(l)-CG), full-storage NumPy oracle."""
    sig = np.zeros(l) if sigmas is None else np.asarray(sigmas, dtype=np.float64)
    assert sig.shape == (l,)
    n = b.shape[0]
    x_run = np.zeros(n) if x0 is None else np.asarray(x0, dtype=np.float64).copy()
    minv = (lambda v: v) if prec is None else prec

    hist: list = []
    total_updates = 0
    restarts = 0
    converged = False
    # Convergence is relative to the *original* residual M-norm, also across
    # breakdown restarts.
    r0 = minv(b - apply_a(x_run))
    norm0_orig = float(np.sqrt(np.dot(b - apply_a(x_run), r0)))

    while True:
        try:
            x_run, nupd, converged, sub_hist = _pl_cg_cycle(
                apply_a, b, l, x_run, minv, tol, max(maxit - total_updates, 1), sig,
                hist_prefix=hist, norm0_orig=norm0_orig,
            )
            hist = sub_hist
            total_updates += nupd
            break
        except SqrtBreakdown:
            restarts += 1
            # Explicit restart from the current iterate (paper §2.2).
            x_run, nupd, sub_hist = _pl_cg_partial_state
            total_updates += nupd
            hist = sub_hist
            if restarts > max_restarts:
                break
            continue
    return RefResult(
        x_run, total_updates, restarts, converged, hist,
        float(np.linalg.norm(b - apply_a(x_run))),
    )


_pl_cg_partial_state = None  # (x, nupd, hist) stashed when a breakdown fires


def _pl_cg_cycle(apply_a, b, l, x0, minv, tol, maxit, sig, hist_prefix, norm0_orig):
    """One p(l)-CG cycle (until convergence, breakdown, or maxit updates)."""
    global _pl_cg_partial_state
    n = b.shape[0]
    m = maxit
    mw = m + 2 * l + 4

    # Full storage of the l+1 auxiliary bases Z^(k), the unpreconditioned
    # vectors u_j, the Hessenberg entries, and the G matrix.
    Z = [dict() for _ in range(l + 1)]      # Z[k][j] -> vector z_j^(k)
    U = dict()
    G = np.zeros((mw, mw))
    gam = np.zeros(mw)
    dlt = np.zeros(mw)
    eta = np.zeros(mw)
    zet = np.zeros(mw)
    P = dict()

    x = x0.copy()
    # line 1
    u0_raw = b - apply_a(x)
    r0_raw = minv(u0_raw)
    eta0 = np.sqrt(_dot(u0_raw, r0_raw))
    norm0 = eta0
    hist = list(hist_prefix) + ([norm0] if not hist_prefix else [])
    if eta0 == 0.0:
        return x, 0, True, hist
    v0 = r0_raw / eta0
    for k in range(l + 1):
        Z[k][0] = v0.copy()
    U[0] = u0_raw / eta0
    G[0, 0] = 1.0

    nupd = 0
    converged = False
    for i in range(0, m + l + 1):
        # lines 3-4: SPMV + preconditioner
        az = apply_a(Z[l][i])
        u_new = az - sig[i] * U[i] if i < l else az
        U[i + 1] = u_new
        Z[l][i + 1] = minv(u_new)

        # lines 5-7: pipeline fill copies
        if i < l - 1:
            for k in range(i + 1, l):
                Z[k][i + 1] = Z[l][i + 1].copy()

        if i >= l:
            c = i - l + 1  # column being finalized
            # line 9: correct the Z-dot entries of column c
            for j in range(i - 2 * l + 2, i - l + 1):  # j = i-2l+2 .. i-l
                if j < 0:
                    continue
                ssum = 0.0
                for k in range(max(0, i - 3 * l + 1), j):
                    ssum += G[k, j] * G[k, c]
                G[j, c] = (G[j, c] - ssum) / G[j, j]
            # line 10: diagonal entry (Cholesky step)
            ssum = 0.0
            for k in range(max(0, i - 3 * l + 1), c):
                ssum += G[k, c] ** 2
            arg = G[c, c] - ssum
            # line 11: breakdown check
            if arg <= 0.0:
                _pl_cg_partial_state = (x.copy(), nupd, hist)
                raise SqrtBreakdown()
            G[c, c] = np.sqrt(arg)

            # lines 12-18: new Hessenberg column
            im = i - l
            g_im_im = G[im, im]
            g_im_ip = G[im, im + 1]
            g_prev = G[im - 1, im] if im >= 1 else 0.0
            d_prev = dlt[im - 1] if im >= 1 else 0.0
            if i < 2 * l:
                gam[im] = (g_im_ip + sig[im] * g_im_im - g_prev * d_prev) / g_im_im
                dlt[im] = G[im + 1, im + 1] / g_im_im
            else:
                gam[im] = (
                    g_im_im * gam[im - l] + g_im_ip * dlt[im - l] - g_prev * d_prev
                ) / g_im_im
                dlt[im] = (G[im + 1, im + 1] * dlt[im - l]) / g_im_im

            # lines 19-21: stable recurrences for all l+1 bases
            for k in range(l):  # line 19, k = 0..l-1
                j = i - l + k + 1
                zm1 = Z[k][j - 1]
                zm2 = Z[k][j - 2] if j >= 2 else np.zeros(n)
                d2 = dlt[im - 1] if im >= 1 else 0.0
                Z[k][j] = (
                    Z[k + 1][j] + (sig[k] - gam[im]) * zm1 - d2 * zm2
                ) / dlt[im]
            d2 = dlt[im - 1] if im >= 1 else 0.0
            zm2 = Z[l][i - 1] if i >= 1 else np.zeros(n)
            Z[l][i + 1] = (Z[l][i + 1] - gam[im] * Z[l][i] - d2 * zm2) / dlt[im]
            U[i + 1] = (U[i + 1] - gam[im] * U[i] - d2 * U[i - 1]) / dlt[im]

        # line 23: initiate the dot-product block of column i+1
        for j in range(max(0, i - 2 * l + 1), i - l + 2):
            if j < 0 or j not in Z[0]:
                continue
            G[j, i + 1] = _dot(U[i + 1], Z[0][j])
        for j in range(max(0, i - l + 2), i + 2):
            G[j, i + 1] = _dot(U[i + 1], Z[l][j])

        # lines 24-32: D-Lanczos solution update
        if i == l:
            eta[0] = gam[0]
            zet[0] = norm0
            P[0] = Z[0][0] / eta[0]
        elif i >= l + 1:
            im = i - l
            lam = dlt[im - 1] / eta[im - 1]
            eta[im] = gam[im] - lam * dlt[im - 1]
            zet[im] = -lam * zet[im - 1]
            P[im] = (Z[0][im] - dlt[im - 1] * P[im - 1]) / eta[im]
            x = x + zet[im - 1] * P[im - 1]
            nupd += 1
            hist.append(abs(zet[im]))
            if abs(zet[im]) / norm0_orig < tol:
                converged = True
                break
    return x, nupd, converged, hist
