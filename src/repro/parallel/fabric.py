"""Multi-node fabric launcher: typed process-group life cycle for
``jax.distributed`` jobs (DESIGN.md §17).

The multiprocess backend (``repro.parallel.backends.multiprocess``) is a
*multi-controller* substrate: every rank runs the same program and the
collectives — the staged hop ladder's tagged ppermutes included — block
until every peer participates.  That SPMD discipline has two failure
modes a CI fabric must convert into clean errors instead of hangs:

* **coordinator port collision** — ``jax.distributed.initialize`` binds
  a fixed TCP port; two jobs racing for the same port (parallel CI
  shards) make one of them die at startup.  :func:`launch_fabric`
  allocates a fresh ephemeral port per attempt and RETRIES the whole
  group when a child's output shows a bind failure.
* **peer death** — a rank that dies mid-solve leaves every other rank
  blocked inside a gloo/NCCL collective with no timeout of its own.
  The launcher polls the group; the moment any child exits nonzero it
  kills the survivors and raises :class:`FabricProcessError` (or
  :class:`FabricTimeoutError` when the wall-clock budget runs out) —
  the kill-one-process test in tests/test_fabric.py asserts the error
  arrives in seconds, not at the collective's 900 s budget.

The module is pure host-side process plumbing (subprocess + sockets, no
jax import) so it stays importable — and testable — on any container.
"""

from __future__ import annotations

import dataclasses
import socket
import subprocess
import time
from typing import Callable, Sequence

# Output fragments that identify a coordinator bind collision — the one
# startup failure that is retryable by construction (fresh port, same
# program).  Matched case-insensitively against a dead child's output.
BIND_COLLISION_MARKERS = (
    "address already in use",
    "failed to bind",
    "errno: 98",
    "bind address",
)


class FabricError(RuntimeError):
    """Base class for multi-process fabric failures."""


class FabricTimeoutError(FabricError):
    """The process group exceeded its wall-clock budget: at least one
    rank was still running (typically blocked inside a collective whose
    peer never arrived) when the launcher's watchdog fired.  Survivors
    are killed before this is raised — no orphan ranks."""


class FabricProcessError(FabricError):
    """A rank exited nonzero (or was killed) while its peers were still
    running.  The launcher kills the survivors — who would otherwise
    hang in their next collective waiting for the dead peer — and
    reports which rank failed plus the tail of every rank's output."""


@dataclasses.dataclass
class FabricResult:
    """Outputs of one successful fabric run."""

    outputs: list[str]            # per-rank combined stdout/stderr
    coordinator: str              # "host:port" the group actually used
    attempts: int                 # 1 + bind-collision retries


def free_port(host: str = "127.0.0.1") -> int:
    """One ephemeral port, currently free.  Inherently racy — another
    process may claim it before the coordinator binds — which is why
    :func:`launch_fabric` retries bind collisions instead of trusting
    this value."""
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def pick_coordinator(host: str = "127.0.0.1") -> str:
    return f"{host}:{free_port(host)}"


def _tail(text: str, n: int = 2000) -> str:
    return text[-n:] if len(text) > n else text


def _kill_all(procs: Sequence[subprocess.Popen]) -> list[str]:
    """Kill survivors and drain outputs.  Idempotent: the launcher's
    ``finally`` re-runs it after the error paths already have."""
    outs = []
    for p in procs:
        if p.poll() is None:
            p.kill()
        try:
            out, _ = p.communicate(timeout=30)
        except (subprocess.TimeoutExpired, ValueError, OSError):
            out = ""                # already drained / stream closed
        outs.append(out or "")
    return outs


def _looks_like_bind_collision(output: str) -> bool:
    low = output.lower()
    return any(m in low for m in BIND_COLLISION_MARKERS)


def launch_fabric(
    child_argv: Callable[[str, int], list[str]],
    num_processes: int,
    *,
    env: dict | None = None,
    timeout_s: float = 900.0,
    poll_s: float = 0.2,
    max_port_retries: int = 3,
    host: str = "127.0.0.1",
) -> FabricResult:
    """Run one multi-controller process group to completion.

    ``child_argv(coordinator, process_id)`` builds rank k's argv; every
    rank is spawned with the same ``env`` (stdout+stderr merged, text
    mode).  The launcher then supervises:

    * all ranks exit 0 → :class:`FabricResult` with per-rank outputs;
    * any rank exits nonzero → survivors killed; if the dead rank's
      output shows a coordinator bind collision
      (``BIND_COLLISION_MARKERS``) the whole group relaunches on a
      fresh port, up to ``max_port_retries`` times; otherwise
      :class:`FabricProcessError`;
    * ``timeout_s`` elapses → survivors killed, :class:`FabricTimeoutError`.

    The watchdog property under test in tests/test_fabric.py: killing
    one rank mid-run produces a typed error within ~``poll_s`` of the
    death, never a hang at the full ``timeout_s``.
    """
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    last_outputs: list[str] = []
    for attempt in range(1, max_port_retries + 2):
        coordinator = pick_coordinator(host)
        procs = [
            subprocess.Popen(
                child_argv(coordinator, k), env=env,
                stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            )
            for k in range(num_processes)
        ]
        deadline = time.monotonic() + timeout_s
        try:
            while True:
                codes = [p.poll() for p in procs]
                if all(c == 0 for c in codes):
                    outs = [p.communicate()[0] or "" for p in procs]
                    return FabricResult(outputs=outs,
                                        coordinator=coordinator,
                                        attempts=attempt)
                dead = [(k, c) for k, c in enumerate(codes)
                        if c is not None and c != 0]
                if dead:
                    outs = _kill_all(procs)
                    last_outputs = outs
                    k0, c0 = dead[0]
                    if _looks_like_bind_collision(outs[k0]):
                        # Relaunch the group on a fresh port; when this
                        # was the last allowed attempt the for-loop ends
                        # and the persisted-collision error below fires.
                        break
                    detail = "\n".join(
                        f"--- rank {k} (exit {p.poll()}) ---\n"
                        f"{_tail(outs[k])}"
                        for k, p in enumerate(procs))
                    raise FabricProcessError(
                        f"rank {k0} of {num_processes} exited {c0} while "
                        f"peers were running (coordinator {coordinator}); "
                        f"survivors killed to avoid a collective hang\n"
                        f"{detail}")
                if time.monotonic() > deadline:
                    outs = _kill_all(procs)
                    running = [k for k, c in enumerate(codes) if c is None]
                    raise FabricTimeoutError(
                        f"fabric of {num_processes} rank(s) exceeded "
                        f"{timeout_s:.0f}s (ranks {running} still running, "
                        f"coordinator {coordinator}); group killed\n"
                        + "\n".join(f"--- rank {k} ---\n{_tail(o)}"
                                    for k, o in enumerate(outs)))
                time.sleep(poll_s)
        finally:
            _kill_all(procs)
    raise FabricProcessError(
        f"coordinator bind collision persisted through "
        f"{max_port_retries} port retries\n"
        + "\n".join(f"--- rank {k} ---\n{_tail(o)}"
                    for k, o in enumerate(last_outputs)))
