"""Multi-node fabric launcher: typed process-group life cycle for
``jax.distributed`` jobs (DESIGN.md §17).

The multiprocess backend (``repro.parallel.backends.multiprocess``) is a
*multi-controller* substrate: every rank runs the same program and the
collectives — the staged hop ladder's tagged ppermutes included — block
until every peer participates.  That SPMD discipline has two failure
modes a CI fabric must convert into clean errors instead of hangs:

* **coordinator port collision** — ``jax.distributed.initialize`` binds
  a fixed TCP port; two jobs racing for the same port (parallel CI
  shards) make one of them die at startup.  :func:`launch_fabric`
  allocates a fresh ephemeral port per attempt and RETRIES the whole
  group when a child's output shows a bind failure.
* **peer death** — a rank that dies mid-solve leaves every other rank
  blocked inside a gloo/NCCL collective with no timeout of its own.
  The launcher polls the group; the moment any child exits nonzero it
  kills the survivors and raises :class:`FabricProcessError` (or
  :class:`FabricTimeoutError` when the wall-clock budget runs out) —
  the kill-one-process test in tests/test_fabric.py asserts the error
  arrives in seconds, not at the collective's 900 s budget.

The module is pure host-side process plumbing (subprocess + sockets, no
jax import) so it stays importable — and testable — on any container.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import socket
import subprocess
import tempfile
import time
from typing import Callable, Sequence

# Output fragments that identify a coordinator bind collision — the one
# startup failure that is retryable by construction (fresh port, same
# program).  Matched case-insensitively against a dead child's output.
BIND_COLLISION_MARKERS = (
    "address already in use",
    "failed to bind",
    "errno: 98",
    "bind address",
)

# Env var carrying rank k's heartbeat file path.  The launcher sets it
# per rank; children call :func:`touch_heartbeat` at progress points
# (startup, per solve chunk) so the error messages can distinguish a
# WEDGED rank (alive but silent — e.g. blocked in a collective) from a
# dead or merely slow one by the age of its last heartbeat.
ENV_HEARTBEAT = "REPRO_FABRIC_HEARTBEAT"


def touch_heartbeat(environ=None) -> str | None:
    """Child-side progress marker: touch the heartbeat file the launcher
    assigned this rank (``ENV_HEARTBEAT``).  No-op (returns None) when
    running outside a fabric; cheap enough to call per chunk."""
    env = os.environ if environ is None else environ
    path = env.get(ENV_HEARTBEAT)
    if not path:
        return None
    with open(path, "a"):
        os.utime(path, None)
    return path


def _heartbeat_age(path: str | None, now: float, spawned: float) -> float:
    """Seconds since the rank last touched its heartbeat file; falls back
    to time-since-spawn when the rank never touched it."""
    if path:
        try:
            return max(now - os.path.getmtime(path), 0.0)
        except OSError:
            pass
    return max(now - spawned, 0.0)


def _rank_status(code: int | None, hb_age: float, wedge_after_s: float
                 ) -> str:
    """One human line per rank: exit status + heartbeat age.  ``wedged``
    means alive but heartbeat-silent past the threshold — the signature
    of a rank blocked in a collective whose peer died."""
    if code is None:
        state = "wedged" if hb_age > wedge_after_s else "running"
    else:
        state = f"exit {code}"
    return f"{state}, last heartbeat {hb_age:.1f}s ago"


class FabricError(RuntimeError):
    """Base class for multi-process fabric failures."""


class FabricTimeoutError(FabricError):
    """The process group exceeded its wall-clock budget: at least one
    rank was still running (typically blocked inside a collective whose
    peer never arrived) when the launcher's watchdog fired.  Survivors
    are killed before this is raised — no orphan ranks."""


class FabricProcessError(FabricError):
    """A rank exited nonzero (or was killed) while its peers were still
    running.  The launcher kills the survivors — who would otherwise
    hang in their next collective waiting for the dead peer — and
    reports which rank failed plus the tail of every rank's output."""


@dataclasses.dataclass
class FabricResult:
    """Outputs of one successful fabric run."""

    outputs: list[str]            # per-rank combined stdout/stderr
    coordinator: str              # "host:port" the group actually used
    attempts: int                 # 1 + bind-collision retries


def free_port(host: str = "127.0.0.1") -> int:
    """One ephemeral port, currently free.  Inherently racy — another
    process may claim it before the coordinator binds — which is why
    :func:`launch_fabric` retries bind collisions instead of trusting
    this value."""
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def pick_coordinator(host: str = "127.0.0.1") -> str:
    return f"{host}:{free_port(host)}"


def _tail(text: str, n: int = 2000) -> str:
    return text[-n:] if len(text) > n else text


def _kill_all(procs: Sequence[subprocess.Popen]) -> list[str]:
    """Kill survivors and drain outputs.  Idempotent: the launcher's
    ``finally`` re-runs it after the error paths already have."""
    outs = []
    for p in procs:
        if p.poll() is None:
            p.kill()
        try:
            out, _ = p.communicate(timeout=30)
        except (subprocess.TimeoutExpired, ValueError, OSError):
            out = ""                # already drained / stream closed
        outs.append(out or "")
    return outs


def _looks_like_bind_collision(output: str) -> bool:
    low = output.lower()
    return any(m in low for m in BIND_COLLISION_MARKERS)


def launch_fabric(
    child_argv: Callable[[str, int], list[str]],
    num_processes: int,
    *,
    env: dict | None = None,
    timeout_s: float = 900.0,
    poll_s: float = 0.2,
    max_port_retries: int = 3,
    host: str = "127.0.0.1",
    wedge_after_s: float = 5.0,
) -> FabricResult:
    """Run one multi-controller process group to completion.

    ``child_argv(coordinator, process_id)`` builds rank k's argv; every
    rank is spawned with ``env`` (default: the launcher's environment)
    plus a per-rank ``ENV_HEARTBEAT`` file path (stdout+stderr merged,
    text mode).  Children that call :func:`touch_heartbeat` at progress
    points get per-rank "last heartbeat N s ago" lines in every fabric
    error — a surviving rank whose heartbeat is older than
    ``wedge_after_s`` is reported ``wedged`` (alive but stuck, the
    blocked-collective signature) rather than merely ``running``.
    The launcher supervises:

    * all ranks exit 0 → :class:`FabricResult` with per-rank outputs;
    * any rank exits nonzero → survivors killed; if the dead rank's
      output shows a coordinator bind collision
      (``BIND_COLLISION_MARKERS``) the whole group relaunches on a
      fresh port, up to ``max_port_retries`` times; otherwise
      :class:`FabricProcessError`;
    * ``timeout_s`` elapses → survivors killed, :class:`FabricTimeoutError`.

    The watchdog property under test in tests/test_fabric.py: killing
    one rank mid-run produces a typed error within ~``poll_s`` of the
    death, never a hang at the full ``timeout_s``.
    """
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    last_outputs: list[str] = []
    base_env = dict(os.environ if env is None else env)
    for attempt in range(1, max_port_retries + 2):
        coordinator = pick_coordinator(host)
        hb_dir = tempfile.mkdtemp(prefix="repro-fabric-hb-")
        hb_paths = [os.path.join(hb_dir, f"rank{k}.hb")
                    for k in range(num_processes)]
        spawned = time.time()
        procs = [
            subprocess.Popen(
                child_argv(coordinator, k),
                env={**base_env, ENV_HEARTBEAT: hb_paths[k]},
                stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            )
            for k in range(num_processes)
        ]
        deadline = time.monotonic() + timeout_s

        def statuses(codes):
            now = time.time()
            return [
                _rank_status(codes[k],
                             _heartbeat_age(hb_paths[k], now, spawned),
                             wedge_after_s)
                for k in range(num_processes)
            ]

        try:
            while True:
                codes = [p.poll() for p in procs]
                if all(c == 0 for c in codes):
                    outs = [p.communicate()[0] or "" for p in procs]
                    return FabricResult(outputs=outs,
                                        coordinator=coordinator,
                                        attempts=attempt)
                dead = [(k, c) for k, c in enumerate(codes)
                        if c is not None and c != 0]
                if dead:
                    # Snapshot status BEFORE killing survivors: the exit
                    # codes and heartbeat ages at detection time are the
                    # diagnosis, not the post-kill wreckage.
                    stat = statuses(codes)
                    outs = _kill_all(procs)
                    last_outputs = outs
                    k0, c0 = dead[0]
                    if _looks_like_bind_collision(outs[k0]):
                        # Relaunch the group on a fresh port; when this
                        # was the last allowed attempt the for-loop ends
                        # and the persisted-collision error below fires.
                        break
                    detail = "\n".join(
                        f"--- rank {k} ({stat[k]}) ---\n{_tail(outs[k])}"
                        for k in range(num_processes))
                    raise FabricProcessError(
                        f"rank {k0} of {num_processes} exited {c0} while "
                        f"peers were running (coordinator {coordinator}); "
                        f"survivors killed to avoid a collective hang\n"
                        f"{detail}")
                if time.monotonic() > deadline:
                    stat = statuses(codes)
                    outs = _kill_all(procs)
                    running = [k for k, c in enumerate(codes) if c is None]
                    raise FabricTimeoutError(
                        f"fabric of {num_processes} rank(s) exceeded "
                        f"{timeout_s:.0f}s (ranks {running} still running, "
                        f"coordinator {coordinator}); group killed\n"
                        + "\n".join(
                            f"--- rank {k} ({stat[k]}) ---\n{_tail(o)}"
                            for k, o in enumerate(outs)))
                time.sleep(poll_s)
        finally:
            _kill_all(procs)
            shutil.rmtree(hb_dir, ignore_errors=True)
    raise FabricProcessError(
        f"coordinator bind collision persisted through "
        f"{max_port_retries} port retries\n"
        + "\n".join(f"--- rank {k} ---\n{_tail(o)}"
                    for k, o in enumerate(last_outputs)))
