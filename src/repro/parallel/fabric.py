"""Multi-node fabric launcher: typed process-group life cycle for
``jax.distributed`` jobs (DESIGN.md §17).

The multiprocess backend (``repro.parallel.backends.multiprocess``) is a
*multi-controller* substrate: every rank runs the same program and the
collectives — the staged hop ladder's tagged ppermutes included — block
until every peer participates.  That SPMD discipline has two failure
modes a CI fabric must convert into clean errors instead of hangs:

* **coordinator port collision** — ``jax.distributed.initialize`` binds
  a fixed TCP port; two jobs racing for the same port (parallel CI
  shards) make one of them die at startup.  :func:`launch_fabric`
  allocates a fresh ephemeral port per attempt and RETRIES the whole
  group when a child's output shows a bind failure.
* **peer death** — a rank that dies mid-solve leaves every other rank
  blocked inside a gloo/NCCL collective with no timeout of its own.
  The launcher polls the group; the moment any child exits nonzero it
  kills the survivors and raises :class:`FabricProcessError` (or
  :class:`FabricTimeoutError` when the wall-clock budget runs out) —
  the kill-one-process test in tests/test_fabric.py asserts the error
  arrives in seconds, not at the collective's 900 s budget.

The module is pure host-side process plumbing (subprocess + sockets, no
jax import) so it stays importable — and testable — on any container.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import signal
import socket
import subprocess
import tempfile
import time
from typing import Callable, Sequence

# Output fragments that identify a coordinator bind collision — the one
# startup failure that is retryable by construction (fresh port, same
# program).  Matched case-insensitively against a dead child's output.
BIND_COLLISION_MARKERS = (
    "address already in use",
    "failed to bind",
    "errno: 98",
    "bind address",
)

# Env var carrying rank k's heartbeat file path.  The launcher sets it
# per rank; children call :func:`touch_heartbeat` at progress points
# (startup, per solve chunk) so the error messages can distinguish a
# WEDGED rank (alive but silent — e.g. blocked in a collective) from a
# dead or merely slow one by the age of its last heartbeat.
ENV_HEARTBEAT = "REPRO_FABRIC_HEARTBEAT"

# Exit status a rank reports when it shut down cleanly on the launcher's
# SIGTERM (128 + SIGTERM, the shell convention) — distinct from a crash
# and from SIGKILL's 137, so post-mortems can tell "peer died, launcher
# tore me down gracefully" from "I am the one that died".
SIGTERM_EXIT_CODE = 143


def install_sigterm_handler(*flushes: Callable[[], None],
                            exit_code: int = SIGTERM_EXIT_CODE) -> None:
    """Child-side graceful-teardown hook: on SIGTERM, run the ``flushes``
    (telemetry ring dumps, ``Timeline.save`` closures, ...) then exit
    with ``exit_code``.

    The launcher's :func:`_kill_all` sends SIGTERM first and escalates
    to SIGKILL after a grace period — installing this handler is what
    turns a survivor's teardown from hard data loss into a flushed,
    distinct-status exit (DESIGN.md §19).  Flush errors are swallowed:
    a failing flush must not block the group teardown.
    """

    def _on_term(signum, frame):
        for fn in flushes:
            try:
                fn()
            except Exception:
                pass
        os._exit(exit_code)

    signal.signal(signal.SIGTERM, _on_term)


def touch_heartbeat(environ=None) -> str | None:
    """Child-side progress marker: touch the heartbeat file the launcher
    assigned this rank (``ENV_HEARTBEAT``).  No-op (returns None) when
    running outside a fabric; cheap enough to call per chunk."""
    env = os.environ if environ is None else environ
    path = env.get(ENV_HEARTBEAT)
    if not path:
        return None
    with open(path, "a"):
        os.utime(path, None)
    return path


def _heartbeat_age(path: str | None, now: float, spawned: float) -> float:
    """Seconds since the rank last touched its heartbeat file; falls back
    to time-since-spawn when the rank never touched it."""
    if path:
        try:
            return max(now - os.path.getmtime(path), 0.0)
        except OSError:
            pass
    return max(now - spawned, 0.0)


def _rank_status(code: int | None, hb_age: float, wedge_after_s: float
                 ) -> str:
    """One human line per rank: exit status + heartbeat age.  ``wedged``
    means alive but heartbeat-silent past the threshold — the signature
    of a rank blocked in a collective whose peer died."""
    if code is None:
        state = "wedged" if hb_age > wedge_after_s else "running"
    else:
        state = f"exit {code}"
    return f"{state}, last heartbeat {hb_age:.1f}s ago"


class FabricError(RuntimeError):
    """Base class for multi-process fabric failures."""


class FabricTimeoutError(FabricError):
    """The process group exceeded its wall-clock budget: at least one
    rank was still running (typically blocked inside a collective whose
    peer never arrived) when the launcher's watchdog fired.  Survivors
    are killed before this is raised — no orphan ranks."""


class FabricProcessError(FabricError):
    """A rank exited nonzero (or was killed) while its peers were still
    running.  The launcher kills the survivors — who would otherwise
    hang in their next collective waiting for the dead peer — and
    reports which rank failed plus the tail of every rank's output."""


@dataclasses.dataclass
class FabricResult:
    """Outputs of one successful fabric run."""

    outputs: list[str]            # per-rank combined stdout/stderr
    coordinator: str              # "host:port" the group actually used
    attempts: int                 # 1 + bind-collision retries


def free_port(host: str = "127.0.0.1") -> int:
    """One ephemeral port, currently free.  Inherently racy — another
    process may claim it before the coordinator binds — which is why
    :func:`launch_fabric` retries bind collisions instead of trusting
    this value."""
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def pick_coordinator(host: str = "127.0.0.1") -> str:
    return f"{host}:{free_port(host)}"


def _tail(text: str, n: int = 2000) -> str:
    return text[-n:] if len(text) > n else text


def _kill_all(procs: Sequence[subprocess.Popen],
              grace_s: float = 2.0) -> list[str]:
    """Tear down survivors and drain outputs: SIGTERM every live rank
    (letting :func:`install_sigterm_handler` flush telemetry/timeline
    buffers and exit with a distinct status), then escalate to SIGKILL
    for whoever is still alive after ``grace_s``.  Idempotent: the
    launcher's ``finally`` re-runs it after the error paths already
    have."""
    live = [p for p in procs if p.poll() is None]
    for p in live:
        try:
            p.terminate()
        except OSError:
            pass
    deadline = time.monotonic() + max(grace_s, 0.0)
    while live and time.monotonic() < deadline:
        live = [p for p in live if p.poll() is None]
        if live:
            time.sleep(0.05)
    for p in live:
        try:
            p.kill()
        except OSError:
            pass
    outs = []
    for p in procs:
        try:
            out, _ = p.communicate(timeout=30)
        except (subprocess.TimeoutExpired, ValueError, OSError):
            out = ""                # already drained / stream closed
        outs.append(out or "")
    return outs


def _looks_like_bind_collision(output: str) -> bool:
    low = output.lower()
    return any(m in low for m in BIND_COLLISION_MARKERS)


def launch_fabric(
    child_argv: Callable[[str, int], list[str]],
    num_processes: int,
    *,
    env: dict | None = None,
    timeout_s: float = 900.0,
    poll_s: float = 0.2,
    max_port_retries: int = 3,
    host: str = "127.0.0.1",
    wedge_after_s: float = 5.0,
    term_grace_s: float = 2.0,
) -> FabricResult:
    """Run one multi-controller process group to completion.

    ``child_argv(coordinator, process_id)`` builds rank k's argv; every
    rank is spawned with ``env`` (default: the launcher's environment)
    plus a per-rank ``ENV_HEARTBEAT`` file path (stdout+stderr merged,
    text mode).  Children that call :func:`touch_heartbeat` at progress
    points get per-rank "last heartbeat N s ago" lines in every fabric
    error — a surviving rank whose heartbeat is older than
    ``wedge_after_s`` is reported ``wedged`` (alive but stuck, the
    blocked-collective signature) rather than merely ``running``.
    The launcher supervises:

    * all ranks exit 0 → :class:`FabricResult` with per-rank outputs;
    * any rank exits nonzero → survivors killed; if the dead rank's
      output shows a coordinator bind collision
      (``BIND_COLLISION_MARKERS``) the whole group relaunches on a
      fresh port, up to ``max_port_retries`` times; otherwise
      :class:`FabricProcessError`;
    * ``timeout_s`` elapses → survivors killed, :class:`FabricTimeoutError`.

    The watchdog property under test in tests/test_fabric.py: killing
    one rank mid-run produces a typed error within ~``poll_s`` of the
    death, never a hang at the full ``timeout_s``.
    """
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    last_outputs: list[str] = []
    base_env = dict(os.environ if env is None else env)
    for attempt in range(1, max_port_retries + 2):
        coordinator = pick_coordinator(host)
        hb_dir = tempfile.mkdtemp(prefix="repro-fabric-hb-")
        hb_paths = [os.path.join(hb_dir, f"rank{k}.hb")
                    for k in range(num_processes)]
        spawned = time.time()
        procs = [
            subprocess.Popen(
                child_argv(coordinator, k),
                env={**base_env, ENV_HEARTBEAT: hb_paths[k]},
                stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            )
            for k in range(num_processes)
        ]
        deadline = time.monotonic() + timeout_s

        def statuses(codes):
            now = time.time()
            return [
                _rank_status(codes[k],
                             _heartbeat_age(hb_paths[k], now, spawned),
                             wedge_after_s)
                for k in range(num_processes)
            ]

        try:
            while True:
                codes = [p.poll() for p in procs]
                if all(c == 0 for c in codes):
                    outs = [p.communicate()[0] or "" for p in procs]
                    return FabricResult(outputs=outs,
                                        coordinator=coordinator,
                                        attempts=attempt)
                dead = [(k, c) for k, c in enumerate(codes)
                        if c is not None and c != 0]
                if dead:
                    # Snapshot status BEFORE killing survivors: the exit
                    # codes and heartbeat ages at detection time are the
                    # diagnosis, not the post-kill wreckage.
                    stat = statuses(codes)
                    outs = _kill_all(procs, term_grace_s)
                    last_outputs = outs
                    k0, c0 = dead[0]
                    if _looks_like_bind_collision(outs[k0]):
                        # Relaunch the group on a fresh port; when this
                        # was the last allowed attempt the for-loop ends
                        # and the persisted-collision error below fires.
                        break
                    detail = "\n".join(
                        f"--- rank {k} ({stat[k]}) ---\n{_tail(outs[k])}"
                        for k in range(num_processes))
                    err = FabricProcessError(
                        f"rank {k0} of {num_processes} exited {c0} while "
                        f"peers were running (coordinator {coordinator}); "
                        f"survivors killed to avoid a collective hang\n"
                        f"{detail}")
                    # Full (undisplayed) outputs ride on the error so a
                    # recovery supervisor can harvest child markers —
                    # checkpoint paths, kill iterations — post-mortem.
                    err.outputs = outs
                    err.failed_rank = k0
                    raise err
                if time.monotonic() > deadline:
                    stat = statuses(codes)
                    outs = _kill_all(procs, term_grace_s)
                    running = [k for k, c in enumerate(codes) if c is None]
                    err = FabricTimeoutError(
                        f"fabric of {num_processes} rank(s) exceeded "
                        f"{timeout_s:.0f}s (ranks {running} still running, "
                        f"coordinator {coordinator}); group killed\n"
                        + "\n".join(
                            f"--- rank {k} ({stat[k]}) ---\n{_tail(o)}"
                            for k, o in enumerate(outs)))
                    err.outputs = outs
                    err.failed_rank = running[0] if running else None
                    raise err
                time.sleep(poll_s)
        finally:
            _kill_all(procs, term_grace_s)
            shutil.rmtree(hb_dir, ignore_errors=True)
    raise FabricProcessError(
        f"coordinator bind collision persisted through "
        f"{max_port_retries} port retries\n"
        + "\n".join(f"--- rank {k} ---\n{_tail(o)}"
                    for k, o in enumerate(last_outputs)))


@dataclasses.dataclass
class RecoveryResult:
    """Outcome of a :func:`run_resilient` supervision."""

    result: FabricResult          # the attempt that completed
    attempts: int                 # fabric launches, including the last
    failures: list[FabricError]   # one per failed attempt, in order
    procs_per_attempt: list[int]  # group size of each attempt


def run_resilient(
    child_argv: Callable[[str, int, int, int], list[str]],
    num_processes: int,
    *,
    max_failures: int = 1,
    shrink: bool = False,
    min_processes: int = 1,
    env: dict | None = None,
    attempt_env: Callable[[int], dict] | None = None,
    **launch_kw,
) -> RecoveryResult:
    """Elastic fabric supervisor (DESIGN.md §19 recovery state machine).

    Runs ``launch_fabric`` and, on :class:`FabricProcessError` /
    :class:`FabricTimeoutError` (a dead or wedged rank — survivors are
    already torn down by the launcher), RESPAWNS a fresh process group:
    a new coordinator port, new gloo/NCCL rendezvous, and — because each
    child rebuilds its backend from the operator — a fresh partition of
    the problem via the existing ``PartitionPlan`` machinery.  Children
    that checkpoint (``CheckpointConfig(..., resume=True)`` on a shared
    directory) resume the solve from the last snapshot instead of from
    zero; ``multiprocess_parity.py --recovery`` is the end-to-end drill.

    ``child_argv(coordinator, process_id, num_processes, attempt)``
    builds rank k's argv — the extended signature (vs ``launch_fabric``)
    is what lets a shrunk regroup tell its children the new world size.
    ``shrink=True`` drops one rank per failure (never below
    ``min_processes``) — elastic downsizing for hardware that stays
    dead.  ``attempt_env(attempt)`` merges attempt-specific variables
    (e.g. a chaos plan armed only on the first attempt) over ``env``.
    Exhausting ``max_failures`` re-raises the last fabric error.
    """
    failures: list[FabricError] = []
    procs_hist: list[int] = []
    procs = num_processes
    for attempt in range(1, max_failures + 2):
        procs_hist.append(procs)
        aenv = dict(os.environ if env is None else env)
        if attempt_env is not None:
            aenv.update(attempt_env(attempt))
        p, a = procs, attempt

        def argv(coordinator: str, k: int, _p=p, _a=a) -> list[str]:
            return child_argv(coordinator, k, _p, _a)

        try:
            result = launch_fabric(argv, procs, env=aenv, **launch_kw)
            return RecoveryResult(result=result, attempts=attempt,
                                  failures=failures,
                                  procs_per_attempt=procs_hist)
        except (FabricProcessError, FabricTimeoutError) as e:
            failures.append(e)
            if attempt > max_failures:
                raise
            if shrink and procs > min_processes:
                procs -= 1
    raise AssertionError("unreachable")
