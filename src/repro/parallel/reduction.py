"""Staged ring reduction: the fused dot block as an explicit ladder of
``lax.ppermute`` hops the SOLVER advances (DESIGN.md §14).

The monolithic path hands the (2l+1, s) dot-block payload to one
``lax.psum`` and hopes XLA's scheduler hoists it across the l-iteration
in-flight window.  The paper's mechanism is stronger than a hope: the
global reduction may take up to l iterations to complete
(arXiv:1801.04728), and the Cori runs (arXiv:1905.06850) win by
staggering reduction *phases* against SPMV and halo traffic.  This
module makes the reduction's progress structural:

  * ``staged_start``   — local partials parked in a (P, K, ...) gather
                         buffer, own slot filled (the MPI_Iallreduce
                         post; no wire traffic yet);
  * ``staged_advance`` — ONE ladder step: the scheduled ring hops of
                         that step move neighbour partials one shard
                         around the ring (``REDUCE_TAG``-tagged
                         ``ppermute``; interleaves with ``HALO_TAG``
                         traffic inside the open window);
  * ``staged_wait``    — run whatever steps the solver has not yet
                         advanced, then reduce the gathered partials IN
                         RANK ORDER (the MPI_Wait + combine).

The ladder is a ring ALLGATHER of raw per-shard partials — the P-1 hops
only move data; all arithmetic happens at the wait, summing the P
partials in ascending shard order.  Two properties fall out:

1.  **Stage-count invariance.**  ``stages`` only groups the P-1 hops
    into advance steps (scheduling); the summation the wait performs is
    identical for every stage count, so residual histories are bitwise
    identical across ladder configurations.
2.  **Monolithic parity.**  The rank-ordered sum reproduces the
    deterministic linear reduction order of XLA's CPU all-reduce, so
    staged and monolithic runs agree BITWISE on stencil operators
    (asserted in tests/test_distributed.py; FEM meshes follow the PR 3
    tight-head/bounded-tail convention because their local partials
    already differ at ULP level between substrates).

Mixed precision (``payload_dtype=jnp.float32``): partials are rounded to
fp32 *once* at the start site — every wire hop then carries half the
bytes — and the wait accumulates the gathered fp32 partials into an
fp64 compensated (Kahan) sum, so the squashed-payload error stays at
one fp32 rounding per shard partial instead of growing with P
(DESIGN.md §14 error bound; bounded-tail parity in
tests/test_reduction.py / test_distributed.py).

The local backend runs the same arithmetic as an eager *ladder oracle*
(``oracle_solver_ops``): the vector is split into ``virtual_shards``
contiguous slices whose partials fill the gather buffer directly — no
wire, identical summation tree — which makes a single-device run the
bitwise reference for a staged mesh run of the same shard count.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.types import REDUCE_TAG, dot_block_rows


class ReductionFallbackWarning(UserWarning):
    """A backend silently CANNOT run the requested staged ring ladder and
    downgraded to the monolithic all-reduce.  Arithmetic is still honoured
    — only the overlap mechanism is lost — but a scaling study run under
    this warning is not measuring what it thinks it is, hence a real
    warning (and a ``backend_reduction_fallback`` gauge on the default
    metrics registry) rather than just an attribute."""


@dataclasses.dataclass(frozen=True)
class StagedConfig:
    """Shape of one staged ring reduction.

    ``n_shards`` is the ring size P; ``stages`` groups the P-1 allgather
    hops into that many advance steps (``hop_groups``); ``payload_dtype``
    is the wire dtype (None = the solver dtype); a payload narrower than
    the solver dtype switches the wait to fp64 compensated accumulation.
    ``axis`` is the mesh axis name (None = the local eager oracle).
    """

    n_shards: int
    stages: int = 2
    payload_dtype: Any = None
    axis: str | None = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not (1 <= self.stages <= max(self.n_shards - 1, 1)):
            raise ValueError(
                f"stages must be in [1, {max(self.n_shards - 1, 1)}] "
                f"for {self.n_shards} shards, got {self.stages}")

    @property
    def n_hops(self) -> int:
        """Wire hops of one reduction: the P-1 ring-allgather permutes."""
        return self.n_shards - 1

    def wire_dtype(self, solver_dtype) -> Any:
        return solver_dtype if self.payload_dtype is None \
            else jnp.dtype(self.payload_dtype)

    def compensated(self, solver_dtype) -> bool:
        """fp64-compensated wait accumulation when the wire narrows."""
        wire = self.wire_dtype(solver_dtype)
        return jnp.dtype(wire).itemsize < jnp.dtype(solver_dtype).itemsize


def hop_groups(n_shards: int, stages: int) -> list[list[int]]:
    """Partition the ring's ``n_shards - 1`` hop indices into ``stages``
    contiguous advance steps, earlier steps no smaller than later ones
    (ceil-split) so the ladder front-loads while the window is widest."""
    n_hops = n_shards - 1
    groups: list[list[int]] = []
    start = 0
    for step in range(stages):
        size = math.ceil((n_hops - start) / (stages - step))
        groups.append(list(range(start, start + size)))
        start += size
    assert start == n_hops, (n_shards, stages, groups)
    return groups


# --------------------------------------------------------------------------
# Distributed ladder (inside shard_map).
# --------------------------------------------------------------------------

def staged_start(partials: jax.Array, cfg: StagedConfig) -> jax.Array:
    """Park this shard's dot-block partials in a fresh gather buffer.

    ``partials`` is the local (K,)/(K, s) contribution; the handle is a
    (P, K[, s]) buffer in the wire dtype with the own-rank slot filled —
    the posted-but-unprogressed Iallreduce.  No collective is issued
    here: the wire traffic is the solver-advanced hops.
    """
    wire = cfg.wire_dtype(partials.dtype)
    buf = jnp.zeros((cfg.n_shards,) + partials.shape, wire)
    r = lax.axis_index(cfg.axis)
    return lax.dynamic_update_index_in_dim(
        buf, partials.astype(wire), r, axis=0)


def staged_advance(handle: jax.Array, step: int,
                   cfg: StagedConfig) -> jax.Array:
    """Run advance step ``step`` of the ladder: its scheduled ring hops.

    Hop k forwards the partial received k hops ago one shard up the ring
    and files it under its origin rank, so after all P-1 hops every shard
    holds every partial.  Each hop is one ``ppermute`` in a
    ``REDUCE_TAG{k}`` scope — the unit the overlap tracer counts and the
    thing that interleaves with HALO_TAG traffic in the schedule.
    Steps outside the ladder (``step >= stages``) are a no-op so solvers
    can advance unconditionally at every pipeline age.
    """
    if step >= cfg.stages or cfg.n_shards == 1:
        return handle
    p = cfg.n_shards
    ring = [(i, (i + 1) % p) for i in range(p)]
    r = lax.axis_index(cfg.axis)
    for k in hop_groups(p, cfg.stages)[step]:
        with jax.named_scope(f"{REDUCE_TAG}{k}"):
            send = lax.dynamic_index_in_dim(
                handle, jnp.mod(r - k, p), axis=0, keepdims=False)
            recv = lax.ppermute(send, cfg.axis, ring)
            handle = lax.dynamic_update_index_in_dim(
                handle, recv, jnp.mod(r - k - 1, p), axis=0)
    return handle


def ordered_reduce(gathered: jax.Array, out_dtype,
                   compensated: bool) -> jax.Array:
    """Sum the (P, K[, s]) gathered partials over shard rank 0..P-1.

    The explicit rank-ascending add chain is the determinism anchor: it
    is the same order on every shard (all shards hold identical buffers
    after the allgather), the same order the eager local oracle uses,
    and — measured, tests/test_reduction.py — the order XLA's CPU
    all-reduce applies, which is what makes staged-vs-monolithic stencil
    histories bitwise.  ``compensated`` switches to Kahan accumulation
    in ``out_dtype`` (the fp32-payload path: one compensated fp64 sum of
    P fp32 partials, DESIGN.md §14).
    """
    if not compensated:
        acc = gathered[0].astype(out_dtype)
        for k in range(1, gathered.shape[0]):
            acc = acc + gathered[k].astype(out_dtype)
        return acc
    acc = jnp.zeros(gathered.shape[1:], out_dtype)
    comp = jnp.zeros(gathered.shape[1:], out_dtype)
    for k in range(gathered.shape[0]):
        y = gathered[k].astype(out_dtype) - comp
        t = acc + y
        comp = (t - acc) - y
        acc = t
    return acc


def staged_wait(handle: jax.Array, advanced: int, cfg: StagedConfig,
                out_dtype) -> jax.Array:
    """Finish the ladder and combine (MPI_Wait).

    ``advanced`` is how many advance steps the solver already ran on
    this handle (p(l)-CG: l-1; a blocking start+wait: 0).  The remaining
    steps execute here — back-to-back, the modeled 'wait stall' of
    ``launch.autotune`` — then the gathered partials reduce in rank
    order.
    """
    for step in range(advanced, cfg.stages):
        handle = staged_advance(handle, step, cfg)
    return ordered_reduce(handle, out_dtype,
                          cfg.compensated(out_dtype))


# --------------------------------------------------------------------------
# Wiring into SolverOps (distributed + local-oracle forms).
# --------------------------------------------------------------------------

def staged_ops_pieces(cfg: StagedConfig, solver_dtype=None) -> dict:
    """The ``SolverOps.create`` override kwargs for a staged substrate.

    ``start`` computes local partials with the SAME row-sum expression
    as every other substrate (``types.dot_block_rows``) and parks them;
    ``advance``/``wait`` drive the ladder; ``handle_zeros`` tells the
    solver what an in-flight D-ring slot looks like ((P, K) wire-dtype);
    ``combine_partials`` is the superkernel's entry: identical ladder on
    VMEM-accumulated partials (DESIGN.md §13/§14).
    """
    def start(mat, vec):
        return staged_start(dot_block_rows(mat, vec), cfg)

    def advance(handle, step):
        return staged_advance(handle, step, cfg)

    def wait(handle, advanced=0):
        # out dtype: the solver dtype the partials were rounded from.
        out = handle.dtype if cfg.payload_dtype is None else _SOLVER_DTYPE(
            solver_dtype)
        return staged_wait(handle, advanced, cfg, out)

    def handle_zeros(shape, dtype):
        return jnp.zeros((cfg.n_shards,) + tuple(shape),
                         cfg.wire_dtype(dtype))

    def combine_partials(partials):
        return staged_start(partials, cfg)

    return dict(dot_block_start=start, dot_block_advance=advance,
                dot_block_wait=wait, handle_zeros=handle_zeros,
                combine_partials=combine_partials)


def _SOLVER_DTYPE(solver_dtype):
    if solver_dtype is None:
        # The widest float this runtime supports (f64 under x64, f32
        # otherwise) — matches the solvers' default b.dtype in this repo.
        return jax.dtypes.canonicalize_dtype(jnp.float64)
    return jnp.dtype(solver_dtype)


# --------------------------------------------------------------------------
# Eager local oracle (single device, no wire).
# --------------------------------------------------------------------------

def oracle_start(mat: jax.Array, vec: jax.Array,
                 cfg: StagedConfig) -> jax.Array:
    """Local partials of all ``n_shards`` virtual slices at once.

    The vector axis splits into P contiguous slices — the same row
    blocks the mesh partition owns — and each slice's partial is the
    same ``dot_block_rows`` expression a shard evaluates, so the gather
    buffer matches the distributed ladder's final buffer bitwise and
    ``ordered_reduce`` finishes identically (the oracle property,
    tests/test_reduction.py)."""
    p = cfg.n_shards
    n = vec.shape[0]
    if n % p:
        raise ValueError(f"oracle needs n divisible by virtual shards "
                         f"({n} % {p})")
    wire = cfg.wire_dtype(vec.dtype)
    nl = n // p
    mats = mat.reshape(mat.shape[0], p, nl)
    vecs = vec.reshape(p, nl)
    parts = [dot_block_rows(mats[:, r, :], vecs[r]).astype(wire)
             for r in range(p)]
    return jnp.stack(parts, axis=0)


def oracle_partials(partials: jax.Array, cfg: StagedConfig) -> jax.Array:
    """Oracle ``combine_partials``: a single device has ONE partial —
    file it as the full gather buffer (slice splitting happens inside
    the superkernel's own accumulation, which the oracle cannot redo),
    zero elsewhere.  Used by the fused path on the local substrate."""
    wire = cfg.wire_dtype(partials.dtype)
    buf = jnp.zeros((cfg.n_shards,) + partials.shape, wire)
    return buf.at[0].set(partials.astype(wire))


def oracle_ops_pieces(cfg: StagedConfig, solver_dtype=None) -> dict:
    """``SolverOps.create`` overrides for the local eager ladder oracle.

    ``advance`` is an eager no-op inside the tagged scope (no wire on one
    device, but the tracer still sees the step structure), ``wait`` runs
    the identical ordered/compensated reduce.
    """
    def start(mat, vec):
        return oracle_start(mat, vec, cfg)

    def advance(handle, step):
        if step >= cfg.stages:
            return handle
        with jax.named_scope(f"{REDUCE_TAG}{step}"):
            return handle

    def wait(handle, advanced=0):
        out = handle.dtype if cfg.payload_dtype is None else _SOLVER_DTYPE(
            solver_dtype)
        return ordered_reduce(handle, out, cfg.compensated(out))

    def handle_zeros(shape, dtype):
        return jnp.zeros((cfg.n_shards,) + tuple(shape),
                         cfg.wire_dtype(dtype))

    return dict(dot_block_start=start, dot_block_advance=advance,
                dot_block_wait=wait, handle_zeros=handle_zeros,
                combine_partials=lambda p_: oracle_partials(p_, cfg))


def resolve_backend_reduction(backend, reduction: str, stages: int,
                              dtype, n_shards: int,
                              axis: str | None) -> StagedConfig | None:
    """Shared reduction-request resolution for backend constructors.

    Validates the mode, clamps ``stages`` into the ladder's [1, P-1]
    range, honours the backend's ``supports_staged_reduction``
    capability flag (declining backends DOWNGRADE to monolithic and
    record why), and sets ``reduction_mode`` / ``reduction_fallback``
    on the backend.  Returns the StagedConfig to thread through the
    solver ops, or None for the monolithic psum — ONE copy of this
    policy, so local / shard_map / multiprocess can never diverge.
    """
    if reduction == "monolithic":
        backend.reduction_mode = "monolithic"
        backend.reduction_fallback = None
        return None
    if reduction != "staged":
        raise ValueError(
            f"unknown reduction mode {reduction!r} "
            "(want 'monolithic' or 'staged')")
    if not type(backend).supports_staged_reduction:
        # Explicit capability fallback: the request is honoured
        # arithmetically by the monolithic psum; the flag records that
        # no ladder ran — surfaced three ways (attribute, structured
        # warning, default-registry gauge) so it cannot pass unnoticed
        # in a scaling study (DESIGN.md §16).  No in-tree backend
        # declines any more — multiprocess runs the ladder over real
        # process boundaries since DESIGN.md §17 — but the policy stays
        # for out-of-tree backends registered via register_backend.
        backend.reduction_mode = "monolithic"
        backend.reduction_fallback = (
            f"backend {backend.name!r} does not support the staged "
            "ring ladder; dot block downgraded to the monolithic "
            "all-reduce")
        warnings.warn(backend.reduction_fallback,
                      ReductionFallbackWarning, stacklevel=2)
        from repro.obs.metrics import default_registry
        default_registry().gauge(
            "backend_reduction_fallback",
            "1 = staged reduction request downgraded to monolithic",
            label_names=("backend",)).labels(backend=backend.name).set(1)
        return None
    backend.reduction_mode = "staged"
    backend.reduction_fallback = None
    # Pin the gauge at 0 for granted requests: "no fallback happened" is
    # an asserted invariant of the cross-process fabric (DESIGN.md §17,
    # tests/test_fabric.py), so it must be observable, not just absent.
    from repro.obs.metrics import default_registry
    default_registry().gauge(
        "backend_reduction_fallback",
        "1 = staged reduction request downgraded to monolithic",
        label_names=("backend",)).labels(backend=backend.name).set(0)
    n_shards = max(n_shards, 1)
    stages = max(1, min(stages, max(n_shards - 1, 1)))
    return StagedConfig(n_shards=n_shards, stages=stages,
                        payload_dtype=dtype, axis=axis)


def oracle_solver_ops(op, prec, cfg: StagedConfig):
    """Full single-device SolverOps running the eager ladder oracle —
    the staged analogue of ``SolverOps.local`` (DESIGN.md §14).

    ``cfg.n_shards`` is the VIRTUAL shard count: the dot block splits
    into that many contiguous slices whose partials fill the gather
    buffer directly, so a staged mesh run of the same shard count is
    reproduced bitwise without any wire.  Used by the local backend
    (``reduction="staged"``) and as the shape oracle for staged slab
    programs."""
    from repro.core.types import SolverOps
    from repro.kernels.ops import fused_iteration_factory

    pfun = (lambda v: v) if prec is None else (lambda v: prec.apply(v))
    return SolverOps.create(
        apply_a=lambda v: op.apply(v),
        prec=pfun,
        dot_block=dot_block_rows,
        fused_iter_factory=fused_iteration_factory(op, prec),
        **oracle_ops_pieces(cfg),
    )


# --------------------------------------------------------------------------
# Wire accounting (the reduce_bench metrics, DESIGN.md §14).
# --------------------------------------------------------------------------

def hop_payload_bytes(l: int, s: int = 1, dsize: int = 8) -> int:
    """Bytes ONE ladder hop carries: the full (2l+1)[, s] dot block in
    the wire dtype — the message size that sits on the latency-bound
    wire each hop (the fp32 option halves exactly this)."""
    return (2 * l + 1) * max(s, 1) * dsize


def reduction_wire_bytes(n_shards: int, l: int, s: int = 1,
                         dsize: int = 8) -> int:
    """Total bytes one shard sends per staged reduction: P-1 hops x the
    hop payload.  Honest accounting: a ring allgather of raw partials
    ships more TOTAL bytes than a bandwidth-optimal tree all-reduce —
    the regime this subsystem targets is latency-bound (tiny K), where
    per-hop payload and hop count dominate, not aggregate bytes."""
    return (n_shards - 1) * hop_payload_bytes(l, s, dsize)
