"""Parallel execution of the solver family (DESIGN.md §2/§3).

Two layers:

* ``repro.parallel.backends`` — the pluggable reduction-backend registry
  (``get_backend("local" | "shard_map" | "multiprocess")``), the API new
  code should use;
* ``repro.parallel.distributed`` — the shard_map mechanism (halo
  exchange, operator partitioning, the fused-psum dot block) the
  backends are built from;
* ``repro.parallel.reduction`` — the staged ring-reduction ladder the
  dot block runs as when a backend is built with ``reduction="staged"``
  (DESIGN.md §14).
"""

from repro.parallel.backends import (
    ReductionBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.parallel.reduction import StagedConfig
from repro.parallel.distributed import (
    distributed_solve,
    distributed_solve_batched,
    make_solver_mesh,
    partitioned_solver_ops,
    shard_map_compat,
)

__all__ = [
    "ReductionBackend",
    "StagedConfig",
    "available_backends",
    "get_backend",
    "register_backend",
    "distributed_solve",
    "distributed_solve_batched",
    "make_solver_mesh",
    "partitioned_solver_ops",
    "shard_map_compat",
]
