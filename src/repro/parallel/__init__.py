from repro.parallel.distributed import (
    distributed_solve,
    make_solver_mesh,
    partitioned_solver_ops,
)

__all__ = ["distributed_solve", "make_solver_mesh", "partitioned_solver_ops"]
