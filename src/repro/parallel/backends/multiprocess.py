"""Multi-process reduction backend: ``jax.distributed`` + explicit
collective axis, with the staged hop ladder running over REAL process
boundaries (DESIGN.md §3/§14/§17).

One JAX process per host (the paper's MPI rank), glued into a single
logical mesh by ``jax.distributed.initialize``.  After initialization
``jax.devices()`` spans every process, so the same shard_map machinery as
the single-process backend applies — the fused dot block's ``lax.psum``
now crosses host boundaries exactly like the paper's MPI_Iallreduce over
the world communicator.

Launch one process per host, all with the same coordinator::

    # host k of K:
    be = get_backend(
        "multiprocess",
        coordinator_address="10.0.0.1:1234",
        num_processes=K, process_id=k,
        reduction="staged", reduction_stages=2,
    )
    res = be.solve(op, b, method="plcg", l=3, sigmas=sig)

Single-process degradation: with no coordinator and one process, the
backend spans the local devices only (identical to ``shard_map``) — this
keeps the code path importable and testable in single-host CI containers
where no second process exists.

Cross-process hop transport (DESIGN.md §17)
-------------------------------------------
``reduction="staged"`` runs the SAME hop ladder as ``shard_map``
(``repro.parallel.reduction``): hop k of the ring allgather is one
``lax.ppermute`` inside a ``REDUCE_TAG{k}`` scope — a pure
point-to-point neighbour message, the tag being the wire protocol's hop
identity.  What this backend adds is the wire those hops ride:

* the ring permutation ``(i, i+1 mod P)`` is laid out over the GLOBAL
  device order, which jax keeps contiguous per process — so with R
  processes exactly R of the ring edges cross a process boundary every
  hop (``cross_process_edges``), and each crossing is one tagged
  point-to-point transfer on the ``jax.distributed`` transport: NCCL
  when the ranks hold GPUs, the gloo TCP backend on CPU hosts (selected
  by :func:`_configure_collectives` before initialization);
* compiled staged solves carry ZERO dot-block all-reduces across the
  wire — only tagged hop permutes plus the HALO_TAG traffic they
  stagger against, asserted across real process boundaries by
  scripts/multiprocess_parity.py and reproduced bitwise against the
  single-device ``virtual_shards`` ladder oracle (the PR 5 invariant,
  now crossing the wire: rank-ordered combine is transport-independent).

The ``supports_staged_reduction = False`` downgrade this backend carried
through PR 5–7 (and its ``ReductionFallbackWarning`` path) is GONE: the
ladder's static hop schedule — every rank executes the same ppermute
sequence with the same tags — is exactly the access pattern gloo's
connected-pair transport guarantees, which the cross-process bitwise
parity proves per CI run.  The ``backend_reduction_fallback`` gauge now
pins 0 for this backend (tests/test_fabric.py).

Batched multi-RHS serving (DESIGN.md §11) is inherited wholesale from
``ShardMapBackend``: ``solve_batched`` / ``make_slab_program`` stage the
same vmapped per-column programs, and the slab's (2l+1, s) dot-block
payload rides the cross-host wire exactly once per iteration — as ONE
psum (monolithic) or one ladder of per-hop messages (staged) — however
many requests are in flight.  The fused-iteration superkernel and the
donated slab state (DESIGN.md §13) are likewise inherited.
"""

from __future__ import annotations

import jax

from repro.parallel.backends.shard_map import ShardMapBackend
from repro.parallel.distributed import make_solver_mesh

# jax.distributed.initialize may only run once per process; repeated
# get_backend("multiprocess", coordinator_address=...) calls (the natural
# registry usage) must not re-initialize.
_DISTRIBUTED_INITIALIZED = False


def _configure_collectives() -> None:
    """Select the cross-process transport BEFORE ``initialize``.

    GPU ranks get NCCL automatically from jax.distributed; CPU ranks
    need the gloo TCP collectives backend for cross-host ppermute/psum
    (the default shared-memory CPU collectives cannot cross hosts).
    Setting the config after initialization is a no-op, hence this runs
    first — idempotent, and tolerant of jax versions that only read the
    JAX_CPU_COLLECTIVES_IMPLEMENTATION env var (the launcher sets that
    too, scripts/multiprocess_parity.py).
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:      # pragma: no cover - very old/new jax
        pass


def _ensure_initialized(**kwargs) -> None:
    global _DISTRIBUTED_INITIALIZED
    if _DISTRIBUTED_INITIALIZED:
        return
    _configure_collectives()
    try:
        jax.distributed.initialize(**kwargs)
    except RuntimeError as e:
        # Already initialized outside this module (user code, launcher):
        # adopt that runtime rather than failing.
        if "already" not in str(e).lower():
            raise
    _DISTRIBUTED_INITIALIZED = True


class MultiprocessBackend(ShardMapBackend):
    name = "multiprocess"

    # The staged ring ladder runs for real on this backend since
    # DESIGN.md §17: tagged per-hop ppermutes over the jax.distributed
    # transport (NCCL / gloo), bitwise vs the single-device ladder
    # oracle across real process boundaries.  (ReductionBackend defaults
    # this to True; restated here because its absence WAS the PR 5–7
    # capability downgrade.)
    supports_staged_reduction = True

    def __init__(
        self,
        coordinator_address: str | None = None,
        num_processes: int | None = None,
        process_id: int | None = None,
        local_device_ids=None,
        n_shards: int | None = None,
        jit: bool = True,
        reduction: str = "monolithic",
        reduction_stages: int = 2,
        reduction_dtype=None,
    ):
        if coordinator_address is not None:
            # Multi-controller mode: every process must execute the same
            # program; initialize() blocks until the full job is up.
            # Idempotent — a second backend instance adopts the existing
            # distributed runtime instead of re-initializing.
            _ensure_initialized(
                coordinator_address=coordinator_address,
                num_processes=num_processes,
                process_id=process_id,
                local_device_ids=local_device_ids,
            )
        elif (num_processes or 1) > 1:
            raise ValueError(
                "multiprocess backend with num_processes > 1 needs a "
                "coordinator_address (jax.distributed.initialize)"
            )
        self.n_processes = num_processes or jax.process_count()
        # Global mesh: jax.devices() spans all processes after initialize.
        mesh = make_solver_mesh(n_shards, devices=jax.devices())
        super().__init__(mesh=mesh, jit=jit, reduction=reduction,
                         reduction_stages=reduction_stages,
                         reduction_dtype=reduction_dtype)

    # ------------------------------------------------- wire introspection --
    def hop_wire(self) -> str:
        """What carries one tagged ladder hop between ranks: ``"nccl"``
        (GPU ranks), ``"gloo"`` (CPU ranks over TCP), or
        ``"intra-process"`` when the whole mesh lives in this process
        (single-controller degradation — no wire at all)."""
        if self.n_processes <= 1:
            return "intra-process"
        platforms = {d.platform for d in self.mesh.devices.flat}
        return "nccl" if platforms & {"gpu", "cuda", "rocm"} else "gloo"

    def cross_process_edges(self) -> int:
        """Ring edges of the hop ladder that cross a process boundary —
        the per-hop count of REAL point-to-point wire transfers.  The
        mesh's device order is contiguous per process, so this equals
        the process count whenever more than one process participates
        (every rank's last device forwards to the next rank's first)."""
        devs = list(self.mesh.devices.flat)
        p = len(devs)
        return sum(
            devs[i].process_index != devs[(i + 1) % p].process_index
            for i in range(p)) if p > 1 else 0

    def describe(self) -> str:
        base = (
            f"multiprocess (jax.distributed, {self.n_processes} "
            f"process(es), {self.n_shards} global device(s), axis "
            f"'{self.axis}')"
        )
        if self.reduction_cfg is not None:
            cfg = self.reduction_cfg
            base += (
                f" staged ring dot block: {cfg.n_hops} hops / "
                f"{cfg.stages} stage(s), {self.cross_process_edges()} "
                f"cross-process edge(s)/hop over {self.hop_wire()}"
            )
        return base
