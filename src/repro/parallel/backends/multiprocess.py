"""Multi-process reduction backend: ``jax.distributed`` + explicit
collective axis (DESIGN.md §3).

One JAX process per host (the paper's MPI rank), glued into a single
logical mesh by ``jax.distributed.initialize``.  After initialization
``jax.devices()`` spans every process, so the same shard_map machinery as
the single-process backend applies — the fused dot block's ``lax.psum``
now crosses host boundaries exactly like the paper's MPI_Iallreduce over
the world communicator.

Launch one process per host, all with the same coordinator::

    # host k of K:
    be = get_backend(
        "multiprocess",
        coordinator_address="10.0.0.1:1234",
        num_processes=K, process_id=k,
    )
    res = be.solve(op, b, method="plcg", l=3, sigmas=sig)

Single-process degradation: with no coordinator and one process, the
backend spans the local devices only (identical to ``shard_map``) — this
keeps the code path importable and testable in single-host CI containers
where no second process exists.

Batched multi-RHS serving (DESIGN.md §11) is inherited wholesale from
``ShardMapBackend``: ``solve_batched`` / ``make_slab_program`` stage the
same vmapped per-column programs, and the slab's (2l+1, s) dot-block
matrix rides ONE cross-host psum per iteration — the amortized payload
crosses the wire exactly once however many requests are in flight
(parity over this backend asserted in tests/test_serve.py).  The
fused-iteration superkernel and the donated slab state (DESIGN.md §13)
are likewise inherited: ``fused_iteration=True`` fuses each rank's
local vector phase into one HBM pass, the cross-host psum then carries
the VMEM-accumulated partials, and chunk/inject donate the sharded
state buffers exactly as on ``shard_map``.
"""

from __future__ import annotations

import jax

from repro.parallel.backends.shard_map import ShardMapBackend
from repro.parallel.distributed import make_solver_mesh

# jax.distributed.initialize may only run once per process; repeated
# get_backend("multiprocess", coordinator_address=...) calls (the natural
# registry usage) must not re-initialize.
_DISTRIBUTED_INITIALIZED = False


def _ensure_initialized(**kwargs) -> None:
    global _DISTRIBUTED_INITIALIZED
    if _DISTRIBUTED_INITIALIZED:
        return
    try:
        jax.distributed.initialize(**kwargs)
    except RuntimeError as e:
        # Already initialized outside this module (user code, launcher):
        # adopt that runtime rather than failing.
        if "already" not in str(e).lower():
            raise
    _DISTRIBUTED_INITIALIZED = True


class MultiprocessBackend(ShardMapBackend):
    name = "multiprocess"

    # Capability flag (DESIGN.md §14): the staged ring ladder needs
    # dependable point-to-point collective-permute chains, which the
    # gloo CPU collectives backing cross-host jax.distributed runs do
    # not guarantee for the ladder's dynamic-sliced hop pattern.  A
    # ``reduction="staged"`` request therefore DOWNGRADES to the
    # monolithic cross-host psum — arithmetically equivalent modulo
    # reduction order — and records the downgrade in
    # ``reduction_fallback`` so callers can tell which wire path ran
    # (exercised across real process boundaries by
    # scripts/multiprocess_parity.py --staged).
    supports_staged_reduction = False

    def __init__(
        self,
        coordinator_address: str | None = None,
        num_processes: int | None = None,
        process_id: int | None = None,
        local_device_ids=None,
        n_shards: int | None = None,
        jit: bool = True,
        reduction: str = "monolithic",
        reduction_stages: int = 2,
        reduction_dtype=None,
    ):
        if coordinator_address is not None:
            # Multi-controller mode: every process must execute the same
            # program; initialize() blocks until the full job is up.
            # Idempotent — a second backend instance adopts the existing
            # distributed runtime instead of re-initializing.
            _ensure_initialized(
                coordinator_address=coordinator_address,
                num_processes=num_processes,
                process_id=process_id,
                local_device_ids=local_device_ids,
            )
        elif (num_processes or 1) > 1:
            raise ValueError(
                "multiprocess backend with num_processes > 1 needs a "
                "coordinator_address (jax.distributed.initialize)"
            )
        self.n_processes = num_processes or jax.process_count()
        # Global mesh: jax.devices() spans all processes after initialize.
        # The ShardMapBackend constructor routes the reduction request
        # through _resolve_reduction, which consults
        # supports_staged_reduction — so a staged request lands on the
        # monolithic psum here, with reduction_fallback set.
        mesh = make_solver_mesh(n_shards, devices=jax.devices())
        super().__init__(mesh=mesh, jit=jit, reduction=reduction,
                         reduction_stages=reduction_stages,
                         reduction_dtype=reduction_dtype)

    def describe(self) -> str:
        tail = ""
        if self.reduction_fallback is not None:
            tail = ", staged reduction request downgraded to monolithic"
        return (
            f"multiprocess (jax.distributed, {self.n_processes} process(es), "
            f"{self.n_shards} global device(s), axis '{self.axis}'{tail})"
        )
