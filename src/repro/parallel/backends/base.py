"""Reduction-backend interface (DESIGN.md §3).

A *reduction backend* is the execution substrate behind ``SolverOps``: it
decides where the vectors live, how the SPMV halo moves, and — the part
the paper cares about — how the fused 2l+1-entry dot block becomes ONE
global reduction whose completion can be deferred (the MPI_Iallreduce /
MPI_Wait pair).  The solvers in ``repro.core`` are substrate-agnostic;
swapping backends never changes their arithmetic, only where it runs:

  ``local``         single device; the dot block is a plain matmul.
  ``shard_map``     domain decomposition over a 1-D device mesh; the dot
                    block is one ``lax.psum`` (the current production path).
  ``multiprocess``  ``jax.distributed`` multi-controller: same psum, but
                    the mesh spans every process's devices and the
                    collective axis crosses host boundaries.

Select one via the registry::

    from repro.parallel import get_backend
    be = get_backend("shard_map", n_shards=8)
    res = be.solve(op, b, method="plcg", l=3, sigmas=sig)

Backends also expose ``run``/``lower_hlo`` so tools that need to trace
*inside* the SPMD context — the overlap tracer (DESIGN.md §6), the
pipeline-depth autotuner (``repro.launch.autotune``) — can stage arbitrary
solver fragments without duplicating mesh/partition plumbing.
"""

from __future__ import annotations

import abc
from typing import Any, Callable, ClassVar

import jax

# The three CG variants of the paper — THE shared dispatch table
# (repro.core.METHODS); distributed_solve uses the same object, so the
# solver sets can never fork between substrates.
from repro.core import METHODS
from repro.core.batched import SlabProgram
from repro.core.types import SolveResult, SolverOps


class ReductionBackend(abc.ABC):
    """Pluggable substrate for the CG solver family (DESIGN.md §3)."""

    name: ClassVar[str]

    # Capability flag (DESIGN.md §14/§17): whether this substrate can run
    # the dot block as the staged ring-reduction ladder
    # (``repro.parallel.reduction``) — tagged ppermute hops the solver
    # advances.  Every in-tree backend supports it (multiprocess runs the
    # ladder over real process boundaries since DESIGN.md §17); an
    # out-of-tree backend that cannot may set this False to accept
    # ``reduction="staged"`` but DOWNGRADE to the monolithic psum,
    # recording the request in ``reduction_fallback`` so callers can
    # assert which wire path actually ran.
    supports_staged_reduction: ClassVar[bool] = True
    # Instance attributes set by constructors: the reduction mode that
    # actually runs, and why it differs from the request (or None).
    reduction_mode: str = "monolithic"
    reduction_fallback: str | None = None

    # ------------------------------------------------------------ solve --
    @abc.abstractmethod
    def solve(self, op, b, method: str = "plcg", prec=None,
              **solver_kwargs) -> SolveResult:
        """Solve A x = b with the chosen CG variant on this substrate.

        ``solver_kwargs`` are forwarded to the solver (l, tol, maxit,
        sigmas, unroll, ...).
        """

    def make_solver(self, op, method: str = "plcg", prec=None,
                    **solver_kwargs) -> Callable[[jax.Array], SolveResult]:
        """A reusable compiled solver ``b -> SolveResult``.

        Unlike :meth:`solve` — which stages a fresh computation per call —
        the returned callable holds one jit cache, so repeated calls
        retrace nothing.  This is what the autotuner times
        (``repro.launch.autotune.measured_runner``).
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not support make_solver")

    # -------------------------------------------------- batched multi-RHS --
    def solve_batched(self, op, B, method: str = "plcg", prec=None,
                      **solver_kwargs) -> SolveResult:
        """Solve A X = B for every column of B (n, s) in lock-step.

        The per-iteration fused dot block of ALL columns is reduced as a
        single (K, s) payload — one reduction per iteration whatever s is
        (DESIGN.md §11).  The returned ``SolveResult`` leaves carry a
        leading s-axis; column i matches the sequential
        ``solve(op, B[:, i], ...)`` result (parity asserted per backend in
        tests/test_serve.py).
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not support solve_batched")

    def make_batched_solver(self, op, method: str = "plcg", prec=None,
                            **solver_kwargs) -> Callable[[jax.Array], SolveResult]:
        """Reusable compiled batched solver ``B (n, s) -> SolveResult``
        (one jit cache per B shape) — the slab analogue of
        :meth:`make_solver`, used by throughput benchmarks."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support make_batched_solver")

    def make_slab_program(self, op, s: int, method: str = "plcg", prec=None,
                          chunk_iters: int = 16, dtype=None,
                          **solver_kwargs) -> SlabProgram:
        """Compile the chunked slab lifecycle for the serving layer
        (``repro.serve``, DESIGN.md §11): init / chunk / inject / status /
        extract over a fixed-(n, s) slab.  Converged columns freeze,
        retire, and their slots are re-initialized against new RHS columns
        by ``inject`` — all through the same compiled computations, so the
        request mix never forces a retrace."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support make_slab_program")

    # ----------------------------------------------------- SPMD staging --
    @abc.abstractmethod
    def run(self, fn: Callable[[SolverOps, jax.Array], Any], op, b,
            prec=None, b_spec=None) -> Any:
        """Execute ``fn(ops, b_local)`` inside this backend's SPMD context.

        ``fn`` receives backend-built :class:`SolverOps` plus the local
        shard of ``b`` and must return a pytree that is *replicated*
        across shards (scalars, residual histories, reduction results —
        anything derived from the fused dot block qualifies).  ``b_spec``
        overrides the partitioning of ``b`` on distributed backends (the
        default shards its first axis); pass e.g. ``P(axis, None)``-style
        specs for (n, s) slab operands.
        """

    @abc.abstractmethod
    def lower_hlo(self, fn: Callable[[SolverOps, jax.Array], Any], op, b,
                  prec=None, b_spec=None) -> str:
        """Compiled (optimized, scheduled) HLO text of ``run(fn, ...)``.

        This is the input the overlap tracer analyses; ``b`` may be a
        ``jax.ShapeDtypeStruct`` when only the schedule is needed.
        """

    # ------------------------------------------------------------ misc ---
    def describe(self) -> str:
        return f"{self.name} reduction backend"

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} {self.describe()}>"
