"""``shard_map`` reduction backend — the paper's MPI layout on one process
(DESIGN.md §2/§3).

Domain decomposition over a 1-D "shards" mesh: halo exchange via
``lax.ppermute``, communication-free preconditioner, and ALL inner
products of an iteration fused into ONE ``lax.psum`` — the single
MPI_Iallreduce of the G-column.  This ports the original
``repro.parallel.distributed`` path onto the backend interface; the heavy
lifting (operator partitioning, halo kernels) stays in that module.

Example (8 simulated hosts — set ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` before importing jax)::

    from repro.parallel import get_backend
    be = get_backend("shard_map", n_shards=8)
    res = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-8)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import batched as batched_mod
from repro.core.batched import SlabProgram, SlabStatus
from repro.core.types import SolverOps
from repro.parallel.distributed import (
    _permutation_wrappers,
    batched_result_specs,
    batched_state_specs,
    distributed_solve,
    distributed_solve_batched,
    make_solver_mesh,
    partitioned_solver_ops,
    shard_map_compat,
)
from repro.parallel.backends.base import ReductionBackend
from repro.parallel.reduction import (StagedConfig, oracle_solver_ops,
                                      resolve_backend_reduction)


class ShardMapBackend(ReductionBackend):
    name = "shard_map"

    def __init__(self, mesh: Mesh | None = None, n_shards: int | None = None,
                 jit: bool = True, reduction: str = "monolithic",
                 reduction_stages: int = 2, reduction_dtype=None):
        """``reduction="staged"`` swaps the dot block's monolithic psum
        for the hop-per-iteration ring ladder (DESIGN.md §14):
        ``reduction_stages`` advance steps spread the P-1 allgather hops
        over the solver's in-flight window, and ``reduction_dtype``
        (e.g. jnp.float32) narrows the wire payload with fp64
        compensated accumulation at the wait."""
        self.mesh = mesh if mesh is not None else make_solver_mesh(n_shards)
        self.axis = self.mesh.axis_names[0]
        self.n_shards = self.mesh.devices.size
        self.jit = jit
        self.reduction_cfg = self._resolve_reduction(
            reduction, reduction_stages, reduction_dtype)

    def _resolve_reduction(self, reduction: str, stages: int,
                           dtype) -> StagedConfig | None:
        # One shared policy (validation, stage clamp, capability
        # fallback) for every backend — see reduction.py.
        return resolve_backend_reduction(self, reduction, stages, dtype,
                                         self.n_shards, self.axis)

    # ------------------------------------------------------------ solve --
    def solve(self, op, b, method: str = "plcg", prec=None, **solver_kwargs):
        ckpt = solver_kwargs.pop("checkpoint", None)
        if ckpt is not None and getattr(ckpt, "armed", False):
            # Host-segmented checkpointing driver (DESIGN.md §19) — the
            # pieces jit+shard_map themselves; no outer jit here.
            from repro.parallel.distributed import \
                distributed_checkpointed_solve
            return distributed_checkpointed_solve(
                self.mesh, op, b, method=method, prec=prec,
                reduction=self.reduction_cfg, checkpoint=ckpt,
                **solver_kwargs)
        return distributed_solve(self.mesh, op, b, method=method, prec=prec,
                                 jit=self.jit, reduction=self.reduction_cfg,
                                 **solver_kwargs)

    def make_solver(self, op, method: str = "plcg", prec=None,
                    **solver_kwargs):
        # jit=False hands back (shard_map fn, partitioned arrays); one
        # jit wrapper around the pair is the reusable compiled solver.
        # distributed_solve only reads b's shape on this path.
        bspec = jax.ShapeDtypeStruct((op.n,), jnp.float32)
        fn, arrays = distributed_solve(self.mesh, op, bspec, method=method,
                                       prec=prec, jit=False,
                                       reduction=self.reduction_cfg,
                                       **solver_kwargs)
        jfn = jax.jit(fn)
        return lambda bb: jfn(bb, arrays)

    # -------------------------------------------------- batched multi-RHS --
    def solve_batched(self, op, B, method: str = "plcg", prec=None,
                      **solver_kwargs):
        return distributed_solve_batched(self.mesh, op, B, method=method,
                                         prec=prec, jit=self.jit,
                                         reduction=self.reduction_cfg,
                                         **solver_kwargs)

    def make_batched_solver(self, op, method: str = "plcg", prec=None,
                            **solver_kwargs):
        bspec = jax.ShapeDtypeStruct((op.n, 1), jnp.float32)
        fn, arrays = distributed_solve_batched(
            self.mesh, op, bspec, method=method, prec=prec, jit=False,
            reduction=self.reduction_cfg, **solver_kwargs)
        jfn = jax.jit(fn)
        return lambda BB: jfn(BB, arrays)

    def make_slab_program(self, op, s: int, method: str = "plcg", prec=None,
                          chunk_iters: int = 16, dtype=None,
                          **solver_kwargs) -> SlabProgram:
        """Slab lifecycle under shard_map (DESIGN.md §11).

        Each piece is one shard_map-wrapped jit: the slab B (n, s) is
        domain-decomposed on n, the state's vector leaves shard their
        trailing axis (``batched_state_specs``), and per-column scalars /
        histories are replicated.  The state crosses the host boundary
        between chunks so the serve layer can retire and inject columns —
        with fixed shapes throughout, nothing ever retraces.
        """
        kw = dict(solver_kwargs)
        dtype = jnp.zeros((), jnp.float64).dtype if dtype is None else dtype
        n, axis = op.n, self.axis
        arrays, build, perm = partitioned_solver_ops(
            op, prec, self.n_shards, axis, reduction=self.reduction_cfg)
        pre, post = _permutation_wrappers(perm)
        arr_specs = jax.tree.map(lambda _: P(axis), arrays)
        b_spec = P(axis, None)

        # State structure/ndims are substrate-independent: eval_shape the
        # batched init against plain local ops to derive partition specs.
        # Staged mode must mirror the widened D-ring handle shapes, so
        # the shape oracle is the eager ladder with the same config.
        if self.reduction_cfg is None:
            ops_shape = SolverOps.local(op, prec)
        else:
            ops_shape = oracle_solver_ops(
                op, prec, dataclasses.replace(self.reduction_cfg, axis=None))
        st_struct = jax.eval_shape(
            lambda BB: batched_mod.batched_init(ops_shape, BB, method, kw),
            jax.ShapeDtypeStruct((n, s), dtype))
        st_specs = batched_state_specs(method, st_struct, axis)
        status_specs = SlabStatus(running=P(), converged=P(), iters=P())

        def staged(fn, in_specs, out_specs, donate=()):
            wrapped = shard_map_compat(fn, mesh=self.mesh,
                                       in_specs=in_specs,
                                       out_specs=out_specs)
            # donate=(1,) on chunk/inject: the slab state is consumed and
            # replaced every call, so its sharded buffers alias through
            # the jit boundary instead of copying (DESIGN.md §13).
            return jax.jit(wrapped, donate_argnums=donate)

        init_j = staged(
            lambda Bl, loc: batched_mod.batched_init(build(loc), Bl, method,
                                                     kw),
            (b_spec, arr_specs), st_specs)
        chunk_j = staged(
            lambda Bl, st, loc: batched_mod.batched_chunk(
                build(loc), Bl, st, method, kw, chunk_iters),
            (b_spec, st_specs, arr_specs), st_specs, donate=(1,))
        inject_j = staged(
            lambda Bl, st, mask, loc: batched_mod.batched_inject(
                build(loc), Bl, st, mask, method, kw),
            (b_spec, st_specs, P(), arr_specs), st_specs, donate=(1,))
        status_j = staged(
            lambda Bl, st, loc: batched_mod.batched_status(build(loc), Bl,
                                                           st, method, kw),
            (b_spec, st_specs, arr_specs), status_specs)
        extract_j = staged(
            lambda Bl, st, loc: batched_mod.batched_extract(build(loc), Bl,
                                                            st, method, kw),
            (b_spec, st_specs, arr_specs),
            batched_result_specs(
                axis, telemetry=bool(kw.get("telemetry_cap", 0)),
                governor=kw.get("governor") is not None))

        # The slab B crosses into the solver's (possibly RCM-permuted)
        # basis on every entry point and the extracted solutions map back
        # on the way out; the state itself lives permuted throughout.
        return SlabProgram(
            method=method, s=s, n=n, chunk_iters=chunk_iters,
            init=lambda B: init_j(pre(B), arrays),
            chunk=lambda B, st: chunk_j(pre(B), st, arrays),
            inject=lambda B, st, mask: inject_j(pre(B), st, mask, arrays),
            status=lambda B, st: status_j(pre(B), st, arrays),
            extract=lambda B, st: post(extract_j(pre(B), st, arrays)),
        )

    # ----------------------------------------------------- SPMD staging --
    def _staged(self, fn: Callable[[SolverOps, jax.Array], Any], op, prec,
                b_spec=None):
        """(wrapped_fn, arrays): shard_map-wrapped ``fn`` with replicated
        outputs, plus the partitioned operator arrays to pass alongside.

        ``fn`` sees the solver's basis: for an RCM-partitioned SparseOp
        the local shard of ``b`` is in permuted order — irrelevant for
        schedule tracing (the staging use case), which often passes a
        ShapeDtypeStruct anyway."""
        arrays, build, _perm = partitioned_solver_ops(
            op, prec, self.n_shards, self.axis,
            reduction=self.reduction_cfg)

        def run(b_local, loc):
            return fn(build(loc), b_local)

        b_spec = P(self.axis) if b_spec is None else b_spec
        arr_specs = jax.tree.map(lambda _: P(self.axis), arrays)
        wrapped = shard_map_compat(
            run, mesh=self.mesh, in_specs=(b_spec, arr_specs),
            out_specs=P(),
        )
        return wrapped, arrays

    def run(self, fn, op, b, prec=None, b_spec=None) -> Any:
        wrapped, arrays = self._staged(fn, op, prec, b_spec)
        return jax.jit(wrapped)(b, arrays)

    def lower_hlo(self, fn, op, b, prec=None, b_spec=None) -> str:
        wrapped, arrays = self._staged(fn, op, prec, b_spec)
        bsh = NamedSharding(
            self.mesh, P(self.axis) if b_spec is None else b_spec)
        ash = jax.tree.map(lambda _: NamedSharding(self.mesh, P(self.axis)),
                           arrays)
        lowered = jax.jit(wrapped, in_shardings=(bsh, ash)).lower(b, arrays)
        return lowered.compile().as_text()

    def describe(self) -> str:
        if self.reduction_cfg is not None:
            cfg = self.reduction_cfg
            wire = "solver-dtype" if cfg.payload_dtype is None else str(
                jnp.dtype(cfg.payload_dtype))
            return (f"shard_map over {self.n_shards} device(s), axis "
                    f"'{self.axis}' (staged ring dot block: "
                    f"{cfg.n_hops} hops / {cfg.stages} stage(s), "
                    f"{wire} wire)")
        return (f"shard_map over {self.n_shards} device(s), "
                f"axis '{self.axis}' (fused psum dot block)")
