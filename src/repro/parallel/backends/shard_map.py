"""``shard_map`` reduction backend — the paper's MPI layout on one process
(DESIGN.md §2/§3).

Domain decomposition over a 1-D "shards" mesh: halo exchange via
``lax.ppermute``, communication-free preconditioner, and ALL inner
products of an iteration fused into ONE ``lax.psum`` — the single
MPI_Iallreduce of the G-column.  This ports the original
``repro.parallel.distributed`` path onto the backend interface; the heavy
lifting (operator partitioning, halo kernels) stays in that module.

Example (8 simulated hosts — set ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` before importing jax)::

    from repro.parallel import get_backend
    be = get_backend("shard_map", n_shards=8)
    res = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-8)
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import SolverOps
from repro.parallel.backends.base import ReductionBackend
from repro.parallel.distributed import (
    distributed_solve,
    make_solver_mesh,
    partitioned_solver_ops,
    shard_map_compat,
)


class ShardMapBackend(ReductionBackend):
    name = "shard_map"

    def __init__(self, mesh: Mesh | None = None, n_shards: int | None = None,
                 jit: bool = True):
        self.mesh = mesh if mesh is not None else make_solver_mesh(n_shards)
        self.axis = self.mesh.axis_names[0]
        self.n_shards = self.mesh.devices.size
        self.jit = jit

    # ------------------------------------------------------------ solve --
    def solve(self, op, b, method: str = "plcg", prec=None, **solver_kwargs):
        return distributed_solve(self.mesh, op, b, method=method, prec=prec,
                                 jit=self.jit, **solver_kwargs)

    def make_solver(self, op, method: str = "plcg", prec=None,
                    **solver_kwargs):
        # jit=False hands back (shard_map fn, partitioned arrays); one
        # jit wrapper around the pair is the reusable compiled solver.
        # distributed_solve only reads b's shape on this path.
        bspec = jax.ShapeDtypeStruct((op.n,), jnp.float32)
        fn, arrays = distributed_solve(self.mesh, op, bspec, method=method,
                                       prec=prec, jit=False, **solver_kwargs)
        jfn = jax.jit(fn)
        return lambda bb: jfn(bb, arrays)

    # ----------------------------------------------------- SPMD staging --
    def _staged(self, fn: Callable[[SolverOps, jax.Array], Any], op, prec):
        """(wrapped_fn, arrays): shard_map-wrapped ``fn`` with replicated
        outputs, plus the partitioned operator arrays to pass alongside."""
        arrays, build = partitioned_solver_ops(op, prec, self.n_shards,
                                               self.axis)

        def run(b_local, loc):
            return fn(build(loc), b_local)

        arr_specs = jax.tree.map(lambda _: P(self.axis), arrays)
        wrapped = shard_map_compat(
            run, mesh=self.mesh, in_specs=(P(self.axis), arr_specs),
            out_specs=P(),
        )
        return wrapped, arrays

    def run(self, fn, op, b, prec=None) -> Any:
        wrapped, arrays = self._staged(fn, op, prec)
        return jax.jit(wrapped)(b, arrays)

    def lower_hlo(self, fn, op, b, prec=None) -> str:
        wrapped, arrays = self._staged(fn, op, prec)
        bsh = NamedSharding(self.mesh, P(self.axis))
        ash = jax.tree.map(lambda _: bsh, arrays)
        lowered = jax.jit(wrapped, in_shardings=(bsh, ash)).lower(b, arrays)
        return lowered.compile().as_text()

    def describe(self) -> str:
        return (f"shard_map over {self.n_shards} device(s), "
                f"axis '{self.axis}' (fused psum dot block)")
