"""Single-device reduction backend (DESIGN.md §3).

The fused dot block is a plain in-process row reduction
(``types.dot_block_rows``) — there is no wire, but the issue/consume
sites are tagged exactly like the distributed backends, so the overlap
tracer sees the same chain structure and ``local`` serves as the
bitwise-comparable oracle for ``shard_map``/``multiprocess`` runs
(the residual-history parity asserted in tests/test_cg_convergence.py).

Slab programs jit their chunk/inject steps with ``donate_argnums`` on
the state: the (s, NV, N) vector slab crosses the serving loop's jit
boundary aliased instead of copied (DESIGN.md §13), matching the
superkernel's ``input_output_aliases`` inside the iteration.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core import batched as batched_mod
from repro.core.batched import SlabProgram, SlabStatus
from repro.core.types import SolverOps
from repro.parallel.backends.base import METHODS, ReductionBackend


class LocalBackend(ReductionBackend):
    name = "local"

    def __init__(self, jit: bool = True, reduction: str = "monolithic",
                 reduction_stages: int = 2, reduction_dtype=None,
                 virtual_shards: int = 1):
        """``reduction="staged"`` runs the EAGER LADDER ORACLE
        (DESIGN.md §14): the dot block splits into ``virtual_shards``
        contiguous slices whose partials fill the gather buffer
        directly — no wire, but bitwise the same rank-ordered
        (optionally fp64-compensated, ``reduction_dtype=jnp.float32``)
        combine as a staged mesh run with that many shards.  The oracle
        is the single-device reference the distributed staged tests
        compare against (tests/test_reduction.py)."""
        from repro.parallel.reduction import resolve_backend_reduction

        self.jit = jit
        # Same resolution policy as the distributed backends (one copy,
        # reduction.py); the oracle's ring size is the VIRTUAL shard
        # count and there is no mesh axis.
        self.reduction_cfg = resolve_backend_reduction(
            self, reduction, reduction_stages, reduction_dtype,
            virtual_shards, axis=None)

    def make_ops(self, op, prec=None) -> SolverOps:
        if self.reduction_cfg is not None:
            from repro.parallel.reduction import oracle_solver_ops
            return oracle_solver_ops(op, prec, self.reduction_cfg)
        return SolverOps.local(op, prec)

    def solve(self, op, b, method: str = "plcg", prec=None, **solver_kwargs):
        ckpt = solver_kwargs.get("checkpoint")
        if ckpt is not None and getattr(ckpt, "armed", False):
            # The checkpointing driver (DESIGN.md §19) segments the solve
            # on the host, so it cannot live under an outer jit; it jits
            # its own segment/interrupt pieces internally.
            ops = self.make_ops(op, prec)
            return METHODS[method](ops, b, solver_kwargs)
        if self.jit:
            return self.make_solver(op, method, prec, **solver_kwargs)(b)
        ops = self.make_ops(op, prec)
        return METHODS[method](ops, b, solver_kwargs)

    def make_solver(self, op, method: str = "plcg", prec=None,
                    **solver_kwargs):
        ops = self.make_ops(op, prec)
        return jax.jit(lambda bb: METHODS[method](ops, bb, solver_kwargs))

    # -------------------------------------------------- batched multi-RHS --
    def solve_batched(self, op, B, method: str = "plcg", prec=None,
                      **solver_kwargs):
        return self.make_batched_solver(op, method, prec, **solver_kwargs)(B)

    def make_batched_solver(self, op, method: str = "plcg", prec=None,
                            **solver_kwargs):
        ops = self.make_ops(op, prec)
        return jax.jit(
            lambda BB: batched_mod.solve_batched(ops, BB, method,
                                                 **solver_kwargs))

    def make_slab_program(self, op, s: int, method: str = "plcg", prec=None,
                          chunk_iters: int = 16, dtype=None,
                          **solver_kwargs) -> SlabProgram:
        ops = self.make_ops(op, prec)
        kw = dict(solver_kwargs)
        return SlabProgram(
            method=method, s=s, n=op.n, chunk_iters=chunk_iters,
            init=jax.jit(
                lambda B: batched_mod.batched_init(ops, B, method, kw)),
            # donate the incoming slab state: chunk/inject consume it and
            # return its successor, so XLA aliases the state buffers
            # in place of a per-chunk copy (DESIGN.md §13; asserted on
            # compiled HLO in tests/test_fused_iter.py).
            chunk=jax.jit(
                lambda B, st: batched_mod.batched_chunk(
                    ops, B, st, method, kw, chunk_iters),
                donate_argnums=(1,)),
            inject=jax.jit(
                lambda B, st, mask: batched_mod.batched_inject(
                    ops, B, st, mask, method, kw),
                donate_argnums=(1,)),
            status=jax.jit(
                lambda B, st: batched_mod.batched_status(ops, B, st, method,
                                                         kw)),
            extract=jax.jit(
                lambda B, st: batched_mod.batched_extract(ops, B, st, method,
                                                          kw)),
        )

    def run(self, fn: Callable[[SolverOps, jax.Array], Any], op, b,
            prec=None, b_spec=None) -> Any:
        ops = self.make_ops(op, prec)
        return jax.jit(lambda bb: fn(ops, bb))(b)

    def lower_hlo(self, fn: Callable[[SolverOps, jax.Array], Any], op, b,
                  prec=None, b_spec=None) -> str:
        ops = self.make_ops(op, prec)
        return (
            jax.jit(lambda bb: fn(ops, bb)).lower(b).compile().as_text()
        )

    def describe(self) -> str:
        return "local (single device, in-process dot block)"
