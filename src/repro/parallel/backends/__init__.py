"""Pluggable reduction backends for the CG solver family (DESIGN.md §3).

Registry keyed by name::

    from repro.parallel.backends import get_backend, available_backends
    available_backends()              # ("local", "shard_map", "multiprocess")
    be = get_backend("shard_map", n_shards=8)
    res = be.solve(op, b, method="plcg", l=2, sigmas=sig)

Third-party substrates register with :func:`register_backend`; the class
only needs to implement :class:`~repro.parallel.backends.base.
ReductionBackend`'s three methods (solve / run / lower_hlo).
"""

from __future__ import annotations

from repro.parallel.backends.base import METHODS, ReductionBackend
from repro.parallel.backends.local import LocalBackend
from repro.parallel.backends.multiprocess import MultiprocessBackend
from repro.parallel.backends.shard_map import ShardMapBackend

_REGISTRY: dict[str, type[ReductionBackend]] = {
    LocalBackend.name: LocalBackend,
    ShardMapBackend.name: ShardMapBackend,
    MultiprocessBackend.name: MultiprocessBackend,
}


def available_backends() -> tuple[str, ...]:
    """Names accepted by :func:`get_backend`, in registration order."""
    return tuple(_REGISTRY)


def register_backend(name: str, cls: type[ReductionBackend],
                     overwrite: bool = False) -> None:
    """Add a custom substrate to the registry."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _REGISTRY[name] = cls


def get_backend(name: str, **kwargs) -> ReductionBackend:
    """Instantiate a reduction backend by name.

    ``kwargs`` go to the backend constructor (e.g. ``n_shards`` / ``mesh``
    for shard_map, ``coordinator_address`` / ``num_processes`` /
    ``process_id`` for multiprocess, ``jit`` for local).
    """
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown reduction backend {name!r}; "
            f"available: {', '.join(_REGISTRY)}"
        ) from None
    return cls(**kwargs)


__all__ = [
    "METHODS",
    "ReductionBackend",
    "LocalBackend",
    "ShardMapBackend",
    "MultiprocessBackend",
    "available_backends",
    "get_backend",
    "register_backend",
]
