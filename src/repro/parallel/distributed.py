"""Distributed execution of the CG solver family under ``shard_map``.

This module is the *mechanism* behind the ``shard_map`` reduction backend
(``repro.parallel.backends.shard_map``, DESIGN.md §3).  Prefer the backend
API for new code::

    from repro.parallel import get_backend
    be = get_backend("shard_map", n_shards=8)
    res = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-8)

``distributed_solve`` below remains the stable low-level entry point the
backend delegates to.

This is the paper's MPI rank layout mapped to a JAX mesh (DESIGN.md §2):

  * the solution vector is DOMAIN-DECOMPOSED: each device owns a contiguous
    block of grid rows (the paper's per-rank sub-domain);
  * the SPMV is a halo exchange (``lax.ppermute`` of one boundary plane in
    each direction — point-to-point neighbour communication, the MPI halo
    send/recv) followed by a purely local stencil application;
  * the preconditioner is communication-free (Jacobi / block-Jacobi with
    blocks interior to a shard — the paper's "limited communication
    preconditioner" that motivates longer pipelines);
  * ALL inner products of one iteration form ONE fused ``lax.psum`` — the
    single ``MPI_Iallreduce`` of the G-column block (Alg. 2, line 11).

The solvers themselves (``repro.core``) are substrate-agnostic: the same
code runs locally or distributed, because every global operation goes
through ``SolverOps``.  Under ``shard_map`` the p(l)-CG data-dependency
structure means the ``psum`` issued at iteration i has no consumer for l
loop iterations — XLA's latency-hiding scheduler can keep l reductions in
flight (the Iallreduce/Wait window of Fig. 1).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import METHODS
from repro.core import batched as batched_mod
from repro.core.types import (HALO_TAG, SolveResult, SolverOps,
                              dot_block_rows)
from repro.linalg import partition as partition_mod
from repro.linalg.operators import (
    DiagonalOp,
    LinearOperator,
    Stencil2D5,
    Stencil3D7,
    Stencil3D27,
)
from repro.linalg.preconditioners import BlockJacobi, IdentityPrec, JacobiPrec
from repro.linalg.sparse import SparseOp


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map`` with ``check_vma``; 0.4.x has
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  Both
    checks are disabled: the solver outputs mix sharded (x) and replicated
    (scalars/history) results that the checker cannot infer through
    ``lax.while_loop``.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def make_solver_mesh(n_shards: int | None = None, devices=None) -> Mesh:
    """1-D mesh over all (or the first ``n_shards``) devices.

    The solver path flattens whatever production mesh exists into a single
    "shards" axis: CG's domain decomposition is rank-structured, exactly as
    in the paper's MPI runs.
    """
    devs = jax.devices() if devices is None else devices
    n = len(devs) if n_shards is None else n_shards
    return Mesh(np.asarray(devs[:n]).reshape(n), ("shards",))


# --------------------------------------------------------------------------
# Halo exchange (the MPI neighbour send/recv).
# --------------------------------------------------------------------------

def _halo_first_dim(g: jax.Array, axis: str) -> tuple[jax.Array, jax.Array]:
    """Exchange one boundary plane along the (sharded) first grid dim.

    Returns (plane_above, plane_below) for this shard.  ``ppermute`` leaves
    zeros where no neighbour exists — which is exactly the homogeneous
    Dirichlet boundary condition of the operators.
    """
    # lax.axis_size is not present in every jax version; psum of a Python
    # scalar folds to the static axis size under both old and new jax.
    n = int(lax.psum(1, axis)) if not hasattr(lax, "axis_size") \
        else lax.axis_size(axis)
    if n == 1:
        z = jnp.zeros_like(g[:1])
        return z, z
    # HALO_TAG scope: the overlap tracer locates these point-to-point
    # exchanges in the compiled schedule to verify they ride inside the
    # in-flight reduction windows (DESIGN.md §6/§12).
    with jax.named_scope(HALO_TAG):
        above = lax.ppermute(g[-1:], axis, [(i, i + 1) for i in range(n - 1)])
        below = lax.ppermute(g[:1], axis, [(i, i - 1) for i in range(1, n)])
    return above, below


def _apply_2d5_local(x: jax.Array, nxl: int, ny: int, axis: str) -> jax.Array:
    g = x.reshape(nxl, ny)
    up, dn = _halo_first_dim(g, axis)
    gp = jnp.concatenate([up, g, dn], axis=0)          # (nxl+2, ny)
    gy = jnp.pad(g, ((0, 0), (1, 1)))
    out = 4.0 * g - gp[:-2] - gp[2:] - gy[:, :-2] - gy[:, 2:]
    return out.reshape(-1)


def _apply_3d7_local(
    x: jax.Array, nxl: int, ny: int, nz: int, eps_z: float, axis: str
) -> jax.Array:
    g = x.reshape(nxl, ny, nz)
    up, dn = _halo_first_dim(g, axis)
    gp = jnp.concatenate([up, g, dn], axis=0)
    gy = jnp.pad(g, ((0, 0), (1, 1), (0, 0)))
    gz = jnp.pad(g, ((0, 0), (0, 0), (1, 1)))
    ez = jnp.asarray(eps_z, dtype=x.dtype)
    out = (
        (4.0 + 2.0 * ez) * g
        - gp[:-2] - gp[2:]
        - gy[:, :-2, :] - gy[:, 2:, :]
        - ez * gz[:, :, :-2] - ez * gz[:, :, 2:]
    )
    return out.reshape(-1)


def _apply_3d27_local(
    x: jax.Array, nxl: int, ny: int, nz: int, centre: float, axis: str
) -> jax.Array:
    g = x.reshape(nxl, ny, nz)
    up, dn = _halo_first_dim(g, axis)
    gp = jnp.concatenate([up, g, dn], axis=0)          # (nxl+2, ny, nz)
    gp = jnp.pad(gp, ((0, 0), (1, 1), (1, 1)))         # pad y,z of halo too
    out = jnp.asarray(centre, x.dtype) * g
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                order = abs(di) + abs(dj) + abs(dk)
                if order == 0:
                    continue
                w = {1: 1.0, 2: 0.5, 3: 0.25}[order]
                out = out - w * gp[
                    1 + di : 1 + di + nxl,
                    1 + dj : 1 + dj + ny,
                    1 + dk : 1 + dk + nz,
                ]
    return out.reshape(-1)


# --------------------------------------------------------------------------
# Partitioning of operators / preconditioners into (sharded arrays, builder).
# --------------------------------------------------------------------------

def _partition_op(op: LinearOperator, n_shards: int):
    """Return (arrays, build, perm) where ``arrays`` is a pytree of global
    arrays sharded over the solver axis, ``build(local_arrays, axis)``
    yields the local apply function (for use INSIDE shard_map), and
    ``perm`` is the global row ordering the partition imposed
    (``perm[new] = old``; None when the operator keeps its own order).

    Structured operators partition for free (their halo is a boundary
    plane); a general :class:`SparseOp` goes through the partitioning
    layer (``repro.linalg.partition``, DESIGN.md §12): RCM ordering →
    contiguous row blocks → precomputed send/recv index sets, making the
    shard-level SpMV local-rows + ``ppermute`` halo gather.
    """
    if isinstance(op, SparseOp):
        plan = partition_mod.plan_for(op, n_shards)
        arrays = {
            "cols": plan.cols, "vals": plan.vals,
            "send_up": plan.send_up, "send_dn": plan.send_dn,
        }
        use_kernel = op.use_kernel

        def build(loc, axis):
            return lambda x: partition_mod.apply_local(
                x, loc["cols"][0], loc["vals"][0],
                loc["send_up"][0], loc["send_dn"][0], axis,
                use_kernel=use_kernel,
            )

        perm = None if plan.identity_perm else plan.perm
        return arrays, build, perm

    if isinstance(op, DiagonalOp):
        arrays = {"d": op.d}

        def build(loc, axis):
            return lambda x: loc["d"].astype(x.dtype) * x

        return arrays, build, None

    if isinstance(op, Stencil2D5):
        assert op.nx % n_shards == 0, (op.nx, n_shards)
        nxl = op.nx // n_shards
        return {}, lambda loc, axis: partial(
            _apply_2d5_local, nxl=nxl, ny=op.ny, axis=axis
        ), None

    if isinstance(op, Stencil3D7):
        assert op.nx % n_shards == 0, (op.nx, n_shards)
        nxl = op.nx // n_shards
        return {}, lambda loc, axis: partial(
            _apply_3d7_local, nxl=nxl, ny=op.ny, nz=op.nz, eps_z=op.eps_z, axis=axis
        ), None

    if isinstance(op, Stencil3D27):
        assert op.nx % n_shards == 0, (op.nx, n_shards)
        nxl = op.nx // n_shards
        return {}, lambda loc, axis: partial(
            _apply_3d27_local, nxl=nxl, ny=op.ny, nz=op.nz, centre=op.centre,
            axis=axis,
        ), None

    raise TypeError(f"no distributed implementation for {type(op).__name__}")


def _partition_prec(prec, op: LinearOperator, n_shards: int, perm=None):
    """As ``_partition_op`` for the preconditioner.  ``perm`` is the row
    ordering the operator partition imposed: pointwise preconditioners
    are permuted to match; block-structured ones cannot be re-blocked
    after the fact — pre-order the operator (``sparse.rcm_reorder``) and
    factor the preconditioner in that basis instead."""
    if prec is None or isinstance(prec, IdentityPrec):
        return {}, lambda loc, axis: (lambda x: x)
    if isinstance(prec, JacobiPrec):
        inv_diag = prec.inv_diag if perm is None \
            else prec.inv_diag[jnp.asarray(perm)]
        arrays = {"inv_diag": inv_diag}
        return arrays, lambda loc, axis: (
            lambda x: loc["inv_diag"].astype(x.dtype) * x
        )
    if perm is not None:
        raise TypeError(
            f"{type(prec).__name__} is block-structured and cannot follow "
            "the partitioner's RCM reordering; reorder the operator first "
            "(repro.linalg.sparse.rcm_reorder) and build the "
            "preconditioner from the ordered operator")
    if isinstance(prec, BlockJacobi):
        nb, bs, _ = prec.inv_blocks.shape
        assert (op.n // n_shards) % bs == 0, (
            "block-Jacobi blocks must be interior to a shard "
            f"(local size {op.n // n_shards}, block {bs})"
        )

        def build(loc, axis):
            def apply(x):
                inv = loc["inv_blocks"]
                nbl = inv.shape[0]
                y = jnp.einsum(
                    "nij,nj->ni", inv.astype(x.dtype), x.reshape(nbl, bs)
                )
                return y.reshape(-1)

            return apply

        return {"inv_blocks": prec.inv_blocks}, build
    raise TypeError(f"no distributed implementation for {type(prec).__name__}")


def _fused_spmv_local(op, loc, n_shards: int, axis: str):
    """Shard-level :class:`~repro.kernels.fused_iter.FusedSpmv` for the
    fused-iteration superkernel (DESIGN.md §13): the halo exchange stays
    OUTSIDE the kernel in ``prepare`` (one HALO_TAG'd ppermute per
    direction/hop, riding the open reduction windows exactly as the
    unfused path, DESIGN.md §12); the kernel then evaluates the same
    local stencil / ELL expression as the unfused shard apply, so row
    updates stay bitwise.  None when the operator has no fused path.
    """
    from repro.kernels import fused_iter as fi

    if isinstance(op, DiagonalOp):
        return fi.diagonal_spmv(loc["d"])
    if isinstance(op, SparseOp):
        if op.use_kernel:
            return None              # kernel-in-kernel: no fused mirror
        cols, vals = loc["cols"][0], loc["vals"][0]
        send_up, send_dn = loc["send_up"][0], loc["send_dn"][0]
        nxl = cols.shape[0]
        hops, max_send = send_up.shape

        def prep_sparse(z):
            return partition_mod.halo_exchange(z, send_up, send_dn, axis)

        return fi.ell_spmv(cols, vals, prep_sparse,
                           nxl + 2 * hops * max_send)
    if getattr(op, "use_kernel", False):
        return None
    if isinstance(op, Stencil2D5):
        nxl, ny = op.nx // n_shards, op.ny

        def prep2d(z):
            g = z.reshape(nxl, ny)
            up, dn = _halo_first_dim(g, axis)
            return jnp.concatenate([up, g, dn], axis=0).reshape(-1)

        def expr2d(zf):
            gp = zf.reshape(nxl + 2, ny)
            g = gp[1:-1]
            gy = jnp.pad(g, ((0, 0), (1, 1)))
            out = 4.0 * g - gp[:-2] - gp[2:] - gy[:, :-2] - gy[:, 2:]
            return out.reshape(-1)

        return fi.resident_spmv(expr2d, prep2d, (nxl + 2) * ny)
    if isinstance(op, Stencil3D7):
        nxl, ny, nz, eps_z = op.nx // n_shards, op.ny, op.nz, op.eps_z

        def prep3d(z):
            g = z.reshape(nxl, ny, nz)
            up, dn = _halo_first_dim(g, axis)
            return jnp.concatenate([up, g, dn], axis=0).reshape(-1)

        def expr3d(zf):
            gp = zf.reshape(nxl + 2, ny, nz)
            g = gp[1:-1]
            gy = jnp.pad(g, ((0, 0), (1, 1), (0, 0)))
            gz = jnp.pad(g, ((0, 0), (0, 0), (1, 1)))
            ez = jnp.asarray(eps_z, dtype=zf.dtype)
            out = (
                (4.0 + 2.0 * ez) * g
                - gp[:-2] - gp[2:]
                - gy[:, :-2, :] - gy[:, 2:, :]
                - ez * gz[:, :, :-2] - ez * gz[:, :, 2:]
            )
            return out.reshape(-1)

        return fi.resident_spmv(expr3d, prep3d, (nxl + 2) * ny * nz)
    return None


def _fused_factory_dist(op, prec, loc, n_shards: int, axis: str):
    """``SolverOps.fused_iter_factory`` for the shard_map substrate, or
    None for unsupported (operator, preconditioner) pairs."""
    from repro.kernels import fused_iter as fi
    from repro.kernels.ops import _interpret_default

    if prec is None or isinstance(prec, IdentityPrec):
        inv_diag = None
    elif isinstance(prec, JacobiPrec):
        inv_diag = loc["inv_diag"]
    else:
        return None                  # block solves are not pointwise
    spmv = _fused_spmv_local(op, loc, n_shards, axis)
    if spmv is None:
        return None

    def factory(layout, interpret=None, block_n=None):
        interp = _interpret_default() if interpret is None else interpret
        return fi.build_fused_iteration(layout, spmv, inv_diag,
                                        block_n=block_n, interpret=interp)

    return factory


def partitioned_solver_ops(op, prec, n_shards: int, axis: str = "shards",
                           reduction=None):
    """(arrays, build, perm) for a full SolverOps: build(local_arrays,
    axis) must be called inside shard_map; dot_block is ONE fused psum
    over ``axis``.  ``perm`` (``perm[new] = old``, or None) is the row
    ordering the partition imposed — callers permute b on the way in and
    un-permute x on the way out (the solver runs entirely in the
    permuted basis; every scalar it derives is permutation-invariant).

    ``reduction`` (a :class:`repro.parallel.reduction.StagedConfig`, or
    None for the monolithic psum) swaps the dot-block combine for the
    staged ring ladder (DESIGN.md §14): the start site parks local
    partials in a gather buffer, the solver advances one REDUCE_TAG'd
    ``ppermute`` hop group per iteration, and the wait finishes the ring
    and reduces the partials in rank order — the compiled dot block then
    carries NO all-reduce at all (asserted in tests/test_distributed.py).
    """
    op_arrays, op_build, perm = _partition_op(op, n_shards)
    pr_arrays, pr_build = _partition_prec(prec, op, n_shards, perm)
    arrays = {"op": op_arrays, "prec": pr_arrays}

    def build(loc) -> SolverOps:
        from repro.parallel import reduction as reduction_mod

        apply_a = op_build(loc["op"], axis)
        prec_fn = pr_build(loc["prec"], axis)

        def dot_block(mat, vec):
            # (K5): all local contributions + ONE global reduction.
            # dot_block_rows (not mat @ vec) so local partials round
            # identically to the superkernel's VMEM accumulation and to
            # the vmapped slab path (types.dot_block_rows).
            return lax.psum(dot_block_rows(mat, vec), axis)

        # create() tags the issue/consume sites for the overlap tracer
        # (DESIGN.md §6) — monolithic: the psum above is the
        # MPI_Iallreduce payload and combine_partials its superkernel
        # half (ONE psum of the VMEM-accumulated local dot partials,
        # DESIGN.md §13); staged: the whole handle life cycle comes from
        # the ladder subsystem (same tagged sites, zero all-reduces).
        if reduction is None:
            staged_kw = dict(combine_partials=lambda p: lax.psum(p, axis))
        else:
            cfg = dataclasses.replace(reduction, n_shards=n_shards,
                                      axis=axis)
            staged_kw = reduction_mod.staged_ops_pieces(cfg)
        return SolverOps.create(
            apply_a=apply_a, prec=prec_fn, dot_block=dot_block,
            fused_iter_factory=_fused_factory_dist(
                op, prec, {**loc["op"], **loc["prec"]}, n_shards, axis),
            **staged_kw,
        )

    return arrays, build, perm


def _permutation_wrappers(perm):
    """(pre, post) callables for a partition-imposed row ordering: ``pre``
    maps an (n,) or (n, s) operand into the permuted basis, ``post`` maps
    a SolveResult's solution back.  Identity pass-throughs for None."""
    if perm is None:
        return (lambda b: b), (lambda res: res)
    pj = jnp.asarray(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    ij = jnp.asarray(inv)

    def pre(b):
        return b[pj]

    def post(res: SolveResult) -> SolveResult:
        x = res.x
        # single-RHS x is (n,); batched results carry a leading s-axis.
        return res._replace(x=x[ij] if x.ndim == 1 else x[..., ij])

    return pre, post


# One dispatch table for every substrate (repro.core.METHODS).
_METHODS = METHODS


def batched_state_specs(method: str, state_shapes, axis: str):
    """Partition specs for a batched slab state under ``shard_map``.

    ``state_shapes`` is the ``jax.eval_shape`` pytree of the batched
    state (leading s-axis).  Vector-valued leaves (trailing axis = the
    domain-decomposed n, per ``repro.core.batched.vector_mask``) shard
    their LAST axis over ``axis``; windows, scalars and histories are
    replicated (they are derived from psum'd dot blocks, hence identical
    on every shard)."""
    mask = batched_mod.vector_mask(method)

    def spec(sh, is_vec):
        if not is_vec:
            return P()
        return P(*([None] * (sh.ndim - 1) + [axis]))

    return jax.tree.map(spec, state_shapes, mask)


def batched_result_specs(axis: str, telemetry: bool = False,
                         governor: bool = False) -> SolveResult:
    """Out-specs of a stacked (leading s-axis) SolveResult: x is (s, n)
    with n domain-decomposed; everything else replicated.  ``telemetry``
    mirrors whether the solve is instrumented (telemetry_cap > 0): the
    telemetry ring is replicated scalar state (P()), and None on plain
    solves — None is an empty pytree subtree, so both shapes of result
    match their spec (DESIGN.md §16).  ``governor`` mirrors whether the
    solve is governed (same contract: replicated scalar state when
    armed, absent otherwise — DESIGN.md §18)."""
    return SolveResult(x=P(None, axis), iters=P(), restarts=P(),
                       converged=P(), res_history=P(), norm0=P(),
                       telemetry=P() if telemetry else None,
                       governor=P() if governor else None)


def distributed_solve_batched(
    mesh: Mesh,
    op: LinearOperator,
    B: jax.Array,
    method: str = "plcg",
    prec=None,
    jit: bool = True,
    reduction=None,
    **kwargs,
):
    """Solve A X = B for all s columns of B (n, s) in lock-step, domain-
    decomposed over ``mesh`` — per iteration ONE fused psum of the whole
    (K, s) dot-block matrix (DESIGN.md §11), or its staged ring-ladder
    equivalent when ``reduction`` names a StagedConfig (DESIGN.md §14).
    Mirrors :func:`distributed_solve`; the result's leaves carry a
    leading s-axis.
    """
    axis = mesh.axis_names[0]
    n_shards = mesh.devices.size
    assert B.shape[0] % n_shards == 0
    arrays, build, perm = partitioned_solver_ops(op, prec, n_shards, axis,
                                                 reduction=reduction)
    pre, post = _permutation_wrappers(perm)

    def run(B_local, local_arrays):
        ops = build(local_arrays)
        return batched_mod.solve_batched(ops, B_local, method, **kwargs)

    arr_specs = jax.tree.map(lambda _: P(axis), arrays)
    inner = shard_map_compat(
        run, mesh=mesh, in_specs=(P(axis, None), arr_specs),
        out_specs=batched_result_specs(
            axis, telemetry=bool(kwargs.get("telemetry_cap", 0)),
            governor=kwargs.get("governor") is not None),
    )

    def fn(B, arrays):
        return post(inner(pre(B), arrays))

    if not jit:
        return fn, arrays
    return jax.jit(fn)(B, arrays)


def distributed_checkpointed_solve(
    mesh: Mesh,
    op: LinearOperator,
    b: jax.Array,
    method: str = "plcg",
    prec=None,
    reduction=None,
    checkpoint=None,
    x0=None,
    pieces: bool = False,
    **kwargs,
):
    """Checkpointed solve on the shard_map substrate (DESIGN.md §19).

    The segmented driver of ``repro.checkpoint`` with every compiled
    piece shard_map-wrapped: ``seg`` runs the solver between interrupt
    boundaries (the in-loop arithmetic is the UNCHANGED program pieces,
    so histories stay bitwise vs the monolithic while-loop), ``gather``
    all-gathers the domain-decomposed vector leaves into fully
    replicated hosts arrays at each drained-ring boundary, and only
    process 0 writes.  The host evaluates ``cond``/``needs_interrupt``
    directly on the replicated scalar leaves — deterministic, so every
    process takes the same branch (SPMD-safe).  Snapshots store the
    state in the partition-imposed row ordering (``perm``); stencil and
    diagonal operators impose none, which is what makes their restores
    substrate-elastic.
    """
    from repro import checkpoint as ckpt_mod
    from repro.core.batched import BUILDERS
    from repro.parallel.reduction import oracle_solver_ops

    cfg = checkpoint
    axis = mesh.axis_names[0]
    n_shards = mesh.devices.size
    assert b.shape[0] % n_shards == 0
    arrays, build, perm = partitioned_solver_ops(op, prec, n_shards, axis,
                                                 reduction=reduction)
    pre, post = _permutation_wrappers(perm)
    kw = ckpt_mod.effective_kw(method, kwargs, cfg.every)
    b_p = pre(jnp.asarray(b))
    x0_p = jnp.zeros_like(b_p) if x0 is None else pre(x0.astype(b_p.dtype))

    # Host-side program: cond / needs_interrupt touch only replicated
    # scalar leaves and finish only slices the state, so a shape-oracle
    # ops (never executed through a collective) is sufficient.  The
    # staged oracle shares the staged mesh's handle-ring structure
    # (DESIGN.md §14), so the eval_shape'd state tree matches.
    ops_shape = SolverOps.local(op, prec) if reduction is None else \
        oracle_solver_ops(op, prec, dataclasses.replace(
            reduction, n_shards=n_shards, axis=None))
    prog_host = BUILDERS[method](ops_shape, b_p, **kw)
    if prog_host.needs_interrupt is None or prog_host.interrupt is None:
        raise ckpt_mod.CheckpointError(
            f"method {method!r} exposes no interrupt boundary")
    st_shapes = jax.eval_shape(prog_host.init,
                               jax.ShapeDtypeStruct(b_p.shape, b_p.dtype))
    vec = batched_mod.vector_mask(method)
    st_specs = jax.tree.map(
        lambda sh, v: P(*([None] * (sh.ndim - 1) + [axis])) if v else P(),
        st_shapes, vec)
    arr_specs = jax.tree.map(lambda _: P(axis), arrays)

    def _prog(bl, loc):
        return BUILDERS[method](build(loc), bl, **kw)

    def _init(bl, xl, loc):
        return _prog(bl, loc).init(xl)

    def _seg(bl, st, loc):
        p = _prog(bl, loc)
        return lax.while_loop(lambda t: p.cond(t) & ~p.needs_interrupt(t),
                              p.step, st)

    def _int(bl, st, loc):
        return _prog(bl, loc).interrupt(st)

    rel_fn = ckpt_mod.make_rel_fn(method, kw)

    def _rel(bl, st, loc):
        return rel_fn(build(loc), bl, st)

    def _gather(st):
        # Vector leaves -> fully replicated global arrays (tiled
        # all_gather on the trailing n axis); everything else is already
        # replicated at a drained-ring boundary (post-psum scalars) —
        # EXCEPT the in-flight D ring, which the checkpoint excludes.
        return jax.tree.map(
            lambda v, is_vec: lax.all_gather(v, axis, axis=v.ndim - 1,
                                             tiled=True) if is_vec else v,
            st, vec)

    sm = partial(shard_map_compat, mesh=mesh)
    init_j = jax.jit(sm(_init, in_specs=(P(axis), P(axis), arr_specs),
                        out_specs=st_specs))
    seg_j = jax.jit(sm(_seg, in_specs=(P(axis), st_specs, arr_specs),
                       out_specs=st_specs))
    int_j = jax.jit(sm(_int, in_specs=(P(axis), st_specs, arr_specs),
                       out_specs=st_specs))
    rel_j = jax.jit(sm(_rel, in_specs=(P(axis), st_specs, arr_specs),
                       out_specs=P()))
    gather_j = jax.jit(sm(_gather, in_specs=(st_specs,),
                          out_specs=jax.tree.map(lambda _: P(), st_shapes)))

    st = init_j(b_p, x0_p, arrays)
    if pieces:
        # Structural introspection for tests: the EXACT jitted pieces
        # the segmented driver runs (lowerable for HLO assertions —
        # e.g. "the seg piece keeps one pipelined reduction start per
        # iteration"), plus the initial state to lower them against.
        return {"init": init_j, "seg": seg_j, "interrupt": int_j,
                "rel": rel_j, "gather": gather_j, "state": st,
                "b_p": b_p, "arrays": arrays, "prog_host": prog_host}
    mask = ckpt_mod.solve.exclude_mask(method, st)
    meta_base = ckpt_mod.solve.solver_meta(method, b.shape[0], b.dtype, kw,
                                           cfg.every)
    meta_base["treedef"] = ckpt_mod.solve.state_treedef_str(st)
    rel_of = lambda s: rel_j(b_p, s, arrays)
    if cfg.resume:
        st = ckpt_mod.solve.try_restore(st, cfg, meta_base, mask, rel_of)
    snapshot = ckpt_mod.solve.make_snapshot_fn(
        cfg, meta_base, mask, method, rel_of, gather=gather_j,
        is_writer=jax.process_index() == 0)
    st = ckpt_mod.run_segmented(
        st, cond=prog_host.cond, needs=prog_host.needs_interrupt,
        seg=lambda s: seg_j(b_p, s, arrays), method=method,
        interrupt=lambda s: int_j(b_p, s, arrays), cfg=cfg,
        snapshot=snapshot)
    return post(prog_host.finish(st))


def distributed_solve(
    mesh: Mesh,
    op: LinearOperator,
    b: jax.Array,
    method: str = "plcg",
    prec=None,
    jit: bool = True,
    reduction=None,
    **kwargs,
):
    """Solve A x = b with the chosen CG variant, domain-decomposed over
    ``mesh`` (1-D).  Returns (callable_or_result, lowered-compatible fn).

    ``kwargs`` are forwarded to the solver (l, tol, maxit, sigmas, unroll...).
    ``reduction`` (StagedConfig | None) selects the staged ring ladder
    for the dot block (DESIGN.md §14).
    """
    axis = mesh.axis_names[0]
    n_shards = mesh.devices.size
    assert b.shape[0] % n_shards == 0
    arrays, build, perm = partitioned_solver_ops(op, prec, n_shards, axis,
                                                 reduction=reduction)
    pre, post = _permutation_wrappers(perm)

    def run(b_local, local_arrays):
        ops = build(local_arrays)
        return _METHODS[method](ops, b_local, kwargs)

    out_specs = SolveResult(
        x=P(axis), iters=P(), restarts=P(), converged=P(),
        res_history=P(), norm0=P(),
        # Replicated when instrumented (every recorded scalar is post-psum
        # state), absent otherwise — mirrors SolveResult.telemetry; the
        # governor vector follows the same contract (DESIGN.md §18).
        telemetry=P() if kwargs.get("telemetry_cap", 0) else None,
        governor=P() if kwargs.get("governor") is not None else None,
    )
    arr_specs = jax.tree.map(lambda _: P(axis), arrays)
    inner = shard_map_compat(
        run, mesh=mesh, in_specs=(P(axis), arr_specs), out_specs=out_specs,
    )

    def fn(b, arrays):
        return post(inner(pre(b), arrays))

    if not jit:
        return fn, arrays
    jfn = jax.jit(fn)
    return jfn(b, arrays)
