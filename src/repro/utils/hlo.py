"""HLO text analysis: collective operations and their byte volumes.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic; we recover it by parsing the (optimized) HLO text and summing the
tensor sizes of every collective op (DESIGN.md §2, system-prompt §Roofline).

Byte convention (documented, used consistently everywhere):
  * all-reduce          : payload = output tensor bytes
  * all-gather          : payload = output tensor bytes (gathered size)
  * reduce-scatter      : payload = input  tensor bytes (pre-scatter size)
  * all-to-all          : payload = output tensor bytes
  * collective-permute  : payload = output tensor bytes

On-wire cost per device is payload × ring_factor / n_participants where the
ring factor is 2(n-1)/n for all-reduce and (n-1)/n for gather/scatter-type
ops; that conversion happens in ``repro.utils.roofline`` so that this module
stays a pure parser.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

# Collective op kinds of interest.  HLO spells them e.g. "all-reduce",
# "all-reduce-start", "all-gather", "reduce-scatter", "all-to-all",
# "collective-permute", and fused async forms.
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction: "  %name = <shape or tuple> opcode(...)..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(",
)


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _canon_kind(opcode: str) -> str | None:
    for kind in _COLLECTIVES:
        if opcode == kind or opcode == kind + "-start":
            return kind
    return None


def count_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-collective-kind {count, bytes} from HLO text.

    Uses the op *output* shape for every kind except reduce-scatter, where
    the input shape (inside the parens) is the payload; `-done` ops are
    skipped so async pairs are counted once.
    """
    out: dict[str, dict[str, float]] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, opcode = m.group(1), m.group(2)
        kind = _canon_kind(opcode)
        if kind is None:
            continue
        if kind == "reduce-scatter":
            # payload = operand size: parse shapes inside the call parens
            paren = line[m.end() :].split("),")[0]
            nbytes = parse_shape_bytes(paren)
            if nbytes == 0:
                nbytes = parse_shape_bytes(shape_str)
        else:
            nbytes = parse_shape_bytes(shape_str)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return dict(out)


def collective_bytes(hlo_text: str) -> int:
    """Total collective payload bytes (all kinds)."""
    return int(sum(v["bytes"] for v in count_collectives(hlo_text).values()))


@dataclasses.dataclass
class CollectiveSummary:
    per_kind: dict[str, dict[str, float]]

    @property
    def total_bytes(self) -> int:
        return int(sum(v["bytes"] for v in self.per_kind.values()))

    @property
    def total_count(self) -> int:
        return int(sum(v["count"] for v in self.per_kind.values()))

    def __str__(self) -> str:
        rows = [
            f"  {k:<20s} count={int(v['count']):5d} bytes={v['bytes']:.3e}"
            for k, v in sorted(self.per_kind.items())
        ]
        return "\n".join(rows) if rows else "  (no collectives)"


def summarize_collectives(hlo_text: str) -> CollectiveSummary:
    return CollectiveSummary(per_kind=count_collectives(hlo_text))
