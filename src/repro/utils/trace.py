"""Overlap tracer: recover the staggered in-flight reductions from HLO
(DESIGN.md §6 — the measurement behind the paper's Fig. 4 'staggering').

The p(l)-CG claim is *structural*: the fused dot block initiated at
iteration i is first consumed at iteration i+l, so up to l global
reductions are simultaneously in flight.  This module verifies the claim
on the *compiled, scheduled* HLO rather than trusting the Python source:

1.  Every reduction backend tags the issue site (``GLRED_START_TAG``) and
    the solvers tag the consumption site (``GLRED_WAIT_TAG``) with
    ``jax.named_scope``.  The scopes survive XLA optimization as
    instruction ``metadata op_name``.
2.  ``plcg_overlap_report`` stages a *flat window* of ``window`` raw
    p(l)-CG iterations (no ``lax.while_loop``) through a backend, each
    iteration wrapped in a ``plwin{k}`` scope, and compiles it.  This is
    the same code window ``unroll`` exposes to XLA inside the production
    while-loop, laid out where the whole schedule is one entry
    computation.
3.  ``analyze_overlap`` walks the entry computation's instruction
    sequence (the schedule), finds per-window start events (the tagged
    all-reduces / dot blocks) and wait events (the tagged arrival
    scatter), and counts, at every consumption point, how many chains
    are already issued but not yet consumed.

For p(l)-CG with ``window >= l+1`` a healthy pipeline reports
``max_in_flight >= l``; classic CG reports 1 (each reduction is waited
before the next is issued) — the Table 1 contrast, now measured.
"""

from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp

from repro.core import pipelined_cg
from repro.core.types import (GLRED_START_TAG, GLRED_WAIT_TAG, HALO_TAG,
                              REDUCE_TAG)
from repro.utils.hlo import count_collectives

# Window scope prefix used by the flat trace harness (and by the unrolled
# while-loop driver, which uses "plu{k}").
WINDOW_SCOPE = "plwin"

# HLO opcodes that implement a started reduction on a distributed
# substrate.  On the local backend the tagged op is the dot itself.
_COLLECTIVE_START_OPS = ("all-reduce", "all-reduce-start")

# HLO opcodes of the point-to-point halo exchange (``lax.ppermute``),
# tagged HALO_TAG by the distributed SPMVs (structured planes in
# ``parallel.distributed``, unstructured send/recv sets in
# ``linalg.partition``).  ``-done`` halves are skipped so async pairs
# count once.
_PERMUTE_OPS = ("collective-permute", "collective-permute-start")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\([^=]*?\)|\S+)\s+([\w\-]+)\("
)
_OPNAME_RE = re.compile(r'metadata=\{[^}]*?op_name="([^"]*)"')
_WINDOW_RE = re.compile(WINDOW_SCOPE + r"(\d+)(?:\D|$)")
# Staged ring-ladder hops (DESIGN.md §14): ``lax.ppermute`` inside a
# ``glred_hop{k}`` scope, k the global hop index 0..P-2.  Hop 0 is the
# first wire movement of a freshly issued handle — counting hop-0
# permutes per window is the staged substitute for the all-reduce-based
# logical-reduction count (exactly one per iteration, whatever the
# ladder's stage grouping or the slab width s).
_HOP_RE = re.compile(REDUCE_TAG + r"(\d+)(?:\D|$)")


@dataclasses.dataclass(frozen=True)
class ChainEvent:
    """One tagged site in the scheduled entry computation."""

    kind: str          # "start" | "wait" | "halo" | "hop"
    window: int        # plwin{k} iteration index
    pos: int           # instruction position in the entry computation
    opcode: str
    name: str          # HLO instruction name
    hop: int | None = None   # ladder hop index (kind == "hop" only)


@dataclasses.dataclass
class OverlapReport:
    """In-flight reduction chains recovered from one HLO schedule."""

    l: int                          # pipeline depth used for chain pairing
    window: int                     # traced iteration-window length
    events: list[ChainEvent]
    chains: list[tuple[int, int, int | None]]  # (window k, start, wait pos)
    max_in_flight: int              # peak #chains issued but not consumed
    n_collectives: int              # all-reduce count in the module
    collective_bytes: float
    # Tagged collective-start instructions per traced window — the
    # "reduction handles issued per iteration" count.  For a healthy
    # (batched or not) p(l)-CG schedule every window shows exactly 1:
    # batching widens the payload, never the handle count (DESIGN.md §11).
    starts_per_window: dict[int, int] = dataclasses.field(
        default_factory=dict)
    # HALO_TAG'd collective-permutes found in the schedule, and how many
    # of them sit strictly INSIDE an open reduction window (after a
    # chain's start, before its wait) — the paper's second staggering
    # claim: neighbour communication overlaps the in-flight Iallreduce
    # (DESIGN.md §12).  Operators without point-to-point halo (diagonal,
    # single shard) report 0/0.
    n_halo_permutes: int = 0
    halos_in_flight: int = 0
    # Staged ring-ladder metrics (DESIGN.md §14).  ``reduce_hops_per_
    # window``: REDUCE_TAG'd ppermutes per traced window — the ladder
    # traffic the solver advances hop-by-hop (a healthy staged p(l)-CG
    # schedule shows >= l hops in every late window).  ``staged_starts_
    # per_window``: hop-0 permutes per window, the staged analogue of
    # ``starts_per_window`` — exactly 1 per iteration means one logical
    # reduction handle enters the wire per iteration however the hops
    # are grouped or the slab widened.  ``hops_in_flight``: ladder hops
    # scheduled strictly inside open reduction windows — together with
    # ``halos_in_flight`` this is the hop/halo staggering invariant (the
    # reduction's own wire traffic interleaves with neighbour exchange
    # inside the in-flight window).  All zero on monolithic schedules.
    reduce_hops_per_window: dict[int, int] = dataclasses.field(
        default_factory=dict)
    staged_starts_per_window: dict[int, int] = dataclasses.field(
        default_factory=dict)
    n_reduce_hops: int = 0
    hops_in_flight: int = 0

    def __str__(self) -> str:
        staged = ""
        if self.n_reduce_hops:
            staged = (f"; staged ladder: {self.n_reduce_hops} hop(s), "
                      f"{self.hops_in_flight} inside reduction windows, "
                      f"min {min(self.reduce_hops_per_window.values())}"
                      f"/window")
        lines = [
            f"overlap trace: window={self.window} depth l={self.l} -> "
            f"max {self.max_in_flight} reduction chain(s) in flight "
            f"({self.n_collectives} all-reduce(s), "
            f"{self.collective_bytes:.3e} B payload; "
            f"{self.halos_in_flight}/{self.n_halo_permutes} halo "
            f"permute(s) inside reduction windows{staged})"
        ]
        for k, s, w in self.chains:
            tail = f"waited @ {w}" if w is not None else "open at window end"
            lines.append(f"  chain {k:>3d}: issued @ instr {s:>5d}, {tail}")
        return "\n".join(lines)


def _entry_instructions(hlo_text: str) -> list[tuple[str, str, str]]:
    """(name, opcode, op_name-metadata) for the ENTRY computation, in
    schedule (text) order."""
    out = []
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry:
            if line.startswith("}"):
                break
            m = _INSTR_RE.match(line)
            if not m:
                continue
            om = _OPNAME_RE.search(line)
            out.append((m.group(1), m.group(2), om.group(1) if om else ""))
    return out


def extract_events(hlo_text: str) -> list[ChainEvent]:
    """Tagged start/wait events from the scheduled entry computation.

    A start event per window = the first instruction carrying both the
    window scope and GLRED_START_TAG, preferring collective opcodes (the
    all-reduce itself) over the local-matmul fallback.  A wait event per
    window = the first instruction carrying the window scope and
    GLRED_WAIT_TAG (the arrival scatter into the G window).
    """
    instrs = _entry_instructions(hlo_text)
    starts: dict[int, ChainEvent] = {}
    waits: dict[int, ChainEvent] = {}
    halos: list[ChainEvent] = []
    hops: list[ChainEvent] = []
    for pos, (name, opcode, op_name) in enumerate(instrs):
        wm = _WINDOW_RE.search(op_name)
        if wm is None:
            continue
        k = int(wm.group(1))
        # Staged ladder hops are counted on their own axis: a hop that
        # executes inside the wait's scope (the steps the solver had not
        # advanced yet) carries BOTH glred_wait and glred_hop{j} — it is
        # a hop event, never the wait's consumption marker.
        hm = _HOP_RE.search(op_name)
        is_hop = hm is not None and opcode in _PERMUTE_OPS
        if is_hop:
            hops.append(ChainEvent("hop", k, pos, opcode, name,
                                   hop=int(hm.group(1))))
        if GLRED_START_TAG in op_name:
            ev = ChainEvent("start", k, pos, opcode, name)
            cur = starts.get(k)
            is_coll = opcode in _COLLECTIVE_START_OPS
            cur_coll = cur is not None and cur.opcode in _COLLECTIVE_START_OPS
            if cur is None or (is_coll and not cur_coll):
                starts[k] = ev
        elif GLRED_WAIT_TAG in op_name and not is_hop and k not in waits:
            waits[k] = ChainEvent("wait", k, pos, opcode, name)
        elif HALO_TAG in op_name and opcode in _PERMUTE_OPS and not is_hop:
            # Every halo permute is an event (a window has one per
            # direction and hop) — the staggering metric counts them all.
            halos.append(ChainEvent("halo", k, pos, opcode, name))
    evs = list(starts.values()) + list(waits.values()) + halos + hops
    evs.sort(key=lambda e: e.pos)
    return evs


def reduction_starts_per_window(hlo_text: str) -> dict[int, int]:
    """Count tagged COLLECTIVE start instructions per ``plwin{k}`` window.

    This is the per-iteration reduction-handle count: each all-reduce (or
    all-reduce-start) carrying both a window scope and GLRED_START_TAG in
    its op_name is one issued handle.  The batched multi-RHS solvers must
    keep this at exactly 1 per iteration whatever the slab width s — the
    amortization claim of DESIGN.md §11, checked against compiled HLO in
    tests/test_distributed.py."""
    counts: dict[int, int] = {}
    for _name, opcode, op_name in _entry_instructions(hlo_text):
        if opcode not in _COLLECTIVE_START_OPS:
            continue
        if GLRED_START_TAG not in op_name:
            continue
        wm = _WINDOW_RE.search(op_name)
        if wm is None:
            continue
        k = int(wm.group(1))
        counts[k] = counts.get(k, 0) + 1
    return counts


def analyze_overlap(hlo_text: str, l: int, window: int | None = None
                    ) -> OverlapReport:
    """Count outstanding chains at every consumption point.

    Chain k is *in flight* from its start event (window k) until its wait
    event (window k+l).  The peak is measured at the wait events ONLY:
    at each consumption point, how many chains are already issued and not
    yet consumed (the chain being waited counts; trailing chains whose
    wait lies beyond the traced window count when issued, but never form
    a peak on their own).  A fully serialized schedule
    (start, wait, start, wait, ...) therefore reports 1 — the metric is
    falsifiable, not guaranteed by construction — while the paper's
    staggering reports l: the D-ring dataflow forces starts k..k+l-1
    before the consumption of chain k.
    """
    events = extract_events(hlo_text)
    starts = {e.window: e for e in events if e.kind == "start"}
    waits = {e.window: e for e in events if e.kind == "wait"}
    halos = [e for e in events if e.kind == "halo"]
    hops = [e for e in events if e.kind == "hop"]
    if window is None:
        window = max(starts, default=-1) + 1

    chains: list[tuple[int, int, int | None]] = []
    for k, s in sorted(starts.items()):
        w = waits.get(k + l)
        chains.append((k, s.pos, w.pos if w else None))

    peak = 0
    for we in sorted(waits.values(), key=lambda e: e.pos):
        n = sum(
            1 for _k, spos, wpos in chains
            if spos <= we.pos and (wpos is None or wpos >= we.pos)
        )
        peak = max(peak, n)

    # Halo staggering: a permute "rides inside" a reduction window when
    # the schedule places it strictly after a chain's issue and before
    # that chain's consumption — the Iallreduce / neighbour-exchange
    # overlap of the paper, now a measured property of the compiled
    # schedule rather than an assumption.
    halos_in_flight = sum(
        1 for e in halos
        if any(spos < e.pos and (wpos is None or e.pos < wpos)
               for _k, spos, wpos in chains)
    )
    # Hop staggering (DESIGN.md §14): a ladder hop inside an open chain
    # window is reduction wire traffic riding the in-flight window —
    # exactly where the hop-per-iteration advance schedule puts it.
    hops_in_flight = sum(
        1 for e in hops
        if any(spos < e.pos and (wpos is None or e.pos < wpos)
               for _k, spos, wpos in chains)
    )
    hops_per_window: dict[int, int] = {}
    staged_starts: dict[int, int] = {}
    for e in hops:
        hops_per_window[e.window] = hops_per_window.get(e.window, 0) + 1
        if e.hop == 0:
            staged_starts[e.window] = staged_starts.get(e.window, 0) + 1

    colls = count_collectives(hlo_text)
    n_coll = int(sum(v["count"] for kind, v in colls.items()
                     if kind.startswith("all-reduce")))
    cbytes = float(sum(v["bytes"] for kind, v in colls.items()
                       if kind.startswith("all-reduce")))
    return OverlapReport(l=l, window=window, events=events, chains=chains,
                         max_in_flight=peak, n_collectives=n_coll,
                         collective_bytes=cbytes,
                         starts_per_window=reduction_starts_per_window(
                             hlo_text),
                         n_halo_permutes=len(halos),
                         halos_in_flight=halos_in_flight,
                         reduce_hops_per_window=hops_per_window,
                         staged_starts_per_window=staged_starts,
                         n_reduce_hops=len(hops),
                         hops_in_flight=hops_in_flight)


def plcg_overlap_report(
    backend,
    op,
    b,
    l: int,
    window: int | None = None,
    sigmas=None,
    prec=None,
    fused_iteration: bool = False,
    telemetry_cap: int = 0,
    recurrence: str = "ghysels",
    governor=None,
) -> OverlapReport:
    """Trace a flat ``window``-iteration p(l)-CG schedule through
    ``backend`` and report the in-flight reduction chains.

    ``window`` defaults to l+2 — the smallest window exposing the full
    staggering (the paper recommends ``unroll >= l+1`` in production; see
    DESIGN.md §2/§6).  ``b`` may be a ``jax.ShapeDtypeStruct``.

    ``fused_iteration=True`` traces the superkernel path (DESIGN.md §13):
    the vector phase collapses into one Pallas call per window, but the
    reduction structure must be UNCHANGED — still one tagged start per
    iteration (``ops.start_partials``) consumed l windows later, still
    ``max_in_flight >= l`` (asserted in tests/test_fused_iter.py).

    ``telemetry_cap > 0`` traces the INSTRUMENTED solve (DESIGN.md §16):
    the telemetry-ring writes ride the schedule, and the report must show
    the identical reduction structure — the zero-extra-collectives
    invariant, asserted in tests/test_telemetry.py.

    ``recurrence``/``governor`` trace the stable-recurrence and governed
    solves (DESIGN.md §18): the governor is replicated-scalar work in
    the scalar phase, so a governed schedule must STILL show exactly one
    reduction start per window — asserted in tests/test_stability.py.
    """
    window = l + 2 if window is None else window
    if window < 1:
        raise ValueError("window must be >= 1")

    def harness(ops, b_local):
        prog = pipelined_cg.build(ops, b_local, l, tol=0.0,
                                  maxit=window + l + 2, sigmas=sigmas,
                                  fused_iteration=fused_iteration,
                                  telemetry_cap=telemetry_cap,
                                  recurrence=recurrence, governor=governor)
        st = prog.init(jnp.zeros_like(b_local))
        for k in range(window):
            with jax.named_scope(f"{WINDOW_SCOPE}{k}"):
                st = prog.iteration(
                    st, static_phase="late" if k >= l else "early")
        # The history hangs off every arrival — returning it keeps all
        # traced chains (except the trailing un-consumed ones) live.
        # The telemetry ring is returned too so its writes stay live in
        # the instrumented trace (an unused ring would be DCE'd and the
        # zero-overhead assertion would be vacuous); same for the
        # governor vector on governed traces.
        return st.hist, st.cyc.D, st.tel, st.gov

    hlo = backend.lower_hlo(harness, op, b, prec=prec)
    return analyze_overlap(hlo, l=l, window=window)


def batched_plcg_overlap_report(
    backend,
    op,
    B,
    l: int,
    window: int | None = None,
    sigmas=None,
    prec=None,
    fused_iteration: bool = False,
    telemetry_cap: int = 0,
    recurrence: str = "ghysels",
    governor=None,
) -> OverlapReport:
    """Overlap report for the BATCHED multi-RHS p(l)-CG slab
    (DESIGN.md §11): a flat ``window``-iteration schedule of the vmapped
    per-column iteration, staged through ``backend`` with the slab
    B (n, s) domain-decomposed on n.

    The claims this measures: (a) the staggering survives batching —
    ``max_in_flight >= l`` exactly as in the single-RHS trace; (b)
    amortization — ``starts_per_window[k] == 1`` for every window: one
    reduction handle per iteration carrying the whole (2l+1, s) payload,
    not s handles.  ``B`` may be a ``jax.ShapeDtypeStruct``.
    ``telemetry_cap > 0`` traces the instrumented slab (DESIGN.md §16) —
    same invariants, asserted in tests/test_telemetry.py.
    """
    window = l + 2 if window is None else window
    if window < 1:
        raise ValueError("window must be >= 1")

    def harness(ops, B_local):
        def col(bcol):
            prog = pipelined_cg.build(ops, bcol, l, tol=0.0,
                                      maxit=window + l + 2, sigmas=sigmas,
                                      fused_iteration=fused_iteration,
                                      telemetry_cap=telemetry_cap,
                                      recurrence=recurrence,
                                      governor=governor)
            st = prog.init(jnp.zeros_like(bcol))
            for k in range(window):
                with jax.named_scope(f"{WINDOW_SCOPE}{k}"):
                    st = prog.iteration(
                        st, static_phase="late" if k >= l else "early")
            return st.hist, st.cyc.D, st.tel, st.gov

        return jax.vmap(col, in_axes=1)(B_local)

    try:
        from jax.sharding import PartitionSpec as P
        b_spec = P(getattr(backend, "axis", None), None) \
            if hasattr(backend, "axis") else None
    except ImportError:          # pragma: no cover
        b_spec = None
    hlo = backend.lower_hlo(harness, op, B, prec=prec, b_spec=b_spec)
    return analyze_overlap(hlo, l=l, window=window)
