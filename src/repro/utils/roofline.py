"""Roofline terms from compiled dry-run artifacts (system prompt §Roofline).

    compute term    = HLO_FLOPs          / (chips × peak_FLOP/s)
    memory term     = HLO_bytes          / (chips × HBM_bw)
    collective term = collective_seconds (ring-model per-device wire time)

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` FLOPs/bytes are whole-program totals (all devices), so
both are divided by the chip count.  Collective wire time uses the standard
ring model on the payload bytes parsed from HLO:

    all-reduce          2·(n−1)/n · payload / n? — NO: HLO payload is already
                        the per-replica-group tensor; a ring all-reduce moves
                        2·(n−1)/n × payload bytes through each device's link.
    all-gather          (n−1)/n × output bytes
    reduce-scatter      (n−1)/n × input  bytes
    all-to-all          (n−1)/n × payload
    collective-permute  1       × payload (point-to-point)

where n = number of participants (we use the dominant mesh-axis size; for
multi-axis groups this is conservative).
"""

from __future__ import annotations

import dataclasses

from repro.utils.hlo import count_collectives


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax returns a single dict, newer versions a one-element list of
    dicts (one per computation); normalize to a plain dict so callers can
    ``.get("flops")`` unconditionally.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float        # per chip, FLOP/s (bf16)
    hbm_bw: float            # per chip, bytes/s
    link_bw: float           # per ICI link, bytes/s
    hbm_per_chip: float      # bytes
    links_per_chip: int = 6  # v5e: 4 in-plane (2D torus per pod) is realistic;
                             # we charge a single link (worst case serialization)


HW_V5E = Hardware(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_per_chip=16 * 1024**3,
)

_RING_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


@dataclasses.dataclass
class RooflineTerms:
    flops: float             # PER-DEVICE HLO FLOPs (cost_analysis reports
                             # the SPMD-partitioned single-device module —
                             # verified empirically in tests/test_roofline.py)
    hbm_bytes: float         # per-device HLO bytes accessed
    coll_bytes: float        # per-device collective payload bytes (parsed)
    t_compute: float         # seconds
    t_memory: float          # seconds
    t_collective: float      # seconds
    chips: int
    hw: Hardware
    per_kind: dict

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time if the two non-dominant terms fully overlap
        the dominant one (perfect latency hiding)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def useful_fraction(self, model_flops: float) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — useful share of compiled
        compute (catches remat/redundancy waste)."""
        tot = self.flops * self.chips
        return model_flops / tot if tot else float("nan")

    def mfu(self, model_flops: float) -> float:
        """Model-FLOPs utilization at the roofline-bound step time."""
        denom = self.t_bound * self.chips * self.hw.peak_flops
        return model_flops / denom if denom else float("nan")

    def row(self) -> str:
        return (
            f"compute {self.t_compute:.3e}s | memory {self.t_memory:.3e}s | "
            f"collective {self.t_collective:.3e}s | dominant={self.dominant}"
        )


def roofline_terms(
    cost_analysis: dict,
    hlo_text: str,
    chips: int,
    hw: Hardware = HW_V5E,
) -> RooflineTerms:
    flops = float(cost_analysis.get("flops", 0.0) or 0.0)
    hbm = float(cost_analysis.get("bytes accessed", 0.0) or 0.0)
    per_kind = count_collectives(hlo_text)

    t_coll = 0.0
    coll_bytes = 0.0
    for kind, v in per_kind.items():
        coll_bytes += v["bytes"]
        t_coll += _RING_FACTOR[kind](chips) * v["bytes"] / hw.link_bw
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll_bytes,
        t_compute=flops / hw.peak_flops,      # per-device numerators
        t_memory=hbm / hw.hbm_bw,
        t_collective=t_coll,
        chips=chips,
        hw=hw,
        per_kind=per_kind,
    )


def dense_model_flops(n_params: float, tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D for a training step over D tokens."""
    return 6.0 * n_params * tokens


def forward_model_flops(n_params: float, tokens: float) -> float:
    """2·N·D for inference (prefill/decode) steps."""
    return 2.0 * n_params * tokens
