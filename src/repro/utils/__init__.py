from repro.utils.hlo import collective_bytes, count_collectives, parse_shape_bytes
from repro.utils.roofline import HW_V5E, RooflineTerms, cost_analysis_dict, roofline_terms
from repro.utils.trace import (
    OverlapReport,
    analyze_overlap,
    extract_events,
    plcg_overlap_report,
)

__all__ = [
    "collective_bytes",
    "count_collectives",
    "parse_shape_bytes",
    "HW_V5E",
    "RooflineTerms",
    "cost_analysis_dict",
    "roofline_terms",
    "OverlapReport",
    "analyze_overlap",
    "extract_events",
    "plcg_overlap_report",
]
