from repro.utils.hlo import collective_bytes, count_collectives, parse_shape_bytes
from repro.utils.roofline import HW_V5E, RooflineTerms, roofline_terms

__all__ = [
    "collective_bytes",
    "count_collectives",
    "parse_shape_bytes",
    "HW_V5E",
    "RooflineTerms",
    "roofline_terms",
]
