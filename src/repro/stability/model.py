"""Attainable-accuracy gap model + governor state layout (DESIGN.md §18).

Deep pipelines trade synchronization for rounding-error amplification:
the recursive residual of p(l)-CG drifts away from the true residual
``b - A x`` as local rounding errors are propagated through the
multi-term basis recurrences (the attainable-accuracy analysis of
Cools et al., arXiv:1804.02962).  The *governor* tracks a cheap upper
bound on that drift — the predicted true-vs-recursive residual **gap**
— using ONLY scalars the solver already holds in its scalar phase (the
arrived 2l+1 dot block and the freshly formed Hessenberg entries), so
detection costs zero extra reductions and zero vector traffic.

Two detection arms, both evaluated on replicated scalar state:

* **gap arm** — the accumulated gap estimate crosses into the residual:
  ``safety * gap >= rnorm/norm0``.  The recursive residual can no
  longer be distinguished from its own rounding noise, so the governor
  schedules a residual replacement (cycle re-init from the current
  iterate, which recomputes ``b - A x`` in clean arithmetic).  The
  estimate is not purely modeled: every restart MEASURES the actual
  true-vs-recursive discrepancy (the restart recomputes the true
  residual M-norm anyway) and converts it into a per-iteration drift
  RATE that floors the next cycle's gap growth, so a solver whose
  reductions are corrupted beyond the first-order eps model
  (``repro.chaos``) is caught on the first restart and governed at an
  adaptive replacement period afterwards.
* **patience arm** — the relative recursive residual has not improved
  by ``improve_ratio`` for ``patience`` solution updates: flat
  stagnation the gap model cannot see (e.g. catastrophic corruption
  that keeps the recursion bouncing around a floor).

A governed solve certifies convergence against the TRUE residual: the
recursive residual reaching tol schedules a *verification* replacement
instead of terminating, and only a replacement whose measured true
residual is below tol sets ``converged`` (the sequential solver's
"lucky breakdown" check).  A governed result therefore never reports a
converged flag its true residual does not back — the silent
false-convergence mode of corrupted deep pipelines is structurally
closed (tests/test_stability.py).

Replacements that keep failing to improve the true residual
(``demote_after`` consecutive fruitless replacements) flip the terminal
``STAGNATED`` flag: the solve stops early with a typed diagnosis
instead of silently burning ``maxit`` (``repro.stability.governor``
then demotes the pipeline depth or raises :class:`StagnationError`).

Everything in this module is pure jnp on small scalars — importable
from the solver core without cycles, and property-testable in
isolation (tests/test_stability_properties.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- slots --
# The governor's state is one flat (N_SLOTS,) float vector carried in the
# solver state (``_State.gov``) — a leaf, not a pytree, so vmap/shard_map
# treat it exactly like the other replicated scalars.
GAP = 0          # accumulated relative true-vs-recursive residual gap
BEST = 1         # best rnorm/norm0 seen so far (patience reference)
BEST_UPD = 2     # solution-update count when BEST last improved
DUE = 3          # pending action code: 0 none, 1 gap arm, 2 patience arm
REPL = 4         # governor-triggered residual replacements so far
FRUITLESS = 5    # consecutive replacements without true-residual progress
STAGNATED = 6    # terminal: demote_after fruitless replacements (0/1)
LAST_REL = 7     # true rnorm/norm0 recorded at the last replacement
RATE = 8         # measured per-iteration gap growth from the last cycle
N_SLOTS = 9

# Telemetry "action" column codes (kernels.fused_iter.tel_layout).
ACTION_NONE = 0.0
ACTION_GAP_REPLACE = 1.0
ACTION_PATIENCE_REPLACE = 2.0
ACTION_STAGNATED = 3.0


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """Stability-governor policy knobs (DESIGN.md §18).

    ``safety``        gap-arm trigger margin: act when
                      ``safety * gap >= rnorm/norm0``.
    ``patience``      solution updates without an ``improve_ratio``
                      improvement before the patience arm fires;
                      0 (default) auto-resolves to ``max(32, 8l)`` —
                      several pipeline refills, wide enough that the
                      plateaus of an ordinary converging CG run never
                      trip it (a plateau still improves a few percent
                      per window; genuine stagnation improves nothing).
    ``improve_ratio`` "improved" means rel residual < ratio * best;
                      0.99 accepts any 1% improvement per window.
    ``demote_after``  consecutive fruitless replacements before the
                      solve is declared stagnated (terminal).
    ``eps``           unit roundoff seeding the gap model; None uses
                      the solve dtype's machine epsilon.  The seed only
                      matters until the first restart measures the real
                      discrepancy.
    ``kappa``         gap-model scale factor (operator-conditioning
                      fudge; 1.0 is the plain first-order model).
    """

    safety: float = 4.0
    patience: int = 0
    improve_ratio: float = 0.99
    demote_after: int = 3
    eps: float | None = None
    kappa: float = 1.0

    def resolved_patience(self, l: int) -> int:
        return int(self.patience) if self.patience > 0 else max(32, 8 * l)

    def resolved_eps(self, dtype) -> float:
        return float(jnp.finfo(dtype).eps) if self.eps is None else float(self.eps)


def gov_init(dtype) -> jax.Array:
    """Initial governor vector: gap 0, BEST = 1 (rel residual starts at
    1 by definition), LAST_REL = 1, everything else 0."""
    g = jnp.zeros((N_SLOTS,), dtype)
    return g.at[BEST].set(1.0).at[LAST_REL].set(1.0)


def gap_step(gap, gam_new, d2, dlt_safe, basis, eps, kappa=1.0):
    """One first-order update of the accumulated gap estimate.

    Per late iteration the local rounding error injected into the
    recursive residual is O(eps) times the magnitude of the recurrence
    coefficients applied to the basis — here summarized as

        amp   = (1 + |gam_new| + |d2|) / |dlt_safe|
        gap' = gap + kappa * eps * amp * max(basis, 1)

    with ``basis`` the current basis-vector scale (the solver feeds
    ``sqrt(|G(col,col)|)`` from the already-arrived dot block).  The
    estimate is deliberately one-sided: it only ever GROWS — monotone
    non-decreasing in ``gap`` and monotone in each magnitude input —
    which is the property the governor's trigger logic relies on
    (tests/test_stability_properties.py).
    """
    denom = jnp.abs(dlt_safe)
    denom = jnp.where(denom == 0, jnp.ones_like(denom), denom)
    amp = (1.0 + jnp.abs(gam_new) + jnp.abs(d2)) / denom
    return gap + kappa * eps * amp * jnp.maximum(basis, 1.0)
