"""Stability-governed deep pipelines (DESIGN.md §18).

``repro.stability`` keeps deep p(l)-CG honest about rounding: the
attainable-accuracy gap model and governor policy (``model``), and the
host-side depth-demotion ladder with its typed stagnation diagnosis
(``governor``).  The solver-side wiring lives in
``repro.core.pipelined_cg`` (``recurrence=`` / ``governor=``); the
fault-injection layer that exercises all of it is ``repro.chaos``.
"""

from repro.stability.model import (ACTION_GAP_REPLACE, ACTION_NONE,
                                   ACTION_PATIENCE_REPLACE,
                                   ACTION_STAGNATED, BEST, BEST_UPD, DUE,
                                   FRUITLESS, GAP, LAST_REL, N_SLOTS, REPL,
                                   STAGNATED, GovernorConfig, gap_step,
                                   gov_init)
from repro.stability.governor import (StagnationError, diagnose,
                                      governed_solve)

__all__ = [
    "GovernorConfig", "gap_step", "gov_init",
    "StagnationError", "diagnose", "governed_solve",
    "GAP", "BEST", "BEST_UPD", "DUE", "REPL", "FRUITLESS", "STAGNATED",
    "LAST_REL", "N_SLOTS",
    "ACTION_NONE", "ACTION_GAP_REPLACE", "ACTION_PATIENCE_REPLACE",
    "ACTION_STAGNATED",
]
