"""Host-side governed solve: the pipeline-depth demotion ladder
(DESIGN.md §18).

The in-solver governor (``repro.core.pipelined_cg`` with a
:class:`~repro.stability.model.GovernorConfig`) detects and repairs
accuracy loss *within* one compiled solve — residual replacements
through the interrupt machinery, terminal STAGNATED when replacements
stop helping.  What it cannot do from inside a ``lax.while_loop`` is
change the pipeline depth: ``l`` is a static trace parameter.  That
escalation lives here, on the host:

    result, attempts = governed_solve(backend, op, b, l=8, ...)

Each stagnated attempt halves ``l`` (never below ``min_l``) and
warm-restarts from the returned iterate — the attainable-accuracy model
says shallower pipelines round less (arXiv:1804.02962), so demotion
trades the hidden-latency budget for accuracy only when the governor
has PROVEN the current depth cannot reach tol.  When even ``l = min_l``
stagnates, a typed :class:`StagnationError` carries the full diagnosis
instead of a silently non-converged result.
"""

from __future__ import annotations

import numpy as np

from repro.stability import model as M
from repro.stability.model import GovernorConfig


class StagnationError(RuntimeError):
    """The governed solve stagnated at every pipeline depth down to
    ``min_l``: residual replacements stopped improving the TRUE residual
    before tol was reached.  ``diagnosis`` holds the per-attempt
    governor summaries (depth, replacements, best relative residual) so
    the failure is actionable — typically a genuinely inconsistent
    system, a broken operator, or injected corruption beyond the
    replacement model's reach."""

    def __init__(self, message: str, diagnosis: dict | None = None):
        super().__init__(message)
        self.diagnosis = diagnosis or {}


def diagnose(result) -> dict:
    """Summarize a governed ``SolveResult``'s final governor vector."""
    if result.governor is None:
        raise ValueError("result carries no governor state "
                         "(solve ran with governor=None)")
    g = np.asarray(result.governor)
    return {
        "gap": float(g[M.GAP]),
        "best_rel": float(g[M.BEST]),
        "replacements": int(g[M.REPL]),
        "fruitless": int(g[M.FRUITLESS]),
        "stagnated": bool(g[M.STAGNATED] > 0),
        "last_replacement_rel": float(g[M.LAST_REL]),
        "converged": bool(np.asarray(result.converged)),
        "iters": int(np.asarray(result.iters)),
    }


def governed_solve(backend, op, b, *, l: int, prec=None,
                   governor: GovernorConfig | None = None,
                   recurrence: str = "stable", min_l: int = 1,
                   ops_transform=None, **solver_kwargs):
    """Solve with the stability governor armed, demoting the pipeline
    depth on stagnation.

    Returns ``(result, attempts)`` where ``attempts`` is the list of
    per-depth :func:`diagnose` dicts (each tagged with its ``l``).  The
    ladder: solve at ``l``; any outcome the governor could NOT certify
    against the true residual — explicit STAGNATED, or the restart /
    iteration budget exhausted without truth-certified convergence
    (catastrophic corruption burns the budget in breakdown restarts
    without ever letting a governed replacement fire) — demotes: halve
    ``l`` (floor ``min_l``) and warm-restart from the returned iterate.
    A failed attempt at ``min_l`` raises :class:`StagnationError`; a
    governed solve never returns silent non-convergence.

    ``ops_transform`` (optional) rewrites the backend's
    :class:`~repro.core.types.SolverOps` before the solve — the wire
    point ``repro.chaos.chaos_ops`` uses to inject reduction-payload
    faults in tests and benchmarks.
    """
    assert min_l >= 1
    cfg = governor if governor is not None else GovernorConfig()
    x0 = solver_kwargs.pop("x0", None)
    attempts: list[dict] = []
    cur_l = int(l)

    def run(cur_l, x0):
        kw = dict(solver_kwargs, l=cur_l, recurrence=recurrence,
                  governor=cfg, **({} if x0 is None else {"x0": x0}))
        if ops_transform is None:
            return backend.solve(op, b, method="plcg", prec=prec, **kw)
        from repro.core import pipelined_cg
        return backend.run(
            lambda ops, bb: pipelined_cg.solve(ops_transform(ops), bb, **kw),
            op, b, prec=prec)

    while True:
        res = run(cur_l, x0)
        d = diagnose(res)
        d["l"] = cur_l
        attempts.append(d)
        if d["converged"]:
            return res, attempts
        if cur_l <= min_l:
            why = "stagnated" if d["stagnated"] else "exhausted its budget"
            raise StagnationError(
                f"governed p(l)-CG {why} at every depth down to l={min_l}: "
                f"best relative residual {d['best_rel']:.3e} after "
                f"{d['replacements']} governed replacement(s) at l={cur_l} "
                f"({len(attempts)} depth(s) tried)",
                diagnosis={"attempts": attempts})
        # Warm restart shallower: the iterate is the best clean state we
        # have (every replacement re-derived it from b - A x).
        x0 = res.x
        cur_l = max(min_l, cur_l // 2)
