"""Request queue + dynamic batcher for the solver service (DESIGN.md §11).

Incoming ``(operator key, b, tol)`` requests are bucketed by *slab key*
``(op_key, tol)`` — every request in a slab shares the compiled solver
(operator, tolerance, method, pipeline depth are trace-time constants;
the RHS column is runtime data).  The batcher is dynamic in the serving
sense: it never waits to fill a slab.  Free slots are handed whatever is
queued right now, partial slabs run with zero-padded columns (a zero RHS
has ``norm0 == 0`` and retires at iteration 0 — exact, not approximate),
and slots freed by retirement are re-packed from the queue between
chunks.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Hashable

import numpy as np

SlabKey = tuple[Hashable, float]       # (op_key, tol)


@dataclasses.dataclass
class SolveRequest:
    """One queued solve: right-hand side ``b`` against operator ``op_key``."""

    req_id: int
    op_key: Hashable
    b: np.ndarray
    tol: float
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def slab_key(self) -> SlabKey:
        return (self.op_key, self.tol)


class RequestQueue:
    """FIFO request buckets per slab key.

    ``submit`` assigns monotone request ids; ``take`` pops up to ``k``
    requests for one slab key (the batcher's packing step).  Iteration
    order over keys is insertion order — old traffic is not starved by
    new operators.
    """

    def __init__(self):
        self._buckets: "OrderedDict[SlabKey, deque[SolveRequest]]" = \
            OrderedDict()
        self._next_id = 0

    def submit(self, op_key: Hashable, b: np.ndarray,
               tol: float) -> SolveRequest:
        req = SolveRequest(req_id=self._next_id, op_key=op_key,
                           b=np.asarray(b), tol=float(tol))
        self._next_id += 1
        self._buckets.setdefault(req.slab_key, deque()).append(req)
        return req

    def take(self, key: SlabKey, k: int) -> list[SolveRequest]:
        bucket = self._buckets.get(key)
        if not bucket:
            return []
        out = [bucket.popleft() for _ in range(min(k, len(bucket)))]
        if not bucket:
            del self._buckets[key]
        return out

    def keys(self) -> list[SlabKey]:
        return list(self._buckets.keys())

    def pending(self, key: SlabKey | None = None) -> int:
        if key is not None:
            return len(self._buckets.get(key, ()))
        return sum(len(b) for b in self._buckets.values())

    def __len__(self) -> int:
        return self.pending()
