"""Request queue + dynamic batcher for the solver service (DESIGN.md §11/§15).

Incoming ``(operator key, b, tol)`` requests are bucketed by *slab key*
``(op_key, tol)`` — every request in a slab shares the compiled solver
(operator, tolerance, method, pipeline depth are trace-time constants;
the RHS column is runtime data).  The batcher is dynamic in the serving
sense: it never waits to fill a slab.  Free slots are handed whatever is
queued right now, partial slabs run with zero-padded columns (a zero RHS
has ``norm0 == 0`` and retires at iteration 0 — exact, not approximate),
and slots freed by retirement are re-packed from the queue between
chunks.

Since DESIGN.md §15 the batcher is also the *admission* layer: requests
carry an optional ``deadline_s`` SLO, timestamps come from an injectable
clock (``repro.serve.clock``), and :class:`AdmissionPolicy` decides at
submit time whether a request is accepted (queue-depth ceiling,
deadline feasibility) — overload is refused at the door instead of
silently inflating every queued request's latency.  Requests that were
admitted but whose deadline expires while they wait are *shed* by the
scheduler at pack time (``SolveRequest.expired``): work that can no
longer meet its SLO never occupies a slab slot.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Hashable

import numpy as np

SlabKey = tuple[Hashable, float]       # (op_key, tol)


@dataclasses.dataclass
class SolveRequest:
    """One queued solve: right-hand side ``b`` against operator ``op_key``.

    ``submitted_at`` is in the submitting clock's timeframe (virtual
    seconds under a ``VirtualClock``); ``deadline_s`` is the SLO budget
    *relative to submission* — the request should retire by
    ``submitted_at + deadline_s`` (None: no deadline).
    """

    req_id: int
    op_key: Hashable
    b: np.ndarray
    tol: float
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    deadline_s: float | None = None
    # Times this request has been requeued by the service's RetryPolicy
    # (shed -> backoff -> resubmit); drives the exponential backoff and
    # the bounded give-up.
    retries: int = 0

    @property
    def slab_key(self) -> SlabKey:
        return (self.op_key, self.tol)

    def expired(self, now: float) -> bool:
        """Deadline already blown at time ``now`` (shed candidates)."""
        return (self.deadline_s is not None
                and now - self.submitted_at > self.deadline_s)


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """SLO-aware admission control (DESIGN.md §15).

    ``max_pending``:    reject new work once this many requests are
                        queued or in flight (None: unbounded — the
                        pre-§15 behavior).  Bounding the queue bounds
                        worst-case latency: under open-loop overload an
                        unbounded queue grows without limit and EVERY
                        request misses its SLO; rejecting early keeps
                        the served fraction fast (goodput over
                        throughput).
    ``min_deadline_s``: reject deadlines at or below this floor — a
                        deadline the service cannot possibly meet is
                        refused immediately rather than accepted and
                        shed later.
    ``shed_expired``:   scheduler-side load shedding: drop queued
                        requests whose deadline already passed instead
                        of packing them into slab slots.
    """

    max_pending: int | None = None
    min_deadline_s: float = 0.0
    shed_expired: bool = True

    def check(self, pending: int, deadline_s: float | None) -> str | None:
        """Admission verdict: None to accept, else the rejection reason
        (``"queue_full"`` / ``"deadline_infeasible"``)."""
        if self.max_pending is not None and pending >= self.max_pending:
            return "queue_full"
        if deadline_s is not None and deadline_s <= self.min_deadline_s:
            return "deadline_infeasible"
        return None


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for shed requests
    (DESIGN.md §15).

    A request whose deadline expired in queue is shed by the scheduler;
    with a retry policy armed the service REQUEUES it instead of
    dropping it — after ``backoff(retries)`` seconds of service-clock
    delay and with a fresh SLO window — up to ``max_retries`` times.
    The backoff is pure arithmetic on the service clock, so replays
    under a :class:`~repro.serve.clock.VirtualClock` retry at exactly
    the same virtual instants (tests/test_serve_replay.py).

    ``max_retries = 0`` disables requeueing (the pre-§18 behavior);
    the policy then only supplies the :class:`AdmissionRejected`
    ``retry_after_s`` hint.
    """

    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_cap_s: float = 1.0

    def backoff(self, retries: int) -> float:
        """Delay before retry number ``retries + 1`` (exponential,
        capped)."""
        return min(self.backoff_cap_s,
                   self.backoff_base_s * self.backoff_factor ** retries)


class RequestQueue:
    """FIFO request buckets per slab key.

    ``submit`` assigns monotone request ids; ``take`` pops up to ``k``
    requests for one slab key (the batcher's packing step).  Iteration
    order over keys is insertion order — old traffic is not starved by
    new operators.
    """

    def __init__(self):
        self._buckets: "OrderedDict[SlabKey, deque[SolveRequest]]" = \
            OrderedDict()
        self._next_id = 0

    def submit(self, op_key: Hashable, b: np.ndarray, tol: float,
               deadline_s: float | None = None,
               now: float | None = None) -> SolveRequest:
        """Enqueue a request.  ``now`` is the submitting clock's
        timestamp (defaults to the system clock for standalone use —
        the service always passes its own clock's reading)."""
        req = SolveRequest(req_id=self._next_id, op_key=op_key,
                           b=np.asarray(b), tol=float(tol),
                           deadline_s=deadline_s)
        if now is not None:
            req.submitted_at = float(now)
        self._next_id += 1
        self._buckets.setdefault(req.slab_key, deque()).append(req)
        return req

    def take(self, key: SlabKey, k: int) -> list[SolveRequest]:
        bucket = self._buckets.get(key)
        if not bucket:
            return []
        out = [bucket.popleft() for _ in range(min(k, len(bucket)))]
        if not bucket:
            del self._buckets[key]
        return out

    def keys(self) -> list[SlabKey]:
        return list(self._buckets.keys())

    def pending(self, key: SlabKey | None = None) -> int:
        if key is not None:
            return len(self._buckets.get(key, ()))
        return sum(len(b) for b in self._buckets.values())

    def __len__(self) -> int:
        return self.pending()
