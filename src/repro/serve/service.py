"""Solver-as-a-service: slab scheduler over the batched CG family
(DESIGN.md §11).

``SolverService`` is the single-threaded, deterministic serving loop the
ROADMAP's "heavy traffic" north star asks for, built on three pieces:

* the **request queue / dynamic batcher** (``repro.serve.batcher``) packs
  incoming (op_key, b, tol) requests into fixed-width (n, s) slabs;
* the backend-compiled **slab program** (``make_slab_program``) steps a
  slab ``chunk_iters`` iterations at a time, amortizing the per-iteration
  global reduction over all s columns — one (K, s) allreduce per
  iteration however many requests are in flight;
* the **setup cache** (``repro.serve.cache``) makes repeat traffic
  against a known operator skip the block-Jacobi factorization and
  Chebyshev shift estimation.

Lifecycle per scheduler tick (``step``): pack free slots from the queue
(``inject`` re-initializes exactly those columns), run one chunk, then
retire every occupied column whose loop has stopped — converged or
iteration-capped — recording its result and latency and freeing the slot.
Converged-but-not-yet-retired columns are bitwise frozen by the while-loop
batching rule (``repro.core.batched``), so a retired iterate is unaffected
by however long its slab-mates keep running.  All device computations have
fixed (n, s) shapes: the request mix never forces a recompile.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Hashable

import jax.numpy as jnp
import numpy as np

from repro.core.batched import SlabProgram
from repro.serve.batcher import RequestQueue, SlabKey, SolveRequest
from repro.serve.cache import SetupCache


@dataclasses.dataclass
class RequestResult:
    """Retired solve: solution + per-request telemetry."""

    req_id: int
    op_key: Hashable
    x: np.ndarray
    iters: int
    converged: bool
    res_history: np.ndarray        # recorded residual norms (trimmed)
    latency_s: float               # submit -> retirement wall clock


@dataclasses.dataclass
class _Slab:
    """Runtime state of one compiled slab (one slab key)."""

    program: SlabProgram
    B: np.ndarray                          # (n, s) host-side RHS columns
    slots: list[SolveRequest | None]       # len s; None = free
    state: Any = None                      # device slab state (after init)
    B_dev: Any = None

    def free_slots(self) -> list[int]:
        return [j for j, r in enumerate(self.slots) if r is None]

    def occupied(self) -> list[int]:
        return [j for j, r in enumerate(self.slots) if r is not None]


@dataclasses.dataclass
class OperatorEntry:
    op: Any
    prec: Any
    solver_kwargs: dict


class SolverService:
    """Batched multi-RHS solver service over one reduction backend.

    Parameters
    ----------
    backend:      any ``ReductionBackend`` (local / shard_map /
                  multiprocess) — the slab programs are compiled through
                  its ``make_slab_program``.
    s:            slab width (requests solved in lock-step per slab).
    method:       "cg" | "pcg" | "plcg" (the shared METHODS keys).
    l:            pipeline depth for plcg.
    chunk_iters:  iterations per scheduler tick between retirement scans.
    maxit:        iteration cap per request (trace-time constant).
    prec:         None | "jacobi" | "block_jacobi" — per-operator setup,
                  built through the fingerprint cache.
    block_size:   block-Jacobi block size (default: one grid line /
                  shard-interior heuristic left to the caller).
    """

    def __init__(self, backend, s: int = 8, method: str = "plcg",
                 l: int = 2, chunk_iters: int = 16, maxit: int = 500,
                 prec: str | None = None, block_size: int | None = None,
                 replace_every: int = 0, cache: SetupCache | None = None):
        self.backend = backend
        self.s = int(s)
        self.method = method
        self.l = int(l)
        self.chunk_iters = int(chunk_iters)
        self.maxit = int(maxit)
        self.prec_kind = prec
        self.block_size = block_size
        self.replace_every = int(replace_every)
        self.cache = SetupCache() if cache is None else cache

        self.queue = RequestQueue()
        # Retired results are held until the caller collects them
        # (``pop_result`` / ``drain``); latency percentiles come from a
        # bounded reservoir so long-lived services don't grow stats state.
        self.results: dict[int, RequestResult] = {}
        self._latencies: deque[float] = deque(maxlen=4096)
        self._operators: dict[Hashable, OperatorEntry] = {}
        self._slabs: dict[SlabKey, _Slab] = {}
        self.chunks_run = 0
        self.retired = 0

    # -------------------------------------------------------- registry ---
    def register_operator(self, key: Hashable, op,
                          block_size: int | None = None) -> None:
        """One-time (cached) setup for an operator clients will solve
        against: preconditioner factorization + Chebyshev shifts."""
        prec = None
        if self.prec_kind == "jacobi":
            prec = self.cache.jacobi(op)
        elif self.prec_kind == "block_jacobi":
            bs = block_size or self.block_size
            assert bs, "block_jacobi needs a block_size"
            prec = self.cache.block_jacobi(op, bs)
        elif self.prec_kind is not None:
            raise ValueError(f"unknown prec kind {self.prec_kind!r}")
        kw: dict = {"maxit": self.maxit}
        if self.method == "plcg":
            kw.update(l=self.l,
                      sigmas=self.cache.sigmas(op, self.l, prec=prec))
            if self.replace_every:
                kw.update(replace_every=self.replace_every,
                          max_restarts=10 + self.maxit // self.replace_every)
        elif self.method == "pcg" and self.replace_every:
            kw.update(replace_every=self.replace_every)
        self._operators[key] = OperatorEntry(op=op, prec=prec,
                                             solver_kwargs=kw)

    # --------------------------------------------------------- clients ---
    def submit(self, op_key: Hashable, b, tol: float = 1e-8) -> int:
        """Enqueue a solve; returns the request id (see ``results``)."""
        entry = self._operators.get(op_key)
        assert entry is not None, f"operator {op_key!r} not registered"
        b = np.asarray(b)
        assert b.shape == (entry.op.n,), (b.shape, entry.op.n)
        return self.queue.submit(op_key, b, tol).req_id

    # ------------------------------------------------------- scheduler ---
    def _slab_for(self, key: SlabKey) -> _Slab:
        slab = self._slabs.get(key)
        if slab is None:
            op_key, tol = key
            entry = self._operators[op_key]
            program = self.backend.make_slab_program(
                entry.op, s=self.s, method=self.method, prec=entry.prec,
                chunk_iters=self.chunk_iters, tol=tol,
                **entry.solver_kwargs)
            B = np.zeros((entry.op.n, self.s))
            slab = _Slab(program=program, B=B, slots=[None] * self.s)
            self._slabs[key] = slab
        return slab

    def _pack(self, key: SlabKey, slab: _Slab) -> None:
        free = slab.free_slots()
        incoming = self.queue.take(key, len(free))
        if not incoming and slab.state is not None:
            return
        refresh = np.zeros((self.s,), dtype=bool)
        for j, req in zip(free, incoming):
            slab.B[:, j] = req.b
            slab.slots[j] = req
            refresh[j] = True
        slab.B_dev = jnp.asarray(slab.B)
        if slab.state is None:
            # First pack: init the whole slab (zero columns retire at 0).
            slab.state = slab.program.init(slab.B_dev)
        elif refresh.any():
            slab.state = slab.program.inject(slab.B_dev, slab.state,
                                             jnp.asarray(refresh))

    def _retire(self, key: SlabKey, slab: _Slab) -> list[RequestResult]:
        stat = slab.program.status(slab.B_dev, slab.state)
        running = np.asarray(stat.running)
        done = [j for j in slab.occupied() if not running[j]]
        if not done:
            return []
        res = slab.program.extract(slab.B_dev, slab.state)
        x = np.asarray(res.x)
        iters = np.asarray(res.iters)
        conv = np.asarray(res.converged)
        hist = np.asarray(res.res_history)
        now = time.perf_counter()
        out = []
        for j in done:
            req = slab.slots[j]
            h = hist[j]
            rr = RequestResult(
                req_id=req.req_id, op_key=req.op_key, x=x[j],
                iters=int(iters[j]), converged=bool(conv[j]),
                res_history=h[h >= 0], latency_s=now - req.submitted_at,
            )
            self.results[req.req_id] = rr
            self._latencies.append(rr.latency_s)
            slab.slots[j] = None
            self.retired += 1
            out.append(rr)
        return out

    def pop_result(self, req_id: int) -> RequestResult:
        """Collect (and release) a retired result — the steady-state
        client path: results held in the service are freed on collection
        so sustained traffic doesn't accumulate solution vectors."""
        return self.results.pop(req_id)

    def step(self) -> list[RequestResult]:
        """One scheduler tick over every slab with work: pack free slots,
        run one chunk, retire finished columns.  Returns the requests
        retired this tick."""
        retired: list[RequestResult] = []
        # Deterministic scheduling order: existing slabs in creation
        # order, then new slab keys in queue-insertion order.
        keys = list(self._slabs)
        keys += [k for k in self.queue.keys() if k not in self._slabs]
        for key in keys:
            slab = self._slab_for(key)
            self._pack(key, slab)
            if not slab.occupied():
                continue
            slab.state = slab.program.chunk(slab.B_dev, slab.state)
            self.chunks_run += 1
            retired.extend(self._retire(key, slab))
        return retired

    def drain(self, max_ticks: int = 10_000) -> dict[int, RequestResult]:
        """Run the scheduler until queue and slabs are empty."""
        for _ in range(max_ticks):
            if len(self.queue) == 0 and not any(
                    s.occupied() for s in self._slabs.values()):
                break
            self.step()
        else:
            raise RuntimeError("drain: max_ticks exceeded "
                               "(requests not converging?)")
        return self.results

    # ------------------------------------------------------- telemetry ---
    def reset_stats(self) -> None:
        """Zero the latency reservoir and counters (e.g. after a compile
        warmup, so percentiles reflect steady-state traffic only)."""
        self._latencies.clear()
        self.chunks_run = 0
        self.retired = 0

    def stats(self) -> dict:
        lats = sorted(self._latencies)

        def pct(p):
            if not lats:
                return 0.0
            return lats[min(int(p / 100 * len(lats)), len(lats) - 1)]

        return {
            "retired": self.retired,
            "pending": len(self.queue),
            "chunks_run": self.chunks_run,
            "slabs": len(self._slabs),
            "latency_p50_s": pct(50),
            "latency_p99_s": pct(99),
            "setup_cache": self.cache.stats(),
        }
