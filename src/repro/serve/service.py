"""Solver-as-a-service: continuous-batching serve over the batched CG
family (DESIGN.md §11/§15).

``SolverService`` is the single-threaded, deterministic serving loop the
ROADMAP's "heavy traffic" north star asks for, built on four pieces:

* the **request queue / admission layer** (``repro.serve.batcher``)
  buckets incoming (op_key, b, tol, deadline) requests and applies the
  :class:`AdmissionPolicy` (queue-depth ceiling, deadline feasibility)
  at the door;
* the **multi-slab scheduler** (``repro.serve.scheduler``) runs a pool
  of slab workers — per slab key, plus replicated workers for hot keys —
  with work stealing, continuous slot injection at every chunk boundary,
  and deadline-based load shedding;
* the backend-compiled **slab program** (``make_slab_program``) steps
  each slab ``chunk_iters`` iterations at a time, amortizing the
  per-iteration global reduction over all s columns — one (K, s)
  allreduce per iteration per slab however many requests are in flight
  (arXiv:1905.06850's amortized-reduction win);
* the **setup cache** (``repro.serve.cache``) makes repeat traffic
  against a known operator skip the block-Jacobi factorization and
  Chebyshev shift estimation.

All timestamps — request submission, retirement latency, deadline
checks — come from an injectable clock (``repro.serve.clock``): under a
:class:`VirtualClock` the whole service is bit-for-bit deterministic,
which is what the open-loop traffic replay harness
(``repro.serve.replay``) and tests/test_serve_replay.py rely on.

Lifecycle per scheduler tick (``step``): route queued requests to
workers, pack free slots (``inject`` re-initializes exactly those
columns, uploading only the changed ones), run one chunk on every busy
slab (dispatched back-to-back so slabs overlap), then retire every
occupied column whose loop has stopped — converged or iteration-capped —
recording its result and latency and freeing the slot.  Converged-but-
not-yet-retired columns are bitwise frozen by the while-loop batching
rule (``repro.core.batched``), so a retired iterate is unaffected by
however long its slab-mates keep running.  All device computations have
fixed (n, s) shapes: the request mix never forces a recompile.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Hashable

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serve.batcher import (AdmissionPolicy, RequestQueue, RetryPolicy,
                                 SlabKey, SolveRequest)
from repro.serve.cache import SetupCache
from repro.serve.clock import Clock, SystemClock
from repro.serve.errors import (AdmissionRejected, BadRequestError,
                                ConfigError, UnknownOperatorError)
from repro.serve.scheduler import SlabScheduler


@dataclasses.dataclass
class RequestResult:
    """Retired solve: solution + per-request telemetry.

    ``shed`` results carry ``x=None`` — the request was dropped
    unstarted because its deadline expired in queue (load shedding);
    ``slo_met`` is converged-within-deadline (requests without a
    deadline count as met when converged), the numerator of goodput.
    """

    req_id: int
    op_key: Hashable
    x: np.ndarray | None
    iters: int
    converged: bool
    res_history: np.ndarray        # recorded residual norms (trimmed)
    latency_s: float               # submit -> retirement (service clock)
    worker: int = 0                # slab worker that ran it
    deadline_s: float | None = None
    shed: bool = False
    slo_met: bool = True


@dataclasses.dataclass
class OperatorEntry:
    op: Any
    prec: Any
    solver_kwargs: dict


class SolverService:
    """Batched multi-RHS solver service over one reduction backend.

    Parameters
    ----------
    backend:      any ``ReductionBackend`` (local / shard_map /
                  multiprocess) — the slab programs are compiled through
                  its ``make_slab_program``.
    s:            slab width (requests solved in lock-step per slab).
    method:       "cg" | "pcg" | "plcg" (the shared METHODS keys).
    l:            pipeline depth for plcg.
    chunk_iters:  iterations per scheduler tick between retirement scans.
    maxit:        iteration cap per request (trace-time constant).
    prec:         None | "jacobi" | "block_jacobi" — per-operator setup,
                  built through the fingerprint cache.
    block_size:   block-Jacobi block size (default: one grid line /
                  shard-interior heuristic left to the caller).
    clock:        time source (default :class:`SystemClock`); inject a
                  :class:`~repro.serve.clock.VirtualClock` for
                  deterministic scheduling/latency accounting.
    admission:    :class:`AdmissionPolicy` (default: admit everything —
                  the pre-§15 behavior).
    max_replicas: slab workers allowed per slab key (>1 enables hot-key
                  scale-out; replicas share the compiled program).
    replicate_watermark:  spawn a replica when every existing worker's
                  backlog is >= watermark * s.
    steal:        idle workers steal queued requests from same-key
                  siblings (deterministic; logged).
    continuous:   refill freed slots at every chunk boundary.  False =
                  drain-to-empty baseline (slots recycle only once a
                  slab is fully empty) — kept for the utilization
                  comparison in BENCH_serve.json.
    retry:        :class:`~repro.serve.batcher.RetryPolicy` — requeue
                  shed requests after exponential backoff (bounded by
                  ``max_retries``, fresh SLO window per attempt) instead
                  of dropping them, and attach a ``retry_after_s`` hint
                  to queue-full :class:`AdmissionRejected`.  None
                  (default) keeps the drop-on-shed behavior.  Pure
                  service-clock arithmetic: deterministic under a
                  VirtualClock replay.
    registry:     :class:`~repro.obs.metrics.MetricsRegistry` all serve
                  stats report through (DESIGN.md §16); default a fresh
                  per-service registry so two services never share
                  series.  The pre-§16 stat attributes (``retired``,
                  ``rejected``, ``shed``, ``slo_met``, ``_latencies``)
                  remain as read-only views onto it for one release.
    telemetry_cap: rows of the on-device telemetry ring per slab column
                  (plcg only, DESIGN.md §16).  0 (default) compiles the
                  ring out entirely; >0 appends a (cap, 2l+10) ring to
                  each column's donated state — zero extra collectives,
                  zero host transfers, bitwise-invisible to the
                  arithmetic (tests/test_telemetry.py).
    """

    def __init__(self, backend, s: int = 8, method: str = "plcg",
                 l: int = 2, chunk_iters: int = 16, maxit: int = 500,
                 prec: str | None = None, block_size: int | None = None,
                 replace_every: int = 0, cache: SetupCache | None = None,
                 clock: Clock | None = None,
                 admission: AdmissionPolicy | None = None,
                 max_replicas: int = 1, replicate_watermark: float = 1.0,
                 steal: bool = True, continuous: bool = True,
                 registry: MetricsRegistry | None = None,
                 telemetry_cap: int = 0,
                 retry: RetryPolicy | None = None,
                 fault_injector=None):
        self.backend = backend
        self.s = int(s)
        self.method = method
        self.l = int(l)
        self.chunk_iters = int(chunk_iters)
        self.maxit = int(maxit)
        self.prec_kind = prec
        self.block_size = block_size
        self.replace_every = int(replace_every)
        self.telemetry_cap = int(telemetry_cap)
        if self.telemetry_cap and method != "plcg":
            raise ConfigError("telemetry_cap needs method='plcg' "
                              f"(got {method!r})")
        self.registry = MetricsRegistry() if registry is None else registry
        self.cache = (SetupCache(registry=self.registry) if cache is None
                      else cache)
        self.clock = SystemClock() if clock is None else clock
        self.admission = AdmissionPolicy() if admission is None else admission
        self.retry = retry
        # Backoff queue of requeued shed requests: (due_t, req_id, req)
        # min-heap on the service clock — req_id tiebreak keeps the pop
        # order deterministic under a VirtualClock.
        self._retry_q: list[tuple[float, int, SolveRequest]] = []

        self.queue = RequestQueue()
        self.scheduler = SlabScheduler(
            self._make_program, max_replicas=max_replicas,
            replicate_watermark=replicate_watermark, steal=steal,
            continuous=continuous,
            shed_expired=self.admission.shed_expired,
            registry=self.registry,
            fault_injector=fault_injector)
        # Retired results are held until the caller collects them
        # (``pop_result`` / ``drain``); latency percentiles come from a
        # bounded reservoir so long-lived services don't grow stats state.
        self.results: dict[int, RequestResult] = {}
        self._operators: dict[Hashable, OperatorEntry] = {}
        # Retirement log: (req_id, worker, tick, t) in retirement order —
        # the determinism witness the replay tests compare bitwise.
        self.retirement_log: list[tuple[int, int, int, float]] = []
        # Request lifecycle stats, all registry series (DESIGN.md §16).
        m = self.registry
        self._c_retired = m.counter(
            "serve_requests_retired_total", "requests retired with a result")
        self._c_rejected = m.counter(
            "serve_requests_rejected_total", "requests refused at admission")
        self._c_shed = m.counter(
            "serve_requests_shed_total",
            "requests dropped unstarted (deadline expired in queue)")
        self._c_slo = m.counter(
            "serve_requests_slo_met_total",
            "requests converged within their deadline")
        self._c_retried = m.counter(
            "serve_requests_retried_total",
            "shed requests requeued by the retry policy")
        self._c_resubmitted = m.counter(
            "serve_requests_resubmitted_total",
            "in-flight requests of a dead worker resubmitted with a "
            "fresh SLO window")
        self._h_latency = m.histogram(
            "serve_request_latency_seconds",
            "submit -> retirement latency (bounded reservoir)")

    # -------------------------------------------------------- registry ---
    def register_operator(self, key: Hashable, op,
                          block_size: int | None = None) -> None:
        """One-time (cached) setup for an operator clients will solve
        against: preconditioner factorization + Chebyshev shifts."""
        if not hasattr(op, "n") or not hasattr(op, "apply"):
            raise ConfigError(
                f"operator for {key!r} must expose .n and .apply "
                f"(got {type(op).__name__})")
        prec = None
        if self.prec_kind == "jacobi":
            prec = self.cache.jacobi(op)
        elif self.prec_kind == "block_jacobi":
            bs = block_size or self.block_size
            if not bs:
                raise ConfigError("block_jacobi needs a block_size "
                                  "(service or register_operator kwarg)")
            prec = self.cache.block_jacobi(op, bs)
        elif self.prec_kind is not None:
            raise ConfigError(f"unknown prec kind {self.prec_kind!r}")
        kw: dict = {"maxit": self.maxit}
        if self.method == "plcg":
            kw.update(l=self.l,
                      sigmas=self.cache.sigmas(op, self.l, prec=prec))
            if self.telemetry_cap:
                kw.update(telemetry_cap=self.telemetry_cap)
            if self.replace_every:
                kw.update(replace_every=self.replace_every,
                          max_restarts=10 + self.maxit // self.replace_every)
        elif self.method == "pcg" and self.replace_every:
            kw.update(replace_every=self.replace_every)
        self._operators[key] = OperatorEntry(op=op, prec=prec,
                                             solver_kwargs=kw)

    def _make_program(self, key: SlabKey):
        """Compile the slab program for one slab key (scheduler callback;
        replicas share the result)."""
        op_key, tol = key
        entry = self._operators[op_key]
        return self.backend.make_slab_program(
            entry.op, s=self.s, method=self.method, prec=entry.prec,
            chunk_iters=self.chunk_iters, tol=tol, **entry.solver_kwargs)

    # --------------------------------------------------------- clients ---
    @property
    def pending(self) -> int:
        """Admitted-but-unfinished request count (queue + worker queues +
        in-flight slots + backoff-delayed retries) — the admission
        policy's queue-depth metric."""
        return (len(self.queue) + self.scheduler.backlog()
                + self.scheduler.in_flight() + len(self._retry_q))

    def submit(self, op_key: Hashable, b, tol: float = 1e-8,
               deadline_s: float | None = None) -> int:
        """Enqueue a solve; returns the request id (see ``results``).

        Raises :class:`UnknownOperatorError` / :class:`BadRequestError`
        on malformed requests and :class:`AdmissionRejected` when the
        admission policy refuses the work (queue full, hopeless
        deadline).
        """
        entry = self._operators.get(op_key)
        if entry is None:
            raise UnknownOperatorError(op_key)
        b = np.asarray(b)
        if b.shape != (entry.op.n,):
            raise BadRequestError(
                f"RHS shape {b.shape} != ({entry.op.n},) for {op_key!r}")
        if not np.issubdtype(b.dtype, np.floating):
            raise BadRequestError(f"RHS dtype {b.dtype} is not floating")
        if not np.isfinite(b).all():
            raise BadRequestError("RHS contains non-finite entries")
        tol = float(tol)
        if not (tol >= 0.0):            # NaN fails this too
            raise BadRequestError(f"tol must be >= 0 (got {tol})")
        if deadline_s is not None:
            deadline_s = float(deadline_s)
            if not np.isfinite(deadline_s):
                raise BadRequestError(f"deadline_s must be finite "
                                      f"(got {deadline_s})")
        reason = self.admission.check(self.pending, deadline_s)
        if reason is not None:
            self._c_rejected.inc()
            # Backoff hint: queue pressure drains, so suggest the retry
            # policy's first backoff; an infeasible deadline gets none
            # (resubmitting the same deadline can never be admitted).
            hint = (self.retry.backoff(0)
                    if self.retry is not None and reason == "queue_full"
                    else None)
            raise AdmissionRejected(reason, f"pending={self.pending}",
                                    retry_after_s=hint)
        return self.queue.submit(op_key, b, tol, deadline_s=deadline_s,
                                 now=self.clock.now()).req_id

    # ------------------------------------------------------- scheduler ---
    def _dispatch_queue(self) -> None:
        """Route every queued request to a slab worker (insertion-fair
        over keys; FIFO within a key)."""
        for key in self.queue.keys():
            for req in self.queue.take(key, self.queue.pending(key)):
                self.scheduler.dispatch(req)

    def _record(self, req: SolveRequest, *, worker: int, x, iters: int,
                converged: bool, res_history, shed: bool,
                now: float) -> RequestResult:
        latency = now - req.submitted_at
        met = (not shed and converged
               and (req.deadline_s is None or latency <= req.deadline_s))
        rr = RequestResult(
            req_id=req.req_id, op_key=req.op_key, x=x, iters=iters,
            converged=converged, res_history=res_history,
            latency_s=latency, worker=worker, deadline_s=req.deadline_s,
            shed=shed, slo_met=met)
        self.results[req.req_id] = rr
        if shed:
            self._c_shed.inc()
        else:
            self._h_latency.observe(latency)
            self._c_retired.inc()
            self.retirement_log.append(
                (req.req_id, worker, self.scheduler.ticks, now))
        if met:
            self._c_slo.inc()
        return rr

    def _release_due_retries(self, now: float) -> None:
        """Move backoff-expired retries back onto the workers (fresh SLO
        window: the deadline re-anchors at the release instant)."""
        while self._retry_q and self._retry_q[0][0] <= now:
            _due, _rid, req = heapq.heappop(self._retry_q)
            req.submitted_at = now
            self.scheduler.dispatch(req)

    def _maybe_requeue(self, req: SolveRequest, now: float,
                       counter=None) -> bool:
        """Shed-path retry: True when the request was requeued with
        backoff instead of dropped (bounded by the policy)."""
        if self.retry is None or req.retries >= self.retry.max_retries:
            return False
        delay = self.retry.backoff(req.retries)
        req.retries += 1
        heapq.heappush(self._retry_q, (now + delay, req.req_id, req))
        (self._c_retried if counter is None else counter).inc()
        return True

    def step(self) -> list[RequestResult]:
        """One scheduler tick over every slab with work: release due
        retries, dispatch, pack free slots, chunk all busy slabs, retire
        finished columns.  Returns the requests retired (or shed) this
        tick.

        Requests stranded by a worker death (``TickReport.failed``) are
        resubmitted through the retry policy with a fresh SLO window —
        the deadline re-anchors when the backoff releases them — and
        shed-recorded only on exhausted retries (DESIGN.md §19)."""
        self._release_due_retries(self.clock.now())
        self._dispatch_queue()
        report = self.scheduler.tick(self.clock.now())
        now = self.clock.now()
        out = []
        for rc in report.retired:
            out.append(self._record(
                rc.req, worker=rc.worker, x=rc.x, iters=rc.iters,
                converged=rc.converged, res_history=rc.res_history,
                shed=False, now=now))
        for req in report.shed:
            if self._maybe_requeue(req, now):
                continue
            out.append(self._record(
                req, worker=-1, x=None, iters=0, converged=False,
                res_history=np.empty(0), shed=True, now=now))
        for req in report.failed:
            if self._maybe_requeue(req, now, counter=self._c_resubmitted):
                continue
            out.append(self._record(
                req, worker=-1, x=None, iters=0, converged=False,
                res_history=np.empty(0), shed=True, now=now))
        return out

    def drain(self, max_ticks: int = 10_000) -> dict[int, RequestResult]:
        """Run the scheduler until queue and slabs are empty.  When the
        only remaining work is backoff-delayed retries, the clock sleeps
        to the next due instant (advancing a VirtualClock exactly)."""
        for _ in range(max_ticks):
            if self.pending == 0:
                break
            if self._retry_q and self.pending == len(self._retry_q):
                self.clock.sleep(
                    max(self._retry_q[0][0] - self.clock.now(), 0.0))
            self.step()
        else:
            raise RuntimeError("drain: max_ticks exceeded "
                               "(requests not converging?)")
        return self.results

    def pop_result(self, req_id: int) -> RequestResult:
        """Collect (and release) a retired result — the steady-state
        client path: results held in the service are freed on collection
        so sustained traffic doesn't accumulate solution vectors."""
        return self.results.pop(req_id)

    # ------------------------------------------------------- telemetry ---
    @property
    def chunks_run(self) -> int:
        return self.scheduler.chunks_run

    # Thin read-only views of the registry series — the pre-§16 stats
    # API, kept for one release (tests assert view/registry parity).
    @property
    def retired(self) -> int:
        return int(self._c_retired.value())

    @property
    def rejected(self) -> int:
        return int(self._c_rejected.value())

    @property
    def shed(self) -> int:
        return int(self._c_shed.value())

    @property
    def retried(self) -> int:
        return int(self._c_retried.value())

    @property
    def resubmitted(self) -> int:
        return int(self._c_resubmitted.value())

    @property
    def worker_deaths(self) -> int:
        return int(self.scheduler._c_deaths.value())

    @property
    def slo_met(self) -> int:
        return int(self._c_slo.value())

    @property
    def _latencies(self):
        return self._h_latency.reservoir()

    def reset_stats(self) -> None:
        """Zero the latency reservoir and counters (e.g. after a compile
        warmup, so percentiles reflect steady-state traffic only)."""
        self._h_latency.clear()
        self._c_retired.reset()
        self._c_rejected.reset()
        self._c_shed.reset()
        self._c_slo.reset()
        self._c_retried.reset()
        self._c_resubmitted.reset()
        self.retirement_log.clear()
        self.scheduler.reset_stats()

    def stats(self) -> dict:
        sched = self.scheduler
        return {
            "retired": self.retired,
            "pending": self.pending,
            "chunks_run": sched.chunks_run,
            "slabs": len(sched._programs),
            "workers": len(sched.workers),
            "rejected": self.rejected,
            "shed": self.shed,
            "retried": self.retried,
            "resubmitted": self.resubmitted,
            "worker_deaths": self.worker_deaths,
            "slo_met": self.slo_met,
            "stolen": len(sched.steal_log),
            "slot_utilization": sched.slot_utilization(),
            "uploaded_cols": sum(w.uploaded_cols for w in sched.workers),
            "full_uploads": sum(w.full_uploads for w in sched.workers),
            "latency_p50_s": self._h_latency.quantile(50),
            "latency_p99_s": self._h_latency.quantile(99),
            "setup_cache": self.cache.stats(),
        }

    def metrics_snapshot(self) -> dict:
        """Registry snapshot stamped with the SERVICE clock — under a
        VirtualClock two replays of the same trace export byte-identical
        snapshots (DESIGN.md §16)."""
        self._export_gauges()
        return self.registry.snapshot(self.clock)

    def metrics_text(self) -> str:
        """Prometheus text exposition of the service registry."""
        self._export_gauges()
        return self.registry.to_prometheus_text()

    def _export_gauges(self) -> None:
        """Point-in-time gauges refreshed at export (cheap derived
        state; counters/histograms update at the event sites)."""
        g = self.registry.gauge
        g("serve_pending_requests",
          "admitted but unfinished requests").set(self.pending)
        g("serve_workers", "live slab workers").set(
            len(self.scheduler.workers))
        g("serve_slabs", "compiled slab programs").set(
            len(self.scheduler._programs))
        g("serve_slot_utilization",
          "occupied-slot-iterations / capacity").set(
            self.scheduler.slot_utilization())
