"""Typed error hierarchy for the serving layer (DESIGN.md §15).

The service originally validated requests with ``assert`` — which
vanishes under ``python -O``, turning a malformed request into a shape
error (or silent corruption) deep inside a compiled slab.  Every
client-visible failure is now a :class:`ServeError` subclass raised at
the service boundary, so callers can distinguish "your request is
wrong" (:class:`BadRequestError`, :class:`UnknownOperatorError`), "the
service is misconfigured" (:class:`ConfigError`) and "the service is
protecting itself" (:class:`AdmissionRejected` — SLO-aware admission
control / load shedding, the open-loop overload story).

``BadRequestError``/``ConfigError`` double as ``ValueError`` and
``UnknownOperatorError`` as ``KeyError`` so pre-existing callers that
caught the builtin types keep working.
"""

from __future__ import annotations


class ServeError(Exception):
    """Base class for every serving-layer failure."""


class UnknownOperatorError(ServeError, KeyError):
    """Request names an operator key that was never registered."""

    def __init__(self, op_key):
        super().__init__(f"operator {op_key!r} not registered")
        self.op_key = op_key

    def __str__(self) -> str:          # KeyError quotes its arg; don't
        return self.args[0]


class BadRequestError(ServeError, ValueError):
    """Malformed solve request: wrong RHS shape, non-finite entries,
    nonsensical tolerance or deadline."""


class ConfigError(ServeError, ValueError):
    """Service/operator registration misconfiguration (unknown
    preconditioner kind, missing block size, bad scheduler knobs)."""


class WorkerFault(ServeError, RuntimeError):
    """A slab worker's backing program/process faulted mid-serve (device
    runtime error, dead fabric rank, injected chaos fault).  The
    scheduler tears the worker down and hands its unretired in-flight
    requests back to the service for resubmission through the retry
    policy (DESIGN.md §19 self-healing serve)."""


class AdmissionRejected(ServeError):
    """Request refused by the admission policy (queue depth above the
    configured ceiling, or a deadline that cannot be met).

    ``reason`` is machine-readable: ``"queue_full"`` or
    ``"deadline_infeasible"``.  ``retry_after_s`` is the service's
    backoff hint: resubmit no sooner than this many (service-clock)
    seconds, or None when retrying cannot help (an infeasible deadline
    stays infeasible; a service without a retry policy offers no hint).
    """

    def __init__(self, reason: str, detail: str = "",
                 retry_after_s: float | None = None):
        hint = (f"; retry after {retry_after_s:g}s"
                if retry_after_s is not None else "")
        super().__init__(f"admission rejected ({reason})"
                         + (f": {detail}" if detail else "") + hint)
        self.reason = reason
        self.retry_after_s = retry_after_s
